"""Serving substrate: DRS-scheduled prefill/decode disaggregation."""

from .pipeline import ServingModel, StageRates, rates_from_dryrun
from .router import ServingReport, ServingSimulation

__all__ = [
    "ServingModel", "StageRates", "rates_from_dryrun",
    "ServingReport", "ServingSimulation",
]
