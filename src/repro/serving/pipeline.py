"""LLM serving as a DRS-scheduled operator network (DESIGN.md §2).

The serving pipeline has two device-side operators — **prefill** and
**decode** — plus host-side tokenize/detokenize.  Autoregressive decoding
is a Jackson self-loop: a request that just produced a token returns to
the decode queue with probability p = 1 - 1/E[output_len], so the traffic
equations automatically give lambda_decode = lambda_0 * E[output_len].
DRS Program (4)/(6) then splits chips between the prefill and decode
groups — the principled version of the disaggregated-serving capacity
split (DistServe et al. tune this by hand).

Service rates come from the dry-run roofline (model-based prior; the
measurer corrects online):  a chip group of size k running the compiled
step whose roofline bound is T_bound(chips_0) has

    mu(k) ~ batch_unit / (T_bound * chips_0 / k)        (work-conserving)

i.e. replica/group scaling per OperatorSpec.scaling (see core/jackson.py).
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

from ..api import AppGraph, Edge, OpDef
from ..core.allocator import AllocationResult, allocate
from ..core.jackson import Topology

__all__ = ["StageRates", "ServingModel", "rates_from_dryrun"]


@dataclass(frozen=True)
class StageRates:
    """Per-chip service rates (requests/sec/chip) for the two stages."""

    prefill_per_chip: float  # prompts/sec per chip
    decode_per_chip: float  # tokens/sec per chip (one decode visit = 1 token)


def rates_from_dryrun(
    arch: str,
    results_dir: str | Path,
    mesh: str = "pod16x16",
) -> StageRates:
    """Derive mu priors from the dry-run roofline records.

    The bound time for the compiled step is max(compute, memory,
    collective); the step processes `global_batch` requests (prefill) or
    `global_batch` tokens (decode) on `chips` chips.
    """
    results_dir = Path(results_dir)

    def load(shape):
        p = results_dir / f"{arch}--{shape}--{mesh}.json"
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            raise FileNotFoundError(f"no ok dry-run for {arch} x {shape}")
        r = rec["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        return bound, rec

    pre_bound, pre = load("prefill_32k")
    dec_bound, dec = load("decode_32k")
    pre_batch = 32  # requests per compiled prefill step
    dec_batch = 128  # tokens per compiled decode step
    chips = pre["chips"]
    return StageRates(
        prefill_per_chip=pre_batch / (pre_bound * chips),
        decode_per_chip=dec_batch / (dec_bound * chips),
    )


class ServingModel:
    """Jackson model of the serving pipeline + DRS allocation calls."""

    def __init__(
        self,
        rates: StageRates,
        *,
        mean_output_tokens: float = 64.0,
        group_alpha: float = 0.01,
        host_tokenize_rate: float = 2000.0,
    ):
        if mean_output_tokens < 1:
            raise ValueError("mean_output_tokens must be >= 1")
        self.rates = rates
        self.mean_out = mean_output_tokens
        self.group_alpha = group_alpha
        self.host_rate = host_tokenize_rate
        self._names: list[str] | None = None

    def graph(self, lam0: float) -> AppGraph:
        """The pipeline as a declarative AppGraph: tokenize(host) ->
        prefill -> decode (leaking self-loop) -> detokenize(host).

        Chip-group stages use "group" scaling (one gang per stage; mu
        grows ~linearly with the group's chips, with an efficiency rolloff
        alpha from the collective share).  Autoregressive decoding is the
        typed edge ``decode -> decode`` at ``p = 1 - 1/E[output_len]`` —
        the traffic equations then give lambda_decode = lam0 * E[len].
        """
        p_loop = 1.0 - 1.0 / self.mean_out
        edges = [
            Edge("tokenize", "prefill"),
            Edge("prefill", "decode"),  # first token
            Edge("decode", "detokenize", multiplicity=1.0 - p_loop),
        ]
        if p_loop > 0:  # mean_output_tokens == 1: single visit, no loop
            edges.append(Edge("decode", "decode", multiplicity=p_loop))
        return AppGraph(
            [
                OpDef("tokenize", mu=self.host_rate),
                OpDef(
                    "prefill", mu=self.rates.prefill_per_chip, scaling="group",
                    group_alpha=self.group_alpha,
                ),
                OpDef(
                    "decode", mu=self.rates.decode_per_chip, scaling="group",
                    group_alpha=self.group_alpha,
                ),
                OpDef("detokenize", mu=self.host_rate),
            ],
            edges,
            {"tokenize": lam0},
        )

    @property
    def names(self) -> list[str]:
        if self._names is None:
            self._names = self.graph(0.0).names
        return self._names

    def topology(self, lam0: float) -> Topology:
        """Compiled Jackson model of :meth:`graph` (back-compat surface)."""
        return self.graph(lam0).topology()

    def plan(
        self,
        lam0: float,
        *,
        k_max: int | None = None,
        t_max: float | None = None,
    ) -> AllocationResult:
        """DRS allocation for the pipeline (Program 4 and/or 6)."""
        return allocate(self.topology(lam0), k_max=k_max, t_max=t_max)

    def split(self, alloc: AllocationResult) -> dict[str, int]:
        return dict(zip(self.names, alloc.k.tolist()))

    def expected_latency(self, lam0: float, k: dict[str, int]) -> float:
        graph = self.graph(lam0)
        return graph.topology().expected_sojourn(graph.k_vector(k))
