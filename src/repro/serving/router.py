"""Serving runtime: request router + continuous batching + DRS control.

Runs in **simulated time** on the DES substrate (streaming/des.py) —
the same queueing dynamics a real router sees, with service rates taken
from the dry-run roofline — and exposes the DRS control loop end-to-end:
requests arrive, the measurer estimates (lambda, mu), the scheduler
rebalances chips between prefill and decode groups, latency recovers.

benchmarks/bench_serving.py drives this to produce the DRS-vs-static
comparison; examples/serve_drs.py is the narrative walkthrough.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.allocator import assign_processors
from ..core.jackson import Topology
from ..streaming.des import ArrivalProcess, NetworkSimulator, ServiceProcess, SimConfig
from .pipeline import ServingModel

__all__ = ["ServingSimulation", "ServingReport"]


@dataclass
class ServingReport:
    mean_latency: float
    p95_latency: float
    completed: int
    allocation: dict[str, int]
    model_latency: float
    sojourn_series: list = field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "mean_latency": self.mean_latency,
            "p95_latency": self.p95_latency,
            "completed": self.completed,
            "allocation": self.allocation,
            "model_latency": self.model_latency,
        }


class ServingSimulation:
    """DES-backed serving run under a fixed or DRS-chosen allocation."""

    def __init__(
        self,
        model: ServingModel,
        lam0: float,
        *,
        seed: int = 0,
        horizon: float = 600.0,
        warmup: float = 60.0,
    ):
        self.model = model
        self.lam0 = lam0
        self.seed = seed
        self.horizon = horizon
        self.warmup = warmup

    def run(
        self,
        allocation: dict[str, int],
        *,
        rebalance_to: dict[str, int] | None = None,
        rebalance_at: float | None = None,
        arrival_kind: str = "exponential",
    ) -> ServingReport:
        top = self.model.topology(self.lam0)
        k = np.array(
            [allocation[n] for n in ("tokenize", "prefill", "decode", "detokenize")]
        )
        # group-scaled stages are modeled in the DES as single fast servers
        # (M/M/1 at mu_eff) to mirror OperatorSpec.scaling="group".
        services, k_eff = [], []
        for i, op in enumerate(top.operators):
            if op.scaling == "group":
                eff = 1.0 / (1.0 + op.group_alpha * (int(k[i]) - 1))
                services.append(ServiceProcess(rate=op.mu * int(k[i]) * eff))
                k_eff.append(1)
            else:
                services.append(ServiceProcess(rate=op.mu))
                k_eff.append(int(k[i]))
        arrivals = [
            ArrivalProcess(rate=float(top.lam0[i]), kind=arrival_kind)
            for i in range(top.n)
        ]
        sim = NetworkSimulator(
            top,
            np.array(k_eff),
            config=SimConfig(seed=self.seed, horizon=self.horizon, warmup=self.warmup),
            arrivals=arrivals,
            services=services,
        )
        if rebalance_to is not None and rebalance_at is not None:
            k2 = np.array(
                [rebalance_to[n] for n in ("tokenize", "prefill", "decode", "detokenize")]
            )
            k2_eff = []
            for i, op in enumerate(top.operators):
                k2_eff.append(1 if op.scaling == "group" else int(k2[i]))
            # service-rate changes for the group stages
            for i, op in enumerate(top.operators):
                if op.scaling == "group":
                    eff = 1.0 / (1.0 + op.group_alpha * (int(k2[i]) - 1))
                    sim.schedule_rate_change(rebalance_at, i, op.mu * int(k2[i]) * eff)
            sim.rebalance_at(rebalance_at, np.array(k2_eff), pause=1.0)
        res = sim.run()
        return ServingReport(
            mean_latency=res.mean_sojourn,
            p95_latency=res.p95_sojourn,
            completed=res.completed,
            allocation=dict(allocation),
            model_latency=float(top.expected_sojourn(self._k_model(top, k))),
            sojourn_series=res.sojourn_series,
        )

    @staticmethod
    def _k_model(top: Topology, k: np.ndarray) -> np.ndarray:
        return k

    def drs_allocation(self, k_max: int) -> dict[str, int]:
        alloc = assign_processors(self.model.topology(self.lam0), k_max)
        return self.model.split(alloc)
