"""Serving runtime: request router + continuous batching + DRS control.

Runs in **simulated time** on the DES substrate via the declarative API
(``ServingModel.graph(lam0).bind("des")``) — the same queueing dynamics a
real router sees, with service rates taken from the dry-run roofline —
and exposes the DRS control loop end-to-end: requests arrive, the measurer
estimates (lambda, mu), the scheduler rebalances chips between prefill and
decode groups, latency recovers.  The group-scaled chip-gang conversion
(one effective server at ``mu * k * eff(k)`` per gang, DESIGN.md §2) is
owned by :class:`~repro.api.DESBackend`, not hand-rolled here.

benchmarks/bench_serving.py drives this to produce the DRS-vs-static
comparison; examples/serve_drs.py is the narrative walkthrough.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..api import DRSSession
from ..core.allocator import assign_processors
from .pipeline import ServingModel

__all__ = ["ServingSimulation", "ServingReport"]


@dataclass
class ServingReport:
    mean_latency: float
    p95_latency: float
    completed: int
    allocation: dict[str, int]
    model_latency: float
    sojourn_series: list = field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "mean_latency": self.mean_latency,
            "p95_latency": self.p95_latency,
            "completed": self.completed,
            "allocation": self.allocation,
            "model_latency": self.model_latency,
        }


class ServingSimulation:
    """DES-backed serving run under a fixed or DRS-chosen allocation."""

    def __init__(
        self,
        model: ServingModel,
        lam0: float,
        *,
        seed: int = 0,
        horizon: float = 600.0,
        warmup: float = 60.0,
    ):
        self.model = model
        self.lam0 = lam0
        self.seed = seed
        self.horizon = horizon
        self.warmup = warmup
        self.graph = model.graph(lam0)

    def session(self, *, arrival_kind: str = "exponential") -> DRSSession:
        """The serving graph bound to the DES backend."""
        return self.graph.bind(
            "des",
            seed=self.seed,
            horizon=self.horizon,
            warmup=self.warmup,
            arrival_kind=arrival_kind,
        )

    def run(
        self,
        allocation: dict[str, int],
        *,
        rebalance_to: dict[str, int] | None = None,
        rebalance_at: float | None = None,
        arrival_kind: str = "exponential",
    ) -> ServingReport:
        session = self.session(arrival_kind=arrival_kind)
        res = session.simulate(
            allocation,
            rebalance_to=rebalance_to,
            rebalance_at=rebalance_at,
            pause=1.0,
        )
        top = self.graph.topology()
        return ServingReport(
            mean_latency=res.mean_sojourn,
            p95_latency=res.p95_sojourn,
            completed=res.completed,
            allocation=dict(allocation),
            model_latency=float(top.expected_sojourn(self.graph.k_vector(allocation))),
            sojourn_series=res.sojourn_series,
        )

    def drs_allocation(self, k_max: int) -> dict[str, int]:
        alloc = assign_processors(self.graph.topology(), k_max)
        return self.graph.k_dict(alloc.k)
