"""Model assembly for all assigned architecture families.

Families (config.family):
  dense   — llama3.2-1b / yi-34b / phi3-medium-14b / command-r-35b (GQA,
            RoPE, SwiGLU, RMSNorm, no biases)
  moe     — mixtral-8x22b (8e top-2, SWA) / kimi-k2 (384e top-8 + shared)
  vlm     — qwen2-vl-2b backbone (M-RoPE; patch embeddings are stub inputs)
  ssm     — rwkv6-1.6b (Finch time-mix + channel-mix; attention-free)
  hybrid  — zamba2-7b (mamba2 SSD blocks + one shared GQA block every N)
  audio   — whisper-medium (enc-dec; mel frontend is a stub input)

Layers are `lax.scan`ned with stacked params so the HLO contains ONE layer
body regardless of depth (kimi-k2: 61 layers, 384 experts — unrolled HLO
would be unlowerable).  Each param carries logical axes for the rule-based
sharding in distributed/sharding.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .attention import attention_train
from .common import (
    ModelConfig,
    ParamStore,
    apply_mrope,
    apply_rope,
    cross_entropy_loss,
    rms_norm,
    shard,
)
from .ffn import moe_layer, moe_layer_ep, swiglu
from .ssm import rwkv6_chunked, rwkv6_step, ssd_chunked, ssd_step

__all__ = ["init_params", "forward", "loss_fn", "Cache"]

Cache = dict[str, jnp.ndarray]

_RWKV_W_MIN = 0.05  # decay floor — keeps chunked exp() inside f32 (ssm.py)
_SSD_LOGA_MIN = -6.0


# ===================================================================== #
# Parameter init
# ===================================================================== #
def init_params(cfg: ModelConfig, key: jax.Array) -> tuple[dict, dict]:
    """Returns (params, logical_axes) pytrees."""
    st = ParamStore(key, dtype=cfg.dtype)
    d, v = cfg.d_model, cfg.vocab
    L = cfg.n_layers

    st.param("embed", (v, d), ("vocab", "d_model"), scale=0.02)
    if not cfg.tie_embeddings:
        st.param("lm_head", (d, v), ("d_model", "vocab"))
    st.param("final_norm", (d,), ("d_model",), init="ones")

    if cfg.family in ("dense", "moe", "vlm"):
        _init_decoder_stack(st, cfg, "layers", L)
    elif cfg.family == "ssm":
        _init_rwkv_stack(st, cfg, L)
    elif cfg.family == "hybrid":
        _init_zamba_stack(st, cfg, L)
    elif cfg.family == "audio":
        _init_whisper(st, cfg)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return st.params, st.axes


def _init_attn(st: ParamStore, cfg: ModelConfig, pfx: str, L: int, bias: bool = False):
    d = cfg.d_model
    st.param(f"{pfx}.attn_norm", (L, d), ("layers", "d_model"), init="ones")
    st.param(f"{pfx}.wq", (L, d, cfg.q_dim), ("layers", "d_model", "heads"))
    st.param(f"{pfx}.wk", (L, d, cfg.kv_dim), ("layers", "d_model", "kv_heads"))
    st.param(f"{pfx}.wv", (L, d, cfg.kv_dim), ("layers", "d_model", "kv_heads"))
    st.param(f"{pfx}.wo", (L, cfg.q_dim, d), ("layers", "heads", "d_model"))
    if bias:
        st.param(f"{pfx}.bq", (L, cfg.q_dim), ("layers", "heads"), init="zeros")
        st.param(f"{pfx}.bk", (L, cfg.kv_dim), ("layers", "kv_heads"), init="zeros")
        st.param(f"{pfx}.bv", (L, cfg.kv_dim), ("layers", "kv_heads"), init="zeros")


def _init_decoder_stack(st: ParamStore, cfg: ModelConfig, pfx: str, L: int):
    d = cfg.d_model
    _init_attn(st, cfg, pfx, L, bias=cfg.m_rope)  # qwen2-vl uses qkv biases
    st.param(f"{pfx}.ffn_norm", (L, d), ("layers", "d_model"), init="ones")
    if cfg.n_experts > 0:
        f = cfg.expert_ff
        st.param(f"{pfx}.router", (L, d, cfg.n_experts), ("layers", "d_model", "experts"))
        st.param(f"{pfx}.moe_wi_gate", (L, cfg.n_experts, d, f), ("layers", "experts", "d_model", "d_ff"))
        st.param(f"{pfx}.moe_wi_up", (L, cfg.n_experts, d, f), ("layers", "experts", "d_model", "d_ff"))
        st.param(f"{pfx}.moe_wo", (L, cfg.n_experts, f, d), ("layers", "experts", "d_ff", "d_model"))
        if cfg.n_shared_experts > 0:
            fs = cfg.expert_ff * cfg.n_shared_experts
            st.param(f"{pfx}.shared.wi_gate", (L, d, fs), ("layers", "d_model", "d_ff"))
            st.param(f"{pfx}.shared.wi_up", (L, d, fs), ("layers", "d_model", "d_ff"))
            st.param(f"{pfx}.shared.wo", (L, fs, d), ("layers", "d_ff", "d_model"))
    else:
        st.param(f"{pfx}.wi_gate", (L, d, cfg.d_ff), ("layers", "d_model", "d_ff"))
        st.param(f"{pfx}.wi_up", (L, d, cfg.d_ff), ("layers", "d_model", "d_ff"))
        st.param(f"{pfx}.wo_ffn", (L, cfg.d_ff, d), ("layers", "d_ff", "d_model"))


def _init_rwkv_stack(st: ParamStore, cfg: ModelConfig, L: int):
    d, f = cfg.d_model, cfg.d_ff
    lora = max(32, d // 32)
    st.param("layers.tm_norm", (L, d), ("layers", "d_model"), init="ones")
    for nm in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"):
        st.param(f"layers.{nm}", (L, d), ("layers", "d_model"), init="uniform", scale=0.5)
    for nm in ("wr", "wk", "wv", "wg"):
        st.param(f"layers.{nm}", (L, d, d), ("layers", "d_model", "heads"))
    st.param("layers.w_base", (L, d), ("layers", "d_model"), init="zeros")
    st.param("layers.w_lora_a", (L, d, lora), ("layers", "d_model", None))
    st.param("layers.w_lora_b", (L, lora, d), ("layers", None, "d_model"), init="zeros")
    st.param("layers.bonus_u", (L, d), ("layers", "d_model"), init="uniform", scale=0.3)
    st.param("layers.ln_x", (L, d), ("layers", "d_model"), init="ones")
    st.param("layers.wo", (L, d, d), ("layers", "heads", "d_model"))
    st.param("layers.cm_norm", (L, d), ("layers", "d_model"), init="ones")
    st.param("layers.cm_mu_k", (L, d), ("layers", "d_model"), init="uniform", scale=0.5)
    st.param("layers.cm_mu_r", (L, d), ("layers", "d_model"), init="uniform", scale=0.5)
    st.param("layers.cm_wk", (L, d, f), ("layers", "d_model", "d_ff"))
    st.param("layers.cm_wv", (L, f, d), ("layers", "d_ff", "d_model"))
    st.param("layers.cm_wr", (L, d, d), ("layers", "d_model", "heads"))


def _init_zamba_stack(st: ParamStore, cfg: ModelConfig, L: int):
    d = cfg.d_model
    d_inner = 2 * d
    n_h = d_inner // 64  # mamba2 head dim 64
    dst = cfg.ssm_state
    st.param("layers.norm", (L, d), ("layers", "d_model"), init="ones")
    st.param("layers.in_proj", (L, d, 2 * d_inner), ("layers", "d_model", "heads"))
    st.param("layers.bc_proj", (L, d, 2 * dst), ("layers", "d_model", None))
    st.param("layers.dt_proj", (L, d, n_h), ("layers", "d_model", None))
    st.param("layers.dt_bias", (L, n_h), ("layers", None), init="zeros")
    st.param("layers.a_log", (L, n_h), ("layers", None), init="uniform", scale=1.0)
    st.param("layers.d_skip", (L, n_h), ("layers", None), init="ones")
    st.param("layers.out_proj", (L, d_inner, d), ("layers", "heads", "d_model"))
    # NOTE: zamba2 mamba layers have NO per-layer MLP — the only MLP lives
    # in the shared attention block below (that is what keeps 81 layers at
    # ~7B params).
    # shared attention block (ONE set of params, applied every N layers)
    cfg1 = dataclasses.replace(cfg)
    _init_attn(st, cfg1, "shared_attn", 1)
    st.param("shared_attn.ffn_norm", (1, d), ("layers", "d_model"), init="ones")
    st.param("shared_attn.wi_gate", (1, d, cfg.d_ff), ("layers", "d_model", "d_ff"))
    st.param("shared_attn.wi_up", (1, d, cfg.d_ff), ("layers", "d_model", "d_ff"))
    st.param("shared_attn.wo_ffn", (1, cfg.d_ff, d), ("layers", "d_ff", "d_model"))


def _init_whisper(st: ParamStore, cfg: ModelConfig):
    d = cfg.d_model
    Le = cfg.enc_layers or cfg.n_layers
    Ld = cfg.n_layers
    # encoder (frames arrive pre-embedded: conv frontend is a stub input)
    st.param("enc.pos_scale", (1,), (None,), init="ones")
    _init_attn(st, cfg, "enc", Le)
    st.param("enc.ffn_norm", (Le, d), ("layers", "d_model"), init="ones")
    st.param("enc.wi", (Le, d, cfg.d_ff), ("layers", "d_model", "d_ff"))
    st.param("enc.wo_ffn", (Le, cfg.d_ff, d), ("layers", "d_ff", "d_model"))
    st.param("enc.final_norm", (d,), ("d_model",), init="ones")
    # decoder: self-attn + cross-attn + mlp
    _init_attn(st, cfg, "dec", Ld)
    st.param("dec.xattn_norm", (Ld, d), ("layers", "d_model"), init="ones")
    st.param("dec.xq", (Ld, d, cfg.q_dim), ("layers", "d_model", "heads"))
    st.param("dec.xk", (Ld, d, cfg.kv_dim), ("layers", "d_model", "kv_heads"))
    st.param("dec.xv", (Ld, d, cfg.kv_dim), ("layers", "d_model", "kv_heads"))
    st.param("dec.xo", (Ld, cfg.q_dim, d), ("layers", "heads", "d_model"))
    st.param("dec.ffn_norm", (Ld, d), ("layers", "d_model"), init="ones")
    st.param("dec.wi", (Ld, d, cfg.d_ff), ("layers", "d_model", "d_ff"))
    st.param("dec.wo_ffn", (Ld, cfg.d_ff, d), ("layers", "d_ff", "d_model"))


# ===================================================================== #
# Blocks (train path)
# ===================================================================== #
def _attn_block(
    lp: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    positions_3d: jnp.ndarray | None,
    *,
    window: int | None,
) -> jnp.ndarray:
    b, s, d = x.shape
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if "bq" in lp:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim_)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
    if cfg.m_rope and positions_3d is not None:
        q = apply_mrope(q, positions_3d, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions_3d, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = attention_train(
        q, k, v, causal=True, window=window,
        impl=cfg.attn_impl, q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
    )
    return x + o.reshape(b, s, cfg.q_dim) @ lp["wo"], (k, v)


def _ffn_block(lp: dict, x: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    if cfg.n_experts > 0:
        moe_params = {
            "router": lp["router"],
            "wi_gate": lp["moe_wi_gate"],
            "wi_up": lp["moe_wi_up"],
            "wo": lp["moe_wo"],
        }
        if cfg.n_shared_experts > 0:
            moe_params["shared"] = {
                "wi_gate": lp["shared"]["wi_gate"],
                "wi_up": lp["shared"]["wi_up"],
                "wo": lp["shared"]["wo"],
            }
        impl = moe_layer_ep if cfg.moe_impl == "shard_map_ep" else moe_layer
        o, aux = impl(moe_params, h, cfg)
        return x + o, aux
    o = swiglu({"wi_gate": lp["wi_gate"], "wi_up": lp["wi_up"], "wo": lp["wo_ffn"]}, h)
    return x + o, jnp.zeros((), jnp.float32)


def _decoder_layers(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    positions_3d: jnp.ndarray | None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    window = cfg.swa_window if cfg.attention == "swa" else None

    def body(carry, lp):
        h, aux = carry
        h = shard(h, ("batch", "seq_sp", "d_model"))
        h, _kv = _attn_block(lp, h, cfg, positions, positions_3d, window=window)
        h, a = _ffn_block(lp, h, cfg)
        return (h, aux + a), None

    body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    return x, aux


# ---------------- RWKV6 ---------------- #
def _rwkv_time_mix(lp, x, x_prev, cfg, state=None):
    """x [B,S,D]; x_prev [B,D] last token of previous segment.
    Returns (out, new_shift, final_state)."""
    b, s, d = x.shape
    n_h = cfg.n_heads
    dh = d // n_h
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)  # shifted

    def mix(mu):
        return x + (xs - x) * mu

    r = mix(lp["mu_r"]) @ lp["wr"]
    k = mix(lp["mu_k"]) @ lp["wk"]
    v = mix(lp["mu_v"]) @ lp["wv"]
    g = jax.nn.silu((mix(lp["mu_g"]) @ lp["wg"]).astype(jnp.float32)).astype(x.dtype)
    mw = mix(lp["mu_w"])
    w_raw = lp["w_base"] + jnp.tanh(mw @ lp["w_lora_a"]) @ lp["w_lora_b"]
    w = jnp.clip(
        jnp.exp(-jax.nn.softplus(-w_raw.astype(jnp.float32))), _RWKV_W_MIN, 0.9995
    )
    hs = lambda t: t.reshape(b, s, n_h, dh)
    u = lp["bonus_u"].reshape(n_h, dh)
    if s == 1 and state is not None:
        o, new_state = rwkv6_step(
            hs(r)[:, 0], hs(k)[:, 0], hs(v)[:, 0], w.reshape(b, s, n_h, dh)[:, 0], u, state
        )
        o = o[:, None]
    else:
        o, new_state = rwkv6_chunked(
            hs(r), hs(k), hs(v), w.reshape(b, s, n_h, dh), u,
            chunk=_pick_chunk(s), initial_state=state,
        )
    o = o.reshape(b, s, d)
    o = rms_norm(o, lp["ln_x"], cfg.norm_eps) * g
    return o @ lp["wo"], x[:, -1], new_state


def _rwkv_channel_mix(lp, x, x_prev):
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mk = x + (xs - x) * lp["cm_mu_k"]
    mr = x + (xs - x) * lp["cm_mu_r"]
    k = jnp.square(jax.nn.relu((mk @ lp["cm_wk"]).astype(jnp.float32))).astype(x.dtype)
    return jax.nn.sigmoid((mr @ lp["cm_wr"]).astype(jnp.float32)).astype(x.dtype) * (
        k @ lp["cm_wv"]
    ), x[:, -1]


def _pick_chunk(s: int) -> int:
    for c in (32, 16, 8, 4, 2, 1):
        if s % c == 0:
            return c
    return 1


def _rwkv_layers(params, x, cfg, cache: Cache | None):
    """Scan over RWKV layers; returns (x, aux, new_cache)."""
    b, s, d = x.shape
    L = cfg.n_layers
    zeros_shift = jnp.zeros((L, b, d), x.dtype)
    tm_shift = cache["tm_shift"] if cache else zeros_shift
    cm_shift = cache["cm_shift"] if cache else zeros_shift
    wkv_state = (
        cache["wkv"] if cache
        else jnp.zeros((L, b, cfg.n_heads, d // cfg.n_heads, d // cfg.n_heads), jnp.float32)
    )

    def body(h, layer_in):
        lp, tm_prev, cm_prev, st0 = layer_in
        h = shard(h, ("batch", "seq_sp", "d_model"))
        a = rms_norm(h, lp["tm_norm"], cfg.norm_eps)
        o, tm_new, st1 = _rwkv_time_mix(lp, a, tm_prev, cfg, st0)
        h = h + o
        c = rms_norm(h, lp["cm_norm"], cfg.norm_eps)
        o2, cm_new = _rwkv_channel_mix(lp, c, cm_prev)
        h = h + o2
        return h, (tm_new, cm_new, st1)

    body = jax.checkpoint(body, prevent_cse=False)
    x, (tm_new, cm_new, st_new) = jax.lax.scan(
        body, x, (params["layers"], tm_shift, cm_shift, wkv_state)
    )
    new_cache = {"tm_shift": tm_new, "cm_shift": cm_new, "wkv": st_new}
    return x, jnp.zeros((), jnp.float32), new_cache


# ---------------- zamba2 (mamba2 + shared attn) ---------------- #
def _mamba2_mixer(lp, x, cfg, state=None):
    """x [B,S,D] -> (y [B,S,D], final_state [B,H,Dst,64])."""
    b, s, d = x.shape
    d_inner = 2 * d
    n_h = d_inner // 64
    dst = cfg.ssm_state
    zx = x @ lp["in_proj"]  # [B,S,2*d_inner]
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = x @ lp["bc_proj"]  # [B,S,2*dst]
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus((x @ lp["dt_proj"] + lp["dt_bias"]).astype(jnp.float32))  # [B,S,H]
    a_log = -jnp.exp(lp["a_log"].astype(jnp.float32))  # [H] negative
    loga = jnp.clip(dt * a_log, _SSD_LOGA_MIN, 0.0)  # [B,S,H]
    xh = xin.reshape(b, s, n_h, 64) * dt[..., None].astype(x.dtype)
    bmat_h = jnp.broadcast_to(bmat[:, :, None, :], (b, s, n_h, dst))
    cmat_h = jnp.broadcast_to(cmat[:, :, None, :], (b, s, n_h, dst))
    if s == 1 and state is not None:
        y, new_state = ssd_step(xh[:, 0], loga[:, 0], bmat_h[:, 0], cmat_h[:, 0], state)
        y = y[:, None]
    else:
        y, new_state = ssd_chunked(
            xh, loga, bmat_h, cmat_h, chunk=_pick_chunk_ssd(s), initial_state=state
        )
    y = y + xin.reshape(b, s, n_h, 64) * lp["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, d_inner) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return y @ lp["out_proj"], new_state


def _pick_chunk_ssd(s: int) -> int:
    for c in (64, 32, 16, 8, 4, 2, 1):
        if s % c == 0:
            return c
    return 1


def _shared_attn_apply(params, h, cfg, positions, kv_write=None):
    """Apply the shared attention + MLP block (zamba2).  kv_write is used
    by the serve path; training recomputes attention in-layer."""
    sp = jax.tree.map(lambda t: t[0], params["shared_attn"])
    h2, kv = _attn_block(sp, h, cfg, positions, None, window=None)
    f = rms_norm(h2, sp["ffn_norm"], cfg.norm_eps)
    o = swiglu({"wi_gate": sp["wi_gate"], "wi_up": sp["wi_up"], "wo": sp["wo_ffn"]}, f)
    return h2 + o, kv


def _zamba_layers(params, x, cfg, positions, cache: Cache | None):
    b, s, d = x.shape
    L = cfg.n_layers
    every = max(cfg.hybrid_attn_every, 1)
    d_inner = 2 * d
    n_h = d_inner // 64
    ssm_state = (
        cache["ssm"] if cache
        else jnp.zeros((L, b, n_h, cfg.ssm_state, 64), jnp.float32)
    )

    def body(h, layer_in):
        lp, st0 = layer_in
        h = shard(h, ("batch", "seq_sp", "d_model"))
        a = rms_norm(h, lp["norm"], cfg.norm_eps)
        o, st1 = _mamba2_mixer(lp, a, cfg, st0)
        h = h + o
        return h, st1

    body = jax.checkpoint(body, prevent_cse=False)

    # Scan mamba blocks in groups of `every`; apply the shared attention
    # block between groups (the shared block is NOT scanned — one param set).
    n_groups = (L + every - 1) // every
    new_states = []
    idx = 0
    for g in range(n_groups):
        span = min(every, L - idx)
        grp = jax.tree.map(lambda t: t[idx : idx + span], params["layers"])
        st_grp = ssm_state[idx : idx + span]
        x, st_new = jax.lax.scan(body, x, (grp, st_grp))
        new_states.append(st_new)
        x, _ = _shared_attn_apply(params, x, cfg, positions)
        idx += span
    new_cache = {"ssm": jnp.concatenate(new_states, axis=0)}
    return x, jnp.zeros((), jnp.float32), new_cache


# ---------------- whisper ---------------- #
def _sinusoidal(s: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _whisper_encoder(params, frames, cfg):
    """frames: [B, S_enc, D] pre-embedded (conv frontend stub)."""
    b, s, d = frames.shape
    x = frames + (_sinusoidal(s, d) * params["enc"]["pos_scale"]).astype(frames.dtype)

    def body(h, lp):
        h2 = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q = (h2 @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim_)
        k = (h2 @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
        v = (h2 @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
        o = attention_train(q, k, v, causal=False)
        h = h + o.reshape(b, s, cfg.q_dim) @ lp["wo"]
        f = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        f = jax.nn.gelu((f @ lp["wi"]).astype(jnp.float32)).astype(h.dtype)
        return h + f @ lp["wo_ffn"], None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers_view"])
    return rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)


def _whisper_decoder(params, x, enc_out, cfg, positions):
    b, s, d = x.shape
    be, se, _ = enc_out.shape

    def body(h, lp):
        h2 = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q = (h2 @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim_)
        k = (h2 @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
        v = (h2 @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention_train(q, k, v, causal=True)
        h = h + o.reshape(b, s, cfg.q_dim) @ lp["wo"]
        # cross attention
        h2 = rms_norm(h, lp["xattn_norm"], cfg.norm_eps)
        q = (h2 @ lp["xq"]).reshape(b, s, cfg.n_heads, cfg.head_dim_)
        k = (enc_out @ lp["xk"]).reshape(be, se, cfg.n_kv_heads, cfg.head_dim_)
        v = (enc_out @ lp["xv"]).reshape(be, se, cfg.n_kv_heads, cfg.head_dim_)
        o = attention_train(q, k, v, causal=False)
        h = h + o.reshape(b, s, cfg.q_dim) @ lp["xo"]
        f = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        f = jax.nn.gelu((f @ lp["wi"]).astype(jnp.float32)).astype(h.dtype)
        return h + f @ lp["wo_ffn"], None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers_view"])
    return x


def _whisper_views(params: dict) -> dict:
    """Group per-layer whisper params into scan-able stacked trees."""
    enc_keys = ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "wi", "wo_ffn")
    dec_keys = enc_keys + ("xattn_norm", "xq", "xk", "xv", "xo")
    p = dict(params)
    p["enc_layers_view"] = {k: params["enc"][k] for k in enc_keys}
    p["dec_layers_view"] = {k: params["dec"][k] for k in dec_keys}
    return p


# ===================================================================== #
# Forward + loss
# ===================================================================== #
def forward(
    params: dict,
    cfg: ModelConfig,
    batch: dict[str, jnp.ndarray],
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Training/eval forward: returns (logits [B,S,V], aux_loss []).

    batch keys: "tokens" [B,S] always; family extras:
      vlm:   "patch_embeds" [B,P,D], "positions_3d" [3,B,S+P]
      audio: "frames" [B,S_enc,D] (stub mel embeddings), tokens are the
             decoder side.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = shard(x, ("batch", "seq_sp", "d_model"))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    positions_3d = batch.get("positions_3d")

    if cfg.family in ("dense", "moe"):
        x, aux = _decoder_layers(params, x, cfg, positions, None)
    elif cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(cfg.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        p = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(p)[None], (b, p))
        x, aux = _decoder_layers(params, x, cfg, positions, positions_3d)
        x = x[:, patches.shape[1] :]
    elif cfg.family == "ssm":
        x, aux, _ = _rwkv_layers(params, x, cfg, None)
    elif cfg.family == "hybrid":
        x, aux, _ = _zamba_layers(params, x, cfg, positions, None)
    elif cfg.family == "audio":
        p = _whisper_views(params)
        enc = _whisper_encoder(p, batch["frames"].astype(cfg.dtype), cfg)
        x = _whisper_decoder(p, x, enc, cfg, positions)
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    logits = shard(logits, ("batch", "seq_sp", "vocab"))
    return logits, aux


def loss_fn(
    params: dict, cfg: ModelConfig, batch: dict[str, jnp.ndarray], aux_weight: float = 0.01
) -> tuple[jnp.ndarray, dict]:
    logits, aux = forward(params, cfg, batch)
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux, "total": total}
