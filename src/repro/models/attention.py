"""GQA attention: training (full / sliding-window / cross) and KV-cache decode.

Training attention is pure jnp (XLA fuses it well and the flash_attention
Pallas kernel in kernels/flash_attention is the TPU drop-in); decode
attention reads a cache laid out as [B, S_max, Hkv, Dh] whose **sequence
axis is sharded over the "model" mesh axis** (flash-decoding style): GSPMD
turns the softmax reduction over the sharded axis into partial reductions
+ an all-reduce, which is exactly the sequence-parallel decode schedule we
want on TPU (DESIGN.md §6).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_mrope, apply_rope, shard

__all__ = ["KVCache", "attention_train", "attention_decode", "init_kv_cache"]


class KVCache(NamedTuple):
    k: jnp.ndarray  # [L, B, S_max, Hkv, Dh]
    v: jnp.ndarray  # [L, B, S_max, Hkv, Dh]
    length: jnp.ndarray  # [] int32 — tokens currently filled


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """[B, S, Hkv, Dh] -> [B, S, Hkv * n_rep, Dh] (GQA head expansion)."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def _causal_mask(s_q: int, s_k: int, window: int | None, offset: int = 0) -> jnp.ndarray:
    """Boolean [s_q, s_k]: True = attend. offset = k positions before q[0]."""
    q_pos = jnp.arange(s_q)[:, None] + offset
    k_pos = jnp.arange(s_k)[None, :]
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def attention_train(
    q: jnp.ndarray,  # [B, S, Hq, Dh]
    k: jnp.ndarray,  # [B, Skv, Hkv, Dh]
    v: jnp.ndarray,  # [B, Skv, Hkv, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    impl: str = "naive",  # naive | chunked
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Batched multi-head attention; returns [B, S, Hq, Dh].

    impl="naive" materialises the (S, S) logits — the paper-faithful
    baseline the dry-run records first.  impl="chunked" is the XLA-level
    flash attention (online softmax over KV chunks inside a scan): HBM
    traffic drops from O(S^2) to O(S^2/q_chunk * Dh) reads of K/V and the
    (S, S) intermediate never exists; the Pallas kernel
    (kernels/flash_attention) is the same algorithm tiled for VMEM.
    """
    if (
        impl == "chunked"
        and q.shape[1] > q_chunk
        and q.shape[1] % q_chunk == 0
        and k.shape[1] % kv_chunk == 0
    ):
        return _attention_chunked(
            q, k, v, causal=causal, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk
        )
    b, s_q, hq, dh = q.shape
    _, s_k, hkv, _ = k.shape
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    q = shard(q, ("batch", "seq", "heads", None))
    scale = 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = _causal_mask(s_q, s_k, window, offset=s_k - s_q)
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return shard(out, ("batch", "seq", "heads", None))


def _attention_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    window: int | None,
    q_chunk: int,
    kv_chunk: int,
) -> jnp.ndarray:
    """Blockwise online-softmax attention (flash attention in pure XLA)."""
    b, s_q, hq, dh = q.shape
    _, s_k, hkv, _ = k.shape
    assert s_q % q_chunk == 0 and s_k % kv_chunk == 0, (s_q, s_k, q_chunk, kv_chunk)
    n_rep = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    nq, nk = s_q // q_chunk, s_k // kv_chunk
    offset = s_k - s_q
    f32 = jnp.float32

    kc = k.reshape(b, nk, kv_chunk, hkv, dh)
    vc = v.reshape(b, nk, kv_chunk, hkv, dh)
    qc = q.reshape(b, nq, q_chunk, hq, dh)

    def q_block(iq, qb):  # qb: [B, q_chunk, Hq, Dh]
        qb = (qb.astype(f32) * scale).reshape(b, q_chunk, hkv, n_rep, dh)
        q_start = iq * q_chunk + offset

        def kv_step(carry, ik):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kc, ik, axis=1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vc, ik, axis=1, keepdims=False)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qb, kb.astype(f32))
            if causal:
                q_pos = q_start + jnp.arange(q_chunk)[:, None]
                k_pos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
                mask = k_pos <= q_pos
                if window is not None:
                    mask = mask & (k_pos > q_pos - window)
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhrqk,bkhd->bhrqd", p, vb.astype(f32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, n_rep, q_chunk), -1e30, f32)
        l0 = jnp.zeros((b, hkv, n_rep, q_chunk), f32)
        a0 = jnp.zeros((b, hkv, n_rep, q_chunk, dh), f32)
        if causal:
            # skip fully-masked kv chunks: the last relevant chunk index
            ik_hi = jnp.minimum((q_start + q_chunk - 1) // kv_chunk + 1, nk)
        else:
            ik_hi = nk
        (m, l, acc), _ = jax.lax.scan(
            lambda c, ik: jax.lax.cond(
                ik < ik_hi, lambda cc: kv_step(cc, ik), lambda cc: (cc, None), c
            ),
            (m0, l0, a0),
            jnp.arange(nk),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, dh).astype(q.dtype)

    outs = jax.vmap(q_block, in_axes=(0, 1), out_axes=1)(jnp.arange(nq), qc)
    return outs.reshape(b, s_q, hq, dh)


def attention_decode(
    q: jnp.ndarray,  # [B, 1, Hq, Dh]
    k_cache: jnp.ndarray,  # [B, S_max, Hkv, Dh] (seq sharded over "model")
    v_cache: jnp.ndarray,  # [B, S_max, Hkv, Dh]
    length: jnp.ndarray,  # [] int32 — valid prefix
    *,
    window: int | None = None,
) -> jnp.ndarray:
    """Single-token decode against the cache; returns [B, 1, Hq, Dh].

    The cache's S_max axis carries the "kv_seq" logical axis -> "model"
    mesh axis; the masked softmax over it becomes partial-max/partial-sum
    + all-reduce under GSPMD (flash-decoding).
    """
    b, _, hq, dh = q.shape
    _, s_max, hkv, _ = k_cache.shape
    k_cache = shard(k_cache, ("batch", "kv_seq", None, None))
    v_cache = shard(v_cache, ("batch", "kv_seq", None, None))
    n_rep = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qh = q[:, 0].reshape(b, hkv, n_rep, dh)  # group by kv head
    logits = jnp.einsum("bhrd,bshd->bhrs", qh, k_cache).astype(jnp.float32) * scale
    pos = jnp.arange(s_max)
    valid = pos[None, None, None, :] < length
    if window is not None:
        valid &= pos[None, None, None, :] > (length - 1 - window)
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrs,bshd->bhrd", probs, v_cache)
    return out.reshape(b, 1, hq, dh)


def init_kv_cache(
    cfg: ModelConfig, batch: int, s_max: int, n_layers: int | None = None
) -> KVCache:
    n_layers = n_layers if n_layers is not None else cfg.n_layers
    shape = (n_layers, batch, s_max, cfg.n_kv_heads, cfg.head_dim_)
    return KVCache(
        k=jnp.zeros(shape, dtype=cfg.dtype),
        v=jnp.zeros(shape, dtype=cfg.dtype),
        length=jnp.zeros((), dtype=jnp.int32),
    )


def project_qkv(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, ...]:
    """x [B, S, D] -> q [B,S,Hq,Dh], k/v [B,S,Hkv,Dh] (no biases — the
    assigned archs are no-bias GQA designs)."""
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim_)
    k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
    v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
    return q, k, v


def rope_qk(
    q: jnp.ndarray,
    k: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    positions_3d: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    if cfg.m_rope and positions_3d is not None:
        q = apply_mrope(q, positions_3d, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions_3d, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k
