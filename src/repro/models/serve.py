"""Serving paths: cache init, prefill, and single-token decode per family.

``decode_step`` is the unit the dry-run lowers for ``decode_32k`` /
``long_500k`` (one new token against a cache of seq_len).  Cache layouts:

  dense/moe/vlm : k/v  [L, B, S_max, Hkv, Dh]  (kv_seq sharded on "model")
  ssm (rwkv6)   : wkv  [L, B, H, Dk, Dv] f32 + token shifts [L, B, D]
  hybrid        : ssm  [L, B, H, Dst, 64] f32 + shared-attn k/v
                  [G, B, S_max, Hkv, Dh]  (G = number of shared-block sites)
  audio         : decoder self k/v [L, B, S_max, Hkv, Dh] + precomputed
                  cross k/v [L, B, S_enc, Hkv, Dh]

The KV sequence axis carries the "kv_seq" logical axis; with the decode
rule table it maps onto the "model" mesh axis (flash-decoding sequence
sharding, DESIGN.md §6) — that is what makes 500k-token caches fit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention_decode, attention_train
from .common import ModelConfig, apply_mrope, apply_rope, rms_norm
from .ffn import swiglu
from .transformer import (
    Cache,
    _attn_block,
    _ffn_block,
    _mamba2_mixer,
    _rwkv_layers,
    _shared_attn_apply,
    _whisper_encoder,
    _whisper_views,
)

__all__ = ["init_cache", "prefill", "decode_step"]


def _n_shared_sites(cfg: ModelConfig) -> int:
    every = max(cfg.hybrid_attn_every, 1)
    return (cfg.n_layers + every - 1) // every


def init_cache(cfg: ModelConfig, batch: int, s_max: int) -> Cache:
    L, dt = cfg.n_layers, cfg.dtype
    dh = cfg.head_dim_
    if cfg.family in ("dense", "moe", "vlm"):
        return {
            "k": jnp.zeros((L, batch, s_max, cfg.n_kv_heads, dh), dt),
            "v": jnp.zeros((L, batch, s_max, cfg.n_kv_heads, dh), dt),
            "length": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "ssm":
        d = cfg.d_model
        hd = d // cfg.n_heads
        return {
            "wkv": jnp.zeros((L, batch, cfg.n_heads, hd, hd), jnp.float32),
            "tm_shift": jnp.zeros((L, batch, d), dt),
            "cm_shift": jnp.zeros((L, batch, d), dt),
            "length": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        g = _n_shared_sites(cfg)
        n_h = (2 * cfg.d_model) // 64
        return {
            "ssm": jnp.zeros((L, batch, n_h, cfg.ssm_state, 64), jnp.float32),
            "k": jnp.zeros((g, batch, s_max, cfg.n_kv_heads, dh), dt),
            "v": jnp.zeros((g, batch, s_max, cfg.n_kv_heads, dh), dt),
            "length": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "audio":
        Ld = cfg.n_layers
        return {
            "k": jnp.zeros((Ld, batch, s_max, cfg.n_kv_heads, dh), dt),
            "v": jnp.zeros((Ld, batch, s_max, cfg.n_kv_heads, dh), dt),
            "xk": jnp.zeros((Ld, batch, cfg.enc_seq, cfg.n_kv_heads, dh), dt),
            "xv": jnp.zeros((Ld, batch, cfg.enc_seq, cfg.n_kv_heads, dh), dt),
            "length": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.family)


# --------------------------------------------------------------------- #
# Prefill
# --------------------------------------------------------------------- #
def prefill(
    params: dict, cfg: ModelConfig, batch: dict, cache: Cache
) -> tuple[jnp.ndarray, Cache]:
    """Process the prompt; fill the cache; return last-position logits."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    window = cfg.swa_window if cfg.attention == "swa" else None

    if cfg.family in ("dense", "moe", "vlm"):
        positions_3d = batch.get("positions_3d")
        if cfg.family == "vlm" and "patch_embeds" in batch:
            x = jnp.concatenate([batch["patch_embeds"].astype(cfg.dtype), x], axis=1)
            s = x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(h, lp):
            h, (k, v) = _attn_block(lp, h, cfg, positions, positions_3d, window=window)
            h, _ = _ffn_block(lp, h, cfg)
            return h, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], ks.astype(cfg.dtype), (0, 0, 0, 0, 0)
        )
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vs.astype(cfg.dtype), (0, 0, 0, 0, 0)
        )
        cache["length"] = jnp.int32(s)
    elif cfg.family == "ssm":
        x, _, new_cache = _rwkv_layers(params, x, cfg, None)
        cache = {**new_cache, "length": jnp.int32(s)}
    elif cfg.family == "hybrid":
        x, cache = _zamba_prefill(params, x, cfg, positions, cache)
        cache["length"] = jnp.int32(s)
    elif cfg.family == "audio":
        p = _whisper_views(params)
        enc = _whisper_encoder(p, batch["frames"].astype(cfg.dtype), cfg)
        x, cache = _whisper_prefill(p, x, enc, cfg, positions, cache)
        cache["length"] = jnp.int32(s)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head)[:, 0], cache


def _zamba_prefill(params, x, cfg, positions, cache):
    b, s, d = x.shape
    L = cfg.n_layers
    every = max(cfg.hybrid_attn_every, 1)
    n_h = (2 * d) // 64
    ssm0 = jnp.zeros((L, b, n_h, cfg.ssm_state, 64), jnp.float32)

    def body(h, layer_in):
        lp, st0 = layer_in
        a = rms_norm(h, lp["norm"], cfg.norm_eps)
        o, st1 = _mamba2_mixer(lp, a, cfg, st0)
        return h + o, st1

    n_groups = _n_shared_sites(cfg)
    states, kss, vss = [], [], []
    idx = 0
    for g in range(n_groups):
        span = min(every, L - idx)
        grp = jax.tree.map(lambda t: t[idx : idx + span], params["layers"])
        x, st_new = jax.lax.scan(body, x, (grp, ssm0[idx : idx + span]))
        states.append(st_new)
        x, (k, v) = _shared_attn_apply(params, x, cfg, positions)
        kss.append(k)
        vss.append(v)
        idx += span
    cache = dict(cache)
    cache["ssm"] = jnp.concatenate(states, axis=0)
    ks = jnp.stack(kss, axis=0).astype(cfg.dtype)  # [G, B, S, Hkv, Dh]
    vs = jnp.stack(vss, axis=0).astype(cfg.dtype)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0))
    return x, cache


def _whisper_prefill(p, x, enc_out, cfg, positions, cache):
    b, s, d = x.shape
    be, se, _ = enc_out.shape

    def body(h, lp):
        h2 = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q = (h2 @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim_)
        k = (h2 @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
        v = (h2 @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim_)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention_train(q, k, v, causal=True)
        h = h + o.reshape(b, s, cfg.q_dim) @ lp["wo"]
        h2 = rms_norm(h, lp["xattn_norm"], cfg.norm_eps)
        q = (h2 @ lp["xq"]).reshape(b, s, cfg.n_heads, cfg.head_dim_)
        xk = (enc_out @ lp["xk"]).reshape(be, se, cfg.n_kv_heads, cfg.head_dim_)
        xv = (enc_out @ lp["xv"]).reshape(be, se, cfg.n_kv_heads, cfg.head_dim_)
        o = attention_train(q, xk, xv, causal=False)
        h = h + o.reshape(b, s, cfg.q_dim) @ lp["xo"]
        f = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        f = jax.nn.gelu((f @ lp["wi"]).astype(jnp.float32)).astype(h.dtype)
        return h + f @ lp["wo_ffn"], (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, p["dec_layers_view"])
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], ks.astype(cfg.dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vs.astype(cfg.dtype), (0, 0, 0, 0, 0))
    cache["xk"] = xks.astype(cfg.dtype)
    cache["xv"] = xvs.astype(cfg.dtype)
    return x, cache


# --------------------------------------------------------------------- #
# Decode (one token)
# --------------------------------------------------------------------- #
def decode_step(
    params: dict, cfg: ModelConfig, tokens: jnp.ndarray, cache: Cache
) -> tuple[jnp.ndarray, Cache]:
    """tokens [B] int32 -> (logits [B, V], updated cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None].astype(cfg.dtype)  # [B,1,D]
    length = cache["length"]
    positions = jnp.broadcast_to(length[None, None], (b, 1)).astype(jnp.int32)
    window = cfg.swa_window if cfg.attention == "swa" else None

    if cfg.family in ("dense", "moe", "vlm"):
        positions_3d = (
            jnp.broadcast_to(length[None, None, None], (3, b, 1)).astype(jnp.int32)
            if cfg.m_rope
            else None
        )

        def body(h, layer_in):
            lp, k_row, v_row = layer_in
            h2 = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            q = h2 @ lp["wq"]
            k = h2 @ lp["wk"]
            v = h2 @ lp["wv"]
            if "bq" in lp:
                q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
            q = q.reshape(b, 1, cfg.n_heads, cfg.head_dim_)
            k = k.reshape(b, 1, cfg.n_kv_heads, cfg.head_dim_)
            v = v.reshape(b, 1, cfg.n_kv_heads, cfg.head_dim_)
            if cfg.m_rope and positions_3d is not None:
                q = apply_mrope(q, positions_3d, cfg.rope_theta, cfg.mrope_sections)
                k = apply_mrope(k, positions_3d, cfg.rope_theta, cfg.mrope_sections)
            else:
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
            k_row = jax.lax.dynamic_update_slice(k_row, k.astype(cfg.dtype), (0, length, 0, 0))
            v_row = jax.lax.dynamic_update_slice(v_row, v.astype(cfg.dtype), (0, length, 0, 0))
            o = attention_decode(q, k_row, v_row, length + 1, window=window)
            h = h + o.reshape(b, 1, cfg.q_dim) @ lp["wo"]
            h, _ = _ffn_block(lp, h, cfg)
            return h, (k_row, v_row)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        cache = {**cache, "k": ks, "v": vs, "length": length + 1}
    elif cfg.family == "ssm":
        serve_cache = {k: cache[k] for k in ("wkv", "tm_shift", "cm_shift")}
        x, _, new_cache = _rwkv_layers(params, x, cfg, serve_cache)
        cache = {**new_cache, "length": length + 1}
    elif cfg.family == "hybrid":
        x, cache = _zamba_decode(params, x, cfg, positions, cache)
        cache["length"] = length + 1
    elif cfg.family == "audio":
        p = _whisper_views(params)
        x, cache = _whisper_decode(p, x, cfg, positions, cache)
        cache["length"] = length + 1
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head)[:, 0], cache


def _zamba_decode(params, x, cfg, positions, cache):
    b = x.shape[0]
    d = cfg.d_model
    L = cfg.n_layers
    every = max(cfg.hybrid_attn_every, 1)
    length = cache["length"]

    def body(h, layer_in):
        lp, st0 = layer_in
        a = rms_norm(h, lp["norm"], cfg.norm_eps)
        o, st1 = _mamba2_mixer(lp, a, cfg, st0)
        return h + o, st1

    sp = jax.tree.map(lambda t: t[0], params["shared_attn"])
    n_groups = _n_shared_sites(cfg)
    states, kss, vss = [], [], []
    idx = 0
    for g in range(n_groups):
        span = min(every, L - idx)
        grp = jax.tree.map(lambda t: t[idx : idx + span], params["layers"])
        x, st_new = jax.lax.scan(body, x, (grp, cache["ssm"][idx : idx + span]))
        states.append(st_new)
        # shared attn decode against this site's kv cache
        h2 = rms_norm(x, sp["attn_norm"], cfg.norm_eps)
        q = (h2 @ sp["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim_)
        k = (h2 @ sp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim_)
        v = (h2 @ sp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim_)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_row = jax.lax.dynamic_update_slice(
            cache["k"][g], k.astype(cfg.dtype), (0, length, 0, 0)
        )
        v_row = jax.lax.dynamic_update_slice(
            cache["v"][g], v.astype(cfg.dtype), (0, length, 0, 0)
        )
        kss.append(k_row)
        vss.append(v_row)
        o = attention_decode(q, k_row, v_row, length + 1)
        x = x + o.reshape(b, 1, cfg.q_dim) @ sp["wo"]
        f = rms_norm(x, sp["ffn_norm"], cfg.norm_eps)
        x = x + swiglu({"wi_gate": sp["wi_gate"], "wi_up": sp["wi_up"], "wo": sp["wo_ffn"]}, f)
        idx += span
    cache = {
        **cache,
        "ssm": jnp.concatenate(states, axis=0),
        "k": jnp.stack(kss, axis=0),
        "v": jnp.stack(vss, axis=0),
    }
    return x, cache


def _whisper_decode(p, x, cfg, positions, cache):
    b = x.shape[0]
    length = cache["length"]

    def body(h, layer_in):
        lp, k_row, v_row, xk, xv = layer_in
        h2 = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q = (h2 @ lp["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim_)
        k = (h2 @ lp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim_)
        v = (h2 @ lp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim_)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_row = jax.lax.dynamic_update_slice(k_row, k.astype(cfg.dtype), (0, length, 0, 0))
        v_row = jax.lax.dynamic_update_slice(v_row, v.astype(cfg.dtype), (0, length, 0, 0))
        o = attention_decode(q, k_row, v_row, length + 1)
        h = h + o.reshape(b, 1, cfg.q_dim) @ lp["wo"]
        h2 = rms_norm(h, lp["xattn_norm"], cfg.norm_eps)
        q = (h2 @ lp["xq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim_)
        o = attention_decode(q, xk, xv, jnp.int32(cfg.enc_seq))
        h = h + o.reshape(b, 1, cfg.q_dim) @ lp["xo"]
        f = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
        f = jax.nn.gelu((f @ lp["wi"]).astype(jnp.float32)).astype(h.dtype)
        return h + f @ lp["wo_ffn"], (k_row, v_row)

    x, (ks, vs) = jax.lax.scan(
        body, x, (p["dec_layers_view"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    return x, {**cache, "k": ks, "v": vs}
