"""SSM blocks: RWKV6 "Finch" time-mix and Mamba2 SSD (for zamba2 hybrid).

Both are linear-recurrence layers with O(1) decode state — which is why
the rwkv6 / zamba2 / mixtral(SWA) architectures are the ones that run the
``long_500k`` shape (DESIGN.md §5).

Training uses the **chunked** formulation (the standard linear-attention
chunking: intra-chunk quadratic term masked by decay + inter-chunk
recurrent state carried by a scan over chunks).  This is the TPU-native
adaptation: the per-token recurrence becomes MXU matmuls of size
chunk x chunk and chunk x state, and the sequential scan shrinks from
seq_len steps to seq_len / chunk steps.  kernels/rwkv6_scan holds the
Pallas version of the intra-chunk hot loop; this file is the reference
path the dry-run compiles.

RWKV6 (arXiv:2404.05892): per head h, state S in R^{dk x dv};
    S_t = diag(w_t) S_{t-1} + k_t^T v_t       (w_t: data-dependent decay)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)   (u: bonus for current token)

Mamba2 SSD (arXiv:2405.21060): scalar-per-head decay a_t = exp(dt * A):
    S_t = a_t S_{t-1} + dt_t B_t^T x_t ;  y_t = C_t S_t + D x_t
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


__all__ = [
    "rwkv6_chunked",
    "rwkv6_step",
    "ssd_chunked",
    "ssd_step",
    "SSMState",
]


class SSMState(NamedTuple):
    state: jnp.ndarray  # rwkv: [L, B, H, Dk, Dv]; mamba2: [L, B, H, Dst, Dh]
    token_shift: jnp.ndarray  # rwkv: [L, B, D] last hidden (for time-shift); mamba2: conv state


# --------------------------------------------------------------------- #
# RWKV6
# --------------------------------------------------------------------- #
def rwkv6_chunked(
    r: jnp.ndarray,  # [B, S, H, Dk]
    k: jnp.ndarray,  # [B, S, H, Dk]
    v: jnp.ndarray,  # [B, S, H, Dv]
    w: jnp.ndarray,  # [B, S, H, Dk]  decay in (0,1), data-dependent
    u: jnp.ndarray,  # [H, Dk]        current-token bonus
    *,
    chunk: int = 32,
    initial_state: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV6; returns (out [B,S,H,Dv], final_state [B,H,Dk,Dv]).

    Numerics: intra-chunk decays are products of exponentials whose
    exponents span chunk * |log w|; the model layer clamps per-token decay
    to w >= ~0.1 and the default chunk of 32 keeps exp() within f32 range
    (see models/transformer.py rwkv parametrisation).

    Within a chunk of length C (positions i, j):
      intra[i,j] = r_i . (prod_{m=j+1..i-1} w_m) k_j   for j < i
                   r_i . (u k_i)                       for j == i
      cross[i]   = r_i . (prod_{m<i} w_m) S_in
    and the state update uses the chunk's total decay + decayed k v outer
    products.  All products are computed in log space for stability.
    """
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    f32 = jnp.float32

    # Keep the scan xs in the INPUT dtype and derive all f32 cumulative-
    # decay factors INSIDE the chunk step: materialising full-sequence f32
    # pcum/exp tensors outside the scan costs ~6 x (B,S,H,Dk) f32 of HBM
    # per layer (the dominant memory term of the rwkv6 train cell before
    # this change — EXPERIMENTS.md §Perf).  In-chunk, they are (B,C,H,Dk)
    # working-set values XLA keeps fused.
    rr = r.reshape(b, n, chunk, h, dk)
    kk = k.reshape(b, n, chunk, h, dk)
    vv = v.reshape(b, n, chunk, h, dv)
    ww = w.reshape(b, n, chunk, h, dk)

    if initial_state is None:
        s0 = jnp.zeros((b, h, dk, dv), dtype=f32)
    else:
        s0 = initial_state.astype(f32)

    def chunk_step(state, inputs):
        rc_raw, kc_raw, vc_raw, wc_raw = inputs  # [B,C,H,Dk] input dtype
        rc = rc_raw.astype(f32)
        kc = kc_raw.astype(f32)
        vc = vc_raw.astype(f32)
        lw = jnp.log(jnp.clip(wc_raw.astype(f32), 1e-8, 1.0))
        pc = jnp.cumsum(lw, axis=1)  # [B,C,H,Dk]
        tot = pc[:, -1]  # [B,H,Dk]
        # pc_{i-1}: cumulative log-decay *before* token i (0 for i = 0)
        pc_prev = jnp.concatenate([jnp.zeros_like(pc[:, :1]), pc[:, :-1]], axis=1)
        # cross-chunk: o_i += (r_i * exp(pc_{i-1})) @ S_in
        r_dec = rc * jnp.exp(pc_prev)  # [B,C,H,Dk]
        cross = jnp.einsum("bchk,bhkv->bchv", r_dec, state)
        # intra-chunk: att[i,j] = r_i . (exp(pc_{i-1} - pc_j) k_j) for j < i
        att = jnp.einsum("bchk,bdhk->bhcd", r_dec, kc * jnp.exp(-pc))  # [B,H,C,C]
        idx = jnp.arange(chunk)
        mask = (idx[:, None] > idx[None, :]).astype(f32)  # strict lower
        att = att * mask[None, None]
        # diagonal (current token, bonus u)
        diag = jnp.einsum("bchk,bchk->bch", rc * u[None, None], kc)  # [B,C,H]
        intra = jnp.einsum("bhcd,bdhv->bchv", att, vc) + diag[..., None] * vc
        out_c = cross + intra
        # state update: S' = diag(exp(tot)) S + sum_j exp(tot - pc_j) k_j v_j^T
        k_dec = kc * jnp.exp(tot[:, None] - pc)  # [B,C,H,Dk]
        state = jnp.exp(tot)[..., None] * state + jnp.einsum(
            "bchk,bchv->bhkv", k_dec, vc
        )
        return state, out_c

    inputs = (
        jnp.moveaxis(rr, 1, 0),
        jnp.moveaxis(kk, 1, 0),
        jnp.moveaxis(vv, 1, 0),
        jnp.moveaxis(ww, 1, 0),
    )
    final_state, outs = jax.lax.scan(chunk_step, s0, inputs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dv)
    return out.astype(r.dtype), final_state


def rwkv6_step(
    r: jnp.ndarray,  # [B, H, Dk]
    k: jnp.ndarray,
    v: jnp.ndarray,  # [B, H, Dv]
    w: jnp.ndarray,  # [B, H, Dk]
    u: jnp.ndarray,  # [H, Dk]
    state: jnp.ndarray,  # [B, H, Dk, Dv]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token WKV6 recurrence (decode path)."""
    f32 = jnp.float32
    rf, kf, vf, wf = (x.astype(f32) for x in (r, k, v, w))
    kv = kf[..., :, None] * vf[..., None, :]  # [B,H,Dk,Dv]
    out = jnp.einsum("bhk,bhkv->bhv", rf, state + u[None, ..., None] * kv)
    new_state = wf[..., None] * state + kv
    return out.astype(r.dtype), new_state


# --------------------------------------------------------------------- #
# Mamba2 SSD
# --------------------------------------------------------------------- #
def ssd_chunked(
    x: jnp.ndarray,  # [B, S, H, Dh]   (already dt-scaled input)
    a: jnp.ndarray,  # [B, S, H]       log-decay per step (dt * A, <= 0)
    bmat: jnp.ndarray,  # [B, S, H, Dst]
    cmat: jnp.ndarray,  # [B, S, H, Dst]
    *,
    chunk: int = 64,
    initial_state: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD (Mamba2); returns (y [B,S,H,Dh], state [B,H,Dst,Dh]).

    y_t = C_t . S_t with S_t = exp(a_t) S_{t-1} + B_t^T x_t.
    """
    b, s, h, dh = x.shape
    dst = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    f32 = jnp.float32

    # As in rwkv6_chunked: xs stay in the input dtype; all f32 cumulative-
    # decay factors are derived inside the chunk (HBM-traffic motivation
    # in EXPERIMENTS.md §Perf).
    xx = x.reshape(b, n, chunk, h, dh)
    aa = a.reshape(b, n, chunk, h)
    bb = bmat.reshape(b, n, chunk, h, dst)
    cc = cmat.reshape(b, n, chunk, h, dst)

    if initial_state is None:
        s0 = jnp.zeros((b, h, dst, dh), dtype=f32)
    else:
        s0 = initial_state.astype(f32)

    def chunk_step(state, inputs):
        xc_raw, ac_raw, bc_raw, cc_raw = inputs
        xc = xc_raw.astype(f32)
        ac = ac_raw.astype(f32)
        bc = bc_raw.astype(f32)
        ccc = cc_raw.astype(f32)
        pc = jnp.cumsum(ac, axis=1)  # [B,C,H]
        tot = pc[:, -1]  # [B,H]
        # cross: y_i += (C_i exp(pc_i)) @ S_in   (state S includes decay to i)
        c_dec = ccc * jnp.exp(pc)[..., None]  # [B,C,H,Dst]
        cross = jnp.einsum("bchs,bhsd->bchd", c_dec, state)
        # intra: y_i += sum_{j<=i} exp(pc_i - pc_j) (C_i.B_j) x_j
        att = jnp.einsum("bchs,bdhs->bhcd", c_dec, bc * jnp.exp(-pc)[..., None])
        idx = jnp.arange(chunk)
        mask = (idx[:, None] >= idx[None, :]).astype(f32)  # includes diagonal
        att = att * mask[None, None]
        intra = jnp.einsum("bhcd,bdhe->bche", att, xc)
        y_c = cross + intra
        # state: S' = exp(tot) S + sum_j exp(tot - pc_j) B_j^T x_j
        b_dec = bc * jnp.exp(tot[:, None] - pc)[..., None]
        state = jnp.exp(tot)[..., None, None] * state + jnp.einsum(
            "bchs,bchd->bhsd", b_dec, xc
        )
        return state, y_c

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (xx, aa, bb, cc))
    final_state, ys = jax.lax.scan(chunk_step, s0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dh)
    return y.astype(x.dtype), final_state


def ssd_step(
    x: jnp.ndarray,  # [B, H, Dh]
    a: jnp.ndarray,  # [B, H] log decay
    bvec: jnp.ndarray,  # [B, H, Dst]
    cvec: jnp.ndarray,  # [B, H, Dst]
    state: jnp.ndarray,  # [B, H, Dst, Dh]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSD recurrence (decode path)."""
    f32 = jnp.float32
    xf, af, bf, cf = (t.astype(f32) for t in (x, a, bvec, cvec))
    new_state = jnp.exp(af)[..., None, None] * state + bf[..., :, None] * xf[..., None, :]
    y = jnp.einsum("bhs,bhsd->bhd", cf, new_state)
    return y.astype(x.dtype), new_state
