"""Model zoo: all assigned architectures as ModelConfig-driven JAX models."""

from .common import ModelConfig, axis_rules, cross_entropy_loss, logical_to_spec
from .transformer import forward, init_params, loss_fn
from . import serve

__all__ = [
    "ModelConfig",
    "axis_rules",
    "cross_entropy_loss",
    "logical_to_spec",
    "forward",
    "init_params",
    "loss_fn",
    "serve",
]
