"""Feed-forward layers: SwiGLU and capacity-based top-k MoE.

The MoE dispatch is **sort-based with a fixed per-expert capacity**
(GShard/Switch style, implemented with argsort + gather instead of the
one-hot dispatch einsum): compute cost in the compiled HLO is the *active*
FLOPs  tokens x top_k x (3 d_model expert_ff)  plus O(tokens) gather
bookkeeping — not the n_experts-dense einsum, which for kimi-k2's 384
experts would inflate HLO FLOPs 48x and wreck both the roofline's
usefulness and actual TPU time.  Expert weights carry the "experts"
logical axis so the rule table can lay them out as EP (experts over a mesh
axis) or FSDP (d_model/d_ff sharded) per architecture.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from .common import ModelConfig, current_mesh, shard

__all__ = ["swiglu", "moe_layer", "moe_layer_ep", "router_top_k"]


def swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x [.., D] with params wi_gate [D,F], wi_up [D,F], wo [F,D]."""
    gate = x @ params["wi_gate"]
    up = x @ params["wi_up"]
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = shard(h, ("batch", "seq", "d_ff"))
    return h @ params["wo"]


def router_top_k(
    logits: jnp.ndarray, top_k: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token router: logits [T, E] -> (weights [T, k], experts [T, k]).

    Softmax over the selected k (Mixtral-style renormalisation).
    """
    gates, experts = jax.lax.top_k(logits, top_k)  # [T, k]
    weights = jax.nn.softmax(gates.astype(jnp.float32), axis=-1)
    return weights, experts


def moe_layer(
    params: dict,
    x: jnp.ndarray,  # [B, S, D]
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE with fixed capacity; returns (out [B,S,D], aux_loss []).

    Dispatch: flatten tokens, route, then for each (token, slot) pair sort
    by expert id and scatter into a [E, C, D] buffer; experts run as one
    batched matmul over the leading E axis; results gather back weighted
    by router probabilities.  Tokens beyond an expert's capacity C are
    dropped (standard capacity-factor semantics; the aux loss pushes the
    router toward balance, making drops rare).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * t * k / e))
    xf = x.reshape(t, d)

    logits = (xf @ params["router"]).astype(jnp.float32)  # [T, E]
    weights, experts = router_top_k(logits, k)  # [T,k]

    # Load-balancing auxiliary loss (Switch Transformer eq. 4).
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    me = probs.mean(axis=0)  # mean router prob per expert
    one_hot_top1 = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)  # fraction of tokens (top-1) per expert
    aux = e * jnp.sum(me * ce)

    # ---- sort-based dispatch ---------------------------------------- #
    flat_expert = experts.reshape(-1)  # [T*k]
    flat_weight = weights.reshape(-1)  # [T*k]
    flat_token = jnp.repeat(jnp.arange(t), k)  # [T*k]
    order = jnp.argsort(flat_expert)  # stable
    se, sw, stok = flat_expert[order], flat_weight[order], flat_token[order]
    # segment rank: index of each routed slot within its expert's run
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")  # [E]
    pos_in_expert = jnp.arange(t * k) - seg_start[se]
    keep = pos_in_expert < cap
    slot = jnp.clip(pos_in_expert, 0, cap - 1)

    # scatter tokens into [E, C, D]
    buf = jnp.zeros((e, cap, d), dtype=x.dtype)
    src = jnp.where(keep[:, None], xf[stok], 0.0)
    buf = buf.at[se, slot].add(src)
    buf = shard(buf, ("experts", None, "d_model"))

    # batched expert matmuls: [E, C, D] x [E, D, F] -> [E, C, F] -> [E, C, D]
    gate = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = shard(h, ("experts", None, "d_ff"))
    out_e = jnp.einsum("ecf,efd->ecd", h, params["wo"])

    # gather back to tokens, weighted
    vals = out_e[se, slot]  # [T*k, D]
    vals = jnp.where(keep[:, None], vals * sw[:, None].astype(x.dtype), 0.0)
    out = jnp.zeros((t, d), dtype=x.dtype).at[stok].add(vals)

    # shared experts (kimi-k2): dense SwiGLU applied to every token
    if cfg.n_shared_experts > 0:
        out = out + swiglu(params["shared"], xf.reshape(b, s, d)).reshape(t, d)
    return out.reshape(b, s, d), aux


# --------------------------------------------------------------------- #
# shard_map expert parallelism (the collective-bound hillclimb, §Perf)
# --------------------------------------------------------------------- #
def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_layer_ep(
    params: dict,
    x: jnp.ndarray,  # [B, S, D]
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map + explicit all_to_all.

    The GSPMD path (moe_layer) lets the partitioner handle the global
    scatter into the [E, C, D] dispatch buffer; at kimi-k2 scale the
    partitioner falls back to replicating the buffer (observed: 1.18 TB
    temp / 1.5 TB all-reduce per device).  This path makes the EP schedule
    explicit instead:

      per device (shard_map over the full mesh):
        route local tokens -> sort by destination EP shard -> fixed-
        capacity send buffer [n_ep, C, D] -> all_to_all('data') ->
        local dispatch to [E_loc, C2, D] (a LOCAL scatter: no SPMD
        repartitioning) -> batched expert matmuls (d_ff sliced over
        'model') -> partial down-proj -> gather back -> all_to_all
        ('data') -> weighted combine -> psum('model').

    Collectives per layer: 2 all_to_all of ~(tokens_loc * k * D) bytes +
    1 psum of the [B_loc, S, D] output — vs the GSPMD path's full-buffer
    all-reduces.  Tokens beyond capacity drop (capacity_factor), as in
    the GSPMD path.  Requires n_experts % (data-axis size) == 0.
    """
    mesh = current_mesh()
    if mesh is None or "data" not in mesh.axis_names:
        return moe_layer(params, x, cfg)
    n_ep = mesh.shape["data"]
    if cfg.n_experts % n_ep != 0:
        return moe_layer(params, x, cfg)
    e_loc = cfg.n_experts // n_ep
    bd = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    has_model = "model" in mesh.axis_names
    f = cfg.expert_ff
    f_axis = "model" if (has_model and f % mesh.shape["model"] == 0) else None

    # Make batch the only sharded activation dim at the boundary.
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(bd, None, None))
    )

    in_specs = (
        P(bd, None, None),  # x
        P(None, None),  # router (small; replicated)
        P("data", None, f_axis),  # wi_gate [E, D, F]
        P("data", None, f_axis),  # wi_up
        P("data", f_axis, None),  # wo [E, F, D]
    )
    args = [x, params["router"], params["wi_gate"], params["wi_up"], params["wo"]]
    has_shared = cfg.n_shared_experts > 0
    if has_shared:
        fs = f * cfg.n_shared_experts
        fs_axis = "model" if (has_model and fs % mesh.shape["model"] == 0) else None
        in_specs = in_specs + (
            P(None, fs_axis), P(None, fs_axis), P(fs_axis, None),
        )
        args += [params["shared"]["wi_gate"], params["shared"]["wi_up"], params["shared"]["wo"]]

    def body(xb, router, wg, wu, wo, *shared_w):
        b_loc, s, d = xb.shape
        t = b_loc * s
        xf = xb.reshape(t, d)
        logits = (xf @ router).astype(jnp.float32)  # [T, E] (global experts)
        weights, experts = router_top_k(logits, cfg.top_k)  # [T, k]

        probs = jax.nn.softmax(logits, axis=-1)
        # token-means are linear: pmean BEFORE the product so the aux loss
        # equals the global-batch formula exactly (tested vs moe_layer)
        me = jax.lax.pmean(probs.mean(axis=0), bd)
        ce = jax.lax.pmean(
            jax.nn.one_hot(experts[:, 0], cfg.n_experts, dtype=jnp.float32).mean(axis=0), bd
        )
        aux = cfg.n_experts * jnp.sum(me * ce)

        k = cfg.top_k
        flat_e = experts.reshape(-1)
        flat_w = weights.reshape(-1).astype(xb.dtype)
        flat_tok = jnp.repeat(jnp.arange(t), k)
        dest = flat_e // e_loc  # EP shard owning the expert
        local_e = flat_e % e_loc

        cap = _round_up(max(int(cfg.capacity_factor * t * k / n_ep), 8), 8)
        order = jnp.argsort(dest)
        d_s, tok_s, le_s, w_s = dest[order], flat_tok[order], local_e[order], flat_w[order]
        seg_start = jnp.searchsorted(d_s, jnp.arange(n_ep), side="left")
        pos = jnp.arange(t * k) - seg_start[d_s]
        keep = pos < cap
        slot = jnp.clip(pos, 0, cap - 1)

        send_x = jnp.zeros((n_ep, cap, d), xb.dtype).at[d_s, slot].add(
            jnp.where(keep[:, None], xf[tok_s], 0)
        )
        send_le = jnp.full((n_ep, cap), e_loc, jnp.int32).at[d_s, slot].min(
            jnp.where(keep, le_s, e_loc).astype(jnp.int32)
        )  # e_loc marks empty slots
        recv_x = jax.lax.all_to_all(send_x, "data", split_axis=0, concat_axis=0, tiled=False)
        recv_le = jax.lax.all_to_all(send_le, "data", split_axis=0, concat_axis=0, tiled=False)

        # local dispatch: [n_ep * cap] slots -> [E_loc, C2, D]
        rl = recv_le.reshape(-1)
        rx = recv_x.reshape(-1, d)
        c2 = _round_up(max(int(cfg.capacity_factor * n_ep * cap / e_loc), 8), 8)
        order2 = jnp.argsort(rl)  # empty slots (e_loc) sort to the end
        rl2, idx2 = rl[order2], order2
        seg2 = jnp.searchsorted(rl2, jnp.arange(e_loc), side="left")
        pos2 = jnp.arange(rl2.shape[0]) - seg2[jnp.clip(rl2, 0, e_loc - 1)]
        keep2 = (pos2 < c2) & (rl2 < e_loc)
        slot2 = jnp.clip(pos2, 0, c2 - 1)
        buf = jnp.zeros((e_loc, c2, d), xb.dtype).at[
            jnp.clip(rl2, 0, e_loc - 1), slot2
        ].add(jnp.where(keep2[:, None], rx[idx2], 0))

        gate = jnp.einsum("ecd,edf->ecf", buf, wg)
        up = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(xb.dtype) * up
        out_e = jnp.einsum("ecf,efd->ecd", h, wo)  # partial over sliced f

        # undo local dispatch: back to [n_ep * cap] slot order
        vals = out_e[jnp.clip(rl2, 0, e_loc - 1), slot2]
        vals = jnp.where(keep2[:, None], vals, 0)
        back = jnp.zeros((rl.shape[0], d), xb.dtype).at[idx2].add(vals)
        back = back.reshape(n_ep, cap, d)
        ret_x = jax.lax.all_to_all(back, "data", split_axis=0, concat_axis=0, tiled=False)

        # combine on the home device
        vals_home = ret_x[d_s, slot]
        vals_home = jnp.where(keep[:, None], vals_home * w_s[:, None], 0)
        out = jnp.zeros((t, d), xb.dtype).at[tok_s].add(vals_home)

        if shared_w:
            swg, swu, swo = shared_w
            hs = jax.nn.silu((xf @ swg).astype(jnp.float32)).astype(xb.dtype) * (xf @ swu)
            out = out + hs @ swo  # partial over sliced fs
        if has_model:
            out = jax.lax.psum(out, "model")
        return out.reshape(b_loc, s, d), aux

    out_specs = (P(bd, None, None), P())
    fn = _shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return fn(*args)
