"""Model substrate: config, logical-axis params, norms, RoPE, embeddings.

All models are pure-functional JAX (params as pytrees).  Every parameter
carries **logical axis names** (a parallel pytree of tuples) so the
distribution layer (distributed/sharding.py) can map any architecture onto
any mesh with a rule table — the same mechanism MaxText uses.  Sharding
constraints inside model code go through :func:`shard` which resolves the
current rule set (a context var); with no rules active it is a no-op, so
models run unchanged on a single CPU device for smoke tests.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ModelConfig",
    "ParamStore",
    "axis_rules",
    "current_rules",
    "logical_to_spec",
    "shard",
    "rms_norm",
    "layer_norm",
    "rope_frequencies",
    "apply_rope",
    "apply_mrope",
    "cross_entropy_loss",
]


# --------------------------------------------------------------------- #
# Config
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class ModelConfig:
    """One config covers all 10 assigned architectures (see configs/)."""

    arch: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention
    attention: str = "full"  # full | swa | none
    swa_window: int = 4096
    rope_theta: float = 500000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (kimi: 2048); 0 -> d_ff
    n_shared_experts: int = 0  # kimi: 1 shared expert
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0  # mamba2 state size (zamba2: 64) or rwkv head state
    hybrid_attn_every: int = 0  # zamba2: shared attn block every N mamba blocks
    # enc-dec (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500  # whisper: 30s of audio -> 1500 frames
    # VLM (qwen2-vl)
    m_rope: bool = False
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    # implementation selection (perf knobs; semantics-preserving)
    attn_impl: str = "naive"  # naive | chunked  (chunked = XLA flash attention)
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    moe_impl: str = "gspmd"  # gspmd | shard_map_ep
    # numerics
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0
    tie_embeddings: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def params_count(self) -> int:
        """Total parameter count (for roofline MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.head_dim_
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family in ("ssm",):  # rwkv6: time-mix + channel-mix
            per_layer = 4 * d * d + 2 * d * self.d_ff + d * d  # r,k,v,o,g + ffn
        elif self.family == "hybrid":
            # mamba2 block: in_proj (z,x: d->4d) + bc/dt proj + out_proj (2d->d)
            d_inner = 2 * d
            n_h = d_inner // 64
            per_layer = (
                d * 2 * d_inner + d * 2 * self.ssm_state + d * n_h + d_inner * d
            )
        else:
            per_layer = attn + 3 * d * self.d_ff
        if self.n_experts > 0:
            moe = self.n_experts * 3 * d * self.expert_ff
            dense_ffn = 3 * d * self.expert_ff * self.n_shared_experts
            per_layer = attn + moe + dense_ffn + d * self.n_experts
        if self.family == "audio":
            # decoder: self-attn + cross-attn + 2-matrix GELU MLP
            per_layer = 2 * attn + 2 * d * self.d_ff
        n = self.n_layers * per_layer + self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "hybrid":
            n += attn + 3 * d * self.d_ff  # the single shared attn+MLP block
        if self.enc_dec:
            n += self.enc_layers * (attn + 2 * d * self.d_ff + attn)  # enc + cross
        return n

    def active_params_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.n_experts == 0:
            return self.params_count()
        d = self.d_model
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        active_moe = (self.top_k + self.n_shared_experts) * 3 * d * self.expert_ff
        per_layer = attn + active_moe + d * self.n_experts
        return self.n_layers * per_layer + self.vocab * d * 2


# --------------------------------------------------------------------- #
# Logical axis rules (context) + sharding constraint helper
# --------------------------------------------------------------------- #
_RULES: contextvars.ContextVar[tuple[tuple[str, Any], ...] | None] = contextvars.ContextVar(
    "axis_rules", default=None
)
_MESH: contextvars.ContextVar[Any] = contextvars.ContextVar("model_mesh", default=None)


@contextlib.contextmanager
def axis_rules(rules: dict[str, Any], mesh: Any = None):
    """Activate logical->mesh axis rules, e.g. {"batch": ("pod", "data"),
    "heads": "model"}.  Values may be str, tuple or None.  The optional
    mesh is what shard_map-based layers (ffn.moe_layer_ep) run over."""
    tok = _RULES.set(tuple(rules.items()))
    tok_m = _MESH.set(mesh)
    try:
        yield
    finally:
        _RULES.reset(tok)
        _MESH.reset(tok_m)


def current_rules() -> dict[str, Any]:
    r = _RULES.get()
    return dict(r) if r else {}


def current_mesh():
    return _MESH.get()


def logical_to_spec(axes: tuple[str | None, ...], rules: dict[str, Any] | None = None) -> P:
    """Map logical axis names to a PartitionSpec under the given rules.

    Guards against reusing one mesh axis for two tensor dims (illegal in
    GSPMD): later dims that would reuse an axis get None.
    """
    rules = current_rules() if rules is None else rules
    used: set[str] = set()
    spec = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            spec.append(None)
            continue
        parts = (m,) if isinstance(m, str) else tuple(m)
        free = tuple(p for p in parts if p not in used)
        if not free:
            spec.append(None)
            continue
        used.update(free)
        spec.append(free[0] if len(free) == 1 else free)
    return P(*spec)


def shard(x: jnp.ndarray, axes: tuple[str | None, ...]) -> jnp.ndarray:
    """Apply a sharding constraint if rules are active; no-op otherwise."""
    rules = current_rules()
    if not rules:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, logical_to_spec(axes, rules))
    except (ValueError, RuntimeError):
        return x  # outside a mesh context


# --------------------------------------------------------------------- #
# Parameter store with logical axes
# --------------------------------------------------------------------- #
class ParamStore:
    """Accumulates params + their logical axes during init."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype
        self.params: dict[str, Any] = {}
        self.axes: dict[str, Any] = {}

    def _split(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(
        self,
        name: str,
        shape: tuple[int, ...],
        axes: tuple[str | None, ...],
        *,
        init: str = "normal",
        scale: float | None = None,
        dtype=None,
    ) -> jnp.ndarray:
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.dtype
        if init == "normal":
            scale = scale if scale is not None else 1.0 / math.sqrt(shape[-2] if len(shape) >= 2 else shape[-1])
            x = jax.random.normal(self._split(), shape, dtype=jnp.float32) * scale
        elif init == "zeros":
            x = jnp.zeros(shape, dtype=jnp.float32)
        elif init == "ones":
            x = jnp.ones(shape, dtype=jnp.float32)
        elif init == "uniform":
            s = scale if scale is not None else 1.0
            x = jax.random.uniform(self._split(), shape, minval=-s, maxval=s, dtype=jnp.float32)
        else:
            raise ValueError(f"unknown init {init!r}")
        x = x.astype(dtype)
        _assign(self.params, name, x)
        _assign(self.axes, name, axes)
        return x


def _assign(tree: dict, dotted: str, value: Any) -> None:
    parts = dotted.split(".")
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    tree[parts[-1]] = value


# --------------------------------------------------------------------- #
# Numerics
# --------------------------------------------------------------------- #
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(
    x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray | None, eps: float = 1e-5
) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for rotary embeddings [head_dim // 2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, Dh]; positions: [B, S] int32 -> rotated x."""
    dh = x.shape[-1]
    inv = rope_frequencies(dh, theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * inv  # [B, S, Dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions_3d: jnp.ndarray,
    theta: float,
    sections: tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: positions_3d [3, B, S] (t, h, w ids).

    The head-dim half is split into `sections` (t, h, w) frequency bands;
    each band rotates by its own position stream (arXiv:2409.12191 §3.1).
    """
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    inv = rope_frequencies(dh, theta)  # [Dh/2]
    # Select which position stream drives each frequency band.
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=dh // 2
    )  # [Dh/2] in {0,1,2}
    pos = positions_3d.astype(jnp.float32)  # [3, B, S]
    # angles[b, s, f] = pos[sec_id[f], b, s] * inv[f]
    pos_sel = pos[sec_id, :, :]  # [Dh/2, B, S]
    angles = jnp.transpose(pos_sel, (1, 2, 0)) * inv  # [B, S, Dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Mean next-token cross entropy; logits [B, S, V], labels [B, S]."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
