"""Dispatch wrapper: Pallas on TPU, jnp reference on CPU."""
from __future__ import annotations
import jax
from . import kernel as _kernel, ref as _ref


def attention(q, k, v, *, causal=True, window=None, interpret=False, force_kernel=False):
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.flash_attention_pallas(
            q, k, v, causal=causal, window=window, interpret=interpret
        )
    return _ref.attention(q, k, v, causal=causal, window=window)
