"""Pure-jnp oracle for flash attention (heads-first layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention"]


def attention(
    q: jnp.ndarray,  # [B, H, Sq, Dh]
    k: jnp.ndarray,  # [B, H, Skv, Dh]
    v: jnp.ndarray,  # [B, H, Skv, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    scale = scale if scale is not None else 1.0 / (dh**0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    if causal:
        q_pos = jnp.arange(sq)[:, None] + (skv - sq)
        k_pos = jnp.arange(skv)[None, :]
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
