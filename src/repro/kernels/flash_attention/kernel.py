"""Pallas TPU flash attention (blockwise online softmax).

Tiling: grid = (B*H, Sq/bq, Skv/bk) with the KV axis innermost, so each
(bh, iq) out block is revisited across sequential KV steps — the running
max / normaliser / accumulator live in VMEM scratch that persists across
the revisits (TPU grid steps execute in order).  VMEM per step:

  q (bq, Dh) + k,v (bk, Dh) + acc (bq, Dh) f32 + logits (bq, bk) f32
  ~ (128*128)*2*3 + 128*128*4*2 = 230 KiB  << 16 MiB,

leaving headroom to raise bq/bk to 512 on real hardware.  Causal masking
prunes fully-masked KV blocks with @pl.when (they still occupy grid steps
but skip the matmuls — XLA's Mosaic pipeline makes them near-free; a
fully tight skip needs a data-dependent grid, out of scope here).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, window, bq, bk, skv, sq):
    ik = pl.program_id(2)
    n_k = pl.num_programs(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq + (skv - sq)  # absolute position of q block row 0
    k_start = ik * bk

    run = True
    if causal:
        # fully-masked block: first k position beyond the last q position
        run = k_start <= q_start + bq - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, Dh)
        k = k_ref[0].astype(jnp.float32)  # (bk, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = k_pos <= q_pos
            if window is not None:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret", "scale")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [B, H, Sq, Dh]
    k: jnp.ndarray,  # [B, H, Skv, Dh]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    scale = scale if scale is not None else 1.0 / (dh**0.5)
    bh = b * h
    qr = q.reshape(bh, sq, dh)
    kr = k.reshape(bh, skv, dh)
    vr = v.reshape(bh, skv, dh)
    grid = (bh, sq // bq, skv // bk)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk, skv=skv, sq=sq
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh_, iq, ik: (bh_, iq, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh_, iq, ik: (bh_, ik, 0)),
            pl.BlockSpec((1, bk, dh), lambda bh_, iq, ik: (bh_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh_, iq, ik: (bh_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),  # running max
            pltpu.VMEM((bq,), jnp.float32),  # running normaliser
            pltpu.VMEM((bq, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, dh)
