"""Erlang-B/C recurrence kernel — the analytic core's hot loop.

The batched scheduler (core/batched.py) evaluates Erlang-C sojourn times
for every operator at every processor count up to K_max.  The only
sequential part is the Erlang-B recursion

    B(0) = 1;  B(j) = a * B(j-1) / (j + a * B(j-1)),   j = 1..K,

which is embarrassingly parallel across operators / offered loads ``a``
(lanes) and sequential only in ``j`` (the fori_loop).  ``ops.erlang_b_table``
dispatches: Pallas kernel on TPU, pure-jnp scan oracle elsewhere; the
float64 *numpy* path that the allocator's bit-exactness guarantee rests on
lives in ``core/batched.py`` (see DESIGN.md §12 for the fallback rules).
"""

from .ops import erlang_b_table

__all__ = ["erlang_b_table"]
