"""Pure-jnp oracle for the Erlang-B recurrence table (lax.scan over j)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["erlang_b_table"]


def erlang_b_table(a: jnp.ndarray, *, k_hi: int, unroll: int = 1) -> jnp.ndarray:
    """[S] offered loads -> [k_hi+1, S] table; dtype follows the input
    (float64 under enable_x64, else float32).

    ``unroll`` is forwarded to ``lax.scan``: it restructures the loop
    without reassociating any per-lane float op, so the table is bitwise
    identical for every value (asserted in tests/test_kernels_all.py).
    """
    a = jnp.asarray(a)
    b0 = jnp.ones_like(a)

    def step(b, j):
        b = a * b / (j + a * b)
        return b, b

    js = jnp.arange(1, k_hi + 1, dtype=a.dtype)
    _, rows = jax.lax.scan(step, b0, js, unroll=max(int(unroll), 1))
    return jnp.concatenate([b0[None, :], rows], axis=0)
