"""Dispatch wrapper: Pallas on TPU, jnp scan reference elsewhere."""

from __future__ import annotations

import jax

from . import kernel as _kernel, ref as _ref

__all__ = ["erlang_b_table"]


def erlang_b_table(
    a,
    *,
    k_hi: int,
    interpret: bool = False,
    force_kernel: bool = False,
    unroll: int = 1,
):
    """[S] offered loads -> [k_hi+1, S] Erlang-B blocking table.

    ``unroll`` tunes the reference scan's unroll factor (bitwise-safe);
    the Pallas kernel iterates in-core and ignores it.
    """
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.erlang_b_table_pallas(a, k_hi=k_hi, interpret=interpret)
    return _ref.erlang_b_table(a, k_hi=k_hi, unroll=unroll)
