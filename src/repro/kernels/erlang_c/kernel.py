"""Pallas TPU kernel: the Erlang-B recurrence table over a lane of loads.

One grid step; the offered loads sit in a (1, S) VMEM row (S padded to the
128-lane width) and the fori_loop walks j = 1..k_hi writing one (1, S) row
of the table per step:

    B(j) = a * B(j-1) / (j + a * B(j-1)).

The recursion is inherently sequential in j, so the kernel's only
parallelism is across lanes — which is exactly the batch axis the
scheduler needs (operators x tenants).  VMEM footprint is the whole
(k_hi+1, S) table: k_hi = 4096 at S = 128 lanes is 4097*128*4 B ~ 2 MiB,
comfortably under the ~16 MiB budget; callers tile S beyond one lane row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["erlang_b_table_pallas"]


def _erlang_b_kernel(a_ref, out_ref, *, k_hi: int):
    a = a_ref[...]  # (1, S)
    ones = jnp.ones_like(a)
    out_ref[pl.ds(0, 1), :] = ones

    def body(j, b):
        b = a * b / (j.astype(a.dtype) + a * b)
        out_ref[pl.ds(j, 1), :] = b
        return b

    jax.lax.fori_loop(1, k_hi + 1, body, ones)


@functools.partial(jax.jit, static_argnames=("k_hi", "interpret"))
def erlang_b_table_pallas(
    a: jnp.ndarray, *, k_hi: int, interpret: bool = False
) -> jnp.ndarray:
    """[S] offered loads -> [k_hi+1, S] Erlang-B blocking table (float32).

    Row j holds B(j, a) for every lane; row 0 is all-ones.  Lanes are
    padded to 128 and the pad is sliced off before returning.
    """
    if a.ndim != 1:
        raise ValueError(f"a must be 1-D, got shape {a.shape}")
    s = a.shape[0]
    lane_pad = (-s) % 128
    rows = k_hi + 1
    row_pad = (-rows) % 8  # float32 sublane tile
    a2 = jnp.pad(a.astype(jnp.float32), (0, lane_pad)).reshape(1, s + lane_pad)
    out = pl.pallas_call(
        functools.partial(_erlang_b_kernel, k_hi=k_hi),
        out_shape=jax.ShapeDtypeStruct((rows + row_pad, s + lane_pad), jnp.float32),
        interpret=interpret,
    )(a2)
    return out[:rows, :s]
