"""Pallas TPU flash-decoding: single-token attention over a blocked KV cache.

One new token attends to a long cache.  The cache's sequence axis is the
only large dimension, so the kernel blocks over it: grid = (B, S/bs), with
running max / normaliser / accumulator in VMEM scratch across the
sequential S steps (same revisiting pattern as flash_attention, one q row
per head instead of a q block).  All heads of one batch element are
processed in a grid step: the q "matrix" is (H, Dh) — small — and each
step's score matrix is (H, bs).

This kernel is also the single-device mirror of the cross-device
sequence-sharded decode schedule (distributed/sharding.py DECODE_RULES
maps kv_seq -> "model"): on the pod, GSPMD computes per-shard partial
softmax and all-reduces (max, sum, acc) — exactly what this kernel's
scratch does across blocks within one chip.

Length masking uses absolute positions against a scalar prefix length in
SMEM, so cache slots past `length` contribute nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

_NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, window, bs):
    ib = pl.program_id(0)
    ik = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ik * bs
    run = k_start < length  # skip wholly-invalid cache blocks

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (H, Dh)
        k = k_ref[0].astype(jnp.float32)  # (bs, H, Dh)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.einsum("hd,shd->hs", q, k) * scale  # (H, bs)
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = pos < length
        if window is not None:
            valid &= pos > (length - 1 - window)
        s = jnp.where(valid, s, _NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.einsum("hs,shd->hd", p, v)
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret", "scale"))
def decode_attention_pallas(
    q: jnp.ndarray,  # [B, H, Dh]
    k_cache: jnp.ndarray,  # [B, S, H, Dh]
    v_cache: jnp.ndarray,
    length: jnp.ndarray,  # [] int32
    *,
    window: int | None = None,
    scale: float | None = None,
    bs: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, dh = q.shape
    s = k_cache.shape[1]
    assert s % bs == 0, (s, bs)
    scale = scale if scale is not None else 1.0 / (dh**0.5)
    grid = (b, s // bs)
    kern = functools.partial(_kernel, scale=scale, window=window, bs=bs)
    length_arr = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, h, dh), lambda ib, ik: (ib, 0, 0)),
            pl.BlockSpec((1, bs, h, dh), lambda ib, ik: (ib, ik, 0, 0)),
            pl.BlockSpec((1, bs, h, dh), lambda ib, ik: (ib, ik, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda ib, ik: (ib, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
        interpret=interpret,
    )(length_arr, q, k_cache, v_cache)
