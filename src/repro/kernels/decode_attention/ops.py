"""Dispatch wrapper: Pallas on TPU, jnp reference on CPU."""
from __future__ import annotations
import jax
from . import kernel as _kernel, ref as _ref


def decode_attention(q, k_cache, v_cache, length, *, window=None, interpret=False, force_kernel=False):
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.decode_attention_pallas(
            q, k_cache, v_cache, length, window=window, interpret=interpret
        )
    return _ref.decode_attention(q, k_cache, v_cache, length, window=window)
