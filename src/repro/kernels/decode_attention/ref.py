"""Pure-jnp oracle for single-token decode attention with a KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decode_attention"]


def decode_attention(
    q: jnp.ndarray,  # [B, H, Dh] — one new token per sequence
    k_cache: jnp.ndarray,  # [B, S, H, Dh]
    v_cache: jnp.ndarray,  # [B, S, H, Dh]
    length: jnp.ndarray,  # [] or [B] int32 — valid prefix
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    b, h, dh = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / (dh**0.5)
    logits = jnp.einsum(
        "bhd,bshd->bhs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(s)
    ln = jnp.broadcast_to(jnp.asarray(length), (b,))
    valid = pos[None, :] < ln[:, None]  # [B, S]
    if window is not None:
        valid &= pos[None, :] > (ln[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", probs, v_cache.astype(jnp.float32)).astype(q.dtype)
