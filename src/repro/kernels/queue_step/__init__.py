"""Bounded-queue fluid-step kernel (batch scenario simulator hot loop)."""

from . import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
