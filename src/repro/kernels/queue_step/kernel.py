"""Pallas TPU kernel: the bounded-queue fluid step over a lane of queues.

One grid step, no loop: the four state/input rows sit in (1, S) VMEM rows
(S padded to the 128-lane width) and the update is a handful of VPU
min/max ops per lane:

    served   = min(q, cap_serve)
    q1       = q - served
    admitted = min(inflow, max(cap_queue - q1, 0))
    q_next   = q1 + admitted,   dropped = inflow - admitted

The lane axis is scenarios x operators — exactly the batch the scenario
matrix sweeps (`streaming/batchsim.py` calls this once per simulated time
step from inside a lax.scan).  `cap_queue = +inf` encodes unbounded or
block-policy lanes, whose `dropped` is then identically 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["queue_step_pallas"]


def _queue_step_kernel(q_ref, inflow_ref, cap_serve_ref, cap_queue_ref,
                       q_next_ref, served_ref, dropped_ref):
    q = q_ref[...]  # (1, S)
    inflow = inflow_ref[...]
    served = jnp.minimum(q, cap_serve_ref[...])
    q1 = q - served
    space = jnp.maximum(cap_queue_ref[...] - q1, 0.0)
    admitted = jnp.minimum(inflow, space)
    q_next_ref[...] = q1 + admitted
    served_ref[...] = served
    dropped_ref[...] = inflow - admitted


@functools.partial(jax.jit, static_argnames=("interpret",))
def queue_step_pallas(q, inflow, cap_serve, cap_queue, *, interpret: bool = False):
    """[M] queue lanes -> (q_next, served, dropped), each [M] float32.

    Lanes are padded to 128 and the pad is sliced off before returning.
    Padding rides through as all-zero lanes (0 backlog, 0 inflow, 0
    capacity -> 0 outputs).
    """
    if q.ndim != 1:
        raise ValueError(f"q must be 1-D, got shape {q.shape}")
    m = q.shape[0]
    pad = (-m) % 128
    rows = [
        jnp.pad(jnp.asarray(x, dtype=jnp.float32), (0, pad)).reshape(1, m + pad)
        for x in (q, inflow, cap_serve, cap_queue)
    ]
    shape = jax.ShapeDtypeStruct((1, m + pad), jnp.float32)
    q_next, served, dropped = pl.pallas_call(
        _queue_step_kernel,
        out_shape=(shape, shape, shape),
        interpret=interpret,
    )(*rows)
    return q_next[0, :m], served[0, :m], dropped[0, :m]
