"""Pure-jnp oracle for the bounded-queue fluid step (DESIGN.md §13).

One discrete-time step of every (scenario, operator) queue lane:

    served   = min(q, cap_serve)             # drain the step-start backlog
    q1       = q - served
    space    = max(cap_queue - q1, 0)        # +inf lanes never shed (block /
    admitted = min(inflow, space)            #  unbounded queues)
    dropped  = inflow - admitted
    q_next   = q1 + admitted

Entirely elementwise, so the lane axis carries scenarios x operators.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["queue_step"]


def queue_step(q, inflow, cap_serve, cap_queue):
    """[M] lanes -> (q_next, served, dropped), each [M], dtype follows q."""
    q = jnp.asarray(q)
    inflow = jnp.asarray(inflow, dtype=q.dtype)
    cap_serve = jnp.asarray(cap_serve, dtype=q.dtype)
    cap_queue = jnp.asarray(cap_queue, dtype=q.dtype)
    served = jnp.minimum(q, cap_serve)
    q1 = q - served
    space = jnp.maximum(cap_queue - q1, 0.0)
    admitted = jnp.minimum(inflow, space)
    dropped = inflow - admitted
    return q1 + admitted, served, dropped
