"""Dispatch wrapper: Pallas on TPU, jnp oracle elsewhere."""

from __future__ import annotations

import jax

from . import kernel as _kernel, ref as _ref

__all__ = ["queue_step"]


def queue_step(q, inflow, cap_serve, cap_queue, *,
               interpret: bool = False, force_kernel: bool = False):
    """[M] queue lanes -> (q_next, served, dropped).

    Pallas kernel on TPU (or with ``force_kernel=True, interpret=True`` on
    CPU — repo kernel idiom, see kernels/__init__.py); jnp oracle
    elsewhere.  Note the kernel computes in float32; the oracle follows
    the input dtype (float64 under enable_x64).
    """
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.queue_step_pallas(q, inflow, cap_serve, cap_queue,
                                         interpret=interpret)
    return _ref.queue_step(q, inflow, cap_serve, cap_queue)
