"""Dispatch wrapper: Pallas fused kernel on TPU, jnp oracle elsewhere.

Also hosts the block-shape tuning hooks the compiled bench tier
persists: ``DEFAULT_UNROLL`` (the Erlang scan unroll the CPU oracle
runs with — unroll is bitwise-safe, so the tuned value is purely a perf
knob) and ``autotune_unroll`` (a small sweep the bench records into
``BENCH_kernels.json`` so a host's best factor is reproducible).
"""

from __future__ import annotations

import time

import jax

from . import kernel as _kernel, ref as _ref

__all__ = ["batch_decide", "autotune_unroll", "DEFAULT_UNROLL", "UNROLL_SWEEP"]

# Measured on the reference CPU host: unroll=4 is ~1.5x over unroll=1 on
# the [112, 512] Erlang scan and the table is bitwise identical (tested).
DEFAULT_UNROLL = 4
UNROLL_SWEEP = (1, 2, 4, 8)


def batch_decide(
    lam,
    mu_eff,
    *,
    group,
    alpha,
    active,
    k_cur,
    k_max,
    k_hi: int,
    j_cap: int | None = None,
    unroll: int | None = None,
    interpret: bool = False,
    force_kernel: bool = False,
    n_pad: int = _kernel._LANE,
):
    """``[B, N]`` solved rates -> ``(k4, k_start, t_cur, t4)``.

    Kernel dispatch follows the repo idiom (``force_kernel`` or a real
    TPU backend -> Pallas, else the jnp oracle; ``interpret`` alone does
    not switch).  The oracle keeps the caller's dtype and is bit-exact
    with the two-pass decide; the kernel is float32 end to end.

    Compacted-width invocation (DESIGN.md §18): the trigger-gated sparse
    decide calls this at each rung of the ``bucket_ladder`` — ``B`` is
    just the leading grid extent, so every rung is a separate (cached)
    jit/Pallas specialization while the lane-axis pad arithmetic
    (``_pad_shapes``, keyed on ``(n, k_hi, n_pad)`` only) is shared
    across rungs.  Lanes gathered twice via the clipped fill index
    compute real rows that the caller's drop-mode scatter discards —
    every op here is per-scenario-lane, so duplicated rows cannot
    contaminate their neighbours.
    """
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.batch_decide_pallas(
            lam, mu_eff, group, alpha, active, k_cur, k_max,
            k_hi=k_hi, j_cap=j_cap, interpret=interpret, n_pad=n_pad,
        )
    return _ref.batch_decide(
        lam, mu_eff, group=group, alpha=alpha, active=active,
        k_cur=k_cur, k_max=k_max, k_hi=k_hi, j_cap=j_cap,
        unroll=DEFAULT_UNROLL if unroll is None else unroll,
        interpret=interpret, force_kernel=force_kernel,
    )


def autotune_unroll(a, *, k_hi: int, sweep=UNROLL_SWEEP, reps: int = 5):
    """Time the Erlang-B reference scan per unroll factor.

    Returns ``(best_unroll, {unroll: seconds})``.  Because unroll is
    bitwise-safe the result only affects speed; the bench persists the
    sweep so the chosen ``DEFAULT_UNROLL`` stays auditable per host.
    """
    import jax.numpy as jnp

    from ..erlang_c import ref as _eref

    a = jnp.asarray(a)
    timings: dict[int, float] = {}
    for u in sweep:
        fn = jax.jit(lambda x, u=u: _eref.erlang_b_table(x, k_hi=k_hi, unroll=u))
        fn(a).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(a).block_until_ready()
        timings[u] = (time.perf_counter() - t0) / reps
    best = min(timings, key=timings.get)
    return best, timings
