"""Pallas TPU kernel: the whole batch decide in one VMEM-resident pass.

One grid step per scenario.  The per-operator lanes sit on the 128-wide
lane axis; the allocation axis ``k`` walks the float32 sublane tiles of
two VMEM scratch buffers:

1. **Recurrence** — a ``fori_loop`` over ``k = 1..k_hi`` carries the
   Erlang-B blocking row ``B(k)`` and the previous sojourn row, writing
   one ``(1, N)`` row of the ``E[T_i](k)`` table (Erlang-C conversion
   for replica lanes, the M/M/1 closed form for group-scaled lanes) and
   one Algorithm-1 gain row ``G[k-1] = lam * (T[k-1] - T[k])`` per step.
2. **Floor** — ``k_start`` = first finite table row per lane (min-reduce
   over a row iota; ``k_hi + 1`` marks an infeasible active lane), and
   the Program-4 budget = ``max(k_max - sum k_start, 0)`` from the SMEM
   scalar.
3. **Selection** — the budget-th largest gain inside each lane's
   ``[k_start, k_start + j_cap)`` window is pinned by 31 bisection steps
   over float32 bit patterns (positive IEEE-754 floats order like their
   int32 bits — the ``kernels/gain_topr`` technique, applied here to the
   *unshifted* gain table: the window mask replaces the two-pass path's
   gather, which selects exactly the same entries).  Threshold ties are
   distributed in operator order via a strictly-lower-triangular matmul
   prefix-sum.
4. **Pricing** — ``T[k4]`` and ``T[k_cur]`` leave the core as two
   ``(1, N)`` rows (one-hot row selects), so the caller can price the
   allocation without the ``[B, N, K]`` table ever reaching HBM.

Everything is float32 (allocation counts are exact integers far below
2^24).  The jnp oracle (`ref.py`) computes the identical result in the
caller's dtype; interpret-mode tests assert elementwise agreement on
float32 inputs.  HBM traffic per scenario drops from the two-pass
path's ~``3 * N * K`` table floats to ``6 * N`` lane floats in and
``4 * N`` out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["batch_decide_pallas"]

_LANE = 128


@functools.lru_cache(maxsize=None)
def _pad_shapes(n: int, k_hi: int, n_pad: int) -> tuple[int, int, int]:
    """(lane-padded N, T-table rows, G-table rows), tile-aligned.

    Hoisted out of the traced wrapper body (and cached per shape) so
    retracing never recomputes pad arithmetic — the same hoist as
    ``kernels/gain_topr``.
    """
    npad = n + ((-n) % n_pad)
    rows_t = (k_hi + 1) + ((-(k_hi + 1)) % 8)  # float32 sublane tile
    rows_g = k_hi + ((-k_hi) % 8)
    return npad, rows_t, rows_g


def _decide_fused_kernel(
    lam_ref, mu_ref, grp_ref, alpha_ref, act_ref, kcur_ref, kmax_ref,
    k4_ref, kst_ref, tcur_ref, t4_ref,
    t_scr, g_scr,
    *, k_hi: int, j_cap: int,
):
    lam = lam_ref[...]  # (1, Np) float32
    mu = mu_ref[...]
    grp = grp_ref[...] > 0.0
    alpha = alpha_ref[...]
    act = act_ref[...] > 0.0
    kcur = kcur_ref[...]
    kmax = kmax_ref[0, 0].astype(jnp.float32)

    inf = jnp.float32(jnp.inf)
    one = jnp.float32(1.0)  # typed: weak-float where() would promote to f64
    zero = jnp.float32(0.0)
    a_rep = lam / mu
    row_inf = jnp.full_like(lam, inf)
    t_scr[pl.ds(0, 1), :] = row_inf  # k = 0 is never feasible (min_k = 1)

    def body(k, carry):
        b_prev, t_prev = carry
        kf = k.astype(jnp.float32)
        bb = a_rep * b_prev / (kf + a_rep * b_prev)
        # Erlang-C conversion + replica sojourn (core/batched.py mirror).
        c = kf * bb / (kf - a_rep * (1.0 - bb))
        t_rep = c / (kf * mu - lam) + 1.0 / mu
        t_rep = jnp.where(kf > a_rep, t_rep, inf)
        # Group-scaled lanes: M/M/1 at mu * k * eff(k).
        eff = 1.0 / (1.0 + alpha * (kf - 1.0))
        mug = mu * kf * eff
        ag = lam / mug
        bg = ag / (1.0 + ag)
        cg = bg / (1.0 - ag * (1.0 - bg))
        t_grp = cg / (mug - lam) + 1.0 / mug
        t_grp = jnp.where(ag < 1.0, t_grp, inf)
        t = jnp.where(grp, t_grp, t_rep)
        t_scr[pl.ds(k, 1), :] = t
        g = lam * (t_prev - t)
        g_scr[pl.ds(k - 1, 1), :] = jnp.where(jnp.isfinite(t_prev), g, inf)
        return bb, t

    jax.lax.fori_loop(1, k_hi + 1, body, (jnp.ones_like(lam), row_inf))
    rows_t, rows_g = t_scr.shape[0], g_scr.shape[0]
    for r in range(k_hi + 1, rows_t):  # static tile-pad rows, masked below
        t_scr[pl.ds(r, 1), :] = row_inf
    for r in range(k_hi, rows_g):
        g_scr[pl.ds(r, 1), :] = jnp.zeros_like(lam)

    T = t_scr[...]
    G = g_scr[...]
    kio_t = jax.lax.broadcasted_iota(jnp.float32, T.shape, 0)
    kio_g = jax.lax.broadcasted_iota(jnp.float32, G.shape, 0)

    # Minimal feasible allocation: first finite table row per lane.
    fin = jnp.isfinite(T) & (kio_t <= k_hi)
    first = jnp.min(
        jnp.where(fin, kio_t, jnp.float32(rows_t + 1)), axis=0, keepdims=True
    )
    has_f = first <= k_hi
    kst = jnp.where(act, jnp.where(has_f, first, jnp.float32(k_hi + 1)), 0.0)
    floor_total = jnp.sum(kst)
    bud = jnp.maximum(kmax - floor_total, 0.0)

    # Program 4: masked top-R over the raw gain table.  The window mask
    # IS the two-pass path's shifted gather (same entries, same order).
    win = (
        (kio_g >= kst) & (kio_g < kst + j_cap) & (kio_g < k_hi)
        & act & jnp.isfinite(G)
    )
    pos = win & (G > 0.0)
    pos_row = jnp.sum(jnp.where(pos, one, zero), axis=0, keepdims=True)
    total_pos = jnp.sum(pos_row)
    use_all = total_pos <= bud

    def bisect(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2  # int32-overflow-safe midpoint
        t = jax.lax.bitcast_convert_type(mid, jnp.float32)
        c = jnp.sum(jnp.where(pos & (G >= t), one, zero))
        enough = c >= bud  # still >= budget entries at/above mid
        return jnp.where(enough, mid, lo), jnp.where(enough, hi, mid)

    # Invariant: count(>= bitcast(lo)) >= budget > count(>= bitcast(hi));
    # 31 halvings leave bitcast(lo) == the budget-th largest positive gain.
    lo, _hi = jax.lax.fori_loop(
        0, 31, bisect, (jnp.int32(1), jnp.int32(0x7F800000))
    )
    thresh = jax.lax.bitcast_convert_type(lo, jnp.float32)
    strict = jnp.sum(jnp.where(pos & (G > thresh), one, zero), axis=0, keepdims=True)
    ties = jnp.sum(jnp.where(pos & (G == thresh), one, zero), axis=0, keepdims=True)
    rem = bud - jnp.sum(strict)
    np_ = ties.shape[-1]
    row = jax.lax.broadcasted_iota(jnp.float32, (np_, np_), 0)
    col = jax.lax.broadcasted_iota(jnp.float32, (np_, np_), 1)
    lower = jnp.where(row < col, one, zero)  # strictly-lower mask
    before = jnp.dot(ties, lower, preferred_element_type=jnp.float32)
    extra = jnp.clip(jnp.minimum(ties, rem - before), zero, None)
    take = jnp.where(use_all, pos_row, strict + extra)
    take = jnp.where(bud > 0, take, 0.0)
    k4 = kst + take

    # E[T] at the current and proposed allocations: one-hot row selects
    # (select-then-sum, not multiply: inf rows must ride through intact).
    k4c = jnp.clip(k4, 0.0, jnp.float32(k_hi))
    kcc = jnp.clip(kcur, 0.0, jnp.float32(k_hi))
    t4 = jnp.sum(jnp.where(kio_t == k4c, T, zero), axis=0, keepdims=True)
    tcur = jnp.sum(jnp.where(kio_t == kcc, T, zero), axis=0, keepdims=True)

    k4_ref[...] = k4
    kst_ref[...] = kst
    tcur_ref[...] = tcur
    t4_ref[...] = t4


@functools.partial(
    jax.jit, static_argnames=("k_hi", "j_cap", "interpret", "n_pad")
)
def batch_decide_pallas(
    lam,
    mu_eff,
    group,
    alpha,
    active,
    k_cur,
    k_max,
    *,
    k_hi: int,
    j_cap: int | None = None,
    interpret: bool = False,
    n_pad: int = _LANE,
):
    """``[B, N]`` rates -> ``(k4 i32, k_start i32, t_cur f32, t4 f32)``.

    Float32 throughout; operator lanes are padded to ``n_pad`` (the lane
    tiling static — multiples of 128) and padding rides through as
    inactive lanes, which every mask discards.  ``j_cap`` bounds the
    selection window (see ref.py — exact whenever ``budget <= j_cap``).
    """
    if lam.ndim != 2:
        raise ValueError(f"lam must be [B, N], got shape {lam.shape}")
    if n_pad % _LANE:
        raise ValueError(f"n_pad must be a multiple of {_LANE}, got {n_pad}")
    b, n = lam.shape
    jc = k_hi if j_cap is None else max(min(int(j_cap), k_hi), 1)
    npad, rows_t, rows_g = _pad_shapes(n, k_hi, n_pad)

    def lane(x, fill=0.0):
        x = jnp.asarray(x, dtype=jnp.float32)
        return jnp.pad(x, ((0, 0), (0, npad - n)), constant_values=fill)

    args = (
        lane(lam), lane(mu_eff), lane(group), lane(alpha), lane(active),
        lane(k_cur),
        jnp.asarray(k_max, dtype=jnp.int32).reshape(b, 1),
    )
    row_spec = pl.BlockSpec((1, npad), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_decide_fused_kernel, k_hi=k_hi, j_cap=jc),
        grid=(b,),
        in_specs=[row_spec] * 6 + [pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=[row_spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((b, npad), jnp.float32)] * 4,
        scratch_shapes=[
            pltpu.VMEM((rows_t, npad), jnp.float32),
            pltpu.VMEM((rows_g, npad), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    k4f, kstf, tcurf, t4f = out
    return (
        k4f[:, :n].astype(jnp.int32),
        kstf[:, :n].astype(jnp.int32),
        tcurf[:, :n],
        t4f[:, :n],
    )
