"""Pure-jnp oracle for the fused batch-decide pass, plus a numpy twin.

``batch_decide`` runs the decide hot path's model chain — sojourn table,
Algorithm-1 gains, minimal feasible allocation, Program-4 budget-th-
largest selection, and the ``E[T]``-at-allocation gathers — as ONE
function of the solved per-lane rates.  It is composed from the
*identical* expressions the two-pass decide in ``core/controller.py``
executes (same ``sojourn_table_jax`` call, same gain/window/tie-break
construction, same ``kernels/gain_topr`` reference selection), so with
the fused knob on the CPU decide produces bit-for-bit the decisions the
two-pass path produces — that path stays the bit-exactness oracle.

Two perf levers, both exactness-preserving:

* ``unroll`` — the Erlang-B recurrence's ``lax.scan`` unroll factor.
  Unrolling only restructures the loop; every lane still runs the same
  float ops in the same order, so the table is bitwise identical for
  any value (asserted in tests/test_kernels_all.py).
* ``j_cap`` — truncates the per-operator candidate window to the first
  ``j_cap`` gains past ``k_start``.  Per-lane gains are non-increasing
  (paper Ineq. 5 — the same convexity the threshold-equals-greedy
  argument already rests on), so positives form a prefix and no row can
  receive more than ``budget <= j_cap`` increments: the selected set,
  including row-major tie distribution, is provably unchanged (see
  tests), while the threshold search shrinks from ``[B, N, K]`` to
  ``[B, N, j_cap]``.  Callers must guarantee ``budget <= j_cap`` (the
  controller passes the static fleet-wide ``max(k_max)``).
"""

from __future__ import annotations

import numpy as np

__all__ = ["batch_decide", "batch_decide_np"]


def batch_decide(
    lam,
    mu_eff,
    *,
    group,
    alpha,
    active,
    k_cur,
    k_max,
    k_hi: int,
    j_cap: int | None = None,
    unroll: int = 1,
    interpret: bool = False,
    force_kernel: bool = False,
):
    """``[B, N]`` solved rates -> ``(k4, k_start, t_cur, t4)``.

    ``lam`` are the solved (active-masked, clamped) per-operator arrival
    rates, ``mu_eff`` the speed-scaled service rates, ``k_cur`` the
    int32 allocation in force and ``k_max [B]`` the budgets.  Returns
    the Program-4 allocation ``k4 [B, N]`` int32, the minimal feasible
    allocation ``k_start [B, N]`` int32 (``k_hi + 1`` = infeasible lane),
    and the per-operator sojourn values ``T[k_cur]`` / ``T[k4]`` —
    multiplying by ``lam`` and normalising happens in the caller with
    the same expressions both decide paths share, so ``E[T]`` parity
    reduces to these gathers being exact.
    """
    import jax.numpy as jnp

    from ...core.batched import sojourn_table_jax
    from ..gain_topr import ref as topr_ref

    lam = jnp.asarray(lam)
    b, n = lam.shape
    T = sojourn_table_jax(
        lam.reshape(-1), jnp.asarray(mu_eff).reshape(-1), k_hi=k_hi,
        group=jnp.asarray(group).reshape(-1), alpha=jnp.asarray(alpha).reshape(-1),
        min_k=jnp.ones(b * n, dtype=jnp.int32),
        interpret=interpret, force_kernel=force_kernel, unroll=unroll,
    ).reshape(b, n, k_hi + 1)
    G = lam[..., None] * (T[..., :-1] - T[..., 1:])
    G = jnp.where(jnp.isfinite(T[..., :-1]), G, jnp.inf)

    finite = jnp.isfinite(T)
    has_finite = finite.any(axis=-1)
    first = jnp.argmax(finite, axis=-1).astype(jnp.int32)
    k_start = jnp.where(active, jnp.where(has_finite, first, k_hi + 1), 0)
    floor_total = k_start.sum(axis=-1)

    budget = jnp.clip(k_max - floor_total, 0, None).astype(jnp.int32)
    jc = k_hi if j_cap is None else max(min(int(j_cap), k_hi), 1)
    j = jnp.arange(jc, dtype=jnp.int32)
    idx = k_start[..., None] + j[None, None, :]
    cand = jnp.take_along_axis(G, jnp.clip(idx, 0, k_hi - 1), axis=-1)
    cand = jnp.where(
        (idx < k_hi) & active[..., None] & jnp.isfinite(cand), cand, 0.0
    )
    take = topr_ref.gain_topr(cand, budget)
    k4 = k_start + take

    def _gather(k_vec):
        return jnp.take_along_axis(
            T, jnp.clip(k_vec, 0, k_hi).astype(jnp.int32)[..., None], axis=-1
        )[..., 0]

    return k4, k_start, _gather(k_cur), _gather(k4)


def batch_decide_np(
    lam,
    mu_eff,
    *,
    group,
    alpha,
    active,
    k_cur,
    k_max,
    k_hi: int,
    j_cap: int | None = None,
):
    """Float64 numpy twin of :func:`batch_decide` (same outputs).

    Mirrors the oracle with the forecast plane's xp-generic table and
    the numpy top-R twin — the debugging surface for the fused pass,
    exact against the jnp oracle under enable_x64.
    """
    from ...forecast.mpc import gain_topr_np, sojourn_table_arrays

    lam = np.asarray(lam, dtype=np.float64)
    mu_eff = np.asarray(mu_eff, dtype=np.float64)
    group = np.asarray(group, dtype=bool)
    alpha = np.asarray(alpha, dtype=np.float64)
    active = np.asarray(active, dtype=bool)
    k_cur = np.asarray(k_cur)
    k_max = np.asarray(k_max)
    T = sojourn_table_arrays(lam, mu_eff, group, alpha, k_hi, xp=np)
    with np.errstate(invalid="ignore"):  # inf - inf in masked (infeasible) cells
        G = lam[..., None] * (T[..., :-1] - T[..., 1:])
    G = np.where(np.isfinite(T[..., :-1]), G, np.inf)

    finite = np.isfinite(T)
    has_finite = finite.any(axis=-1)
    first = np.argmax(finite, axis=-1).astype(np.int32)
    k_start = np.where(active, np.where(has_finite, first, k_hi + 1), 0).astype(
        np.int32
    )
    floor_total = k_start.sum(axis=-1)

    budget = np.clip(k_max - floor_total, 0, None).astype(np.int64)
    jc = k_hi if j_cap is None else max(min(int(j_cap), k_hi), 1)
    j = np.arange(jc, dtype=np.int32)
    idx = k_start[..., None] + j[None, None, :]
    cand = np.take_along_axis(G, np.clip(idx, 0, k_hi - 1), axis=-1)
    cand = np.where(
        (idx < k_hi) & active[..., None] & np.isfinite(cand), cand, 0.0
    )
    take = gain_topr_np(cand, budget)
    k4 = (k_start + take).astype(np.int32)

    def _gather(k_vec):
        return np.take_along_axis(
            T, np.clip(k_vec, 0, k_hi).astype(np.int32)[..., None], axis=-1
        )[..., 0]

    return k4, k_start, _gather(k_cur), _gather(k4)
