"""Fused batch-decide: offered load -> Program-4 allocation in one pass.

The reactive jit decide (core/controller.py) historically ran the model
chain as two kernel dispatches with the full ``[B, N, K]`` Erlang/
sojourn/gain tables materialised between them: ``kernels/erlang_c``
(recurrence) -> jnp table/gain construction -> ``kernels/gain_topr``
(Program-4 top-R selection).  This package fuses the whole chain —
Erlang-B/C recurrence, the ``E[T_i](k)`` sojourn table, Algorithm-1
marginal gains, the budget-th-largest bisection, and the final
``E[T]``-at-allocation gathers — into one VMEM-resident Pallas pass
(`kernel.py`), so the gain table never leaves the core.

Layout mirrors the repo kernel idiom:

* ``kernel.py`` — the Pallas TPU kernel (float32, one grid step per
  scenario);
* ``ref.py``    — the jnp oracle, composed from the *identical* ops the
  two-pass decide runs (so knob-on CPU decisions are bit-for-bit equal
  to knob-off), plus a float64 numpy twin;
* ``ops.py``    — dispatch (kernel on TPU / ``force_kernel``, oracle
  elsewhere) and the scan-unroll autotune hook the bench persists.
"""
