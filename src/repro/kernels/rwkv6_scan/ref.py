"""Pure-jnp oracle for the RWKV6 intra-chunk compute (one chunk).

Mirrors the intra-chunk math of models/ssm.py rwkv6_chunked:
  out_i = (r_i exp(pc_{i-1})) @ state
        + sum_{j<i} [r_i . exp(pc_{i-1} - pc_j) k_j] v_j
        + [(r_i * u) . k_i] v_i
  state' = exp(tot) state + sum_j [k_j exp(tot - pc_j)] v_j^T
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["rwkv6_chunk"]


def rwkv6_chunk(
    r: jnp.ndarray,  # [C, Dk]
    k: jnp.ndarray,  # [C, Dk]
    v: jnp.ndarray,  # [C, Dv]
    lw: jnp.ndarray,  # [C, Dk] per-token log decay (<= 0)
    u: jnp.ndarray,  # [Dk]
    state: jnp.ndarray,  # [Dk, Dv]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    f32 = jnp.float32
    r, k, v, lw, u, state = (t.astype(f32) for t in (r, k, v, lw, u, state))
    c = r.shape[0]
    pc = jnp.cumsum(lw, axis=0)  # [C, Dk]
    pc_prev = jnp.concatenate([jnp.zeros_like(pc[:1]), pc[:-1]], axis=0)
    tot = pc[-1]  # [Dk]
    r_dec = r * jnp.exp(pc_prev)
    cross = r_dec @ state  # [C, Dv]
    att = r_dec @ (k * jnp.exp(-pc)).T  # [C, C]
    mask = jnp.tril(jnp.ones((c, c)), k=-1)
    att = att * mask
    diag = jnp.sum(r * u[None] * k, axis=1)  # [C]
    out = cross + att @ v + diag[:, None] * v
    k_dec = k * jnp.exp(tot[None] - pc)
    new_state = jnp.exp(tot)[:, None] * state + k_dec.T @ v
    return out, new_state
