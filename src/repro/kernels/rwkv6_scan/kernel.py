"""Pallas TPU kernel for the RWKV6 chunk scan (per batch*head program).

Grid = (B*H, n_chunks) with chunks innermost-sequential; the recurrent
state (Dk, Dv) f32 lives in VMEM scratch and persists across the chunk
steps of one (batch, head) program — the cross-chunk dependency becomes a
scratch carry instead of a lax.scan, so the whole sequence is ONE kernel
launch with chunk-local MXU matmuls:

  per chunk:  r_dec @ state        (Dk x Dv cross term)
              r_dec @ (k e^{-pc})^T  (C x C intra attention, strictly lower)
              att @ v + diag        (C x Dv)
              state <- e^{tot} state + (k e^{tot-pc})^T v

VMEM per step: r/k/v/lw chunks (C=32..64, D<=128) + state f32 (128*64*4 =
32 KiB) — tiny; the win over the jnp path on TPU is keeping the state
resident instead of round-tripping it through HBM 61x per layer stack.
Decay exponents stay bounded by the model-level clamp (w >= 0.05,
chunk <= 64 -> exp() <= e^192 is avoided by the C=32 default; see
models/ssm.py numerics note).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_scan_pallas"]


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref, s_scr, *, chunk):
    ic = pl.program_id(1)
    n_c = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (C, Dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (C, Dv)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (1, Dk) block of u

    pc = jnp.cumsum(lw, axis=0)
    pc_prev = pc - lw
    tot = pc[-1:]  # (1, Dk)
    r_dec = r * jnp.exp(pc_prev)
    state = s_scr[...]
    cross = jnp.dot(r_dec, state, preferred_element_type=jnp.float32)
    att = jnp.dot(r_dec, (k * jnp.exp(-pc)).T, preferred_element_type=jnp.float32)
    c = r.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    att = jnp.where(ii > jj, att, 0.0)
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)  # (C, 1)
    out = cross + jnp.dot(att, v, preferred_element_type=jnp.float32) + diag * v
    o_ref[0] = out.astype(o_ref.dtype)
    k_dec = k * jnp.exp(tot - pc)
    s_scr[...] = jnp.exp(tot).T * state + jnp.dot(
        k_dec.T, v, preferred_element_type=jnp.float32
    )

    @pl.when(ic == n_c - 1)
    def _finalize():
        sT_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_pallas(
    r: jnp.ndarray,  # [BH, S, Dk]
    k: jnp.ndarray,
    v: jnp.ndarray,  # [BH, S, Dv]
    lw: jnp.ndarray,  # [BH, S, Dk] log decay
    u: jnp.ndarray,  # [BH, Dk] bonus (pre-broadcast per head)
    s0: jnp.ndarray,  # [BH, Dk, Dv] initial state (f32)
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bh, s, dk = r.shape
    dv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    kern = functools.partial(_kernel, chunk=chunk)
    out, s_t = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk), lambda b, c: (b, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), r.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return out, s_t
