"""Dispatch wrapper: Pallas on TPU, models/ssm.py chunked-jnp on CPU."""
from __future__ import annotations
import jax
from . import kernel as _kernel


def rwkv6_scan(r, k, v, lw, u, s0, *, chunk=32, interpret=False, force_kernel=False):
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.rwkv6_scan_pallas(r, k, v, lw, u, s0, chunk=chunk, interpret=interpret)
    import jax.numpy as jnp
    from ...models.ssm import rwkv6_chunked
    bh, s, dk = r.shape
    rs = lambda t: t[:, None] if t.ndim == 2 else t
    # models/ssm expects [B,S,H,D]; fold BH into B with H=1
    out, st = rwkv6_chunked(
        r[:, :, None], k[:, :, None], v[:, :, None], jnp.exp(lw)[:, :, None],
        u[:1], chunk=chunk, initial_state=s0[:, None],
    )
    return out[:, :, 0], st[:, 0]
