"""Pure-jnp oracle for the Mamba2 SSD chunk scan (one batch*head stream)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ssd_chunk"]


def ssd_chunk(
    x: jnp.ndarray,  # [C, Dh]
    a: jnp.ndarray,  # [C] log decay (<= 0)
    b: jnp.ndarray,  # [C, Dst]
    c: jnp.ndarray,  # [C, Dst]
    state: jnp.ndarray,  # [Dst, Dh]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    f32 = jnp.float32
    x, a, b, c, state = (t.astype(f32) for t in (x, a, b, c, state))
    n = x.shape[0]
    pc = jnp.cumsum(a)  # [C]
    tot = pc[-1]
    c_dec = c * jnp.exp(pc)[:, None]
    cross = c_dec @ state  # [C, Dh]
    att = c_dec @ (b * jnp.exp(-pc)[:, None]).T  # [C, C]
    att = att * jnp.tril(jnp.ones((n, n)))
    y = cross + att @ x
    b_dec = b * jnp.exp(tot - pc)[:, None]
    new_state = jnp.exp(tot) * state + b_dec.T @ x
    return y, new_state
