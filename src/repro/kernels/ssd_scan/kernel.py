"""Pallas TPU kernel for the Mamba2 SSD chunk scan.

Identical carry structure to rwkv6_scan (state in VMEM scratch, chunks as
the inner sequential grid axis) but with scalar-per-step decay a_t and the
inclusive (diagonal) causal mask of SSD:

  y_i = (C_i e^{pc_i}) S_in + sum_{j<=i} e^{pc_i - pc_j} (C_i.B_j) x_j
  S'  = e^{tot} S_in + sum_j (B_j e^{tot - pc_j})^T x_j
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas"]


def _kernel(x_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sT_ref, s_scr, *, chunk):
    ic = pl.program_id(1)
    n_c = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)  # (C, Dh)
    a = a_ref[0].astype(jnp.float32)  # (C, 1) — kept 2-D for TPU iota rules
    b = b_ref[0].astype(jnp.float32)  # (C, Dst)
    c = c_ref[0].astype(jnp.float32)

    pc = jnp.cumsum(a[:, 0])[:, None]  # (C, 1)
    tot = pc[-1, 0]
    c_dec = c * jnp.exp(pc)
    state = s_scr[...]
    cross = jnp.dot(c_dec, state, preferred_element_type=jnp.float32)
    att = jnp.dot(c_dec, (b * jnp.exp(-pc)).T, preferred_element_type=jnp.float32)
    n = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    att = jnp.where(ii >= jj, att, 0.0)
    y = cross + jnp.dot(att, x, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    b_dec = b * jnp.exp(tot - pc)
    s_scr[...] = jnp.exp(tot) * state + jnp.dot(
        b_dec.T, x, preferred_element_type=jnp.float32
    )

    @pl.when(ic == n_c - 1)
    def _finalize():
        sT_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jnp.ndarray,  # [BH, S, Dh]
    a: jnp.ndarray,  # [BH, S] log decay
    b: jnp.ndarray,  # [BH, S, Dst]
    c: jnp.ndarray,  # [BH, S, Dst]
    s0: jnp.ndarray,  # [BH, Dst, Dh] f32
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bh, s, dh = x.shape
    dst = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    kern = functools.partial(_kernel, chunk=chunk)
    y, s_t = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dst), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, dst), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, dst, dh), lambda bi, ci: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dh), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, dst, dh), lambda bi, ci: (bi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), x.dtype),
            jax.ShapeDtypeStruct((bh, dst, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dst, dh), jnp.float32)],
        interpret=interpret,
    )(x, a[..., None], b, c, s0)
    return y, s_t
