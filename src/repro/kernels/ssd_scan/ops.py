"""Dispatch wrapper: Pallas on TPU, models/ssm.py chunked-jnp on CPU."""
from __future__ import annotations
import jax
from . import kernel as _kernel


def ssd_scan(x, a, b, c, s0, *, chunk=64, interpret=False, force_kernel=False):
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.ssd_scan_pallas(x, a, b, c, s0, chunk=chunk, interpret=interpret)
    from ...models.ssm import ssd_chunked
    y, st = ssd_chunked(
        x[:, :, None], a[:, :, None], b[:, :, None], c[:, :, None],
        chunk=chunk, initial_state=s0[:, None],
    )
    return y[:, :, 0], st[:, 0]
