"""Pallas TPU kernel: batched masked top-R marginal-gain selection.

One grid step per scenario.  The candidate-gain tile sits in VMEM as a
``(J, N)`` block (gain index on sublanes, operators on lanes, both padded
to the float32 tile shape) and the budget scalar in SMEM.  Instead of a
sort, the budget-th largest positive gain is found by **bisection over
float bit patterns**: positive IEEE-754 floats order like their int32
bits, so 31 fori_loop steps of one masked VPU count-reduction each pin
the threshold *exactly* (no epsilon).  Per-operator takes are then two
more masked row counts, and threshold ties are distributed in operator
order via a lower-triangular matmul prefix-sum (MXU) — the same
tie-breaking as ``allocator.greedy_increments``.

The selection is exact on the float32 values it is given; the jnp oracle
(`ref.py`) computes the identical result with a sort, which the
interpret-mode CPU test asserts elementwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gain_topr_pallas"]

_LANE = 128


@functools.lru_cache(maxsize=None)
def _pad_shapes(n: int, j: int) -> tuple[int, int]:
    """(lane-padded N, sublane-padded J) for the float32 tile.

    Hoisted out of the traced wrapper body and cached per shape, so
    retracing a new (B, N, J) never recomputes the pad arithmetic; the
    padded entries ride through as zero gains, which the positivity mask
    discards — asserted exactly in tests/test_kernels_all.py.
    """
    return n + ((-n) % _LANE), j + ((-j) % 8)


def _gain_topr_kernel(cand_ref, budget_ref, take_ref):
    x = cand_ref[0]  # (Jp, Np) float32; masked/padding entries are 0
    budget = budget_ref[0, 0]  # int32
    budget_f = budget.astype(jnp.float32)
    pos = x > 0.0
    pos_row = jnp.sum(jnp.where(pos, 1.0, 0.0), axis=0, keepdims=True)  # (1, Np)
    total_pos = jnp.sum(pos_row)
    use_all = total_pos <= budget_f

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2  # int32-overflow-safe midpoint
        t = jax.lax.bitcast_convert_type(mid, jnp.float32)
        c = jnp.sum(jnp.where(pos & (x >= t), 1.0, 0.0))
        enough = c >= budget_f  # still >= budget entries at/above mid
        return jnp.where(enough, mid, lo), jnp.where(enough, hi, mid)

    # Invariant: count(>= bitcast(lo)) >= budget > count(>= bitcast(hi)).
    # 31 halvings of the positive-float bit range leave hi == lo + 1, so
    # bitcast(lo) IS the budget-th largest positive gain.
    lo, hi = jax.lax.fori_loop(
        0, 31, body, (jnp.int32(1), jnp.int32(0x7F800000))
    )
    thresh = jax.lax.bitcast_convert_type(lo, jnp.float32)
    strict = jnp.sum(jnp.where(pos & (x > thresh), 1.0, 0.0), axis=0, keepdims=True)
    ties = jnp.sum(jnp.where(pos & (x == thresh), 1.0, 0.0), axis=0, keepdims=True)
    rem = budget_f - jnp.sum(strict)
    np_ = ties.shape[-1]
    row = jax.lax.broadcasted_iota(jnp.float32, (np_, np_), 0)
    col = jax.lax.broadcasted_iota(jnp.float32, (np_, np_), 1)
    lower = jnp.where(row < col, 1.0, 0.0)  # strictly-lower mask
    before = jnp.dot(ties, lower, preferred_element_type=jnp.float32)
    extra = jnp.clip(jnp.minimum(ties, rem - before), 0.0, None)
    take = jnp.where(use_all, pos_row, strict + extra)
    take_ref[...] = jnp.where(budget > 0, take, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gain_topr_pallas(cand, budget, *, interpret: bool = False):
    """``cand [B, N, J]`` + ``budget [B]`` -> ``take [B, N]`` int32.

    Computes in float32 (counts are exact integers far below 2^24).
    Operators and gain columns are padded to the 128-lane tile; padding
    rides through as zero gains, which the positivity mask discards.
    """
    if cand.ndim != 3:
        raise ValueError(f"cand must be [B, N, J], got shape {cand.shape}")
    b, n, j = cand.shape
    npad, jpad = _pad_shapes(n, j)
    x = jnp.pad(
        jnp.asarray(cand, dtype=jnp.float32), ((0, 0), (0, npad - n), (0, jpad - j))
    )
    x = jnp.swapaxes(x, 1, 2)  # (B, Jp, Np): gains on sublanes, ops on lanes
    bud = jnp.asarray(budget, dtype=jnp.int32).reshape(b, 1)
    take = pl.pallas_call(
        _gain_topr_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, jpad, npad), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, npad), jnp.float32),
        interpret=interpret,
    )(x, bud)
    return take[:, :n].astype(jnp.int32)
