"""Pure-jnp oracle for the batched top-R gain selection (DESIGN.md §14).

Input is the Algorithm-1 candidate-gain tensor ``cand[b, i, j]`` — the
marginal benefit of operator *i*'s *j*-th extra processor in scenario
*b*, gathered from the PR-3 gain table starting at each operator's
minimal feasible allocation (masked/invalid entries are 0).  Each
scenario hands out ``budget[b]`` processors to the largest *positive*
gains; because every row is non-increasing (convexity, paper Ineq. 5)
the result equals the scalar greedy's argmax walk, with threshold ties
resolved in operator-index order (`allocator.greedy_increments`'s rule).

Selection = one threshold: ``take[b, i] = #{j : cand[b,i,j] > theta_b}``
with ``theta_b`` the budget-th largest positive gain, plus ties at
``theta_b`` distributed row-major until the budget is exact.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gain_topr"]


def gain_topr(cand, budget):
    """``cand [B, N, J]`` gains + ``budget [B]`` -> ``take [B, N]`` int32."""
    cand = jnp.asarray(cand)
    budget = jnp.asarray(budget, dtype=jnp.int32)
    b, n, j = cand.shape
    flat = cand.reshape(b, n * j)
    pos = flat > 0
    pos_row = (cand > 0).sum(axis=-1).astype(jnp.int32)
    total_pos = pos.sum(axis=-1).astype(jnp.int32)
    use_all = total_pos <= budget
    # theta = budget-th largest positive value (descending sort; non-
    # positive entries sink to -inf so they are never the threshold).
    vals = jnp.sort(jnp.where(pos, flat, -jnp.inf), axis=-1)[:, ::-1]
    idx = jnp.clip(budget - 1, 0, n * j - 1)
    thresh = jnp.take_along_axis(vals, idx[:, None], axis=-1)[:, 0]
    strict = ((cand > thresh[:, None, None]) & (cand > 0)).sum(-1).astype(jnp.int32)
    ties = ((cand == thresh[:, None, None]) & (cand > 0)).sum(-1).astype(jnp.int32)
    rem = budget - strict.sum(axis=-1)
    before = jnp.cumsum(ties, axis=-1) - ties
    extra = jnp.clip(jnp.minimum(ties, rem[:, None] - before), 0, None)
    take = jnp.where(use_all[:, None], pos_row, strict + extra)
    return jnp.where(budget[:, None] > 0, take, 0).astype(jnp.int32)
