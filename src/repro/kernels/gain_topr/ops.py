"""Dispatch wrapper: Pallas on TPU, jnp sort-based oracle elsewhere."""

from __future__ import annotations

import jax

from . import kernel as _kernel, ref as _ref

__all__ = ["gain_topr"]


def gain_topr(cand, budget, *, interpret: bool = False, force_kernel: bool = False):
    """[B, N, J] candidate gains + [B] budgets -> [B, N] int32 takes.

    Pallas kernel on TPU (or with ``force_kernel=True, interpret=True`` on
    CPU — repo kernel idiom, see kernels/__init__.py); jnp oracle
    elsewhere.  The kernel selects in float32; the oracle follows the
    input dtype (float64 under enable_x64).

    The §18 compacted MPC pricing calls this at ``bucket_ladder`` rungs:
    each candidate row is scored against its own row's budget only, so a
    gathered (or fill-duplicated) lane selects exactly what it would at
    the dense extent and drop-mode scatter discards the duplicates.
    """
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.gain_topr_pallas(cand, budget, interpret=interpret)
    return _ref.gain_topr(cand, budget)
