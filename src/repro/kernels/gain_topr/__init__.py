"""Batched masked top-R marginal-gain selection (controller hot loop)."""

from . import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
