"""Pure-jnp oracle for the l2_match kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pairwise_sq_l2", "match_count"]


def pairwise_sq_l2(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distances between rows of a [M,D] and b [N,D] -> [M,N].

    Uses the expansion ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y (the same
    identity the kernel exploits to ride the MXU), clamped at zero against
    cancellation.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)  # [M,1]
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T  # [1,N]
    cross = a @ b.T  # [M,N]
    return jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)


def match_count(
    a: jnp.ndarray, b: jnp.ndarray, threshold: float, valid: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Per-library-row count of query rows within `threshold` L2 distance.

    a: queries [M,D]; b: library [N,D]; valid: optional [M] bool mask.
    Returns int32 [N].
    """
    d2 = pairwise_sq_l2(a, b)
    hits = d2 <= threshold * threshold
    if valid is not None:
        hits = hits & valid[:, None]
    return hits.sum(axis=0).astype(jnp.int32)
