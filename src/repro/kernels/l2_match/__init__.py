"""l2_match — blocked pairwise L2 distance + fused match counting.

The compute hot spot of the paper's VLD feature-matcher bolt, adapted to
the MXU (see kernel.py for the tiling argument).
"""

from . import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
