"""Pallas TPU kernel: blocked pairwise squared-L2 distance (+ fused count).

The paper's VLD matcher bolt computes L2 distances between every frame
descriptor and a pre-generated logo library — its dominant compute (the
recommended allocation 10:11:1 puts half the cluster on this bolt).  On
TPU the distance matrix should ride the MXU via

    ||a - b||^2 = ||a||^2 + ||b||^2 - 2 a.b^T,

so the kernel is a blocked matmul with two fused rank-1 corrections:

* grid (M/bm, N/bn); each step loads an A tile (bm, D) and B tile (bn, D)
  into VMEM, computes the cross term with ``jnp.dot`` (MXU,
  preferred_element_type=f32), adds the row/col norms (VPU), clamps at 0.
* ``l2_match_count_kernel`` additionally fuses the threshold + column
  reduction, accumulating per-library-row match counts across the M grid
  axis — TPU grid steps run sequentially, so the accumulation is safe
  (init at i == 0); this keeps the (M, N) distance matrix entirely out of
  HBM, turning an O(M*N) memory intermediate into O(N).

Block sizes default to MXU-aligned (128, 128); D is kept whole in VMEM
(descriptor dims are small: 64-128 for SIFT-like features).  VMEM budget
per step = bm*D + bn*D + bm*bn floats ~ (128*128)*3 * 4B = 192 KiB << 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_sq_l2_pallas", "match_count_pallas"]


def _dist_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...].astype(jnp.float32)  # (bm, D)
    b = b_ref[...].astype(jnp.float32)  # (bn, D)
    cross = jnp.dot(a, b.T, preferred_element_type=jnp.float32)  # MXU
    a2 = jnp.sum(a * a, axis=1, keepdims=True)  # (bm, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T  # (1, bn)
    out_ref[...] = jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pairwise_sq_l2_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """[M,D] x [N,D] -> [M,N] squared L2 distances. M % bm == N % bn == 0."""
    m, d = a.shape
    n, d2 = b.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


def _count_kernel(a_ref, b_ref, valid_ref, thresh_ref, out_ref):
    i = pl.program_id(0)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    cross = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T
    d2 = jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)  # (bm, bn)
    t2 = thresh_ref[0]
    hits = (d2 <= t2) & (valid_ref[...][:, None] > 0)
    partial = hits.sum(axis=0).astype(jnp.int32)[None, :]  # (1, bn)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def match_count_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    valid: jnp.ndarray,
    threshold: float | jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused distance+threshold+count: int32 [N] without materialising [M,N].

    Accumulates across the (sequential) M grid axis; the N axis is the
    minor grid axis so each out block is visited m//bm times in a row.
    """
    m, d = a.shape
    n, _ = b.shape
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    t2 = jnp.asarray([jnp.float32(threshold) ** 2])
    grid = (m // bm, n // bn)
    out = pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(a, b, valid.astype(jnp.int32), t2)
    return out[0]
