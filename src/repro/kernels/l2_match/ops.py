"""Jit'd dispatch wrapper for the l2_match kernel.

On TPU the Pallas kernel runs compiled; on CPU (this container) the
default path is the jnp reference (fast) while the kernel itself is
validated in interpret mode by tests/test_kernels_l2_match.py.  Shapes are
padded to block multiples here so callers never care about alignment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref

__all__ = ["pairwise_sq_l2", "match_count"]

# "auto": kernel on TPU, reference on CPU. Tests force "kernel_interpret".
_MODE = "auto"


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in ("auto", "ref", "kernel", "kernel_interpret"), mode
    _MODE = mode


def _use_kernel() -> tuple[bool, bool]:
    """(use_kernel, interpret)"""
    if _MODE == "ref":
        return False, False
    if _MODE == "kernel":
        return True, False
    if _MODE == "kernel_interpret":
        return True, True
    return (jax.default_backend() == "tpu"), False


def _pad_rows(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    m = x.shape[0]
    pad = (-m) % block
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def pairwise_sq_l2(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128, bn: int = 128) -> jnp.ndarray:
    use, interp = _use_kernel()
    if not use:
        return _ref.pairwise_sq_l2(a, b)
    a_p, m = _pad_rows(a, bm)
    b_p, n = _pad_rows(b, bn)
    out = _kernel.pairwise_sq_l2_pallas(a_p, b_p, bm=bm, bn=bn, interpret=interp)
    return out[:m, :n]


def match_count(
    a: jnp.ndarray,
    b: jnp.ndarray,
    threshold: float,
    valid: jnp.ndarray | None = None,
    *,
    bm: int = 128,
    bn: int = 128,
) -> jnp.ndarray:
    use, interp = _use_kernel()
    if valid is None:
        valid = jnp.ones(a.shape[0], dtype=bool)
    if not use:
        return _ref.match_count(a, b, threshold, valid)
    a_p, _ = _pad_rows(a, bm)
    b_p, n = _pad_rows(b, bn)
    valid_p = jnp.pad(valid, (0, a_p.shape[0] - a.shape[0]))
    out = _kernel.match_count_pallas(a_p, b_p, valid_p, threshold, bm=bm, bn=bn, interpret=interp)
    return out[:n]
