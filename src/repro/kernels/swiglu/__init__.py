"""swiglu — Pallas TPU kernel + jnp oracle (see kernel.py docstring)."""
from . import kernel, ref
