"""Dispatch wrapper: Pallas on TPU, jnp reference on CPU."""
from __future__ import annotations
import jax
from . import kernel as _kernel, ref as _ref


def swiglu(x, wg, wu, wo, *, interpret=False, force_kernel=False):
    if force_kernel or jax.default_backend() == "tpu":
        return _kernel.swiglu_pallas(x, wg, wu, wo, interpret=interpret)
    return _ref.swiglu(x, wg, wu, wo)
