"""Pure-jnp oracle for the fused SwiGLU FFN."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["swiglu"]


def swiglu(
    x: jnp.ndarray,  # [T, D]
    wg: jnp.ndarray,  # [D, F]
    wu: jnp.ndarray,  # [D, F]
    wo: jnp.ndarray,  # [F, D]
) -> jnp.ndarray:
    h = jax.nn.silu((x @ wg).astype(jnp.float32)) * (x @ wu).astype(jnp.float32)
    return (h.astype(x.dtype) @ wo).astype(x.dtype)
