"""Pallas TPU fused SwiGLU: out = (silu(x Wg) * (x Wu)) Wo without ever
materialising the (T, F) hidden in HBM.

Grid = (T/bt, F/bf) with F innermost: each step computes the (bt, bf)
hidden slab in VMEM (two MXU matmuls + VPU silu/mul) and immediately
contracts it with the Wo slab into a (bt, D) accumulator that is revisited
across F steps.  HBM traffic drops from  2*T*F (hidden write+read)  to
zero extra — the classic d_ff-blocked FFN fusion.  VMEM per step at
(bt, bf, D) = (256, 256, 4096) bf16:  x 2 MiB + wg/wu slabs 4 MiB +
wo slab 2 MiB + acc f32 4 MiB = 12 MiB — at the v5e budget; shrink bt for
larger D.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["swiglu_pallas"]


def _kernel(x_ref, wg_ref, wu_ref, wo_ref, o_ref, acc_scr):
    jf = pl.program_id(1)
    n_f = pl.num_programs(1)

    @pl.when(jf == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]  # (bt, D)
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)  # (bt, bf)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_scr[...] += jnp.dot(h, wo_ref[...], preferred_element_type=jnp.float32)

    @pl.when(jf == n_f - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "bf", "interpret"))
def swiglu_pallas(
    x: jnp.ndarray,  # [T, D]
    wg: jnp.ndarray,  # [D, F]
    wu: jnp.ndarray,
    wo: jnp.ndarray,  # [F, D]
    *,
    bt: int = 256,
    bf: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    t, d = x.shape
    f = wg.shape[1]
    assert t % bt == 0 and f % bf == 0, (t, f, bt, bf)
    grid = (t // bt, f // bf)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda it, jf: (it, 0)),
            pl.BlockSpec((d, bf), lambda it, jf: (0, jf)),
            pl.BlockSpec((d, bf), lambda it, jf: (0, jf)),
            pl.BlockSpec((bf, d), lambda it, jf: (jf, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda it, jf: (it, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wo)
