"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel subpackage has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd wrapper with padding/dispatch (ref on CPU, kernel on TPU)
  ref.py    — pure-jnp oracle used by tests (interpret=True validation)
"""
