"""Distribution substrate: rule-based sharding, gradient compression."""

from . import compress, sharding

__all__ = ["compress", "sharding"]
