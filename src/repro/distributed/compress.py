"""int8 gradient compression with per-tensor scales (error-feedback-free
stochastic variant kept simple: symmetric absmax quantisation).

At 1000+ nodes the cross-pod all-reduce bandwidth dominates step time for
large dense models; quantising the gradient payload to int8 cuts the
cross-pod bytes 2x vs bf16 (4x vs f32).  The quantisation is applied to
the *gradient tree* before the (GSPMD-inserted) all-reduce consumes it —
XLA then moves int8, not bf16.  Accuracy: absmax int8 keeps SNR ~ 48 dB
per tensor which empirically does not move loss curves for LLM pretraining
at these scales; the error-feedback accumulator variant is provided for
the paranoid (compress_tree(..., error_state)).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressedTensor", "compress", "decompress", "compress_tree", "decompress_tree"]


class CompressedTensor(NamedTuple):
    q: jnp.ndarray  # int8 payload
    scale: jnp.ndarray  # [] f32 absmax / 127


def compress(x: jnp.ndarray) -> CompressedTensor:
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return CompressedTensor(q, scale)


def decompress(c: CompressedTensor, dtype=jnp.float32) -> jnp.ndarray:
    return (c.q.astype(jnp.float32) * c.scale).astype(dtype)


def compress_tree(tree: Any) -> Any:
    return jax.tree.map(compress, tree)


def decompress_tree(tree: Any) -> Any:
    return jax.tree.map(
        lambda c: decompress(c),
        tree,
        is_leaf=lambda t: isinstance(t, CompressedTensor),
    )


class ErrorFeedbackState(NamedTuple):
    residual: Any  # tree of f32 residuals


def compress_with_feedback(
    tree: Any, ef: ErrorFeedbackState | None
) -> tuple[Any, ErrorFeedbackState]:
    """Quantise (g + residual); keep the quantisation error as the next
    residual — guarantees the accumulated error stays bounded."""
    if ef is None:
        ef = ErrorFeedbackState(jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), tree))
    carried = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, tree, ef.residual)
    comp = compress_tree(carried)
    deq = decompress_tree(comp)
    new_res = jax.tree.map(lambda c, d: c - d, carried, deq)
    return comp, ErrorFeedbackState(new_res)
