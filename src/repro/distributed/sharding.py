"""Rule-based sharding: logical axes -> mesh axes per (family, mode).

The production mesh is (16, 16) = ("data", "model") per pod, with a
leading "pod" axis multi-pod (launch/mesh.py).  Parameters and activations
carry logical axis names (models/common.py); the tables here map them to
mesh axes.  `safe_spec` drops any assignment whose dimension is not
divisible by the mesh-axis extent — this is what lets one rule table serve
every architecture (e.g. whisper's vocab 51865 is indivisible by 16 and
silently falls back to replicated, while command-r's 256000 shards 16-way).

Defaults (see DESIGN.md §6):

* train: batch over (pod, data); TP over heads/d_ff/vocab; FSDP shards
  every param's d_model/d_ff-complement over data (ZeRO-3; the all-gather
  happens per scan step and overlaps with compute under XLA's latency
  hiding); experts over data where divisible (kimi-k2: 384/16).
* prefill: like train minus FSDP (weights stay TP + replicated over data)
  for latency; batch over (pod, data).
* decode: KV cache kv_seq over model (flash-decoding partial softmax);
  experts over data; params TP over model and — for the 1T-param MoE —
  expert-sharded over data as well.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "TRAIN_RULES",
    "PREFILL_RULES",
    "DECODE_RULES",
    "rules_for",
    "safe_spec",
    "tree_shardings",
    "batch_spec",
    "fleet_mesh",
    "bucket_ladder",
]


def fleet_mesh(n_devices: int | None = None, *, axis: str = "fleet") -> Mesh:
    """1-D mesh over the scenario/tenant batch axis (DESIGN.md §16).

    The control plane is data-parallel over B, so its mesh is a single
    axis — unlike the (pod, data, model) model meshes above.  Defaults to
    every visible device; pass ``n_devices=1`` for the pinned-to-one-
    device baseline the bench compares against.  On CPU hosts set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax
    imports to emulate a multi-device mesh (the CI lane does this).
    """
    devices = jax.devices()
    if n_devices is not None:
        if n_devices > len(devices):
            raise ValueError(
                f"requested {n_devices} devices, only {len(devices)} visible"
            )
        devices = devices[:n_devices]
    return Mesh(np.asarray(devices), (axis,))


def bucket_ladder(b: int, *, fractions: tuple[int, ...] = (16, 4, 1)) -> tuple[int, ...]:
    """Static compacted-width ladder for the trigger-gated sparse decide
    (DESIGN.md §18).

    The fused control plane gathers the active (triggered) lanes into the
    smallest ladder width that holds them and runs the decide at that
    width — a MoE-style capacity ladder, so every tick dispatches to one
    of a handful of pre-compiled shapes instead of recompiling per active
    count.  Default rungs: ceil(b/16), ceil(b/4), and b (the dense
    fallback, always present so a fully-triggered tick degrades to the
    plain dense decide, never an overflow).

    Under a device mesh the ladder is built **per shard** (``b`` = the
    shard's lane extent): each device compacts its own lanes inside the
    ``shard_map`` body, so no cross-device gather/scatter collective is
    needed.  The tradeoff is load imbalance — lane activity is not
    redistributed, so a shard whose lanes are all hot runs its ``b/1``
    rung while a quiet shard runs ``b/16`` and waits at the next
    collective.  That is deliberate: re-balancing would cost an
    all-to-all per tick, and the worst case (every shard hot) is exactly
    the dense cost we had before compaction.  Interleave hot scenario
    families across the batch axis when packing the fleet if imbalance
    shows up in profiles.
    """
    if b < 1:
        raise ValueError(f"batch extent must be >= 1, got {b}")
    widths = {max(1, -(-b // f)) for f in fractions}
    widths.add(b)
    return tuple(sorted(w for w in widths if w <= b))

TRAIN_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq_sp": "model",  # sequence-parallel residual stream between blocks
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "data",  # EP when divisible (kimi 384/16); else FSDP fallback
    "d_model": "data",  # FSDP axis for params (activations: batch wins "data")
    "layers": None,
    "kv_seq": None,
    "enc_seq": None,
}

PREFILL_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq_sp": None,
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "data",
    "d_model": None,  # no FSDP at serve time: weights replicated over data
    "layers": None,
    "kv_seq": None,
    "enc_seq": None,
}

DECODE_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq_sp": None,
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "data",
    "d_model": None,
    "layers": None,
    "kv_seq": "model",  # sequence-sharded KV cache (flash-decoding)
    "enc_seq": None,
}


# Per-arch corrections (merged between base table and call-site overrides).
# mixtral-8x22b: 8 experts do not divide the 16-way data axis, so expert
# weights can't shard over "experts" — FSDP them over d_model at serve time
# or 140B params x bf16 / 16 (TP only) = 17.5 GB/chip would not fit.
ARCH_RULE_OVERRIDES: dict[tuple[str, str], dict[str, Any]] = {
    ("mixtral-8x22b", "prefill"): {"d_model": "data"},
    ("mixtral-8x22b", "decode"): {"d_model": "data"},
}


def rules_for(
    mode: str,
    overrides: dict[str, Any] | None = None,
    *,
    arch: str | None = None,
) -> dict[str, Any]:
    base = {"train": TRAIN_RULES, "prefill": PREFILL_RULES, "decode": DECODE_RULES}[mode]
    out = dict(base)
    if arch is not None:
        out.update(ARCH_RULE_OVERRIDES.get((arch, mode), {}))
    if overrides:
        out.update(overrides)
    return out


def prune_rules(rules: dict[str, Any], mesh: Mesh) -> dict[str, Any]:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' single-pod)."""
    names = set(mesh.axis_names)
    out: dict[str, Any] = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
            continue
        parts = (v,) if isinstance(v, str) else tuple(v)
        kept = tuple(p for p in parts if p in names)
        out[k] = None if not kept else (kept[0] if len(kept) == 1 else kept)
    return out


def _axis_size(mesh: Mesh, assignment) -> int:
    if assignment is None:
        return 1
    parts = (assignment,) if isinstance(assignment, str) else tuple(assignment)
    n = 1
    for p in parts:
        n *= mesh.shape[p]
    return n


def safe_spec(
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    rules: dict[str, Any],
    mesh: Mesh,
) -> P:
    """PartitionSpec with divisibility + axis-reuse guards."""
    used: set[str] = set()
    spec: list[Any] = []
    for dim, ax in zip(shape, axes):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            spec.append(None)
            continue
        parts = [p for p in ((m,) if isinstance(m, str) else tuple(m)) if p not in used]
        # keep only the prefix of parts whose product divides the dim
        chosen: list[str] = []
        n = 1
        for p in parts:
            if dim % (n * mesh.shape[p]) == 0:
                chosen.append(p)
                n *= mesh.shape[p]
        if not chosen:
            spec.append(None)
            continue
        used.update(chosen)
        spec.append(chosen[0] if len(chosen) == 1 else tuple(chosen))
    return P(*spec)


def tree_shardings(
    shapes_tree: Any,
    axes_tree: Any,
    mesh: Mesh,
    rules: dict[str, Any],
) -> Any:
    """NamedSharding tree for a params-like tree.

    shapes_tree: tree of arrays or ShapeDtypeStructs; axes_tree: matching
    tree of logical-axis tuples.
    """

    def one(x, axes):
        return NamedSharding(mesh, safe_spec(tuple(x.shape), tuple(axes), rules, mesh))

    return jax.tree.map(
        one, shapes_tree, axes_tree, is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t
        ),
    )


def batch_spec(
    name: str, shape: tuple[int, ...], rules: dict[str, Any], mesh: Mesh
) -> P:
    """PartitionSpec for a named model input."""
    axes_by_name: dict[str, tuple[str | None, ...]] = {
        "tokens": ("batch", None),
        "labels": ("batch", None),
        "mask": ("batch", None),
        "patch_embeds": ("batch", None, None),
        "positions_3d": (None, "batch", None),
        "frames": ("batch", "enc_seq", None),
    }
    if name == "tokens" and len(shape) == 1:  # decode: [B]
        return safe_spec(shape, ("batch",), rules, mesh)
    axes = axes_by_name.get(name)
    if axes is None or len(axes) != len(shape):
        return P()
    return safe_spec(shape, axes, rules, mesh)


# Cache logical axes (serve.init_cache layouts) ------------------------- #
def cache_axes(family: str) -> dict[str, tuple[str | None, ...]]:
    if family in ("dense", "moe", "vlm"):
        return {
            "k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "length": (),
        }
    if family == "ssm":
        return {
            "wkv": ("layers", "batch", "heads", None, None),
            "tm_shift": ("layers", "batch", "d_model"),
            "cm_shift": ("layers", "batch", "d_model"),
            "length": (),
        }
    if family == "hybrid":
        return {
            "ssm": ("layers", "batch", "heads", None, None),
            "k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "length": (),
        }
    if family == "audio":
        return {
            "k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "xk": ("layers", "batch", "enc_seq", "kv_heads", None),
            "xv": ("layers", "batch", "enc_seq", "kv_heads", None),
            "length": (),
        }
    raise ValueError(family)


def cache_shardings(cache_shapes: dict, family: str, mesh: Mesh, rules: dict) -> dict:
    ax = cache_axes(family)
    return {
        k: NamedSharding(mesh, safe_spec(tuple(v.shape), ax[k], rules, mesh))
        for k, v in cache_shapes.items()
    }
