"""MPC lookahead planner: price candidate allocations against the
forecast horizon, commit the cheapest plan that keeps E[T] under T_max
(DESIGN.md §15).

Where the reactive controller (core/controller.py) sizes Programs
(4)/(6) at the *measured* rates — and therefore always lags a ramp by
one control interval and ignores accumulated backlog — the planner here:

1. takes the predictor's per-operator offered-rate forecast
   ``lam_pred [B, H, N]`` (forecast/predictors.py);
2. sizes Program (6) at the **predicted peak** with one analytic pass:
   per-lane Algorithm-1 gains are non-increasing (paper Ineq. 5), so the
   greedy's E[T]-vs-increment curve is the floor E[T] minus the running
   sum of the globally sorted gains — the whole sizing is a sort + a
   cumsum, no sequential greedy, hence jit-able;
3. builds a small candidate set: hold the current allocation, the
   Program-6-at-peak sizing, and its +/- ``neighbor`` hysteresis
   neighbors (allocated via the same masked top-R gain selection the
   reactive jit decide uses — ``kernels/gain_topr``);
4. prices every candidate at every horizon step two ways and takes the
   worse: the analytic M/M/k visit-sum E[T] at the predicted rates
   (steady state), and a bounded-queue fluid rollout of the fused
   window recurrence started from the **actual backlog** ``q0`` (the
   drain-time term the steady-state model cannot see — this is what
   lets the planner keep scaling after a flash crowd until the queue is
   actually gone);
5. picks the cheapest candidate whose predicted E[T] stays under T_max
   across the whole horizon (ties prefer holding, and a cheaper plan
   must undercut ``scale_in_hysteresis * current`` to displace it).

``any_ok = False`` (no candidate survives) and a closed confidence gate
(:func:`~repro.forecast.predictors.confidence`) both mean "fall back to
the reactive ``decide_single`` path" — the caller owns that merge
(core/controller.py ``tick_batch`` / ``make_fused_loop``).

Twin/jit discipline: every function takes ``xp`` and runs the identical
float-op sequence under numpy float64 and jax (the Erlang recursion
mirrors ``core.batched.sojourn_table_jax`` term for term), so the numpy
twin and the compiled path agree to <= 1e-9 under x64 and the whole
predict -> simulate -> price -> commit step stays inside the one
``lax.scan`` program.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

from .predictors import (
    PredictorParams,
    confidence,
    error_init,
    error_update,
    forecast_rates,
    history_init,
    history_push,
)

__all__ = [
    "MPCConfig",
    "ProactiveController",
    "forecast_init_state",
    "forecast_step",
    "mpc_plan",
    "mpc_plan_compact",
    "gain_topr_np",
    "sojourn_table_arrays",
]

_TINY = 1e-300


def _quiet(fn):
    """The masked-inf arithmetic below is deliberate (infeasible lanes
    price to inf and are where()-ed out) — silence numpy's warnings the
    same way the batchsim twins do (no-op under the traced jax path)."""

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            return fn(*args, **kwargs)

    return wrapped


@dataclass(frozen=True)
class MPCConfig:
    """Knobs of the proactive mode (static: baked into the jit program).

    ``horizon`` is the lookahead in control ticks; ``window`` the rate
    history the predictors smooth over; ``neighbor`` the +/- budget step
    of the candidate set; the three gate knobs close the confidence gate
    (fallback to reactive) when the tracked one-step forecast error is
    too high or too young.  ``headroom`` mirrors the reactive
    Program-(6) provisioning guard.
    """

    horizon: int = 3
    window: int = 12
    predictor: PredictorParams = field(default_factory=PredictorParams)
    neighbor: int = 2
    headroom: float = 1.1
    min_scored: int = 3
    mase_gate: float = 2.0
    smape_gate: float = 0.25
    scale_in_hysteresis: float = 0.8

    def __post_init__(self):
        if self.horizon < 1:
            raise ValueError(f"need horizon >= 1 ticks, got {self.horizon}")
        if self.window < 2:
            raise ValueError(f"need window >= 2 ticks, got {self.window}")
        if self.predictor.kind == "seasonal" and self.window < self.predictor.season:
            raise ValueError(
                f"window {self.window} must cover one season "
                f"({self.predictor.season} ticks) for the seasonal predictor"
            )
        if self.neighbor < 1:
            raise ValueError(f"need neighbor >= 1, got {self.neighbor}")
        if not 0.0 <= self.scale_in_hysteresis <= 1.0:
            raise ValueError(
                f"need 0 <= scale_in_hysteresis <= 1, got {self.scale_in_hysteresis}"
            )


# --------------------------------------------------------------------------- #
# Forecast state plumbing (history window + error tracker as one tuple)
# --------------------------------------------------------------------------- #
def forecast_init_state(b: int, n: int, cfg: MPCConfig, xp=np, dtype=np.float64):
    """``(hist [B,W,N], prev_pred, prev_y, abs_err, naive_err, smape_sum,
    n_obs)`` — a flat tuple of arrays (lax.scan-carry compatible)."""
    return (history_init(b, cfg.window, n, xp=xp, dtype=dtype),) + error_init(
        b, n, xp=xp, dtype=dtype
    )


def forecast_step(state, lam_hat, active, cfg: MPCConfig, xp=np):
    """One tick of the predictor plane: score, push, forecast, gate.

    ``lam_hat [B, N]`` is the window's measured per-operator offered
    rate (non-finite / inactive lanes are treated as 0).  Returns
    ``(state', lam_pred [B, H, N], confident [B])``.
    """
    hist, err = state[0], state[1:]
    y = xp.where(active & xp.isfinite(lam_hat), lam_hat, 0.0)
    hist = history_push(hist, y, err[5], xp=xp)
    lam_pred = forecast_rates(hist, cfg.horizon, cfg.predictor, xp=xp)
    err = error_update(err, lam_pred[:, 0, :], y, xp=xp)
    conf = confidence(
        err,
        active,
        min_scored=cfg.min_scored,
        mase_gate=cfg.mase_gate,
        smape_gate=cfg.smape_gate,
        xp=xp,
    )
    return (hist,) + err, lam_pred, conf


# --------------------------------------------------------------------------- #
# Batched analytic tables (xp-agnostic mirror of core.batched.sojourn_table_jax)
# --------------------------------------------------------------------------- #
@_quiet
def sojourn_table_arrays(lam, mu, group, alpha, k_hi: int, xp=np):
    """``[..., N] -> [..., N, K+1]`` E[T_i](k) table, min_k = 1.

    Term-for-term mirror of :func:`repro.core.batched.sojourn_table_jax`
    (Erlang-B recursion ``b = a b / (j + a b)``, Erlang-C conversion,
    group M/M/1 closed form), written against ``xp`` so the numpy twin
    and the traced jax path produce bit-identical float64 values.  The
    recursion unrolls over the static ``k_hi``.
    """
    dtype = lam.dtype
    a_rep = lam / mu
    b = xp.ones_like(a_rep)
    rows = [b]
    for j in range(1, k_hi + 1):
        b = a_rep * b / (j + a_rep * b)
        rows.append(b)
    btab = xp.stack(rows, axis=-1)  # [..., N, K+1]
    ks = xp.arange(k_hi + 1, dtype=dtype)
    kk = ks[(None,) * (lam.ndim)]  # broadcast over every leading dim
    c = kk * btab / (kk - a_rep[..., None] * (1.0 - btab))
    t_rep = c / (kk * mu[..., None] - lam[..., None]) + 1.0 / mu[..., None]
    t_rep = xp.where(kk > a_rep[..., None], t_rep, xp.inf)
    eff = 1.0 / (1.0 + alpha[..., None] * (kk - 1.0))
    mu_eff = mu[..., None] * kk * eff
    a_grp = lam[..., None] / mu_eff
    bg = a_grp / (1.0 + a_grp)
    cg = bg / (1.0 - a_grp * (1.0 - bg))
    t_grp = cg / (mu_eff - lam[..., None]) + 1.0 / mu_eff
    t_grp = xp.where(a_grp < 1.0, t_grp, xp.inf)
    T = xp.where(group[..., None], t_grp, t_rep)
    return xp.where(kk >= 1.0, T, xp.inf)


def gain_topr_np(cand, budget):
    """Numpy float64 twin of ``kernels/gain_topr`` (threshold + row-major
    tie split — identical take-for-take to the jnp oracle)."""
    cand = np.asarray(cand, dtype=np.float64)
    budget = np.asarray(budget, dtype=np.int64)
    b, n, j = cand.shape
    flat = cand.reshape(b, n * j)
    pos = flat > 0
    pos_row = (cand > 0).sum(axis=-1)
    total_pos = pos.sum(axis=-1)
    use_all = total_pos <= budget
    vals = np.sort(np.where(pos, flat, -np.inf), axis=-1)[:, ::-1]
    idx = np.clip(budget - 1, 0, n * j - 1)
    thresh = np.take_along_axis(vals, idx[:, None], axis=-1)[:, 0]
    strict = ((cand > thresh[:, None, None]) & (cand > 0)).sum(-1)
    ties = ((cand == thresh[:, None, None]) & (cand > 0)).sum(-1)
    rem = budget - strict.sum(axis=-1)
    before = np.cumsum(ties, axis=-1) - ties
    extra = np.clip(np.minimum(ties, rem[:, None] - before), 0, None)
    take = np.where(use_all[:, None], pos_row, strict + extra)
    return np.where(budget[:, None] > 0, take, 0).astype(np.int64)


def _capacity(k, mu_eff, group, alpha, xp):
    """Effective service capacity at allocation ``k`` (group rolloff
    curve; k floored at 0 — the fused simulator's rule)."""
    kf = xp.maximum(k, 0) * xp.ones_like(mu_eff)
    eff = 1.0 / (1.0 + alpha * (kf - 1.0))
    return xp.where(group, mu_eff * kf * eff, mu_eff * kf)


def _price(T, k_vec, lam, lam0, k_hi: int, xp):
    """Visit-sum E[T] of allocation ``k_vec [..., N]`` under table ``T
    [..., N, K+1]`` at rates ``lam [..., N]`` / external ``lam0 [...]``."""
    idx = xp.clip(k_vec, 0, k_hi)[..., None]
    per_op = xp.take_along_axis(T, idx, axis=-1)[..., 0]
    contrib = xp.where(lam > 0, lam * per_op, 0.0)
    return contrib.sum(axis=-1) / xp.maximum(lam0, _TINY)


# --------------------------------------------------------------------------- #
# The planner
# --------------------------------------------------------------------------- #
@_quiet
def mpc_plan(
    lam_pred,
    q0,
    k_cur,
    *,
    mu,
    group,
    alpha,
    speed,
    active,
    src_mask,
    cap_queue,
    t_max,
    k_max,
    span: float,
    cfg: MPCConfig,
    k_hi: int,
    xp=np,
    topr=None,
    alloc=None,
):
    """One MPC planning pass over the forecast horizon.

    Inputs (all arrays; int/bool as noted): ``lam_pred [B, H, N]``
    predicted per-operator *offered* rates, ``q0 [B, N]`` current
    backlog, ``k_cur [B, N]`` current allocation, ``mu/group/alpha/
    speed/active/src_mask/cap_queue [B, N]`` model statics, ``t_max
    [B]`` (inf = no constraint), ``k_max [B]`` budgets, ``span`` seconds
    per control tick.  ``topr(cand [M,N,J], budget [M]) -> take [M,N]``
    is the top-R gain selection (defaults to the numpy twin; the jit
    path passes ``kernels/gain_topr``).  ``alloc(lam_m [M, N],
    budgets_m [M]) -> k_alloc [M, N]``, when given, replaces the whole
    floor + top-R block with one fused allocator call per candidate
    budget (``kernels/decide_fused`` — budgets are absolute totals, so
    the hook recomputes the same floor internally and spends
    ``budget - floor``; bit-identical to the ``topr`` route).

    Returns ``(k_plan [B, N] int, any_ok [B] bool, et_hold [B],
    et_plan [B], need [B] int)``: the committed allocation, whether any
    candidate met the constraint (False => reactive fallback), the
    predicted next-tick E[T] of holding vs the plan, and the raw
    Program-(6)-at-peak demand (headroom applied; feeds negotiator
    leases in the twin).
    """
    if topr is None:
        topr = gain_topr_np
    dtype = lam_pred.dtype
    b, h, n = lam_pred.shape
    lam_pred = xp.where(active[:, None, :], lam_pred, 0.0)
    mu_eff = mu * speed
    lam_peak = lam_pred.max(axis=1)  # [B, N]

    # ONE table pass for the peak + every horizon step: [B, H+1, N, K+1].
    lam_all = xp.concatenate([lam_peak[:, None, :], lam_pred], axis=1)
    shape = lam_all.shape
    T_all = sojourn_table_arrays(
        lam_all,
        xp.broadcast_to(mu_eff[:, None, :], shape) + xp.zeros(shape, dtype=dtype),
        xp.broadcast_to(group[:, None, :], shape),
        xp.broadcast_to(alpha[:, None, :], shape) + xp.zeros(shape, dtype=dtype),
        k_hi,
        xp=xp,
    )
    T_peak = T_all[:, 0]  # [B, N, K+1]
    T_h = T_all[:, 1:]  # [B, H, N, K+1]
    lam0_h = xp.maximum(
        xp.where(src_mask[:, None, :], lam_pred, 0.0).sum(axis=-1), _TINY
    )  # [B, H]
    lam0_peak = xp.maximum(xp.where(src_mask, lam_peak, 0.0).sum(axis=-1), _TINY)

    # Minimal feasible allocation at the predicted peak (first finite col).
    finite = xp.isfinite(T_peak)
    has_finite = finite.any(axis=-1)
    first = xp.argmax(finite, axis=-1).astype(xp.int32)
    k_start = xp.where(active, xp.where(has_finite, first, k_hi + 1), 0).astype(
        xp.int32
    )
    floor_total = k_start.sum(axis=-1)

    # Algorithm-1 candidate gains from k_start (the reactive jit decide's
    # construction, at the predicted peak instead of the measured rates).
    G = lam_peak[..., None] * (T_peak[..., :-1] - T_peak[..., 1:])
    G = xp.where(xp.isfinite(T_peak[..., :-1]), G, xp.inf)
    j = xp.arange(k_hi, dtype=xp.int32)
    idx = k_start[..., None] + j[None, None, :]
    cand = xp.take_along_axis(G, xp.clip(idx, 0, k_hi - 1), axis=-1)
    cand = xp.where(
        (idx < k_hi) & active[..., None] & xp.isfinite(cand), cand, 0.0
    )

    # Program (6) at the peak, closed form: per-lane gains are
    # non-increasing, so the greedy's E[T] after m increments is
    # et_floor - cumsum(sorted gains)[m-1] / lam0 — count how many
    # increments stay above T_max instead of walking them.
    et_floor = _price(T_peak, k_start, lam_peak, lam0_peak, k_hi, xp)
    g_sorted = xp.sort(cand.reshape(b, n * k_hi), axis=-1)[:, ::-1]
    ets = et_floor[:, None] - xp.cumsum(g_sorted, axis=-1) / lam0_peak[:, None]
    need_extra = xp.where(et_floor > t_max, 1, 0) + (
        ets[:, :-1] > t_max[:, None]
    ).sum(axis=-1)
    need = xp.ceil((floor_total + need_extra) * cfg.headroom).astype(xp.int32)

    # Candidate set: hold, Program-6-at-peak, +/- neighbor.
    step = int(cfg.neighbor)
    budgets = xp.stack([need, need - step, need + step], axis=-1)  # [B, 3]
    budgets = xp.clip(budgets, floor_total[:, None], k_max[:, None])
    if alloc is not None:
        lam_rep = xp.broadcast_to(lam_peak[:, None, :], (b, 3, n)).reshape(b * 3, n)
        k_alloc = alloc(lam_rep, budgets.reshape(b * 3)).reshape(b, 3, n)
        k_alloc = k_alloc.astype(xp.int32)
    else:
        extra = xp.clip(budgets - floor_total[:, None], 0, None).astype(xp.int32)
        cand_rep = xp.broadcast_to(cand[:, None, :, :], (b, 3, n, k_hi)).reshape(
            b * 3, n, k_hi
        )
        take = topr(cand_rep, extra.reshape(b * 3))
        k_alloc = k_start[:, None, :] + take.reshape(b, 3, n).astype(xp.int32)
    k_alloc = xp.where(active[:, None, :], k_alloc, 0)
    k_hold = xp.where(active, k_cur, 0).astype(xp.int32)[:, None, :]
    k_cand = xp.concatenate([k_hold, k_alloc], axis=1)  # [B, C=4, N]

    # Price every candidate at every horizon step: analytic steady state...
    kc = xp.clip(k_cand, 0, k_hi).astype(xp.int32)
    per_op = xp.take_along_axis(T_h[:, None], kc[:, :, None, :, None], axis=-1)[
        ..., 0
    ]  # [B, C, H, N]
    lam_h = lam_pred[:, None]  # [B, 1, H, N]
    contrib = xp.where(lam_h > 0, lam_h * per_op, 0.0)
    et_a = contrib.sum(axis=-1) / lam0_h[:, None, :]  # [B, C, H]

    # ...and a bounded-queue fluid rollout from the actual backlog (the
    # batch simulator's window recurrence at tick granularity; lam_pred
    # is already per-op offered rate, so no routing hop is re-applied).
    cap_rate = _capacity(k_cand, mu_eff[:, None, :], group[:, None, :],
                         alpha[:, None, :], xp)  # [B, C, N]
    svc = xp.where(
        group[:, None, :],
        xp.where(cap_rate > 0, 1.0 / xp.maximum(cap_rate, _TINY), xp.inf),
        1.0 / mu_eff[:, None, :],
    )
    q = xp.where(active, q0, 0.0)[:, None, :] + xp.zeros_like(cap_rate)
    et_roll = []
    for hi in range(h):
        lam_s = lam_pred[:, hi][:, None, :]  # [B, 1, N]
        avail = q + lam_s * span
        served = xp.minimum(avail, cap_rate * span)
        q = xp.minimum(avail - served, cap_queue[:, None, :])
        wait = xp.where(cap_rate > 0, q / xp.maximum(cap_rate, _TINY), xp.inf)
        contrib_r = xp.where(lam_s > 0, lam_s * (wait + svc), 0.0)
        et_roll.append(contrib_r.sum(axis=-1) / lam0_h[:, hi][:, None])
    et_r = xp.stack(et_roll, axis=-1)  # [B, C, H]
    et_hat = xp.maximum(et_a, et_r)

    # Feasible = under T_max across the horizon AND within budget.
    tot = k_cand.sum(axis=-1)  # [B, C]
    ok = (
        (et_hat <= t_max[:, None, None]).all(axis=-1)
        & (tot <= k_max[:, None])
        & (floor_total <= k_max)[:, None]
    )
    score = xp.where(ok, tot.astype(dtype), xp.inf)
    choice = xp.argmin(score, axis=-1)  # first min: ties prefer holding
    chosen_tot = xp.take_along_axis(tot, choice[:, None], axis=-1)[:, 0]
    hold_tot = tot[:, 0]
    keep_hold = (
        ok[:, 0]
        & (chosen_tot < hold_tot)
        & (chosen_tot > cfg.scale_in_hysteresis * hold_tot)
    )
    choice = xp.where(keep_hold, 0, choice)
    k_plan = xp.take_along_axis(k_cand, choice[:, None, None], axis=1)[:, 0]
    et_plan = xp.take_along_axis(et_hat, choice[:, None, None], axis=1)[:, 0, 0]
    return k_plan, ok.any(axis=-1), et_hat[:, 0, 0], et_plan, need


def mpc_plan_compact(eligible, lam_pred, q0, k_cur, *, k_max, **plan_kw):
    """:func:`mpc_plan` restricted to the ``eligible [B]`` lanes — the
    twin side of the trigger-gated compaction (DESIGN.md §18).

    A plan is only ever *committed* where the caller's
    ``use = conf & any_ok & complete & ~hot & isfinite(t_max)`` gate is
    open, and ``use`` is a subset of the eligibility mask the caller
    passes here (``conf & complete & ~hot & isfinite(t_max)``), so
    pricing only those lanes is exact: every op in :func:`mpc_plan` is
    per-lane, hence the gathered results are bitwise what a dense pass
    would produce for the same lanes.  Unpriced lanes return the
    fall-back row (``any_ok = False`` — reactive path — plus hold
    allocation, inf E[T], ``need = 0``); none of those defaults is read
    where ``use`` is False except the ``need`` diagnostic, which is
    documented to be 0 on unpriced lanes.
    """
    b = lam_pred.shape[0]
    active = np.asarray(plan_kw["active"], dtype=bool)
    k_plan = np.where(active, np.asarray(k_cur), 0).astype(np.int32)
    any_ok = np.zeros(b, dtype=bool)
    et_hold = np.full(b, np.inf, dtype=lam_pred.dtype)
    et_plan = np.full(b, np.inf, dtype=lam_pred.dtype)
    need = np.zeros(b, dtype=np.int32)
    idx = np.nonzero(np.asarray(eligible, dtype=bool))[0]
    if idx.size:

        def gather(v):
            arr = np.asarray(v)
            return arr[idx] if arr.ndim >= 1 and arr.shape[0] == b else v

        kp, ok, eh, ep, nd = mpc_plan(
            lam_pred[idx],
            np.asarray(q0)[idx],
            np.asarray(k_cur)[idx],
            k_max=np.asarray(k_max)[idx],
            **{key: gather(val) for key, val in plan_kw.items()},
        )
        k_plan[idx] = kp
        any_ok[idx] = ok
        et_hold[idx] = eh
        et_plan[idx] = ep
        need[idx] = nd
    return k_plan, any_ok, et_hold, et_plan, need


# --------------------------------------------------------------------------- #
# Twin-side stateful shell (numpy; the fused path carries the same state
# tuple through its lax.scan instead)
# --------------------------------------------------------------------------- #
@dataclass
class ProactiveController:
    """Forecast state + the sim-side statics the rollout needs, for the
    float64 twin paths (``tick_batch`` and ``DRSScheduler``).

    ``mpc_used`` / ``confident`` / ``need`` hold the last tick's [B]
    outcomes (trajectory surface for ScenarioRunner / benchmarks).
    """

    cfg: MPCConfig
    cap_queue: np.ndarray  # [B, N]
    span: float
    state: tuple
    mpc_used: np.ndarray | None = None
    confident: np.ndarray | None = None
    need: np.ndarray | None = None

    @classmethod
    def create(
        cls, b: int, n: int, cfg: MPCConfig, *, cap_queue=None, span: float = 10.0
    ) -> "ProactiveController":
        cap = (
            np.full((b, n), np.inf)
            if cap_queue is None
            else np.asarray(cap_queue, dtype=np.float64)
        )
        return cls(cfg, cap, float(span), forecast_init_state(b, n, cfg))
