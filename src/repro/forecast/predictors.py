"""Per-operator arrival-rate forecasters over the measurement history
window (DESIGN.md §15).

Every predictor is one pure batched function ``(history [B, W, N],
horizon) -> predicted rates [B, H, N]`` written once against an ``xp``
array namespace, so the float64 numpy twin and the jit jax path execute
the *identical* float-op sequence (the batchsim twin/jit discipline):
``forecast_rates(h, H, params)`` is the twin, ``forecast_rates(h, H,
params, xp=jnp)`` traces under ``jax.jit`` / ``lax.scan`` with no shape
dynamism (the smoothing recursions unroll over the static window length).

Kinds (:class:`PredictorParams`):

* ``ewma`` — simple exponential smoothing; the h-step forecast is the
  level (flat), the right prior for noisy-but-stationary rates;
* ``holt`` — Holt double-exponential (level + trend); the h-step
  forecast extrapolates the trend (clamped at 0), which is what lets the
  MPC planner see a flash-crowd ramp *before* the overload trigger;
* ``seasonal`` — seasonal-naive over ``season`` ticks: the forecast for
  phase p is the observation one season back at the same phase — the
  diurnal-aware variant (a sinusoid with period = ``season`` ticks is
  predicted exactly after one full season of history).

Online error tracking (:func:`error_update` etc.) keeps per-operator
MASE and sMAPE of the one-step-ahead forecasts; :func:`confidence`
collapses them into the planner's per-scenario trust gate — the MPC
layer (forecast/mpc.py) falls back to the reactive ``decide_single``
path whenever the gate is closed (DESIGN.md §15 fallback semantics).

State is a flat tuple of arrays (no objects), so it slots directly into
the fused loop's ``lax.scan`` carry (core/controller.py).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "PREDICTOR_KINDS",
    "PredictorParams",
    "forecast_rates",
    "error_init",
    "error_update",
    "mase",
    "smape",
    "confidence",
    "history_init",
    "history_push",
]

PREDICTOR_KINDS = ("ewma", "holt", "seasonal")

# sMAPE denominator guard: a 0-rate observation met by a 0-rate forecast
# scores 0 error, not 0/0.
_SMAPE_EPS = 1e-9
# MASE denominator guard (a perfectly constant history has zero naive
# error; any model error then rightly blows the ratio up).
_MASE_EPS = 1e-12


@dataclass(frozen=True)
class PredictorParams:
    """One predictor's knobs (static: baked into the jit program)."""

    kind: str = "holt"
    alpha: float = 0.5  # level smoothing weight (newest observation)
    beta: float = 0.3  # trend smoothing weight (holt)
    season: int = 0  # season length in ticks (seasonal; >= 2)

    def __post_init__(self):
        if self.kind not in PREDICTOR_KINDS:
            raise ValueError(
                f"unknown predictor kind {self.kind!r}; expected one of "
                f"{PREDICTOR_KINDS}"
            )
        if not 0.0 < self.alpha <= 1.0 or not 0.0 <= self.beta <= 1.0:
            raise ValueError(
                f"need 0 < alpha <= 1 and 0 <= beta <= 1; got "
                f"alpha={self.alpha}, beta={self.beta}"
            )
        if self.kind == "seasonal" and self.season < 2:
            raise ValueError(
                f"seasonal predictor needs season >= 2 ticks, got {self.season}"
            )


def _ewma_level(history, alpha: float, xp):
    """[B, N] smoothed level after one pass over the window."""
    level = history[:, 0, :]
    for t in range(1, history.shape[1]):
        level = alpha * history[:, t, :] + (1.0 - alpha) * level
    return level


def _holt_state(history, alpha: float, beta: float, xp):
    """[B, N] (level, trend) after one Holt pass over the window."""
    level = history[:, 0, :]
    trend = xp.zeros_like(level)
    for t in range(1, history.shape[1]):
        y = history[:, t, :]
        new_level = alpha * y + (1.0 - alpha) * (level + trend)
        trend = beta * (new_level - level) + (1.0 - beta) * trend
        level = new_level
    return level, trend


def forecast_rates(history, horizon: int, params: PredictorParams, xp=np):
    """``history [B, W, N]`` (oldest first) -> predicted rates ``[B, H, N]``.

    ``history[:, -1]`` is the latest observed per-operator rate;
    prediction step h (0-based) targets tick ``now + h + 1``.  Pure and
    shape-static: jit-able with ``xp=jax.numpy``.  Negative
    extrapolations clamp to 0 (rates).  The seasonal kind requires
    ``W >= season``; callers size the window accordingly
    (:class:`~repro.forecast.mpc.MPCConfig` validates it).
    """
    b, w, n = history.shape
    if horizon < 1:
        raise ValueError(f"need horizon >= 1, got {horizon}")
    if params.kind == "ewma":
        level = _ewma_level(history, params.alpha, xp)
        return xp.broadcast_to(level[:, None, :], (b, horizon, n)) + xp.zeros(
            (b, horizon, n), dtype=history.dtype
        )
    if params.kind == "holt":
        level, trend = _holt_state(history, params.alpha, params.beta, xp)
        steps = xp.arange(1, horizon + 1, dtype=history.dtype)
        return xp.maximum(
            level[:, None, :] + steps[None, :, None] * trend[:, None, :], 0.0
        )
    # seasonal-naive: phase h of the next season = the same phase one
    # season back.  Static integer gather, so twin/jit agreement is exact.
    s = params.season
    if w < s:
        raise ValueError(f"seasonal window {w} shorter than season {s}")
    idx = np.array([w - s + (h % s) for h in range(horizon)], dtype=np.int64)
    return history[:, idx, :]


# --------------------------------------------------------------------------- #
# Online forecast-error tracking (MASE / sMAPE per operator)
# --------------------------------------------------------------------------- #
def error_init(b: int, n: int, xp=np, dtype=np.float64):
    """Zeroed tracker state: ``(prev_pred [B,N], prev_y [B,N],
    abs_err_sum [B,N], naive_err_sum [B,N], smape_sum [B,N], n_obs [B])``.

    ``n_obs`` counts observations; comparison i is only scored once both
    a prior prediction and a prior observation exist (n_obs >= 2 at
    scoring time), so the zero-initialised ``prev_*`` never pollute the
    sums.  A flat tuple of arrays: drops straight into a lax.scan carry.
    """
    z = xp.zeros((b, n), dtype=dtype)
    return (z, z, z, z, z, xp.zeros(b, dtype=dtype))


def error_update(state, pred_next, y, xp=np):
    """Score last tick's one-step forecast against the observed ``y``
    [B, N], then arm ``pred_next`` (this tick's h=1 forecast) for the
    next scoring round.  Returns the new state tuple."""
    prev_pred, prev_y, abs_err, naive_err, smape_sum, n_obs = state
    scored = xp.where(n_obs >= 1.0, 1.0, 0.0)[:, None]
    err = xp.abs(prev_pred - y)
    naive = xp.abs(y - prev_y)
    sm = 2.0 * err / (xp.abs(prev_pred) + xp.abs(y) + _SMAPE_EPS)
    return (
        pred_next,
        y,
        abs_err + scored * err,
        naive_err + scored * naive,
        smape_sum + scored * sm,
        n_obs + 1.0,
    )


def mase(state, xp=np):
    """[B, N] mean absolute scaled error: model error relative to the
    naive (persistence) forecaster.  < 1 = beats persistence."""
    return state[2] / xp.maximum(state[3], _MASE_EPS)


def smape(state, xp=np):
    """[B, N] symmetric MAPE of the one-step forecasts, in [0, 2]."""
    scored = xp.maximum(state[5] - 1.0, 1.0)[:, None]
    return state[4] / scored


def confidence(
    state,
    active,
    *,
    min_scored: int,
    mase_gate: float,
    smape_gate: float,
    xp=np,
):
    """[B] bool: is this scenario's forecast trustworthy?

    Requires at least ``min_scored`` scored comparisons AND the
    active-lane mean MASE / sMAPE under their gates.  The MPC planner
    treats a closed gate as "fall back to the reactive decide"
    (DESIGN.md §15) — an unforecastable trace (e.g. an adversarial MMPP
    switcher) keeps sMAPE high and never hands control to the planner.
    """
    m = mase(state, xp=xp)
    s = smape(state, xp=xp)
    act = xp.where(active, 1.0, 0.0)
    cnt = xp.maximum(act.sum(axis=-1), 1.0)
    m_mean = (act * m).sum(axis=-1) / cnt
    s_mean = (act * s).sum(axis=-1) / cnt
    scored = state[5] - 1.0
    return (scored >= float(min_scored)) & (m_mean <= mase_gate) & (s_mean <= smape_gate)


# --------------------------------------------------------------------------- #
# Rolling history window
# --------------------------------------------------------------------------- #
def history_init(b: int, w: int, n: int, xp=np, dtype=np.float64):
    """Zeroed ``[B, W, N]`` rate-history window (oldest first)."""
    return xp.zeros((b, w, n), dtype=dtype)


def history_push(hist, y, n_obs, xp=np):
    """Append observation ``y [B, N]`` to the window.

    The very first observation (``n_obs < 1``) back-fills the whole
    window, so the smoothing recursions start from the first real rate
    instead of a zero ramp — without this, the first W forecasts would
    chase a phantom step from 0.
    """
    rolled = xp.concatenate([hist[:, 1:, :], y[:, None, :]], axis=1)
    filled = xp.broadcast_to(y[:, None, :], hist.shape)
    first = (n_obs < 1.0)[:, None, None]
    return xp.where(first, filled, rolled)
