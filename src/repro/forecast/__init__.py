"""Predictive arrival modeling + MPC lookahead planning (DESIGN.md §15).

Layers: :mod:`repro.forecast.predictors` (EWMA / Holt / seasonal rate
forecasters + MASE/sMAPE trust tracking) and :mod:`repro.forecast.mpc`
(horizon pricing of a small candidate-allocation set, confidence-gated
against the reactive controller).  Integration lives in
``core/controller.py`` (``proactive=`` on ``tick_batch`` /
``make_fused_loop``), ``core/scheduler.py`` and ``api/session.py``.
"""

from .mpc import (
    MPCConfig,
    ProactiveController,
    forecast_init_state,
    forecast_step,
    gain_topr_np,
    mpc_plan,
    sojourn_table_arrays,
)
from .predictors import (
    PREDICTOR_KINDS,
    PredictorParams,
    confidence,
    error_init,
    error_update,
    forecast_rates,
    history_init,
    history_push,
    mase,
    smape,
)

__all__ = [
    "PREDICTOR_KINDS",
    "PredictorParams",
    "forecast_rates",
    "error_init",
    "error_update",
    "mase",
    "smape",
    "confidence",
    "history_init",
    "history_push",
    "MPCConfig",
    "ProactiveController",
    "forecast_init_state",
    "forecast_step",
    "gain_topr_np",
    "mpc_plan",
    "sojourn_table_arrays",
]
