"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000.  GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""

from ..models.common import ModelConfig

ARCH = "command-r-35b"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab=256000,
        rope_theta=8000000.0,
        tie_embeddings=True,  # command-r ties embeddings
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=1,
        d_ff=176,
        vocab=512,  # big-vocab family flavour
        rope_theta=10000.0,
        tie_embeddings=True,
    )
