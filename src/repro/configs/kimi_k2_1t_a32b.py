"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared expert) — trillion-param MoE.
[arXiv:2501.kimi2 paper table; unverified]"""

from ..models.common import ModelConfig

ARCH = "kimi-k2-1t-a32b"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,  # per-expert hidden (paper table: d_ff=2048)
        vocab=163840,
        rope_theta=1000000.0,
        n_experts=384,
        top_k=8,
        moe_d_ff=2048,
        n_shared_experts=1,
        capacity_factor=1.25,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=32,
        vocab=256,
        rope_theta=10000.0,
        n_experts=8,
        top_k=2,
        moe_d_ff=32,
        n_shared_experts=1,
    )
