"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
llama-arch GQA.  [arXiv:2403.04652; hf]"""

from ..models.common import ModelConfig

ARCH = "yi-34b"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="dense",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab=64000,
        rope_theta=5000000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="dense",
        n_layers=3,
        d_model=56,  # keeps 56-head ratio family: 7 heads of 8
        n_heads=7,
        n_kv_heads=1,
        d_ff=160,
        vocab=256,
        rope_theta=10000.0,
    )
