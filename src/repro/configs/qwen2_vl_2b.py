"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936.  M-RoPE, dynamic resolution (patch frontend is a stub input).
[arXiv:2409.12191; hf]"""

from ..models.common import ModelConfig

ARCH = "qwen2-vl-2b"

# Fixed stub patch count fed by input_specs (dynamic resolution is the
# frontend's business; the backbone sees a flat patch sequence).
N_PATCHES = 256


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        rope_theta=1000000.0,
        m_rope=True,
        mrope_sections=(16, 24, 24),
        tie_embeddings=True,  # qwen2-vl-2b ties embeddings
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="vlm",
        n_layers=2,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_ff=96,
        vocab=256,
        rope_theta=10000.0,
        m_rope=True,
        mrope_sections=(4, 2, 2),  # head_dim 16 -> half = 8
        tie_embeddings=True,
    )
