"""Architecture configs — one module per assigned architecture.

``get_config(arch, preset)`` returns a ModelConfig; preset "full" is the
exact published configuration (dry-run only: ShapeDtypeStruct, never
allocated on CPU), preset "smoke" is a reduced same-family config for CPU
smoke tests.  ``ARCHS`` lists all assigned ids.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "rwkv6-1.6b",
    "command-r-35b",
    "llama3.2-1b",
    "yi-34b",
    "phi3-medium-14b",
    "qwen2-vl-2b",
    "mixtral-8x22b",
    "kimi-k2-1t-a32b",
    "zamba2-7b",
    "whisper-medium",
]

_MODULES = {
    "rwkv6-1.6b": "rwkv6_1_6b",
    "command-r-35b": "command_r_35b",
    "llama3.2-1b": "llama3_2_1b",
    "yi-34b": "yi_34b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "mixtral-8x22b": "mixtral_8x22b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "zamba2-7b": "zamba2_7b",
    "whisper-medium": "whisper_medium",
}


def get_config(arch: str, preset: str = "full"):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    if preset == "full":
        return mod.full()
    if preset == "smoke":
        return mod.smoke()
    raise ValueError(f"unknown preset {preset!r}")
