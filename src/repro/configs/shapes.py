"""Assigned input shapes and ``input_specs()`` (ShapeDtypeStruct stand-ins).

Shapes (assigned to this paper; LM shapes are seq_len x global_batch):
  train_4k     seq_len=4096    global_batch=256   (training, train_step)
  prefill_32k  seq_len=32768   global_batch=32    (inference prefill)
  decode_32k   seq_len=32768   global_batch=128   (decode: ONE new token
                                                   against a 32k cache)
  long_500k    seq_len=524288  global_batch=1     (long-context decode;
                                                   sub-quadratic archs only)

``long_500k`` runs for rwkv6-1.6b (attention-free), zamba2-7b (hybrid SSM)
and mixtral-8x22b (SWA window 4096 bounds decode attention); it is SKIPPED
for the pure full-attention archs (see DESIGN.md §5).

For [audio]/[vlm] archs the modality frontend is a STUB: input_specs
provides precomputed frame/patch embeddings, per the assignment.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import get_config
from .qwen2_vl_2b import N_PATCHES

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "cell_is_supported", "skip_reason"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

_SUBQUADRATIC = {"rwkv6-1.6b", "zamba2-7b", "mixtral-8x22b"}


def cell_is_supported(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in _SUBQUADRATIC
    return True


def skip_reason(arch: str, shape: str) -> str | None:
    if cell_is_supported(arch, shape):
        return None
    return (
        f"{arch} is pure full attention: a 500k-token decode cache has no "
        "sub-quadratic path (DESIGN.md §5); long_500k runs only for "
        "SSM/hybrid/SWA archs"
    )


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(arch: str, shape: str) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the given cell.

    For ``train``: the train_step batch.  For ``prefill``: prompt batch.
    For ``decode``: one-token batch + the full-size cache (built by
    launch/dryrun.py via serve.init_cache eval_shape).
    """
    cfg = get_config(arch, "full")
    spec = SHAPES[shape]
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32

    if spec.kind == "train":
        batch = {
            "tokens": _sds((b, s), i32),
            "labels": _sds((b, s), i32),
        }
        if cfg.family == "vlm":
            s_text = s - N_PATCHES
            batch = {
                "tokens": _sds((b, s_text), i32),
                "labels": _sds((b, s_text), i32),
                "patch_embeds": _sds((b, N_PATCHES, cfg.d_model), cfg.dtype),
                "positions_3d": _sds((3, b, s), i32),
            }
        if cfg.family == "audio":
            batch["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), cfg.dtype)
        return batch

    if spec.kind == "prefill":
        batch = {"tokens": _sds((b, s), i32)}
        if cfg.family == "vlm":
            batch = {
                "tokens": _sds((b, s - N_PATCHES), i32),
                "patch_embeds": _sds((b, N_PATCHES, cfg.d_model), cfg.dtype),
                "positions_3d": _sds((3, b, s), i32),
            }
        if cfg.family == "audio":
            batch["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), cfg.dtype)
        return batch

    # decode: one new token; cache shapes come from serve.init_cache
    return {"tokens": _sds((b,), i32)}
