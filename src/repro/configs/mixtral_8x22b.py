"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA.  [arXiv:2401.04088; hf]"""

from ..models.common import ModelConfig

ARCH = "mixtral-8x22b"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=32768,
        rope_theta=1000000.0,
        attention="swa",
        swa_window=4096,
        n_experts=8,
        top_k=2,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        rope_theta=10000.0,
        attention="swa",
        swa_window=8,
        n_experts=4,
        top_k=2,
        # Tiny smoke batches hit capacity drops at the default 1.25 factor,
        # which would make prefill+decode diverge from forward() for
        # reasons that are *correct* MoE semantics but not what the
        # teacher-forcing equivalence test probes.  No-drop regime:
        capacity_factor=8.0,
    )
