"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352.  RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""

from ..models.common import ModelConfig

ARCH = "phi3-medium-14b"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_ff=17920,
        vocab=100352,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,  # kv=10 of 40 -> same 4:1-ish grouping flavour
        d_ff=224,
        vocab=256,
        rope_theta=10000.0,
    )
