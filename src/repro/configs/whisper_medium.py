"""whisper-medium [audio] — enc-dec 24L d_model=1024 16H d_ff=4096
vocab=51865; conv/mel frontend is a STUB (input_specs provides frame
embeddings).  [arXiv:2212.04356; unverified]"""

from ..models.common import ModelConfig

ARCH = "whisper-medium"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="audio",
        n_layers=24,  # decoder layers
        enc_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=51865,
        enc_dec=True,
        enc_seq=1500,  # 30 s of audio at 50 frames/s
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="audio",
        n_layers=2,
        enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        enc_dec=True,
        enc_seq=32,
        rope_theta=10000.0,
    )
