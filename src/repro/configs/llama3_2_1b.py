"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256.  [hf:meta-llama/Llama-3.2-1B; unverified]"""

from ..models.common import ModelConfig

ARCH = "llama3.2-1b"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=128256,
        rope_theta=500000.0,
        tie_embeddings=True,  # llama3.2-1b ties input/output embeddings
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        rope_theta=10000.0,
        tie_embeddings=True,
    )
