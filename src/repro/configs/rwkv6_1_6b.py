"""rwkv6-1.6b [ssm] — Finch: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536, data-dependent decay.  [arXiv:2404.05892; unverified]"""

from ..models.common import ModelConfig

ARCH = "rwkv6-1.6b"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,  # wkv heads (head size 64)
        n_kv_heads=32,
        d_ff=7168,
        vocab=65536,
        attention="none",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        attention="none",
    )
