"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64.  Mamba2 blocks + shared attention blocks.
[arXiv:2411.15242; unverified]"""

from ..models.common import ModelConfig

ARCH = "zamba2-7b"


def full() -> ModelConfig:
    return ModelConfig(
        arch=ARCH,
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,  # shared block is full MHA
        d_ff=14336,
        vocab=32000,
        rope_theta=10000.0,
        ssm_state=64,
        hybrid_attn_every=6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-smoke",
        family="hybrid",
        n_layers=5,  # two groups: 3 + 2 with attn sites after each
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        rope_theta=10000.0,
        ssm_state=8,
        hybrid_attn_every=3,
    )
