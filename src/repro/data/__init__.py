"""Data substrate: synthetic token streams + DRS-schedulable loader."""

from .pipeline import DataConfig, PipelinedLoader, SyntheticTokens

__all__ = ["DataConfig", "PipelinedLoader", "SyntheticTokens"]
