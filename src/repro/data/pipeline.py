"""Host-side data pipeline, modeled (and scheduled!) as a DRS topology.

The pipeline is a chain of host operators — ``read -> tokenize -> pack ->
device_put`` — each with ``k_i`` worker threads, exactly the paper's
operator/processor structure.  A Measurer samples each stage; when the
training job's consumption rate exceeds a stage's throughput, the
DRSScheduler reallocates host workers (examples/train_smoke.py wires this
up) — this is the paper's technique applied to the *input* side of
training, where stragglers and rate fluctuations are endemic at 1000-node
scale.

The synthetic token source is deterministic given (seed, step) so a
restored-from-checkpoint run replays the exact same stream: the iterator
state IS the step counter (checkpoint/store.py persists it via `extra`).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "SyntheticTokens", "PipelinedLoader"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    pack_docs: bool = True
    mean_doc_len: int = 512


class SyntheticTokens:
    """Deterministic synthetic LM stream: doc-packed token blocks.

    Documents have exponential lengths (mean ``mean_doc_len``), contents
    are a Zipf-ish unigram draw, and documents are packed back-to-back
    into (batch, seq_len) blocks with EOS=0 separators — shaped like a
    real pretraining feed, cheap enough for CPU smoke runs.
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def _block(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.batch, cfg.seq_len
        total = b * (s + 1)
        if cfg.pack_docs:
            toks = np.empty(total, dtype=np.int64)
            pos = 0
            while pos < total:
                doc_len = max(1, int(rng.exponential(cfg.mean_doc_len)))
                n = min(doc_len, total - pos - 1)
                # Zipf-ish unigram over the vocab
                u = rng.random(n)
                toks[pos : pos + n] = (cfg.vocab - 2) * u**3 + 1
                pos += n
                if pos < total:
                    toks[pos] = 0  # EOS
                    pos += 1
        else:
            toks = rng.integers(1, cfg.vocab, size=total)
        toks = toks.reshape(b, s + 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        out = self._block(self.step)
        self.step += 1
        return out

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.step = int(state["step"])


class PipelinedLoader:
    """Multi-stage prefetching loader with per-stage worker pools.

    Stages: generate -> transform (tokenize/augment hook) -> ready queue.
    Per-stage parallelism is adjustable at runtime (`scale_stage`), which
    is the knob the DRS scheduler turns.
    """

    def __init__(
        self,
        source: SyntheticTokens,
        *,
        transform=None,
        capacity: int = 8,
        workers: dict[str, int] | None = None,
        measurer=None,
    ):
        self.source = source
        self.transform = transform or (lambda x: x)
        self._raw: queue.Queue = queue.Queue(maxsize=capacity)
        self._ready: queue.Queue = queue.Queue(maxsize=capacity)
        self._stop = threading.Event()
        self._source_lock = threading.Lock()
        self.measurer = measurer
        self._probes = {}
        if measurer is not None:
            self._probes = {
                "generate": measurer.new_probe("generate"),
                "transform": measurer.new_probe("transform"),
            }
        self._workers: dict[str, list[tuple[threading.Thread, threading.Event]]] = {
            "generate": [],
            "transform": [],
        }
        workers = workers or {"generate": 1, "transform": 1}
        for stage, n in workers.items():
            self.scale_stage(stage, n)

    def scale_stage(self, stage: str, n: int) -> None:
        cur = self._workers[stage]
        while len(cur) < n:
            ev = threading.Event()
            t = threading.Thread(target=self._loop, args=(stage, ev), daemon=True)
            cur.append((t, ev))
            t.start()
        while len(cur) > n:
            _, ev = cur.pop()
            ev.set()

    def k(self) -> dict[str, int]:
        return {s: len(w) for s, w in self._workers.items()}

    def _loop(self, stage: str, stop: threading.Event) -> None:
        import time as _time

        while not stop.is_set() and not self._stop.is_set():
            try:
                if stage == "generate":
                    with self._source_lock:
                        item = next(self.source)
                    t0 = _time.perf_counter()
                    self._raw.put(item, timeout=0.2)
                    if self._probes:
                        self._probes["generate"].on_enqueue()
                        self._probes["generate"].on_processed(_time.perf_counter() - t0)
                else:
                    item = self._raw.get(timeout=0.2)
                    t0 = _time.perf_counter()
                    out = self.transform(item)
                    self._ready.put(out, timeout=5.0)
                    if self._probes:
                        self._probes["transform"].on_enqueue()
                        self._probes["transform"].on_processed(_time.perf_counter() - t0)
            except queue.Empty:
                continue
            except queue.Full:
                continue

    def __next__(self):
        while True:
            try:
                return self._ready.get(timeout=5.0)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration from None

    def __iter__(self):
        return self

    def stop(self) -> None:
        self._stop.set()
        for stage in self._workers.values():
            for _, ev in stage:
                ev.set()
