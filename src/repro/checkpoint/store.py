"""Sharded, async, elastic checkpointing.

Design (DESIGN.md §8):

* **Layout-independent**: arrays are saved per-leaf as .npy plus a JSON
  manifest keyed by the pytree path and the *logical axes* — a checkpoint
  taken on a (2,16,16) mesh restores onto (16,16) or (4,16,16) because
  restore re-shards from the logical axes, not from the device layout at
  save time.
* **Atomic**: writes go to ``<dir>.tmp`` then rename; a crash mid-save
  never corrupts the latest checkpoint; ``latest_step`` scans for complete
  manifests only.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes to disk on a background thread — the train loop keeps
  stepping during the disk write (the paper's "minimise overhead"
  principle applied to fault tolerance).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointStore"]

# numpy round-trips ml_dtypes arrays as raw void bytes ("|V2"), which can't
# be cast back.  Store them as same-width uints + the logical dtype name.
_EXOTIC_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _exotic(name: str):
    import ml_dtypes

    return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        out.append((key, leaf))
    return out


class CheckpointStore:
    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self.save_count = 0

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> Path:
        """Synchronous atomic save."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, *, extra: dict | None = None) -> None:
        """Snapshot now, write on a background thread."""
        self.wait()  # one in-flight save at a time
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # device->host sync point
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: dict) -> Path:
        final = self._step_dir(step)
        tmp = final.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest: dict[str, Any] = {"step": step, "time": time.time(), "extra": extra, "leaves": {}}
        for key, leaf in _flatten_with_paths(host_tree):
            arr = np.asarray(leaf)
            fname = key.replace("/", "__") + ".npy"
            logical = arr.dtype.name
            if logical in _EXOTIC_DTYPES:
                np.save(tmp / fname, arr.view(_EXOTIC_DTYPES[logical]))
            else:
                np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": logical,
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        with self._lock:
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self.save_count += 1
        return final

    # ------------------------------------------------------------------ #
    def latest_step(self) -> int | None:
        steps = []
        for d in self.dir.glob("step_*"):
            if d.is_dir() and (d / "manifest.json").exists():
                try:
                    steps.append(int(d.name.split("_")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template`` (arrays or
        ShapeDtypeStructs).  Returns (tree, extra).  Dtypes are cast to the
        template's, so a checkpoint saved with f32 moments restores onto a
        bf16-moment template (and vice versa) with an explicit cast."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = dict(_flatten_with_paths(template))

        restored: dict[str, np.ndarray] = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if meta["dtype"] in _EXOTIC_DTYPES:
                arr = arr.view(_exotic(meta["dtype"]))
            if key in leaves:
                want = leaves[key]
                if tuple(arr.shape) != tuple(want.shape):
                    raise ValueError(
                        f"shape mismatch for {key}: ckpt {arr.shape} vs template {want.shape}"
                    )
                want_dtype = np.dtype(want.dtype)
                if want_dtype.name in _EXOTIC_DTYPES:
                    want_dtype = _exotic(want_dtype.name)
                arr = arr.astype(want_dtype)
            restored[key] = arr

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        out_leaves = []
        for path, leaf in flat:
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
            )
            if key not in restored:
                raise KeyError(f"checkpoint at step {step} missing leaf {key}")
            out_leaves.append(restored[key])
        tree = jax.tree_util.tree_unflatten(jax.tree.structure(template), out_leaves)
        return tree, manifest.get("extra", {})

    def prune(self, keep: int = 3) -> int:
        """Delete all but the newest ``keep`` checkpoints."""
        dirs = sorted(
            (d for d in self.dir.glob("step_*") if (d / "manifest.json").exists()),
            key=lambda d: int(d.name.split("_")[1]),
        )
        removed = 0
        for d in dirs[:-keep] if keep else dirs:
            shutil.rmtree(d)
            removed += 1
        return removed
