"""Checkpoint substrate: sharded async elastic checkpointing."""

from .store import CheckpointStore

__all__ = ["CheckpointStore"]
