"""DRSSession — one AppGraph bound to one backend (DESIGN.md §3).

A session owns the whole measure -> model -> rebalance loop that every
call site used to assemble by hand: scheduler construction (names, routing
matrix, scaling lists all derived from the graph), measurer wiring,
negotiator hookup, tick driving, and decision application.  The same
``AppGraph`` binds unmodified to:

* :class:`EngineBackend` — the live micro-batch ``StreamEngine`` (worker
  threads, real wall-clock measurements);
* :class:`DESBackend` — the discrete-event ``NetworkSimulator`` (simulated
  time, statistically tight model validation), including the group-scaled
  chip-gang conversion the serving router used to hand-roll.

Typical use::

    session = graph.bind("engine", config=SchedulerConfig(k_max=6))
    session.start({"extract": 1, "match": 2, "aggregate": 1})
    ...inject tuples...
    session.tick()          # pulls measurements, decides, applies rescale
    session.drain(); session.stop()

    report = graph.bind("des", seed=3, horizon=2000.0).simulate(k)
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import numpy as np

from ..core import controller as ctl
from ..core.allocator import AllocationResult, InsufficientResourcesError, allocate
from ..core.jackson import Topology
from ..core.measurer import Measurer, MeasurementBatch, stack_snapshots
from ..core.negotiator import Negotiator
from ..core.planner import FleetPlan, FleetPlanner, Tenant
from ..core.rebalance import ExecutableCache, RebalanceCostModel
from ..core.scheduler import DRSScheduler, SchedulerConfig, SchedulerDecision
from .graph import AppGraph, GraphValidationError

__all__ = [
    "DRSSession",
    "EngineBackend",
    "DESBackend",
    "FleetSession",
    "FleetDecision",
    "ScenarioRunner",
    "ScenarioReport",
]


def _group_effective_services(top: Topology, k_vec: np.ndarray):
    """Convert group-scaled operators for the DES: one fast server at
    ``mu * k * eff(k)`` instead of k parallel servers (mirrors
    ``OperatorSpec.scaling == "group"``; DESIGN.md §2)."""
    from ..streaming.des import ServiceProcess

    services, k_eff = [], []
    for i, op in enumerate(top.operators):
        k_i = int(k_vec[i])
        if op.scaling == "group":
            eff = 1.0 / (1.0 + op.group_alpha * (k_i - 1))
            services.append(ServiceProcess(rate=op.mu * k_i * eff))
            k_eff.append(1)
        else:
            services.append(ServiceProcess(rate=op.mu))
            k_eff.append(k_i)
    return services, np.asarray(k_eff, dtype=np.int64)


class EngineBackend:
    """Live StreamEngine behind the backend protocol.

    ``queue_capacity`` bounds every operator queue (``None`` = unbounded)
    and ``overload_policy`` (``"block"`` | ``"shed-newest"`` |
    ``"shed-oldest"``, or an :class:`~repro.streaming.overload.OverloadPolicy`)
    decides what happens when one fills — DESIGN.md §11.
    """

    kind = "engine"

    def __init__(
        self,
        graph: AppGraph,
        *,
        queue_capacity: int | None = 10_000,
        overload_policy: Any = "block",
    ):
        from ..streaming.engine import Operator, StreamEngine

        missing = [op.name for op in graph.ops if op.fn is None]
        if missing:
            raise GraphValidationError(
                f"engine backend needs a compute fn on every operator; "
                f"missing: {missing} (attach with AppGraph.with_fns)"
            )
        self.graph = graph
        self.engine = StreamEngine(
            [Operator(op.name, op.fn) for op in graph.ops],
            queue_capacity=queue_capacity,
            overload_policy=overload_policy,
        )
        self.measurer: Measurer = self.engine.measurer

    def start(self, k: Mapping[str, int]) -> None:
        self.engine.start(dict(k))

    def apply_allocation(self, k: Mapping[str, int]) -> None:
        self.engine.scale_to(dict(k))

    def allocation(self) -> dict[str, int]:
        return self.engine.k()

    def inject(
        self, payload: Any, source: str | None = None, *, timeout: float | None = None
    ) -> int | None:
        if source is None:
            srcs = self.graph.source_names
            if len(srcs) != 1:
                raise GraphValidationError(
                    f"graph has {len(srcs)} sources {srcs}; pass source= explicitly"
                )
            source = srcs[0]
        return self.engine.inject(source, payload, timeout=timeout)

    def drain(self, timeout: float = 10.0) -> bool:
        return self.engine.drain(timeout=timeout)

    def stop(self) -> None:
        self.engine.stop()

    @property
    def completed_sojourns(self) -> list[float]:
        return self.engine.completed_sojourns

    def drop_counts(self) -> dict[str, int]:
        """Cumulative tuples shed per operator (overload policy drops)."""
        return self.engine.drop_counts()


class DESBackend:
    """NetworkSimulator behind the backend protocol (simulated time)."""

    kind = "des"

    def __init__(
        self,
        graph: AppGraph,
        *,
        seed: int = 0,
        horizon: float = 120.0,
        warmup: float = 10.0,
        network_delay: float = 0.0,
        arrival_kind: str | None = None,
        arrival_kw: Mapping[str, float] | None = None,
        measurer: Measurer | None = None,
        queue_capacity: int | None = None,
        overload_policy: Any = "shed-newest",
    ):
        self.graph = graph
        self.seed = seed
        self.horizon = horizon
        self.warmup = warmup
        self.network_delay = network_delay
        self.arrival_kind = arrival_kind or graph.arrival_kind
        # Extra ArrivalProcess parameters for every source — required for
        # the modulated kinds, e.g. bind("des", arrival_kind="mmpp",
        # arrival_kw={"rate2": 50.0, "switch01": 0.2, "switch10": 0.8}) or
        # arrival_kind="burst" with rate2/burst_every/burst_length.
        self.arrival_kw = dict(arrival_kw or {})
        self.measurer = measurer
        self.queue_capacity = queue_capacity
        self.overload_policy = overload_policy

    # The DES is batch-simulated, not tick-driven: the live control-loop
    # protocol fails with a pointer to simulate() instead of AttributeError.
    def _not_live(self, method: str):
        raise GraphValidationError(
            f"DES backend is batch-simulated; {method}() is only available on "
            "the engine backend — use simulate(k, rebalance_to=, rebalance_at=) "
            "to run allocation changes in simulated time"
        )

    def start(self, k):
        self._not_live("start")

    def apply_allocation(self, k):
        self._not_live("apply_allocation")

    def allocation(self):
        self._not_live("allocation")

    def inject(self, payload, source=None):
        self._not_live("inject")

    def drain(self, timeout: float = 10.0):
        self._not_live("drain")

    def stop(self):
        self._not_live("stop")

    @property
    def completed_sojourns(self):
        self._not_live("completed_sojourns")

    def simulator(
        self,
        k: Mapping[str, int] | Sequence[int] | np.ndarray,
        *,
        seed: int | None = None,
        horizon: float | None = None,
        warmup: float | None = None,
    ):
        """Build a NetworkSimulator for allocation ``k`` (group ops are
        collapsed to single effective servers)."""
        from ..streaming.des import ArrivalProcess, NetworkSimulator, ServiceProcess, SimConfig

        graph = self.graph
        top = graph.topology()
        k_vec = graph.k_vector(k)
        services, k_eff = _group_effective_services(top, k_vec)
        # apply each op's declared DES service distribution, keeping the
        # (possibly group-effective) rate the helper computed
        for i, op in enumerate(graph.ops):
            if op.service_kind != "exponential" or op.service_cv != 1.0:
                services[i] = ServiceProcess(
                    rate=services[i].rate, kind=op.service_kind, cv=op.service_cv
                )
        arrivals = [
            ArrivalProcess(rate=float(top.lam0[i]), kind=self.arrival_kind,
                           **self.arrival_kw)
            for i in range(top.n)
        ]
        cfg = SimConfig(
            seed=self.seed if seed is None else seed,
            horizon=self.horizon if horizon is None else horizon,
            warmup=self.warmup if warmup is None else warmup,
            network_delay=self.network_delay,
            queue_capacity=self.queue_capacity,
            overload_policy=self.overload_policy,
        )
        return NetworkSimulator(
            top, k_eff, config=cfg, arrivals=arrivals, services=services,
            measurer=self.measurer,
        )

    def simulate(
        self,
        k: Mapping[str, int] | Sequence[int] | np.ndarray,
        *,
        rebalance_to: Mapping[str, int] | Sequence[int] | np.ndarray | None = None,
        rebalance_at: float | None = None,
        pause: float = 1.0,
        seed: int | None = None,
        horizon: float | None = None,
        warmup: float | None = None,
    ):
        """Run the DES under ``k``; optionally switch to ``rebalance_to``
        at ``rebalance_at`` (with a processing pause) mid-run."""
        graph = self.graph
        sim = self.simulator(k, seed=seed, horizon=horizon, warmup=warmup)
        if rebalance_to is not None and rebalance_at is not None:
            top = sim.top
            k2 = graph.k_vector(rebalance_to)
            services2, k2_eff = _group_effective_services(top, k2)
            for i, op in enumerate(top.operators):
                if op.scaling == "group":
                    sim.schedule_rate_change(rebalance_at, i, services2[i].rate)
            sim.rebalance_at(rebalance_at, k2_eff, pause=pause)
        return sim.run()


_BACKENDS = {"engine": EngineBackend, "des": DESBackend}


class DRSSession:
    """One AppGraph + one backend + the DRS control loop.

    Construction wires the scheduler from the graph (names, routing matrix,
    scaling modes — no positional hand-syncing) and the backend's measurer.
    ``tick()`` pulls, models, decides, and *applies* the decision to the
    backend; ``plan()``/``topology()`` expose the model side directly.
    """

    def __init__(
        self,
        graph: AppGraph,
        backend: EngineBackend | DESBackend,
        *,
        config: SchedulerConfig | None = None,
        negotiator: Negotiator | None = None,
        cost_model: RebalanceCostModel | None = None,
        executable_cache: ExecutableCache | None = None,
        on_decision=None,
        proactive=None,
    ):
        self.graph = graph
        self.backend = backend
        self.config = config or SchedulerConfig()
        self.negotiator = negotiator
        self.cost_model = cost_model
        self.executable_cache = executable_cache
        self.on_decision = on_decision
        self.proactive = proactive  # forecast/MPC mode (MPCConfig | True)
        self.scheduler: DRSScheduler | None = None

    # Construction ------------------------------------------------------ #
    @classmethod
    def bind(cls, graph: AppGraph, backend: Any = "des", **kwargs) -> "DRSSession":
        session_keys = ("config", "negotiator", "cost_model", "executable_cache", "on_decision", "proactive")
        session_kw = {k: kwargs.pop(k) for k in session_keys if k in kwargs}
        if isinstance(backend, str):
            try:
                backend_cls = _BACKENDS[backend]
            except KeyError:
                raise GraphValidationError(
                    f"unknown backend {backend!r}; expected one of {sorted(_BACKENDS)} "
                    "or a backend instance"
                ) from None
            backend = backend_cls(graph, **kwargs)
        elif kwargs:
            raise GraphValidationError(
                f"unexpected options for pre-built backend: {sorted(kwargs)}"
            )
        return cls(graph, backend, **session_kw)

    # Model side --------------------------------------------------------- #
    def topology(self, mu: Mapping[str, float] | None = None) -> Topology:
        return self.graph.topology(mu)

    def plan(
        self, *, k_max: int | None = None, t_max: float | None = None
    ) -> AllocationResult:
        """Program (4)/(6) on the declared graph (priors, not measurements)."""
        k_max = k_max if k_max is not None else self.config.k_max
        t_max = t_max if t_max is not None else self.config.t_max
        if k_max is None and t_max is None:
            raise GraphValidationError(
                "plan() needs a budget: pass k_max= or t_max=, or bind with "
                "config=SchedulerConfig(k_max=..., t_max=...)"
            )
        return allocate(self.topology(), k_max=k_max, t_max=t_max)

    def split(self, alloc: AllocationResult | Sequence[int] | np.ndarray) -> dict[str, int]:
        k = alloc.k if isinstance(alloc, AllocationResult) else alloc
        return self.graph.k_dict(k)

    # Control loop ------------------------------------------------------- #
    def _build_scheduler(self, k0: np.ndarray) -> DRSScheduler:
        scaling, group_alpha = self.graph.scaling_lists()
        return DRSScheduler(
            self.graph.names,
            self.graph.routing_matrix(),
            k0,
            self.config,
            measurer=self.backend.measurer,
            negotiator=self.negotiator,
            cost_model=self.cost_model,
            executable_cache=self.executable_cache,
            scaling=scaling,
            group_alpha=group_alpha,
            on_decision=self.on_decision,
            proactive=self.proactive,
        )

    def start(
        self, k0: Mapping[str, int] | Sequence[int] | np.ndarray | None = None
    ) -> dict[str, int]:
        """Start the backend under ``k0`` (default: the planned optimum)
        and arm the scheduler.  Returns the starting allocation."""
        if k0 is None:
            k0_vec = self.plan().k
        else:
            k0_vec = self.graph.k_vector(k0)
        self.scheduler = self._build_scheduler(k0_vec.copy())
        self.backend.start(self.graph.k_dict(k0_vec))
        # Anchor the measurer's pull clock so the first tick has a window.
        self.backend.measurer.pull(time.time())
        return self.graph.k_dict(k0_vec)

    def tick(self, now: float | None = None) -> SchedulerDecision:
        """One scheduler tick: pull -> model -> decide -> apply."""
        if self.scheduler is None:
            raise RuntimeError("session not started; call start() first")
        decision = self.scheduler.tick(now)
        if decision.action in (
            "rebalance", "scale_out", "scale_in", "overloaded", "proactive"
        ):
            # "overloaded" with no feasible target keeps the current k.
            if decision.k_target is not None:
                self.backend.apply_allocation(self.graph.k_dict(decision.k_target))
        return decision

    @property
    def allocation(self) -> dict[str, int]:
        if self.scheduler is not None:
            return self.graph.k_dict(self.scheduler.k_current)
        return self.backend.allocation()

    @property
    def history(self) -> list[SchedulerDecision]:
        return [] if self.scheduler is None else self.scheduler.history

    # Backend pass-throughs ---------------------------------------------- #
    def inject(
        self, payload: Any, source: str | None = None, *, timeout: float | None = None
    ) -> int | None:
        """Inject an external tuple.  Under a bounded queue with the
        ``block`` policy this backpressures the caller; returns ``None``
        when the tuple was shed at admission (DESIGN.md §11)."""
        if isinstance(self.backend, EngineBackend):
            return self.backend.inject(payload, source=source, timeout=timeout)
        return self.backend.inject(payload, source=source)

    def drain(self, timeout: float = 10.0) -> bool:
        return self.backend.drain(timeout=timeout)

    def stop(self) -> None:
        self.backend.stop()

    @property
    def completed_sojourns(self) -> list[float]:
        return self.backend.completed_sojourns

    def drop_counts(self) -> dict[str, int]:
        """Cumulative tuples shed per operator (engine backend)."""
        if not isinstance(self.backend, EngineBackend):
            raise GraphValidationError(
                "drop_counts() needs the engine backend; the DES reports "
                "drops on its SimResult (per_op_dropped / per_op_drop_rate)"
            )
        return self.backend.drop_counts()

    def simulate(self, k=None, **kwargs):
        """DES-mode: simulate allocation ``k`` (default: planned optimum)."""
        if not isinstance(self.backend, DESBackend):
            raise GraphValidationError(
                f"simulate() needs a DES backend, have {self.backend.kind!r}"
            )
        if k is None:
            k = self.plan().k
        return self.backend.simulate(k, **kwargs)

    def run(self, k=None, **kwargs):
        """One-call entry point: DES -> :meth:`simulate`; engine ->
        :meth:`start` (then inject/tick/drain at your own pace)."""
        if isinstance(self.backend, DESBackend):
            return self.simulate(k, **kwargs)
        return self.start(k)


# --------------------------------------------------------------------------- #
# Fleet: several sessions against one shared pool
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class FleetDecision:
    """One fleet control tick's outcome."""

    t: float
    # "none" | "rebalance" | "scale_in" | "overloaded" | "infeasible"
    action: str
    k_max: int
    plan: FleetPlan | None
    # tenant -> name-keyed allocation actually in force after the tick
    k: dict
    overloaded_tenants: tuple = ()
    objective_current: float = float("inf")
    reason: str = ""


class FleetSession:
    """Several :class:`DRSSession` tenants scheduled against ONE pool.

    Where a ``DRSSession`` runs the paper's control loop for one graph, a
    ``FleetSession`` owns the cross-tenant loop (DESIGN.md §12): every
    tick it pulls each tenant's measurements, rebuilds each tenant's model
    (reusing the per-tenant scheduler's offered-load clamping when a
    tenant is overloaded), and solves the merged Program (4)/(6) with
    :class:`~repro.core.planner.FleetPlanner` — per-tenant ``T_max`` come
    from each session's ``SchedulerConfig.t_max``.

    Overload reuses PR 2's semantics fleet-wide: any tenant with measured
    ``rho >= 1``, or Program-(6) floors exceeding the pool, makes the tick
    ``"overloaded"`` — the negotiator is asked for capacity immediately
    and the replan is applied with no improvement gate.

    Tenants may be model-only (never started): their declared priors feed
    the planner and allocations are tracked but not applied to a backend.

    Typical use::

        fleet = FleetSession(
            {"vld": vld_graph.bind("engine", config=SchedulerConfig(t_max=0.5)),
             "fpd": fpd_graph.bind("engine", config=SchedulerConfig(t_max=2.0))},
            k_max=64,
        )
        fleet.start()          # plans the pool split and starts each backend
        ...inject per tenant...
        fleet.tick()           # merged measure -> model -> replan -> apply
    """

    def __init__(
        self,
        sessions: Mapping[str, DRSSession],
        *,
        k_max: int | None = None,
        negotiator: Negotiator | None = None,
        objective: str = "fair",
        min_improvement: float = 0.05,
        headroom: float = 1.1,
        scale_in_hysteresis: float = 0.8,
        on_decision=None,
        solver: str = "scalar",
        mesh=None,
    ):
        if not sessions:
            raise GraphValidationError("fleet needs at least one session")
        if k_max is None and negotiator is None:
            raise GraphValidationError("fleet needs k_max= and/or negotiator=")
        if solver not in ("scalar", "batched"):
            raise GraphValidationError(
                f"unknown solver {solver!r}; expected 'scalar' or 'batched'"
            )
        if mesh is not None and solver != "batched":
            raise GraphValidationError("mesh= requires solver='batched'")
        self.sessions: dict[str, DRSSession] = dict(sessions)
        self._static_k_max = k_max
        self.negotiator = negotiator
        self.objective = objective
        self.min_improvement = min_improvement
        self.headroom = headroom
        self.scale_in_hysteresis = scale_in_hysteresis
        self.on_decision = on_decision
        # "batched" solves the merged greedy as one gain_topr selection
        # (FleetPlanner.plan_batched); mesh= additionally runs it as the
        # cross-device fleet reduction of DESIGN.md §16.
        self.solver = solver
        self.mesh = mesh
        self.history: list[FleetDecision] = []
        # tenant -> index-ordered allocation currently in force
        self._k: dict[str, np.ndarray] = {}

    # ------------------------------------------------------------------ #
    @property
    def k_max(self) -> int:
        if self.negotiator is not None:
            k = self.negotiator.k_max
            return max(k, self._static_k_max or 0)
        return self._static_k_max

    def tenants(self) -> list[Tenant]:
        return [
            Tenant(name=name, graph=s.graph, t_max=s.config.t_max)
            for name, s in self.sessions.items()
        ]

    def planner(self) -> FleetPlanner:
        return FleetPlanner(self.tenants(), self.k_max, objective=self.objective)

    def plan(self, *, k_max: int | None = None) -> FleetPlan:
        """Cross-tenant Programs (4)/(6) on the declared priors."""
        return self._plan_with(self.planner(), k_max=k_max)

    def _plan_with(
        self, planner: FleetPlanner, tops: dict | None = None,
        *, k_max: int | None = None,
    ) -> FleetPlan:
        """Every plan call routes here so the solver choice (scalar greedy
        vs batched/sharded top-R) applies uniformly across start/tick."""
        if self.solver == "batched":
            return planner.plan_batched(tops, k_max=k_max, mesh=self.mesh)
        return planner.plan(tops, k_max=k_max)

    def allocations(self) -> dict[str, dict[str, int]]:
        """tenant -> name-keyed allocation currently in force."""
        return {
            name: self.sessions[name].graph.k_dict(k) for name, k in self._k.items()
        }

    # ------------------------------------------------------------------ #
    def start(self) -> dict[str, dict[str, int]]:
        """Plan the pool split on priors and start every engine-backed
        tenant under its share (model-only/DES tenants are planned but not
        started).  With a negotiator, the initial lease is acquired here —
        stability minima first, then the Program-(6) floors."""
        try:
            plan = self.plan()
        except InsufficientResourcesError as e:
            if self.negotiator is None:
                raise
            self.negotiator.ensure(int(np.ceil(e.needed * self.headroom)))
            plan = self.plan()
        if self.negotiator is not None and plan.needed_total > self.k_max:
            self.negotiator.ensure(int(np.ceil(plan.needed_total * self.headroom)))
            plan = self.plan()
        for name, session in self.sessions.items():
            k = plan.k[name]
            self._k[name] = k.copy()
            if isinstance(session.backend, EngineBackend):
                session.start(k)
            else:
                # Arm the model side so tick() can track without a backend.
                session.scheduler = session._build_scheduler(k.copy())
        return self.allocations()

    def stop(self) -> None:
        for session in self.sessions.values():
            if isinstance(session.backend, EngineBackend):
                session.stop()

    # ------------------------------------------------------------------ #
    def _measured_topologies(self, now: float) -> tuple[dict, list[str]]:
        """Per-tenant measured model rebuilds + overloaded tenant names.

        The per-tenant measurer pulls stay in Python (live probes), but
        the model plane is batched: the snapshots are stacked into one
        :class:`~repro.core.measurer.MeasurementBatch` and the §11
        overload trigger + throughput-capped propagation run vectorized
        across the whole fleet (core/controller.py) before the per-tenant
        offered-load clamp.  Tenants without a complete snapshot (or
        never started) fall back to their declared priors by omission —
        the planner resolves those from the graph."""
        tops: dict[str, Topology] = {}
        hot: list[str] = []
        pulled: list[tuple[str, DRSScheduler]] = []
        snaps = []
        for name, session in self.sessions.items():
            sched = session.scheduler
            if sched is None:
                continue
            snap = sched.measurer.pull(now)
            sched._observe_instances()
            if not snap.complete():
                continue
            pulled.append((name, sched))
            snaps.append(snap)
        if not pulled:
            return tops, hot
        batch = stack_snapshots(snaps)
        b, n = batch.batch, batch.n
        routing = np.zeros((b, n, n))
        group = np.zeros((b, n), dtype=bool)
        alpha = np.zeros((b, n))
        active = np.zeros((b, n), dtype=bool)
        k_cur = np.zeros((b, n), dtype=np.int64)
        mu_eff = batch.mu_hat.copy()
        for bi, (_, sched) in enumerate(pulled):
            ni = len(sched.names)
            routing[bi, :ni, :ni] = sched.base_routing
            group[bi, :ni] = sched._group
            alpha[bi, :ni] = sched._alpha
            active[bi, :ni] = True
            k_cur[bi, :ni] = sched.k_current
            if sched.speed_factors is not None:
                mu_eff[bi, :ni] = mu_eff[bi, :ni] * sched.speed_factors
        over = ctl.overloaded_mask_batch(
            batch.lam_hat, mu_eff, batch.drop_hat, k_cur, group, alpha
        ) & active
        capped = ctl.capped_mask_batch(over, routing, active)
        for bi, (name, sched) in enumerate(pulled):
            ni = len(sched.names)
            mask = over[bi, :ni]
            if mask.any():
                hot.append(name)
            tops[name] = ctl.clamp_row(
                sched.names,
                sched.base_routing,
                batch.lam_hat[bi, :ni],
                batch.mu_hat[bi, :ni],
                float(batch.lam0_hat[bi]),
                mask,
                capped[bi, :ni],
                sched.scaling,
                sched.group_alpha,
                speed=sched.speed_factors,
            )
        return tops, hot

    def _objective_of(self, planner: FleetPlanner, tops: dict) -> float:
        """Fleet objective of the allocations currently in force — scored
        with the planner's own weighting so the improvement gate compares
        like with like."""
        if not self._k:
            return float("inf")
        total = 0.0
        for tenant in planner.tenants:
            k = self._k.get(tenant.name)
            if k is None:
                return float("inf")
            top = tenant.resolve(tops.get(tenant.name))
            et = top.expected_sojourn(k)
            w = planner.weight(tenant, top)
            total += w * top.lam0_total * et if np.isfinite(et) else float("inf")
        return total

    def _apply(self, plan: FleetPlan) -> dict:
        for name, session in self.sessions.items():
            k = plan.k[name]
            self._k[name] = k.copy()
            if session.scheduler is not None:
                session.scheduler.k_current = k.copy()
            if isinstance(session.backend, EngineBackend):
                session.backend.apply_allocation(session.graph.k_dict(k))
        return self.allocations()

    def tick(self, now: float | None = None) -> FleetDecision:
        """One fleet tick: pull every tenant, replan the pool, apply.

        Mirrors ``DRSScheduler.decide``'s gates at fleet level: an
        improvement below ``min_improvement`` keeps the current split;
        overload (any tenant's measured rho >= 1, or Program-(6) floors
        exceeding the pool) bypasses the gate and leases immediately."""
        now = time.time() if now is None else now
        tops, hot = self._measured_topologies(now)
        k_max = self.k_max
        planner = FleetPlanner(self.tenants(), k_max, objective=self.objective)
        try:
            plan = self._plan_with(planner, tops, k_max=k_max)
        except InsufficientResourcesError as e:
            if self.negotiator is not None:
                self.negotiator.ensure(int(np.ceil(e.needed * self.headroom)))
                k_max = self.k_max
                try:
                    plan = self._plan_with(planner, tops, k_max=k_max)
                except InsufficientResourcesError as e2:
                    return self._emit(FleetDecision(
                        now, "infeasible", k_max, None, self.allocations(),
                        tuple(hot), reason=str(e2),
                    ))
            else:
                return self._emit(FleetDecision(
                    now, "infeasible", k_max, None, self.allocations(),
                    tuple(hot), reason=str(e),
                ))

        overloaded = bool(hot) or plan.overloaded
        if overloaded and self.negotiator is not None and plan.needed_total > k_max:
            # PR-2 overload semantics: lease now, no hysteresis, no gate.
            self.negotiator.ensure(int(np.ceil(plan.needed_total * self.headroom)))
            if self.k_max > k_max:
                k_max = self.k_max
                plan = self._plan_with(planner, tops, k_max=k_max)
        elif (
            self.negotiator is not None
            and self._static_k_max is None
            # Mirror DRSScheduler: only scale in when the floors are real
            # latency targets — every tenant must declare a T_max, or the
            # "need" is just the stability minimum and releasing to it
            # would degrade tenants that never asked for a budget cut.
            and all(t.t_max is not None for t in planner.tenants)
            and plan.needed_total > 0
            and np.ceil(plan.needed_total * self.headroom)
            < self.scale_in_hysteresis * k_max
        ):
            # Shrink the lease and the allocation together: replan at the
            # smaller pool and apply in the same tick, so the machines we
            # hand back are never still part of the split in force.
            target = int(np.ceil(plan.needed_total * self.headroom))
            self.negotiator.ensure(target)
            if self.k_max < k_max:
                cur_obj = self._objective_of(planner, tops)
                k_max = self.k_max
                plan = self._plan_with(planner, tops, k_max=k_max)
                self._apply(plan)
                return self._emit(FleetDecision(
                    now, "scale_in", k_max, plan, self.allocations(), tuple(hot),
                    cur_obj,
                    reason=f"floors need {plan.needed_total} (headroom {target}) "
                    f"<< leased; released to k_max={k_max}",
                ))

        cur_obj = self._objective_of(planner, tops)
        if overloaded:
            self._apply(plan)
            return self._emit(FleetDecision(
                now, "overloaded", k_max, plan, self.allocations(), tuple(hot),
                cur_obj,
                reason=f"overloaded tenants {hot}; floors need "
                f"{plan.needed_total} of {k_max}",
            ))
        improvement = (
            (cur_obj - plan.objective) / cur_obj
            if np.isfinite(cur_obj) and cur_obj > 0
            else float("inf")
        )
        unchanged = all(
            np.array_equal(self._k.get(n), plan.k[n]) for n in self.sessions
        )
        if unchanged or improvement < self.min_improvement:
            return self._emit(FleetDecision(
                now, "none", k_max, plan, self.allocations(), tuple(hot), cur_obj,
                reason=f"improvement {improvement:.1%} < {self.min_improvement:.0%}",
            ))
        self._apply(plan)
        return self._emit(FleetDecision(
            now, "rebalance", k_max, plan, self.allocations(), tuple(hot), cur_obj,
            reason=f"fleet objective {cur_obj:.4g} -> {plan.objective:.4g}",
        ))

    def _emit(self, d: FleetDecision) -> FleetDecision:
        self.history.append(d)
        if self.on_decision:
            self.on_decision(d)
        return d


# --------------------------------------------------------------------------- #
# Scenario matrix sweeps (DESIGN.md §13)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ScenarioReport:
    """One scenario's outcome after a controlled (or fixed-k) sweep."""

    name: str
    actions: tuple  # scheduler action per tick, in order
    allocations: tuple  # name-keyed allocation in force after each tick
    k_final: dict
    provisioned_total: int  # sum of the final allocation
    optimal_total: int | None  # Program (4)/(6) total at the mean true topology
    deadline_miss_rate: float  # post-warmup windows with est. E[T] > t_max
    drop_rate: float  # post-warmup shed fraction of offered load
    mean_sojourn: float  # batchsim visit-sum E[T] estimate at k_final
    saturated: tuple  # operator names at/above capacity post-warmup
    # Per-tick time series (dict of equal-length lists): "t", "k_total"
    # (allocation in force after the tick = the per-tick provisioned
    # cost), "miss" (post-warmup deadline-miss mask), "sojourn", "warm",
    # and — in proactive mode — "mpc_used" / "confident".  None for an
    # uncontrolled sweep.
    trajectory: dict | None = None

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "actions": list(self.actions),
            "allocations": [dict(a) for a in self.allocations],
            "k_final": dict(self.k_final),
            "provisioned_total": self.provisioned_total,
            "optimal_total": self.optimal_total,
            "deadline_miss_rate": self.deadline_miss_rate,
            "drop_rate": self.drop_rate,
            "mean_sojourn": self.mean_sojourn,
            "saturated": list(self.saturated),
            "trajectory": self.trajectory,
        }


class ScenarioRunner:
    """Sweep a scenario matrix through the full measure -> model ->
    rebalance loop on the vectorized batch simulator (DESIGN.md §13/§14).

    Every ``tick_interval`` of simulated time the whole batch advances one
    window; the window aggregates become ONE stacked
    :class:`~repro.core.measurer.MeasurementBatch` fed to the batched
    controller (``core/controller.py``) — the *identical* decide math the
    live ``DRSScheduler`` shell runs, including the §11 overload
    semantics — and applied decisions change each scenario's allocation
    for the next window.  Per-scenario ``Negotiator`` leases are invoked
    as hooks at the batch boundary between windows.

    When every scenario has a static budget (``negotiated=False``) and
    ``backend="jax"``, the whole sweep — simulate, measure, decide,
    apply, for every tick — compiles to ONE jit program
    (:func:`repro.core.controller.make_fused_loop`); ``fused=False``
    forces the window-at-a-time float64 twin instead.
    ``controlled=False`` freezes ``k`` (pure simulation sweep).

    Reports per scenario: deadline-miss rate, drop rate, and provisioned
    vs Program-(4)/(6)-optimal resources at the trace's mean rate.
    """

    def __init__(
        self,
        scenarios: Sequence,
        *,
        tick_interval: float = 10.0,
        controlled: bool = True,
        backend: str = "numpy",
        interpret: bool = False,
        force_kernel: bool = False,
        fused: bool | None = None,
        fused_decide: bool = False,
        proactive=None,
        mesh=None,
        compact=None,
    ):
        from ..streaming.batchsim import BatchQueueSim
        from ..streaming.scenarios import pack_allocations, pack_scenarios

        self.scenarios = list(scenarios)
        self.tick_interval = tick_interval
        self.controlled = controlled
        self.backend = backend
        self.interpret = interpret
        self.force_kernel = force_kernel
        # The decide-dispatch knob (SchedulerConfig.fused_decide): route
        # the jit decide through kernels/decide_fused — note this is
        # orthogonal to `fused` below, which fuses the *loop* over ticks.
        self.fused_decide = bool(fused_decide)
        # Device mesh for the fused loop (DESIGN.md §16): shard the batch
        # axis across devices.  Only the fused path consumes it — the
        # window-at-a-time twin is a numpy debugging surface.
        self.mesh = mesh
        # Trigger-gated lane compaction (DESIGN.md §18): True or a
        # CompactionConfig turns on the sparse decide — exact memoization
        # on the fused path, the per-lane replay cache on the twin.
        # Output-invisible by construction: decisions stay bitwise equal
        # to the dense run, only the `repriced` diagnostic reveals it.
        self.compact = compact if compact not in (False,) else None
        # Forecast/MPC mode (DESIGN.md §15): True -> default MPCConfig;
        # an MPCConfig customizes predictor/horizon/gate knobs.
        if proactive is True:
            from ..forecast.mpc import MPCConfig

            proactive = MPCConfig()
        self.proactive_cfg = proactive
        self._proactive_ctl = None
        self.arrays = pack_scenarios(self.scenarios)
        self.sim = BatchQueueSim(
            self.arrays, backend=backend, interpret=interpret, force_kernel=force_kernel
        )
        self.k = pack_allocations(self.scenarios, [s.plan_k0() for s in self.scenarios])
        self.static = ctl.ControllerStatic.from_graphs(
            [s.graph for s in self.scenarios],
            speed=[s.speed_vector() for s in self.scenarios],
        )
        self.negotiators = [
            self._negotiator_for(s, self.k[bi, : s.graph.n])
            for bi, s in enumerate(self.scenarios)
        ]
        self._steps_per_tick = max(int(round(self.tick_interval / self.arrays.dt)), 1)
        can_fuse = (
            controlled
            and backend == "jax"
            and all(neg is None for neg in self.negotiators)
            and self.arrays.steps % self._steps_per_tick == 0
        )
        if fused is None:
            fused = can_fuse
        elif fused and not can_fuse:
            # Forcing the fused path past its preconditions would silently
            # change semantics (leases need Python hooks, controlled=False
            # must freeze k, a partial final window would be dropped).
            raise GraphValidationError(
                "fused=True requires controlled=True, backend='jax', no "
                "negotiated scenarios, and a horizon divisible by the tick "
                "interval; use fused=None for the automatic gate"
            )
        self.fused = fused
        if mesh is not None and not fused:
            raise GraphValidationError(
                "mesh= shards the fused loop's batch axis; it has no effect "
                "on the window-at-a-time path (pass fused=True or drop mesh)"
            )
        # Per-scenario decision parameters are static except the budgets,
        # which negotiator leases move between ticks — stack once here,
        # refresh only k_max in _params() (the tick hot loop).
        self._base_params = ctl.ControllerParams.stack(
            [
                SchedulerConfig(
                    k_max=None if neg is not None else s.k_max,
                    t_max=s.t_max,
                    tick_interval=self.tick_interval,
                    allocator=s.allocator,
                    fused_decide=self.fused_decide,
                )
                for s, neg in zip(self.scenarios, self.negotiators)
            ],
            [
                neg.k_max if neg is not None else s.k_max
                for s, neg in zip(self.scenarios, self.negotiators)
            ],
        )
        self.decisions: list[list[SchedulerDecision]] = [[] for _ in self.scenarios]
        self._miss = np.zeros(len(self.scenarios), dtype=np.int64)
        self._windows_warm = 0
        self._fused_result = None
        self._traj: list[dict[str, list]] = [
            {"t": [], "k_total": [], "miss": [], "sojourn": [], "warm": []}
            for _ in self.scenarios
        ]

    def _negotiator_for(self, s, k0: np.ndarray):
        """The scenario zoo's optional machine lease: ``negotiated``
        scenarios draw ``machine_size``-processor machines from a finite
        pool (speed-tagged when the scenario declares machine-class
        factors) instead of holding a static budget."""
        if not s.negotiated:
            return None
        from ..core.negotiator import Machine, Negotiator as _Neg, ResourcePool

        size = max(int(s.machine_size), 1)
        speed = s.speed_vector()
        mean_speed = 1.0 if speed is None else float(np.mean(speed))
        pool = ResourcePool(
            [
                Machine(f"m{i}", size, speed=mean_speed)
                for i in range(-(-s.k_max // size))
            ]
        )
        negotiator = _Neg(pool)
        negotiator.ensure(int(k0.sum()))
        return negotiator

    def _params(self) -> ctl.ControllerParams:
        """Per-scenario decision parameters with the budget re-resolved
        from each negotiator's current lease (the scalar ``_k_max`` rule)."""
        if all(neg is None for neg in self.negotiators):
            return self._base_params
        from dataclasses import replace

        return replace(self._base_params, k_max=np.array(
            [
                neg.k_max if neg is not None else s.k_max
                for s, neg in zip(self.scenarios, self.negotiators)
            ],
            dtype=np.int64,
        ))

    # ------------------------------------------------------------------ #
    def _window_measurement(self, w: dict) -> tuple[MeasurementBatch, np.ndarray]:
        """One stacked synthetic measurement from a window's aggregates.

        The sojourn estimate is NaN for a scenario that admitted no
        external tuples this window (no sojourn is defined; ``NaN >
        t_max`` is False, so idle trace troughs never register deadline
        misses).  ``mu_hat`` carries the reference-class priors — the
        controller applies the machine-class ``speed`` factors on the
        model side, mirroring the sim's scaled service capacity."""
        from ..streaming.batchsim import composed_wait, per_op_service_time, visit_sum_sojourn

        a = self.arrays
        span = w["span"]
        lam_hat = w["offered"] / span
        drop_hat = w["dropped"] / span
        mu_eff = a.mu if a.speed is None else a.mu * a.speed
        admitted = np.maximum(lam_hat - drop_hat, 0.0)
        wait = composed_wait(
            w["q_mean"], admitted, a.dt, span, self.k, a.mu, a.group, a.alpha,
            a.speed, a.ca2, a.cs2,
        )
        svc = per_op_service_time(w["capacity"], mu_eff, a.group)
        lam0 = np.maximum(w["ext_admitted"] / span, 0.0)
        sojourn = visit_sum_sojourn(admitted, wait, svc, lam0)
        return MeasurementBatch.from_rates(
            lam_hat, a.mu, lam0, sojourn, self.sim.now, drop_hat=drop_hat
        ), sojourn

    def _ensure_hooks(self):
        hooks = []
        for neg in self.negotiators:
            if neg is None:
                hooks.append(None)
            else:
                def hook(target: int, _neg=neg) -> int:
                    _neg.ensure(target)
                    return _neg.k_max
                hooks.append(hook)
        return hooks

    def _to_decision(self, bi: int, row: ctl.RowDecision, meas, error) -> SchedulerDecision:
        s = self.scenarios[bi]
        return SchedulerDecision(
            self.sim.now,
            row.action,
            row.k_next.copy(),
            row.k_target,
            s.k_max if error is not None else row.k_max,
            row.et_cur,
            row.et_target,
            float(meas.sojourn_hat[bi]),
            row.plan,
            row.reason,
        )

    def run(self) -> list[ScenarioReport]:
        if self.fused:
            return self._run_fused()
        a = self.arrays
        t_max = np.array(
            [np.nan if s.t_max is None else s.t_max for s in self.scenarios]
        )
        hooks = self._ensure_hooks()
        cstate = None
        if self.controlled and self.compact is not None:
            cstate = ctl.TwinCompactionState.create(
                len(self.scenarios), self.static.n
            )
        pc = None
        if self.controlled and self.proactive_cfg is not None:
            from ..forecast.mpc import ProactiveController

            pc = ProactiveController.create(
                len(self.scenarios), self.static.n, self.proactive_cfg,
                cap_queue=a.cap_queue, span=self._steps_per_tick * a.dt,
            )
            self._proactive_ctl = pc
            for tr in self._traj:
                tr["mpc_used"] = []
                tr["confident"] = []
        while self.sim.step_index < a.steps:
            w = self.sim.step_window(self.k, self._steps_per_tick)
            warm = w["t0"] >= self.scenarios[0].warmup
            if warm:
                self._windows_warm += 1
            meas, sojourn = self._window_measurement(w)
            with np.errstate(invalid="ignore"):
                miss_mask = (sojourn > t_max) & warm
            if warm:
                self._miss += miss_mask.astype(np.int64)
            if self.controlled:
                batch = ctl.tick_batch(
                    meas, self.k, self.static, self._params(), ensure=hooks,
                    proactive=pc, q_backlog=w["q_final"],
                    compact_state=cstate,
                )
                for bi, row in enumerate(batch.rows):
                    s = self.scenarios[bi]
                    self.decisions[bi].append(
                        self._to_decision(bi, row, meas, batch.errors[bi])
                    )
                    if row.applied:
                        self.k[bi, : s.graph.n] = row.k_next
            for bi, s in enumerate(self.scenarios):
                tr = self._traj[bi]
                tr["t"].append(float(self.sim.now))
                tr["k_total"].append(int(self.k[bi, : s.graph.n].sum()))
                tr["miss"].append(bool(miss_mask[bi]))
                tr["sojourn"].append(float(sojourn[bi]))
                tr["warm"].append(bool(warm))
                if pc is not None:
                    tr["mpc_used"].append(bool(pc.mpc_used[bi]))
                    tr["confident"].append(bool(pc.confident[bi]))
        return self.reports()

    def _run_fused(self) -> list[ScenarioReport]:
        """The one-program path: lax.scan over every control window, the
        decide compiled inline (negotiator-free scenarios only)."""
        from ..streaming.batchsim import BatchSimResult

        a = self.arrays
        run, n_ticks = ctl.make_fused_loop(
            a, self.static, self._params(),
            steps_per_tick=self._steps_per_tick,
            warmup_seconds=self.scenarios[0].warmup,
            interpret=self.interpret, force_kernel=self.force_kernel,
            proactive=self.proactive_cfg, mesh=self.mesh,
            compact=self.compact,
        )
        out = {key: np.asarray(v) for key, v in run(self.k).items()}
        self.k = out["k_final"].astype(np.int64)
        self._windows_warm = int(out["warm_windows"])
        self._miss = np.where(
            [s.t_max is not None for s in self.scenarios], out["miss"], 0
        ).astype(np.int64)
        if self.proactive_cfg is not None:
            for tr in self._traj:
                tr["mpc_used"] = []
                tr["confident"] = []
        t_max_arr = np.array(
            [np.nan if s.t_max is None else s.t_max for s in self.scenarios]
        )
        for ti in range(n_ticks):
            now = (ti + 1) * self._steps_per_tick * a.dt
            warm = (ti * self._steps_per_tick * a.dt) >= self.scenarios[0].warmup
            for bi, s in enumerate(self.scenarios):
                action = ctl.ACTIONS[int(out["codes"][ti, bi])]
                k_row = out["k"][ti, bi, : s.graph.n].astype(np.int64)
                # k_target only when the jit decide actually applied an
                # allocation (the twin's rule: an infeasible "overloaded"
                # row proposes nothing).
                applied = bool(out["applied"][ti, bi])
                self.decisions[bi].append(SchedulerDecision(
                    now, action, k_row, k_row if applied else None, s.k_max,
                    float(out["et_cur"][ti, bi]), float(out["et_target"][ti, bi]),
                    float(out["sojourn"][ti, bi]),
                    reason="fused jit decide",
                ))
                tr = self._traj[bi]
                soj = float(out["sojourn"][ti, bi])
                with np.errstate(invalid="ignore"):
                    missed = bool((soj > t_max_arr[bi]) and warm)
                tr["t"].append(now)
                tr["k_total"].append(int(k_row.sum()))
                tr["miss"].append(missed)
                tr["sojourn"].append(soj)
                tr["warm"].append(bool(warm))
                if self.proactive_cfg is not None:
                    tr["mpc_used"].append(bool(out["mpc_used"][ti, bi]))
                    tr["confident"].append(bool(out["confident"][ti, bi]))
        warm_steps = max(a.steps - a.warmup_steps, 0)
        self._fused_result = BatchSimResult(
            offered=out["offered"], served=out["served"], dropped=out["dropped"],
            ext_admitted=out["ext_admitted"], ext_offered=out["ext_offered"],
            q_final=out["q_final"], q_mean=out["q_int"] / max(warm_steps, 1),
            max_backlog=out["q_max"], span=warm_steps * a.dt, dt=a.dt,
        )
        return self.reports()

    def reports(self) -> list[ScenarioReport]:
        from ..core.allocator import InsufficientResourcesError, allocate
        from ..core.jackson import UnstableTopologyError

        res = self._fused_result if self._fused_result is not None else self.sim.result()
        a = self.arrays
        sojourns = res.sojourn(self.k, a.mu, a.group, a.alpha, a.speed,
                               ca2=a.ca2, cs2=a.cs2)
        sat = res.saturated(self.k, a.mu, a.group, a.alpha, a.speed)
        out = []
        for bi, s in enumerate(self.scenarios):
            n = s.graph.n
            try:
                optimal = allocate(s.mean_topology(), k_max=s.k_max, t_max=s.t_max).total
            except (InsufficientResourcesError, UnstableTopologyError):
                optimal = None
            offered = float(res.offered[bi, :n].sum())
            dropped = float(res.dropped[bi, :n].sum())
            decs = self.decisions[bi]
            out.append(
                ScenarioReport(
                    name=s.name,
                    actions=tuple(d.action for d in decs),
                    allocations=tuple(
                        dict(zip(s.graph.names, map(int, d.k_current))) for d in decs
                    ),
                    k_final=dict(zip(s.graph.names, map(int, self.k[bi, :n]))),
                    provisioned_total=int(self.k[bi, :n].sum()),
                    optimal_total=None if optimal is None else int(optimal),
                    deadline_miss_rate=(
                        float(self._miss[bi] / self._windows_warm)
                        if (self._windows_warm and s.t_max is not None)
                        else float("nan")
                    ),
                    drop_rate=dropped / max(offered, 1e-300),
                    mean_sojourn=float(sojourns[bi]),
                    saturated=tuple(
                        nm for i, nm in enumerate(s.graph.names) if sat[bi, i]
                    ),
                    trajectory=self._traj[bi] if self._traj[bi]["t"] else None,
                )
            )
        return out
