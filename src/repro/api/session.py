"""DRSSession — one AppGraph bound to one backend (DESIGN.md §3).

A session owns the whole measure -> model -> rebalance loop that every
call site used to assemble by hand: scheduler construction (names, routing
matrix, scaling lists all derived from the graph), measurer wiring,
negotiator hookup, tick driving, and decision application.  The same
``AppGraph`` binds unmodified to:

* :class:`EngineBackend` — the live micro-batch ``StreamEngine`` (worker
  threads, real wall-clock measurements);
* :class:`DESBackend` — the discrete-event ``NetworkSimulator`` (simulated
  time, statistically tight model validation), including the group-scaled
  chip-gang conversion the serving router used to hand-roll.

Typical use::

    session = graph.bind("engine", config=SchedulerConfig(k_max=6))
    session.start({"extract": 1, "match": 2, "aggregate": 1})
    ...inject tuples...
    session.tick()          # pulls measurements, decides, applies rescale
    session.drain(); session.stop()

    report = graph.bind("des", seed=3, horizon=2000.0).simulate(k)
"""

from __future__ import annotations

import time
from typing import Any, Mapping, Sequence

import numpy as np

from ..core.allocator import AllocationResult, allocate
from ..core.jackson import Topology
from ..core.measurer import Measurer
from ..core.negotiator import Negotiator
from ..core.rebalance import ExecutableCache, RebalanceCostModel
from ..core.scheduler import DRSScheduler, SchedulerConfig, SchedulerDecision
from .graph import AppGraph, GraphValidationError

__all__ = ["DRSSession", "EngineBackend", "DESBackend"]


def _group_effective_services(top: Topology, k_vec: np.ndarray):
    """Convert group-scaled operators for the DES: one fast server at
    ``mu * k * eff(k)`` instead of k parallel servers (mirrors
    ``OperatorSpec.scaling == "group"``; DESIGN.md §2)."""
    from ..streaming.des import ServiceProcess

    services, k_eff = [], []
    for i, op in enumerate(top.operators):
        k_i = int(k_vec[i])
        if op.scaling == "group":
            eff = 1.0 / (1.0 + op.group_alpha * (k_i - 1))
            services.append(ServiceProcess(rate=op.mu * k_i * eff))
            k_eff.append(1)
        else:
            services.append(ServiceProcess(rate=op.mu))
            k_eff.append(k_i)
    return services, np.asarray(k_eff, dtype=np.int64)


class EngineBackend:
    """Live StreamEngine behind the backend protocol.

    ``queue_capacity`` bounds every operator queue (``None`` = unbounded)
    and ``overload_policy`` (``"block"`` | ``"shed-newest"`` |
    ``"shed-oldest"``, or an :class:`~repro.streaming.overload.OverloadPolicy`)
    decides what happens when one fills — DESIGN.md §11.
    """

    kind = "engine"

    def __init__(
        self,
        graph: AppGraph,
        *,
        queue_capacity: int | None = 10_000,
        overload_policy: Any = "block",
    ):
        from ..streaming.engine import Operator, StreamEngine

        missing = [op.name for op in graph.ops if op.fn is None]
        if missing:
            raise GraphValidationError(
                f"engine backend needs a compute fn on every operator; "
                f"missing: {missing} (attach with AppGraph.with_fns)"
            )
        self.graph = graph
        self.engine = StreamEngine(
            [Operator(op.name, op.fn) for op in graph.ops],
            queue_capacity=queue_capacity,
            overload_policy=overload_policy,
        )
        self.measurer: Measurer = self.engine.measurer

    def start(self, k: Mapping[str, int]) -> None:
        self.engine.start(dict(k))

    def apply_allocation(self, k: Mapping[str, int]) -> None:
        self.engine.scale_to(dict(k))

    def allocation(self) -> dict[str, int]:
        return self.engine.k()

    def inject(
        self, payload: Any, source: str | None = None, *, timeout: float | None = None
    ) -> int | None:
        if source is None:
            srcs = self.graph.source_names
            if len(srcs) != 1:
                raise GraphValidationError(
                    f"graph has {len(srcs)} sources {srcs}; pass source= explicitly"
                )
            source = srcs[0]
        return self.engine.inject(source, payload, timeout=timeout)

    def drain(self, timeout: float = 10.0) -> bool:
        return self.engine.drain(timeout=timeout)

    def stop(self) -> None:
        self.engine.stop()

    @property
    def completed_sojourns(self) -> list[float]:
        return self.engine.completed_sojourns

    def drop_counts(self) -> dict[str, int]:
        """Cumulative tuples shed per operator (overload policy drops)."""
        return self.engine.drop_counts()


class DESBackend:
    """NetworkSimulator behind the backend protocol (simulated time)."""

    kind = "des"

    def __init__(
        self,
        graph: AppGraph,
        *,
        seed: int = 0,
        horizon: float = 120.0,
        warmup: float = 10.0,
        network_delay: float = 0.0,
        arrival_kind: str | None = None,
        arrival_kw: Mapping[str, float] | None = None,
        measurer: Measurer | None = None,
        queue_capacity: int | None = None,
        overload_policy: Any = "shed-newest",
    ):
        self.graph = graph
        self.seed = seed
        self.horizon = horizon
        self.warmup = warmup
        self.network_delay = network_delay
        self.arrival_kind = arrival_kind or graph.arrival_kind
        # Extra ArrivalProcess parameters for every source — required for
        # the modulated kinds, e.g. bind("des", arrival_kind="mmpp",
        # arrival_kw={"rate2": 50.0, "switch01": 0.2, "switch10": 0.8}) or
        # arrival_kind="burst" with rate2/burst_every/burst_length.
        self.arrival_kw = dict(arrival_kw or {})
        self.measurer = measurer
        self.queue_capacity = queue_capacity
        self.overload_policy = overload_policy

    # The DES is batch-simulated, not tick-driven: the live control-loop
    # protocol fails with a pointer to simulate() instead of AttributeError.
    def _not_live(self, method: str):
        raise GraphValidationError(
            f"DES backend is batch-simulated; {method}() is only available on "
            "the engine backend — use simulate(k, rebalance_to=, rebalance_at=) "
            "to run allocation changes in simulated time"
        )

    def start(self, k):
        self._not_live("start")

    def apply_allocation(self, k):
        self._not_live("apply_allocation")

    def allocation(self):
        self._not_live("allocation")

    def inject(self, payload, source=None):
        self._not_live("inject")

    def drain(self, timeout: float = 10.0):
        self._not_live("drain")

    def stop(self):
        self._not_live("stop")

    @property
    def completed_sojourns(self):
        self._not_live("completed_sojourns")

    def simulator(
        self,
        k: Mapping[str, int] | Sequence[int] | np.ndarray,
        *,
        seed: int | None = None,
        horizon: float | None = None,
        warmup: float | None = None,
    ):
        """Build a NetworkSimulator for allocation ``k`` (group ops are
        collapsed to single effective servers)."""
        from ..streaming.des import ArrivalProcess, NetworkSimulator, ServiceProcess, SimConfig

        graph = self.graph
        top = graph.topology()
        k_vec = graph.k_vector(k)
        services, k_eff = _group_effective_services(top, k_vec)
        # apply each op's declared DES service distribution, keeping the
        # (possibly group-effective) rate the helper computed
        for i, op in enumerate(graph.ops):
            if op.service_kind != "exponential" or op.service_cv != 1.0:
                services[i] = ServiceProcess(
                    rate=services[i].rate, kind=op.service_kind, cv=op.service_cv
                )
        arrivals = [
            ArrivalProcess(rate=float(top.lam0[i]), kind=self.arrival_kind,
                           **self.arrival_kw)
            for i in range(top.n)
        ]
        cfg = SimConfig(
            seed=self.seed if seed is None else seed,
            horizon=self.horizon if horizon is None else horizon,
            warmup=self.warmup if warmup is None else warmup,
            network_delay=self.network_delay,
            queue_capacity=self.queue_capacity,
            overload_policy=self.overload_policy,
        )
        return NetworkSimulator(
            top, k_eff, config=cfg, arrivals=arrivals, services=services,
            measurer=self.measurer,
        )

    def simulate(
        self,
        k: Mapping[str, int] | Sequence[int] | np.ndarray,
        *,
        rebalance_to: Mapping[str, int] | Sequence[int] | np.ndarray | None = None,
        rebalance_at: float | None = None,
        pause: float = 1.0,
        seed: int | None = None,
        horizon: float | None = None,
        warmup: float | None = None,
    ):
        """Run the DES under ``k``; optionally switch to ``rebalance_to``
        at ``rebalance_at`` (with a processing pause) mid-run."""
        graph = self.graph
        sim = self.simulator(k, seed=seed, horizon=horizon, warmup=warmup)
        if rebalance_to is not None and rebalance_at is not None:
            top = sim.top
            k2 = graph.k_vector(rebalance_to)
            services2, k2_eff = _group_effective_services(top, k2)
            for i, op in enumerate(top.operators):
                if op.scaling == "group":
                    sim.schedule_rate_change(rebalance_at, i, services2[i].rate)
            sim.rebalance_at(rebalance_at, k2_eff, pause=pause)
        return sim.run()


_BACKENDS = {"engine": EngineBackend, "des": DESBackend}


class DRSSession:
    """One AppGraph + one backend + the DRS control loop.

    Construction wires the scheduler from the graph (names, routing matrix,
    scaling modes — no positional hand-syncing) and the backend's measurer.
    ``tick()`` pulls, models, decides, and *applies* the decision to the
    backend; ``plan()``/``topology()`` expose the model side directly.
    """

    def __init__(
        self,
        graph: AppGraph,
        backend: EngineBackend | DESBackend,
        *,
        config: SchedulerConfig | None = None,
        negotiator: Negotiator | None = None,
        cost_model: RebalanceCostModel | None = None,
        executable_cache: ExecutableCache | None = None,
        on_decision=None,
    ):
        self.graph = graph
        self.backend = backend
        self.config = config or SchedulerConfig()
        self.negotiator = negotiator
        self.cost_model = cost_model
        self.executable_cache = executable_cache
        self.on_decision = on_decision
        self.scheduler: DRSScheduler | None = None

    # Construction ------------------------------------------------------ #
    @classmethod
    def bind(cls, graph: AppGraph, backend: Any = "des", **kwargs) -> "DRSSession":
        session_keys = ("config", "negotiator", "cost_model", "executable_cache", "on_decision")
        session_kw = {k: kwargs.pop(k) for k in session_keys if k in kwargs}
        if isinstance(backend, str):
            try:
                backend_cls = _BACKENDS[backend]
            except KeyError:
                raise GraphValidationError(
                    f"unknown backend {backend!r}; expected one of {sorted(_BACKENDS)} "
                    "or a backend instance"
                ) from None
            backend = backend_cls(graph, **kwargs)
        elif kwargs:
            raise GraphValidationError(
                f"unexpected options for pre-built backend: {sorted(kwargs)}"
            )
        return cls(graph, backend, **session_kw)

    # Model side --------------------------------------------------------- #
    def topology(self, mu: Mapping[str, float] | None = None) -> Topology:
        return self.graph.topology(mu)

    def plan(
        self, *, k_max: int | None = None, t_max: float | None = None
    ) -> AllocationResult:
        """Program (4)/(6) on the declared graph (priors, not measurements)."""
        k_max = k_max if k_max is not None else self.config.k_max
        t_max = t_max if t_max is not None else self.config.t_max
        if k_max is None and t_max is None:
            raise GraphValidationError(
                "plan() needs a budget: pass k_max= or t_max=, or bind with "
                "config=SchedulerConfig(k_max=..., t_max=...)"
            )
        return allocate(self.topology(), k_max=k_max, t_max=t_max)

    def split(self, alloc: AllocationResult | Sequence[int] | np.ndarray) -> dict[str, int]:
        k = alloc.k if isinstance(alloc, AllocationResult) else alloc
        return self.graph.k_dict(k)

    # Control loop ------------------------------------------------------- #
    def _build_scheduler(self, k0: np.ndarray) -> DRSScheduler:
        scaling, group_alpha = self.graph.scaling_lists()
        return DRSScheduler(
            self.graph.names,
            self.graph.routing_matrix(),
            k0,
            self.config,
            measurer=self.backend.measurer,
            negotiator=self.negotiator,
            cost_model=self.cost_model,
            executable_cache=self.executable_cache,
            scaling=scaling,
            group_alpha=group_alpha,
            on_decision=self.on_decision,
        )

    def start(
        self, k0: Mapping[str, int] | Sequence[int] | np.ndarray | None = None
    ) -> dict[str, int]:
        """Start the backend under ``k0`` (default: the planned optimum)
        and arm the scheduler.  Returns the starting allocation."""
        if k0 is None:
            k0_vec = self.plan().k
        else:
            k0_vec = self.graph.k_vector(k0)
        self.scheduler = self._build_scheduler(k0_vec.copy())
        self.backend.start(self.graph.k_dict(k0_vec))
        # Anchor the measurer's pull clock so the first tick has a window.
        self.backend.measurer.pull(time.time())
        return self.graph.k_dict(k0_vec)

    def tick(self, now: float | None = None) -> SchedulerDecision:
        """One scheduler tick: pull -> model -> decide -> apply."""
        if self.scheduler is None:
            raise RuntimeError("session not started; call start() first")
        decision = self.scheduler.tick(now)
        if decision.action in ("rebalance", "scale_out", "scale_in", "overloaded"):
            # "overloaded" with no feasible target keeps the current k.
            if decision.k_target is not None:
                self.backend.apply_allocation(self.graph.k_dict(decision.k_target))
        return decision

    @property
    def allocation(self) -> dict[str, int]:
        if self.scheduler is not None:
            return self.graph.k_dict(self.scheduler.k_current)
        return self.backend.allocation()

    @property
    def history(self) -> list[SchedulerDecision]:
        return [] if self.scheduler is None else self.scheduler.history

    # Backend pass-throughs ---------------------------------------------- #
    def inject(
        self, payload: Any, source: str | None = None, *, timeout: float | None = None
    ) -> int | None:
        """Inject an external tuple.  Under a bounded queue with the
        ``block`` policy this backpressures the caller; returns ``None``
        when the tuple was shed at admission (DESIGN.md §11)."""
        if isinstance(self.backend, EngineBackend):
            return self.backend.inject(payload, source=source, timeout=timeout)
        return self.backend.inject(payload, source=source)

    def drain(self, timeout: float = 10.0) -> bool:
        return self.backend.drain(timeout=timeout)

    def stop(self) -> None:
        self.backend.stop()

    @property
    def completed_sojourns(self) -> list[float]:
        return self.backend.completed_sojourns

    def drop_counts(self) -> dict[str, int]:
        """Cumulative tuples shed per operator (engine backend)."""
        if not isinstance(self.backend, EngineBackend):
            raise GraphValidationError(
                "drop_counts() needs the engine backend; the DES reports "
                "drops on its SimResult (per_op_dropped / per_op_drop_rate)"
            )
        return self.backend.drop_counts()

    def simulate(self, k=None, **kwargs):
        """DES-mode: simulate allocation ``k`` (default: planned optimum)."""
        if not isinstance(self.backend, DESBackend):
            raise GraphValidationError(
                f"simulate() needs a DES backend, have {self.backend.kind!r}"
            )
        if k is None:
            k = self.plan().k
        return self.backend.simulate(k, **kwargs)

    def run(self, k=None, **kwargs):
        """One-call entry point: DES -> :meth:`simulate`; engine ->
        :meth:`start` (then inject/tick/drain at your own pace)."""
        if isinstance(self.backend, DESBackend):
            return self.simulate(k, **kwargs)
        return self.start(k)
