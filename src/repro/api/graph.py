"""Declarative application graphs — the single topology surface (DESIGN.md §1).

Every DRS consumer used to declare its operator network a different way: a
hand-built numpy routing matrix for :class:`~repro.core.jackson.Topology`,
an ``Operator`` list for the live :class:`~repro.streaming.engine.StreamEngine`,
a ``SimConfig`` + parallel arrival/service lists for the DES, and bespoke
wiring inside the serving model — with the scheduler constructed from
positionally hand-synced name/routing/k lists at every call site.

:class:`AppGraph` collapses those surfaces into one typed declaration:

* :class:`OpDef` — one operator: name, service-rate prior, optional compute
  fn (for the live engine), scaling mode (``replica`` M/M/k or ``group``
  chip-gang, see DESIGN.md §2), and DES service-time distribution.
* :class:`Edge` — one directed edge with an expected multiplicity.  ``> 1``
  models fan-out (a feature extractor emitting many features per frame);
  ``src == dst`` with multiplicity ``< 1`` models a leaking self-loop (the
  FPD detector, autoregressive decode).

The graph validates at construction — unknown endpoints, duplicate names,
negative rates, and non-leaking loops (spectral radius >= 1) all fail
immediately with a precise error — and compiles to the core primitives:
routing matrix, external-arrival vector, name/index maps, and a
:class:`~repro.core.jackson.Topology` for the performance model.  Binding
a backend (:meth:`AppGraph.bind`) yields a
:class:`~repro.api.session.DRSSession` that owns the whole
measure -> model -> rebalance loop.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from ..core.jackson import OperatorSpec, Topology, UnstableTopologyError

__all__ = ["OpDef", "Edge", "AppGraph", "GraphValidationError"]


class GraphValidationError(ValueError):
    """The graph declaration is malformed (bad names, edges, or rates)."""


@dataclass(frozen=True)
class OpDef:
    """One operator in an application graph.

    ``mu`` is the per-processor service-rate *prior* (tuples/sec); the
    measurer corrects it online.  ``fn`` is the live-engine compute:
    ``fn(payload) -> list[(downstream_name, payload)]`` (may be ``None``
    for model-only / DES graphs).  ``scaling`` selects how k processors
    compose — ``"replica"`` (k independent servers, exact M/M/k) or
    ``"group"`` (one gang of k chips at ``mu * k * eff(k)``, DESIGN.md §2).
    ``service_kind``/``service_cv`` choose the DES service-time
    distribution used when the graph is bound to the simulator.
    """

    name: str
    mu: float
    fn: Callable[[Any], list[tuple[str, Any]]] | None = None
    scaling: str = "replica"
    group_alpha: float = 0.0
    min_k: int = 1
    max_k: int = 1 << 30
    service_kind: str = "exponential"
    service_cv: float = 1.0

    def spec(self, mu: float | None = None) -> OperatorSpec:
        """Compile to the core model's operator description."""
        return OperatorSpec(
            name=self.name,
            mu=self.mu if mu is None else mu,
            scaling=self.scaling,
            group_alpha=self.group_alpha,
            min_k=self.min_k,
            max_k=self.max_k,
        )


@dataclass(frozen=True)
class Edge:
    """Directed edge ``src -> dst`` with expected multiplicity.

    ``multiplicity`` is the expected number of tuples delivered to ``dst``
    per tuple completed at ``src`` — a probability for routing splits, or
    > 1 for fan-out.  A self-loop (``src == dst``) must keep the routing
    matrix's spectral radius below 1 (it has to leak).
    """

    src: str
    dst: str
    multiplicity: float = 1.0


class AppGraph:
    """A validated operator network: ops + edges + external sources.

    One ``AppGraph`` is the single source of truth for every backend: the
    performance model (:meth:`topology`), the live engine, the DES, and
    the scheduler all derive their wiring from it — no more parallel
    name/routing/k lists.

    Parameters
    ----------
    ops:      operator definitions (order fixes the model's index space).
    edges:    typed edge declarations.
    sources:  mapping ``op name -> external arrival rate`` (lam0).
    arrival_kind: DES inter-arrival distribution for the sources
              (``exponential`` | ``uniform`` | ``deterministic``).
    validate_stability: check spectral radius < 1 at construction
              (disable only for deliberately-unstable experiments).
    """

    def __init__(
        self,
        ops: Sequence[OpDef],
        edges: Sequence[Edge] = (),
        sources: Mapping[str, float] | None = None,
        *,
        arrival_kind: str = "exponential",
        validate_stability: bool = True,
    ):
        self.ops: tuple[OpDef, ...] = tuple(ops)
        self.edges: tuple[Edge, ...] = tuple(edges)
        self.arrival_kind = arrival_kind
        self.validate_stability = validate_stability
        if not self.ops:
            raise GraphValidationError("graph needs at least one operator")
        self.names: list[str] = [op.name for op in self.ops]
        if len(set(self.names)) != len(self.names):
            dupes = sorted({n for n in self.names if self.names.count(n) > 1})
            raise GraphValidationError(f"duplicate operator names: {dupes}")
        self.index: dict[str, int] = {n: i for i, n in enumerate(self.names)}
        for op in self.ops:
            if op.mu <= 0:
                raise GraphValidationError(
                    f"operator {op.name!r}: service rate mu must be > 0, got {op.mu}"
                )
            if op.scaling not in ("replica", "group"):
                raise GraphValidationError(
                    f"operator {op.name!r}: unknown scaling {op.scaling!r}"
                )

        n = len(self.ops)
        self._routing = np.zeros((n, n), dtype=np.float64)
        for e in self.edges:
            for endpoint in (e.src, e.dst):
                if endpoint not in self.index:
                    raise GraphValidationError(
                        f"edge {e.src!r} -> {e.dst!r}: unknown operator {endpoint!r}"
                    )
            if e.multiplicity <= 0:
                raise GraphValidationError(
                    f"edge {e.src!r} -> {e.dst!r}: multiplicity must be > 0, "
                    f"got {e.multiplicity}"
                )
            i, j = self.index[e.src], self.index[e.dst]
            if self._routing[i, j] != 0.0:
                raise GraphValidationError(
                    f"duplicate edge {e.src!r} -> {e.dst!r}"
                )
            self._routing[i, j] = e.multiplicity

        self._lam0 = np.zeros(n, dtype=np.float64)
        for name, rate in (sources or {}).items():
            if name not in self.index:
                raise GraphValidationError(f"unknown source operator {name!r}")
            if rate < 0:
                raise GraphValidationError(
                    f"source {name!r}: arrival rate must be >= 0, got {rate}"
                )
            self._lam0[self.index[name]] = rate

        if validate_stability:
            radius = self.spectral_radius
            if radius >= 1.0 - 1e-12:
                loops = [e for e in self.edges if e.src == e.dst]
                hint = (
                    f" (self-loops: {[(e.src, e.multiplicity) for e in loops]})"
                    if loops
                    else ""
                )
                raise UnstableTopologyError(
                    f"routing spectral radius {radius:.6f} >= 1; every cycle "
                    f"must leak probability for the open network to be stable"
                    + hint
                )

    # Introspection ----------------------------------------------------- #
    @property
    def n(self) -> int:
        return len(self.ops)

    @property
    def spectral_radius(self) -> float:
        try:
            return float(max(abs(np.linalg.eigvals(self._routing))))
        except np.linalg.LinAlgError:  # pragma: no cover - defensive
            return float("inf")

    @property
    def source_names(self) -> list[str]:
        return [n for n, r in zip(self.names, self._lam0) if r > 0]

    def op(self, name: str) -> OpDef:
        return self.ops[self.index[name]]

    def routing_matrix(self) -> np.ndarray:
        """The derived routing matrix P (``P[i][j]`` = multiplicity i->j)."""
        return self._routing.copy()

    def lam0_vector(self) -> np.ndarray:
        """External arrival rates in operator-index order."""
        return self._lam0.copy()

    # Name-keyed <-> index-ordered conversion --------------------------- #
    def k_vector(self, k: Mapping[str, int] | Sequence[int] | np.ndarray) -> np.ndarray:
        """Allocation as an index-ordered int vector (accepts dict or seq)."""
        if isinstance(k, Mapping):
            missing = [n for n in self.names if n not in k]
            if missing:
                raise GraphValidationError(f"allocation missing operators: {missing}")
            extra = sorted(set(k) - set(self.names))
            if extra:
                raise GraphValidationError(f"allocation has unknown operators: {extra}")
            return np.array([int(k[n]) for n in self.names], dtype=np.int64)
        vec = np.asarray(k, dtype=np.int64)
        if vec.shape != (self.n,):
            raise GraphValidationError(
                f"allocation must have shape ({self.n},), got {vec.shape}"
            )
        return vec.copy()

    def k_dict(self, k: Sequence[int] | np.ndarray | Mapping[str, int]) -> dict[str, int]:
        """Allocation as a name-keyed dict."""
        return dict(zip(self.names, self.k_vector(k).tolist()))

    # Compilation ------------------------------------------------------- #
    def topology(self, mu: Mapping[str, float] | None = None) -> Topology:
        """Compile to the core Jackson-network model.

        ``mu`` optionally overrides per-operator service-rate priors by
        name (e.g. with measured values).
        """
        overrides = dict(mu or {})
        unknown = set(overrides) - set(self.names)
        if unknown:
            raise GraphValidationError(f"mu overrides for unknown operators: {sorted(unknown)}")
        specs = [op.spec(overrides.get(op.name)) for op in self.ops]
        return Topology(specs, self._lam0.copy(), self._routing.copy())

    def scaling_lists(self) -> tuple[list[str], list[float]]:
        """(scaling mode, group_alpha) per operator, index-ordered — the
        scheduler's view of how processors compose."""
        return [op.scaling for op in self.ops], [op.group_alpha for op in self.ops]

    # Derivation -------------------------------------------------------- #
    def with_sources(self, sources: Mapping[str, float]) -> "AppGraph":
        """Same graph, different external arrival rates (e.g. a new lam0)."""
        return AppGraph(
            self.ops, self.edges, sources, arrival_kind=self.arrival_kind,
            validate_stability=self.validate_stability,
        )

    def with_fns(self, fns: Mapping[str, Callable]) -> "AppGraph":
        """Same graph with compute fns attached (model-only -> runnable)."""
        unknown = set(fns) - set(self.names)
        if unknown:
            raise GraphValidationError(f"fns for unknown operators: {sorted(unknown)}")
        ops = [
            replace(op, fn=fns.get(op.name, op.fn)) for op in self.ops
        ]
        return AppGraph(
            ops, self.edges, dict(zip(self.names, self._lam0.tolist())),
            arrival_kind=self.arrival_kind,
            validate_stability=self.validate_stability,
        )

    # Binding ----------------------------------------------------------- #
    def bind(self, backend: Any = "des", **kwargs):
        """Bind this graph to a backend and get a :class:`DRSSession`.

        ``backend`` is ``"engine"`` (live StreamEngine), ``"des"``
        (NetworkSimulator), or an already-constructed backend object.
        Session-level options (``config=SchedulerConfig(...)``,
        ``negotiator=...``) and backend options (``seed=``, ``horizon=``,
        ``queue_capacity=``, ...) are passed through ``kwargs``.
        """
        from .session import DRSSession  # local import: session imports backends

        return DRSSession.bind(self, backend, **kwargs)

    # Convenience constructors ------------------------------------------ #
    @staticmethod
    def chain(
        names_mus: Sequence[tuple[str, float]],
        lam0: float,
        *,
        arrival_kind: str = "exponential",
    ) -> "AppGraph":
        """A linear chain: external tuples enter op0, op_i feeds op_{i+1}
        (the VLD shape) — mirrors ``Topology.chain`` declaratively."""
        ops = [OpDef(name=nm, mu=mu) for nm, mu in names_mus]
        edges = [
            Edge(names_mus[i][0], names_mus[i + 1][0])
            for i in range(len(names_mus) - 1)
        ]
        return AppGraph(
            ops, edges, {names_mus[0][0]: lam0}, arrival_kind=arrival_kind
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"AppGraph(ops={self.names}, edges={len(self.edges)}, "
            f"sources={ {n: float(self._lam0[self.index[n]]) for n in self.source_names} })"
        )
