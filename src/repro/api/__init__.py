"""repro.api — the declarative AppGraph + DRSSession surface (DESIGN.md).

Declare an application once as a typed graph; compile it to the Jackson
performance model; bind it to any backend (live engine, DES, serving) and
drive the DRS measure -> model -> rebalance loop through one facade::

    from repro.api import AppGraph, Edge, OpDef, SchedulerConfig

    graph = AppGraph(
        [OpDef("extract", mu=2.0, fn=...), OpDef("match", mu=5.0, fn=...)],
        [Edge("extract", "match")],
        sources={"extract": 13.0},
    )
    session = graph.bind("engine", config=SchedulerConfig(k_max=22))

``core.*`` primitives stay importable for backward compatibility; new code
should declare topologies through this package.
"""

from ..core.allocator import AllocationResult, InsufficientResourcesError
from ..core.jackson import Topology, UnstableTopologyError
from ..core.planner import FleetPlan, FleetPlanner, Tenant
from ..core.scheduler import SchedulerConfig, SchedulerDecision
from .graph import AppGraph, Edge, GraphValidationError, OpDef
from .session import (
    DESBackend,
    DRSSession,
    EngineBackend,
    FleetDecision,
    FleetSession,
    ScenarioReport,
    ScenarioRunner,
)

__all__ = [
    "AppGraph",
    "Edge",
    "OpDef",
    "GraphValidationError",
    "DRSSession",
    "EngineBackend",
    "DESBackend",
    "FleetSession",
    "FleetDecision",
    "ScenarioRunner",
    "ScenarioReport",
    "FleetPlan",
    "FleetPlanner",
    "Tenant",
    "SchedulerConfig",
    "SchedulerDecision",
    "AllocationResult",
    "InsufficientResourcesError",
    "Topology",
    "UnstableTopologyError",
]
