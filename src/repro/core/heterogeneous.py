"""Heterogeneous-processor extension (paper §III-A: "the proposed models
and algorithms can also support settings with heterogeneous processors").

The cloud pool offers processors in speed classes (e.g. older/newer TPU
generations, big/little host cores).  A processor of speed s serves at
s * mu_i on operator i.  Two model regimes:

* **M/M/k-equivalent** (used here): an operator holding processors with
  speeds {s_1..s_k} is approximated as k homogeneous servers at the
  MEAN speed — exact when speeds within one operator are equal, and a
  standard approximation otherwise (heterogeneous M/M/k has no closed
  form).  To keep the approximation tight the allocator assigns speeds
  GREEDILY: each new processor drawn for an operator is the fastest
  remaining, so operators tend to hold contiguous speed bands.

The greedy allocation remains optimal per-step by the same convexity
argument as Theorem 1 *given* the fastest-first draw order (each step
adds the largest available marginal benefit over both operators and
processor classes); a global optimality proof does not carry over —
tests compare against brute force on small instances and show the gap
is zero or negligible.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .allocator import InsufficientResourcesError
from .erlang import expected_sojourn
from .jackson import Topology

__all__ = ["SpeedPool", "HeterogeneousAllocation", "assign_heterogeneous"]


@dataclass(frozen=True)
class SpeedPool:
    """Inventory of processors by speed class, e.g. {1.0: 16, 0.5: 8}."""

    counts: tuple[tuple[float, int], ...]  # ((speed, n), ...) fastest first

    @staticmethod
    def of(d: dict[float, int]) -> "SpeedPool":
        return SpeedPool(tuple(sorted(d.items(), reverse=True)))

    @property
    def total(self) -> int:
        return sum(n for _, n in self.counts)

    def draws(self) -> list[float]:
        """All speeds, fastest first."""
        out: list[float] = []
        for s, n in self.counts:
            out.extend([s] * n)
        return out


@dataclass
class HeterogeneousAllocation:
    speeds: list[list[float]]  # per-operator assigned speeds
    expected_sojourn: float

    @property
    def k(self) -> np.ndarray:
        return np.array([len(s) for s in self.speeds], dtype=np.int64)

    def effective_mu(self, base_mu: list[float]) -> list[float]:
        return [
            base_mu[i] * (float(np.mean(s)) if s else 1.0)
            for i, s in enumerate(self.speeds)
        ]


def _op_sojourn(op_mu: float, speeds: list[float], lam: float) -> float:
    """E[T_i] under the mean-speed M/M/k approximation."""
    k = len(speeds)
    if k == 0:
        return math.inf
    mu_eff = op_mu * float(np.mean(speeds))
    return expected_sojourn(k, lam, mu_eff)


def assign_heterogeneous(
    top: Topology, pool: SpeedPool
) -> HeterogeneousAllocation:
    """Greedy Algorithm-1 analogue drawing processors fastest-first.

    Initialisation mirrors Algorithm 1 lines 1-4: give each operator
    fastest-remaining processors until it is stable; raise
    InsufficientResourcesError if the pool runs dry first.  Then spend the
    remainder by maximum marginal benefit (delta recomputed per step with
    the next available speed).
    """
    lam = top.arrival_rates
    draws = pool.draws()  # fastest first
    speeds: list[list[float]] = [[] for _ in range(top.n)]

    # stabilisation: repeatedly give the fastest remaining processor to the
    # operator whose capacity deficit costs the most processor-equivalents
    # (deficit / (mu_i * s_next)) — the aggregator's small mu-relative
    # deficit never outbids the heavy bolts for the fast units.
    def deficit(i: int, s_next: float) -> float:
        cap = top.operators[i].mu * sum(speeds[i])
        return (lam[i] - cap) / (top.operators[i].mu * s_next)

    while True:
        if all(deficit(i, 1.0) < 0 for i in range(top.n) if lam[i] > 0):
            break
        if not draws:
            raise InsufficientResourcesError(pool.total + 1, pool.total, np.array(
                [len(s) for s in speeds]))
        s_next = draws[0]
        worst = max(
            (i for i in range(top.n) if lam[i] > 0), key=lambda i: deficit(i, s_next)
        )
        speeds[worst].append(draws.pop(0))

    # greedy spend of the remainder
    while draws:
        s_next = draws[0]
        best_i, best_delta = -1, 0.0
        for i in range(top.n):
            if lam[i] == 0:
                continue
            t0 = _op_sojourn(top.operators[i].mu, speeds[i], lam[i])
            t1 = _op_sojourn(top.operators[i].mu, speeds[i] + [s_next], lam[i])
            delta = lam[i] * (t0 - t1)
            if delta > best_delta:
                best_delta, best_i = delta, i
        if best_i < 0:
            break  # nothing benefits
        speeds[best_i].append(draws.pop(0))

    total = 0.0
    for i in range(top.n):
        if lam[i] == 0:
            continue
        t = _op_sojourn(top.operators[i].mu, speeds[i], lam[i])
        if math.isinf(t):
            return HeterogeneousAllocation(speeds, math.inf)
        total += lam[i] * t
    return HeterogeneousAllocation(speeds, total / top.lam0_total)
