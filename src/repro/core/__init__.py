"""DRS core — the paper's primary contribution.

Performance model (Erlang M/M/k + Jackson OQN, paper §III-B), optimal
greedy allocator (Algorithm 1; Programs (4) and (6), §III-C), and the
runtime modules (measurer / scheduler / negotiator / rebalance, §IV).
"""

from .erlang import (
    erlang_b,
    erlang_c,
    expected_sojourn,
    expected_sojourn_factorial,
    marginal_benefit,
    min_stable_k,
    sojourn_curve,
)
from .jackson import (
    OperatorSpec,
    Topology,
    UnstableTopologyError,
    solve_traffic_equations,
)
from .allocator import (
    AllocationResult,
    InsufficientResourcesError,
    allocate,
    assign_processors,
    assign_processors_naive,
    assign_processors_table,
    brute_force_optimal,
    greedy_increments,
    min_processors,
    min_processors_table,
)
from .batched import (
    OperatorArrays,
    expected_sojourn_batch,
    gain_table,
    operator_arrays,
    sojourn_table,
    solve_traffic_batch,
)
from .planner import FleetPlan, FleetPlanner, Tenant
from .controller import (
    ControllerParams,
    ControllerStatic,
    decide_single,
    tick_batch,
)
from .measurer import (
    EwmaSmoother,
    InstanceProbe,
    Measurer,
    MeasurementBatch,
    MeasurementSnapshot,
    WindowSmoother,
    stack_snapshots,
)
from .negotiator import LeaseChange, Machine, Negotiator, ResourcePool
from .rebalance import ExecutableCache, RebalanceCostModel, RebalancePlan
from .heterogeneous import HeterogeneousAllocation, SpeedPool, assign_heterogeneous
from .scheduler import (
    DRSScheduler,
    SchedulerConfig,
    SchedulerDecision,
    StragglerDetector,
)

__all__ = [
    "erlang_b", "erlang_c", "expected_sojourn", "expected_sojourn_factorial",
    "marginal_benefit", "min_stable_k", "sojourn_curve",
    "OperatorSpec", "Topology", "UnstableTopologyError", "solve_traffic_equations",
    "AllocationResult", "InsufficientResourcesError", "allocate",
    "assign_processors", "assign_processors_naive", "assign_processors_table",
    "brute_force_optimal", "greedy_increments",
    "min_processors", "min_processors_table",
    "OperatorArrays", "operator_arrays", "sojourn_table", "gain_table",
    "expected_sojourn_batch", "solve_traffic_batch",
    "FleetPlan", "FleetPlanner", "Tenant",
    "ControllerParams", "ControllerStatic", "decide_single", "tick_batch",
    "EwmaSmoother", "InstanceProbe", "Measurer", "MeasurementBatch",
    "MeasurementSnapshot", "WindowSmoother", "stack_snapshots",
    "LeaseChange", "Machine", "Negotiator", "ResourcePool",
    "ExecutableCache", "RebalanceCostModel", "RebalancePlan",
    "DRSScheduler", "SchedulerConfig", "SchedulerDecision", "StragglerDetector",
    "HeterogeneousAllocation", "SpeedPool", "assign_heterogeneous",
]
