"""FleetPlanner — cross-tenant Programs (4)/(6) on one shared pool.

The paper schedules ONE application against one cluster.  The fleet
setting (DESIGN.md §12) schedules M tenant graphs — each its own Jackson
network with its own arrival process and optionally its own real-time
constraint T_max — against one shared processor pool K_max:

    min   sum_m w_m * sum_i lam_{m,i} * E[T_{m,i}](k_{m,i})
    s.t.  sum_m sum_i k_{m,i} <= K_max,
          E[T_m](k_m) <= T_max_m             for tenants that declare one.

Because each tenant's objective is separable and convex in its own k
(paper Ineq. 5), the cross-tenant optimum is the same marginal-benefit
greedy as Algorithm 1 run over the *merged* gain tables: first every
tenant gets its Program-(6) minimum (its T_max floor, or the stability
floor when no T_max is declared), then the remaining budget goes one
processor at a time to the globally largest *weighted* gain ``w_m *
lam_i * (E[T_i](k) - E[T_i](k+1))`` — which the batched core collapses
to a top-R selection over the stacked ``[sum_m N_m, K]`` table
(core/batched.py, allocator.greedy_increments).

Weighting selects the fleet objective:

* ``objective="fair"`` (default) — ``w_m = 1 / lam0_m``: minimizes
  ``sum_m E[T_m]``, every tenant's mean sojourn counts equally regardless
  of its traffic volume.
* ``objective="throughput"`` — ``w_m = 1``: minimizes total tuple-seconds
  ``sum_m lam0_m * E[T_m]``; exactly Program (4) on the block-diagonal
  union of the tenant networks (tests exploit this equivalence).

``Tenant.weight`` multiplies on top (paying tenants, SLO tiers).

Overload semantics reuse PR 2's: when the per-tenant T_max floors alone
exceed the pool, the plan is flagged ``overloaded`` — the caller
(api.session.FleetSession) reacts like the single-tenant scheduler's
``"overloaded"`` action: ask the negotiator for ``needed_total``
immediately, no scale-in hysteresis, no cost/benefit gate — and the
planner still hands out the whole pool best-effort (weighted Program (4))
so queues drain as fast as the lease allows while capacity arrives.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .allocator import (
    AllocationResult,
    InsufficientResourcesError,
    greedy_increments,
    min_processors_table,
)
from .batched import gain_table
from .jackson import Topology

__all__ = ["Tenant", "FleetPlan", "FleetPlanner"]


@dataclass(frozen=True)
class Tenant:
    """One tenant: a declared graph (or a prebuilt/measured Topology), an
    optional per-tenant real-time constraint, and an optional objective
    weight multiplier (> 0; default 1)."""

    name: str
    graph: object | None = None  # repro.api.AppGraph (kept untyped: core < api)
    topology: Topology | None = None
    t_max: float | None = None
    weight: float = 1.0

    def __post_init__(self) -> None:
        if self.graph is None and self.topology is None:
            raise ValueError(f"tenant {self.name!r}: need a graph or a topology")
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0, got {self.weight}")

    def resolve(self, override: Topology | None = None) -> Topology:
        if override is not None:
            return override
        if self.topology is not None:
            return self.topology
        return self.graph.topology()


@dataclass(frozen=True)
class FleetPlan:
    """One cross-tenant allocation decision."""

    k: dict[str, np.ndarray]  # tenant -> per-operator allocation
    per_tenant: dict[str, AllocationResult]
    total: int  # processors handed out
    k_max: int  # pool size planned against
    needed_total: int  # sum of per-tenant Program-(6) floors
    overloaded: bool  # floors alone exceed the pool (PR-2 overload semantics)
    unmet: tuple[str, ...] = ()  # declared T_max not satisfied by this plan
    unreachable: tuple[str, ...] = ()  # T_max below the tenant's service floor
    objective: float = math.inf  # sum_m w_m * lam0_m * E[T_m]
    evaluations: int = 0  # table entries materialised

    def as_dict(self) -> dict:
        return {
            "k": {t: k.tolist() for t, k in self.k.items()},
            "expected_sojourn": {
                t: r.expected_sojourn for t, r in self.per_tenant.items()
            },
            "total": self.total,
            "k_max": self.k_max,
            "needed_total": self.needed_total,
            "overloaded": self.overloaded,
            "unmet": list(self.unmet),
            "unreachable": list(self.unreachable),
            "objective": self.objective,
        }


@dataclass
class FleetPlanner:
    """Solves the cross-tenant program on merged per-tenant gain tables."""

    tenants: list[Tenant]
    k_max: int
    objective: str = "fair"  # "fair" | "throughput"
    _names: list[str] = field(init=False, repr=False)

    def __post_init__(self) -> None:
        self.tenants = list(self.tenants)
        if not self.tenants:
            raise ValueError("fleet needs at least one tenant")
        self._names = [t.name for t in self.tenants]
        if len(set(self._names)) != len(self._names):
            dupes = sorted({n for n in self._names if self._names.count(n) > 1})
            raise ValueError(f"duplicate tenant names: {dupes}")
        if self.objective not in ("fair", "throughput"):
            raise ValueError(
                f"unknown objective {self.objective!r}; expected 'fair' or 'throughput'"
            )

    # ------------------------------------------------------------------ #
    def weight(self, tenant: Tenant, top: Topology) -> float:
        """Gain multiplier w_m for this tenant under the fleet objective
        (the FleetSession improvement gate reuses this so the two sides
        always score with the same formula).  A zero-traffic tenant gets
        the visit-count guard, not a division crash — an idle measurement
        window must not kill the fleet control loop."""
        base = (
            1.0 / max(top.lam0_total, 1e-300) if self.objective == "fair" else 1.0
        )
        return tenant.weight * base

    def plan(
        self,
        topologies: dict[str, Topology] | None = None,
        *,
        k_max: int | None = None,
    ) -> FleetPlan:
        """Solve the fleet program.  ``topologies`` overrides tenants'
        declared graphs with measured models (the FleetSession control
        loop passes the offered-load-clamped rebuilds here).

        Raises :class:`InsufficientResourcesError` when even the stability
        minima don't fit the pool (no finite-E[T] allocation exists).
        """
        resolved, ctx = self._floors(topologies, k_max)
        take = np.zeros(sum(top.n for _, top in resolved), dtype=np.int64)
        if ctx["budget"] > 0:
            rows, k_start, evals = self._gain_rows(
                resolved, ctx["starts"], ctx["budget"]
            )
            ctx["evals"] += evals
            take = greedy_increments(rows, k_start, ctx["budget"])
        return self._assemble(resolved, take, ctx)

    def plan_batched(
        self,
        topologies: dict[str, Topology] | None = None,
        *,
        k_max: int | None = None,
        mesh=None,
    ) -> FleetPlan:
        """:meth:`plan` with the merged greedy as ONE batched top-R
        selection (``kernels/gain_topr``) over the stacked tenant rows —
        the jit fleet solve of DESIGN.md §16.

        The Program-(6) floors and the gain tables are built by the same
        float64 numpy code as :meth:`plan`, and ``gain_topr`` implements
        exactly ``greedy_increments``'s threshold + row-major tie rule,
        so under ``jax.config.enable_x64`` the plan is bit-identical to
        the scalar path (tests/test_planner.py asserts equality; without
        x64 the float32 cast can resolve near-ties differently).

        ``mesh`` (1-D) runs the selection as a cross-device fleet
        reduction: the stacked rows are sharded over devices, each shard
        ``all_gather``s the merged gain table, solves the SAME global
        top-R (replicated, so every device agrees bitwise), and keeps its
        own rows' take — Programs (4)/(6) over the merged gain tables of
        a sharded tenant stack.
        """
        resolved, ctx = self._floors(topologies, k_max)
        take = np.zeros(sum(top.n for _, top in resolved), dtype=np.int64)
        if ctx["budget"] > 0:
            rows, k_start, evals = self._gain_rows(
                resolved, ctx["starts"], ctx["budget"]
            )
            ctx["evals"] += evals
            take = _merged_topr(rows, k_start, ctx["budget"], mesh=mesh)
        return self._assemble(resolved, take, ctx)

    # ------------------------------------------------------------------ #
    # Shared plan stages (scalar + batched solvers)
    # ------------------------------------------------------------------ #
    def _floors(
        self, topologies: dict[str, Topology] | None, k_max: int | None
    ) -> tuple[list, dict]:
        """Resolve tenants, compute Program-(6) floors, classify overload,
        and derive the residual budget — everything before the greedy."""
        k_max = self.k_max if k_max is None else k_max
        tops = topologies or {}
        resolved = [(t, t.resolve(tops.get(t.name))) for t in self.tenants]
        k_min = [top.min_feasible_allocation() for _, top in resolved]
        min_total = int(sum(int(k.sum()) for k in k_min))
        if min_total > k_max:
            raise InsufficientResourcesError(
                min_total, k_max, np.concatenate(k_min)
            )
        evals = 0

        # --- Program (6) floors: what each tenant needs for its T_max --- #
        floors: list[np.ndarray] = []
        unreachable: list[str] = []
        for (tenant, top), km in zip(resolved, k_min):
            if tenant.t_max is None:
                floors.append(km.astype(np.int64))
                continue
            try:
                need = min_processors_table(top, tenant.t_max)
                evals += need.evaluations
                floors.append(need.k.astype(np.int64))
            except InsufficientResourcesError:
                unreachable.append(tenant.name)
                floors.append(km.astype(np.int64))
        needed_total = int(sum(int(f.sum()) for f in floors))

        # --- Overload fast path: floors don't fit the pool -------------- #
        overloaded = needed_total > k_max
        starts = k_min if overloaded else floors  # best-effort vs floors-granted
        granted = int(sum(int(s.sum()) for s in starts))
        return resolved, {
            "k_max": k_max,
            "needed_total": needed_total,
            "overloaded": overloaded,
            "unreachable": unreachable,
            "starts": starts,
            "budget": k_max - granted,
            "evals": evals,
        }

    def _gain_rows(
        self, resolved: list, starts: list, budget: int
    ) -> tuple[np.ndarray, np.ndarray, int]:
        """Stacked weighted gain rows ``[sum_m N_m, width]`` + start
        columns — the merged table both solvers select from."""
        evals = 0
        k_start = np.concatenate([s.astype(np.int64) for s in starts])
        width = int(max(int(s.max()) for s in starts)) + budget
        rows = []
        for (tenant, top), s in zip(resolved, starts):
            k_hi = int(s.max()) + budget
            T, G = gain_table(top, k_hi)
            evals += T.size
            w = self.weight(tenant, top)
            Gw = np.full((top.n, width), -np.inf)
            Gw[:, :k_hi] = w * G
            rows.append(Gw)
        return np.vstack(rows), k_start, evals

    def _assemble(self, resolved: list, take: np.ndarray, ctx: dict) -> FleetPlan:
        k_out: dict[str, np.ndarray] = {}
        per_tenant: dict[str, AllocationResult] = {}
        unmet: list[str] = []
        objective = 0.0
        off = 0
        for (tenant, top), s in zip(resolved, ctx["starts"]):
            n = top.n
            k = np.asarray(s, dtype=np.int64) + take[off : off + n]
            off += n
            et = top.expected_sojourn(k)
            k_out[tenant.name] = k
            per_tenant[tenant.name] = AllocationResult(k, et, int(k.sum()), 0)
            if tenant.t_max is not None and not et <= tenant.t_max:
                unmet.append(tenant.name)
            w = self.weight(tenant, top)
            objective += w * top.lam0_total * et if math.isfinite(et) else math.inf
        return FleetPlan(
            k=k_out,
            per_tenant=per_tenant,
            total=int(sum(int(k.sum()) for k in k_out.values())),
            k_max=ctx["k_max"],
            needed_total=ctx["needed_total"],
            overloaded=ctx["overloaded"],
            unmet=tuple(unmet),
            unreachable=tuple(ctx["unreachable"]),
            objective=objective,
            evaluations=ctx["evals"],
        )


def _merged_topr(
    G: np.ndarray, k_start: np.ndarray, budget: int, *, mesh=None
) -> np.ndarray:
    """``greedy_increments``'s selection as one batched ``gain_topr`` call
    over the merged fleet rows (optionally as a cross-device reduction).

    Gathers the same ``[R, budget]`` candidate window the scalar greedy
    walks (rows start at each operator's floor; entries are finite there
    because floors sit at/above every stability minimum), then hands the
    whole fleet's budget to the globally largest positive gains in one
    top-R selection.  With ``mesh``, rows are sharded across devices and
    each shard ``all_gather``s the full table before solving — every
    device computes the identical global selection, then keeps its own
    rows (DESIGN.md §16 fleet reduction).
    """
    import jax.numpy as jnp

    from .controller import _topr_ops

    topr_ops = _topr_ops()
    r = G.shape[0]
    if budget <= 0:
        return np.zeros(r, dtype=np.int64)
    idx = k_start[:, None] + np.arange(budget)[None, :]
    if idx.max() >= G.shape[1]:
        raise ValueError(
            f"gain table too narrow: need column {int(idx.max())}, have {G.shape[1]}"
        )
    cand = G[np.arange(r)[:, None], idx]  # [R, budget]
    budget_arr = jnp.asarray([budget], dtype=jnp.int32)
    if mesh is None:
        take = topr_ops.gain_topr(jnp.asarray(cand[None]), budget_arr)[0]
        return np.asarray(take, dtype=np.int64)

    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if len(mesh.axis_names) != 1:
        raise ValueError(f"fleet mesh must be 1-D; got axes {mesh.axis_names}")
    axis = mesh.axis_names[0]
    d = int(mesh.size)
    r_pad = -(-r // d) * d
    if r_pad > r:  # zero-gain rows are never selected
        cand = np.concatenate([cand, np.zeros((r_pad - r, budget))])

    def solve(local_rows):
        merged = lax.all_gather(local_rows, axis, axis=0, tiled=True)
        take_all = topr_ops.gain_topr(merged[None], budget_arr)[0]
        i0 = lax.axis_index(axis) * local_rows.shape[0]
        return lax.dynamic_slice_in_dim(take_all, i0, local_rows.shape[0])

    take = shard_map(
        solve, mesh=mesh, in_specs=P(axis, None), out_specs=P(axis),
        check_rep=False,
    )(jnp.asarray(cand))
    return np.asarray(take[:r], dtype=np.int64)
