"""Rebalance mechanics: executable cache + migration cost model.

The paper's key systems trick is a cheap rebalance (their improved Storm
re-balancing reuses JVMs, cutting 1-2 min suspensions to seconds).  The TPU
analogue: changing an operator's chip count means running a *different*
pjit-compiled executable — recompiling at rebalance time would be the "JVM
restart" mistake.  We instead keep an **executable cache** keyed by
(stage, k, shape signature): rebalancing to a previously-seen configuration
is a dictionary lookup; new configurations compile off the critical path
(background warm-up of the neighbours k±1 of the current allocation).

The **cost model** prices a proposed rebalance so the scheduler can make
the paper's Appendix B-B cost/benefit call:

    pause      — control-plane pause to swap executables (cache hit vs miss)
    migration  — state bytes moved / ICI bandwidth (KV caches, optimizer
                 shards) when an operator's chip group changes size
    backlog    — tuples that queue up during the pause take time to drain:
                 a pause of P seconds builds a backlog of lam0*P tuples that
                 drains at (capacity - lam) tuples/sec.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

__all__ = ["ExecutableCache", "RebalanceCostModel", "RebalancePlan"]


@dataclass
class _CacheEntry:
    value: Any
    compile_seconds: float
    hits: int = 0


class ExecutableCache:
    """Cache of compiled executables keyed by (stage, k, signature)."""

    def __init__(self, compile_fn: Callable[[str, int, Any], Any] | None = None):
        self._store: dict[tuple, _CacheEntry] = {}
        self._compile_fn = compile_fn
        self.hits = 0
        self.misses = 0

    def key(self, stage: str, k: int, signature: Any = None) -> tuple:
        return (stage, int(k), signature)

    def get(self, stage: str, k: int, signature: Any = None) -> Any | None:
        e = self._store.get(self.key(stage, k, signature))
        if e is not None:
            e.hits += 1
            self.hits += 1
            return e.value
        self.misses += 1
        return None

    def put(self, stage: str, k: int, value: Any, *, signature: Any = None, compile_seconds: float = 0.0) -> None:
        self._store[self.key(stage, k, signature)] = _CacheEntry(value, compile_seconds)

    def get_or_compile(self, stage: str, k: int, signature: Any = None) -> Any:
        hit = self.get(stage, k, signature)
        if hit is not None:
            return hit
        if self._compile_fn is None:
            raise KeyError(f"no cached executable for {(stage, k, signature)}")
        t0 = time.perf_counter()
        v = self._compile_fn(stage, k, signature)
        self.put(stage, k, v, signature=signature, compile_seconds=time.perf_counter() - t0)
        return v

    def warm_neighbours(self, stage: str, k: int, signature: Any = None, radius: int = 1) -> int:
        """Pre-compile k±radius configurations off the critical path."""
        if self._compile_fn is None:
            return 0
        n = 0
        for kk in range(max(1, k - radius), k + radius + 1):
            if self.get(stage, kk, signature) is None:
                self.get_or_compile(stage, kk, signature)
                n += 1
        return n

    def __len__(self) -> int:
        return len(self._store)


@dataclass(frozen=True)
class RebalancePlan:
    """A priced proposal to move from allocation k_old to k_new."""

    k_old: np.ndarray
    k_new: np.ndarray
    pause_seconds: float
    migration_seconds: float
    backlog_drain_seconds: float
    benefit_per_second: float  # E[T](k_old) - E[T](k_new), seconds saved per tuple

    @property
    def total_cost_seconds(self) -> float:
        return self.pause_seconds + self.migration_seconds + self.backlog_drain_seconds

    def worthwhile(self, horizon_seconds: float, lam0: float) -> bool:
        """Cost/benefit over a planning horizon (paper Appendix B-B).

        Benefit ~ tuples processed over the horizon * per-tuple seconds
        saved; cost ~ the one-off disruption (pause + migration + drain).
        """
        if np.array_equal(self.k_old, self.k_new):
            return False
        gain = self.benefit_per_second * lam0 * horizon_seconds
        return gain > self.total_cost_seconds * max(lam0, 1.0)


@dataclass
class RebalanceCostModel:
    """Prices a rebalance for the scheduler's decision.

    ici_bandwidth: per-chip link bandwidth used for state migration.
    pause_cache_hit / pause_cache_miss: control-plane pause depending on
    whether every new (stage, k) executable is already cached.
    """

    ici_bandwidth: float = 50e9
    # The paper's improved rebalance "takes a few seconds" vs Storm's 1-2
    # minutes; our executable cache makes a hit sub-second, and background
    # neighbour warm-up (ExecutableCache.warm_neighbours) keeps most misses
    # off the critical path, so the default miss pause is seconds.
    pause_cache_hit: float = 0.5
    pause_cache_miss: float = 5.0
    state_bytes_per_processor: np.ndarray | None = None  # per-operator

    def plan(
        self,
        topology,
        k_old: np.ndarray,
        k_new: np.ndarray,
        *,
        cache: ExecutableCache | None = None,
        stage_names: list[str] | None = None,
    ) -> RebalancePlan:
        k_old = np.asarray(k_old)
        k_new = np.asarray(k_new)
        changed = np.nonzero(k_old != k_new)[0]
        # Pause: cache hit if every changed stage's new executable is cached.
        pause = self.pause_cache_hit
        if cache is not None and stage_names is not None:
            for i in changed:
                if cache.get(stage_names[i], int(k_new[i])) is None:
                    pause = self.pause_cache_miss
                    break
        elif cache is None:
            pause = self.pause_cache_miss if len(changed) else self.pause_cache_hit
        # Migration: bytes proportional to |delta k| per operator.
        mig = 0.0
        if self.state_bytes_per_processor is not None:
            delta = np.abs(k_new - k_old).astype(np.float64)
            mig = float((delta * self.state_bytes_per_processor).sum()) / self.ici_bandwidth
        # Backlog drain: lam0*pause extra tuples drained at (capacity - lam0).
        et_old = topology.expected_sojourn(k_old)
        et_new = topology.expected_sojourn(k_new)
        lam0 = topology.lam0_total
        mus = np.array([op.mu for op in topology.operators])
        capacity_new = float(np.min(k_new * mus / np.maximum(topology.visit_counts, 1e-12)))
        slack = max(capacity_new - lam0, 1e-9)
        drain = lam0 * (pause + mig) / slack
        benefit = (et_old - et_new) if np.isfinite(et_old) else float("inf")
        return RebalancePlan(k_old, k_new, pause, mig, drain, benefit)
