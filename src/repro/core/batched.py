"""Batched analytic core — vectorized Erlang/Jackson evaluation (DESIGN.md §12).

The scalar modules (erlang.py, jackson.py) price ONE allocation of ONE
topology per call; every control tick the allocator then re-walks the
Erlang-B recursion thousands of times.  This module evaluates the model in
bulk along three axes:

* **k axis** — :func:`sojourn_table` materialises ``E[T_i](k)`` for every
  operator at every ``k in [0, k_hi]`` in ONE pass of the Erlang-B
  recursion (``[N, k_hi+1]``); :func:`gain_table` turns it into the
  marginal-benefit table Algorithm 1 consumes.
* **allocation batch axis** — :func:`expected_sojourn_batch` prices a
  ``[B, N]`` batch of candidate allocations (what-if configurations)
  against one topology via table gather.
* **tenant/scenario batch axis** — :func:`solve_traffic_batch` solves the
  Jackson traffic equations for a ``[B, N]`` batch of ``lam0`` vectors
  (optionally a ``[B, N, N]`` batch of routing matrices) in one
  ``linalg.solve``.

Backends and the fallback rule (DESIGN.md §12): every function has a
float64 **numpy** implementation — the default off-TPU, and the one the
allocator's bit-exactness guarantee rests on (it replays the scalar
recursion's float ops verbatim, vectorized across lanes) — and a pure-jnp
``jit``/``vmap``-able implementation (``backend="jax"``) whose hot
Erlang-B recursion dispatches to the Pallas kernel
(``kernels/erlang_c``) on TPU and the lax.scan oracle elsewhere.  The jnp
path inherits JAX's active precision (float32 unless x64 is enabled), so
CPU tests pin tolerances accordingly.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .jackson import Topology

__all__ = [
    "OperatorArrays",
    "operator_arrays",
    "sojourn_table",
    "gain_table",
    "sojourn_from_table",
    "expected_sojourn_batch",
    "solve_traffic_batch",
    "sojourn_table_jax",
    "expected_sojourn_batch_jax",
    "solve_traffic_batch_jax",
]


# --------------------------------------------------------------------------- #
# Topology -> flat arrays
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class OperatorArrays:
    """Flat per-operator arrays the batched kernels consume (index order
    matches the Topology's)."""

    lam: np.ndarray  # solved per-operator arrival rates [N]
    mu: np.ndarray  # per-processor service-rate priors/estimates [N]
    group: np.ndarray  # bool [N]: True = chip-gang scaling (M/M/1 @ mu*k*eff)
    alpha: np.ndarray  # group efficiency rolloff [N]
    min_k: np.ndarray  # per-operator floor [N]
    lam0_total: float


def operator_arrays(top: Topology) -> OperatorArrays:
    ops = top.operators
    return OperatorArrays(
        lam=np.asarray(top.arrival_rates, dtype=np.float64),
        mu=np.array([op.mu for op in ops], dtype=np.float64),
        group=np.array([op.scaling == "group" for op in ops], dtype=bool),
        alpha=np.array([op.group_alpha for op in ops], dtype=np.float64),
        min_k=np.array([op.min_k for op in ops], dtype=np.int64),
        lam0_total=top.lam0_total,
    )


# --------------------------------------------------------------------------- #
# numpy float64 path (default off-TPU; bit-compatible with the scalar core)
# --------------------------------------------------------------------------- #
def sojourn_table(top: Topology, k_hi: int) -> np.ndarray:
    """``T[i, k] = E[T_i](k)`` for ``k in [0, k_hi]`` — ``[N, k_hi+1]`` float64.

    Entries below the operator's ``min_k`` or in the unstable region
    (``k*mu <= lam`` replica / ``mu_eff(k) <= lam`` group) are ``+inf``,
    mirroring ``OperatorSpec.sojourn`` exactly: the vectorized recursion
    performs the same float64 operations in the same order as the scalar
    ``erlang.expected_sojourn``, so finite entries are bit-identical to the
    scalar values — that is what lets the table-driven greedy reproduce
    ``assign_processors_naive`` decision-for-decision.
    """
    if k_hi < 0:
        raise ValueError(f"k_hi must be >= 0, got {k_hi}")
    arr = operator_arrays(top)
    n = arr.lam.shape[0]
    T = np.full((n, k_hi + 1), np.inf, dtype=np.float64)

    rep = ~arr.group
    if rep.any():
        lam, mu = arr.lam[rep], arr.mu[rep]
        a = lam / mu
        r = int(rep.sum())
        # Erlang-B recursion B(j) = aB/(j + aB).  It is sequential in j, so
        # the loop stays — but its body is kept to the bare recursion and,
        # for narrow operator sets, run in plain Python floats (~30x less
        # per-step overhead than numpy scalar-array ops; the float ops are
        # the same either way, preserving bit-equality with erlang.erlang_b).
        B = np.empty((r, k_hi + 1), dtype=np.float64)
        B[:, 0] = 1.0
        if r <= 64:
            for i in range(r):
                ai = float(a[i])
                row = B[i]
                b = 1.0
                for j in range(1, k_hi + 1):
                    ab = ai * b
                    b = ab / (j + ab)
                    row[j] = b
        else:
            b = np.ones_like(a)
            for j in range(1, k_hi + 1):
                ab = a * b
                b = ab / (j + ab)
                B[:, j] = b
        # B -> C -> E[T], one vectorized pass over the whole [r, k_hi+1]
        # grid (elementwise ops in the scalar expressions' order).
        ks = np.arange(k_hi + 1, dtype=np.int64)[None, :]
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            c = ks * B / (ks - a[:, None] * (1.0 - B))
            t = c / (ks * mu[:, None] - lam[:, None]) + 1.0 / mu[:, None]
            sub = np.where(ks > a[:, None], t, np.inf)
        T[rep] = sub

    if arr.group.any():
        ks = np.arange(k_hi + 1, dtype=np.float64)
        for i in np.nonzero(arr.group)[0]:
            lam, mu, alpha = arr.lam[i], arr.mu[i], arr.alpha[i]
            with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
                eff = 1.0 / (1.0 + alpha * (ks - 1.0))
                mu_eff = mu * ks * eff
                a = lam / mu_eff
                stable = 1.0 > a  # M/M/1: scalar inf branch is `1 <= a`
                # j=1 step of the B recursion with b0=1: a*1/(1 + a*1)
                b = a / (1.0 + a)
                c = b / (1.0 - a * (1.0 - b))
                t = c / (mu_eff - lam) + 1.0 / mu_eff
            row = np.full(k_hi + 1, np.inf)
            row[stable] = t[stable]
            T[i] = row

    for i in range(n):
        lo = min(int(arr.min_k[i]), k_hi + 1)
        T[i, :lo] = np.inf
    return T


def gain_table(top: Topology, k_hi: int) -> tuple[np.ndarray, np.ndarray]:
    """``(T, G)`` where ``G[i, k] = lam_i * (T[i,k] - T[i,k+1])`` — the
    Algorithm-1 marginal benefit of the k -> k+1 processor, ``[N, k_hi]``.

    ``G`` is ``+inf`` where ``T[i, k]`` is infinite (the processor is
    mandatory), matching ``erlang.marginal_benefit``.
    """
    T = sojourn_table(top, k_hi)
    lam = np.asarray(top.arrival_rates, dtype=np.float64)
    with np.errstate(invalid="ignore"):
        G = lam[:, None] * (T[:, :-1] - T[:, 1:])
    G[np.isinf(T[:, :-1])] = np.inf
    return T, G


def sojourn_from_table(T: np.ndarray, k: np.ndarray, lam: np.ndarray, lam0_total: float):
    """Vector of per-op sojourns + E[T] (paper Eq. 3) gathered from the table.

    ``k`` may be ``[N]`` or ``[B, N]``; returns ``(per_op, e2e)`` with the
    matching leading shape.  Uses a vectorized sum (tolerance ~1e-12 of the
    scalar sequential sum; callers needing the scalar-exact value recompute
    via ``Topology.expected_sojourn``).
    """
    k = np.asarray(k, dtype=np.int64)
    per_op = np.take_along_axis(
        np.broadcast_to(T, k.shape[:-1] + T.shape), k[..., None], axis=-1
    )[..., 0]
    with np.errstate(invalid="ignore"):  # 0 * inf on zero-traffic operators
        contrib = np.where(lam > 0, lam * per_op, 0.0)
    e2e = contrib.sum(axis=-1) / max(lam0_total, 1e-300)  # idle-network guard
    return per_op, e2e


def expected_sojourn_batch(top: Topology, k_batch, *, backend: str = "numpy"):
    """E[T](k) for a ``[B, N]`` batch of allocations — ``[B]`` floats.

    ``backend="numpy"`` (default): float64 table + gather.
    ``backend="jax"``: the jit'd jnp path (float32 unless x64 is enabled).
    """
    k_batch = np.atleast_2d(np.asarray(k_batch, dtype=np.int64))
    if k_batch.shape[-1] != top.n:
        raise ValueError(f"k batch must be [B, {top.n}], got {k_batch.shape}")
    if backend == "jax":
        return np.asarray(expected_sojourn_batch_jax(top, k_batch))
    k_hi = int(k_batch.max(initial=0))
    T = sojourn_table(top, k_hi)
    _, e2e = sojourn_from_table(T, k_batch, top.arrival_rates, top.lam0_total)
    return e2e


def solve_traffic_batch(lam0_batch, routing, *, backend: str = "numpy") -> np.ndarray:
    """Traffic equations ``lam = lam0 + P^T lam`` for a batch of externals.

    ``lam0_batch`` is ``[B, N]``; ``routing`` is one shared ``[N, N]`` or a
    per-scenario ``[B, N, N]``.  Returns ``[B, N]`` solved arrival rates
    (tiny negatives from numerical noise are clamped to 0, as in the scalar
    ``solve_traffic_equations``).
    """
    lam0 = np.atleast_2d(np.asarray(lam0_batch, dtype=np.float64))
    p = np.asarray(routing, dtype=np.float64)
    n = lam0.shape[-1]
    if p.shape not in ((n, n),) and p.shape != (lam0.shape[0], n, n):
        raise ValueError(
            f"routing must be ({n},{n}) or ({lam0.shape[0]},{n},{n}), got {p.shape}"
        )
    if backend == "jax":
        return np.asarray(solve_traffic_batch_jax(lam0, p))
    pt = np.swapaxes(p, -1, -2)
    a = np.eye(n) - pt
    lam = np.linalg.solve(a, lam0[..., None])[..., 0] if a.ndim == 3 else (
        np.linalg.solve(a, lam0.T).T
    )
    lam[np.abs(lam) < 1e-12] = 0.0
    return lam


# --------------------------------------------------------------------------- #
# jnp path — pure functions, jit/vmap-able; Pallas recursion kernel on TPU
# --------------------------------------------------------------------------- #
def sojourn_table_jax(
    lam,
    mu,
    *,
    k_hi: int,
    group=None,
    alpha=None,
    min_k=None,
    interpret: bool = False,
    force_kernel: bool = False,
    unroll: int = 1,
):
    """jnp ``[N, k_hi+1]`` sojourn table (the numpy path's jit-able twin).

    The Erlang-B recursion runs through ``kernels.erlang_c.ops`` — Pallas
    on TPU, lax.scan elsewhere; pass ``force_kernel=True, interpret=True``
    to exercise the Pallas kernel itself on CPU (``interpret`` alone does
    not switch the dispatch — repo kernel idiom, see kernels/__init__.py).
    Group-scaled operators use the M/M/1 closed form and are merged in
    with ``jnp.where`` so the whole function stays traceable.  ``unroll``
    tunes the reference scan's unroll factor — bitwise-safe, so callers
    may autotune it freely (kernels/decide_fused does).
    """
    import jax.numpy as jnp

    from ..kernels.erlang_c import ops as _erlang_ops

    lam = jnp.asarray(lam)
    dtype = lam.dtype
    mu = jnp.asarray(mu, dtype=dtype)
    n = lam.shape[0]
    group = (
        jnp.zeros(n, dtype=bool) if group is None else jnp.asarray(group, dtype=bool)
    )
    alpha = jnp.zeros(n, dtype=dtype) if alpha is None else jnp.asarray(alpha, dtype=dtype)
    min_k = (
        jnp.ones(n, dtype=jnp.int32) if min_k is None else jnp.asarray(min_k, jnp.int32)
    )
    ks = jnp.arange(k_hi + 1, dtype=dtype)  # [K+1]

    # Replica: one recursion pass over the operator lane.
    a_rep = lam / mu
    btab = _erlang_ops.erlang_b_table(
        a_rep, k_hi=k_hi, interpret=interpret, force_kernel=force_kernel,
        unroll=unroll,
    ).T.astype(dtype)  # [N, K+1]
    kk = ks[None, :]
    c = kk * btab / (kk - a_rep[:, None] * (1.0 - btab))
    t_rep = c / (kk * mu[:, None] - lam[:, None]) + 1.0 / mu[:, None]
    t_rep = jnp.where(kk > a_rep[:, None], t_rep, jnp.inf)

    # Group: M/M/1 at mu * k * eff(k).
    eff = 1.0 / (1.0 + alpha[:, None] * (kk - 1.0))
    mu_eff = mu[:, None] * kk * eff
    a_grp = lam[:, None] / mu_eff
    b = a_grp / (1.0 + a_grp)
    cg = b / (1.0 - a_grp * (1.0 - b))
    t_grp = cg / (mu_eff - lam[:, None]) + 1.0 / mu_eff
    t_grp = jnp.where(a_grp < 1.0, t_grp, jnp.inf)

    T = jnp.where(group[:, None], t_grp, t_rep)
    return jnp.where(kk >= min_k[:, None], T, jnp.inf)


def expected_sojourn_batch_jax(top: Topology, k_batch, *, interpret: bool = False):
    """E[T](k) over a ``[B, N]`` jnp batch of allocations (gather on the
    jnp table).  Returns a jnp ``[B]`` vector."""
    import jax.numpy as jnp

    arr = operator_arrays(top)
    k_batch = jnp.atleast_2d(jnp.asarray(k_batch, dtype=jnp.int32))
    k_hi = int(np.asarray(k_batch).max(initial=0))
    T = sojourn_table_jax(
        jnp.asarray(arr.lam),
        jnp.asarray(arr.mu),
        k_hi=k_hi,
        group=arr.group,
        alpha=arr.alpha,
        min_k=arr.min_k,
        interpret=interpret,
    )
    per_op = jnp.take_along_axis(
        jnp.broadcast_to(T, k_batch.shape[:1] + T.shape), k_batch[..., None], axis=-1
    )[..., 0]
    lam = jnp.asarray(arr.lam, dtype=per_op.dtype)
    contrib = jnp.where(lam > 0, lam * per_op, 0.0)
    return contrib.sum(axis=-1) / max(arr.lam0_total, 1e-300)


def solve_traffic_batch_jax(lam0_batch, routing):
    """jnp traffic-equation solve for ``[B, N]`` externals (shared or
    per-scenario routing) via ``jnp.linalg.solve``."""
    import jax.numpy as jnp

    lam0 = jnp.atleast_2d(jnp.asarray(lam0_batch))
    p = jnp.asarray(routing, dtype=lam0.dtype)
    n = lam0.shape[-1]
    pt = jnp.swapaxes(p, -1, -2)
    a = jnp.eye(n, dtype=lam0.dtype) - pt
    if a.ndim == 3:
        lam = jnp.linalg.solve(a, lam0[..., None])[..., 0]
    else:
        lam = jnp.linalg.solve(a, lam0.T).T
    return jnp.where(jnp.abs(lam) < 1e-12, 0.0, lam)
