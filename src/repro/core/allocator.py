"""DRS resource allocation — paper Algorithm 1 and Programs (4) and (6).

Three solvers are provided for Program (4) (min E[T] s.t. sum k_i <= K_max):

* :func:`assign_processors_naive` — the paper's Algorithm 1 verbatim:
  each round recomputes every operator's marginal benefit and increments the
  argmax.  O(K_max * N) sojourn evaluations.  Kept as the reference oracle.
* :func:`assign_processors` — heap-based: because the marginal benefit
  ``delta_i(k) = lam_i (E[T_i](k) - E[T_i](k+1))`` is non-increasing in k
  (convexity, paper Ineq. 5), a max-heap of each operator's *next* gain
  yields the identical allocation in O((K_max - sum k_min) log N) *scalar*
  sojourn evaluations (each an O(k) Erlang recursion).
* :func:`assign_processors_table` — the batched-core rewrite (DESIGN.md
  §12): ONE vectorized Erlang pass materialises the full ``[N, K]``
  marginal-gain table (core/batched.py), then the greedy collapses to a
  top-R selection over it.  The numpy float64 table replays the scalar
  recursion bit-for-bit, and the selection breaks ties exactly like the
  argmax loop (lowest operator index first, increasing k within an
  operator), so the allocation is **bit-identical** to
  ``assign_processors_naive`` — at ~1000x less Python-interpreter work
  (benchmarks/bench_overhead.py, the Table-II reproduction).

Program (6) (min sum k_i s.t. E[T] <= T_max) is solved by the same greedy
run until the constraint is met — scalar (:func:`min_processors`) or
table-driven with a binary search over the increment count
(:func:`min_processors_table`).

Theorem 1 (optimality of the greedy for Program 4) is exercised in
tests/test_core_allocator.py against brute-force enumeration.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass

import numpy as np

from .batched import gain_table
from .jackson import Topology

__all__ = [
    "InsufficientResourcesError",
    "AllocationResult",
    "assign_processors",
    "assign_processors_naive",
    "assign_processors_table",
    "min_processors",
    "min_processors_table",
    "greedy_increments",
    "allocate",
]


class InsufficientResourcesError(RuntimeError):
    """Paper Algorithm 1 lines 4-6: sum of minimal k_i exceeds K_max."""

    def __init__(self, needed: int, k_max: int, k_min: np.ndarray):
        super().__init__(
            f"minimum feasible allocation needs {needed} processors but "
            f"K_max={k_max} (per-operator minima: {k_min.tolist()})"
        )
        self.needed = needed
        self.k_max = k_max
        self.k_min = k_min


@dataclass(frozen=True)
class AllocationResult:
    k: np.ndarray  # processors per operator
    expected_sojourn: float  # model E[T](k), seconds
    total: int  # sum k_i
    evaluations: int  # number of E[T_i] evaluations performed (cost metric)

    def as_dict(self) -> dict:
        return {
            "k": self.k.tolist(),
            "expected_sojourn": self.expected_sojourn,
            "total": self.total,
            "evaluations": self.evaluations,
        }


def _marginal(top: Topology, lam: np.ndarray, i: int, k_i: int) -> float:
    """delta_i = lam_i * (E[T_i](k_i) - E[T_i](k_i+1)), Algorithm 1 line 9."""
    op = top.operators[i]
    t0 = op.sojourn(k_i, lam[i])
    t1 = op.sojourn(k_i + 1, lam[i])
    if math.isinf(t0):
        return math.inf
    return lam[i] * (t0 - t1)


def assign_processors_naive(top: Topology, k_max: int) -> AllocationResult:
    """Paper Algorithm 1, literal transcription (reference implementation)."""
    lam = top.arrival_rates
    k = top.min_feasible_allocation()
    evals = 0
    if int(k.sum()) > k_max:
        raise InsufficientResourcesError(int(k.sum()), k_max, k)
    while int(k.sum()) < k_max:
        deltas = np.empty(top.n)
        for i in range(top.n):
            deltas[i] = _marginal(top, lam, i, int(k[i]))
            evals += 2
        j = int(np.argmax(deltas))
        if deltas[j] <= 0.0:
            break  # no operator benefits; adding more would be pure waste
        k[j] += 1
    return AllocationResult(k, top.expected_sojourn(k), int(k.sum()), evals)


def assign_processors(top: Topology, k_max: int) -> AllocationResult:
    """Heap-based Algorithm 1 — identical output, O((K-K0) log N)."""
    lam = top.arrival_rates
    k = top.min_feasible_allocation()
    evals = 0
    total = int(k.sum())
    if total > k_max:
        raise InsufficientResourcesError(total, k_max, k)
    # Max-heap of (-delta, i); each operator's entry reflects its next gain.
    heap: list[tuple[float, int]] = []
    for i in range(top.n):
        if lam[i] == 0.0:
            continue
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heap.append((-d, i))
    heapq.heapify(heap)
    while total < k_max and heap:
        neg_d, i = heapq.heappop(heap)
        if -neg_d <= 0.0:
            break
        k[i] += 1
        total += 1
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heapq.heappush(heap, (-d, i))
    return AllocationResult(k, top.expected_sojourn(k), total, evals)


# --------------------------------------------------------------------------- #
# Gain-table greedy (batched core)
# --------------------------------------------------------------------------- #
def _heap_greedy_counts(cand: np.ndarray, budget: int) -> np.ndarray:
    """Exact argmax-greedy walk over a candidate-gain matrix (used when the
    float gain rows are not non-increasing, so prefix selection is unsafe).
    ``cand[i, j]`` is operator i's gain for its j-th extra processor."""
    n, width = cand.shape
    take = np.zeros(n, dtype=np.int64)
    heap = [(-float(cand[i, 0]), i) for i in range(n) if width > 0]
    heapq.heapify(heap)
    while budget > 0 and heap:
        neg_d, i = heapq.heappop(heap)
        if -neg_d <= 0.0:
            break
        take[i] += 1
        budget -= 1
        if take[i] < width:
            heapq.heappush(heap, (-float(cand[i, take[i]]), i))
    return take


def greedy_increments(G: np.ndarray, k_start: np.ndarray, budget: int) -> np.ndarray:
    """How many of ``budget`` processors each operator receives when they are
    handed out one-at-a-time to the largest current gain, reading gains from
    the precomputed table ``G[i, k]`` starting at ``k_start[i]``.

    Decision-for-decision identical to Algorithm 1's argmax loop, including
    its tie-breaking (``np.argmax`` returns the *first* maximum, so the
    lowest operator index wins a tie and keeps winning until its gain drops
    below the tie value): because each row of ``G`` is non-increasing
    (convexity, paper Ineq. 5), the greedy takes exactly the globally
    largest ``budget`` positive entries, with threshold ties resolved in
    (operator index, k) order.  If float rounding ever breaks a row's
    monotonicity the function falls back to an exact heap walk over the
    same table.
    """
    n = G.shape[0]
    if budget <= 0:
        return np.zeros(n, dtype=np.int64)
    idx = k_start[:, None] + np.arange(budget)[None, :]
    if idx.max() >= G.shape[1]:
        raise ValueError(
            f"gain table too narrow: need column {int(idx.max())}, have {G.shape[1]}"
        )
    cand = G[np.arange(n)[:, None], idx]  # [n, budget]
    if np.any(cand[:, 1:] > cand[:, :-1]):
        return _heap_greedy_counts(cand, budget)
    pos = cand > 0.0
    pos_counts = pos.sum(axis=1).astype(np.int64)
    if int(pos_counts.sum()) <= budget:
        return pos_counts  # every beneficial processor fits in the budget
    vals = cand[pos]
    thresh = np.partition(vals, len(vals) - budget)[len(vals) - budget]
    take = (cand > thresh).sum(axis=1).astype(np.int64)
    rem = budget - int(take.sum())
    if rem > 0:
        ties = ((cand == thresh) & pos).sum(axis=1)
        for i in range(n):
            if rem == 0:
                break
            t = min(int(ties[i]), rem)
            take[i] += t
            rem -= t
    return take


def assign_processors_table(top: Topology, k_max: int) -> AllocationResult:
    """Program (4) via the precomputed ``[N, K]`` marginal-gain table.

    Output is bit-identical to :func:`assign_processors_naive` (same float
    values, same tie-breaking — see :func:`greedy_increments`), at the cost
    of one vectorized Erlang pass instead of O(K*N) scalar recursions.
    ``evaluations`` counts materialised table entries.
    """
    k = top.min_feasible_allocation()
    total = int(k.sum())
    if total > k_max:
        raise InsufficientResourcesError(total, k_max, k)
    budget = k_max - total
    if budget == 0:
        return AllocationResult(k, top.expected_sojourn(k), total, 0)
    k_hi = int(k.max()) + budget
    T, G = gain_table(top, k_hi)
    k = k + greedy_increments(G, k.astype(np.int64), budget)
    return AllocationResult(k, top.expected_sojourn(k), int(k.sum()), T.size)


def min_processors_table(
    top: Topology, t_max: float, *, k_cap: int = 1 << 20
) -> AllocationResult:
    """Program (6) on the gain table: binary-search the increment count.

    Greedy allocations are nested (the m-increment allocation is a prefix of
    the (m+1)-increment one), and E[T] is non-increasing along that chain,
    so the smallest m with ``E[T](k(m)) <= T_max`` is found by bisection —
    each probe is a table selection plus one exact scalar ``E[T]``
    recompute (the same model value the caller sees, as in
    :func:`min_processors`).  The table widens geometrically until the
    constraint is reachable or ``k_cap`` is hit.
    """
    lam = top.arrival_rates
    floor = sum(
        lam[i] / top.lam0_total / op.mu for i, op in enumerate(top.operators) if lam[i] > 0
    )
    if t_max < floor:
        raise InsufficientResourcesError(k_cap, k_cap, top.min_feasible_allocation())
    k0 = top.min_feasible_allocation()
    total0 = int(k0.sum())
    et0 = top.expected_sojourn(k0)
    if et0 <= t_max:
        return AllocationResult(k0, et0, total0, 0)
    evals = 0
    budget = 256
    while True:
        budget = min(budget, max(k_cap - total0, 0))
        k_hi = int(k0.max()) + budget
        T, G = gain_table(top, k_hi)
        evals += T.size
        take_full = greedy_increments(G, k0.astype(np.int64), budget)
        k_full = k0 + take_full
        et_full = top.expected_sojourn(k_full)
        if et_full <= t_max:
            lo, hi = 1, int(take_full.sum())  # hi is feasible; find minimal m
            while lo < hi:
                mid = (lo + hi) // 2
                k_mid = k0 + greedy_increments(G, k0.astype(np.int64), mid)
                if top.expected_sojourn(k_mid) <= t_max:
                    hi = mid
                else:
                    lo = mid + 1
            k = k0 + greedy_increments(G, k0.astype(np.int64), lo)
            return AllocationResult(k, top.expected_sojourn(k), int(k.sum()), evals)
        exhausted = int(take_full.sum()) < budget  # no positive gains left
        if exhausted or budget >= k_cap - total0:
            raise InsufficientResourcesError(int(k_full.sum()), k_cap, k_full)
        budget *= 4


def min_processors(
    top: Topology, t_max: float, *, k_cap: int = 1 << 20
) -> AllocationResult:
    """Program (6): min sum k_i s.t. E[T](k) <= T_max (greedy, paper §III-C).

    Starts from the minimal feasible allocation and adds the max-marginal-
    benefit processor until the constraint holds.  ``k_cap`` bounds the
    search (raises if T_max is unreachable, e.g. below the service-time
    floor sum_i v_i / mu_i which no amount of processors can beat).
    """
    lam = top.arrival_rates
    # Constraint floor: E[T] >= sum_i (lam_i/lam0) * (1/mu_i) even with k=inf.
    floor = sum(
        lam[i] / top.lam0_total / op.mu for i, op in enumerate(top.operators) if lam[i] > 0
    )
    if t_max < floor:
        raise InsufficientResourcesError(
            k_cap, k_cap, top.min_feasible_allocation()
        )
    k = top.min_feasible_allocation()
    evals = 0
    heap: list[tuple[float, int]] = []
    for i in range(top.n):
        if lam[i] == 0.0:
            continue
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heap.append((-d, i))
    heapq.heapify(heap)
    et = top.expected_sojourn(k)
    total = int(k.sum())
    while et > t_max and heap and total < k_cap:
        neg_d, i = heapq.heappop(heap)
        gain = -neg_d
        if gain <= 0.0:
            break
        k[i] += 1
        total += 1
        # E[T] drops by lam_i * gain / lam0 (Eq. 3 weighting) — an O(1)
        # running estimate that accumulates float error over thousands of
        # increments, so it only steers the loop; feasibility is judged on
        # the exactly recomputed value below.
        et -= gain / top.lam0_total
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heapq.heappush(heap, (-d, i))
    # Re-derive the true E[T](k): near T_max the drifted running value can
    # mis-accept (accept/raise must use the same model the caller sees).
    et = top.expected_sojourn(k)
    while et > t_max and heap and total < k_cap:
        # Drift made the loop exit one (or a few) processors early: keep
        # adding by exact marginal benefit until the true E[T] satisfies
        # the constraint or no processor helps.
        neg_d, i = heapq.heappop(heap)
        if -neg_d <= 0.0:
            break
        k[i] += 1
        total += 1
        et = top.expected_sojourn(k)
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heapq.heappush(heap, (-d, i))
    if et > t_max:
        raise InsufficientResourcesError(total, k_cap, k)
    return AllocationResult(k, et, total, evals)


def allocate(
    top: Topology,
    *,
    k_max: int | None = None,
    t_max: float | None = None,
) -> AllocationResult:
    """Dispatch: Program (4) when k_max given, Program (6) when t_max given.

    When both are given: solve Program (6) first; if its total exceeds
    k_max, fall back to Program (4) at k_max (best effort under the lease) —
    this is the scheduler's "not enough machines yet, do the best we can
    while the negotiator acquires more" path.

    Solves on the batched gain-table path (DESIGN.md §12).
    """
    if k_max is None and t_max is None:
        raise ValueError("need k_max and/or t_max")
    if t_max is not None:
        try:
            res = min_processors_table(top, t_max)
            if k_max is None or res.total <= k_max:
                return res
        except InsufficientResourcesError:
            if k_max is None:
                raise
    assert k_max is not None
    return assign_processors_table(top, k_max)


def brute_force_optimal(top: Topology, k_max: int) -> tuple[np.ndarray, float]:
    """Exhaustive Program-(4) solver for tests (tiny instances only)."""
    k_min = top.min_feasible_allocation()
    if int(k_min.sum()) > k_max:
        raise InsufficientResourcesError(int(k_min.sum()), k_max, k_min)
    best_k, best_t = None, math.inf
    n = top.n

    def rec(i: int, remaining: int, k: list[int]) -> None:
        nonlocal best_k, best_t
        if i == n:
            t = top.expected_sojourn(np.array(k))
            if t < best_t:
                best_t, best_k = t, np.array(k)
            return
        for extra in range(remaining + 1):
            rec(i + 1, remaining - extra, k + [int(k_min[i]) + extra])

    rec(0, k_max - int(k_min.sum()), [])
    assert best_k is not None
    return best_k, best_t
