"""DRS resource allocation — paper Algorithm 1 and Programs (4) and (6).

Two solvers are provided for Program (4) (min E[T] s.t. sum k_i <= K_max):

* :func:`assign_processors_naive` — the paper's Algorithm 1 verbatim:
  each round recomputes every operator's marginal benefit and increments the
  argmax.  O(K_max * N) sojourn evaluations.  Kept as the reference.
* :func:`assign_processors` — heap-based: because the marginal benefit
  ``delta_i(k) = lam_i (E[T_i](k) - E[T_i](k+1))`` is non-increasing in k
  (convexity, paper Ineq. 5), a max-heap of each operator's *next* gain
  yields the identical allocation in O((K_max - sum k_min) log N).
  This is a beyond-paper efficiency win needed at K_max ~ thousands of chips
  (see benchmarks/bench_overhead.py, the Table-II reproduction).

Program (6) (min sum k_i s.t. E[T] <= T_max) is solved by the same greedy
run until the constraint is met (:func:`min_processors`), as in the paper.

Theorem 1 (optimality of the greedy for Program 4) is exercised in
tests/test_allocator.py against brute-force enumeration.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass

import numpy as np

from .jackson import Topology

__all__ = [
    "InsufficientResourcesError",
    "AllocationResult",
    "assign_processors",
    "assign_processors_naive",
    "min_processors",
    "allocate",
]


class InsufficientResourcesError(RuntimeError):
    """Paper Algorithm 1 lines 4-6: sum of minimal k_i exceeds K_max."""

    def __init__(self, needed: int, k_max: int, k_min: np.ndarray):
        super().__init__(
            f"minimum feasible allocation needs {needed} processors but "
            f"K_max={k_max} (per-operator minima: {k_min.tolist()})"
        )
        self.needed = needed
        self.k_max = k_max
        self.k_min = k_min


@dataclass(frozen=True)
class AllocationResult:
    k: np.ndarray  # processors per operator
    expected_sojourn: float  # model E[T](k), seconds
    total: int  # sum k_i
    evaluations: int  # number of E[T_i] evaluations performed (cost metric)

    def as_dict(self) -> dict:
        return {
            "k": self.k.tolist(),
            "expected_sojourn": self.expected_sojourn,
            "total": self.total,
            "evaluations": self.evaluations,
        }


def _marginal(top: Topology, lam: np.ndarray, i: int, k_i: int) -> float:
    """delta_i = lam_i * (E[T_i](k_i) - E[T_i](k_i+1)), Algorithm 1 line 9."""
    op = top.operators[i]
    t0 = op.sojourn(k_i, lam[i])
    t1 = op.sojourn(k_i + 1, lam[i])
    if math.isinf(t0):
        return math.inf
    return lam[i] * (t0 - t1)


def assign_processors_naive(top: Topology, k_max: int) -> AllocationResult:
    """Paper Algorithm 1, literal transcription (reference implementation)."""
    lam = top.arrival_rates
    k = top.min_feasible_allocation()
    evals = 0
    if int(k.sum()) > k_max:
        raise InsufficientResourcesError(int(k.sum()), k_max, k)
    while int(k.sum()) < k_max:
        deltas = np.empty(top.n)
        for i in range(top.n):
            deltas[i] = _marginal(top, lam, i, int(k[i]))
            evals += 2
        j = int(np.argmax(deltas))
        if deltas[j] <= 0.0:
            break  # no operator benefits; adding more would be pure waste
        k[j] += 1
    return AllocationResult(k, top.expected_sojourn(k), int(k.sum()), evals)


def assign_processors(top: Topology, k_max: int) -> AllocationResult:
    """Heap-based Algorithm 1 — identical output, O((K-K0) log N)."""
    lam = top.arrival_rates
    k = top.min_feasible_allocation()
    evals = 0
    total = int(k.sum())
    if total > k_max:
        raise InsufficientResourcesError(total, k_max, k)
    # Max-heap of (-delta, i); each operator's entry reflects its next gain.
    heap: list[tuple[float, int]] = []
    for i in range(top.n):
        if lam[i] == 0.0:
            continue
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heap.append((-d, i))
    heapq.heapify(heap)
    while total < k_max and heap:
        neg_d, i = heapq.heappop(heap)
        if -neg_d <= 0.0:
            break
        k[i] += 1
        total += 1
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heapq.heappush(heap, (-d, i))
    return AllocationResult(k, top.expected_sojourn(k), total, evals)


def min_processors(
    top: Topology, t_max: float, *, k_cap: int = 1 << 20
) -> AllocationResult:
    """Program (6): min sum k_i s.t. E[T](k) <= T_max (greedy, paper §III-C).

    Starts from the minimal feasible allocation and adds the max-marginal-
    benefit processor until the constraint holds.  ``k_cap`` bounds the
    search (raises if T_max is unreachable, e.g. below the service-time
    floor sum_i v_i / mu_i which no amount of processors can beat).
    """
    lam = top.arrival_rates
    # Constraint floor: E[T] >= sum_i (lam_i/lam0) * (1/mu_i) even with k=inf.
    floor = sum(
        lam[i] / top.lam0_total / op.mu for i, op in enumerate(top.operators) if lam[i] > 0
    )
    if t_max < floor:
        raise InsufficientResourcesError(
            k_cap, k_cap, top.min_feasible_allocation()
        )
    k = top.min_feasible_allocation()
    evals = 0
    heap: list[tuple[float, int]] = []
    for i in range(top.n):
        if lam[i] == 0.0:
            continue
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heap.append((-d, i))
    heapq.heapify(heap)
    et = top.expected_sojourn(k)
    total = int(k.sum())
    while et > t_max and heap and total < k_cap:
        neg_d, i = heapq.heappop(heap)
        gain = -neg_d
        if gain <= 0.0:
            break
        k[i] += 1
        total += 1
        # E[T] drops by lam_i * gain / lam0 (Eq. 3 weighting) — an O(1)
        # running estimate that accumulates float error over thousands of
        # increments, so it only steers the loop; feasibility is judged on
        # the exactly recomputed value below.
        et -= gain / top.lam0_total
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heapq.heappush(heap, (-d, i))
    # Re-derive the true E[T](k): near T_max the drifted running value can
    # mis-accept (accept/raise must use the same model the caller sees).
    et = top.expected_sojourn(k)
    while et > t_max and heap and total < k_cap:
        # Drift made the loop exit one (or a few) processors early: keep
        # adding by exact marginal benefit until the true E[T] satisfies
        # the constraint or no processor helps.
        neg_d, i = heapq.heappop(heap)
        if -neg_d <= 0.0:
            break
        k[i] += 1
        total += 1
        et = top.expected_sojourn(k)
        d = _marginal(top, lam, i, int(k[i]))
        evals += 2
        heapq.heappush(heap, (-d, i))
    if et > t_max:
        raise InsufficientResourcesError(total, k_cap, k)
    return AllocationResult(k, et, total, evals)


def allocate(
    top: Topology,
    *,
    k_max: int | None = None,
    t_max: float | None = None,
) -> AllocationResult:
    """Dispatch: Program (4) when k_max given, Program (6) when t_max given.

    When both are given: solve Program (6) first; if its total exceeds
    k_max, fall back to Program (4) at k_max (best effort under the lease) —
    this is the scheduler's "not enough machines yet, do the best we can
    while the negotiator acquires more" path.
    """
    if k_max is None and t_max is None:
        raise ValueError("need k_max and/or t_max")
    if t_max is not None:
        try:
            res = min_processors(top, t_max)
            if k_max is None or res.total <= k_max:
                return res
        except InsufficientResourcesError:
            if k_max is None:
                raise
    assert k_max is not None
    return assign_processors(top, k_max)


def brute_force_optimal(top: Topology, k_max: int) -> tuple[np.ndarray, float]:
    """Exhaustive Program-(4) solver for tests (tiny instances only)."""
    k_min = top.min_feasible_allocation()
    if int(k_min.sum()) > k_max:
        raise InsufficientResourcesError(int(k_min.sum()), k_max, k_min)
    best_k, best_t = None, math.inf
    n = top.n

    def rec(i: int, remaining: int, k: list[int]) -> None:
        nonlocal best_k, best_t
        if i == n:
            t = top.expected_sojourn(np.array(k))
            if t < best_t:
                best_t, best_k = t, np.array(k)
            return
        for extra in range(remaining + 1):
            rec(i + 1, remaining - extra, k + [int(k_min[i]) + extra])

    rec(0, k_max - int(k_min.sum()), [])
    assert best_k is not None
    return best_k, best_t
