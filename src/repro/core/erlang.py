"""Erlang M/M/k sojourn-time model (paper Eq. 1-2).

Implements the per-operator performance model of DRS: operator *i* with
``k`` parallel identical processors, Poisson arrivals at rate ``lam`` and
exponential service at rate ``mu`` per processor is an M/M/k queue.  The
expected sojourn time (queueing delay + service) is

    E[T](k) = ErlangC(k, a) / (k*mu - lam) + 1/mu,      a = lam/mu,

which is algebraically identical to paper Eq. (1)-(2) (the paper writes the
waiting term as ``a^k pi_0 / (k! (1-rho)^2 mu k)``).

Two implementations are provided:

* :func:`expected_sojourn_factorial` — the paper-literal factorial form.
  It overflows for k beyond ~170 in float64 and is kept as the oracle for
  small k.
* :func:`expected_sojourn` — the numerically stable Erlang-B recursion
  ``B(0)=1; B(k) = a*B(k-1) / (k + a*B(k-1))`` followed by the standard
  B→C conversion.  Exact to ~1e-12 relative and safe for k in the tens of
  thousands (we allocate across chips of a 1000+ node fleet).

Both return ``math.inf`` when the operator is unstable (``k*mu <= lam``),
matching the paper's Eq. (1) second branch.
"""

from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

__all__ = [
    "erlang_b",
    "erlang_c",
    "expected_sojourn",
    "expected_sojourn_factorial",
    "expected_queue_delay",
    "min_stable_k",
    "sojourn_curve",
    "marginal_benefit",
]


def erlang_b(k: int, a: float) -> float:
    """Erlang-B blocking probability B(k, a) via the stable recursion.

    B(0) = 1;  B(j) = a*B(j-1) / (j + a*B(j-1)).
    """
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    if a < 0:
        raise ValueError(f"offered load a must be >= 0, got {a}")
    b = 1.0
    for j in range(1, k + 1):
        b = a * b / (j + a * b)
    return b


def erlang_c(k: int, a: float) -> float:
    """Erlang-C probability that an arrival must wait, C(k, a).

    Valid for a < k (stable queue).  Uses C = k*B / (k - a*(1-B)).
    """
    if a >= k:
        return 1.0  # degenerate; callers guard stability separately
    b = erlang_b(k, a)
    return k * b / (k - a * (1.0 - b))


def expected_sojourn(k: int, lam: float, mu: float) -> float:
    """E[T](k) for an M/M/k operator — stable form (paper Eq. 1).

    Returns +inf when k*mu <= lam (unstable queue, paper's second branch).
    """
    if mu <= 0:
        raise ValueError(f"service rate mu must be > 0, got {mu}")
    if lam < 0:
        raise ValueError(f"arrival rate lam must be >= 0, got {lam}")
    if lam == 0.0:
        return 1.0 / mu
    a = lam / mu
    if k <= a:  # k*mu <= lam
        return math.inf
    c = erlang_c(k, a)
    wait = c / (k * mu - lam)
    return wait + 1.0 / mu


def expected_queue_delay(k: int, lam: float, mu: float) -> float:
    """Expected time spent waiting in queue only, E[W] = E[T] - 1/mu."""
    t = expected_sojourn(k, lam, mu)
    return t - 1.0 / mu if math.isfinite(t) else math.inf


def expected_sojourn_factorial(k: int, lam: float, mu: float) -> float:
    """Paper-literal Eq. (1)-(2) with explicit factorials.

    Oracle for tests; overflows for large k — callers should prefer
    :func:`expected_sojourn`.
    """
    if lam == 0.0:
        return 1.0 / mu
    a = lam / mu
    if k <= a:
        return math.inf
    rho = a / k
    # pi_0 per Eq. (2)
    s = sum(a**l / math.factorial(l) for l in range(k))
    s += a**k / (math.factorial(k) * (1.0 - rho))
    pi0 = 1.0 / s
    wait = (a**k) * pi0 / (math.factorial(k) * (1.0 - rho) ** 2 * mu * k)
    return wait + 1.0 / mu


def min_stable_k(lam: float, mu: float) -> int:
    """Smallest k with finite E[T]: ceil(lam/mu), bumped when lam/mu is integral.

    Paper Algorithm 1 initialises k_i = ceil(lam_i/mu_i); when lam/mu is an
    exact integer that k gives k*mu == lam which is *unstable*, so one more
    processor is required for a finite sojourn time.  (The paper's pseudocode
    glosses this; its Eq. (1) makes k = lam/mu infinite, and the while-loop
    would immediately add the extra processor anyway.)
    """
    if lam == 0.0:
        return 1
    a = lam / mu
    k = math.ceil(a)
    if k <= a:  # a integral
        k += 1
    return max(k, 1)


def sojourn_curve(lam: float, mu: float, k_lo: int, k_hi: int) -> np.ndarray:
    """Vector of E[T](k) for k in [k_lo, k_hi], sharing one B-recursion pass."""
    if k_lo < 0 or k_hi < k_lo:
        raise ValueError(f"bad range [{k_lo}, {k_hi}]")
    if lam == 0.0:
        return np.full(k_hi - k_lo + 1, 1.0 / mu)
    a = lam / mu
    out = np.empty(k_hi - k_lo + 1, dtype=np.float64)
    b = 1.0
    for j in range(1, k_hi + 1):
        b = a * b / (j + a * b)
        if j >= k_lo:
            if j <= a:
                out[j - k_lo] = math.inf
            else:
                c = j * b / (j - a * (1.0 - b))
                out[j - k_lo] = c / (j * mu - lam) + 1.0 / mu
    if k_lo == 0:
        out[0] = math.inf
    return out


def marginal_benefit(k: int, lam: float, mu: float) -> float:
    """delta(k) = lam * (E[T](k) - E[T](k+1)) — Algorithm 1 line 9.

    By convexity of E[T](k) (paper Ineq. 5) this is non-increasing in k,
    which is what makes both the greedy and the heap allocator optimal.
    Returns +inf when E[T](k) is infinite (processor is mandatory).
    """
    t_k = expected_sojourn(k, lam, mu)
    t_k1 = expected_sojourn(k + 1, lam, mu)
    if math.isinf(t_k):
        return math.inf
    return lam * (t_k - t_k1)


@lru_cache(maxsize=65536)
def _cached_sojourn(k: int, lam: float, mu: float) -> float:
    return expected_sojourn(k, lam, mu)


def cached_sojourn(k: int, lam: float, mu: float) -> float:
    """Memoised E[T](k) — the scheduler loop re-evaluates the same points."""
    return _cached_sojourn(k, float(lam), float(mu))
