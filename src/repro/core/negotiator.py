"""DRS resource negotiator (paper §IV + Appendix B-B).

Works *below* the CSP resource manager: leases and releases physical
resources (paper: YARN machines; here: TPU pods / host VMs).  The scheduler
asks for a target processor count; the negotiator translates that into
machine leases (machines come in fixed sizes, e.g. 5 executors per machine
in the paper's cluster, 256 chips per pod here) and tracks what is live.

Elasticity events (pod loss, lease revocation) surface here first; the
scheduler then re-runs Program (4) with the shrunken K_max — see
training/elastic.py for the training-side reaction (checkpoint restore on
a smaller mesh).
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Callable

__all__ = ["Machine", "ResourcePool", "Negotiator", "LeaseChange"]


@dataclass(frozen=True)
class Machine:
    machine_id: str
    processors: int  # executors (paper) / chips (pod)
    speed: float = 1.0  # heterogeneity: relative per-processor speed


@dataclass(frozen=True)
class LeaseChange:
    acquired: tuple[Machine, ...]
    released: tuple[Machine, ...]
    k_max_before: int
    k_max_after: int

    @property
    def delta(self) -> int:
        return self.k_max_after - self.k_max_before


class ResourcePool:
    """The provider side: a finite inventory of machines (cloud quota)."""

    def __init__(self, machines: list[Machine]):
        self._avail: dict[str, Machine] = {m.machine_id: m for m in machines}
        self._leased: dict[str, Machine] = {}
        self._lock = threading.Lock()

    @property
    def available(self) -> list[Machine]:
        with self._lock:
            return list(self._avail.values())

    @property
    def leased(self) -> list[Machine]:
        with self._lock:
            return list(self._leased.values())

    def lease(self, machine_id: str) -> Machine:
        with self._lock:
            m = self._avail.pop(machine_id)
            self._leased[machine_id] = m
            return m

    def release(self, machine_id: str) -> Machine:
        with self._lock:
            m = self._leased.pop(machine_id)
            self._avail[machine_id] = m
            return m

    def revoke(self, machine_id: str) -> Machine:
        """Provider-initiated loss (spot preemption / pod failure)."""
        with self._lock:
            return self._leased.pop(machine_id)


class Negotiator:
    """Leases machines to reach a requested processor budget.

    ``reserve`` processors are held back for system operators (the paper
    reserves 3 of its 25 executors for spouts + DRS itself).
    """

    def __init__(
        self,
        pool: ResourcePool,
        *,
        reserve: int = 0,
        on_change: Callable[[LeaseChange], None] | None = None,
    ):
        self.pool = pool
        self.reserve = reserve
        self.on_change = on_change
        self._lock = threading.Lock()

    @property
    def k_max(self) -> int:
        """Processors currently available to the application."""
        return max(0, sum(m.processors for m in self.pool.leased) - self.reserve)

    def ensure(self, k_target: int) -> LeaseChange:
        """Grow/shrink leases so that k_max >= k_target (grow) or release
        whole machines that are no longer needed (shrink).

        Machines are leased smallest-first when growing (minimise waste) and
        released largest-surplus-first when shrinking.  Never releases below
        k_target.
        """
        with self._lock:
            before = self.k_max
            acquired: list[Machine] = []
            released: list[Machine] = []
            need = k_target + self.reserve
            have = sum(m.processors for m in self.pool.leased)
            if have < need:
                for m in sorted(self.pool.available, key=lambda m: m.processors):
                    if have >= need:
                        break
                    acquired.append(self.pool.lease(m.machine_id))
                    have += m.processors
            elif have > need:
                for m in sorted(self.pool.leased, key=lambda m: -m.processors):
                    if have - m.processors >= need:
                        self.pool.release(m.machine_id)
                        released.append(m)
                        have -= m.processors
            change = LeaseChange(tuple(acquired), tuple(released), before, self.k_max)
            if self.on_change and (acquired or released):
                self.on_change(change)
            return change

    def machines_for(self, k: int, per_machine: int) -> int:
        """How many machines of a given size cover k processors."""
        return math.ceil(k / per_machine)

    def handle_revocation(self, machine_id: str) -> LeaseChange:
        """Provider preempted a machine: update books, notify scheduler."""
        with self._lock:
            before = self.k_max + self.reserve
            m = self.pool.revoke(machine_id)
            change = LeaseChange((), (m,), before - self.reserve, self.k_max)
            if self.on_change:
                self.on_change(change)
            return change
