"""Batched closed-loop controller — the measure -> model -> rebalance loop
as pure functions over stacked arrays (DESIGN.md §14).

PR 3 batched the analytic tables and PR 4 batched the simulator, but the
*decision* path — overload detection, offered-load clamping, Programs
(4)/(6), hysteresis, the improvement and cost/benefit gates — was still
scalar Python living inside :class:`~repro.core.scheduler.DRSScheduler`,
executed once per scenario per tick.  This module extracts that math into
a stateless controller that operates on ``[B, N]`` snapshot stacks:

* **float64 numpy twin** — :func:`tick_batch` / :func:`decide_single` are
  a verbatim port of the scheduler's decision flow.  The measurement
  plane (overload masks, throughput-capped propagation, offered-load
  clamping) is vectorized across the batch; the per-scenario allocator
  and negotiator calls replay the exact scalar float ops (the same
  table-driven Programs (4)/(6) of core/allocator.py), so a B=1 tick is
  **bit-identical** to the pre-extraction scheduler.  ``DRSScheduler``
  is now a thin stateful shell over these functions.
* **jit jax path** — :func:`make_decide_jax` compiles the whole decide
  (batched Jackson solve via ``solve_traffic_batch_jax``, batched
  offered-load clamping, one table pass through ``kernels/erlang_c``,
  Program-4 allocation as a masked top-R selection through
  ``kernels/gain_topr``, vectorized improvement + cost gates) into ONE
  program over the ``[B, N]`` fleet; :func:`make_fused_loop` fuses it
  with the batch simulator's window step in a single ``lax.scan`` so a
  full simulate -> measure -> decide -> apply tick sequence is one XLA
  computation (no Python between ticks).

What stays in Python (the batch boundaries): per-scenario
:class:`~repro.core.negotiator.Negotiator` leases (``ensure`` is a
side-effecting pool mutation), custom
:class:`~repro.core.rebalance.RebalanceCostModel` subclasses /
:class:`~repro.core.rebalance.ExecutableCache` lookups, and the engine
``apply_allocation`` call.  The fused path therefore supports statically
budgeted scenarios end-to-end; negotiated scenarios run the same batched
twin with the lease hooks invoked between ticks.

Machine-class heterogeneity (paper §III-A) is wired through ``speed``:
a per-operator machine-class speed factor scales the effective service
rate ``mu_eff = mu_hat * speed`` everywhere the model consumes it —
equivalent to the uniform-speed case of
:func:`~repro.core.heterogeneous.assign_heterogeneous` (mean-speed
M/M/k), which tests/test_heterogeneous.py asserts.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Callable, NamedTuple, Sequence

import numpy as np

from .allocator import (
    AllocationResult,
    InsufficientResourcesError,
    assign_processors,
    assign_processors_table,
    min_processors,
    min_processors_table,
)
from .jackson import OperatorSpec, Topology, UnstableTopologyError
from .measurer import MeasurementBatch
from .rebalance import RebalanceCostModel, RebalancePlan

__all__ = [
    "ACTIONS",
    "ALLOCATORS",
    "ControllerStatic",
    "ControllerParams",
    "ControllerState",
    "CompactionConfig",
    "DecideCache",
    "TwinCompactionState",
    "init_decide_cache",
    "FusedLoop",
    "RowDecision",
    "BatchDecision",
    "overloaded_mask_batch",
    "capped_mask_batch",
    "clamp_row",
    "decide_single",
    "tick_batch",
    "pad_static",
    "pad_params",
    "make_decide_jax",
    "make_fused_loop",
]

# Action vocabulary (codes shared by the numpy twin and the jit path).
# "proactive" (appended last so earlier codes stay stable) marks an
# allocation committed by the forecast/MPC planner ahead of any trigger.
ACTIONS = (
    "none",
    "rebalance",
    "scale_out",
    "scale_in",
    "infeasible",
    "overloaded",
    "rebalance_hint",
    "proactive",
)
_CODE = {name: i for i, name in enumerate(ACTIONS)}

# Program (4)/(6) solver pairs, keyed like SchedulerConfig.allocator.
ALLOCATORS = {
    "table": (assign_processors_table, min_processors_table),
    "heap": (assign_processors, min_processors),
}

# An operator shedding more than this fraction of its capacity is
# overloaded even if the smoothed arrival rate dips below capacity
# (EWMA lag under bursty arrivals) — DRSScheduler.DROP_TRIGGER_FRACTION.
DROP_TRIGGER_FRACTION = 0.01


# --------------------------------------------------------------------------- #
# Static structure + per-scenario parameters
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ControllerStatic:
    """Declared per-scenario structure, padded to the batch-wide N_max.

    ``names`` keeps each scenario's operator names (reason strings +
    Topology reconstruction); array lanes beyond ``n_ops[b]`` are inert
    padding (no routing, no arrivals, ``active`` False).
    """

    base_routing: np.ndarray  # [B, N, N] declared multiplicities
    group: np.ndarray  # [B, N] bool: chip-gang scaling
    alpha: np.ndarray  # [B, N] group efficiency rolloff
    active: np.ndarray  # [B, N] bool: real operator lanes
    speed: np.ndarray  # [B, N] machine-class speed factors (1 = reference)
    n_ops: np.ndarray  # [B] operators per scenario
    names: tuple  # per-scenario tuple of operator names

    @property
    def batch(self) -> int:
        return self.base_routing.shape[0]

    @property
    def n(self) -> int:
        return self.base_routing.shape[1]

    @classmethod
    def from_graphs(cls, graphs: Sequence, *, speed=None) -> "ControllerStatic":
        """Stack B AppGraphs (padded) into one static bundle."""
        b = len(graphs)
        n = max(g.n for g in graphs)
        routing = np.zeros((b, n, n))
        group = np.zeros((b, n), dtype=bool)
        alpha = np.zeros((b, n))
        active = np.zeros((b, n), dtype=bool)
        spd = np.ones((b, n))
        n_ops = np.zeros(b, dtype=np.int64)
        names = []
        for bi, g in enumerate(graphs):
            ni = g.n
            routing[bi, :ni, :ni] = g.routing_matrix()
            scaling, ga = g.scaling_lists()
            group[bi, :ni] = [s == "group" for s in scaling]
            alpha[bi, :ni] = ga
            active[bi, :ni] = True
            n_ops[bi] = ni
            names.append(tuple(g.names))
            if speed is not None and speed[bi] is not None:
                spd[bi, :ni] = speed[bi]
        return cls(routing, group, alpha, active, spd, n_ops, tuple(names))


@dataclass(frozen=True)
class ControllerParams:
    """Per-scenario decision parameters (SchedulerConfig, stacked).

    ``t_max`` uses NaN for "no real-time constraint"; ``k_max`` is the
    budget *resolved at tick entry* (the static config value, or the
    negotiator's current lease — the caller re-reads it each tick).
    """

    t_max: np.ndarray  # [B] float (NaN = Program 4 only)
    k_max: np.ndarray  # [B] int64 resolved budget
    headroom: np.ndarray  # [B]
    scale_in_hysteresis: np.ndarray  # [B]
    min_improvement: np.ndarray  # [B]
    horizon_seconds: np.ndarray  # [B]
    allocator: tuple  # [B] "table" | "heap"
    fused_decide: bool = False  # dispatch the decide to kernels/decide_fused

    @classmethod
    def stack(cls, configs: Sequence, k_max: Sequence[int]) -> "ControllerParams":
        """From B SchedulerConfig-likes + resolved per-scenario budgets."""
        per_lane = [bool(getattr(c, "fused_decide", False)) for c in configs]
        flags = set(per_lane)
        if len(flags) > 1:
            on = [i for i, f in enumerate(per_lane) if f]
            off = [i for i, f in enumerate(per_lane) if not f]
            raise ValueError(
                "fused_decide must agree across a stacked batch (one jit "
                "program serves every scenario lane); scenario indices "
                f"{on} set fused_decide=True while {off} leave it False"
            )
        return cls(
            t_max=np.array(
                [np.nan if c.t_max is None else float(c.t_max) for c in configs]
            ),
            k_max=np.asarray(k_max, dtype=np.int64),
            headroom=np.array([c.headroom for c in configs]),
            scale_in_hysteresis=np.array([c.scale_in_hysteresis for c in configs]),
            min_improvement=np.array([c.min_improvement for c in configs]),
            horizon_seconds=np.array([c.horizon_seconds for c in configs]),
            allocator=tuple(c.allocator for c in configs),
            fused_decide=flags.pop() if flags else False,
        )


# --------------------------------------------------------------------------- #
# Batch-axis padding (device-mesh sharding needs B % device count == 0)
# --------------------------------------------------------------------------- #
def pad_static(static: ControllerStatic, b_total: int) -> ControllerStatic:
    """Append ``b_total - B`` inert scenario lanes: no operators
    (``n_ops = 0``), ``active`` all-False, zero routing/alpha, unit speed.
    Padded lanes provably decide ``"none"`` with an unchanged allocation
    (tests/test_mesh_control.py asserts this bit-for-bit) so they never
    influence real decisions — the masked-lane contract DESIGN.md §16."""
    b, n = static.batch, static.n
    if b_total < b:
        raise ValueError(f"b_total {b_total} < batch {b}")
    if b_total == b:
        return static
    pad = b_total - b
    return ControllerStatic(
        base_routing=np.concatenate(
            [static.base_routing, np.zeros((pad, n, n))], axis=0
        ),
        group=np.concatenate([static.group, np.zeros((pad, n), dtype=bool)]),
        alpha=np.concatenate([static.alpha, np.zeros((pad, n))]),
        active=np.concatenate([static.active, np.zeros((pad, n), dtype=bool)]),
        speed=np.concatenate([static.speed, np.ones((pad, n))]),
        n_ops=np.concatenate([static.n_ops, np.zeros(pad, dtype=np.int64)]),
        names=static.names + ((),) * pad,
    )


def pad_params(params: ControllerParams, b_total: int) -> ControllerParams:
    """Decision parameters for inert padded lanes: no constraint
    (``t_max = NaN``), zero budget, and an infinite improvement gate —
    every gate in the decide is provably closed on a padded lane."""
    b = params.k_max.shape[0]
    if b_total < b:
        raise ValueError(f"b_total {b_total} < batch {b}")
    if b_total == b:
        return params
    pad = b_total - b
    return ControllerParams(
        t_max=np.concatenate([params.t_max, np.full(pad, np.nan)]),
        k_max=np.concatenate([params.k_max, np.zeros(pad, dtype=np.int64)]),
        headroom=np.concatenate([params.headroom, np.ones(pad)]),
        scale_in_hysteresis=np.concatenate(
            [params.scale_in_hysteresis, np.zeros(pad)]
        ),
        min_improvement=np.concatenate([params.min_improvement, np.full(pad, np.inf)]),
        horizon_seconds=np.concatenate([params.horizon_seconds, np.zeros(pad)]),
        allocator=params.allocator + ("table",) * pad,
        fused_decide=params.fused_decide,
    )


def _mesh_axis(mesh) -> tuple[str, int]:
    """The (axis name, device count) of a 1-D controller mesh."""
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"controller mesh must be 1-D (batch axis only); got axes "
            f"{mesh.axis_names}"
        )
    return mesh.axis_names[0], int(mesh.size)


def _padded_batch(b: int, n_shards: int) -> int:
    """B rounded up to a multiple of the shard count."""
    return -(-b // n_shards) * n_shards


# --------------------------------------------------------------------------- #
# Trigger-gated lane compaction (DESIGN.md §18)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class CompactionConfig:
    """Knobs for the sparse (trigger-gated) decide.

    ``b_active_cap`` is the static bucket ladder: ascending compacted
    widths, the largest of which must be the (per-shard) batch extent so
    a fully-triggered tick falls back to the dense decide.  ``None``
    derives it with :func:`repro.distributed.sharding.bucket_ladder`.

    The compaction is **exact, not approximate**: the decide is a pure
    function of ``(statics, lam_hat, mu_hat, drop_hat, lam0_hat, k)``,
    so a lane whose inputs are bitwise unchanged since it was last
    priced replays its cached outputs — which are, by purity, exactly
    what repricing would produce.  The trigger scan therefore marks a
    lane active when (a) it has no cached entry, (b) any decide input
    changed bitwise (NaN-tolerant: NaN == NaN for this purpose, since
    every consumer of a NaN measurement branches identically on it), or
    (c) the §11 overload mask fires — (c) is subsumed by (b) in steady
    state but is kept as a belt-and-braces guard so a hot lane can never
    ride the fast path.
    """

    b_active_cap: tuple[int, ...] | None = None


class DecideCache(NamedTuple):
    """Per-lane memo for the jit decide: the inputs it was last priced
    with and the outputs it produced (the dense "none"-row fast path
    replays these).  Every leaf is ``[B, ...]``-leading so a device mesh
    shards the whole cache with the same one-axis rule as the statics.

    The cache is deliberately NOT part of :class:`ControllerState`: a
    cold cache only makes the next tick price every lane (same outputs,
    more work), so checkpoints stay layout-independent — a restore into
    a loop with a different mesh/ladder shape resumes bit-identically
    (DESIGN.md §18).
    """

    ok: Any  # [B] bool: lane has a priced entry
    lam: Any  # [B, N] cached lam_hat
    mu: Any  # [B, N] cached mu_hat
    drop: Any  # [B, N] cached drop_hat
    lam0: Any  # [B] cached lam0_hat
    k: Any  # [B, N] int32 cached entry allocation
    code: Any  # [B] int32 cached action code
    k_next: Any  # [B, N] int32 cached post-decide allocation
    et_cur: Any  # [B] cached E[T] at entry allocation
    et_target: Any  # [B] cached E[T] at proposed allocation
    applied: Any  # [B] bool cached applied flag


def init_decide_cache(b: int, n: int, *, dtype=None) -> DecideCache:
    """Cold (all-lanes-invalid) cache — the first tick prices densely."""
    import jax.numpy as jnp

    dtype = jnp.zeros((), dtype=dtype).dtype  # canonical under the x64 flag
    return DecideCache(
        ok=jnp.zeros(b, dtype=bool),
        lam=jnp.zeros((b, n), dtype=dtype),
        mu=jnp.zeros((b, n), dtype=dtype),
        drop=jnp.zeros((b, n), dtype=dtype),
        lam0=jnp.zeros(b, dtype=dtype),
        k=jnp.zeros((b, n), dtype=jnp.int32),
        code=jnp.zeros(b, dtype=jnp.int32),
        k_next=jnp.zeros((b, n), dtype=jnp.int32),
        et_cur=jnp.zeros(b, dtype=dtype),
        et_target=jnp.zeros(b, dtype=dtype),
        applied=jnp.zeros(b, dtype=bool),
    )


def _resolve_ladder(compact, b: int) -> tuple[int, ...]:
    """The static bucket ladder for a (per-shard) batch extent ``b``."""
    from ..distributed.sharding import bucket_ladder

    cfg = compact if isinstance(compact, CompactionConfig) else CompactionConfig()
    if cfg.b_active_cap is None:
        return bucket_ladder(b)
    ladder = tuple(sorted({min(int(w), b) for w in cfg.b_active_cap} | {b}))
    if ladder[0] < 1:
        raise ValueError(f"bucket ladder widths must be >= 1: {cfg.b_active_cap}")
    return ladder


def _bucketed(ladder, b, mask, run_at_width, templates):
    """Gather -> compute -> scatter over the masked lanes at the smallest
    static ladder width that holds them (MoE-style capacity dispatch).

    ``run_at_width(gather_idx)`` receives ``[w]`` clipped lane indices
    and returns a tuple matching ``templates``; lanes outside the mask
    keep their template values.  Unused gather rows (the ``fill_value``
    tail, clipped into range) compute garbage that the drop-mode scatter
    discards — safe because every op in the decide is per-lane.
    """
    import jax
    import jax.numpy as jnp

    idx = jnp.nonzero(mask, size=b, fill_value=b)[0]
    sel = jnp.searchsorted(
        jnp.asarray(ladder, dtype=jnp.int32),
        mask.sum(dtype=jnp.int32),
        side="left",
    )

    def branch(w):
        def go(_):
            outs = run_at_width(jnp.clip(idx[:w], 0, b - 1))
            return tuple(
                t.at[idx[:w]].set(o, mode="drop")
                for t, o in zip(templates, outs)
            )

        return go

    return jax.lax.switch(sel, [branch(w) for w in ladder], 0)


def _make_compact_decide(core, b: int, ladder: tuple[int, ...]):
    """Wrap a dense decide core with the trigger scan + bucketed dispatch.

    ``decide(st, lam_hat, mu_hat, drop_hat, lam0_hat, k_current, cache)
    -> ((code, k_next, et_cur, et_target, applied), repriced, cache')``
    is bitwise identical to ``core(...)`` on every output: active lanes
    are gathered, priced at the compacted width, and scattered back;
    quiet lanes replay their cached row, which purity guarantees equals
    a fresh repricing (see :class:`CompactionConfig`).
    """
    import jax.numpy as jnp

    def _neq(a, c):
        # Bitwise-change test with NaN == NaN (a persistently-NaN
        # measurement must not keep a lane hot forever).
        return (a != c) & ~(jnp.isnan(a) & jnp.isnan(c))

    def decide(st, lam_hat, mu_hat, drop_hat, lam0_hat, k_current, cache):
        k_in = k_current.astype(jnp.int32)
        # --- trigger scan: O(B*N), no table/solve/top-R work ----------- #
        mu_eff = mu_hat * st["speed"]
        k_floor = jnp.maximum(k_in, 1).astype(lam_hat.dtype)
        eff = 1.0 / (1.0 + st["alpha"] * (k_floor - 1.0))
        capacity = jnp.where(
            st["group"], mu_eff * k_floor * eff, mu_eff * k_floor
        )
        valid = jnp.isfinite(lam_hat) & jnp.isfinite(mu_eff) & (mu_eff > 0)
        drops = jnp.nan_to_num(drop_hat, nan=0.0)
        hot = (
            valid & st["active"] & (
                (lam_hat >= capacity * (1.0 - 1e-9))
                | (drops > DROP_TRIGGER_FRACTION * capacity)
            )
        ).any(axis=-1)
        changed = (
            _neq(lam_hat, cache.lam).any(axis=-1)
            | _neq(mu_hat, cache.mu).any(axis=-1)
            | _neq(drop_hat, cache.drop).any(axis=-1)
            | _neq(lam0_hat, cache.lam0)
            | (k_in != cache.k).any(axis=-1)
        )
        repriced = ~cache.ok | changed | hot

        # --- compacted decide + cached-row fast path ------------------- #
        def price(g):
            st_g = {key: val[g] for key, val in st.items()}
            return core(
                st_g, lam_hat[g], mu_hat[g], drop_hat[g], lam0_hat[g], k_in[g]
            )

        code, k_next, et_cur, et_target, applied = _bucketed(
            ladder, b, repriced, price,
            (cache.code, cache.k_next, cache.et_cur, cache.et_target,
             cache.applied),
        )
        new_cache = DecideCache(
            ok=jnp.ones_like(cache.ok),
            lam=lam_hat, mu=mu_hat, drop=drop_hat, lam0=lam0_hat, k=k_in,
            code=code, k_next=k_next, et_cur=et_cur, et_target=et_target,
            applied=applied,
        )
        return (code, k_next, et_cur, et_target, applied), repriced, new_cache

    return decide


@dataclass
class TwinCompactionState:
    """Per-lane memo for the numpy twin's reactive decide (mutable,
    caller-owned; pass it to every :func:`tick_batch` of one run).

    Lanes with a negotiator ``ensure`` hook or a custom cost model are
    never memoized (their decide is side-effecting / stateful); for the
    rest, a bitwise-unchanged input tuple replays the cached
    :class:`RowDecision` — the same purity argument as the jit cache.
    Valid only for a fixed ``(static, params-other-than-k_max)``;
    ``k_max`` is compared per tick because negotiator leases move it.
    """

    valid: np.ndarray  # [B] bool
    lam: np.ndarray  # [B, N]
    mu: np.ndarray  # [B, N]
    drop: np.ndarray  # [B, N]
    lam0: np.ndarray  # [B]
    k: np.ndarray  # [B, N] int64
    k_max: np.ndarray  # [B] int64
    rows: list  # [B] RowDecision | None
    errors: list  # [B] Exception | None
    replayed: np.ndarray  # [B] bool: last tick's fast-path lanes (diagnostic)

    @classmethod
    def create(cls, b: int, n: int) -> "TwinCompactionState":
        return cls(
            valid=np.zeros(b, dtype=bool),
            lam=np.full((b, n), np.nan),
            mu=np.full((b, n), np.nan),
            drop=np.full((b, n), np.nan),
            lam0=np.full(b, np.nan),
            k=np.zeros((b, n), dtype=np.int64),
            k_max=np.zeros(b, dtype=np.int64),
            rows=[None] * b,
            errors=[None] * b,
            replayed=np.zeros(b, dtype=bool),
        )

    def hit(self, bi, lam, mu, drop, lam0, k, k_max) -> bool:
        return bool(
            self.valid[bi]
            and self.k_max[bi] == k_max
            and np.array_equal(self.k[bi, : len(k)], k)
            and np.array_equal(self.lam[bi, : len(lam)], lam, equal_nan=True)
            and np.array_equal(self.mu[bi, : len(mu)], mu, equal_nan=True)
            and np.array_equal(self.drop[bi, : len(drop)], drop, equal_nan=True)
            and (
                np.isnan(self.lam0[bi]) and np.isnan(lam0)
                or self.lam0[bi] == lam0
            )
        )

    def remember(self, bi, lam, mu, drop, lam0, k, k_max, row, error) -> None:
        self.valid[bi] = True
        self.lam[bi, : len(lam)] = lam
        self.mu[bi, : len(mu)] = mu
        self.drop[bi, : len(drop)] = drop
        self.lam0[bi] = lam0
        self.k[bi, : len(k)] = k
        self.k_max[bi] = k_max
        self.rows[bi] = row
        self.errors[bi] = error


# --------------------------------------------------------------------------- #
# Vectorized measurement plane
# --------------------------------------------------------------------------- #
def _source_mask(static: ControllerStatic) -> np.ndarray:
    """[B, N] bool: declared external-arrival entry points (no in-edges;
    a scenario with none falls back to operator 0 — the scalar rule)."""
    in_deg = static.base_routing.sum(axis=1)
    src = (in_deg == 0) & static.active
    for bi in range(static.batch):
        if not src[bi].any():
            src[bi, 0] = True
    return src


def effective_capacity(k, mu_eff, group, alpha) -> np.ndarray:
    """Per-operator service capacity at allocation ``k`` with the group
    efficiency curve applied (k floored at 1, mirroring the scalar
    ``overloaded_mask``)."""
    k_eff = np.maximum(np.asarray(k, dtype=np.int64), 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        eff = 1.0 / (1.0 + alpha * (k_eff - 1))
    return np.where(group, mu_eff * k_eff * eff, mu_eff * k_eff)


def overloaded_mask_batch(lam_hat, mu_eff, drop, k, group, alpha) -> np.ndarray:
    """[B, N] bool: measured offered load >= capacity, or sustained
    shedding — the vectorized twin of ``DRSScheduler.overloaded_mask``
    (same comparisons, so bit-identical decisions at any batch size)."""
    lam_hat = np.asarray(lam_hat, dtype=np.float64)
    mu_eff = np.asarray(mu_eff, dtype=np.float64)
    drops = np.nan_to_num(np.asarray(drop, dtype=np.float64), nan=0.0)
    capacity = effective_capacity(k, mu_eff, group, alpha)
    valid = np.isfinite(lam_hat) & np.isfinite(mu_eff) & (mu_eff > 0)
    with np.errstate(invalid="ignore"):
        hot = (lam_hat >= capacity * (1.0 - 1e-9)) | (
            drops > DROP_TRIGGER_FRACTION * capacity
        )
    return valid & hot


def capped_mask_batch(overloaded, base_routing, active=None) -> np.ndarray:
    """[B, N] bool: operators whose *measured arrival rate* is throughput-
    capped — transitively downstream of a saturated operator (vectorized
    ``DRSScheduler._capped_mask`` fixed point)."""
    overloaded = np.atleast_2d(np.asarray(overloaded, dtype=bool))
    routing = np.asarray(base_routing, dtype=np.float64)
    if routing.ndim == 2:
        routing = routing[None]
    adj = routing > 0  # [B, N, N]
    n = adj.shape[-1]
    out_capped = overloaded.copy()
    in_capped = np.zeros_like(overloaded)
    for _ in range(n):
        new_in = (adj & out_capped[:, :, None]).any(axis=1)
        new_out = overloaded | new_in
        if (new_in == in_capped).all() and (new_out == out_capped).all():
            break
        in_capped, out_capped = new_in, new_out
    if active is not None:
        in_capped = in_capped & np.asarray(active, dtype=bool)
    return in_capped


# --------------------------------------------------------------------------- #
# Offered-load clamping (the topology_from math) — scalar row port
# --------------------------------------------------------------------------- #
def clamp_row(
    names: Sequence[str],
    base_routing: np.ndarray,
    lam_hat: np.ndarray,
    mu_hat: np.ndarray,
    lam0_hat: float,
    overloaded: np.ndarray,
    capped: np.ndarray,
    scaling: Sequence[str],
    group_alpha: Sequence[float],
    speed: np.ndarray | None = None,
) -> Topology:
    """Rebuild one scenario's model from measurements (DESIGN.md §4/§11).

    This is the pure-function extraction of ``DRSScheduler.topology_from``
    — identical float ops, so the rebuilt Topology is bit-identical to the
    pre-extraction scheduler's.  ``speed`` applies machine-class factors
    to the effective per-processor service rates (1.0 = reference class).
    """
    n = len(names)
    hot = bool(np.asarray(overloaded).any())
    lam_hat = np.array(lam_hat, dtype=np.float64)
    lam0 = np.zeros(n)
    in_deg = base_routing.sum(axis=0)
    sources = np.nonzero(in_deg == 0)[0]
    if len(sources) == 0:
        sources = np.array([0])
    if hot:
        for s in sources:
            lam0[s] = lam_hat[s] if math.isfinite(lam_hat[s]) else 0.0
    else:
        src_lam = lam_hat[sources]
        total_src = max(src_lam.sum(), 1e-12)
        for s, l in zip(sources, src_lam):
            lam0[s] = lam0_hat * (l / total_src) if math.isfinite(lam0_hat) else l
    routing = base_routing.copy()
    for j in range(n):
        declared_in = routing[:, j]
        if declared_in.sum() == 0:
            continue
        if capped[j]:
            continue  # measured lam_hat[j] is capacity, not offered load
        inflow = float(np.dot(declared_in, lam_hat))
        if inflow > 1e-12 and math.isfinite(lam_hat[j]) and lam_hat[j] > 0:
            routing[:, j] *= lam_hat[j] / inflow
    ops = [
        OperatorSpec(
            name=names[i],
            mu=float(mu_hat[i]) if speed is None else float(mu_hat[i] * speed[i]),
            scaling=scaling[i],
            group_alpha=group_alpha[i],
        )
        for i in range(n)
    ]
    return Topology(ops, lam0, routing)


# --------------------------------------------------------------------------- #
# The decision flow — scalar row port + batched driver
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class RowDecision:
    """One scenario's tick outcome (pure data; no scheduler state)."""

    action: str
    k_next: np.ndarray  # allocation in force after the tick
    k_target: np.ndarray | None  # proposed allocation (None on hard failure)
    k_max: int  # budget after any lease change
    et_cur: float
    et_target: float | None
    need_total: int | None  # Program-(6)-sized demand (overload / scaling)
    plan: RebalancePlan | None
    reason: str
    applied: bool  # k_next != entry k (an allocation change to execute)

    @property
    def code(self) -> int:
        return _CODE[self.action]


@dataclass
class BatchDecision:
    """Stacked tick outcomes for a B-scenario batch."""

    rows: list  # [B] RowDecision
    errors: list  # [B] Exception | None (model/allocator hard failures)

    @property
    def actions(self) -> list[str]:
        return [r.action for r in self.rows]

    def k_next(self, n: int) -> np.ndarray:
        out = np.zeros((len(self.rows), n), dtype=np.int64)
        for bi, r in enumerate(self.rows):
            out[bi, : len(r.k_next)] = r.k_next
        return out


def _default_cost_plan(
    cost_model: RebalanceCostModel,
    top: Topology,
    k_old: np.ndarray,
    k_new: np.ndarray,
    cache,
    stage_names,
) -> RebalancePlan:
    return cost_model.plan(top, k_old, k_new, cache=cache, stage_names=stage_names)


def decide_single(
    top: Topology,
    k_current: np.ndarray,
    k_max: int,
    *,
    t_max: float | None,
    headroom: float,
    scale_in_hysteresis: float,
    min_improvement: float,
    horizon_seconds: float,
    allocator: str = "table",
    overloaded: np.ndarray | None = None,
    lam_hat: np.ndarray | None = None,
    mu_hat: np.ndarray | None = None,
    drop: np.ndarray | None = None,
    ensure: Callable[[int], int] | None = None,
    cost_model: RebalanceCostModel | None = None,
    cache=None,
    stage_names: Sequence[str] | None = None,
    stragglers: tuple = (),
    names: Sequence[str] | None = None,
) -> RowDecision:
    """One scenario's decide — the float64 numpy twin of the old
    ``DRSScheduler.decide`` body (same branch order, same float ops, same
    allocator calls, so the outcome is bit-identical).

    ``ensure`` is the per-scenario negotiator lease hook (target -> new
    k_max); ``None`` disables the scale-out/scale-in branches exactly
    like a scheduler without a negotiator.  Model/allocator hard failures
    (``UnstableTopologyError`` and uncaught ``InsufficientResourcesError``)
    propagate to the caller, as they did from ``decide``.
    """
    assign_fn, min_proc_fn = ALLOCATORS[allocator]
    names = list(names) if names is not None else [op.name for op in top.operators]
    n = len(names)
    cost_model = cost_model or RebalanceCostModel()
    k_current = np.asarray(k_current, dtype=np.int64)
    et_cur = top.expected_sojourn(k_current)  # may raise UnstableTopologyError

    if overloaded is None:
        if lam_hat is None or mu_hat is None:
            overloaded = np.zeros(n, dtype=bool)
        else:
            group = np.array([op.scaling == "group" for op in top.operators])
            alpha = np.array([op.group_alpha for op in top.operators])
            overloaded = overloaded_mask_batch(
                lam_hat[None], mu_hat[None], None if drop is None else drop[None],
                k_current[None], group[None], alpha[None],
            )[0]

    # --- Overload: defined unstable-snapshot path (no gates) ------------ #
    if overloaded.any():
        hot_names = [names[i] for i in np.nonzero(overloaded)[0]]
        try:
            if t_max is not None:
                need_total = math.ceil(min_proc_fn(top, t_max).total * headroom)
            else:
                need_total = math.ceil(
                    int(top.min_feasible_allocation().sum()) * headroom
                )
        except (InsufficientResourcesError, UnstableTopologyError):
            need_total = k_max + 1
        if need_total > k_max and ensure is not None:
            k_max = max(k_max, ensure(need_total))
        try:
            best = assign_fn(top, k_max)
        except (InsufficientResourcesError, UnstableTopologyError) as e:
            return RowDecision(
                "overloaded", k_current.copy(), None, k_max, et_cur, None,
                need_total, None,
                f"overloaded at {hot_names}; offered load infeasible "
                f"within k_max={k_max}: {e}",
                applied=False,
            )
        return RowDecision(
            "overloaded", best.k.copy(), best.k, k_max, et_cur,
            best.expected_sojourn, need_total, None,
            f"measured rho >= 1 at {hot_names}; offered-load model "
            f"needs {need_total}, reallocated within k_max={k_max}",
            applied=True,
        )

    # --- Program (6): how many processors do we actually need? ---------- #
    need: AllocationResult | None = None
    if t_max is not None:
        try:
            need = min_proc_fn(top, t_max)
        except InsufficientResourcesError:
            need = None

    if t_max is not None:
        needed_total = (
            math.ceil(need.total * headroom) if need is not None else k_max + 1
        )
        # Scale out: T_max unreachable within the current lease.
        if needed_total > k_max and ensure is not None:
            new_k_max = ensure(needed_total)
            if new_k_max > k_max:
                k_max = new_k_max
                best = assign_fn(top, k_max)
                return RowDecision(
                    "scale_out", best.k.copy(), best.k, k_max, et_cur,
                    best.expected_sojourn, needed_total, None,
                    f"Program(6) needs {needed_total} > leased; "
                    f"negotiated k_max={k_max}",
                    applied=True,
                )
        # Scale in: we need much less than we lease (with hysteresis).
        if (
            need is not None
            and ensure is not None
            and math.ceil(need.total * headroom) < scale_in_hysteresis * k_max
        ):
            target_total = math.ceil(need.total * headroom)
            new_k_max = ensure(target_total)
            if new_k_max < k_max:
                best = assign_fn(top, new_k_max)
                return RowDecision(
                    "scale_in", best.k.copy(), best.k, new_k_max, et_cur,
                    best.expected_sojourn, target_total, None,
                    f"Program(6) needs {need.total} (headroom "
                    f"{target_total}) << leased {k_max}; released to {new_k_max}",
                    applied=True,
                )

    # --- Program (4): best placement within k_max ----------------------- #
    try:
        best = assign_fn(top, k_max)
    except InsufficientResourcesError as e:
        return RowDecision(
            "infeasible", k_current.copy(), None, k_max, et_cur, None,
            None if need is None else need.total, None, str(e), applied=False,
        )

    improvement = (
        (et_cur - best.expected_sojourn) / et_cur
        if math.isfinite(et_cur) and et_cur > 0
        else float("inf")
    )
    if np.array_equal(best.k, k_current) or improvement < min_improvement:
        return _none_or_hint_row(
            k_current, best, k_max, et_cur, stragglers,
            reason=f"improvement {improvement:.1%} < {min_improvement:.0%}",
        )

    plan = _default_cost_plan(cost_model, top, k_current, best.k, cache, stage_names)
    if not plan.worthwhile(horizon_seconds, top.lam0_total) and math.isfinite(et_cur):
        return _none_or_hint_row(
            k_current, best, k_max, et_cur, stragglers, plan=plan,
            reason="rebalance cost exceeds benefit over horizon",
        )
    return RowDecision(
        "rebalance", best.k.copy(), best.k, k_max, et_cur,
        best.expected_sojourn, None, plan, "", applied=True,
    )


def _none_or_hint_row(
    k_current, best, k_max, et_cur, stragglers, *, plan=None, reason=""
) -> RowDecision:
    action = "none"
    if stragglers:
        action = "rebalance_hint"
        named = ", ".join(f"{op}[{inst}]" for op, inst in stragglers)
        reason = (reason + "; " if reason else "") + f"stragglers flagged: {named}"
    return RowDecision(
        action, np.asarray(k_current, dtype=np.int64).copy(), best.k, k_max,
        et_cur, best.expected_sojourn, None, plan, reason, applied=False,
    )


def tick_batch(
    meas: MeasurementBatch,
    k_current: np.ndarray,
    static: ControllerStatic,
    params: ControllerParams,
    *,
    ensure: Sequence[Callable[[int], int] | None] | None = None,
    cost_models: Sequence[RebalanceCostModel | None] | None = None,
    raise_errors: bool = False,
    proactive=None,
    q_backlog: np.ndarray | None = None,
    compact_state: TwinCompactionState | None = None,
) -> BatchDecision:
    """One control tick for the whole batch (the float64 numpy twin).

    Vectorized across ``[B, N]``: snapshot completeness, the overload
    trigger, and the throughput-capped propagation.  Per scenario (the
    parts whose float sequencing carries the bit-exactness guarantee, and
    the stateful hooks): offered-load clamping, the Jackson solve, the
    Program-(4)/(6) table allocations, and the negotiator/cost calls.
    Model hard failures become per-row ``errors`` entries with an
    ``"infeasible"`` row (the ScenarioRunner semantics) unless
    ``raise_errors`` (the scalar-scheduler semantics).

    ``proactive`` (a :class:`~repro.forecast.mpc.ProactiveController`)
    switches on the forecast/MPC plane (DESIGN.md §15): the predictor
    state advances on every complete tick, and scenarios whose forecast
    passes the confidence gate — and are NOT currently overloaded (the
    §11 trigger always wins) — commit the MPC plan instead of the
    reactive decide.  ``q_backlog [B, N]`` seeds the planner's rollout
    with the actual queue backlog (0 when the caller has no probe).

    ``compact_state`` (a caller-owned :class:`TwinCompactionState`)
    switches on the twin-side trigger-gated fast path (DESIGN.md §18):
    lanes whose decide inputs are bitwise unchanged — and that are not
    hot, have no negotiator hook / custom cost model, and are not MPC
    overrides — replay their cached :class:`RowDecision` instead of
    re-running clamp + solve + Programs (4)/(6); with ``proactive`` the
    planner prices only the MPC-eligible lanes.  Decisions are bitwise
    identical either way (the memo key is the full input tuple of a pure
    decide); the ``need`` diagnostic defaults to 0 on unpriced lanes.
    """
    b, n = static.batch, static.n
    k_current = np.asarray(k_current, dtype=np.int64)
    mu_eff = meas.mu_hat * static.speed
    overloaded = overloaded_mask_batch(
        meas.lam_hat, mu_eff, meas.drop_hat, k_current, static.group, static.alpha
    ) & static.active
    hot = overloaded.any(axis=1)
    capped = np.zeros((b, n), dtype=bool)
    if hot.any():
        capped = capped_mask_batch(overloaded, static.base_routing, static.active)
    complete = meas.complete(static.active)

    use = np.zeros(b, dtype=bool)
    k_plan = et_hold = et_plan = need_mpc = None
    if proactive is not None:
        from ..forecast.mpc import forecast_step, mpc_plan, mpc_plan_compact

        t_arr = np.nan_to_num(params.t_max, nan=np.inf)
        k_hi = int(max(params.k_max.max(), k_current.max(), 1))
        q0 = (
            np.zeros((b, n)) if q_backlog is None
            else np.asarray(q_backlog, dtype=np.float64)
        )
        proactive.state, lam_pred, conf = forecast_step(
            proactive.state, meas.lam_hat, static.active, proactive.cfg
        )
        plan_kw = dict(
            mu=np.asarray(meas.mu_hat, dtype=np.float64),
            group=static.group, alpha=static.alpha, speed=static.speed,
            active=static.active, src_mask=_source_mask(static),
            cap_queue=proactive.cap_queue, t_max=t_arr,
            span=proactive.span, cfg=proactive.cfg, k_hi=k_hi,
        )
        # A plan can only be committed where the confidence gate is open,
        # the snapshot is complete, the §11 trigger is quiet, and T_max is
        # real — so under compaction the planner prices exactly that set
        # (``use`` below is a subset of it, hence unchanged bitwise).
        eligible = conf & complete & ~hot & np.isfinite(t_arr)

        def _plan(k_max_arr):
            if compact_state is None:
                return mpc_plan(lam_pred, q0, k_current, k_max=k_max_arr, **plan_kw)
            return mpc_plan_compact(
                eligible, lam_pred, q0, k_current, k_max=k_max_arr, **plan_kw
            )

        k_maxes = params.k_max.astype(np.int64).copy()
        k_plan, any_ok, et_hold, et_plan, need_mpc = _plan(k_maxes)
        use = conf & any_ok & complete & ~hot & np.isfinite(t_arr)
        # Negotiator leases: grow toward the Program-6-at-peak demand,
        # release (with hysteresis) when it shrinks; one re-plan pass if
        # any lease moved (the twin-side analogue of scale_out/scale_in).
        if ensure is not None:
            hyst = proactive.cfg.scale_in_hysteresis
            moved = False
            for bi in range(b):
                hook = ensure[bi]
                if hook is None or not use[bi]:
                    continue
                tgt, lease = int(need_mpc[bi]), int(k_maxes[bi])
                if tgt > lease or tgt < hyst * lease:
                    new_lease = int(hook(max(tgt, 1)))
                    if new_lease != lease:
                        k_maxes[bi] = new_lease
                        moved = True
            if moved:
                k_plan, any_ok, et_hold, et_plan, need_mpc = _plan(k_maxes)
                use = conf & any_ok & complete & ~hot & np.isfinite(t_arr)
        proactive.mpc_used = use.copy()
        proactive.confident = conf.copy()
        proactive.need = np.asarray(need_mpc).copy()

    rows: list[RowDecision] = []
    errors: list = [None] * b
    if compact_state is not None:
        compact_state.replayed[:] = False
    for bi in range(b):
        ni = int(static.n_ops[bi])
        k_row = k_current[bi, :ni]
        k_max = int(params.k_max[bi])
        if ni == 0:
            # Padded batch lane (pad_static / pack_scenarios pad_to=): no
            # operators, nothing to decide — the masked-lane contract says
            # it is always "none" with an unchanged (empty) allocation.
            rows.append(RowDecision(
                "none", k_row.copy(), None, k_max, float("nan"), None, None,
                None, "padded lane", applied=False,
            ))
            continue
        if use[bi]:
            k_new = np.asarray(k_plan[bi, :ni], dtype=np.int64)
            changed = bool((k_new != k_row).any())
            rows.append(RowDecision(
                "proactive" if changed else "none",
                k_new.copy() if changed else k_row.copy(),
                k_new, int(k_maxes[bi]), float(et_hold[bi]), float(et_plan[bi]),
                int(need_mpc[bi]), None,
                "MPC plan committed ahead of trigger" if changed
                else "proactive hold",
                applied=changed,
            ))
            continue
        if not complete[bi]:
            rows.append(RowDecision(
                "none", k_row.copy(), None, k_max, float("nan"), None, None,
                None, "insufficient measurements", applied=False,
            ))
            continue
        # Trigger-gated fast path (§18): replay the cached row when every
        # decide input is bitwise unchanged.  Hot lanes always reprice
        # (mirrors the jit trigger); hooked / custom-cost lanes and
        # raise_errors callers never memoize.
        memo = (
            compact_state is not None
            and not raise_errors
            and (ensure is None or ensure[bi] is None)
            and (cost_models is None or cost_models[bi] is None)
        )
        lam_row = np.asarray(meas.lam_hat[bi, :ni], dtype=np.float64)
        mu_row = np.asarray(meas.mu_hat[bi, :ni], dtype=np.float64)
        drop_row = np.asarray(meas.drop_hat[bi, :ni], dtype=np.float64)
        lam0_sc = float(meas.lam0_hat[bi])
        if (
            memo
            and not overloaded[bi, :ni].any()
            and compact_state.hit(
                bi, lam_row, mu_row, drop_row, lam0_sc, k_row, k_max
            )
        ):
            cached = compact_state.rows[bi]
            rows.append(replace(cached, k_next=cached.k_next.copy()))
            errors[bi] = compact_state.errors[bi]
            compact_state.replayed[bi] = True
            continue
        names = static.names[bi]
        scaling = ["group" if g else "replica" for g in static.group[bi, :ni]]
        t_max = params.t_max[bi]
        try:
            top = clamp_row(
                names,
                static.base_routing[bi, :ni, :ni],
                meas.lam_hat[bi, :ni],
                meas.mu_hat[bi, :ni],
                float(meas.lam0_hat[bi]),
                overloaded[bi, :ni],
                capped[bi, :ni],
                scaling,
                static.alpha[bi, :ni],
                speed=None if np.all(static.speed[bi, :ni] == 1.0)
                else static.speed[bi, :ni],
            )
            row = decide_single(
                top,
                k_row,
                k_max,
                t_max=None if math.isnan(t_max) else float(t_max),
                headroom=float(params.headroom[bi]),
                scale_in_hysteresis=float(params.scale_in_hysteresis[bi]),
                min_improvement=float(params.min_improvement[bi]),
                horizon_seconds=float(params.horizon_seconds[bi]),
                allocator=params.allocator[bi],
                overloaded=overloaded[bi, :ni],
                ensure=None if ensure is None else ensure[bi],
                cost_model=None if cost_models is None else cost_models[bi],
                names=names,
            )
        except (InsufficientResourcesError, UnstableTopologyError) as e:
            if raise_errors:
                raise
            errors[bi] = e
            row = RowDecision(
                "infeasible", k_row.copy(), None, k_max, float("inf"), None,
                None, None, str(e), applied=False,
            )
        rows.append(row)
        if memo and not overloaded[bi, :ni].any():
            compact_state.remember(
                bi, lam_row, mu_row, drop_row, lam0_sc, k_row.copy(), k_max,
                row, errors[bi],
            )
    return BatchDecision(rows, errors)


# --------------------------------------------------------------------------- #
# jit path: the whole decide (and the fused simulate->decide loop) in JAX
# --------------------------------------------------------------------------- #
def _topr_ops():
    """The ``kernels/gain_topr`` dispatch module, imported lazily ONCE.

    Every decide path (reactive core, proactive MPC closure, fleet
    planner) shares this accessor instead of repeating the lazy-import
    block — importing here keeps ``import repro.core.controller`` free
    of a hard jax dependency (numpy-twin-only callers never pay it).
    """
    from ..kernels.gain_topr import ops as topr_ops

    return topr_ops


def _decide_fused_ops():
    """The ``kernels/decide_fused`` dispatch module (same lazy idiom)."""
    from ..kernels.decide_fused import ops as fused_ops

    return fused_ops


def _decide_statics(static: ControllerStatic, params: ControllerParams) -> dict:
    """The decide's per-lane array inputs as one ``[B, ...]``-leading dict.

    Every entry has the batch axis leading, so a device mesh shards the
    whole bundle with one rule (``P(axis, None, ...)``) — this is what
    lets the decide run under ``shard_map`` with the statics passed as
    explicit (sharded) arguments instead of replicated closure constants.
    """
    return {
        "routing0": np.asarray(static.base_routing, dtype=np.float64),
        "group": np.asarray(static.group, dtype=bool),
        "alpha": np.asarray(static.alpha, dtype=np.float64),
        "active": np.asarray(static.active, dtype=bool),
        "speed": np.asarray(static.speed, dtype=np.float64),
        "src": _source_mask(static),
        "k_max": np.asarray(params.k_max, dtype=np.int64),
        "min_improvement": np.asarray(params.min_improvement, dtype=np.float64),
        "horizon": np.asarray(params.horizon_seconds, dtype=np.float64),
    }


def _make_decide_core(
    n: int,
    k_hi: int,
    pause: float,
    interpret: bool,
    force_kernel: bool,
    fused: bool = False,
    j_cap: int | None = None,
):
    """The decide body as a pure function of (statics dict, measurements).

    ``core(st, lam_hat, mu_hat, drop_hat, lam0_hat, k_current)`` operates
    on whatever batch extent its inputs carry — the full ``B`` under plain
    jit, or one device's ``B/D`` shard under ``shard_map`` (every op is
    per-lane, so shard results are bit-identical to the unsharded run).

    ``fused=True`` dispatches the model chain (sojourn table ->
    Algorithm-1 gains -> Program-4 top-R -> E[T] gathers) to
    ``kernels/decide_fused`` as ONE pass: the Pallas kernel on TPU /
    ``force_kernel``, otherwise its jnp oracle — which is composed from
    the identical expressions this two-pass body runs, so CPU decisions
    are bit-for-bit the same either way (tier-1 enforced).  ``j_cap``
    truncates the per-lane candidate window (exact while the budget
    stays <= ``j_cap``; callers pass the fleet-wide max budget).
    """
    import jax
    import jax.numpy as jnp

    topr_ops = _topr_ops()
    fused_ops = _decide_fused_ops() if fused else None
    from .batched import sojourn_table_jax, solve_traffic_batch_jax

    def decide(st, lam_hat, mu_hat, drop_hat, lam0_hat, k_current):
        routing0 = st["routing0"]
        adj = routing0 > 0
        group = st["group"]
        alpha = st["alpha"]
        active = st["active"]
        speed = st["speed"]
        src_mask = st["src"]
        k_max = st["k_max"]
        min_improvement = st["min_improvement"]
        horizon = st["horizon"]
        b = lam_hat.shape[0]
        dtype = lam_hat.dtype
        mu_eff = mu_hat * speed
        k_cur = k_current.astype(jnp.int32)
        # --- overload trigger + capped propagation (§11) --------------- #
        k_floor = jnp.maximum(k_cur, 1).astype(dtype)
        eff = 1.0 / (1.0 + alpha * (k_floor - 1.0))
        capacity = jnp.where(group, mu_eff * k_floor * eff, mu_eff * k_floor)
        valid = jnp.isfinite(lam_hat) & jnp.isfinite(mu_eff) & (mu_eff > 0)
        drops = jnp.nan_to_num(drop_hat, nan=0.0)
        overloaded = valid & active & (
            (lam_hat >= capacity * (1.0 - 1e-9))
            | (drops > DROP_TRIGGER_FRACTION * capacity)
        )
        hot = overloaded.any(axis=-1)

        def _prop(_, out_c):
            return overloaded | (adj & out_c[:, :, None]).any(axis=1)

        out_c = jax.lax.fori_loop(0, n, _prop, overloaded)
        capped = (adj & out_c[:, :, None]).any(axis=1) & active

        # --- offered-load clamping (topology_from) ---------------------- #
        lam_src = jnp.where(src_mask & jnp.isfinite(lam_hat), lam_hat, 0.0)
        total_src = jnp.maximum(lam_src.sum(axis=-1), 1e-12)
        lam0_cold = jnp.where(
            jnp.isfinite(lam0_hat)[:, None],
            lam0_hat[:, None] * (lam_src / total_src[:, None]),
            lam_src,
        )
        lam0 = jnp.where(src_mask, jnp.where(hot[:, None], lam_src, lam0_cold), 0.0)
        colsum = routing0.sum(axis=1)
        inflow = jnp.einsum("bij,bi->bj", routing0, jnp.where(active, lam_hat, 0.0))
        rescale = jnp.where(
            (colsum > 0) & ~capped & (inflow > 1e-12)
            & jnp.isfinite(lam_hat) & (lam_hat > 0),
            lam_hat / jnp.maximum(inflow, 1e-300),
            1.0,
        )
        routing = routing0.astype(dtype) * rescale[:, None, :]
        lam = solve_traffic_batch_jax(lam0, routing)
        lam = jnp.where(active, lam, 0.0)
        solve_bad = (~jnp.isfinite(lam) | (lam < 0)).any(axis=-1)
        lam = jnp.where(jnp.isfinite(lam) & (lam >= 0), lam, 0.0)
        lam0_total = lam0.sum(axis=-1)

        def _et_of(per_op):
            # Shared pricing tail: both decide paths produce raw per-op
            # T gathers and normalise them HERE with the same expressions,
            # so fused-on/off E[T] parity reduces to the gathers.
            contrib = jnp.where(lam > 0, lam * per_op, 0.0)
            return contrib.sum(axis=-1) / jnp.maximum(lam0_total, 1e-300)

        if fused:
            # --- ONE fused pass: table -> gains -> Program (4) -> E[T] -- #
            k4, k_start, t_cur_op, t4_op = fused_ops.batch_decide(
                lam, mu_eff, group=group, alpha=alpha, active=active,
                k_cur=k_cur, k_max=k_max, k_hi=k_hi, j_cap=j_cap,
                interpret=interpret, force_kernel=force_kernel,
            )
            floor_total = k_start.sum(axis=-1)
            infeasible = solve_bad | (floor_total > k_max)
        else:
            # --- one table pass: E[T_i](k) and Algorithm-1 gains -------- #
            T = sojourn_table_jax(
                lam.reshape(-1), mu_eff.reshape(-1), k_hi=k_hi,
                group=group.reshape(-1), alpha=alpha.reshape(-1),
                min_k=jnp.ones(b * n, dtype=jnp.int32),
                interpret=interpret, force_kernel=force_kernel,
            ).reshape(b, n, k_hi + 1)
            G = lam[..., None] * (T[..., :-1] - T[..., 1:])
            G = jnp.where(jnp.isfinite(T[..., :-1]), G, jnp.inf)

            # Minimal feasible allocation = first finite table column.
            finite = jnp.isfinite(T)
            has_finite = finite.any(axis=-1)
            first = jnp.argmax(finite, axis=-1).astype(jnp.int32)
            k_start = jnp.where(active, jnp.where(has_finite, first, k_hi + 1), 0)
            floor_total = k_start.sum(axis=-1)
            infeasible = solve_bad | (floor_total > k_max)

            # --- Program (4): masked top-R over the gain table ---------- #
            budget = jnp.clip(k_max - floor_total, 0, None).astype(jnp.int32)
            j = jnp.arange(k_hi, dtype=jnp.int32)
            idx = k_start[..., None] + j[None, None, :]
            cand = jnp.take_along_axis(G, jnp.clip(idx, 0, k_hi - 1), axis=-1)
            cand = jnp.where(
                (idx < k_hi) & active[..., None] & jnp.isfinite(cand), cand, 0.0
            )
            take = topr_ops.gain_topr(
                cand, budget, interpret=interpret, force_kernel=force_kernel
            )
            k4 = k_start + take

            def _gather(k_vec):
                return jnp.take_along_axis(
                    T, jnp.clip(k_vec, 0, k_hi).astype(jnp.int32)[..., None],
                    axis=-1,
                )[..., 0]

            t_cur_op = _gather(k_cur)
            t4_op = _gather(k4)

        et_cur = _et_of(t_cur_op)
        et4 = _et_of(t4_op)

        # --- gates (vectorized improvement + cost/benefit) -------------- #
        unchanged = jnp.where(active, k4 == k_cur, True).all(axis=-1)
        improvement = jnp.where(
            jnp.isfinite(et_cur) & (et_cur > 0),
            (et_cur - et4) / et_cur,
            jnp.inf,
        )
        visit = lam / jnp.maximum(lam0_total, 1e-300)[:, None]
        cap_new = jnp.where(
            active,
            k4.astype(dtype) * mu_eff / jnp.maximum(visit, 1e-12),
            jnp.inf,
        ).min(axis=-1)
        slack = jnp.maximum(cap_new - lam0_total, 1e-9)
        drain = lam0_total * pause / slack
        benefit = jnp.where(jnp.isfinite(et_cur), et_cur - et4, jnp.inf)
        worthwhile = benefit * lam0_total * horizon > (
            (pause + drain) * jnp.maximum(lam0_total, 1.0)
        )
        rebalance = (
            ~unchanged
            & (improvement >= min_improvement)
            & (worthwhile | ~jnp.isfinite(et_cur))
        )

        # --- action selection (precedence mirrors the twin) ------------- #
        complete = (
            jnp.where(active, jnp.isfinite(lam_hat) & jnp.isfinite(mu_hat), True)
            .all(axis=-1)
            & jnp.isfinite(lam0_hat)
        )
        feasible4 = ~infeasible
        code = jnp.where(
            rebalance, _CODE["rebalance"], _CODE["none"]
        )
        code = jnp.where(
            infeasible & ~hot | (solve_bad & hot), _CODE["infeasible"], code
        )
        code = jnp.where(hot & ~solve_bad, _CODE["overloaded"], code)
        code = jnp.where(~complete, _CODE["none"], code)
        apply_mask = complete & ~solve_bad & feasible4 & (
            (hot) | rebalance
        )
        k_next = jnp.where(apply_mask[:, None], k4, k_cur)
        return code, k_next, et_cur, jnp.where(feasible4, et4, jnp.inf), apply_mask

    return decide


def make_decide_jax(
    static: ControllerStatic,
    params: ControllerParams,
    *,
    k_hi: int | None = None,
    pause_seconds: float | None = None,
    interpret: bool = False,
    force_kernel: bool = False,
    fused: bool | None = None,
    mesh=None,
    compact=None,
):
    """Compile the batched decide into one jit program.

    Returns ``decide(lam_hat, mu_hat, drop_hat, lam0_hat, k_current) ->
    (action_code [B], k_next [B, N], et_cur [B], et_target [B],
    applied [B])`` — the
    complete non-negotiated decision flow: overload masks, offered-load
    clamping, batched Jackson solve, one Erlang table pass
    (``kernels/erlang_c``), Program-4 top-R selection
    (``kernels/gain_topr``), and the vectorized improvement + cost gates.
    Negotiator-owned branches (scale_out / scale_in) need the Python
    lease hook and are deliberately absent: ``params.k_max`` is the
    static per-scenario budget.  Dtype follows JAX's active precision.

    ``mesh`` (a 1-D :class:`jax.sharding.Mesh`) shards the batch axis
    across devices with ``shard_map`` (DESIGN.md §16): every statics
    array and measurement input is partitioned on its leading ``B`` dim,
    each device decides its own lane shard, and — because every op in
    the flow is per-lane — the sharded outputs are bit-identical to the
    unsharded ones.  ``B`` need not divide the device count: lanes are
    padded with inert scenarios (:func:`pad_static`, which provably
    decide ``"none"``) and outputs are sliced back to the real ``B``.

    Semantics mirror the numpy twin with two documented deviations
    (DESIGN.md §14): a singular/unstable traffic solve is detected from
    non-finite or negative solved rates (no eigvalue check inside jit),
    and Program (6) sizing is skipped (it only feeds negotiator leases).

    ``fused`` routes the model chain through ``kernels/decide_fused``
    (one pass, DESIGN.md §12); ``None`` reads ``params.fused_decide``
    (the SchedulerConfig knob, default off).  On CPU the fused oracle is
    bit-exact with the two-pass path, so flipping the knob never changes
    a decision — only the dispatch.

    ``compact`` (``True`` or a :class:`CompactionConfig`) returns the
    trigger-gated sparse decide instead (DESIGN.md §18): signature
    ``decide(lam_hat, mu_hat, drop_hat, lam0_hat, k_current, cache) ->
    ((code, k_next, et_cur, et_target, applied), repriced [B] bool,
    cache')`` with ``decide.init_cache()`` producing the cold cache.
    Outputs are bitwise identical to the dense decide on every tick;
    only the work placement changes.  Under a mesh the compaction runs
    per shard inside ``shard_map`` (no cross-device gather) and the
    cache keeps the padded extent.
    """
    import jax
    import jax.numpy as jnp

    b, n = static.batch, static.n
    k_hi = int(k_hi if k_hi is not None else max(int(params.k_max.max()), 1))
    pause = float(
        RebalanceCostModel().pause_cache_miss if pause_seconds is None
        else pause_seconds
    )
    if fused is None:
        fused = bool(getattr(params, "fused_decide", False))
    # Exactness bound for the fused path's candidate-window truncation:
    # every scenario's Program-4 budget is <= its k_max, so the fleet max
    # caps the window (ref.py proof) — static because params is static.
    j_cap = min(k_hi, max(int(params.k_max.max()), 1))
    core = _make_decide_core(
        n, k_hi, pause, interpret, force_kernel, fused=fused, j_cap=j_cap
    )

    if mesh is None:
        st = {k: jnp.asarray(v) for k, v in _decide_statics(static, params).items()}

        if compact:
            core_c = _make_compact_decide(core, b, _resolve_ladder(compact, b))
            jitted = jax.jit(
                lambda lam, mu, drop, lam0, k, cache: core_c(
                    st, lam, mu, drop, lam0, k, cache
                )
            )

            def decide_compact(lam_hat, mu_hat, drop_hat, lam0_hat, k_current,
                               cache):
                return jitted(
                    lam_hat, mu_hat, drop_hat, lam0_hat, k_current, cache
                )

            decide_compact.init_cache = lambda dtype=None: init_decide_cache(
                b, n, dtype=dtype
            )
            return decide_compact

        def decide(lam_hat, mu_hat, drop_hat, lam0_hat, k_current):
            return core(st, lam_hat, mu_hat, drop_hat, lam0_hat, k_current)

        return jax.jit(decide)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis, n_shards = _mesh_axis(mesh)
    b_pad = _padded_batch(b, n_shards)
    st_np = _decide_statics(pad_static(static, b_pad), pad_params(params, b_pad))
    st = {k: jnp.asarray(v) for k, v in st_np.items()}
    st_specs = {
        k: P(axis, *((None,) * (v.ndim - 1))) for k, v in st_np.items()
    }
    row = P(axis, None)
    lane = P(axis)
    pad = b_pad - b

    if compact:
        # Per-shard compaction: each device runs the trigger scan and the
        # bucketed dispatch on its own lane shard — no cross-device
        # gather, at the cost of load imbalance (see bucket_ladder).
        b_shard = b_pad // n_shards
        core_c = _make_compact_decide(
            core, b_shard, _resolve_ladder(compact, b_shard)
        )
        cache_specs = DecideCache(
            ok=lane, lam=row, mu=row, drop=row, lam0=lane, k=row,
            code=lane, k_next=row, et_cur=lane, et_target=lane, applied=lane,
        )
        sharded_c = shard_map(
            core_c,
            mesh=mesh,
            in_specs=(st_specs, row, row, row, lane, row, cache_specs),
            out_specs=((lane, row, lane, lane, lane), lane, cache_specs),
            check_rep=False,
        )

        def decide_padded(lam_hat, mu_hat, drop_hat, lam0_hat, k_current,
                          cache):
            if pad:
                dtype = lam_hat.dtype
                lam_hat = jnp.concatenate([lam_hat, jnp.zeros((pad, n), dtype)])
                mu_hat = jnp.concatenate([mu_hat, jnp.ones((pad, n), dtype)])
                drop_hat = jnp.concatenate(
                    [drop_hat, jnp.zeros((pad, n), dtype)]
                )
                lam0_hat = jnp.concatenate([lam0_hat, jnp.zeros(pad, dtype)])
                k_current = jnp.concatenate(
                    [k_current, jnp.zeros((pad, n), k_current.dtype)]
                )
            out, repriced, cache = sharded_c(
                st, lam_hat, mu_hat, drop_hat, lam0_hat, k_current, cache
            )
            if pad:
                out = tuple(o[:b] for o in out)
                repriced = repriced[:b]
            return out, repriced, cache

        jitted = jax.jit(decide_padded)

        def decide_compact(lam_hat, mu_hat, drop_hat, lam0_hat, k_current,
                           cache):
            return jitted(lam_hat, mu_hat, drop_hat, lam0_hat, k_current, cache)

        # The cache lives at the PADDED extent (it is a shard_map operand).
        decide_compact.init_cache = lambda dtype=None: init_decide_cache(
            b_pad, n, dtype=dtype
        )
        return decide_compact

    sharded = shard_map(
        core,
        mesh=mesh,
        in_specs=(st_specs, row, row, row, lane, row),
        out_specs=(lane, row, lane, lane, lane),
        check_rep=False,
    )

    def decide(lam_hat, mu_hat, drop_hat, lam0_hat, k_current):
        if pad:
            dtype = lam_hat.dtype
            lam_hat = jnp.concatenate([lam_hat, jnp.zeros((pad, n), dtype)])
            mu_hat = jnp.concatenate([mu_hat, jnp.ones((pad, n), dtype)])
            drop_hat = jnp.concatenate([drop_hat, jnp.zeros((pad, n), dtype)])
            lam0_hat = jnp.concatenate([lam0_hat, jnp.zeros(pad, dtype)])
            k_current = jnp.concatenate(
                [k_current, jnp.zeros((pad, n), k_current.dtype)]
            )
        out = sharded(st, lam_hat, mu_hat, drop_hat, lam0_hat, k_current)
        if pad:
            out = tuple(o[:b] for o in out)
        return out

    return jax.jit(decide)


class ControllerState(NamedTuple):
    """The fused loop's scan carry as one donated pytree (DESIGN.md §16).

    ``tick`` (int32 scalar) is the index of the *next* control window,
    which makes the state resumable: :meth:`FusedLoop.run` advances any
    number of ticks from it, and a checkpoint -> restore -> resume
    sequence is bit-identical to a straight-through run
    (tests/test_checkpoint.py).  Under a device mesh the batch extent is
    the padded ``B`` (a multiple of the device count); ``fstate`` is the
    flat ForecastState tuple when the loop is proactive, else ``()``.
    ``acc`` holds the post-warmup run aggregates in BatchQueueSim order:
    (offered, served, dropped, ext_admitted, ext_offered, q_int, q_max).
    """

    q: Any  # [B, N] queue backlog
    served_prev: Any  # [B, N] last-step completions (the routing delay line)
    k: Any  # [B, N] int32 allocation in force
    acc: tuple  # post-warmup aggregates (7-tuple, see above)
    tick: Any  # int32 scalar: next control-window index
    fstate: tuple = ()  # flat ForecastState when proactive


class FusedLoop:
    """One compiled measure -> model -> rebalance program over the horizon.

    ``loop(k0)`` runs the whole horizon and returns the legacy output
    dict (the pre-refactor ``run(k0)`` surface).  The chunked surface —
    ``state = loop.init(k0)`` then ``state, out = loop.run(state,
    ticks)`` — exposes the same program with the carry as an explicit
    :class:`ControllerState`.  The state argument is **donated** to XLA
    on every ``run`` call (``donate_argnums=0``), so long-horizon loops
    update their ``[B, N]`` buffers in place instead of reallocating;
    the caller must keep using the returned state, never the one it
    passed in.  Compiled executables are cached per chunk length.
    """

    def __init__(self, n_ticks: int, init_fn, build_fn):
        self.n_ticks = n_ticks
        self._init_fn = init_fn
        self._build = build_fn
        self._compiled: dict = {}

    def init(self, k0) -> ControllerState:
        """Fresh tick-0 state (k0 is [B, N]; auto-padded under a mesh)."""
        return self._init_fn(k0)

    def run(self, state: ControllerState, ticks: int | None = None):
        """Advance ``ticks`` windows (default: to the end of the horizon).

        Returns ``(new_state, out)`` where ``out`` is the output dict for
        the chunk just run (per-tick stacks cover only this chunk; the
        run aggregates come from ``new_state.acc`` and therefore cover
        everything since tick 0).
        """
        done = int(state.tick)
        if ticks is None:
            ticks = self.n_ticks - done
        ticks = int(ticks)
        if not 0 < ticks <= self.n_ticks - done:
            raise ValueError(
                f"cannot run {ticks} ticks from tick {done} "
                f"(horizon {self.n_ticks})"
            )
        fn = self._compiled.get(ticks)
        if fn is None:
            fn = self._compiled[ticks] = self._build(ticks)
        return fn(state)

    def __call__(self, k0) -> dict:
        _, out = self.run(self.init(k0), self.n_ticks)
        return out


def make_fused_loop(
    arrays,
    static: ControllerStatic,
    params: ControllerParams,
    *,
    steps_per_tick: int,
    k_hi: int | None = None,
    warmup_seconds: float | None = None,
    interpret: bool = False,
    force_kernel: bool = False,
    fused: bool | None = None,
    proactive=None,
    mesh=None,
    compact=None,
):
    """Fuse simulate -> measure -> decide -> apply into ONE jit program.

    ``arrays`` is the :class:`~repro.streaming.batchsim.BatchArrays`
    bundle; the returned :class:`FusedLoop` lax.scans the horizon: each
    scan step advances one control window through the batch simulator's
    step function (``streaming.batchsim.window_step_fn`` — the same
    bounded-queue kernel path the standalone sim uses), derives the
    window's synthetic measurement (§13 Little's-law surface), runs the
    compiled decide, and applies the allocation — no Python between
    ticks.  ``loop(k0)`` yields per-tick stacked decisions plus the
    post-warmup whole-run aggregates (the BatchSimResult surface);
    ``loop.init`` / ``loop.run`` expose the donated, resumable
    :class:`ControllerState` carry.

    ``proactive`` (a :class:`~repro.forecast.mpc.MPCConfig`) extends the
    scan carry with the forecast state (DESIGN.md §15): each tick also
    advances the rate predictors, runs the MPC planner from the live
    queue backlog, and — where the confidence gate is open, no operator
    is overloaded, and some candidate meets T_max — commits the plan over
    the reactive decide.  The whole predict -> simulate -> price ->
    commit step stays inside the one ``lax.scan`` (outputs gain
    ``mpc_used`` / ``confident`` per tick).

    ``mesh`` (a 1-D :class:`jax.sharding.Mesh`, e.g. from
    :func:`repro.distributed.sharding.fleet_mesh`) shards the batch axis
    of the WHOLE loop across devices with ``shard_map`` (DESIGN.md §16):
    arrivals, statics, the carry, and the per-tick outputs are
    partitioned on ``B``, and each device scans its own lane shard —
    every op in the tick is per-lane, so the sharded loop is
    bit-identical to the unsharded one (tests/test_mesh_control.py).
    ``B`` is auto-padded to a multiple of the device count with inert
    lanes (:func:`pad_static` / ``BatchArrays.pad_batch``) and all
    outputs are sliced back to the real ``B``; only the carried
    ``ControllerState`` keeps the padded extent.

    ``compact`` (``True`` or a :class:`CompactionConfig`) splits every
    tick into the cheap O(B*N) trigger scan and the bucketed compacted
    decide (DESIGN.md §18): lanes whose decide inputs are bitwise
    unchanged since their last pricing replay the cached row; triggered
    lanes are gathered to the smallest static ladder width and priced
    there.  With ``proactive`` the MPC planner likewise prices only the
    commit-eligible lanes.  Outputs are bitwise identical to the dense
    loop; the per-tick output dict gains a ``"repriced" [ticks, B]``
    work-placement diagnostic (NOT part of the decision surface — chunk
    boundaries reset the cache, so a resumed run's ``repriced`` differs
    from a straight-through run's even though every decision matches).
    The memo cache rides only the in-chunk ``lax.scan`` carry, never
    :class:`ControllerState`: checkpoints stay layout-independent and a
    restore re-prices every lane once (same outputs, more work).  Under
    a mesh each device compacts its own shard inside ``shard_map`` —
    no cross-device gather (see
    :func:`repro.distributed.sharding.bucket_ladder` for the imbalance
    tradeoff).

    Negotiated scenarios cannot ride in here (leases are Python): callers
    keep those on the numpy twin path.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    from ..streaming.batchsim import composed_wait as _composed_wait
    from ..streaming.batchsim import window_step_fn

    b_real, n = static.batch, static.n
    dt = float(arrays.dt)
    steps = arrays.steps
    n_ticks = steps // steps_per_tick
    k_hi_res = int(k_hi if k_hi is not None else max(int(params.k_max.max()), 1))
    if fused is None:
        fused = bool(getattr(params, "fused_decide", False))
    j_cap = min(k_hi_res, max(int(params.k_max.max()), 1))
    if compact:
        compact_cfg = (
            compact if isinstance(compact, CompactionConfig) else CompactionConfig()
        )
    else:
        compact_cfg = None

    if mesh is not None:
        axis, n_shards = _mesh_axis(mesh)
        b_pad = _padded_batch(b_real, n_shards)
        static = pad_static(static, b_pad)
        params = pad_params(params, b_pad)
        arrays = arrays.pad_batch(b_pad)
    b = static.batch

    decide_core = _make_decide_core(
        n, k_hi_res, float(RebalanceCostModel().pause_cache_miss),
        interpret, force_kernel, fused=fused, j_cap=j_cap,
    )
    window = window_step_fn(interpret=interpret, force_kernel=force_kernel)
    # Every [B, ...]-leading array rides in one of two dicts so the mesh
    # path can pass them as explicit sharded operands (one P(axis, ...)
    # rule per leaf) instead of full-size replicated closure constants.
    st = {k_: jnp.asarray(v) for k_, v in _decide_statics(static, params).items()}
    sim = {
        "mu": jnp.asarray(arrays.mu),  # reference-class priors
        "group": jnp.asarray(arrays.group),
        "alpha": jnp.asarray(arrays.alpha),
        "cap_queue": jnp.asarray(arrays.cap_queue),
        "routing": jnp.asarray(arrays.routing),
        "speed": jnp.asarray(static.speed),
        "t_max": jnp.asarray(np.nan_to_num(params.t_max, nan=np.inf)),
        # §17 Allen-Cunneen inputs for the stationary-wait term of the
        # window measurement (ones = the M/M/k prior when unset).
        "ca2": jnp.asarray(
            np.ones((arrays.batch, arrays.n)) if arrays.ca2 is None
            else arrays.ca2
        ),
        "cs2": jnp.asarray(
            np.ones((arrays.batch, arrays.n)) if arrays.cs2 is None
            else arrays.cs2
        ),
    }
    # Pre-sliced per-tick arrival chunks + warmup masks.
    ext_r = jnp.asarray(
        arrays.ext[: n_ticks * steps_per_tick].reshape(
            n_ticks, steps_per_tick, b, n
        )
    )
    warm_r = jnp.asarray(
        (np.arange(n_ticks * steps_per_tick) >= arrays.warmup_steps)
        .astype(np.float64)
        .reshape(n_ticks, steps_per_tick)
    )
    # A window counts as warm when it *starts* past the warmup boundary,
    # compared in seconds like the twin runner (t0 >= warmup), not in
    # rounded steps — the run-accumulator gating above stays step-based
    # to match BatchQueueSim exactly.
    warmup_s = (
        arrays.warmup_steps * dt if warmup_seconds is None else float(warmup_seconds)
    )
    tick_warm_r = jnp.asarray(
        (np.arange(n_ticks) * steps_per_tick * dt >= warmup_s).astype(np.float64)
    )
    span = steps_per_tick * dt
    t_max_real = sim["t_max"][:b_real]

    if proactive is not None:
        from ..forecast.mpc import forecast_init_state, forecast_step, mpc_plan

        topr_ops = _topr_ops()
        fstate0 = forecast_init_state(b, n, proactive, xp=jnp, dtype=sim["mu"].dtype)

        def topr(c, bud):
            return topr_ops.gain_topr(
                c, bud, interpret=interpret, force_kernel=force_kernel
            )

    def capacity_of(sim_d, k):
        mu_d, alpha_d = sim_d["mu"], sim_d["alpha"]
        kf = jnp.maximum(k.astype(mu_d.dtype), 0.0)
        eff = 1.0 / (1.0 + alpha_d * (kf - 1.0))
        spd = mu_d * sim_d["speed"]
        return jnp.where(sim_d["group"], spd * kf * eff, spd * kf)

    def chunk(ticks, st_d, sim_d, ext_d, warm_d, state):
        """Advance ``ticks`` windows from ``state`` — one lax.scan over
        tick indices (gathered from the pre-sliced arrival chunks, so a
        resumed chunk reads exactly the windows a straight-through run
        would).  Runs on whatever batch extent its operands carry: the
        full ``B`` under plain jit, or one device's shard under
        ``shard_map``."""
        mu = sim_d["mu"]
        mu_eff = sim_d["mu"] * sim_d["speed"]
        active = st_d["active"]
        t_max = sim_d["t_max"]
        alpha = sim_d["alpha"]
        group = sim_d["group"]

        bb = active.shape[0]  # this chunk's batch extent (shard under mesh)
        if compact_cfg is not None:
            decide_c = _make_compact_decide(
                decide_core, bb, _resolve_ladder(compact_cfg, bb)
            )
            mpc_ladder = _resolve_ladder(compact_cfg, bb)

        if proactive is not None and fused:
            # MPC candidate allocator through the SAME fused dispatch:
            # the planner hands us the candidate budgets as absolute
            # totals (already clipped to [floor_total, k_max]), so the
            # fused pass's internal budget = clip(k_max - floor, 0)
            # equals the planner's `extra` exactly — the tables agree
            # bitwise (sojourn_table_arrays mirrors sojourn_table_jax),
            # hence so do k_start and the selected increments.
            # Parameterized over the statics so the compacted MPC branch
            # can rebuild it from gathered (compacted-width) operands.
            def mpc_alloc_of(mu_eff_x, group_x, alpha_x, active_x):
                def mpc_alloc(lam_m, budgets_m):
                    bx = active_x.shape[0]
                    m = lam_m.shape[0]
                    r = m // bx

                    def rep(x):
                        return jnp.broadcast_to(
                            x[:, None, :], (bx, r, x.shape[-1])
                        ).reshape(m, x.shape[-1])

                    k4_m, _, _, _ = _decide_fused_ops().batch_decide(
                        lam_m, rep(mu_eff_x), group=rep(group_x),
                        alpha=rep(alpha_x), active=rep(active_x),
                        k_cur=jnp.zeros(lam_m.shape, dtype=jnp.int32),
                        k_max=budgets_m, k_hi=k_hi_res, j_cap=j_cap,
                        interpret=interpret, force_kernel=force_kernel,
                    )
                    return k4_m

                return mpc_alloc

            mpc_alloc = mpc_alloc_of(mu_eff, group, alpha, active)
        else:
            mpc_alloc_of = None
            mpc_alloc = None

        def tick_fn(carry, t_idx):
            if compact_cfg is not None:
                carry, dcache = carry[:-1], carry[-1]
            if proactive is not None:
                q, served_prev, k, acc, fstate = carry
            else:
                q, served_prev, k, acc = carry
            ext_chunk = lax.dynamic_index_in_dim(ext_d, t_idx, 0, keepdims=False)
            warm_chunk = lax.dynamic_index_in_dim(warm_d, t_idx, 0, keepdims=False)
            cap_serve_dt = capacity_of(sim_d, k) * dt
            out = window(
                q, served_prev, ext_chunk, warm_chunk, cap_serve_dt,
                sim_d["cap_queue"], sim_d["routing"],
            )
            (q1, served_prev1, offered, served_sum, dropped, ext_adm, ext_off,
             q_int, q_max, w_offered, w_served, w_dropped, w_ext_adm, w_ext_off,
             w_q_int) = out
            # Window measurement (ungated): the §13 synthetic snapshot.
            lam_hat = offered / span
            drop_hat = dropped / span
            admitted = jnp.maximum(lam_hat - drop_hat, 0.0)
            q_mean = q_int / steps_per_tick
            # §17 composed wait — the same helper (and op order) as the
            # numpy twin's window measurement, so twin == jit holds on
            # the measured-sojourn surface too.
            wait = _composed_wait(
                q_mean, admitted, dt, span, k, mu, group, alpha,
                sim_d["speed"], sim_d["ca2"], sim_d["cs2"], xp=jnp,
            )
            cap = capacity_of(sim_d, k)
            svc = jnp.where(
                group,
                jnp.where(cap > 0, 1.0 / cap, jnp.inf),
                1.0 / mu_eff,
            )
            lam0 = jnp.maximum(ext_adm / span, 0.0)
            contrib = jnp.where(admitted > 0, admitted * (wait + svc), 0.0)
            sojourn = jnp.where(
                lam0 > 0, contrib.sum(axis=-1) / jnp.maximum(lam0, 1e-300), jnp.nan
            )
            if compact_cfg is not None:
                dout, repriced, dcache = decide_c(
                    st_d, lam_hat, mu, drop_hat, lam0, k, dcache
                )
                code, k_next, et_cur, et_target, applied = dout
            else:
                code, k_next, et_cur, et_target, applied = decide_core(
                    st_d, lam_hat, mu, drop_hat, lam0, k
                )
            if proactive is not None:
                # Forecast plane: advance the predictors on this window's
                # measured rates, plan over the horizon from the live
                # backlog, and commit where the gate is open and the §11
                # trigger is quiet (the trigger always outranks the plan).
                fstate, lam_pred, conf = forecast_step(
                    fstate, lam_hat, active, proactive, xp=jnp
                )
                # Inline recompute of the trigger + completeness (decide
                # owns them internally; same formulas as the twin's
                # gating).  Computed BEFORE the planner so the compacted
                # path can restrict pricing to the commit-eligible lanes.
                k_floor = jnp.maximum(k.astype(jnp.int32), 1).astype(lam_hat.dtype)
                eff_t = 1.0 / (1.0 + alpha * (k_floor - 1.0))
                capacity = jnp.where(
                    group, mu_eff * k_floor * eff_t, mu_eff * k_floor
                )
                valid = jnp.isfinite(lam_hat) & jnp.isfinite(mu_eff) & (mu_eff > 0)
                drops_t = jnp.nan_to_num(drop_hat, nan=0.0)
                hot = (
                    valid & active & (
                        (lam_hat >= capacity * (1.0 - 1e-9))
                        | (drops_t > DROP_TRIGGER_FRACTION * capacity)
                    )
                ).any(axis=-1)
                complete = (
                    jnp.where(active, jnp.isfinite(lam_hat) & jnp.isfinite(mu), True)
                    .all(axis=-1)
                    & jnp.isfinite(lam0)
                )
                plan_kw = dict(
                    span=span, cfg=proactive, k_hi=k_hi_res, xp=jnp, topr=topr,
                )
                if compact_cfg is not None:
                    # A plan can only be committed where use (below) is
                    # open, and use is a subset of this eligibility mask
                    # — so pricing only these lanes is exact (mpc_plan
                    # is per-lane throughout).  any_ok defaults False
                    # (reactive fallback) on unpriced lanes; their
                    # k_plan / E[T] slots are never read.
                    eligible = conf & complete & ~hot & jnp.isfinite(t_max)

                    def price_mpc(g):
                        kp, ok, eh, ep, _ = mpc_plan(
                            lam_pred[g], q1[g], k[g], mu=mu[g],
                            group=st_d["group"][g], alpha=alpha[g],
                            speed=sim_d["speed"][g], active=active[g],
                            src_mask=st_d["src"][g],
                            cap_queue=sim_d["cap_queue"][g], t_max=t_max[g],
                            k_max=st_d["k_max"][g],
                            alloc=None if mpc_alloc_of is None
                            else mpc_alloc_of(
                                mu_eff[g], group[g], alpha[g], active[g]
                            ),
                            **plan_kw,
                        )
                        return kp, ok, eh, ep

                    inf_l = jnp.full(bb, jnp.inf, dtype=lam_hat.dtype)
                    k_plan, any_ok, et_hold, et_plan = _bucketed(
                        mpc_ladder, bb, eligible, price_mpc,
                        (jnp.where(active, k, 0), jnp.zeros(bb, dtype=bool),
                         inf_l, inf_l),
                    )
                else:
                    k_plan, any_ok, et_hold, et_plan, _need = mpc_plan(
                        lam_pred, q1, k, mu=mu, group=st_d["group"],
                        alpha=alpha, speed=sim_d["speed"], active=active,
                        src_mask=st_d["src"], cap_queue=sim_d["cap_queue"],
                        t_max=t_max, k_max=st_d["k_max"], alloc=mpc_alloc,
                        **plan_kw,
                    )
                use = conf & any_ok & complete & ~hot & jnp.isfinite(t_max)
                changed = use & (
                    (k_plan.astype(jnp.int32) != k) & active
                ).any(axis=-1)
                k_next = jnp.where(
                    use[:, None],
                    jnp.where(active, k_plan.astype(jnp.int32), k),
                    k_next,
                )
                code = jnp.where(
                    use,
                    jnp.where(changed, _CODE["proactive"], _CODE["none"]),
                    code,
                )
                applied = jnp.where(use, changed, applied)
                et_cur = jnp.where(use, et_hold, et_cur)
                et_target = jnp.where(use, et_plan, et_target)
            new_acc = tuple(
                a + w for a, w in zip(
                    acc[:6],
                    (w_offered, w_served, w_dropped, w_ext_adm, w_ext_off,
                     w_q_int),
                )
            ) + (jnp.maximum(acc[6], q_max),)
            ys = (code, k_next, sojourn, et_cur, et_target, applied)
            if proactive is not None:
                ys = ys + (use, conf)
            new_carry = (q1, served_prev1, k_next, new_acc)
            if proactive is not None:
                new_carry = new_carry + (fstate,)
            if compact_cfg is not None:
                ys = ys + (repriced,)
                new_carry = new_carry + (dcache,)
            return new_carry, ys

        carry0 = (state.q, state.served_prev, state.k, state.acc)
        if proactive is not None:
            carry0 = carry0 + (state.fstate,)
        if compact_cfg is not None:
            # The memo cache starts COLD every chunk (it is not part of
            # ControllerState): the chunk's first tick prices every lane,
            # which purity makes output-invisible — this is what keeps
            # checkpoints layout-independent (§18).
            carry0 = carry0 + (init_decide_cache(bb, n, dtype=mu.dtype),)
        xs = state.tick + jnp.arange(ticks, dtype=state.tick.dtype)
        final, ys = lax.scan(tick_fn, carry0, xs)
        new_state = ControllerState(
            q=final[0], served_prev=final[1], k=final[2], acc=final[3],
            tick=state.tick + ticks,
            fstate=final[4] if proactive is not None else (),
        )
        return new_state, ys

    def init_fn(k0) -> ControllerState:
        k0 = np.asarray(k0)
        if k0.shape[0] < b:  # mesh padding: inert lanes hold 0 processors
            k0 = np.concatenate(
                [k0, np.zeros((b - k0.shape[0], n), dtype=k0.dtype)]
            )
        # Each leaf gets its OWN buffer: the run step donates the whole
        # state, and XLA rejects the same buffer donated twice.
        def zeros2():
            return jnp.zeros((b, n))

        acc0 = (zeros2(), zeros2(), zeros2(), jnp.zeros(b), jnp.zeros(b),
                zeros2(), zeros2())
        fstate = ()
        if proactive is not None:
            fstate = tuple(jnp.array(x) for x in fstate0)  # copies: see above
        return ControllerState(
            q=zeros2(), served_prev=zeros2(),
            k=jnp.asarray(k0, dtype=jnp.int32),
            acc=acc0, tick=jnp.asarray(0, dtype=jnp.int32),
            fstate=fstate,
        )

    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def _lane_spec(v):
            nd = getattr(v, "ndim", 0)
            return P(axis, *((None,) * (nd - 1))) if nd >= 1 else P()

        st_specs = {k_: _lane_spec(v) for k_, v in st.items()}
        sim_specs = {k_: _lane_spec(v) for k_, v in sim.items()}
        state_specs = jax.tree.map(
            _lane_spec, init_fn(np.zeros((b_real, n), dtype=np.int64))
        )
        ys_lane, ys_row = P(None, axis), P(None, axis, None)
        ys_specs = (ys_lane, ys_row, ys_lane, ys_lane, ys_lane, ys_lane)
        if proactive is not None:
            ys_specs = ys_specs + (ys_lane, ys_lane)
        if compact_cfg is not None:
            ys_specs = ys_specs + (ys_lane,)
        data_specs = (P(None, None, axis, None), P(None, None))

    def build(ticks: int):
        if mesh is None:
            def stepped(state):
                return chunk(ticks, st, sim, ext_r, warm_r, state)
        else:
            sharded = shard_map(
                lambda st_, sim_, ext_, warm_, state_: chunk(
                    ticks, st_, sim_, ext_, warm_, state_
                ),
                mesh=mesh,
                in_specs=(st_specs, sim_specs) + data_specs + (state_specs,),
                out_specs=(state_specs, ys_specs),
                check_rep=False,
            )

            def stepped(state):
                return sharded(st, sim, ext_r, warm_r, state)

        def run(state):
            tick0 = state.tick
            new_state, ys = stepped(state)
            per_tick = tuple(y[:, :b_real] for y in ys)
            codes, k_hist, sojourns, et_cur, et_target, applied = per_tick[:6]
            # Warm flags + miss counting stay OUTSIDE shard_map: they are
            # per-tick scalars / cross-chunk reductions, not per-lane work.
            warm_flags = lax.dynamic_slice_in_dim(tick_warm_r, tick0, ticks)
            miss = (
                (sojourns > t_max_real[None, :]) & (warm_flags[:, None] > 0)
            ).sum(axis=0)
            acc = new_state.acc
            out = {
                "codes": codes, "k": k_hist, "sojourn": sojourns,
                "et_cur": et_cur, "et_target": et_target, "applied": applied,
                "miss": miss, "warm_windows": (warm_flags > 0).sum(),
                "k_final": new_state.k[:b_real], "q_final": new_state.q[:b_real],
                "offered": acc[0][:b_real], "served": acc[1][:b_real],
                "dropped": acc[2][:b_real],
                "ext_admitted": acc[3][:b_real], "ext_offered": acc[4][:b_real],
                "q_int": acc[5][:b_real], "q_max": acc[6][:b_real],
            }
            if proactive is not None:
                out["mpc_used"] = per_tick[6]
                out["confident"] = per_tick[7]
            if compact_cfg is not None:
                out["repriced"] = per_tick[-1]
            return new_state, out

        return jax.jit(run, donate_argnums=0)

    return FusedLoop(n_ticks, init_fn, build), n_ticks
