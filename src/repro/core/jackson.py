"""Jackson open queueing network model (paper Eq. 3 + traffic equations).

An application is a directed graph of operators with probabilistic routing.
``routing[i][j] = p`` means a tuple finishing at operator *i* produces an
input to operator *j* with expected multiplicity ``p`` (p may exceed 1 for
fan-out operators such as a feature extractor emitting many features per
frame — Jackson theory handles mean branching factors).

The per-operator arrival rates are tied to the external arrival vector
``lam0`` by the traffic equations

    lam_i = lam0_i + sum_j routing[j][i] * lam_j        (vector: lam = lam0 + P^T lam)

solved as ``lam = (I - P^T)^{-1} lam0``.  Loops (e.g. the paper's FPD
detector self-loop, or autoregressive decode in LLM serving) are supported
as long as the routing matrix has spectral radius < 1 — i.e. loops leak.

End-to-end expected total sojourn time (paper Eq. 3):

    E[T](k) = (1/lam0_total) * sum_i lam_i * E[T_i](k_i).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .erlang import expected_sojourn, min_stable_k

__all__ = [
    "OperatorSpec",
    "Topology",
    "UnstableTopologyError",
    "solve_traffic_equations",
]


class UnstableTopologyError(ValueError):
    """Routing matrix has spectral radius >= 1 (a loop that does not leak)."""


@dataclass(frozen=True)
class OperatorSpec:
    """Static description of one operator.

    mu is the mean per-processor service rate (tuples/sec).  ``scaling``
    selects how k processors compose:

    * ``"replica"`` — k independent servers: exact M/M/k (the paper's model).
    * ``"group"``   — the k processors form one gang (e.g. one pjit'd chip
      group); service rate is ``mu * k * group_efficiency(k)`` on an M/M/1
      queue.  See DESIGN.md §2 — this is the TPU chip-group extension.
    """

    name: str
    mu: float
    scaling: str = "replica"
    # group-mode efficiency curve: eff(k) multiplier on linear scaling.
    # Stored as (alpha) for eff(k) = 1 / (1 + alpha * (k - 1)); alpha=0 -> linear.
    group_alpha: float = 0.0
    min_k: int = 1
    max_k: int = 1 << 30

    def sojourn(self, k: int, lam: float) -> float:
        """E[T_i](k) for this operator under arrival rate lam."""
        if k < self.min_k:
            return math.inf
        if self.scaling == "replica":
            return expected_sojourn(k, lam, self.mu)
        if self.scaling == "group":
            eff = 1.0 / (1.0 + self.group_alpha * (k - 1))
            return expected_sojourn(1, lam, self.mu * k * eff)
        raise ValueError(f"unknown scaling {self.scaling!r}")

    def min_feasible_k(self, lam: float) -> int:
        """Smallest k with finite sojourn (Algorithm 1 line 2 init)."""
        if self.scaling == "replica":
            return max(self.min_k, min_stable_k(lam, self.mu))
        # group: need mu * k * eff(k) > lam.  With eff(k) = 1/(1+alpha(k-1))
        # the effective rate ASYMPTOTES at mu/alpha as k -> inf, so a load
        # beyond that is unreachable at any k — fail fast instead of
        # searching to max_k.
        if self.group_alpha > 0 and lam >= self.mu / self.group_alpha:
            raise UnstableTopologyError(
                f"operator {self.name}: group scaling saturates at "
                f"mu/alpha = {self.mu / self.group_alpha:.3g} < lam = {lam:.3g}; "
                "no chip count can keep this stage stable"
            )
        k = self.min_k
        while not math.isfinite(self.sojourn(k, lam)):
            k += 1
            if k > self.max_k:
                raise UnstableTopologyError(
                    f"operator {self.name}: no feasible k <= max_k={self.max_k} "
                    f"for lam={lam}, mu={self.mu} (group_alpha={self.group_alpha})"
                )
        return k


def solve_traffic_equations(
    lam0: np.ndarray, routing: np.ndarray, *, check_stability: bool = True
) -> np.ndarray:
    """Solve lam = lam0 + P^T lam for lam (Jackson traffic equations)."""
    lam0 = np.asarray(lam0, dtype=np.float64)
    p = np.asarray(routing, dtype=np.float64)
    n = lam0.shape[0]
    if p.shape != (n, n):
        raise ValueError(f"routing must be ({n},{n}), got {p.shape}")
    if np.any(p < 0):
        raise ValueError("routing probabilities/multiplicities must be >= 0")
    if check_stability:
        try:
            radius = max(abs(np.linalg.eigvals(p)))
        except np.linalg.LinAlgError:  # pragma: no cover - defensive
            radius = np.inf
        if radius >= 1.0 - 1e-12:
            raise UnstableTopologyError(
                f"routing spectral radius {radius:.6f} >= 1; a loop must leak "
                "probability for the open network to be stable"
            )
    lam = np.linalg.solve(np.eye(n) - p.T, lam0)
    # Numerical noise can produce tiny negatives for zero-traffic operators.
    lam[np.abs(lam) < 1e-12] = 0.0
    if np.any(lam < 0):
        raise UnstableTopologyError(f"negative solved arrival rates: {lam}")
    return lam


@dataclass
class Topology:
    """Operator network: specs + external arrivals + routing.

    This is the model-side mirror of a streaming application (or of a
    serving pipeline — see serving/pipeline.py which compiles a serving
    graph down to a Topology).
    """

    operators: list[OperatorSpec]
    lam0: np.ndarray  # external arrival rate per operator
    routing: np.ndarray  # routing[i][j] = expected tuples to j per tuple done at i
    _lam: np.ndarray | None = field(default=None, repr=False)

    def __post_init__(self) -> None:
        self.lam0 = np.asarray(self.lam0, dtype=np.float64)
        self.routing = np.asarray(self.routing, dtype=np.float64)
        n = len(self.operators)
        if self.lam0.shape != (n,):
            raise ValueError(f"lam0 must have shape ({n},), got {self.lam0.shape}")
        if self.routing.shape != (n, n):
            raise ValueError(
                f"routing must have shape ({n},{n}), got {self.routing.shape}"
            )

    # ------------------------------------------------------------------ #
    @property
    def n(self) -> int:
        return len(self.operators)

    @property
    def lam0_total(self) -> float:
        return float(self.lam0.sum())

    @property
    def arrival_rates(self) -> np.ndarray:
        """Per-operator arrival rates lam_i from the traffic equations."""
        if self._lam is None:
            self._lam = solve_traffic_equations(self.lam0, self.routing)
        return self._lam

    @property
    def visit_counts(self) -> np.ndarray:
        """Expected visits to each operator per external tuple: lam_i / lam0."""
        return self.arrival_rates / max(self.lam0_total, 1e-300)

    # ------------------------------------------------------------------ #
    def expected_sojourn(self, k: list[int] | np.ndarray) -> float:
        """E[T](k) — paper Eq. (3)."""
        k = np.asarray(k)
        if k.shape != (self.n,):
            raise ValueError(f"k must have shape ({self.n},), got {k.shape}")
        lam = self.arrival_rates
        total = 0.0
        for i, op in enumerate(self.operators):
            if lam[i] == 0.0:
                continue
            t = op.sojourn(int(k[i]), lam[i])
            if math.isinf(t):
                return math.inf
            total += lam[i] * t
        # Same zero-traffic guard as visit_counts: an idle network (all
        # lam0 == 0, e.g. one quiet measurement window) has E[T] = 0, not
        # a division crash in the middle of a control loop.
        return total / max(self.lam0_total, 1e-300)

    def per_operator_sojourn(self, k: list[int] | np.ndarray) -> np.ndarray:
        lam = self.arrival_rates
        return np.array(
            [op.sojourn(int(ki), lam[i]) for i, (op, ki) in enumerate(zip(self.operators, k))]
        )

    def min_feasible_allocation(self) -> np.ndarray:
        """Algorithm 1 lines 1-3: k_i = ceil(lam_i/mu_i) (stability-bumped)."""
        lam = self.arrival_rates
        return np.array(
            [op.min_feasible_k(lam[i]) for i, op in enumerate(self.operators)],
            dtype=np.int64,
        )

    def utilization(self, k: list[int] | np.ndarray) -> np.ndarray:
        """rho_i = lam_i / (k_i * mu_i) per operator (replica semantics)."""
        lam = self.arrival_rates
        return np.array(
            [
                lam[i] / (int(k[i]) * op.mu) if op.mu > 0 else np.inf
                for i, op in enumerate(self.operators)
            ]
        )

    # Convenience constructors ------------------------------------------ #
    @staticmethod
    def chain(names_mus: list[tuple[str, float]], lam0: float) -> "Topology":
        """A linear chain: source feeds op0, op_i feeds op_{i+1} (VLD shape)."""
        n = len(names_mus)
        ops = [OperatorSpec(name=nm, mu=mu) for nm, mu in names_mus]
        routing = np.zeros((n, n))
        for i in range(n - 1):
            routing[i][i + 1] = 1.0
        lam0_vec = np.zeros(n)
        lam0_vec[0] = lam0
        return Topology(ops, lam0_vec, routing)
