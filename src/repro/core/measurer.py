"""DRS measurer module (paper §IV + Appendix B-A).

Collects, per operator: the average aggregate tuple arrival rate
``lam_hat_i`` and the average service rate ``mu_hat_i``; and globally: the
external arrival rate ``lam0_hat`` and the measured mean complete sojourn
time ``E[T_hat]``.

Faithful to the paper's design:

* **bi-layer sampling** — each operator instance records the metric of one
  tuple every ``N_m`` local inputs (instance layer); the central measurer
  pulls aggregated counters every ``T_m`` seconds (pull layer).
* **operator-level aggregation** — instance counters are summed to operator
  level before model use (Appendix B-A (a)).
* **smoothing** — either alpha-weighted EWMA ``D(n) = a*D(n-1) + (1-a)*d(n)``
  or window averaging ``D(n) = mean(d(n-w+1..n))`` (Appendix B-A (b)).

The arrival-rate probe sits at the queue *tail* (Appendix C: "the rate
measurement position should be at the tail of the operator queue, instead
of the queue head") — i.e. we count enqueues, not dequeues, so an
overloaded operator still reports its true offered load.

Overload accounting (DESIGN.md §11): when the runtime sheds tuples under a
bounded-queue :class:`~repro.streaming.overload.OverloadPolicy`, every shed
tuple is reported through :meth:`InstanceProbe.on_dropped` so the model
sees the load explicitly instead of it silently vanishing (or, worse,
inflating the measured sojourn of the survivors).  Per-operator smoothed
drop rates surface on :class:`MeasurementSnapshot` as ``drop_hat``; the
per-operator ``lam_hat`` stays *offered* load (queue-tail counting includes
tuples that are then shed), while the global ``lam0_hat`` counts only
*admitted* external tuples — the scheduler's overload path combines the
two (see core/scheduler.py).
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "Smoother",
    "EwmaSmoother",
    "WindowSmoother",
    "InstanceProbe",
    "OperatorMetrics",
    "Measurer",
    "MeasurementSnapshot",
    "MeasurementBatch",
    "stack_snapshots",
]


class Smoother:
    def update(self, x: float) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    @property
    def value(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class EwmaSmoother(Smoother):
    """D(n) = alpha * D(n-1) + (1 - alpha) * d(n), alpha in [0, 1)."""

    def __init__(self, alpha: float = 0.6):
        if not 0.0 <= alpha < 1.0:
            raise ValueError(f"alpha must be in [0,1), got {alpha}")
        self.alpha = alpha
        self._v: float | None = None

    def update(self, x: float) -> float:
        self._v = x if self._v is None else self.alpha * self._v + (1 - self.alpha) * x
        return self._v

    @property
    def value(self) -> float:
        return float("nan") if self._v is None else self._v


class WindowSmoother(Smoother):
    """D(n) = (1/w) * sum_{j=n-w+1..n} d(j)."""

    def __init__(self, w: int = 5):
        if w < 1:
            raise ValueError(f"window must be >= 1, got {w}")
        self._buf: deque[float] = deque(maxlen=w)

    def update(self, x: float) -> float:
        self._buf.append(x)
        return self.value

    @property
    def value(self) -> float:
        return float(np.mean(self._buf)) if self._buf else float("nan")


def make_smoother(kind: str, **kw) -> Smoother:
    if kind == "ewma":
        return EwmaSmoother(**kw)
    if kind == "window":
        return WindowSmoother(**kw)
    raise ValueError(f"unknown smoother kind {kind!r}")


@dataclass
class InstanceProbe:
    """Instance-local metric recorder (the injected 'measurement logic').

    Thread-safe; records every ``n_m``-th tuple's service time and counts
    every enqueue (arrivals are never sampled — counting is cheap; only the
    *timing* is sampled, mirroring the paper's overhead argument).
    """

    n_m: int = 10
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    arrivals: int = 0
    processed: int = 0
    dropped: int = 0
    sampled_service_time: float = 0.0
    sampled_count: int = 0
    _tick: int = 0

    def on_enqueue(self, n: int = 1) -> None:
        with self._lock:
            self.arrivals += n

    def on_dropped(self, n: int = 1) -> None:
        """Tuple(s) shed at this operator's queue (still counted as offered
        load by :meth:`on_enqueue`; this records the shed portion)."""
        with self._lock:
            self.dropped += n

    def on_processed(self, service_time: float, n: int = 1) -> None:
        with self._lock:
            self.processed += n
            self._tick += n
            # Subtract (not reset) so batched reports (n > 1) crossing the
            # n_m boundary keep the remainder and the sampling rate stays
            # exactly 1/N_m; each wrap is one sampled tuple.
            while self._tick >= self.n_m:
                self._tick -= self.n_m
                self.sampled_service_time += service_time
                self.sampled_count += 1

    def drain(self) -> tuple[int, int, float, int, int]:
        """Pull-and-reset (the central measurer's T_m pull)."""
        with self._lock:
            out = (
                self.arrivals,
                self.processed,
                self.sampled_service_time,
                self.sampled_count,
                self.dropped,
            )
            self.arrivals = 0
            self.processed = 0
            self.sampled_service_time = 0.0
            self.sampled_count = 0
            self.dropped = 0
            return out


@dataclass
class OperatorMetrics:
    """Operator-level aggregated + smoothed estimates."""

    name: str
    lam_smoother: Smoother
    mu_smoother: Smoother
    drop_smoother: Smoother
    lam_hat: float = float("nan")
    mu_hat: float = float("nan")
    drop_hat: float = 0.0
    last_raw_lam: float = float("nan")
    last_raw_mu: float = float("nan")

    def ingest(
        self,
        arrivals: int,
        service_time_sum: float,
        samples: int,
        dt: float,
        dropped: int = 0,
    ) -> None:
        if dt <= 0:
            return
        raw_lam = arrivals / dt
        self.last_raw_lam = raw_lam
        self.lam_hat = self.lam_smoother.update(raw_lam)
        self.drop_hat = self.drop_smoother.update(dropped / dt)
        if samples > 0 and service_time_sum > 0:
            raw_mu = samples / service_time_sum  # tuples/sec per processor
            self.last_raw_mu = raw_mu
            self.mu_hat = self.mu_smoother.update(raw_mu)


@dataclass(frozen=True)
class MeasurementSnapshot:
    """One pull interval's smoothed view — the optimizer's input."""

    lam_hat: np.ndarray  # per-operator smoothed *offered* arrival rates (queue tail)
    mu_hat: np.ndarray  # per-operator smoothed per-processor service rates
    lam0_hat: float  # external arrival rate (admitted tuples only)
    sojourn_hat: float  # measured mean complete sojourn time E[T^]
    t: float  # timestamp of the pull
    # Per-operator smoothed drop (load-shed) rates, tuples/sec.  Zeros when
    # queues are unbounded / nothing was shed.  lam_hat - drop_hat is the
    # admitted rate; lam_hat alone is the offered load (DESIGN.md §11).
    drop_hat: np.ndarray | None = None

    def complete(self) -> bool:
        return (
            np.all(np.isfinite(self.lam_hat))
            and np.all(np.isfinite(self.mu_hat))
            and np.isfinite(self.lam0_hat)
        )

    def drop_rates(self) -> np.ndarray:
        """Per-operator drop rates (zeros when none were recorded)."""
        if self.drop_hat is None:
            return np.zeros_like(self.lam_hat)
        return np.nan_to_num(self.drop_hat, nan=0.0)

    @classmethod
    def from_rates(
        cls,
        lam_hat,
        mu_hat,
        lam0_hat: float,
        sojourn_hat: float,
        t: float,
        drop_hat=None,
    ) -> "MeasurementSnapshot":
        """Synthetic snapshot from already-aggregated rates (the batched-
        measurement hook: the vectorized scenario sweep measures whole
        windows at once and feeds ``DRSScheduler.tick_from`` directly,
        bypassing the per-instance probe/pull layer)."""
        return cls(
            lam_hat=np.asarray(lam_hat, dtype=np.float64),
            mu_hat=np.asarray(mu_hat, dtype=np.float64),
            lam0_hat=float(lam0_hat),
            sojourn_hat=float(sojourn_hat),
            t=float(t),
            drop_hat=None if drop_hat is None else np.asarray(drop_hat, dtype=np.float64),
        )


@dataclass(frozen=True)
class MeasurementBatch:
    """A ``[B, N]`` stack of measurement snapshots — the batched
    controller's input surface (DESIGN.md §14).

    Scenarios narrower than ``N`` are padded with inert lanes (zero
    rates, finite mu) so the stacked arrays are rectangular; per-scenario
    ``active`` masks (carried by the controller's static bundle, not
    here) recover the real lanes.  Build one with :func:`stack_snapshots`
    (from per-tenant live pulls) or directly from window aggregates (the
    vectorized scenario sweep).
    """

    lam_hat: np.ndarray  # [B, N] smoothed offered arrival rates
    mu_hat: np.ndarray  # [B, N] per-processor service rates (reference class)
    lam0_hat: np.ndarray  # [B] external (admitted) arrival rates
    sojourn_hat: np.ndarray  # [B] measured mean sojourn E[T^]
    t: float  # timestamp shared by the stack
    drop_hat: np.ndarray  # [B, N] smoothed shed rates (zeros when none)

    @property
    def batch(self) -> int:
        return self.lam_hat.shape[0]

    @property
    def n(self) -> int:
        return self.lam_hat.shape[1]

    def complete(self, active: np.ndarray | None = None) -> np.ndarray:
        """[B] bool: every *active* lane finite (the per-snapshot
        ``complete()`` rule, vectorized)."""
        fin = np.isfinite(self.lam_hat) & np.isfinite(self.mu_hat)
        if active is not None:
            fin = fin | ~np.asarray(active, dtype=bool)
        return fin.all(axis=1) & np.isfinite(self.lam0_hat)

    def row(self, bi: int, n: int | None = None) -> MeasurementSnapshot:
        """Scenario ``bi``'s lanes as a scalar MeasurementSnapshot."""
        sl = slice(None) if n is None else slice(0, n)
        return MeasurementSnapshot.from_rates(
            self.lam_hat[bi, sl],
            self.mu_hat[bi, sl],
            float(self.lam0_hat[bi]),
            float(self.sojourn_hat[bi]),
            self.t,
            drop_hat=self.drop_hat[bi, sl],
        )

    @classmethod
    def from_rates(
        cls, lam_hat, mu_hat, lam0_hat, sojourn_hat, t: float, drop_hat=None
    ) -> "MeasurementBatch":
        lam_hat = np.atleast_2d(np.asarray(lam_hat, dtype=np.float64))
        return cls(
            lam_hat=lam_hat,
            mu_hat=np.atleast_2d(np.asarray(mu_hat, dtype=np.float64)),
            lam0_hat=np.atleast_1d(np.asarray(lam0_hat, dtype=np.float64)),
            sojourn_hat=np.atleast_1d(np.asarray(sojourn_hat, dtype=np.float64)),
            t=float(t),
            drop_hat=(
                np.zeros_like(lam_hat)
                if drop_hat is None
                else np.atleast_2d(np.asarray(drop_hat, dtype=np.float64))
            ),
        )


def stack_snapshots(
    snaps: "list[MeasurementSnapshot]", n: int | None = None
) -> MeasurementBatch:
    """Stack per-scenario/tenant snapshots into one padded batch.

    Padding lanes get zero arrival/drop rates and ``mu = 1`` (finite, so
    they never fail the completeness check); ``n`` widens the batch
    beyond the widest snapshot when the caller's static arrays demand it.
    """
    if not snaps:
        raise ValueError("need at least one snapshot to stack")
    width = max(len(s.lam_hat) for s in snaps)
    n = width if n is None else max(n, width)
    b = len(snaps)
    lam = np.zeros((b, n))
    mu = np.ones((b, n))
    drop = np.zeros((b, n))
    lam0 = np.zeros(b)
    soj = np.zeros(b)
    for bi, s in enumerate(snaps):
        ni = len(s.lam_hat)
        lam[bi, :ni] = s.lam_hat
        mu[bi, :ni] = s.mu_hat
        drop[bi, :ni] = s.drop_rates()
        lam0[bi] = s.lam0_hat
        soj[bi] = s.sojourn_hat
    return MeasurementBatch(lam, mu, lam0, soj, float(snaps[0].t), drop)


class Measurer:
    """Central measurer: owns per-operator probes + global tuple tracking.

    The engine (streaming/engine.py) or serving router registers one probe
    per operator instance; completed external tuples report their total
    sojourn time here (the paper uses Storm's acker tree for this).
    """

    def __init__(
        self,
        operator_names: list[str],
        *,
        n_m: int = 10,
        smoother: str = "ewma",
        smoother_kw: dict | None = None,
    ):
        kw = dict(smoother_kw or {})
        self.names = list(operator_names)
        self.n_m = n_m
        self._probes: dict[str, list[InstanceProbe]] = {n: [] for n in self.names}
        self._metrics = {
            n: OperatorMetrics(
                n,
                make_smoother(smoother, **kw),
                make_smoother(smoother, **kw),
                make_smoother(smoother, **kw),
            )
            for n in self.names
        }
        self._lam0_smoother = make_smoother(smoother, **kw)
        self._sojourn_smoother = make_smoother(smoother, **kw)
        self._lock = threading.Lock()
        self._external_arrivals = 0
        self._sojourn_sum = 0.0
        self._sojourn_n = 0
        self._last_pull_t: float | None = None
        # Per-instance raw service rates from the latest pull (probe order =
        # instance index; NaN for instances with no samples in the window).
        # The scheduler's StragglerDetector consumes this — operator-level
        # aggregation hides *which* instance is slow.
        self.last_instance_mu: dict[str, list[float]] = {}

    # Registration / reporting ------------------------------------------ #
    def new_probe(self, operator: str) -> InstanceProbe:
        p = InstanceProbe(n_m=self.n_m)
        self._probes[operator].append(p)
        return p

    def on_external_arrival(self, n: int = 1) -> None:
        with self._lock:
            self._external_arrivals += n

    def on_tuple_complete(self, sojourn: float, n: int = 1) -> None:
        """Completion of an external tuple's whole processing tree."""
        with self._lock:
            self._sojourn_sum += sojourn * n
            self._sojourn_n += n

    # Pull layer --------------------------------------------------------- #
    def pull(self, now: float) -> MeasurementSnapshot:
        """T_m-periodic pull: drain probes, aggregate, smooth, snapshot."""
        dt = 0.0 if self._last_pull_t is None else now - self._last_pull_t
        self._last_pull_t = now
        lam = np.full(len(self.names), np.nan)
        mu = np.full(len(self.names), np.nan)
        drop = np.zeros(len(self.names))
        inst_mu: dict[str, list[float]] = {}
        for idx, name in enumerate(self.names):
            arrivals, _processed, st_sum, st_n, dropped = 0, 0, 0.0, 0, 0
            rates: list[float] = []
            for p in self._probes[name]:
                a, pr, s, c, dr = p.drain()
                arrivals += a
                _processed += pr
                st_sum += s
                st_n += c
                dropped += dr
                rates.append(c / s if (c > 0 and s > 0) else float("nan"))
            inst_mu[name] = rates
            m = self._metrics[name]
            m.ingest(arrivals, st_sum, st_n, dt, dropped)
            lam[idx] = m.lam_hat
            mu[idx] = m.mu_hat
            drop[idx] = m.drop_hat
        self.last_instance_mu = inst_mu
        with self._lock:
            ext, self._external_arrivals = self._external_arrivals, 0
            s_sum, self._sojourn_sum = self._sojourn_sum, 0.0
            s_n, self._sojourn_n = self._sojourn_n, 0
        lam0 = self._lam0_smoother.update(ext / dt) if dt > 0 else float("nan")
        soj = (
            self._sojourn_smoother.update(s_sum / s_n)
            if s_n > 0
            else self._sojourn_smoother.value
        )
        return MeasurementSnapshot(lam, mu, lam0, soj, now, drop_hat=drop)
