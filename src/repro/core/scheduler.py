"""DRS scheduler — the control loop (paper §III-C step (a)-(c), §IV).

Each tick:
  1. pull a smoothed :class:`MeasurementSnapshot` from the measurer;
  2. rebuild the model Topology from (lam0_hat, lam_hat, mu_hat) — routing
     multiplicities are re-estimated from measured per-operator arrival
     ratios, so shifts in data properties (e.g. more SIFT features per
     frame) are tracked without re-declaring the graph;
  3. run Program (6) when a T_max is configured (how many processors do we
     need?) and Program (4) at the current K_max (where do they go?);
  4. decide: scale out (negotiator.ensure) when Program (6) needs more than
     leased; scale in when it needs sufficiently less (hysteresis); and/or
     rebalance the allocation when the cost/benefit plan says so;
  5. emit a :class:`SchedulerDecision` for the CSP layer to execute.

Straggler handling is paper-native: a straggler inside operator i drags the
measured mu_hat_i down; the model then predicts a T_max violation and the
loop reallocates — no special case needed.  A separate watchdog
(:class:`StragglerDetector`) additionally flags *which* instance is slow by
comparing per-instance service-time samples against the operator median.

Overload (DESIGN.md §11) is a defined path, not an accident: when the
measured utilisation rho_i = lam_hat_i / (k_i * mu_hat_i) reaches 1 for
any operator, the snapshot's downstream arrival rates are *throughput-
capped* (a saturated operator only emits at its service capacity, so
everything below it under-reports the true offered load).  The model is
then rebuilt from offered-load rates instead: source lam0 comes from the
queue-tail arrival probes (which count shed tuples too) and the declared
routing multiplicities are kept for every edge whose upstream measurement
is capped.  The decision action is ``"overloaded"``, which bypasses the
rebalance cost/benefit gate and the scale-in hysteresis and asks the
negotiator for capacity immediately.
"""

from __future__ import annotations

import logging
import math
import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from .allocator import (
    AllocationResult,
    InsufficientResourcesError,
    assign_processors,
    assign_processors_table,
    min_processors,
    min_processors_table,
)
from .jackson import OperatorSpec, Topology, UnstableTopologyError
from .measurer import Measurer, MeasurementSnapshot
from .negotiator import Negotiator
from .rebalance import ExecutableCache, RebalanceCostModel, RebalancePlan

logger = logging.getLogger(__name__)

__all__ = ["SchedulerConfig", "SchedulerDecision", "DRSScheduler", "StragglerDetector"]


@dataclass(frozen=True)
class SchedulerConfig:
    t_max: float | None = None  # real-time constraint (seconds); None = Program 4 only
    k_max: int | None = None  # static budget; None = ask the negotiator
    horizon_seconds: float = 300.0  # cost/benefit planning horizon
    scale_in_hysteresis: float = 0.8  # scale in only if need < hysteresis * leased
    min_improvement: float = 0.05  # rebalance only if E[T] improves by >= 5%
    headroom: float = 1.1  # provision Program-6 result * headroom (model error guard)
    tick_interval: float = 10.0  # T_m: pull + decide period
    # Model-evaluation backend for Programs (4)/(6): "table" delegates to the
    # batched gain-table core (core/batched.py, DESIGN.md §12 — bit-identical
    # allocations, ~1000x less per-tick Python work at pod-scale K_max);
    # "heap" keeps the scalar heap greedy (PR-1 behaviour, used as a
    # cross-check in tests and benchmarks).
    allocator: str = "table"


_ALLOCATORS = {
    "table": (assign_processors_table, min_processors_table),
    "heap": (assign_processors, min_processors),
}


@dataclass(frozen=True)
class SchedulerDecision:
    """What the CSP layer should do after a tick."""

    t: float
    # "none" | "rebalance" | "scale_out" | "scale_in" | "infeasible"
    # | "overloaded" (measured rho >= 1 somewhere: offered-load model,
    #   immediate negotiator scale-out, no hysteresis / cost-benefit gate)
    # | "rebalance_hint" (no model-driven change, but the StragglerDetector
    #   flagged slow instances — advisory: the CSP layer should consider
    #   replacing/rebalancing the named (operator, instance) pairs)
    action: str
    k_current: np.ndarray
    k_target: np.ndarray | None
    k_max: int
    model_sojourn_current: float
    model_sojourn_target: float | None
    measured_sojourn: float
    plan: RebalancePlan | None = None
    reason: str = ""
    # (operator, instance) pairs the straggler watchdog flagged this tick.
    stragglers: tuple = ()

    def as_dict(self) -> dict:
        return {
            "t": self.t,
            "action": self.action,
            "k_current": self.k_current.tolist(),
            "k_target": None if self.k_target is None else self.k_target.tolist(),
            "k_max": self.k_max,
            "model_sojourn_current": self.model_sojourn_current,
            "model_sojourn_target": self.model_sojourn_target,
            "measured_sojourn": self.measured_sojourn,
            "reason": self.reason,
            "stragglers": list(self.stragglers),
        }


class DRSScheduler:
    """The DRS optimizer + scheduler modules glued together."""

    def __init__(
        self,
        operator_names: list[str],
        base_routing: np.ndarray,
        k_current: np.ndarray,
        config: SchedulerConfig,
        *,
        measurer: Measurer | None = None,
        negotiator: Negotiator | None = None,
        cost_model: RebalanceCostModel | None = None,
        executable_cache: ExecutableCache | None = None,
        scaling: list[str] | None = None,
        group_alpha: list[float] | None = None,
        on_decision: Callable[[SchedulerDecision], None] | None = None,
        straggler_detector: "StragglerDetector | None" = None,
    ):
        self.names = list(operator_names)
        self.base_routing = np.asarray(base_routing, dtype=np.float64)
        self.k_current = np.asarray(k_current, dtype=np.int64).copy()
        self.config = config
        self.measurer = measurer or Measurer(self.names)
        self.negotiator = negotiator
        self.cost_model = cost_model or RebalanceCostModel()
        self.cache = executable_cache
        self.scaling = scaling or ["replica"] * len(self.names)
        self.group_alpha = group_alpha or [0.0] * len(self.names)
        self.on_decision = on_decision
        self.straggler_detector = (
            StragglerDetector() if straggler_detector is None else straggler_detector
        )
        try:
            self._assign, self._min_proc = _ALLOCATORS[config.allocator]
        except KeyError:
            raise ValueError(
                f"unknown allocator {config.allocator!r}; "
                f"expected one of {sorted(_ALLOCATORS)}"
            ) from None
        self.history: list[SchedulerDecision] = []
        self.rebalance_count = 0

    # ------------------------------------------------------------------ #
    # Drop-rate trigger: an operator shedding more than this fraction of
    # its capacity is overloaded even if the smoothed arrival rate dips
    # below capacity (EWMA lag under bursty arrivals).
    DROP_TRIGGER_FRACTION = 0.01

    def overloaded_mask(self, snap: MeasurementSnapshot) -> np.ndarray:
        """Per-operator bool: measured offered load >= current capacity,
        OR sustained shedding at the operator's queue.

        Combines the two overload signals (measurer docstring): queue-tail
        arrival rates (offered load, shed tuples included) against
        k_current * mu_hat — with group scaling's efficiency curve applied
        — and the per-operator drop rate, which catches saturation the
        smoothed arrival rate is still lagging behind.  This is the
        defined trigger for the ``"overloaded"`` path.
        """
        n = len(self.names)
        drops = snap.drop_rates()
        mask = np.zeros(n, dtype=bool)
        for i in range(n):
            lam, mu = float(snap.lam_hat[i]), float(snap.mu_hat[i])
            if not (math.isfinite(lam) and math.isfinite(mu)) or mu <= 0:
                continue
            k_i = max(int(self.k_current[i]), 1)
            if self.scaling[i] == "group":
                eff = 1.0 / (1.0 + self.group_alpha[i] * (k_i - 1))
                capacity = mu * k_i * eff
            else:
                capacity = mu * k_i
            mask[i] = (
                lam >= capacity * (1.0 - 1e-9)
                or float(drops[i]) > self.DROP_TRIGGER_FRACTION * capacity
            )
        return mask

    def _capped_mask(self, overloaded: np.ndarray) -> np.ndarray:
        """Operators whose *measured arrival rate* is throughput-capped:
        anything downstream (transitively) of a saturated operator — a
        saturated operator emits at its capacity, not its offered load, so
        measurements below it cannot be trusted during overload."""
        n = len(self.names)
        adj = self.base_routing > 0
        out_capped = overloaded.copy()  # operator's output under-represents load
        in_capped = np.zeros(n, dtype=bool)
        for _ in range(n):
            new_in = np.array([(adj[:, j] & out_capped).any() for j in range(n)])
            new_out = overloaded | new_in
            if (new_in == in_capped).all() and (new_out == out_capped).all():
                break
            in_capped, out_capped = new_in, new_out
        return in_capped

    def topology_from(
        self, snap: MeasurementSnapshot, overloaded: np.ndarray | None = None
    ) -> Topology:
        """Rebuild the model from measurements.

        Routing multiplicities are rescaled from the *declared* graph
        shape and the *measured* arrival ratios: for edge (i -> j) with
        declared weight w_ij > 0 we set w'_ij = w_ij * r_j where r_j scales
        all of j's in-edges so the traffic equations reproduce lam_hat_j.
        This keeps the graph structure (which DRS knows) but tracks data-
        dependent fan-out (which only measurement can see).

        Unstable snapshots (some measured rho_i >= 1) clamp the model to
        offered-load rates: source lam0 comes straight from the queue-tail
        arrival probes (``lam0_hat`` only counts admitted tuples and
        under-reports during shedding), and the measured rescale is
        skipped for operators whose in-flow is throughput-capped by a
        saturated upstream — their declared multiplicities are kept.
        """
        n = len(self.names)
        if overloaded is None:
            overloaded = self.overloaded_mask(snap)
        hot = bool(overloaded.any())
        capped = self._capped_mask(overloaded) if hot else np.zeros(n, dtype=bool)
        lam_hat = np.array(snap.lam_hat, dtype=np.float64)
        lam0 = np.zeros(n)
        # External arrivals enter at declared sources (no in-edges).
        in_deg = self.base_routing.sum(axis=0)
        sources = np.nonzero(in_deg == 0)[0]
        if len(sources) == 0:
            sources = np.array([0])
        if hot:
            # Offered load at the queue tail (includes shed tuples).
            for s in sources:
                lam0[s] = lam_hat[s] if math.isfinite(lam_hat[s]) else 0.0
        else:
            src_lam = lam_hat[sources]
            total_src = max(src_lam.sum(), 1e-12)
            for s, l in zip(sources, src_lam):
                lam0[s] = snap.lam0_hat * (l / total_src) if math.isfinite(snap.lam0_hat) else l
        routing = self.base_routing.copy()
        # Rescale in-edges to match measured per-operator arrival rates.
        for j in range(n):
            declared_in = routing[:, j]
            if declared_in.sum() == 0:
                continue
            if capped[j]:
                continue  # measured lam_hat[j] is capacity, not offered load
            inflow = float(np.dot(declared_in, lam_hat))  # predicted from measured upstream
            if inflow > 1e-12 and math.isfinite(lam_hat[j]) and lam_hat[j] > 0:
                routing[:, j] *= lam_hat[j] / inflow
        ops = [
            OperatorSpec(
                name=self.names[i],
                mu=float(snap.mu_hat[i]),
                scaling=self.scaling[i],
                group_alpha=self.group_alpha[i],
            )
            for i in range(n)
        ]
        return Topology(ops, lam0, routing)

    # ------------------------------------------------------------------ #
    def tick(self, now: float | None = None) -> SchedulerDecision:
        now = time.time() if now is None else now
        snap = self.measurer.pull(now)
        self._observe_instances()
        return self.tick_from(snap, now)

    def tick_from(self, snap: MeasurementSnapshot, now: float) -> SchedulerDecision:
        """One tick on an externally-supplied snapshot (no measurer pull).

        This is the batched-snapshot hook: callers that measure outside
        the live probe path — the vectorized scenario sweep
        (``api.session.ScenarioRunner``) builds one synthetic snapshot per
        scenario per window via :meth:`MeasurementSnapshot.from_rates` —
        drive the identical model/decide path the live loop uses.
        """
        if not snap.complete():
            d = SchedulerDecision(
                now, "none", self.k_current.copy(), None,
                self._k_max(), float("nan"), None, snap.sojourn_hat,
                reason="insufficient measurements",
            )
            self._emit(d)
            return d
        overloaded = self.overloaded_mask(snap)
        top = self.topology_from(snap, overloaded)
        return self.decide(top, snap, now, overloaded=overloaded)

    def _k_max(self) -> int:
        if self.config.k_max is not None:
            return self.config.k_max
        if self.negotiator is not None:
            return self.negotiator.k_max
        return int(self.k_current.sum())

    # --- Straggler watchdog -------------------------------------------- #
    def _observe_instances(self) -> None:
        """Feed the per-instance service rates the measurer's last pull
        recorded into the straggler watchdog (instance identity = probe
        index within the operator)."""
        if self.straggler_detector is None:
            return
        for op, rates in (getattr(self.measurer, "last_instance_mu", None) or {}).items():
            for idx, mu in enumerate(rates):
                if math.isfinite(mu):
                    self.straggler_detector.observe(op, idx, mu)

    def straggler_hints(self) -> tuple:
        """(operator, instance) pairs currently flagged by the watchdog."""
        if self.straggler_detector is None:
            return ()
        return tuple(self.straggler_detector.stragglers())

    def decide(
        self,
        top: Topology,
        snap: MeasurementSnapshot,
        now: float,
        overloaded: np.ndarray | None = None,
    ) -> SchedulerDecision:
        cfg = self.config
        k_max = self._k_max()
        et_cur = top.expected_sojourn(self.k_current)
        stragglers = self.straggler_hints()

        # --- Overload: defined unstable-snapshot path ------------------- #
        # tick() passes the mask it already clamped the topology with, so
        # detection and clamping cannot disagree; direct callers get it
        # computed here.
        if overloaded is None:
            overloaded = self.overloaded_mask(snap)
        if overloaded.any():
            return self._handle_overload(top, snap, now, k_max, et_cur, overloaded)

        # --- Program (6): how many processors do we actually need? ------ #
        need: AllocationResult | None = None
        if cfg.t_max is not None:
            try:
                need = self._min_proc(top, cfg.t_max)
            except InsufficientResourcesError:
                need = None

        # Scale out: T_max unreachable within the current lease.
        if cfg.t_max is not None:
            needed_total = (
                math.ceil(need.total * cfg.headroom) if need is not None else k_max + 1
            )
            if needed_total > k_max and self.negotiator is not None:
                self.negotiator.ensure(needed_total)
                new_k_max = self.negotiator.k_max
                if new_k_max > k_max:
                    k_max = new_k_max
                    best = self._assign(top, k_max)
                    return self._apply(
                        now, "scale_out", best, top, et_cur, snap,
                        reason=f"Program(6) needs {needed_total} > leased; "
                        f"negotiated k_max={k_max}",
                    )
            # Scale in: we need much less than we lease (with hysteresis).
            if (
                need is not None
                and self.negotiator is not None
                and math.ceil(need.total * cfg.headroom) < cfg.scale_in_hysteresis * k_max
            ):
                target_total = math.ceil(need.total * cfg.headroom)
                self.negotiator.ensure(target_total)
                new_k_max = self.negotiator.k_max
                if new_k_max < k_max:
                    best = self._assign(top, new_k_max)
                    return self._apply(
                        now, "scale_in", best, top, et_cur, snap,
                        reason=f"Program(6) needs {need.total} (headroom "
                        f"{target_total}) << leased {k_max}; released to {new_k_max}",
                    )

        # --- Program (4): best placement within k_max ------------------- #
        try:
            best = self._assign(top, k_max)
        except InsufficientResourcesError as e:
            d = SchedulerDecision(
                now, "infeasible", self.k_current.copy(), None, k_max,
                et_cur, None, snap.sojourn_hat,
                reason=str(e),
            )
            self._emit(d)
            return d

        improvement = (
            (et_cur - best.expected_sojourn) / et_cur if math.isfinite(et_cur) and et_cur > 0
            else float("inf")
        )
        if np.array_equal(best.k, self.k_current) or improvement < cfg.min_improvement:
            d = self._none_or_hint(
                now, best, k_max, et_cur, snap, stragglers,
                reason=f"improvement {improvement:.1%} < {cfg.min_improvement:.0%}",
            )
            self._emit(d)
            return d

        plan = self.cost_model.plan(
            top, self.k_current, best.k, cache=self.cache, stage_names=self.names
        )
        if not plan.worthwhile(cfg.horizon_seconds, top.lam0_total) and math.isfinite(et_cur):
            d = self._none_or_hint(
                now, best, k_max, et_cur, snap, stragglers, plan=plan,
                reason="rebalance cost exceeds benefit over horizon",
            )
            self._emit(d)
            return d
        return self._apply(now, "rebalance", best, top, et_cur, snap, plan=plan)

    def _none_or_hint(
        self,
        now: float,
        best: AllocationResult,
        k_max: int,
        et_cur: float,
        snap: MeasurementSnapshot,
        stragglers: tuple,
        *,
        plan: RebalancePlan | None = None,
        reason: str = "",
    ) -> SchedulerDecision:
        """A model-driven no-op — unless the straggler watchdog flagged slow
        instances, in which case the decision becomes an advisory
        ``"rebalance_hint"`` naming them (the model can't see *which*
        instance is slow, only the dragged-down operator mu_hat)."""
        action = "none"
        if stragglers:
            action = "rebalance_hint"
            named = ", ".join(f"{op}[{inst}]" for op, inst in stragglers)
            reason = (reason + "; " if reason else "") + f"stragglers flagged: {named}"
        return SchedulerDecision(
            now, action, self.k_current.copy(), best.k, k_max,
            et_cur, best.expected_sojourn, snap.sojourn_hat, plan,
            reason, stragglers,
        )

    def _handle_overload(
        self,
        top: Topology,
        snap: MeasurementSnapshot,
        now: float,
        k_max: int,
        et_cur: float,
        overloaded: np.ndarray,
    ) -> SchedulerDecision:
        """Measured rho_i >= 1 somewhere: scale out *now*.

        ``top`` is already offered-load-clamped by :meth:`topology_from`.
        Sizing uses Program (6) when a T_max is configured, else the
        minimum feasible (stable) allocation; the negotiator is asked
        immediately — no scale-in hysteresis, no cost/benefit gate (queues
        are growing or shedding while we deliberate).
        """
        cfg = self.config
        hot_names = [self.names[i] for i in np.nonzero(overloaded)[0]]
        try:
            if cfg.t_max is not None:
                need_total = math.ceil(self._min_proc(top, cfg.t_max).total * cfg.headroom)
            else:
                need_total = math.ceil(
                    int(top.min_feasible_allocation().sum()) * cfg.headroom
                )
        except (InsufficientResourcesError, UnstableTopologyError):
            # T_max (or stability itself) unreachable at any k — lease as
            # much as the pool allows and do the best we can.
            need_total = k_max + 1
        if need_total > k_max and self.negotiator is not None:
            self.negotiator.ensure(need_total)
            k_max = max(k_max, self.negotiator.k_max)
        try:
            best = self._assign(top, k_max)
        except (InsufficientResourcesError, UnstableTopologyError) as e:
            d = SchedulerDecision(
                now, "overloaded", self.k_current.copy(), None, k_max,
                et_cur, None, snap.sojourn_hat,
                reason=f"overloaded at {hot_names}; offered load infeasible "
                f"within k_max={k_max}: {e}",
            )
            self._emit(d)
            return d
        return self._apply(
            now, "overloaded", best, top, et_cur, snap,
            reason=f"measured rho >= 1 at {hot_names}; offered-load model "
            f"needs {need_total}, reallocated within k_max={k_max}",
        )

    def _apply(
        self,
        now: float,
        action: str,
        best: AllocationResult,
        top: Topology,
        et_cur: float,
        snap: MeasurementSnapshot,
        *,
        plan: RebalancePlan | None = None,
        reason: str = "",
    ) -> SchedulerDecision:
        self.k_current = best.k.copy()
        self.rebalance_count += 1
        d = SchedulerDecision(
            now, action, self.k_current.copy(), best.k, self._k_max(),
            et_cur, best.expected_sojourn, snap.sojourn_hat, plan, reason,
        )
        self._emit(d)
        return d

    def _emit(self, d: SchedulerDecision) -> None:
        self.history.append(d)
        logger.debug("DRS decision: %s", d.as_dict())
        if self.on_decision:
            self.on_decision(d)


class StragglerDetector:
    """Flags slow instances: per-instance mu more than ``factor`` below the
    operator median over the last window of pulls."""

    def __init__(self, factor: float = 2.0, window: int = 3):
        self.factor = factor
        self.window = window
        self._hist: dict[tuple[str, int], list[float]] = {}

    def observe(self, operator: str, instance: int, mu_hat: float) -> None:
        hist = self._hist.setdefault((operator, instance), [])
        hist.append(mu_hat)
        # Only the last `window` samples are ever read; trim so a control
        # loop ticking for months doesn't grow the history unboundedly.
        if len(hist) > self.window:
            del hist[: -self.window]

    def stragglers(self) -> list[tuple[str, int]]:
        by_op: dict[str, list[tuple[int, float]]] = {}
        for (op, inst), hist in self._hist.items():
            recent = [h for h in hist[-self.window :] if math.isfinite(h)]
            if recent:
                by_op.setdefault(op, []).append((inst, float(np.mean(recent))))
        out = []
        for op, pairs in by_op.items():
            if len(pairs) < 2:
                continue
            med = float(np.median([m for _, m in pairs]))
            for inst, m in pairs:
                if m * self.factor < med:
                    out.append((op, inst))
        return out
