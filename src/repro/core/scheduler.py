"""DRS scheduler — the control loop (paper §III-C step (a)-(c), §IV).

Each tick:
  1. pull a smoothed :class:`MeasurementSnapshot` from the measurer;
  2. rebuild the model Topology from (lam0_hat, lam_hat, mu_hat) — routing
     multiplicities are re-estimated from measured per-operator arrival
     ratios, so shifts in data properties (e.g. more SIFT features per
     frame) are tracked without re-declaring the graph;
  3. run Program (6) when a T_max is configured (how many processors do we
     need?) and Program (4) at the current K_max (where do they go?);
  4. decide: scale out (negotiator.ensure) when Program (6) needs more than
     leased; scale in when it needs sufficiently less (hysteresis); and/or
     rebalance the allocation when the cost/benefit plan says so;
  5. emit a :class:`SchedulerDecision` for the CSP layer to execute.

Since the controller extraction (DESIGN.md §14) this class is a thin
*stateful shell*: every step above is pure math living in
:mod:`repro.core.controller` — ``overloaded_mask_batch`` /
``capped_mask_batch`` (vectorized trigger + throughput-capped
propagation), ``clamp_row`` (offered-load model rebuild), and
``decide_single`` (the whole decision flow, bit-identical float64 twin of
the jit batch path).  The shell owns what cannot be batched: the
measurer, the negotiator lease (passed to the controller as the
``ensure`` hook), the cost model / executable cache, the straggler
watchdog, and the decision history.  One scheduler is exactly a B=1 lane
of the batched controller — which is what lets ``ScenarioRunner`` run
thousands of these loops as one fused program.

Straggler handling is paper-native: a straggler inside operator i drags the
measured mu_hat_i down; the model then predicts a T_max violation and the
loop reallocates — no special case needed.  A separate watchdog
(:class:`StragglerDetector`) additionally flags *which* instance is slow by
comparing per-instance service-time samples against the operator median.

Overload (DESIGN.md §11) is a defined path, not an accident: when the
measured utilisation rho_i = lam_hat_i / (k_i * mu_hat_i) reaches 1 for
any operator, the snapshot's downstream arrival rates are *throughput-
capped* (a saturated operator only emits at its service capacity, so
everything below it under-reports the true offered load).  The model is
then rebuilt from offered-load rates instead: source lam0 comes from the
queue-tail arrival probes (which count shed tuples too) and the declared
routing multiplicities are kept for every edge whose upstream measurement
is capped.  The decision action is ``"overloaded"``, which bypasses the
rebalance cost/benefit gate and the scale-in hysteresis and asks the
negotiator for capacity immediately.

Heterogeneous machine classes (paper §III-A): pass ``speed_factors`` —
per-operator speed of the machine class serving that operator, relative
to the class ``mu_hat`` is measured against — and the controller scales
the effective service rates ``mu_eff = mu_hat * speed`` throughout.
"""

from __future__ import annotations

import logging
import math
import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from . import controller as ctl
from .jackson import Topology
from .measurer import Measurer, MeasurementSnapshot
from .negotiator import Negotiator
from .rebalance import ExecutableCache, RebalanceCostModel, RebalancePlan

logger = logging.getLogger(__name__)

__all__ = ["SchedulerConfig", "SchedulerDecision", "DRSScheduler", "StragglerDetector"]


@dataclass(frozen=True)
class SchedulerConfig:
    t_max: float | None = None  # real-time constraint (seconds); None = Program 4 only
    k_max: int | None = None  # static budget; None = ask the negotiator
    horizon_seconds: float = 300.0  # cost/benefit planning horizon
    scale_in_hysteresis: float = 0.8  # scale in only if need < hysteresis * leased
    min_improvement: float = 0.05  # rebalance only if E[T] improves by >= 5%
    headroom: float = 1.1  # provision Program-6 result * headroom (model error guard)
    tick_interval: float = 10.0  # T_m: pull + decide period
    # Model-evaluation backend for Programs (4)/(6): "table" delegates to the
    # batched gain-table core (core/batched.py, DESIGN.md §12 — bit-identical
    # allocations, ~1000x less per-tick Python work at pod-scale K_max);
    # "heap" keeps the scalar heap greedy (PR-1 behaviour, used as a
    # cross-check in tests and benchmarks).
    allocator: str = "table"
    # Dispatch the jit decide's model chain to kernels/decide_fused as ONE
    # pass (Pallas on TPU; on CPU the fused oracle is bit-exact with the
    # two-pass erlang_c -> gain_topr path, which stays the parity oracle).
    # Default off until the parity gate has run on the target backend.
    fused_decide: bool = False


# Backwards-compatible alias: the solver pairs now live with the rest of
# the decision math in core/controller.py.
_ALLOCATORS = ctl.ALLOCATORS


@dataclass(frozen=True)
class SchedulerDecision:
    """What the CSP layer should do after a tick."""

    t: float
    # "none" | "rebalance" | "scale_out" | "scale_in" | "infeasible"
    # | "overloaded" (measured rho >= 1 somewhere: offered-load model,
    #   immediate negotiator scale-out, no hysteresis / cost-benefit gate)
    # | "rebalance_hint" (no model-driven change, but the StragglerDetector
    #   flagged slow instances — advisory: the CSP layer should consider
    #   replacing/rebalancing the named (operator, instance) pairs)
    # | "proactive" (forecast/MPC plane committed an allocation ahead of
    #   any trigger — DESIGN.md §15; only with a `proactive=` scheduler)
    action: str
    k_current: np.ndarray
    k_target: np.ndarray | None
    k_max: int
    model_sojourn_current: float
    model_sojourn_target: float | None
    measured_sojourn: float
    plan: RebalancePlan | None = None
    reason: str = ""
    # (operator, instance) pairs the straggler watchdog flagged this tick.
    stragglers: tuple = ()

    def as_dict(self) -> dict:
        return {
            "t": self.t,
            "action": self.action,
            "k_current": self.k_current.tolist(),
            "k_target": None if self.k_target is None else self.k_target.tolist(),
            "k_max": self.k_max,
            "model_sojourn_current": self.model_sojourn_current,
            "model_sojourn_target": self.model_sojourn_target,
            "measured_sojourn": self.measured_sojourn,
            "reason": self.reason,
            "stragglers": list(self.stragglers),
        }


class DRSScheduler:
    """The DRS optimizer + scheduler modules glued together (stateful
    shell over the pure controller — see module docstring)."""

    def __init__(
        self,
        operator_names: list[str],
        base_routing: np.ndarray,
        k_current: np.ndarray,
        config: SchedulerConfig,
        *,
        measurer: Measurer | None = None,
        negotiator: Negotiator | None = None,
        cost_model: RebalanceCostModel | None = None,
        executable_cache: ExecutableCache | None = None,
        scaling: list[str] | None = None,
        group_alpha: list[float] | None = None,
        speed_factors: list[float] | None = None,
        on_decision: Callable[[SchedulerDecision], None] | None = None,
        straggler_detector: "StragglerDetector | None" = None,
        proactive=None,
    ):
        self.names = list(operator_names)
        self.base_routing = np.asarray(base_routing, dtype=np.float64)
        self.k_current = np.asarray(k_current, dtype=np.int64).copy()
        self.config = config
        self.measurer = measurer or Measurer(self.names)
        self.negotiator = negotiator
        self.cost_model = cost_model or RebalanceCostModel()
        self.cache = executable_cache
        self.scaling = scaling or ["replica"] * len(self.names)
        self.group_alpha = group_alpha or [0.0] * len(self.names)
        self.speed_factors = (
            None if speed_factors is None
            else np.asarray(speed_factors, dtype=np.float64)
        )
        self.on_decision = on_decision
        self.straggler_detector = (
            StragglerDetector() if straggler_detector is None else straggler_detector
        )
        if config.allocator not in ctl.ALLOCATORS:
            raise ValueError(
                f"unknown allocator {config.allocator!r}; "
                f"expected one of {sorted(ctl.ALLOCATORS)}"
            )
        self._group = np.array([s == "group" for s in self.scaling], dtype=bool)
        self._alpha = np.asarray(self.group_alpha, dtype=np.float64)
        # Forecast/MPC plane (DESIGN.md §15): `proactive=True` enables the
        # default MPCConfig; an MPCConfig customizes it.  The live shell is
        # one B=1 lane of the batched proactive tick (no backlog probe on
        # the live measurement path, so the planner's rollout starts at 0).
        self._proactive = None
        if proactive is not None:
            from ..forecast.mpc import MPCConfig, ProactiveController

            cfg = MPCConfig() if proactive is True else proactive
            self._proactive = ProactiveController.create(
                1, len(self.names), cfg, span=config.tick_interval
            )
        self.history: list[SchedulerDecision] = []
        self.rebalance_count = 0

    # Kept as a class attribute for callers/tests that read the trigger
    # threshold off the scheduler; the value lives with the math now.
    DROP_TRIGGER_FRACTION = ctl.DROP_TRIGGER_FRACTION

    def _mu_eff(self, snap: MeasurementSnapshot) -> np.ndarray:
        if self.speed_factors is None:
            return snap.mu_hat
        return snap.mu_hat * self.speed_factors

    def overloaded_mask(self, snap: MeasurementSnapshot) -> np.ndarray:
        """Per-operator bool: measured offered load >= current capacity,
        OR sustained shedding at the operator's queue (the §11 trigger —
        vectorized in :func:`repro.core.controller.overloaded_mask_batch`)."""
        return ctl.overloaded_mask_batch(
            snap.lam_hat[None],
            self._mu_eff(snap)[None],
            snap.drop_rates()[None],
            self.k_current[None],
            self._group[None],
            self._alpha[None],
        )[0]

    def _capped_mask(self, overloaded: np.ndarray) -> np.ndarray:
        """Operators whose *measured arrival rate* is throughput-capped
        (transitively downstream of a saturated operator)."""
        return ctl.capped_mask_batch(overloaded[None], self.base_routing[None])[0]

    def topology_from(
        self, snap: MeasurementSnapshot, overloaded: np.ndarray | None = None
    ) -> Topology:
        """Rebuild the model from measurements (controller ``clamp_row``;
        see DESIGN.md §4/§11 for the offered-load clamping rules)."""
        n = len(self.names)
        if overloaded is None:
            overloaded = self.overloaded_mask(snap)
        capped = (
            self._capped_mask(overloaded)
            if overloaded.any()
            else np.zeros(n, dtype=bool)
        )
        return ctl.clamp_row(
            self.names,
            self.base_routing,
            snap.lam_hat,
            snap.mu_hat,
            snap.lam0_hat,
            overloaded,
            capped,
            self.scaling,
            self.group_alpha,
            speed=self.speed_factors,
        )

    # ------------------------------------------------------------------ #
    def tick(self, now: float | None = None) -> SchedulerDecision:
        now = time.time() if now is None else now
        snap = self.measurer.pull(now)
        self._observe_instances()
        return self.tick_from(snap, now)

    def tick_from(self, snap: MeasurementSnapshot, now: float) -> SchedulerDecision:
        """One tick on an externally-supplied snapshot (no measurer pull).

        This is the batched-snapshot hook: callers that measure outside
        the live probe path — the vectorized scenario sweep
        (``api.session.ScenarioRunner``) stacks whole windows into
        :class:`~repro.core.measurer.MeasurementBatch` rows — drive the
        identical model/decide path through the controller.
        """
        if not snap.complete():
            d = SchedulerDecision(
                now, "none", self.k_current.copy(), None,
                self._k_max(), float("nan"), None, snap.sojourn_hat,
                reason="insufficient measurements",
            )
            self._emit(d)
            return d
        overloaded = self.overloaded_mask(snap)
        if self._proactive is not None:
            d = self._tick_proactive(snap, now, overloaded)
            if d is not None:
                return d
        top = self.topology_from(snap, overloaded)
        return self.decide(top, snap, now, overloaded=overloaded)

    def _tick_proactive(
        self, snap: MeasurementSnapshot, now: float, overloaded: np.ndarray
    ) -> SchedulerDecision | None:
        """One proactive tick (DESIGN.md §15): advance the predictors on
        this (complete) snapshot, and commit the MPC plan when the
        confidence gate is open, the §11 trigger is quiet, and some
        candidate meets T_max.  Returns ``None`` to fall back to the
        reactive decide (which also handles the gate-closed case)."""
        from ..forecast.mpc import forecast_step, mpc_plan

        pc = self._proactive
        n = len(self.names)
        active = np.ones((1, n), dtype=bool)
        pc.state, lam_pred, conf = forecast_step(
            pc.state, np.asarray(snap.lam_hat, dtype=np.float64)[None],
            active, pc.cfg,
        )
        pc.confident = conf.copy()
        pc.mpc_used = np.zeros(1, dtype=bool)
        if self.config.t_max is None or overloaded.any() or not conf[0]:
            return None
        in_deg = self.base_routing.sum(axis=0)
        src = in_deg == 0
        if not src.any():
            src[0] = True
        speed = (
            np.ones(n) if self.speed_factors is None else self.speed_factors
        )
        k_max = self._k_max()
        plan_kw = dict(
            mu=np.asarray(snap.mu_hat, dtype=np.float64)[None],
            group=self._group[None], alpha=self._alpha[None],
            speed=np.asarray(speed, dtype=np.float64)[None], active=active,
            src_mask=src[None], cap_queue=pc.cap_queue,
            t_max=np.array([float(self.config.t_max)]), span=pc.span,
            cfg=pc.cfg,
        )
        q0 = np.zeros((1, n))
        k_cur = self.k_current[None]
        k_hi = int(max(k_max, self.k_current.max(), 1))
        k_plan, any_ok, et_hold, et_plan, need = mpc_plan(
            lam_pred, q0, k_cur, k_max=np.array([k_max]), k_hi=k_hi, **plan_kw
        )
        pc.need = np.asarray(need).copy()
        if self.negotiator is not None:
            tgt = int(need[0])
            if tgt > k_max or tgt < pc.cfg.scale_in_hysteresis * k_max:
                self.negotiator.ensure(max(tgt, 1))
                new_k_max = self._k_max()
                if new_k_max != k_max:
                    k_max = new_k_max
                    k_hi = int(max(k_max, self.k_current.max(), 1))
                    k_plan, any_ok, et_hold, et_plan, need = mpc_plan(
                        lam_pred, q0, k_cur, k_max=np.array([k_max]),
                        k_hi=k_hi, **plan_kw
                    )
                    pc.need = np.asarray(need).copy()
        if not any_ok[0]:
            return None  # no candidate meets T_max: reactive fallback
        pc.mpc_used = np.ones(1, dtype=bool)
        k_new = np.asarray(k_plan[0], dtype=np.int64)
        changed = bool((k_new != self.k_current).any())
        if changed:
            self.k_current = k_new.copy()
            self.rebalance_count += 1
        d = SchedulerDecision(
            now,
            "proactive" if changed else "none",
            self.k_current.copy(),
            k_new,
            k_max,
            float(et_hold[0]),
            float(et_plan[0]),
            snap.sojourn_hat,
            reason=(
                "MPC plan committed ahead of trigger" if changed
                else "proactive hold"
            ),
        )
        self._emit(d)
        return d

    def _k_max(self) -> int:
        if self.config.k_max is not None:
            return self.config.k_max
        if self.negotiator is not None:
            return self.negotiator.k_max
        return int(self.k_current.sum())

    # --- Straggler watchdog -------------------------------------------- #
    def _observe_instances(self) -> None:
        """Feed the per-instance service rates the measurer's last pull
        recorded into the straggler watchdog (instance identity = probe
        index within the operator)."""
        if self.straggler_detector is None:
            return
        for op, rates in (getattr(self.measurer, "last_instance_mu", None) or {}).items():
            for idx, mu in enumerate(rates):
                if math.isfinite(mu):
                    self.straggler_detector.observe(op, idx, mu)

    def straggler_hints(self) -> tuple:
        """(operator, instance) pairs currently flagged by the watchdog."""
        if self.straggler_detector is None:
            return ()
        return tuple(self.straggler_detector.stragglers())

    def decide(
        self,
        top: Topology,
        snap: MeasurementSnapshot,
        now: float,
        overloaded: np.ndarray | None = None,
    ) -> SchedulerDecision:
        """One decision on an already-built model: delegates the whole
        flow to the controller's float64 twin (``decide_single``) and
        applies the outcome to the shell state.

        tick() passes the mask it already clamped the topology with, so
        detection and clamping cannot disagree; direct callers get it
        computed here.
        """
        cfg = self.config
        stragglers = self.straggler_hints()
        if overloaded is None:
            overloaded = self.overloaded_mask(snap)

        ensure = None
        if self.negotiator is not None:
            negotiator = self.negotiator

            def ensure(target: int) -> int:
                negotiator.ensure(target)
                return negotiator.k_max

        row = ctl.decide_single(
            top,
            self.k_current,
            self._k_max(),
            t_max=cfg.t_max,
            headroom=cfg.headroom,
            scale_in_hysteresis=cfg.scale_in_hysteresis,
            min_improvement=cfg.min_improvement,
            horizon_seconds=cfg.horizon_seconds,
            allocator=cfg.allocator,
            overloaded=overloaded,
            ensure=ensure,
            cost_model=self.cost_model,
            cache=self.cache,
            stage_names=self.names,
            stragglers=stragglers,
            names=self.names,
        )
        if row.applied:
            self.k_current = row.k_next.copy()
            self.rebalance_count += 1
        d = SchedulerDecision(
            now,
            row.action,
            self.k_current.copy(),
            row.k_target,
            self._k_max() if row.applied else row.k_max,
            row.et_cur,
            row.et_target,
            snap.sojourn_hat,
            row.plan,
            row.reason,
            stragglers if row.action in ("none", "rebalance_hint") else (),
        )
        self._emit(d)
        return d

    def _emit(self, d: SchedulerDecision) -> None:
        self.history.append(d)
        logger.debug("DRS decision: %s", d.as_dict())
        if self.on_decision:
            self.on_decision(d)


class StragglerDetector:
    """Flags slow instances: per-instance mu more than ``factor`` below the
    operator median over the last window of pulls."""

    def __init__(self, factor: float = 2.0, window: int = 3):
        self.factor = factor
        self.window = window
        self._hist: dict[tuple[str, int], list[float]] = {}

    def observe(self, operator: str, instance: int, mu_hat: float) -> None:
        hist = self._hist.setdefault((operator, instance), [])
        hist.append(mu_hat)
        # Only the last `window` samples are ever read; trim so a control
        # loop ticking for months doesn't grow the history unboundedly.
        if len(hist) > self.window:
            del hist[: -self.window]

    def stragglers(self) -> list[tuple[str, int]]:
        by_op: dict[str, list[tuple[int, float]]] = {}
        for (op, inst), hist in self._hist.items():
            recent = [h for h in hist[-self.window :] if math.isfinite(h)]
            if recent:
                by_op.setdefault(op, []).append((inst, float(np.mean(recent))))
        out = []
        for op, pairs in by_op.items():
            if len(pairs) < 2:
                continue
            med = float(np.median([m for _, m in pairs]))
            for inst, m in pairs:
                if m * self.factor < med:
                    out.append((op, inst))
        return out
