"""Optimizers (pure-JAX, pytree-based): AdamW and SGD-momentum.

AdamW moments default to f32 but can be held in bf16 (``moment_dtype``):
for kimi-k2's 1T params, f32 moments alone are 8 TB — bf16 moments bring
the optimizer+param footprint to ~6 TB (11.7 GB/chip on 512 chips), the
difference between fitting and not fitting on v5e (DESIGN.md §8 / the
dry-run memory analysis).  Error from bf16 moments is bounded by stochastic
rounding-free EMA noise; acceptable for the dry-run scale story and
configurable back to f32.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update", "global_norm", "clip_by_global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray  # [] int32
    mu: Any  # first moment (tree)
    nu: Any  # second moment (tree)


def adamw_init(params: Any, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, dtype=cfg.moment_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def lr_schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw_update(
    params: Any, grads: Any, state: OptState, cfg: AdamWConfig
) -> tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}
