"""Training substrate: optimizer, train step, loop, elastic restart."""

from .optimizer import AdamWConfig, OptState, adamw_init, adamw_update
from .train_step import TrainState, init_train_state, make_train_step

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update",
    "TrainState", "init_train_state", "make_train_step",
]
