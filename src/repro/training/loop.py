"""Training loop with fault tolerance and DRS-scheduled input pipeline.

Features (exercised by tests/test_training_loop.py and examples/):

* resume-from-checkpoint: params + optimizer + data-iterator state restore
  atomically; a killed run resumes bit-exact on the synthetic stream;
* async checkpointing every ``ckpt_every`` steps (no loop stall);
* step watchdog: a step exceeding ``step_timeout`` x median records a
  straggler event (on real pods this triggers the DRS mu-drop path);
* elastic: ``ElasticController.on_lease_change`` rebuilds the mesh-size-
  dependent pieces and restarts from the latest checkpoint — pod loss is
  a restart, not a failure (DESIGN.md §8);
* the host data pipeline is a DRS topology: the loop feeds measured
  consumption/production rates to a DRSScheduler that rescales loader
  worker pools.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable

import jax
import numpy as np

from ..checkpoint.store import CheckpointStore
from ..data.pipeline import DataConfig, PipelinedLoader, SyntheticTokens
from ..models.common import ModelConfig
from .optimizer import AdamWConfig
from .train_step import TrainState, init_train_state, make_train_step

__all__ = ["LoopConfig", "TrainLoop", "StragglerEvent"]


@dataclass(frozen=True)
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_keep: int = 3
    log_every: int = 10
    step_timeout_factor: float = 5.0  # x median step time -> straggler event
    seed: int = 0


@dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class TrainLoop:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        loop_cfg: LoopConfig,
        *,
        ckpt_dir: str | Path,
        data_cfg: DataConfig | None = None,
        on_metrics: Callable[[int, dict], None] | None = None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.loop_cfg = loop_cfg
        self.store = CheckpointStore(ckpt_dir)
        self.data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, batch=2, seq_len=16, seed=loop_cfg.seed
        )
        self.on_metrics = on_metrics
        self.step_times: list[float] = []
        self.straggler_events: list[StragglerEvent] = []
        self.metrics_history: list[dict] = []

    # ------------------------------------------------------------------ #
    def _init_or_restore(self) -> tuple[TrainState, SyntheticTokens]:
        state, _axes = init_train_state(
            self.cfg, self.opt_cfg, jax.random.PRNGKey(self.loop_cfg.seed)
        )
        source = SyntheticTokens(self.data_cfg)
        latest = self.store.latest_step()
        if latest is not None:
            state, extra = self.store.restore(state, latest)
            source.restore(extra["data"])
        return state, source

    def run(self, *, steps: int | None = None, crash_at: int | None = None) -> TrainState:
        """Run (or resume) training.  ``crash_at`` simulates a failure
        after that step's checkpoint-eligible point (for restart tests)."""
        lc = self.loop_cfg
        steps = steps if steps is not None else lc.total_steps
        state, source = self._init_or_restore()
        loader = PipelinedLoader(source, workers={"generate": 1, "transform": 1})
        step_fn = jax.jit(make_train_step(self.cfg, self.opt_cfg), donate_argnums=(0,))
        try:
            start = int(state.step)
            for step in range(start, steps):
                t0 = time.perf_counter()
                batch = next(loader)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(state.params)
                dt = time.perf_counter() - t0
                self.step_times.append(dt)
                med = float(np.median(self.step_times[-50:]))
                if len(self.step_times) > 5 and dt > lc.step_timeout_factor * med:
                    self.straggler_events.append(StragglerEvent(step, dt, med))
                m = {k: float(v) for k, v in metrics.items()}
                m["step_time"] = dt
                self.metrics_history.append(m)
                if self.on_metrics and (step % lc.log_every == 0):
                    self.on_metrics(step, m)
                done = step + 1
                if done % lc.ckpt_every == 0 or done == steps:
                    self.store.save_async(
                        done, state, extra={"data": {"step": source.step, "seed": self.data_cfg.seed}}
                    )
                if crash_at is not None and done >= crash_at:
                    self.store.wait()
                    raise RuntimeError(f"simulated crash at step {done}")
            self.store.wait()
            self.store.prune(lc.ckpt_keep)
            return state
        finally:
            loader.stop()


class ElasticController:
    """Reacts to lease changes: checkpoint -> rebuild -> resume.

    On real pods the mesh changes size and the train step re-lowers for
    the new topology; on CPU we exercise the control flow (restore onto a
    fresh TrainState, resume the data stream exactly) — the re-lowering
    path is covered by the dry-run's two mesh shapes.
    """

    def __init__(self, loop: TrainLoop):
        self.loop = loop
        self.restarts: list[dict] = []

    def on_lease_change(self, change) -> None:
        self.restarts.append(
            {"before": change.k_max_before, "after": change.k_max_after}
        )

    def resume(self, *, steps: int) -> TrainState:
        """Restart from the latest checkpoint after a topology change."""
        return self.loop.run(steps=steps)
