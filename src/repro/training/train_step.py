"""The jittable train step: loss -> grad -> clip -> AdamW -> new state.

This is the unit the multi-pod dry-run lowers for every ``train_4k`` cell
(params + optimizer state as ShapeDtypeStructs), and the unit train.py
executes for real on smoke configs.  Optional int8 gradient compression
with error feedback (distributed/compress.py) kicks in for the cross-pod
all-reduce when ``compress_grads`` is set — at 1000+ nodes the cross-pod
links are the scarce resource (DESIGN.md §8).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..distributed.compress import decompress_tree, compress_tree
from ..models.common import ModelConfig
from ..models.transformer import loss_fn
from .optimizer import AdamWConfig, OptState, adamw_init, adamw_update

__all__ = ["TrainState", "init_train_state", "make_train_step"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    step: jnp.ndarray  # [] int32 — global step (mirrors opt.step)


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key: jax.Array) -> tuple[TrainState, dict]:
    from ..models.transformer import init_params

    params, axes = init_params(cfg, key)
    opt = adamw_init(params, opt_cfg)
    return TrainState(params, opt, jnp.zeros((), jnp.int32)), axes


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    aux_weight: float = 0.01,
    compress_grads: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        def loss(p):
            total, metrics = loss_fn(p, cfg, batch, aux_weight=aux_weight)
            return total, metrics

        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(state.params)
        if compress_grads:
            # int8 quantise -> (implicit cross-pod all-reduce happens on the
            # int8 payload under GSPMD) -> dequantise.  Error feedback is
            # carried via straight-through residual re-add.
            comp = compress_tree(grads)
            grads = decompress_tree(comp)
        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
