"""Roofline-term extraction from compiled XLA artifacts.

``compiled.cost_analysis()`` provides per-device HLO FLOPs and bytes
accessed; collective traffic is NOT in cost_analysis, so we parse the
optimized (post-SPMD-partitioning) HLO text and sum the result-shape bytes
of every collective op.  All quantities are per device; the roofline terms
are then

    compute    = flops / PEAK_FLOPS          (s)
    memory     = bytes_accessed / HBM_BW     (s)
    collective = collective_bytes / ICI_BW   (s)

which equals the global formulation HLO_total / (chips * per_chip_rate).
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

from .mesh import HW

__all__ = ["CollectiveStats", "RooflineTerms", "collective_bytes", "roofline_terms"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  "bf16[16,2048,128]{2,1,0} all-gather(...)"  or tuple results
_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective in optimized HLO text."""
    st = CollectiveStats()
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + b
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
    return st


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # 6*N*D (dense) or 6*N_active*D (MoE), global
    useful_ratio: float  # model_flops / global HLO flops
    memory_analysis: dict = field(default_factory=dict)
    collectives: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return asdict(self)


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    coll: CollectiveStats,
    model_flops: float,
    memory_analysis: dict | None = None,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.total_bytes)
    compute_s = flops / HW.PEAK_FLOPS_BF16
    memory_s = byts / HW.HBM_BW
    collective_s = cb / HW.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    global_flops = flops * chips
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=cb,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=(model_flops / global_flops) if global_flops > 0 else 0.0,
        memory_analysis=memory_analysis or {},
        collectives={
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
        },
    )


def model_flops_for(cfg, shape_spec) -> float:
    """MODEL_FLOPS: 6*N*D for training; 2*N*D for a forward-only unit.

    N = active params (MoE-aware); D = tokens processed by the lowered unit
    (train: batch*seq; prefill: batch*seq; decode: batch*1).
    """
    n_active = cfg.active_params_count()
    if shape_spec.kind == "train":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 6.0 * n_active * tokens
    if shape_spec.kind == "prefill":
        tokens = shape_spec.global_batch * shape_spec.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape_spec.global_batch
