"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: do NOT import .dryrun here — it sets XLA_FLAGS at import time and
must only ever be imported as the program entry point.
"""

from .mesh import HW, make_local_mesh, make_production_mesh

__all__ = ["HW", "make_local_mesh", "make_production_mesh"]
