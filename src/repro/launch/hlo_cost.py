"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 61 layers reports one layer's FLOPs (verified in
tests/test_hlo_cost.py), which would wreck the roofline.  This module
re-derives per-device costs from the optimized HLO text with loop
multiplicity:

* computations are parsed into instruction lists;
* ``while`` ops multiply their body+condition cost by
  ``backend_config known_trip_count`` (1 if absent — conservative);
* FLOPs: ``dot`` (2 * prod(result) * prod(contracting)) and
  ``convolution``; elementwise flops are ignored (dots dominate LLM work);
* collective bytes: result-shape bytes by kind, loop-multiplied.

HBM traffic uses a **perfect-fusion window model** (the TPU-relevant
semantics — the CPU backend's unfused elementwise ops are NOT charged):

* ``dot`` / ``reduce`` / ``sort`` / ``custom-call`` / collectives: read
  operands fully + write the result;
* slice-like ops (``dynamic-slice``, ``gather``, ``slice``) touch only the
  WINDOW: 2 x output bytes — charging the full operand would bill a
  lax.scan's per-step xs slice for the whole stacked tensor every
  iteration (a 100x overcount, observed);
* ``dynamic-update-slice`` / ``scatter``: 2 x update bytes (read update,
  write window) — the buffer itself is donated/aliased;
* ``fusion``: root output + per-parameter reads, where a parameter whose
  only use inside the body is slice-like counts at its windows' size;
* pure layout/elementwise ops (copy, convert, transpose, broadcast, pad,
  concatenate, iota, ...) are fused into neighbours and charged nothing.

This is deliberately a *model* (like any roofline input): exact enough to
rank bottlenecks and to measure sharding/fusion changes cell-over-cell,
cheap enough to run on every dry-run compile.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*(?:\([^{]*\))?\s*->.*{\s*$")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+)$")
_OP_RE = re.compile(r"^((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:{[^}]*})?))\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"^([a-z0-9]+\[[0-9,]*\](?:{[^}]*})?)\s+parameter\((\d+)\)")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+\"?(\d+)')
_CALL_REFS = ("body=", "condition=", "calls=", "to_apply=")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
# Fusions whose body is ONLY dtype/layout glue exist because the CPU
# emitter materialises operands for its matmul library (f32 upconverts,
# transposed copies of bf16 KV caches were observed at 20x the physical
# cache size).  TPU's MXU consumes bf16 and transposed operands natively
# (dot dimension numbers), so such fusions are charged zero.
_GLUE_KINDS = frozenset(
    {"parameter", "convert", "transpose", "copy", "bitcast", "reshape",
     "broadcast", "tuple", "get-tuple-element", "constant", "iota"}
)
_FULL_READ_OPS = ("dot", "convolution", "reduce", "sort", "reduce-window",
                  "select-and-scatter", "custom-call", "cholesky", "triangular-solve",
                  "rng-bit-generator") + _COLLECTIVES
_WINDOW_READ_OPS = ("dynamic-slice", "gather", "slice")
_WINDOW_WRITE_OPS = ("dynamic-update-slice", "scatter")


def _shape_elems_bytes(type_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Instr:
    name: str
    kind: str
    type_text: str
    line: str
    operands: list[str] = field(default_factory=list)
    param_index: int = -1
    is_root: bool = False


@dataclass
class _Computation:
    name: str
    instrs: list[_Instr] = field(default_factory=list)
    types: dict[str, str] = field(default_factory=dict)
    params: dict[int, str] = field(default_factory=dict)  # index -> name
    root: str | None = None
    by_name: dict[str, "_Instr"] = field(default_factory=dict)


@dataclass
class HloCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)
    traffic_by_kind: dict = field(default_factory=dict)
    dot_count: int = 0
    while_count: int = 0

    def merge_scaled(self, other: "HloCost", k: float) -> None:
        self.flops += other.flops * k
        self.traffic_bytes += other.traffic_bytes * k
        self.collective_bytes += other.collective_bytes * k
        self.dot_count += int(other.dot_count * k)
        self.while_count += int(other.while_count * k)
        for d_src, d_dst in (
            (other.bytes_by_kind, self.bytes_by_kind),
            (other.count_by_kind, self.count_by_kind),
            (other.traffic_by_kind, self.traffic_by_kind),
        ):
            for kk, v in d_src.items():
                d_dst[kk] = d_dst.get(kk, 0) + v * k

    def _add_traffic(self, kind: str, b: float) -> None:
        self.traffic_bytes += b
        self.traffic_by_kind[kind] = self.traffic_by_kind.get(kind, 0) + b


def _parse_computations(text: str) -> tuple[dict[str, _Computation], str | None]:
    comps: dict[str, _Computation] = {}
    entry: str | None = None
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m and line.strip().endswith("{"):
                name = m.group(1)
                cur = _Computation(name=name)
                if line.strip().startswith("ENTRY"):
                    entry = name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        is_root, name, rest = bool(m.group(1)), m.group(2), m.group(3)
        if is_root:
            cur.root = name
        pm = _PARAM_RE.match(rest)
        if pm:
            cur.types[name] = pm.group(1)
            cur.params[int(pm.group(2))] = name
            ins = _Instr(name=name, kind="parameter", type_text=pm.group(1), line=rest,
                         param_index=int(pm.group(2)), is_root=is_root)
            cur.instrs.append(ins)
            cur.by_name[name] = ins
            continue
        om = _OP_RE.match(rest)
        if not om:
            continue
        type_text, kind = om.group(1), om.group(2)
        paren = rest[om.end() - 1 :]
        depth, end = 0, len(paren)
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(paren[:end])
        ins = _Instr(name=name, kind=kind, type_text=type_text, line=rest,
                     operands=operands, is_root=is_root)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
        cur.types[name] = type_text
    return comps, entry


def _dot_flops(ins: _Instr, types: dict[str, str]) -> float:
    out_elems = 0
    for dt, dims in _SHAPE_RE.findall(ins.type_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out_elems += n
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", ins.line)
    if not m or not ins.operands:
        return 2.0 * out_elems
    lhs_type = types.get(ins.operands[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    return 2.0 * out_elems * k


def _fusion_param_traffic(body: _Computation) -> dict[int, float]:
    """Per-parameter HBM read bytes for a fusion body.

    * consumed ONLY by slice-like ops -> charged the slices' output sizes
      (window reads);
    * consumed ONLY as operand 0 of dynamic-update-slice ops -> charged 0:
      it is the in-place buffer being updated (XLA aliases it; the write
      is charged via the fusion root, see _fusion_output_traffic);
    * anything else -> full size.
    """
    out: dict[int, float] = {}
    consumers: dict[str, list[_Instr]] = {}
    for ins in body.instrs:
        for op in ins.operands:
            consumers.setdefault(op, []).append(ins)
    for idx, pname in body.params.items():
        uses = consumers.get(pname, [])
        full = _shape_elems_bytes(body.types.get(pname, ""))
        if uses and all(u.kind in _WINDOW_READ_OPS for u in uses):
            out[idx] = float(sum(_shape_elems_bytes(u.type_text) for u in uses))
        elif uses and all(
            u.kind == "dynamic-update-slice" and u.operands and u.operands[0] == pname
            for u in uses
        ):
            out[idx] = 0.0
        else:
            out[idx] = float(full)
    return out


def _fusion_output_traffic(body: _Computation) -> float:
    """HBM write bytes of a fusion: DUS-rooted fusions (the lax.scan
    'stash ys' pattern) write only the update WINDOW, not the whole
    stacked buffer they thread through."""

    def resolve(name: str, depth: int = 0) -> float:
        if depth > 8:
            return 0.0
        ins = body.by_name.get(name)
        if ins is None:
            return float(_shape_elems_bytes(body.types.get(name, "")))
        if ins.kind in ("bitcast", "copy", "reshape", "transpose", "convert") and ins.operands:
            return resolve(ins.operands[0], depth + 1)
        if ins.kind == "tuple":
            return float(sum(resolve(op, depth + 1) for op in ins.operands))
        if ins.kind == "dynamic-update-slice" and len(ins.operands) >= 2:
            return float(_shape_elems_bytes(body.types.get(ins.operands[1], "")))
        return float(_shape_elems_bytes(ins.type_text))

    if body.root is None:
        return 0.0
    return resolve(body.root)


def _comp_cost(
    comp: _Computation,
    comps: dict[str, _Computation],
    memo: dict[str, HloCost],
    stack: frozenset,
) -> HloCost:
    if comp.name in memo:
        return memo[comp.name]
    cost = HloCost()
    for ins in comp.instrs:
        refs = []
        for key in _CALL_REFS:
            for m in re.finditer(re.escape(key) + r"(%[\w\.\-]+)", ins.line):
                refs.append(m.group(1))
        trip = 1.0
        if ins.kind == "while":
            tm = _TRIP_RE.search(ins.line)
            trip = float(tm.group(1)) if tm else 1.0
            cost.while_count += 1

        if ins.kind == "fusion":
            # flops/collectives inside the body still count; traffic is
            # handled by the parameter-window model below (a body's
            # internal values never touch HBM).
            body = comps.get(refs[0]) if refs else None
            if body is not None and all(i.kind in _GLUE_KINDS for i in body.instrs):
                cost._add_traffic("glue", 0.0)
                continue
            if body is not None and refs[0] not in stack:
                sub = _comp_cost(body, comps, memo, stack | {comp.name})
                cost.flops += sub.flops
                cost.collective_bytes += sub.collective_bytes
                cost.dot_count += sub.dot_count
            if body is not None:
                b = _fusion_output_traffic(body)
                pt = _fusion_param_traffic(body)
                for i, op in enumerate(ins.operands):
                    b += pt.get(i, float(_shape_elems_bytes(comp.types.get(op, ""))))
            else:
                b = float(_shape_elems_bytes(ins.type_text))
                for op in ins.operands:
                    b += _shape_elems_bytes(comp.types.get(op, ""))
            cost._add_traffic("fusion", b)
            continue

        for ref in refs:
            sub = comps.get(ref)
            if sub is None or ref in stack:
                continue
            sub_cost = _comp_cost(sub, comps, memo, stack | {comp.name})
            cost.merge_scaled(sub_cost, trip)

        if ins.kind == "dot":
            cost.flops += _dot_flops(ins, comp.types)
            cost.dot_count += 1
        if ins.kind in _COLLECTIVES or any(
            ins.kind == c + "-start" for c in _COLLECTIVES
        ):
            kind = ins.kind.replace("-start", "")
            b = _shape_elems_bytes(ins.type_text)
            cost.collective_bytes += b
            cost.bytes_by_kind[kind] = cost.bytes_by_kind.get(kind, 0) + b
            cost.count_by_kind[kind] = cost.count_by_kind.get(kind, 0) + 1

        if ins.kind in _FULL_READ_OPS:
            b = _shape_elems_bytes(ins.type_text)
            for op in ins.operands:
                b += _shape_elems_bytes(comp.types.get(op, ""))
            cost._add_traffic(ins.kind, b)
        elif ins.kind in _WINDOW_READ_OPS:
            cost._add_traffic(ins.kind, 2.0 * _shape_elems_bytes(ins.type_text))
        elif ins.kind in _WINDOW_WRITE_OPS:
            upd_idx = 1 if ins.kind == "dynamic-update-slice" else 2
            if upd_idx < len(ins.operands):
                upd = _shape_elems_bytes(comp.types.get(ins.operands[upd_idx], ""))
            else:
                upd = _shape_elems_bytes(ins.type_text)
            cost._add_traffic(ins.kind, 2.0 * upd)
    memo[comp.name] = cost
    return cost


def analyze_hlo(text: str) -> HloCost:
    """Per-device trip-count-aware cost of an optimized HLO module."""
    comps, entry = _parse_computations(text)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].instrs)) if comps else None
    if entry is None:
        return HloCost()
    return _comp_cost(comps[entry], comps, {}, frozenset())
