"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device; only
launch/dryrun.py forces 512 host devices).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh with the production axis names (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n, 1), ("pod", "data", "model"))


class HW:
    """TPU v5e-class hardware constants for the roofline (per chip)."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_BW = 50e9  # B/s per link (roofline uses per-chip link bandwidth)
    HBM_BYTES = 16 * 1024**3  # 16 GiB
