import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import (jax locks device count on
first init): the dry-run — and only the dry-run — sees 512 placeholder
host devices so jax.make_mesh can build the production meshes.

For each cell this driver:
  1. builds params / optimizer / cache shapes with jax.eval_shape (no
     allocation — full kimi-k2 is 1T params);
  2. resolves shardings from the rule tables (distributed/sharding.py);
  3. jit(...).lower(...).compile() under the mesh;
  4. records memory_analysis(), cost_analysis(), and the collective bytes
     parsed from the optimized HLO into benchmarks/results/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..configs.shapes import SHAPES, cell_is_supported, input_specs, skip_reason
from ..distributed import sharding as shd
from ..models import serve
from ..models.common import axis_rules
from ..models.transformer import init_params
from ..training.optimizer import AdamWConfig, adamw_init
from ..training.train_step import TrainState, make_train_step
from .hlo_analysis import CollectiveStats, model_flops_for, roofline_terms
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _eval_shapes(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def _params_shapes(cfg):
    """(params ShapeDtypeStruct tree, axes tree) without allocating."""
    shapes, axes_holder = None, {}

    def build(key):
        p, a = init_params(cfg, key)
        axes_holder["axes"] = a
        return p

    shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    return shapes, axes_holder["axes"]


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    rules_overrides: dict | None = None,
    cfg_overrides: dict | None = None,
    opt_moment_dtype=None,
    tag: str = "",
) -> dict:
    """Lower + compile one cell; returns the result record (also saved)."""
    import dataclasses as _dc

    cfg = get_config(arch, "full")
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    record: dict = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "chips": chips,
        "tag": tag,
        "time": time.strftime("%Y-%m-%d %H:%M:%S"),
    }
    if not cell_is_supported(arch, shape):
        record["status"] = "skipped"
        record["reason"] = skip_reason(arch, shape)
        return record

    rules = shd.rules_for(spec.kind, rules_overrides, arch=arch)
    rules = shd.prune_rules(rules, mesh)  # single-pod meshes have no "pod" axis

    params_shapes, axes = _params_shapes(cfg)
    p_shardings = shd.tree_shardings(params_shapes, axes, mesh, rules)
    batch = input_specs(arch, shape)
    b_shardings = {
        k: jax.sharding.NamedSharding(mesh, shd.batch_spec(k, v.shape, rules, mesh))
        for k, v in batch.items()
    }

    t0 = time.perf_counter()
    try:
        if spec.kind == "train":
            opt_cfg = AdamWConfig(
                moment_dtype=opt_moment_dtype
                or (jnp.bfloat16 if arch == "kimi-k2-1t-a32b" else jnp.float32)
            )
            opt_shapes = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_shapes)
            mu_sh = shd.tree_shardings(opt_shapes.mu, axes, mesh, rules)
            nu_sh = shd.tree_shardings(opt_shapes.nu, axes, mesh, rules)
            scalar_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            state_shapes = TrainState(
                params_shapes,
                opt_shapes._replace(step=jax.ShapeDtypeStruct((), jnp.int32)),
                jax.ShapeDtypeStruct((), jnp.int32),
            )
            state_shardings = TrainState(
                p_shardings,
                type(opt_shapes)(step=scalar_sh, mu=mu_sh, nu=nu_sh),
                scalar_sh,
            )
            step_fn = make_train_step(cfg, opt_cfg)

            def wrapped(state, bt):
                with axis_rules(rules, mesh):
                    return step_fn(state, bt)

            jitted = jax.jit(
                wrapped,
                in_shardings=(state_shardings, b_shardings),
                out_shardings=(state_shardings, None),
                donate_argnums=(0,),
            )
            with mesh:
                lowered = jitted.lower(state_shapes, batch)
        elif spec.kind == "prefill":
            cache_shapes = jax.eval_shape(
                lambda: serve.init_cache(cfg, spec.global_batch, spec.seq_len)
            )
            c_shardings = shd.cache_shardings(cache_shapes, cfg.family, mesh, rules)

            def wrapped(p, bt, c):
                with axis_rules(rules, mesh):
                    return serve.prefill(p, cfg, bt, c)

            jitted = jax.jit(
                wrapped,
                in_shardings=(p_shardings, b_shardings, c_shardings),
                out_shardings=(None, c_shardings),
                donate_argnums=(2,),
            )
            with mesh:
                lowered = jitted.lower(params_shapes, batch, cache_shapes)
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: serve.init_cache(cfg, spec.global_batch, spec.seq_len)
            )
            c_shardings = shd.cache_shardings(cache_shapes, cfg.family, mesh, rules)

            def wrapped(p, t, c):
                with axis_rules(rules, mesh):
                    return serve.decode_step(p, cfg, t, c)

            jitted = jax.jit(
                wrapped,
                in_shardings=(p_shardings, b_shardings["tokens"], c_shardings),
                out_shardings=(None, c_shardings),
                donate_argnums=(2,),
            )
            with mesh:
                lowered = jitted.lower(params_shapes, batch["tokens"], cache_shapes)

        record["lower_s"] = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        record["compile_s"] = time.perf_counter() - t1
        cost = _cost_dict(compiled)
        mem = _memory_dict(compiled)
        hlo = compiled.as_text()
        # Trip-count-aware HLO cost model: the builtin cost_analysis counts
        # each scanned layer ONCE (tests/test_hlo_cost.py proves it), which
        # would understate every term by ~n_layers.
        hc = analyze_hlo(hlo)
        coll = CollectiveStats(
            bytes_by_kind=dict(hc.bytes_by_kind), count_by_kind=dict(hc.count_by_kind)
        )
        terms = roofline_terms(
            arch=arch,
            shape=shape,
            mesh_name=mesh_name,
            chips=chips,
            cost={"flops": hc.flops, "bytes accessed": hc.traffic_bytes},
            coll=coll,
            model_flops=model_flops_for(cfg, spec),
            memory_analysis=mem,
        )
        record["status"] = "ok"
        record["cost_analysis_builtin"] = cost  # once-counted; reference only
        record["memory_analysis"] = mem
        record["roofline"] = terms.as_dict()
        record["hlo_bytes"] = len(hlo)
        record["hlo_model"] = {
            "flops": hc.flops,
            "traffic_bytes": hc.traffic_bytes,
            "collective_bytes": hc.collective_bytes,
            "dot_count": hc.dot_count,
            "while_count": hc.while_count,
            "traffic_by_kind": {k: float(v) for k, v in sorted(
                hc.traffic_by_kind.items(), key=lambda kv: -kv[1])},
        }
    except Exception as e:  # noqa: BLE001 — record and move on
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    return record


def save_record(record: dict, out_dir: Path = RESULTS_DIR) -> Path:
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"-{record['tag']}" if record.get("tag") else ""
    name = f"{record['arch']}--{record['shape']}--{record['mesh']}{tag}.json"
    path = out_dir / name
    path.write_text(json.dumps(record, indent=2, default=str))
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every remaining cell")
    ap.add_argument("--force", action="store_true", help="re-run cached cells")
    ap.add_argument("--tag", default="", help="variant tag (perf experiments)")
    ap.add_argument("--set", action="append", default=[], metavar="KEY=VALUE",
                    help="ModelConfig override, e.g. --set attn_impl=chunked")
    args = ap.parse_args()

    cfg_overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if k == "dtype":
            v = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[v]
        else:
            try:
                v = int(v)
            except ValueError:
                pass
        cfg_overrides[k] = v

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape, args.multi_pod))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape, args.multi_pod))

    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = f"-{args.tag}" if args.tag else ""
        path = RESULTS_DIR / f"{arch}--{shape}--{mesh_name}{tag}.json"
        if path.exists() and not args.force:
            prev = json.loads(path.read_text())
            if prev.get("status") in ("ok", "skipped"):
                print(f"[cached] {arch} x {shape} x {mesh_name}: {prev['status']}")
                continue
        print(f"[run] {arch} x {shape} x {mesh_name} ...", flush=True)
        rec = run_cell(
            arch, shape, multi_pod=mp, tag=args.tag, cfg_overrides=cfg_overrides or None
        )
        p = save_record(rec)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (
                f" dominant={r['dominant']} compute={r['compute_s']:.4f}s "
                f"memory={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s"
            )
        elif status == "error":
            extra = f" {rec['error'][:200]}"
        print(f"[done] {arch} x {shape} x {mesh_name}: {status}{extra} -> {p}")


if __name__ == "__main__":
    main()
