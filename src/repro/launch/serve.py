"""Serving driver: DRS-scheduled prefill/decode split (simulated time).

Takes stage service rates from the dry-run roofline records when present
(the model-based mu prior, DESIGN.md §2), runs the DES-backed router under
the DRS allocation, and prints latency vs the queueing-model prediction.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --rate 4.0 --chips 24 --mean-tokens 64
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from ..configs import ARCHS
from ..serving.pipeline import ServingModel, StageRates, rates_from_dryrun
from ..serving.router import ServingSimulation

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="llama3.2-1b")
    ap.add_argument("--rate", type=float, default=4.0, help="requests/sec")
    ap.add_argument("--chips", type=int, default=24)
    ap.add_argument("--t-max", type=float, default=None,
                    help="latency SLO (s): Program (6) sizing instead of fixed chips")
    ap.add_argument("--mean-tokens", type=float, default=64.0)
    ap.add_argument("--horizon", type=float, default=900.0)
    args = ap.parse_args()

    try:
        rates = rates_from_dryrun(args.arch, RESULTS)
        src = "dry-run roofline"
    except (FileNotFoundError, KeyError):
        rates = StageRates(prefill_per_chip=0.5, decode_per_chip=40.0)
        src = "defaults (no dry-run records found)"
    print(f"stage rates from {src}: prefill {rates.prefill_per_chip:.3f} req/s/chip, "
          f"decode {rates.decode_per_chip:.1f} tok/s/chip")

    model = ServingModel(rates, mean_output_tokens=args.mean_tokens)
    alloc = model.plan(args.rate, k_max=args.chips, t_max=args.t_max)
    split = model.split(alloc)
    print(f"DRS allocation (Program {'6' if args.t_max else '4'}): {split} "
          f"-> model E[T] = {alloc.expected_sojourn:.3f}s")

    sim = ServingSimulation(model, args.rate, horizon=args.horizon, warmup=args.horizon / 10)
    rep = sim.run(split)
    print(json.dumps(rep.as_dict(), indent=2))


if __name__ == "__main__":
    main()
