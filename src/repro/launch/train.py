"""Training driver.

Smoke-scale runs execute for real on CPU; full configs are dry-run-only
(use launch/dryrun.py for those).  Demonstrates the full fault-tolerance
loop: checkpoint/resume, straggler logging, DRS-scheduled data pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --preset smoke --steps 200 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json

from ..configs import ARCHS, get_config
from ..data.pipeline import DataConfig
from ..training.loop import LoopConfig, TrainLoop
from ..training.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="llama3.2-1b")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a failure after this step (restart demo)")
    args = ap.parse_args()

    if args.preset == "full":
        raise SystemExit(
            "full configs are dry-run-only on CPU; use "
            "`python -m repro.launch.dryrun --arch ... --shape train_4k`"
        )
    cfg = get_config(args.arch, "smoke")
    loop = TrainLoop(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=10, decay_steps=args.steps),
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every, log_every=10),
        ckpt_dir=args.ckpt,
        data_cfg=DataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq_len),
        on_metrics=lambda step, m: print(
            f"step {step:5d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
            f"gnorm {m['grad_norm']:.2f} {m['step_time']*1e3:.0f} ms"
        ),
    )
    try:
        loop.run(crash_at=args.crash_at)
    except RuntimeError as e:
        print(f"!! {e} — run again to resume from the latest checkpoint")
        raise SystemExit(1) from None
    print(json.dumps({
        "final_loss": loop.metrics_history[-1]["loss"],
        "steps": len(loop.metrics_history),
        "stragglers": len(loop.straggler_events),
        "checkpoints": loop.store.latest_step(),
    }, indent=2))


if __name__ == "__main__":
    main()
