"""Scenario matrix — declarative, seed-deterministic workload scenarios
(DESIGN.md §13).

A :class:`Scenario` pins everything one simulated experiment needs —
AppGraph x arrival trace x service distribution x
:class:`~repro.streaming.overload.OverloadPolicy` x allocator choice x
seed — and compiles to either backend:

* :meth:`Scenario.simulator` -> the event DES (``NetworkSimulator``,
  high fidelity, scalar);
* :func:`pack_scenarios` -> :class:`~repro.streaming.batchsim.BatchArrays`
  for the vectorized batch simulator (hundreds of scenarios per second).

Two generator zoos make the matrix: **arrival traces** (:class:`ArrivalTrace`
— constant, diurnal sinusoid, flash-crowd step, 2-state MMPP, trace
replay) and the **random-topology zoo** (:func:`random_appgraph` — valid
``AppGraph``s with chains, splits, joins, and stability-respecting leaking
loops).  Everything is deterministic given the seed: the same
``Scenario`` produces bit-identical pre-sampled arrival arrays and DES
runs across processes, which is what lets the test suite enforce
DES-vs-model agreement as a regression surface and commit golden decision
traces (tests/golden/).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Mapping, Sequence

import numpy as np

from ..api.graph import AppGraph, Edge, OpDef
from .batchsim import BatchArrays
from .overload import OverloadPolicy

__all__ = [
    "ArrivalTrace",
    "Scenario",
    "random_appgraph",
    "scenario_matrix",
    "pack_scenarios",
    "pack_allocations",
    "control_trace",
    "vld_scenario",
    "fpd_scenario",
]


# --------------------------------------------------------------------------- #
# Arrival-trace zoo
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ArrivalTrace:
    """A deterministic rate schedule lambda_0(t) for one source operator.

    Kinds:

    * ``constant`` — ``rate`` throughout;
    * ``diurnal``  — sinusoid ``rate + amplitude * sin(2 pi t / period)``
      (clamped at 0), the day/night load curve;
    * ``flash``    — ``rate``, stepping to ``peak`` on ``[t_on, t_off)``
      (the Fig. 9/10 flash crowd);
    * ``mmpp``     — 2-state Markov-modulated rate: ``rate`` in state 0,
      ``peak`` in state 1, exponential switching at ``switch01`` /
      ``switch10`` per second.  The state path is sampled once from the
      scenario seed, so the *trace itself* is deterministic;
    * ``replay``   — an explicit measured-rate array ``samples`` covering
      the horizon at ``sample_dt`` spacing (held piecewise-constant,
      clipped at the ends).
    """

    kind: str = "constant"
    rate: float = 10.0
    peak: float | None = None
    amplitude: float = 0.0
    period: float = 60.0
    t_on: float = 0.0
    t_off: float = 0.0
    switch01: float = 0.1
    switch10: float = 0.1
    samples: tuple = ()
    sample_dt: float = 1.0

    _KINDS = ("constant", "diurnal", "flash", "mmpp", "replay")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown trace kind {self.kind!r}; expected {self._KINDS}")
        if self.rate < 0:
            raise ValueError(f"trace rate must be >= 0, got {self.rate}")
        if self.kind in ("flash", "mmpp") and self.peak is None:
            raise ValueError(f"trace kind {self.kind!r} needs peak=")
        if self.kind == "replay" and not self.samples:
            raise ValueError("replay trace needs samples=")

    def rates(self, t_grid: np.ndarray, seed: int = 0) -> np.ndarray:
        """lambda_0 at each grid time — [T] float64, deterministic given
        (trace, seed)."""
        t = np.asarray(t_grid, dtype=np.float64)
        if self.kind == "constant":
            return np.full(t.shape, self.rate)
        if self.kind == "diurnal":
            return np.maximum(
                self.rate + self.amplitude * np.sin(2.0 * math.pi * t / self.period), 0.0
            )
        if self.kind == "flash":
            return np.where((t >= self.t_on) & (t < self.t_off), self.peak, self.rate)
        if self.kind == "replay":
            idx = np.clip((t / self.sample_dt).astype(np.int64), 0, len(self.samples) - 1)
            return np.asarray(self.samples, dtype=np.float64)[idx]
        # mmpp: sample the modulating state path once, from its own stream.
        rng = np.random.default_rng(np.random.SeedSequence([seed, 0x3A7E]))
        rates = np.empty(t.shape)
        state, t_next, now = 0, 0.0, float(t[0]) if t.size else 0.0
        sw = (self.switch01, self.switch10)
        t_next = now + (rng.exponential(1.0 / sw[0]) if sw[0] > 0 else math.inf)
        for i, ti in enumerate(t):
            while ti >= t_next:
                state = 1 - state
                s = sw[state]
                t_next += rng.exponential(1.0 / s) if s > 0 else math.inf
            rates[i] = self.rate if state == 0 else self.peak
        return rates

    def mean_rate(self, horizon: float, seed: int = 0, dt: float = 0.5) -> float:
        """Time-averaged rate over [0, horizon] (model-side lam0): the
        trapezoid integral of :meth:`rates` on a ``dt`` grid divided by
        the covered span — the contract the forecast predictors train
        against (tests/test_scenarios.py locks the <= 1e-9 agreement)."""
        span = max(horizon, dt)
        grid = np.arange(0.0, span + dt / 2.0, dt)
        r = self.rates(grid, seed)
        integral = 0.5 * (r[1:] + r[:-1]).sum() * dt
        return float(integral / (grid[-1] - grid[0]))

    def des_schedule(self, horizon: float, seed: int = 0, dt: float = 1.0):
        """(initial ArrivalProcess kwargs, [(t, rate), ...] mid-run changes)
        — how the event DES reproduces this trace.  ``flash`` and ``mmpp``
        map onto the DES's native ``burst``/``mmpp`` processes only when
        exact (single cycle / matching switch rates); every kind also has
        the generic piecewise-constant fallback used here: the rate grid
        at ``dt`` spacing becomes ``schedule_arrival_change`` calls."""
        if self.kind == "constant":
            return {"rate": self.rate}, []
        grid = np.arange(0.0, horizon + dt, dt)
        rates = self.rates(grid, seed)
        changes = []
        last = rates[0]
        for t, r in zip(grid[1:], rates[1:]):
            if r != last:
                changes.append((float(t), float(r)))
                last = r
        return {"rate": float(rates[0])}, changes


# --------------------------------------------------------------------------- #
# Random-topology zoo
# --------------------------------------------------------------------------- #
def random_appgraph(
    seed: int,
    *,
    n_ops: tuple[int, int] = (3, 7),
    p_split: float = 0.35,
    p_join: float = 0.35,
    p_loop: float = 0.3,
    target_rho: tuple[float, float] = (0.3, 0.8),
    lam0: float = 10.0,
    n_sources: int = 1,
) -> AppGraph:
    """A valid random :class:`AppGraph` with splits, joins, and leaking loops.

    Construction: a random topological spine guarantees every operator is
    reachable from a source; extra forward edges create joins (several
    in-edges) and splits (several out-edges, multiplicities summing to
    ~1); self-loops and back-edges are added with multiplicity small
    enough to keep the routing spectral radius below 0.9 (stability is
    then asserted by ``AppGraph`` itself at construction).  Service rates
    are set from the *solved* per-operator arrival rates so utilisation
    at a handful of processors lands inside ``target_rho`` — the zoo
    yields feasible Programs (4)/(6) by construction, not by rejection.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x70B0]))
    n = int(rng.integers(n_ops[0], n_ops[1] + 1))
    names = [f"op{i}" for i in range(n)]
    routing = np.zeros((n, n))
    n_src = min(n_sources, n)
    # Spine: op i (i >= n_src) receives from a random earlier operator.
    for j in range(n_src, n):
        i = int(rng.integers(0, j))
        routing[i, j] = 1.0
    # Splits: give a random earlier op a second forward edge and split its
    # outflow (multiplicities ~ sum to the original mass, or > 1 fan-out).
    for i in range(n - 1):
        if rng.random() < p_split:
            choices = [j for j in range(i + 1, n) if routing[i, j] == 0.0]
            if choices:
                j = int(rng.choice(choices))
                routing[i, j] = float(rng.uniform(0.2, 1.2))
    # Joins arise from splits/spine overlap; force one more in-edge
    # sometimes so multi-in-degree joins are common.
    for j in range(n_src + 1, n):
        if rng.random() < p_join:
            choices = [i for i in range(j) if routing[i, j] == 0.0]
            if choices:
                i = int(rng.choice(choices))
                routing[i, j] = float(rng.uniform(0.2, 0.9))
    # Loops: a self-loop or back-edge that leaks (kept well under radius 1).
    # Every cycle goes through this one edge (spine/splits/joins are all
    # forward), so damping just it shrinks every cycle's gain while forward
    # fan-out keeps its mass.
    if rng.random() < p_loop:
        i = int(rng.integers(0, n))
        if rng.random() < 0.5 or i == 0:
            li, lj = i, i
            routing[i, i] = float(rng.uniform(0.1, 0.5))
        else:
            li, lj = i, int(rng.integers(0, i))
            routing[li, lj] = float(rng.uniform(0.1, 0.4))
        for _ in range(60):
            radius = float(max(abs(np.linalg.eigvals(routing))))
            if radius < 0.9:
                break
            routing[li, lj] *= 0.7
    lam0_vec = np.zeros(n)
    for s in range(n_src):
        lam0_vec[s] = lam0 / n_src
    # Solve traffic on the final routing, then pick mu so that a small
    # processor count sits inside target_rho.
    lam = np.linalg.solve(np.eye(n) - routing.T, lam0_vec)
    lam = np.maximum(lam, 0.0)
    mus = np.empty(n)
    for i in range(n):
        rho = float(rng.uniform(*target_rho))
        k_nom = int(rng.integers(1, 5))
        mus[i] = max(lam[i] / (rho * k_nom), 1e-3) if lam[i] > 0 else float(rng.uniform(1.0, 10.0))
    ops = [OpDef(name=names[i], mu=float(mus[i])) for i in range(n)]
    edges = [
        Edge(names[i], names[j], multiplicity=float(routing[i, j]))
        for i in range(n)
        for j in range(n)
        if routing[i, j] > 0.0
    ]
    sources = {names[s]: float(lam0_vec[s]) for s in range(n_src) if lam0_vec[s] > 0}
    return AppGraph(ops, edges, sources)


# --------------------------------------------------------------------------- #
# Scenario spec
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Scenario:
    """One fully-pinned experiment: everything both simulators need.

    ``traces`` maps source-operator names to :class:`ArrivalTrace`s
    (sources without a trace run constant at the graph's declared rate).
    ``arrival_kind`` picks the *micro* inter-arrival law around the trace
    rate (``exponential``/``uniform`` sample Poisson step counts in the
    batch sim; ``deterministic`` uses exact fluid mass).  ``k0`` is the
    starting allocation (None = plan Program (4)/(6) on the declared
    priors).  ``allocator`` selects the scheduler's Program solver
    ("table" | "heap") when the scenario runs under control.
    """

    name: str
    graph: AppGraph
    traces: Mapping[str, ArrivalTrace] = field(default_factory=dict)
    arrival_kind: str = "exponential"
    service_kind: str = "exponential"
    overload_policy: OverloadPolicy | str = "shed-newest"
    allocator: str = "table"
    seed: int = 0
    horizon: float = 120.0
    warmup: float = 10.0
    dt: float = 0.05
    queue_capacity: int | None = None
    k_max: int = 64
    t_max: float | None = None
    k0: Mapping[str, int] | None = None
    # Elastic mode: lease machines of ``machine_size`` processors from a
    # pool of ``k_max`` total through a Negotiator instead of holding a
    # static budget — the controller then scales out/in (paper Fig. 10).
    negotiated: bool = False
    machine_size: int = 4
    # Heterogeneous machine classes (paper §III-A): per-operator speed
    # factor of the machine class serving that operator (1.0 = reference).
    # Scales the simulator's service capacity, the model's effective mu
    # (core/controller.py), and — for ``negotiated`` scenarios — tags the
    # leased machines' ``speed``.
    speed_factors: Mapping[str, float] | None = None

    _ARRIVAL_KINDS = ("exponential", "uniform", "deterministic")
    _SERVICE_KINDS = ("exponential", "uniform", "deterministic", "lognormal")
    _ALLOCATORS = ("table", "heap")
    # Squared coefficients of variation of the micro inter-arrival /
    # service laws (DESIGN.md §17): exponential cv^2 = 1, uniform on
    # [0, 2m] = 1/3, deterministic = 0, lognormal = cv^2 (the DES's
    # ServiceProcess default cv is 1.0).  These feed the batch
    # simulator's Allen-Cunneen stationary-wait term.
    _ARRIVAL_SCV = {"exponential": 1.0, "uniform": 1.0 / 3.0, "deterministic": 0.0}
    _SERVICE_SCV = {
        "exponential": 1.0, "uniform": 1.0 / 3.0, "deterministic": 0.0,
        "lognormal": 1.0,
    }

    def __post_init__(self):
        OverloadPolicy.coerce(self.overload_policy)  # validate early
        unknown = set(self.traces) - set(self.graph.names)
        if unknown:
            raise ValueError(f"traces for unknown operators: {sorted(unknown)}")
        if self.speed_factors is not None:
            unknown = set(self.speed_factors) - set(self.graph.names)
            if unknown:
                raise ValueError(
                    f"speed_factors for unknown operators: {sorted(unknown)}"
                )
            bad = {k: v for k, v in self.speed_factors.items() if not v > 0}
            if bad:
                raise ValueError(f"speed_factors must be > 0, got {bad}")
        if self.arrival_kind not in self._ARRIVAL_KINDS:
            raise ValueError(
                f"unknown arrival_kind {self.arrival_kind!r}; expected one of "
                f"{self._ARRIVAL_KINDS} (rate modulation goes in traces=)"
            )
        if self.service_kind not in self._SERVICE_KINDS:
            raise ValueError(
                f"unknown service_kind {self.service_kind!r}; expected one of "
                f"{self._SERVICE_KINDS}"
            )
        if self.allocator not in self._ALLOCATORS:
            raise ValueError(
                f"unknown allocator {self.allocator!r}; expected one of "
                f"{self._ALLOCATORS}"
            )
        if self.dt <= 0 or self.horizon <= 0 or not 0 <= self.warmup < self.horizon:
            raise ValueError(
                f"need dt > 0, horizon > 0, 0 <= warmup < horizon; got "
                f"dt={self.dt}, horizon={self.horizon}, warmup={self.warmup}"
            )

    @property
    def policy(self) -> OverloadPolicy:
        return OverloadPolicy.coerce(self.overload_policy)

    @property
    def arrival_scv(self) -> float:
        """cv^2 of the micro inter-arrival law (§17 ``ca2`` input)."""
        return self._ARRIVAL_SCV[self.arrival_kind]

    @property
    def service_scv(self) -> float:
        """cv^2 of the service law (§17 ``cs2`` input)."""
        return self._SERVICE_SCV[self.service_kind]

    @property
    def steps(self) -> int:
        return int(round(self.horizon / self.dt))

    def with_(self, **kw) -> "Scenario":
        return replace(self, **kw)

    # -- trace compilation ------------------------------------------------ #
    def rate_grid(self) -> np.ndarray:
        """[T, N] external arrival rate per step for every operator."""
        t_grid = (np.arange(self.steps) + 0.5) * self.dt
        rates = np.zeros((self.steps, self.graph.n))
        lam0 = self.graph.lam0_vector()
        for i, name in enumerate(self.graph.names):
            trace = self.traces.get(name)
            if trace is not None:
                rates[:, i] = trace.rates(t_grid, self.seed)
            elif lam0[i] > 0:
                rates[:, i] = lam0[i]
        return rates

    def sample_arrivals(self) -> np.ndarray:
        """[T, N] pre-sampled external arrival *counts* per step — Poisson
        around the trace rate for stochastic arrival kinds, exact fluid
        mass for ``deterministic``.  Seed-deterministic."""
        rates = self.rate_grid()
        if self.arrival_kind == "deterministic":
            return rates * self.dt
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, 0xA881]))
        return rng.poisson(rates * self.dt).astype(np.float64)

    def speed_vector(self) -> np.ndarray | None:
        """[N] machine-class speed factors in graph order (None when the
        scenario is homogeneous)."""
        if self.speed_factors is None:
            return None
        return np.array(
            [float(self.speed_factors.get(n, 1.0)) for n in self.graph.names]
        )

    def mean_topology(self):
        """Model Topology at the traces' time-averaged rates (the "true"
        model a controller should converge to), with machine-class speed
        factors applied to the per-processor service rates."""
        sources = {}
        lam0 = self.graph.lam0_vector()
        for i, name in enumerate(self.graph.names):
            trace = self.traces.get(name)
            if trace is not None:
                sources[name] = trace.mean_rate(self.horizon, self.seed)
            elif lam0[i] > 0:
                sources[name] = float(lam0[i])
        g = self.graph.with_sources(sources)
        if self.speed_factors is None:
            return g.topology()
        return g.topology(
            {op.name: op.mu * float(self.speed_factors.get(op.name, 1.0))
             for op in g.ops}
        )

    # -- DES compilation -------------------------------------------------- #
    def simulator(self, k, *, measurer=None, seed: int | None = None):
        """The event-DES twin of this scenario (same topology, same rate
        schedule, same overload policy; its own exact-process randomness).

        ``seed`` overrides the DES *process* randomness only — the trace
        realization (mmpp state path etc.) stays pinned to the scenario
        seed, so conformance checks can average several independent DES
        runs of the same schedule (DESIGN.md §17)."""
        from ..api.session import _group_effective_services
        from .des import ArrivalProcess, NetworkSimulator, ServiceProcess, SimConfig

        # Machine-class speed factors scale the DES per-processor rates,
        # matching the batch sim's capacity rule and the controller model.
        if self.speed_factors is None:
            top = self.graph.topology()
        else:
            top = self.graph.topology(
                {op.name: op.mu * float(self.speed_factors.get(op.name, 1.0))
                 for op in self.graph.ops}
            )
        k_vec = self.graph.k_vector(k)
        arrivals = []
        changes: list[tuple[float, int, float]] = []
        lam0 = self.graph.lam0_vector()
        for i, name in enumerate(self.graph.names):
            trace = self.traces.get(name)
            if trace is None:
                arrivals.append(
                    ArrivalProcess(rate=float(lam0[i]), kind=self.arrival_kind)
                )
                continue
            kw, sched = trace.des_schedule(self.horizon, self.seed)
            arrivals.append(ArrivalProcess(rate=kw["rate"], kind=self.arrival_kind))
            changes.extend((t, i, r) for t, r in sched)
        # Chip-gang operators collapse to one effective server (DESIGN.md §2),
        # mirroring both the DES backend and the batch sim's capacity rule.
        services, k_eff = _group_effective_services(top, k_vec)
        services = [
            ServiceProcess(rate=svc.rate, kind=self.service_kind)
            for svc in services
        ]
        sim = NetworkSimulator(
            top,
            k_eff,
            config=SimConfig(
                seed=self.seed if seed is None else int(seed),
                horizon=self.horizon,
                warmup=self.warmup,
                queue_capacity=self.queue_capacity,
                overload_policy=self.overload_policy,
            ),
            arrivals=arrivals,
            services=services,
            measurer=measurer,
        )
        for t, i, r in changes:
            sim.schedule_arrival_change(t, i, r)
        return sim

    def plan_k0(self) -> np.ndarray:
        """Starting allocation: declared ``k0`` or Program (4)/(6) on priors."""
        from ..core.allocator import allocate

        if self.k0 is not None:
            return self.graph.k_vector(self.k0)
        res = allocate(self.mean_topology(), k_max=self.k_max, t_max=self.t_max)
        return res.k


# --------------------------------------------------------------------------- #
# Packing: scenarios -> BatchArrays
# --------------------------------------------------------------------------- #
def pack_scenarios(
    scenarios: Sequence[Scenario], *, pad_to: int | None = None
) -> BatchArrays:
    """Pack B scenarios (shared dt/horizon/warmup) into one batch.

    Scenarios with fewer operators than the batch maximum are padded with
    inactive zero-traffic lanes (mu = 1, no routing) that never see mass.

    ``pad_to`` additionally pads the *batch* axis to that extent with
    fully inert scenario lanes (``active`` all-False, zero arrivals) —
    the device-mesh case where B must be a multiple of the device count
    (DESIGN.md §16).  Masked lanes provably decide ``"none"`` in both
    the numpy twin and the jit decide (tests/test_mesh_control.py
    asserts this bit-for-bit); mixed-width stacks no longer assume the
    packed B is exact.
    """
    if not scenarios:
        raise ValueError("need at least one scenario")
    dts = {s.dt for s in scenarios}
    horizons = {s.horizon for s in scenarios}
    warmups = {s.warmup for s in scenarios}
    if len(dts) > 1 or len(horizons) > 1 or len(warmups) > 1:
        raise ValueError(
            "batch scenarios must share dt/horizon/warmup; got "
            f"dt={sorted(dts)}, horizon={sorted(horizons)}, warmup={sorted(warmups)}"
        )
    b = len(scenarios)
    n = max(s.graph.n for s in scenarios)
    steps = scenarios[0].steps
    dt = scenarios[0].dt
    ext = np.zeros((steps, b, n))
    routing = np.zeros((b, n, n))
    mu = np.ones((b, n))
    group = np.zeros((b, n), dtype=bool)
    alpha = np.zeros((b, n))
    cap_queue = np.full((b, n), np.inf)
    active = np.zeros((b, n), dtype=bool)
    speed = np.ones((b, n))
    ca2 = np.ones((b, n))
    cs2 = np.ones((b, n))
    heterogeneous = False
    for bi, s in enumerate(scenarios):
        ni = s.graph.n
        ext[:, bi, :ni] = s.sample_arrivals()
        routing[bi, :ni, :ni] = s.graph.routing_matrix()
        ca2[bi, :ni] = s.arrival_scv
        cs2[bi, :ni] = s.service_scv
        for i, op in enumerate(s.graph.ops):
            mu[bi, i] = op.mu
            group[bi, i] = op.scaling == "group"
            alpha[bi, i] = op.group_alpha
        active[bi, :ni] = True
        if s.queue_capacity is not None and s.policy.sheds:
            cap_queue[bi, :ni] = float(s.queue_capacity)
        sv = s.speed_vector()
        if sv is not None:
            speed[bi, :ni] = sv
            heterogeneous = True
    arrays = BatchArrays(
        ext=ext,
        routing=routing,
        mu=mu,
        group=group,
        alpha=alpha,
        cap_queue=cap_queue,
        dt=dt,
        warmup_steps=int(round(scenarios[0].warmup / dt)),
        active=active,
        speed=speed if heterogeneous else None,
        ca2=ca2,
        cs2=cs2,
    )
    if pad_to is not None:
        arrays = arrays.pad_batch(int(pad_to))
    return arrays


def pack_allocations(scenarios: Sequence[Scenario], ks) -> np.ndarray:
    """[B, N_max] allocation matrix from per-scenario k vectors/dicts
    (padding lanes get 0 processors)."""
    n = max(s.graph.n for s in scenarios)
    out = np.zeros((len(scenarios), n), dtype=np.int64)
    for bi, (s, k) in enumerate(zip(scenarios, ks)):
        out[bi, : s.graph.n] = s.graph.k_vector(k)
    return out


# --------------------------------------------------------------------------- #
# Canonical scenarios + the matrix generator
# --------------------------------------------------------------------------- #
def vld_scenario(**kw) -> Scenario:
    """The paper's VLD chain (extract -> match -> aggregate) as a model-only
    scenario: same shape and service-rate priors as
    ``streaming.apps.vld.build_vld_graph``, no compute fns."""
    graph = AppGraph(
        [OpDef("extract", mu=2.0), OpDef("match", mu=5.0), OpDef("aggregate", mu=50.0)],
        [Edge("extract", "match"), Edge("match", "aggregate")],
        {"extract": 13.0},
        arrival_kind="uniform",
    )
    defaults = dict(
        name="vld",
        graph=graph,
        traces={"extract": ArrivalTrace(kind="flash", rate=10.0, peak=20.0,
                                        t_on=60.0, t_off=90.0)},
        arrival_kind="uniform",  # the paper's uniform fps (graph + DES twin)
        seed=7,
        horizon=150.0,
        warmup=10.0,
        k_max=48,
        t_max=2.5,
        negotiated=True,
    )
    defaults.update(kw)
    return Scenario(**defaults)


def fpd_scenario(**kw) -> Scenario:
    """The paper's FPD graph (generate -> detect[self-loop] -> report) as a
    model-only scenario mirroring ``streaming.apps.fpd.build_fpd_graph``."""
    loop_p = 0.3
    graph = AppGraph(
        [OpDef("generate", mu=4.0), OpDef("detect", mu=3.0), OpDef("report", mu=12.0)],
        [
            Edge("generate", "detect"),
            Edge("detect", "detect", multiplicity=loop_p),
            Edge("detect", "report", multiplicity=1.0 - loop_p),
        ],
        {"generate": 16.0},
    )
    defaults = dict(
        name="fpd",
        graph=graph,
        traces={"generate": ArrivalTrace(kind="diurnal", rate=14.0, amplitude=8.0,
                                         period=80.0)},
        seed=11,
        horizon=160.0,
        warmup=10.0,
        k_max=64,
        t_max=3.0,
        negotiated=True,
    )
    defaults.update(kw)
    return Scenario(**defaults)


def control_trace(
    scenarios: Sequence[Scenario],
    *,
    tick_interval: float = 10.0,
    proactive=None,
    backend: str = "numpy",
    interpret: bool = False,
    fused_decide: bool = False,
    compact=None,
) -> dict:
    """JSON-able decision trace of the full control loop over ``scenarios``
    (the golden-trace surface, DESIGN.md §13).

    Runs the scenarios through :class:`~repro.api.session.ScenarioRunner`
    on the numpy float64 twin — fully deterministic given the scenario
    seeds — and records, per scenario, the scheduler's action sequence,
    the allocation in force after every tick, and the per-tick trajectory
    (provisioned k, miss mask — the reactive-vs-proactive lead-time
    surface).  ``proactive`` (True or an
    :class:`~repro.forecast.mpc.MPCConfig`) switches on the forecast/MPC
    plane, which is just as deterministic — the proactive golden fixture
    proves predictor + planner replayability.  Regenerate the committed
    fixtures with ``PYTHONPATH=src python tests/golden/regen.py``.

    ``backend="jax"`` replays the same trace through the fused jit loop
    under enable_x64 (bit-identical to the twin for non-negotiated
    scenarios); ``fused_decide`` flips the one-pass
    ``kernels/decide_fused`` dispatch inside it, and ``interpret`` runs
    any Pallas dispatch in interpret mode — together the golden replay
    surface for the fused-decide knob (tests/test_golden_traces.py).
    ``compact`` (True or a :class:`~repro.core.controller.CompactionConfig`)
    turns on the trigger-gated sparse decide (DESIGN.md §18); compaction
    is output-invisible, so every golden must replay bit-identically with
    it on — that replay is part of the compaction test surface.
    """
    from ..api.session import ScenarioRunner

    def _run():
        runner = ScenarioRunner(
            scenarios, tick_interval=tick_interval, backend=backend,
            proactive=proactive, interpret=interpret,
            fused_decide=fused_decide, compact=compact,
        )
        return runner.run()

    if backend == "numpy":
        reports = _run()
    else:
        import jax

        with jax.experimental.enable_x64():
            reports = _run()

    def _traj(tr):
        if tr is None:
            return None
        out = {
            "t": [round(float(t), 9) for t in tr["t"]],
            "k_total": list(tr["k_total"]),
            "miss": [int(m) for m in tr["miss"]],
            "warm": [int(w) for w in tr["warm"]],
        }
        if "mpc_used" in tr:
            out["mpc_used"] = [int(u) for u in tr["mpc_used"]]
            out["confident"] = [int(c) for c in tr["confident"]]
        return out

    return {
        "tick_interval": tick_interval,
        "proactive": proactive is not None,
        "scenarios": {
            r.name: {
                "actions": list(r.actions),
                "allocations": [dict(a) for a in r.allocations],
                "provisioned_total": r.provisioned_total,
                "optimal_total": r.optimal_total,
                "drop_rate": round(r.drop_rate, 9),
                "mean_sojourn": round(r.mean_sojourn, 9),
                "deadline_miss_rate": round(r.deadline_miss_rate, 9),
                "trajectory": _traj(r.trajectory),
            }
            for r in reports
        },
    }


def scenario_matrix(
    n_scenarios: int,
    *,
    seed: int = 0,
    horizon: float = 60.0,
    warmup: float = 5.0,
    dt: float = 0.05,
    k_max: int = 48,
) -> list[Scenario]:
    """A seeded sweep over (random topology x trace kind x overload policy
    x allocator) — the CI matrix.  Deterministic: scenario ``i`` of seed
    ``s`` is always the same spec."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5CE0]))
    policies = ("shed-newest", "shed-oldest", "block")
    out = []
    for i in range(n_scenarios):
        g_seed = int(rng.integers(0, 1 << 30))
        graph = random_appgraph(g_seed, lam0=float(rng.uniform(5.0, 20.0)))
        src = graph.source_names[0]
        base = float(graph.lam0_vector().sum())
        kind = ("constant", "diurnal", "flash", "mmpp")[i % 4]
        if kind == "constant":
            trace = ArrivalTrace(kind="constant", rate=base)
        elif kind == "diurnal":
            trace = ArrivalTrace(kind="diurnal", rate=base, amplitude=0.5 * base,
                                 period=float(rng.uniform(0.4 * horizon, horizon)))
        elif kind == "flash":
            trace = ArrivalTrace(kind="flash", rate=base, peak=2.0 * base,
                                 t_on=horizon * 0.4, t_off=horizon * 0.6)
        else:
            trace = ArrivalTrace(kind="mmpp", rate=0.7 * base, peak=1.8 * base,
                                 switch01=0.05, switch10=0.1)
        # Coprime cycle lengths (4 for kind, 3 for policy, 5 and 7 below)
        # so the axes decorrelate: every (kind x policy x bound x allocator
        # x t_max x negotiated) combination appears once the matrix is a
        # few dozen scenarios deep — no axis is a function of another.
        bounded = i % 5 < 2
        allocator = "heap" if i % 7 < 3 else "table"
        negotiated = i % 7 >= 5
        # ~3/5 of the matrix gets a real-time constraint (Program 6 active):
        # 1.5x the best E[T] reachable within the budget, so it is feasible
        # at the mean rate but stressed at the peaks.
        t_max = None
        if i % 5 < 3:
            from ..core.allocator import InsufficientResourcesError, allocate
            from ..core.jackson import UnstableTopologyError

            try:
                sources = {src: trace.mean_rate(horizon, g_seed ^ 0x1234)}
                top = graph.with_sources(sources).topology()
                t_max = 1.5 * allocate(top, k_max=k_max).expected_sojourn
            except (InsufficientResourcesError, UnstableTopologyError):
                t_max = None
        out.append(
            Scenario(
                name=f"m{seed}-{i:03d}-{kind}",
                graph=graph,
                traces={src: trace},
                seed=g_seed ^ 0x1234,
                horizon=horizon,
                warmup=warmup,
                dt=dt,
                overload_policy=policies[i % 3],
                allocator=allocator,
                queue_capacity=int(rng.integers(50, 400)) if bounded else None,
                k_max=k_max,
                t_max=t_max,
                negotiated=negotiated,
            )
        )
    return out
