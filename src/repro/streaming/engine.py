"""Live micro-batch streaming engine (the CSP layer, paper §IV).

A small but real operator runtime: each operator instance is a worker
thread pulling tuples from the operator's shared input queue, applying the
operator's (usually jitted-JAX) compute, and emitting derived tuples
downstream.  Parallelism per operator == number of instances == ``k_i``;
the DRS scheduler rescales an operator by starting/stopping instances —
the engine implements the paper's cheap rebalance (no global suspension:
only the resized operator's workers are swapped, and jitted executables
are cached so a re-scale never recompiles).

Completion tracking mirrors Storm's acker: every external tuple carries a
root id with an outstanding-count; when the count drains to zero the
measurer is notified with the complete sojourn time (paper's definition of
"fully processed").

Queues are *bounded* and overload is a first-class scenario (DESIGN.md
§11): when a queue is full the configured
:class:`~repro.streaming.overload.OverloadPolicy` decides whether the
producer blocks (backpressure propagates to :meth:`StreamEngine.inject`)
or a tuple is shed.  Shed tuples are counted per operator and reported to
the measurer; a root whose tree lost any tuple counts as *shed*, not
completed, so measured sojourn only reflects fully-processed tuples.

This engine is used by the end-to-end tests and examples; the DES
(des.py) is used for statistically tight model validation.
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable


from ..core.measurer import Measurer
from .overload import OverloadPolicy

__all__ = ["StreamTuple", "Operator", "StreamEngine"]


@dataclass
class _RootState:
    t_arrival: float
    outstanding: int = 0
    shed: bool = False  # any tuple of this root's tree was dropped
    lock: threading.Lock = field(default_factory=threading.Lock)


@dataclass
class StreamTuple:
    payload: Any
    root_id: int
    t_emit: float


class Operator:
    """A named operator: fn(payload) -> list of (downstream_name, payload).

    ``fn`` runs inside worker threads; JAX-jitted callables are safe (the
    GIL is released during XLA execution).  ``fn`` may return [] (sink).
    """

    def __init__(self, name: str, fn: Callable[[Any], list[tuple[str, Any]]]):
        self.name = name
        self.fn = fn


class StreamEngine:
    """Topology runtime with per-operator worker pools."""

    def __init__(
        self,
        operators: list[Operator],
        *,
        measurer: Measurer | None = None,
        queue_capacity: int | None = 10_000,
        overload_policy: OverloadPolicy | str = "block",
    ):
        self.operators = {op.name: op for op in operators}
        self.names = [op.name for op in operators]
        self.measurer = measurer or Measurer(self.names)
        if queue_capacity is not None and queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1 or None (unbounded), got "
                f"{queue_capacity}"
            )
        self.queue_capacity = queue_capacity
        self.overload_policy = OverloadPolicy.coerce(overload_policy)
        maxsize = 0 if queue_capacity is None else queue_capacity
        self.queues: dict[str, queue.Queue] = {
            n: queue.Queue(maxsize=maxsize) for n in self.names
        }
        self._workers: dict[str, list[threading.Thread]] = {n: [] for n in self.names}
        self._worker_stop: dict[str, list[threading.Event]] = {n: [] for n in self.names}
        # Dedicated arrival probes (queue-tail measurement position, paper
        # Appendix C) — independent of worker lifecycle.
        self._arrival_probes = {n: self.measurer.new_probe(n) for n in self.names}
        self._roots: dict[int, _RootState] = {}
        self._roots_lock = threading.Lock()
        self._root_ids = itertools.count()
        self._stop = threading.Event()
        self.completed_sojourns: list[float] = []
        self._completed_lock = threading.Lock()
        # Cumulative per-operator shed counts (probes drain-reset on every
        # measurer pull, so the engine keeps its own running totals too).
        self._drops: dict[str, int] = {n: 0 for n in self.names}
        self._drops_lock = threading.Lock()
        self.shed_roots = 0  # external tuples whose tree lost >= 1 tuple

    # ------------------------------------------------------------------ #
    def k(self) -> dict[str, int]:
        return {n: len(self._workers[n]) for n in self.names}

    def drop_counts(self) -> dict[str, int]:
        """Cumulative tuples shed per operator since engine construction."""
        with self._drops_lock:
            return dict(self._drops)

    def scale_to(self, allocation: dict[str, int]) -> None:
        """Rescale operators to the given instance counts (cheap rebalance:
        only affected operators change; queues and other operators keep
        flowing)."""
        for name, target in allocation.items():
            cur = len(self._workers[name])
            if target > cur:
                for _ in range(target - cur):
                    self._start_worker(name)
            elif target < cur:
                for _ in range(cur - target):
                    ev = self._worker_stop[name].pop()
                    ev.set()  # worker exits after its current tuple
                    self._workers[name].pop()

    def _start_worker(self, name: str) -> None:
        ev = threading.Event()
        probe = self.measurer.new_probe(name)
        t = threading.Thread(
            target=self._worker_loop, args=(name, ev, probe), daemon=True
        )
        self._worker_stop[name].append(ev)
        self._workers[name].append(t)
        t.start()

    # ------------------------------------------------------------------ #
    def inject(
        self, source: str, payload: Any, *, timeout: float | None = None
    ) -> int | None:
        """External tuple enters the system (spout emission).

        Under the ``block`` policy this call backpressures: it waits for
        queue space (up to ``timeout`` seconds; ``None`` = indefinitely).
        Returns the root id, or ``None`` when the tuple was shed at
        admission (shed policies, timeout expiry, or engine stop) — a shed
        external tuple is *not* counted as an external arrival, but is
        recorded in the source operator's drop counter.
        """
        root_id = next(self._root_ids)
        st = _RootState(t_arrival=time.perf_counter(), outstanding=1)
        with self._roots_lock:
            self._roots[root_id] = st
        deadline = None if timeout is None else time.perf_counter() + timeout
        tup = StreamTuple(payload, root_id, time.perf_counter())
        if not self._enqueue(source, tup, deadline=deadline):
            return None
        self.measurer.on_external_arrival()
        return root_id

    def _enqueue(
        self, name: str, tup: StreamTuple, *, deadline: float | None = None
    ) -> bool:
        """Offer a tuple to an operator queue under the overload policy.

        Counts the offered load at the queue tail (Appendix C) whether or
        not the tuple is admitted; returns False when it was shed.
        """
        self._arrival_probes[name].on_enqueue()
        q = self.queues[name]
        try:
            q.put_nowait(tup)
            return True
        except queue.Full:
            pass
        kind = self.overload_policy.kind
        if kind == "shed-newest":
            self._shed(name, tup)
            return False
        if kind == "shed-oldest":
            while True:
                try:
                    q.put_nowait(tup)
                    return True
                except queue.Full:
                    try:
                        evicted = q.get_nowait()
                    except queue.Empty:  # a worker beat us to the head
                        continue
                    self._shed(name, evicted)
        # block: wait for space, polling so engine stop / deadline unblocks.
        poll = self.overload_policy.block_poll
        while not self._stop.is_set():
            if deadline is not None and time.perf_counter() >= deadline:
                break
            try:
                q.put(tup, timeout=poll)
                return True
            except queue.Full:
                continue
        self._shed(name, tup)
        return False

    def _shed(self, name: str, tup: StreamTuple) -> None:
        """Drop a tuple at operator ``name``: count it and poison its root."""
        self._arrival_probes[name].on_dropped()
        with self._drops_lock:
            self._drops[name] += 1
        with self._roots_lock:
            root = self._roots.get(tup.root_id)
        if root is not None:
            with root.lock:
                root.shed = True
        self._complete_one(tup.root_id)

    def _worker_loop(self, name: str, stop: threading.Event, probe) -> None:
        op = self.operators[name]
        q = self.queues[name]
        while not stop.is_set() and not self._stop.is_set():
            try:
                tup = q.get(timeout=0.05)
            except queue.Empty:
                continue
            t0 = time.perf_counter()
            try:
                emissions = op.fn(tup.payload) or []
            except Exception:  # pragma: no cover - defensive: drop poison tuples
                emissions = []
            service = time.perf_counter() - t0
            probe.on_processed(service)
            with self._roots_lock:  # _complete_one mutates the dict under it
                root = self._roots.get(tup.root_id)
            if root is not None:
                with root.lock:
                    root.outstanding += len(emissions)
            for dst, payload in emissions:
                self._enqueue(dst, StreamTuple(payload, tup.root_id, time.perf_counter()))
            self._complete_one(tup.root_id)

    def _complete_one(self, root_id: int) -> None:
        with self._roots_lock:
            root = self._roots.get(root_id)
        if root is None:
            return
        with root.lock:
            root.outstanding -= 1
            done = root.outstanding == 0
            shed = root.shed
        if done:
            with self._roots_lock:
                self._roots.pop(root_id, None)
            if shed:
                # Partially-processed tree: its sojourn would be biased low
                # (the shed branches never ran) — count it separately.
                with self._completed_lock:
                    self.shed_roots += 1
                return
            sojourn = time.perf_counter() - root.t_arrival
            self.measurer.on_tuple_complete(sojourn)
            with self._completed_lock:
                self.completed_sojourns.append(sojourn)

    # ------------------------------------------------------------------ #
    def start(self, allocation: dict[str, int]) -> None:
        self.scale_to(allocation)

    def drain(self, timeout: float = 10.0) -> bool:
        """Wait for all in-flight roots to complete."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._roots_lock:
                if not self._roots:
                    return True
            time.sleep(0.01)
        return False

    def stop(self) -> None:
        self._stop.set()
        for workers in self._workers.values():
            for t in workers:
                t.join(timeout=1.0)
