"""Live micro-batch streaming engine (the CSP layer, paper §IV).

A small but real operator runtime: each operator instance is a worker
thread pulling tuples from the operator's shared input queue, applying the
operator's (usually jitted-JAX) compute, and emitting derived tuples
downstream.  Parallelism per operator == number of instances == ``k_i``;
the DRS scheduler rescales an operator by starting/stopping instances —
the engine implements the paper's cheap rebalance (no global suspension:
only the resized operator's workers are swapped, and jitted executables
are cached so a re-scale never recompiles).

Completion tracking mirrors Storm's acker: every external tuple carries a
root id with an outstanding-count; when the count drains to zero the
measurer is notified with the complete sojourn time (paper's definition of
"fully processed").

This engine is used by the end-to-end tests and examples; the DES
(des.py) is used for statistically tight model validation.
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from ..core.measurer import Measurer

__all__ = ["StreamTuple", "Operator", "StreamEngine"]


@dataclass
class _RootState:
    t_arrival: float
    outstanding: int = 0
    lock: threading.Lock = field(default_factory=threading.Lock)


@dataclass
class StreamTuple:
    payload: Any
    root_id: int
    t_emit: float


class Operator:
    """A named operator: fn(payload) -> list of (downstream_name, payload).

    ``fn`` runs inside worker threads; JAX-jitted callables are safe (the
    GIL is released during XLA execution).  ``fn`` may return [] (sink).
    """

    def __init__(self, name: str, fn: Callable[[Any], list[tuple[str, Any]]]):
        self.name = name
        self.fn = fn


class StreamEngine:
    """Topology runtime with per-operator worker pools."""

    def __init__(
        self,
        operators: list[Operator],
        *,
        measurer: Measurer | None = None,
        queue_capacity: int = 10_000,
    ):
        self.operators = {op.name: op for op in operators}
        self.names = [op.name for op in operators]
        self.measurer = measurer or Measurer(self.names)
        self.queues: dict[str, queue.Queue] = {
            n: queue.Queue(maxsize=queue_capacity) for n in self.names
        }
        self._workers: dict[str, list[threading.Thread]] = {n: [] for n in self.names}
        self._worker_stop: dict[str, list[threading.Event]] = {n: [] for n in self.names}
        # Dedicated arrival probes (queue-tail measurement position, paper
        # Appendix C) — independent of worker lifecycle.
        self._arrival_probes = {n: self.measurer.new_probe(n) for n in self.names}
        self._roots: dict[int, _RootState] = {}
        self._roots_lock = threading.Lock()
        self._root_ids = itertools.count()
        self._stop = threading.Event()
        self.completed_sojourns: list[float] = []
        self._completed_lock = threading.Lock()

    # ------------------------------------------------------------------ #
    def k(self) -> dict[str, int]:
        return {n: len(self._workers[n]) for n in self.names}

    def scale_to(self, allocation: dict[str, int]) -> None:
        """Rescale operators to the given instance counts (cheap rebalance:
        only affected operators change; queues and other operators keep
        flowing)."""
        for name, target in allocation.items():
            cur = len(self._workers[name])
            if target > cur:
                for _ in range(target - cur):
                    self._start_worker(name)
            elif target < cur:
                for _ in range(cur - target):
                    ev = self._worker_stop[name].pop()
                    ev.set()  # worker exits after its current tuple
                    self._workers[name].pop()

    def _start_worker(self, name: str) -> None:
        ev = threading.Event()
        probe = self.measurer.new_probe(name)
        t = threading.Thread(
            target=self._worker_loop, args=(name, ev, probe), daemon=True
        )
        self._worker_stop[name].append(ev)
        self._workers[name].append(t)
        t.start()

    # ------------------------------------------------------------------ #
    def inject(self, source: str, payload: Any) -> int:
        """External tuple enters the system (spout emission)."""
        root_id = next(self._root_ids)
        st = _RootState(t_arrival=time.perf_counter(), outstanding=1)
        with self._roots_lock:
            self._roots[root_id] = st
        self.measurer.on_external_arrival()
        self._enqueue(source, StreamTuple(payload, root_id, time.perf_counter()))
        return root_id

    def _enqueue(self, name: str, tup: StreamTuple) -> None:
        self._arrival_probes[name].on_enqueue()
        self.queues[name].put(tup)

    def _worker_loop(self, name: str, stop: threading.Event, probe) -> None:
        op = self.operators[name]
        q = self.queues[name]
        while not stop.is_set() and not self._stop.is_set():
            try:
                tup = q.get(timeout=0.05)
            except queue.Empty:
                continue
            t0 = time.perf_counter()
            try:
                emissions = op.fn(tup.payload) or []
            except Exception:  # pragma: no cover - defensive: drop poison tuples
                emissions = []
            service = time.perf_counter() - t0
            probe.on_processed(service)
            root = self._roots.get(tup.root_id)
            if root is not None:
                with root.lock:
                    root.outstanding += len(emissions)
            for dst, payload in emissions:
                self._enqueue(dst, StreamTuple(payload, tup.root_id, time.perf_counter()))
            self._complete_one(tup.root_id)

    def _complete_one(self, root_id: int) -> None:
        with self._roots_lock:
            root = self._roots.get(root_id)
        if root is None:
            return
        done = False
        with root.lock:
            root.outstanding -= 1
            done = root.outstanding == 0
        if done:
            sojourn = time.perf_counter() - root.t_arrival
            self.measurer.on_tuple_complete(sojourn)
            with self._completed_lock:
                self.completed_sojourns.append(sojourn)
            with self._roots_lock:
                self._roots.pop(root_id, None)

    # ------------------------------------------------------------------ #
    def start(self, allocation: dict[str, int]) -> None:
        self.scale_to(allocation)

    def drain(self, timeout: float = 10.0) -> bool:
        """Wait for all in-flight roots to complete."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._roots_lock:
                if not self._roots:
                    return True
            time.sleep(0.01)
        return False

    def stop(self) -> None:
        self._stop.set()
        for workers in self._workers.values():
            for t in workers:
                t.join(timeout=1.0)
