"""Discrete-event simulator for operator networks (model validation).

The paper validates its Erlang/Jackson model against a live Storm cluster;
this container has one CPU, so we validate against a faithful discrete-
event simulation of the same queueing dynamics instead — and additionally
use it to reproduce the paper's Figures 6-10 behaviourally (see
benchmarks/bench_model_accuracy.py and bench_rebalance.py).

The simulator models exactly what the DSMS does:

* external tuples arrive at source operators via a configurable arrival
  process (exponential, uniform — the paper's VLD uses uniform [1,25] fps —
  or deterministic);
* each operator has one FIFO queue and ``k_i`` parallel servers with a
  configurable service-time distribution (exponential by default, but the
  paper stresses robustness to violations, so deterministic/uniform/
  lognormal are supported);
* on completion at operator *i*, derived tuples are spawned downstream per
  the routing matrix (integer part deterministic + Bernoulli fractional
  part, so the *mean* multiplicity matches the Jackson weight);
* a per-root outstanding-tuple counter implements the paper's "fully
  processed" definition: the **complete sojourn time** of an external tuple
  is from its arrival until its whole processing tree has drained;
* optional per-hop network delay models the out-of-model cost that causes
  the paper's Fig. 8 underestimation;
* ``rebalance_at(t, k_new, pause)`` changes the allocation mid-run with a
  processing pause, reproducing the Fig. 9/10 experiments;
* the DRS :class:`~repro.core.measurer.Measurer` can be attached so the
  whole control loop (measure -> model -> reallocate) runs in simulated
  time end-to-end.
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from ..core.jackson import Topology
from ..core.measurer import Measurer

__all__ = ["ArrivalProcess", "ServiceProcess", "SimConfig", "SimResult", "NetworkSimulator"]


@dataclass(frozen=True)
class ArrivalProcess:
    """Inter-arrival time generator for a source operator."""

    rate: float
    kind: str = "exponential"  # exponential | uniform | deterministic

    def sample(self, rng: np.random.Generator) -> float:
        if self.rate <= 0:
            return math.inf
        mean = 1.0 / self.rate
        if self.kind == "exponential":
            return rng.exponential(mean)
        if self.kind == "uniform":
            # uniform on [0, 2*mean] — mean preserved, like the paper's fps
            return rng.uniform(0.0, 2.0 * mean)
        if self.kind == "deterministic":
            return mean
        raise ValueError(f"unknown arrival kind {self.kind!r}")


@dataclass(frozen=True)
class ServiceProcess:
    """Service-time generator for an operator's servers."""

    rate: float
    kind: str = "exponential"  # exponential | uniform | deterministic | lognormal
    cv: float = 1.0  # coefficient of variation for lognormal

    def sample(self, rng: np.random.Generator) -> float:
        mean = 1.0 / self.rate
        if self.kind == "exponential":
            return rng.exponential(mean)
        if self.kind == "uniform":
            return rng.uniform(0.0, 2.0 * mean)
        if self.kind == "deterministic":
            return mean
        if self.kind == "lognormal":
            sigma2 = math.log(1.0 + self.cv**2)
            mu = math.log(mean) - sigma2 / 2.0
            return rng.lognormal(mu, math.sqrt(sigma2))
        raise ValueError(f"unknown service kind {self.kind!r}")


@dataclass
class SimConfig:
    seed: int = 0
    warmup: float = 10.0  # ignore completions before this time
    horizon: float = 120.0
    network_delay: float = 0.0  # fixed per-hop delay (out-of-model cost, Fig. 8)
    max_events: int = 5_000_000
    queue_capacity: int | None = None  # None = unbounded


@dataclass
class SimResult:
    completed: int
    mean_sojourn: float  # complete sojourn (tree completion) — what the paper measures
    std_sojourn: float
    mean_visit_sum: float  # sum of per-visit sojourns (what Eq. 3 predicts exactly)
    p95_sojourn: float
    per_op_arrival_rate: np.ndarray
    per_op_mean_service: np.ndarray
    per_op_mean_wait: np.ndarray
    dropped: int
    sojourn_series: list[tuple[float, float]] = field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "completed": self.completed,
            "mean_sojourn": self.mean_sojourn,
            "std_sojourn": self.std_sojourn,
            "mean_visit_sum": self.mean_visit_sum,
            "p95_sojourn": self.p95_sojourn,
            "per_op_arrival_rate": self.per_op_arrival_rate.tolist(),
            "dropped": self.dropped,
        }


# Event kinds (ordering tiebreaker: sequence number)
_ARRIVAL, _SERVICE_DONE, _CONTROL = 0, 1, 2


@dataclass
class _Root:
    t_arrival: float
    outstanding: int = 0
    visit_time_sum: float = 0.0


class NetworkSimulator:
    """Event-driven simulation of an operator network under allocation k."""

    def __init__(
        self,
        topology: Topology,
        k: np.ndarray | list[int],
        *,
        config: SimConfig | None = None,
        arrivals: list[ArrivalProcess] | None = None,
        services: list[ServiceProcess] | None = None,
        measurer: Measurer | None = None,
    ):
        self.top = topology
        self.cfg = config or SimConfig()
        self.k = np.asarray(k, dtype=np.int64).copy()
        n = topology.n
        self.arrivals = arrivals or [
            ArrivalProcess(rate=float(topology.lam0[i])) for i in range(n)
        ]
        self.services = services or [
            ServiceProcess(rate=op.mu) for op in topology.operators
        ]
        self.measurer = measurer
        self._probes = (
            [measurer.new_probe(op.name) for op in topology.operators]
            if measurer is not None
            else None
        )
        self.rng = np.random.default_rng(self.cfg.seed)
        self._seq = itertools.count()
        self._events: list[tuple[float, int, int, tuple]] = []
        self._queues: list[list[tuple[float, int]]] = [[] for _ in range(n)]
        self._busy = np.zeros(n, dtype=np.int64)
        self._paused_until = 0.0
        self._roots: dict[int, _Root] = {}
        self._root_ids = itertools.count()
        self._sojourns: list[float] = []
        self._visit_sums: list[float] = []
        self._series: list[tuple[float, float]] = []
        self._op_arrivals = np.zeros(n, dtype=np.int64)
        self._op_service_sum = np.zeros(n)
        self._op_service_n = np.zeros(n, dtype=np.int64)
        self._op_wait_sum = np.zeros(n)
        self._op_wait_n = np.zeros(n, dtype=np.int64)
        self._dropped = 0
        self._rebalances: list[tuple[float, np.ndarray, float]] = []
        self.now = 0.0

    # ------------------------------------------------------------------ #
    def rebalance_at(self, t: float, k_new: np.ndarray | list[int], pause: float = 0.0) -> None:
        """Schedule an allocation change (with optional processing pause)."""
        self._push(t, _CONTROL, ("rebalance", np.asarray(k_new, dtype=np.int64), pause))

    def schedule_rate_change(self, t: float, op_index: int, new_rate: float, kind: str | None = None) -> None:
        """Change an operator's service rate mid-run (workload shift / straggler)."""
        self._push(t, _CONTROL, ("mu", op_index, new_rate, kind))

    def schedule_arrival_change(self, t: float, op_index: int, new_rate: float) -> None:
        self._push(t, _CONTROL, ("lam0", op_index, new_rate))

    def _push(self, t: float, kind: int, payload: tuple) -> None:
        heapq.heappush(self._events, (t, kind, next(self._seq), payload))

    # ------------------------------------------------------------------ #
    def _spawn_external(self, i: int) -> None:
        dt = self.arrivals[i].sample(self.rng)
        if math.isfinite(dt):
            self._push(self.now + dt, _ARRIVAL, ("external", i))

    def _admit(self, i: int, root_id: int) -> None:
        """Tuple arrives at operator i's queue tail."""
        self._op_arrivals[i] += 1
        if self._probes is not None:
            self._probes[i].on_enqueue()
        cap = self.cfg.queue_capacity
        if cap is not None and len(self._queues[i]) >= cap:
            # Dropped tuple never joins the tree; a rejected external tuple
            # (outstanding == 0) is removed outright.
            self._dropped += 1
            if self._roots[root_id].outstanding == 0:
                del self._roots[root_id]
            return
        self._roots[root_id].outstanding += 1
        self._queues[i].append((self.now, root_id))
        self._try_start(i)

    def _try_start(self, i: int) -> None:
        if self.now < self._paused_until:
            return
        while self._busy[i] < self.k[i] and self._queues[i]:
            t_enq, root_id = self._queues[i].pop(0)
            wait = self.now - t_enq
            self._op_wait_sum[i] += wait
            self._op_wait_n[i] += 1
            st = self.services[i].sample(self.rng)
            self._op_service_sum[i] += st
            self._op_service_n[i] += 1
            if self._probes is not None:
                self._probes[i].on_processed(st)
            self._busy[i] += 1
            root = self._roots[root_id]
            root.visit_time_sum += wait + st
            self._push(self.now + st, _SERVICE_DONE, (i, root_id))

    def _finish_derived(self, root_id: int) -> None:
        root = self._roots[root_id]
        root.outstanding -= 1
        if root.outstanding == 0:
            sojourn = self.now - root.t_arrival
            if self.now >= self.cfg.warmup:
                self._sojourns.append(sojourn)
                self._visit_sums.append(root.visit_time_sum)
                self._series.append((self.now, sojourn))
            if self.measurer is not None:
                self.measurer.on_tuple_complete(sojourn)
            del self._roots[root_id]

    def _route_downstream(self, i: int, root_id: int) -> None:
        routing = self.top.routing
        root = self._roots[root_id]
        spawned = 0
        for j in range(self.top.n):
            w = routing[i][j]
            if w <= 0:
                continue
            count = int(w) + (1 if self.rng.random() < (w - int(w)) else 0)
            for _ in range(count):
                spawned += 1
                delay = self.cfg.network_delay
                if delay > 0:
                    root.outstanding += 1  # in-flight on the wire
                    self._push(self.now + delay, _ARRIVAL, ("hop", j, root_id))
                else:
                    self._admit(j, root_id)
        # No children and nothing outstanding is handled by _finish_derived.

    # ------------------------------------------------------------------ #
    def run(self) -> SimResult:
        cfg = self.cfg
        for i in range(self.top.n):
            if self.top.lam0[i] > 0:
                self._spawn_external(i)
        events = 0
        while self._events and events < cfg.max_events:
            t, kind, _, payload = heapq.heappop(self._events)
            if t > cfg.horizon:
                break
            self.now = t
            events += 1
            if kind == _ARRIVAL:
                if payload[0] == "external":
                    i = payload[1]
                    root_id = next(self._root_ids)
                    self._roots[root_id] = _Root(t_arrival=self.now)
                    if self.measurer is not None:
                        self.measurer.on_external_arrival()
                    self._admit(i, root_id)
                    self._spawn_external(i)
                else:  # network hop delivery
                    _, j, root_id = payload
                    self._admit(j, root_id)
                    self._finish_derived(root_id)  # wire leg done
            elif kind == _SERVICE_DONE:
                i, root_id = payload
                self._busy[i] -= 1
                self._route_downstream(i, root_id)
                self._finish_derived(root_id)
                self._try_start(i)
            else:  # _CONTROL
                if payload[0] == "rebalance":
                    _, k_new, pause = payload
                    self.k = k_new.copy()
                    self._rebalances.append((self.now, k_new.copy(), pause))
                    if pause > 0:
                        self._paused_until = self.now + pause
                        self._push(self._paused_until, _CONTROL, ("resume",))
                    else:
                        for i in range(self.top.n):
                            self._try_start(i)
                elif payload[0] == "resume":
                    for i in range(self.top.n):
                        self._try_start(i)
                elif payload[0] == "mu":
                    _, i, rate, svc_kind = payload
                    old = self.services[i]
                    self.services[i] = ServiceProcess(rate, svc_kind or old.kind, old.cv)
                elif payload[0] == "lam0":
                    _, i, rate = payload
                    had = self.arrivals[i].rate > 0
                    self.arrivals[i] = ArrivalProcess(rate, self.arrivals[i].kind)
                    if not had and rate > 0:
                        self._spawn_external(i)
        measured_span = max(self.now - cfg.warmup, 1e-9)
        soj = np.asarray(self._sojourns) if self._sojourns else np.array([np.nan])
        vs = np.asarray(self._visit_sums) if self._visit_sums else np.array([np.nan])
        return SimResult(
            completed=len(self._sojourns),
            mean_sojourn=float(np.mean(soj)),
            std_sojourn=float(np.std(soj)),
            mean_visit_sum=float(np.mean(vs)),
            p95_sojourn=float(np.percentile(soj, 95)),
            per_op_arrival_rate=self._op_arrivals / max(self.now, 1e-9),
            per_op_mean_service=np.where(
                self._op_service_n > 0, self._op_service_sum / np.maximum(self._op_service_n, 1), np.nan
            ),
            per_op_mean_wait=np.where(
                self._op_wait_n > 0, self._op_wait_sum / np.maximum(self._op_wait_n, 1), np.nan
            ),
            dropped=self._dropped,
            sojourn_series=self._series,
        )


def simulate_allocation(
    topology: Topology,
    k: np.ndarray | list[int],
    *,
    seed: int = 0,
    horizon: float = 120.0,
    warmup: float = 10.0,
    network_delay: float = 0.0,
    arrival_kind: str = "exponential",
    service_kind: str = "exponential",
) -> SimResult:
    """One-call helper: simulate topology under allocation k."""
    n = topology.n
    arrivals = [
        ArrivalProcess(rate=float(topology.lam0[i]), kind=arrival_kind) for i in range(n)
    ]
    services = [ServiceProcess(rate=op.mu, kind=service_kind) for op in topology.operators]
    sim = NetworkSimulator(
        topology,
        k,
        config=SimConfig(seed=seed, horizon=horizon, warmup=warmup, network_delay=network_delay),
        arrivals=arrivals,
        services=services,
    )
    return sim.run()
