"""Discrete-event simulator for operator networks (model validation).

The paper validates its Erlang/Jackson model against a live Storm cluster;
this container has one CPU, so we validate against a faithful discrete-
event simulation of the same queueing dynamics instead — and additionally
use it to reproduce the paper's Figures 6-10 behaviourally (see
benchmarks/bench_model_accuracy.py and bench_rebalance.py).

The simulator models exactly what the DSMS does:

* external tuples arrive at source operators via a configurable arrival
  process (exponential, uniform — the paper's VLD uses uniform [1,25] fps —
  deterministic, 2-state Markov-modulated Poisson ``"mmpp"``, or a
  flash-crowd ``"burst"`` schedule for overload experiments);
* each operator has one FIFO queue and ``k_i`` parallel servers with a
  configurable service-time distribution (exponential by default, but the
  paper stresses robustness to violations, so deterministic/uniform/
  lognormal are supported);
* queues may be bounded (``SimConfig.queue_capacity``) with the same
  :class:`~repro.streaming.overload.OverloadPolicy` semantics as the live
  engine — block (backpressure via a pending line), shed-newest, or
  shed-oldest — with per-operator drop accounting that matches the
  engine's (a dropped external tuple is *not* counted as an external
  arrival by the measurer, so ``lam0_hat`` stays unbiased; the queue-tail
  probes still see the full offered load);
* on completion at operator *i*, derived tuples are spawned downstream per
  the routing matrix (integer part deterministic + Bernoulli fractional
  part, so the *mean* multiplicity matches the Jackson weight);
* a per-root outstanding-tuple counter implements the paper's "fully
  processed" definition: the **complete sojourn time** of an external tuple
  is from its arrival until its whole processing tree has drained;
* optional per-hop network delay models the out-of-model cost that causes
  the paper's Fig. 8 underestimation;
* ``rebalance_at(t, k_new, pause)`` changes the allocation mid-run with a
  processing pause, reproducing the Fig. 9/10 experiments;
* the DRS :class:`~repro.core.measurer.Measurer` can be attached so the
  whole control loop (measure -> model -> reallocate) runs in simulated
  time end-to-end.
"""

from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field, replace

import numpy as np

from ..core.jackson import Topology
from ..core.measurer import Measurer
from .overload import OverloadPolicy

__all__ = ["ArrivalProcess", "ServiceProcess", "SimConfig", "SimResult", "NetworkSimulator"]


@dataclass(frozen=True)
class ArrivalProcess:
    """Inter-arrival time generator for a source operator.

    Kinds:

    * ``exponential`` / ``uniform`` / ``deterministic`` — renewal processes
      at mean rate ``rate``;
    * ``mmpp`` — 2-state Markov-modulated Poisson process: Poisson at
      ``rate`` in state 0 and ``rate2`` in state 1, switching at
      exponential rates ``switch01`` (0→1) and ``switch10`` (1→0).  The
      long-run mean rate is ``(switch10*rate + switch01*rate2) /
      (switch01 + switch10)``;
    * ``burst`` — deterministic flash-crowd schedule: Poisson at ``rate``
      except during the first ``burst_length`` seconds of every
      ``burst_every``-second cycle, where the rate is ``rate2`` (the
      Fig. 9/10-style mid-run workload shift, repeatable).

    ``mmpp`` and ``burst`` carry private mutable phase state, so one
    instance must not be shared between concurrently-running simulators.
    """

    rate: float
    kind: str = "exponential"  # exponential | uniform | deterministic | mmpp | burst
    # mmpp state-1 rate / burst peak rate.  Required for those kinds (an
    # explicit 0.0 models an ON/OFF process; None would be a silent
    # degenerate config, so it raises instead).
    rate2: float | None = None
    switch01: float = 0.1  # mmpp: 0 -> 1 transition rate (per second)
    switch10: float = 0.1  # mmpp: 1 -> 0 transition rate (per second)
    burst_every: float = 60.0  # burst: cycle period (seconds)
    burst_length: float = 5.0  # burst: peak-rate window at each cycle start
    _state: dict = field(default_factory=dict, repr=False, compare=False)

    def sample(self, rng: np.random.Generator) -> float:
        if self.kind == "mmpp":
            return self._sample_mmpp(rng)
        if self.kind == "burst":
            return self._sample_burst(rng)
        if self.rate <= 0:
            return math.inf
        mean = 1.0 / self.rate
        if self.kind == "exponential":
            return rng.exponential(mean)
        if self.kind == "uniform":
            # uniform on [0, 2*mean] — mean preserved, like the paper's fps
            return rng.uniform(0.0, 2.0 * mean)
        if self.kind == "deterministic":
            return mean
        raise ValueError(f"unknown arrival kind {self.kind!r}")

    def _rate2(self) -> float:
        if self.rate2 is None:
            raise ValueError(
                f"ArrivalProcess(kind={self.kind!r}) needs rate2= (second-state"
                " / peak rate); pass 0.0 explicitly for an ON/OFF process"
            )
        return self.rate2

    def _sample_mmpp(self, rng: np.random.Generator) -> float:
        """Competing exponentials: in each modulating state the next event
        is either an arrival or a state switch, whichever fires first."""
        rate2 = self._rate2()
        state = self._state.setdefault("s", 0)
        t = 0.0
        while True:
            r = self.rate if state == 0 else rate2
            sw = self.switch01 if state == 0 else self.switch10
            t_arr = rng.exponential(1.0 / r) if r > 0 else math.inf
            t_sw = rng.exponential(1.0 / sw) if sw > 0 else math.inf
            if not math.isfinite(t_arr) and not math.isfinite(t_sw):
                return math.inf
            if t_arr <= t_sw:
                self._state["s"] = state
                return t + t_arr
            t += t_sw
            state = 1 - state

    def _sample_burst(self, rng: np.random.Generator) -> float:
        """Piecewise-constant-rate Poisson: draw within the current phase,
        restarting from the boundary when the draw crosses it."""
        rate2 = self._rate2()
        if self.burst_every <= 0 or not 0 < self.burst_length <= self.burst_every:
            raise ValueError(
                f"burst needs 0 < burst_length <= burst_every, got "
                f"length={self.burst_length}, every={self.burst_every}"
            )
        if self.rate <= 0 and rate2 <= 0:
            return math.inf
        t = self._state.get("t", 0.0)
        t0 = t
        while True:
            phase = t % self.burst_every
            in_burst = phase < self.burst_length
            r = rate2 if in_burst else self.rate
            boundary = t - phase + (self.burst_length if in_burst else self.burst_every)
            if r <= 0:
                t = boundary
                continue
            dt = rng.exponential(1.0 / r)
            if t + dt <= boundary:
                self._state["t"] = t + dt
                return t + dt - t0
            t = boundary


@dataclass(frozen=True)
class ServiceProcess:
    """Service-time generator for an operator's servers."""

    rate: float
    kind: str = "exponential"  # exponential | uniform | deterministic | lognormal
    cv: float = 1.0  # coefficient of variation for lognormal

    def sample(self, rng: np.random.Generator) -> float:
        mean = 1.0 / self.rate
        if self.kind == "exponential":
            return rng.exponential(mean)
        if self.kind == "uniform":
            return rng.uniform(0.0, 2.0 * mean)
        if self.kind == "deterministic":
            return mean
        if self.kind == "lognormal":
            sigma2 = math.log(1.0 + self.cv**2)
            mu = math.log(mean) - sigma2 / 2.0
            return rng.lognormal(mu, math.sqrt(sigma2))
        raise ValueError(f"unknown service kind {self.kind!r}")


@dataclass
class SimConfig:
    seed: int = 0
    warmup: float = 10.0  # ignore completions before this time
    horizon: float = 120.0
    network_delay: float = 0.0  # fixed per-hop delay (out-of-model cost, Fig. 8)
    max_events: int = 5_000_000
    queue_capacity: int | None = None  # None = unbounded
    # What to do when a bounded queue is full (DESIGN.md §11).  The default
    # matches the historical DES behaviour (arriving tuple is dropped).
    overload_policy: OverloadPolicy | str = "shed-newest"


@dataclass
class SimResult:
    completed: int
    mean_sojourn: float  # complete sojourn (tree completion) — what the paper measures
    std_sojourn: float
    mean_visit_sum: float  # sum of per-visit sojourns (what Eq. 3 predicts exactly)
    p95_sojourn: float
    per_op_arrival_rate: np.ndarray  # post-warmup offered arrivals / post-warmup span
    per_op_mean_service: np.ndarray
    per_op_mean_wait: np.ndarray
    dropped: int  # total tuples shed (whole run, all operators)
    sojourn_series: list[tuple[float, float]] = field(default_factory=list)
    # Overload accounting (zeros when queues are unbounded):
    per_op_dropped: np.ndarray | None = None  # tuples shed per operator (whole run)
    per_op_drop_rate: np.ndarray | None = None  # post-warmup sheds / span (tuples/s)
    per_op_max_backlog: np.ndarray | None = None  # max queue + blocked-pending length
    shed_roots: int = 0  # external tuples whose tree lost >= 1 tuple

    def as_dict(self) -> dict:
        return {
            "completed": self.completed,
            "mean_sojourn": self.mean_sojourn,
            "std_sojourn": self.std_sojourn,
            "mean_visit_sum": self.mean_visit_sum,
            "p95_sojourn": self.p95_sojourn,
            "per_op_arrival_rate": self.per_op_arrival_rate.tolist(),
            "dropped": self.dropped,
            "per_op_dropped": None
            if self.per_op_dropped is None
            else self.per_op_dropped.tolist(),
            "per_op_drop_rate": None
            if self.per_op_drop_rate is None
            else self.per_op_drop_rate.tolist(),
            "per_op_max_backlog": None
            if self.per_op_max_backlog is None
            else self.per_op_max_backlog.tolist(),
            "shed_roots": self.shed_roots,
        }


# Event kinds (ordering tiebreaker: sequence number)
_ARRIVAL, _SERVICE_DONE, _CONTROL = 0, 1, 2


@dataclass
class _Root:
    t_arrival: float
    outstanding: int = 0
    visit_time_sum: float = 0.0
    shed: bool = False  # any tuple of this root's tree was dropped


class NetworkSimulator:
    """Event-driven simulation of an operator network under allocation k."""

    def __init__(
        self,
        topology: Topology,
        k: np.ndarray | list[int],
        *,
        config: SimConfig | None = None,
        arrivals: list[ArrivalProcess] | None = None,
        services: list[ServiceProcess] | None = None,
        measurer: Measurer | None = None,
    ):
        self.top = topology
        self.cfg = config or SimConfig()
        self.k = np.asarray(k, dtype=np.int64).copy()
        n = topology.n
        self.arrivals = arrivals or [
            ArrivalProcess(rate=float(topology.lam0[i])) for i in range(n)
        ]
        self.services = services or [
            ServiceProcess(rate=op.mu) for op in topology.operators
        ]
        self.measurer = measurer
        self._probes = (
            [measurer.new_probe(op.name) for op in topology.operators]
            if measurer is not None
            else None
        )
        if self.cfg.queue_capacity is not None and self.cfg.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1 or None (unbounded), got "
                f"{self.cfg.queue_capacity}"
            )
        self.policy = OverloadPolicy.coerce(self.cfg.overload_policy)
        self.rng = np.random.default_rng(self.cfg.seed)
        self._seq = itertools.count()
        self._events: list[tuple[float, int, int, tuple]] = []
        self._queues: list[deque[tuple[float, int]]] = [deque() for _ in range(n)]
        # Block policy: arrivals that found the queue full wait here (the
        # DES analogue of a blocked producer) and are admitted FIFO.
        self._pending: list[deque[tuple[float, int]]] = [deque() for _ in range(n)]
        self._busy = np.zeros(n, dtype=np.int64)
        self._paused_until = 0.0
        self._roots: dict[int, _Root] = {}
        self._root_ids = itertools.count()
        self._sojourns: list[float] = []
        self._visit_sums: list[float] = []
        self._series: list[tuple[float, float]] = []
        self._op_arrivals = np.zeros(n, dtype=np.int64)
        self._op_arrivals_warm = np.zeros(n, dtype=np.int64)  # post-warmup only
        self._op_service_sum = np.zeros(n)
        self._op_service_n = np.zeros(n, dtype=np.int64)
        self._op_wait_sum = np.zeros(n)
        self._op_wait_n = np.zeros(n, dtype=np.int64)
        self._dropped = 0
        self._op_drops = np.zeros(n, dtype=np.int64)
        self._op_drops_warm = np.zeros(n, dtype=np.int64)
        self._op_max_backlog = np.zeros(n, dtype=np.int64)
        self._shed_roots = 0
        self._rebalances: list[tuple[float, np.ndarray, float]] = []
        self.now = 0.0

    # ------------------------------------------------------------------ #
    def rebalance_at(self, t: float, k_new: np.ndarray | list[int], pause: float = 0.0) -> None:
        """Schedule an allocation change (with optional processing pause)."""
        self._push(t, _CONTROL, ("rebalance", np.asarray(k_new, dtype=np.int64), pause))

    def schedule_rate_change(self, t: float, op_index: int, new_rate: float, kind: str | None = None) -> None:
        """Change an operator's service rate mid-run (workload shift / straggler)."""
        self._push(t, _CONTROL, ("mu", op_index, new_rate, kind))

    def schedule_arrival_change(self, t: float, op_index: int, new_rate: float) -> None:
        self._push(t, _CONTROL, ("lam0", op_index, new_rate))

    def _push(self, t: float, kind: int, payload: tuple) -> None:
        heapq.heappush(self._events, (t, kind, next(self._seq), payload))

    # ------------------------------------------------------------------ #
    def _spawn_external(self, i: int) -> None:
        dt = self.arrivals[i].sample(self.rng)
        if math.isfinite(dt):
            self._push(self.now + dt, _ARRIVAL, ("external", i))

    def _admit(self, i: int, root_id: int) -> bool:
        """Tuple arrives at operator i's queue tail.

        Returns True when the tuple joined the system (queue or blocked
        pending line), False when it was shed under the overload policy.
        The queue-tail probe counts it either way (offered load, paper
        Appendix C); drops are recorded separately.
        """
        self._op_arrivals[i] += 1
        if self.now >= self.cfg.warmup:
            self._op_arrivals_warm[i] += 1
        if self._probes is not None:
            self._probes[i].on_enqueue()
        cap = self.cfg.queue_capacity
        q = self._queues[i]
        if cap is not None and (len(q) >= cap or self._pending[i]):
            if self.policy.kind == "shed-newest":
                # Rejected tuple never joins the tree.
                self._record_drop(i)
                self._poison_root(root_id)
                return False
            if self.policy.kind == "shed-oldest":
                _t_old, old_root = q.popleft()
                self._record_drop(i)
                self._drop_queued(old_root)
                # fall through: the new tuple takes the freed slot
            else:  # block: wait at the tail (FIFO behind earlier blocked)
                self._roots[root_id].outstanding += 1
                self._pending[i].append((self.now, root_id))
                self._note_backlog(i)
                return True
        self._roots[root_id].outstanding += 1
        q.append((self.now, root_id))
        self._note_backlog(i)
        self._try_start(i)
        return True

    def _note_backlog(self, i: int) -> None:
        backlog = len(self._queues[i]) + len(self._pending[i])
        if backlog > self._op_max_backlog[i]:
            self._op_max_backlog[i] = backlog

    def _record_drop(self, i: int) -> None:
        self._dropped += 1
        self._op_drops[i] += 1
        if self.now >= self.cfg.warmup:
            self._op_drops_warm[i] += 1
        if self._probes is not None:
            self._probes[i].on_dropped()

    def _poison_root(self, root_id: int) -> None:
        """A tuple of this root was shed before joining a queue."""
        root = self._roots[root_id]
        root.shed = True
        if root.outstanding == 0:
            self._retire_root(root_id)

    def _drop_queued(self, root_id: int) -> None:
        """A queued tuple of this root was evicted (shed-oldest)."""
        root = self._roots[root_id]
        root.shed = True
        root.outstanding -= 1
        if root.outstanding == 0:
            self._retire_root(root_id)

    def _promote_pending(self, i: int) -> None:
        cap = self.cfg.queue_capacity
        q, pend = self._queues[i], self._pending[i]
        while pend and (cap is None or len(q) < cap):
            q.append(pend.popleft())

    def _try_start(self, i: int) -> None:
        if self.now < self._paused_until:
            return
        q = self._queues[i]
        self._promote_pending(i)
        while self._busy[i] < self.k[i] and q:
            t_enq, root_id = q.popleft()
            self._promote_pending(i)  # a slot freed: unblock a producer
            wait = self.now - t_enq
            self._op_wait_sum[i] += wait
            self._op_wait_n[i] += 1
            st = self.services[i].sample(self.rng)
            self._op_service_sum[i] += st
            self._op_service_n[i] += 1
            if self._probes is not None:
                self._probes[i].on_processed(st)
            self._busy[i] += 1
            root = self._roots[root_id]
            root.visit_time_sum += wait + st
            self._push(self.now + st, _SERVICE_DONE, (i, root_id))

    def _retire_root(self, root_id: int) -> None:
        """Outstanding count hit zero: record completion or shed."""
        root = self._roots.pop(root_id)
        if root.shed:
            # Partially-processed tree: its sojourn would be biased (the
            # shed branches never ran), so it is counted, not timed.
            self._shed_roots += 1
            return
        sojourn = self.now - root.t_arrival
        if self.now >= self.cfg.warmup:
            self._sojourns.append(sojourn)
            self._visit_sums.append(root.visit_time_sum)
            self._series.append((self.now, sojourn))
        if self.measurer is not None:
            self.measurer.on_tuple_complete(sojourn)

    def _finish_derived(self, root_id: int) -> None:
        root = self._roots[root_id]
        root.outstanding -= 1
        if root.outstanding == 0:
            self._retire_root(root_id)

    def _route_downstream(self, i: int, root_id: int) -> None:
        routing = self.top.routing
        root = self._roots[root_id]
        spawned = 0
        for j in range(self.top.n):
            w = routing[i][j]
            if w <= 0:
                continue
            count = int(w) + (1 if self.rng.random() < (w - int(w)) else 0)
            for _ in range(count):
                spawned += 1
                delay = self.cfg.network_delay
                if delay > 0:
                    root.outstanding += 1  # in-flight on the wire
                    self._push(self.now + delay, _ARRIVAL, ("hop", j, root_id))
                else:
                    self._admit(j, root_id)
        # No children and nothing outstanding is handled by _finish_derived.

    # ------------------------------------------------------------------ #
    def run(self) -> SimResult:
        cfg = self.cfg
        for i in range(self.top.n):
            if self.top.lam0[i] > 0:
                self._spawn_external(i)
        events = 0
        while self._events and events < cfg.max_events:
            t, kind, _, payload = heapq.heappop(self._events)
            if t > cfg.horizon:
                break
            self.now = t
            events += 1
            if kind == _ARRIVAL:
                if payload[0] == "external":
                    i = payload[1]
                    root_id = next(self._root_ids)
                    self._roots[root_id] = _Root(t_arrival=self.now)
                    admitted = self._admit(i, root_id)
                    # Only admitted tuples count toward lam0_hat; a tuple
                    # shed at the source is visible via the drop counters
                    # instead (otherwise lam0_hat is biased upward and the
                    # model predicts load the network never carries).
                    if admitted and self.measurer is not None:
                        self.measurer.on_external_arrival()
                    self._spawn_external(i)
                else:  # network hop delivery
                    _, j, root_id = payload
                    self._admit(j, root_id)
                    self._finish_derived(root_id)  # wire leg done
            elif kind == _SERVICE_DONE:
                i, root_id = payload
                self._busy[i] -= 1
                self._route_downstream(i, root_id)
                self._finish_derived(root_id)
                self._try_start(i)
            else:  # _CONTROL
                if payload[0] == "rebalance":
                    _, k_new, pause = payload
                    self.k = k_new.copy()
                    self._rebalances.append((self.now, k_new.copy(), pause))
                    if pause > 0:
                        self._paused_until = self.now + pause
                        self._push(self._paused_until, _CONTROL, ("resume",))
                    else:
                        for i in range(self.top.n):
                            self._try_start(i)
                elif payload[0] == "resume":
                    for i in range(self.top.n):
                        self._try_start(i)
                elif payload[0] == "mu":
                    _, i, rate, svc_kind = payload
                    old = self.services[i]
                    self.services[i] = ServiceProcess(rate, svc_kind or old.kind, old.cv)
                elif payload[0] == "lam0":
                    _, i, rate = payload
                    old = self.arrivals[i]
                    had = old.rate > 0 or (old.rate2 or 0.0) > 0
                    # replace() keeps kind AND the mmpp/burst parameters
                    # (rate2, switch rates, burst schedule, phase state).
                    self.arrivals[i] = replace(old, rate=rate)
                    if not had and rate > 0:
                        self._spawn_external(i)
        # Post-warmup counts over the post-warmup span: warmup arrivals
        # must not leak into the steady-state rate estimate.
        measured_span = max(self.now - cfg.warmup, 1e-9)
        soj = np.asarray(self._sojourns) if self._sojourns else np.array([np.nan])
        vs = np.asarray(self._visit_sums) if self._visit_sums else np.array([np.nan])
        return SimResult(
            completed=len(self._sojourns),
            mean_sojourn=float(np.mean(soj)),
            std_sojourn=float(np.std(soj)),
            mean_visit_sum=float(np.mean(vs)),
            p95_sojourn=float(np.percentile(soj, 95)),
            per_op_arrival_rate=self._op_arrivals_warm / measured_span,
            per_op_mean_service=np.where(
                self._op_service_n > 0, self._op_service_sum / np.maximum(self._op_service_n, 1), np.nan
            ),
            per_op_mean_wait=np.where(
                self._op_wait_n > 0, self._op_wait_sum / np.maximum(self._op_wait_n, 1), np.nan
            ),
            dropped=self._dropped,
            sojourn_series=self._series,
            per_op_dropped=self._op_drops.copy(),
            per_op_drop_rate=self._op_drops_warm / measured_span,
            per_op_max_backlog=self._op_max_backlog.copy(),
            shed_roots=self._shed_roots,
        )


def simulate_allocation(
    topology: Topology,
    k: np.ndarray | list[int],
    *,
    seed: int = 0,
    horizon: float = 120.0,
    warmup: float = 10.0,
    network_delay: float = 0.0,
    arrival_kind: str = "exponential",
    service_kind: str = "exponential",
    queue_capacity: int | None = None,
    overload_policy: OverloadPolicy | str = "shed-newest",
) -> SimResult:
    """One-call helper: simulate topology under allocation k."""
    n = topology.n
    arrivals = [
        ArrivalProcess(rate=float(topology.lam0[i]), kind=arrival_kind) for i in range(n)
    ]
    services = [ServiceProcess(rate=op.mu, kind=service_kind) for op in topology.operators]
    sim = NetworkSimulator(
        topology,
        k,
        config=SimConfig(
            seed=seed,
            horizon=horizon,
            warmup=warmup,
            network_delay=network_delay,
            queue_capacity=queue_capacity,
            overload_policy=overload_policy,
        ),
        arrivals=arrivals,
        services=services,
    )
    return sim.run()
