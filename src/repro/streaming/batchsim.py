"""Vectorized discrete-time batch simulator for scenario matrices (DESIGN.md §13).

The event DES (`streaming/des.py`) is the repo's high-fidelity validator —
and a scalar Python heapq loop, so sweeping hundreds of scenarios through
it is minutes of wall-clock.  This module advances **B scenarios x N
operators in parallel** with a discrete-time fluid/queue recurrence:

    served_t   = min(q_t, k * mu_eff * dt)          # drain step-start backlog
    inflow_t   = ext_t + served_{t-1} @ P           # one-step hop delay
    admitted_t = min(inflow_t, max(cap_queue - (q_t - served_t), 0))
    q_{t+1}    = q_t - served_t + admitted_t,  dropped_t = inflow_t - admitted_t

External arrivals ``ext_t`` are **pre-sampled counts** (seeded numpy
Poisson for stochastic kinds, exact ``rate * dt`` for deterministic), so
both backends consume identical randomness:

* **numpy float64** — the bit-exact debugging twin (same seed => bit-
  identical ``BatchSimResult``), and the default off-TPU;
* **jax** — ``jit`` over a ``lax.scan`` whose per-step bounded-queue
  update dispatches through ``kernels/queue_step`` (Pallas on TPU, jnp
  oracle elsewhere; ``force_kernel=True, interpret=True`` exercises the
  kernel on CPU).  Dtype follows JAX's active precision: float64 under
  ``enable_x64`` (matches the twin to ~1e-9), float32 otherwise.

Overload semantics mirror DESIGN.md §11: ``cap_queue = +inf`` encodes
unbounded queues AND the ``block`` policy (blocked producers hold tuples
in a pending line — backlog grows, nothing is shed), finite ``cap_queue``
encodes the shed policies (in fluid volume terms ``shed-newest`` and
``shed-oldest`` drop identical mass; only tuple *age* differs, which a
fluid model does not represent).  Per-operator drop accounting splits
each step's shed mass proportionally between external and routed inflow
so the admitted external rate stays unbiased, exactly like the DES's
``lam0_hat`` rule.

Divergence vs the event DES (bounds in DESIGN.md §13/§17): the fluid
recurrence itself carries no stationary stochastic queueing delay (its
post-warmup backlog is ~0 whenever rho < 1), so the *measurement* layer
composes two wait terms per operator:

* :func:`little_wait` — Little's law on the time-averaged backlog minus
  the one-step admission floor.  Captures rate-driven (overload / trace
  peak) queueing; ~0 in steady stable state.
* :func:`stationary_wait` — the Erlang-C M/M/k waiting time at the
  admitted rate, scaled by the Allen-Cunneen factor ``(ca^2 + cs^2)/2``
  (``ca2``/``cs2`` are the squared coefficients of variation of the
  scenario's inter-arrival and service laws — 1 exponential, 1/3
  uniform, 0 deterministic, cv^2 lognormal).  Captures the stochastic
  waiting the fluid backlog cannot; identically 0 for deterministic/
  deterministic scenarios, so those stay fluid-exact.

The composed estimate is ``max(little, min(stationary, span))`` — max
avoids double counting (the fluid backlog already *is* queueing where it
exists), and the ``span`` clamp keeps a near-saturated window from
reporting a stationary wait longer than the window that measured it.
Throughputs, drop rates, and the saturated-operator set agree with the
DES; DESIGN.md §17 quantifies the sojourn bounds per scenario family.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "BatchArrays",
    "BatchSimResult",
    "BatchQueueSim",
    "composed_wait",
    "service_capacity",
    "stationary_wait",
    "window_step_fn",
]

# Static iteration bound for the masked Erlang-B recurrence in
# :func:`stationary_wait` — covers every allocation the repo's zoo and
# fleet tables reach (k_max <= 64 per scenario, 512 in the fleet tier).
# Iterations past a lane's k are where-masked no-ops, so the numpy twin
# may stop at max(k) while the jit path always runs to the cap: both
# orderings produce bit-identical lanes.
STATIONARY_K_CAP = 512


def service_capacity(k, mu, group, alpha, speed=None):
    """Per-operator service rate (tuples/sec) at allocation ``k`` — replica
    ``k * mu``, chip-gang ``mu * k * eff(k)`` (DESIGN.md §2).  ``speed``
    applies per-operator machine-class factors (heterogeneous pools,
    paper §III-A): processors of class s serve at ``s * mu``."""
    k = np.maximum(np.asarray(k, dtype=np.float64), 0.0)
    if speed is not None:
        mu = mu * speed
    with np.errstate(divide="ignore", invalid="ignore"):
        eff = 1.0 / (1.0 + alpha * (k - 1.0))
    return np.where(group, mu * k * eff, mu * k)


def little_wait(q_mean, admitted_rate, dt: float):
    """Little's-law per-operator wait from a time-averaged backlog, minus
    the one-step admission floor (a tuple admitted at step t is served
    earliest at step t+1 — the known discretization bias, DESIGN.md §13)."""
    with np.errstate(divide="ignore", invalid="ignore"):
        return np.where(
            admitted_rate > 0,
            np.maximum(q_mean / np.maximum(admitted_rate, 1e-300) - dt, 0.0),
            0.0,
        )


def stationary_wait(k, lam, mu, group, alpha, speed=None, ca2=None, cs2=None, xp=np):
    """Stationary stochastic queueing wait per operator (DESIGN.md §17).

    Erlang-C M/M/k waiting time ``C(k, a) / (k*mu - lam)`` at the admitted
    rate ``lam``, scaled by the Allen-Cunneen G/G/k factor
    ``(ca2 + cs2) / 2``.  Replica operators are M/M/k at per-server rate
    ``mu * speed``; chip-gang operators collapse to one effective server
    at the gang capacity (M/M/1), mirroring :func:`service_capacity`.
    Zero where the lane is idle (``lam == 0``), unallocated (``k == 0``),
    or not stable (``rho >= 1`` — there the fluid backlog term owns the
    wait).  ``ca2``/``cs2`` default to 1 (the M/M/k case).

    ``xp`` selects the array namespace: ``numpy`` (the float64 twin) or
    ``jax.numpy`` (the fused jit tick).  Both run the *same* masked
    Erlang-B recurrence ``B_j = a B_{j-1} / (j + a B_{j-1})`` in the same
    op order, so twin and jit agree to float-rounding on every lane.
    """
    # k * 1.0 promotes the integer allocation to mu's float dtype (exact
    # for any realistic k) identically under numpy and jnp.
    kf = xp.maximum(xp.asarray(k) * xp.ones_like(mu), 0.0)
    mu_rep = mu if speed is None else mu * speed
    one = 1.0
    with np.errstate(divide="ignore", invalid="ignore"):
        eff = one / (one + alpha * (kf - one))
        cap = xp.where(group, mu_rep * kf * eff, mu_rep * kf)
        k_srv = xp.where(group, xp.minimum(kf, one), kf)
        mu_srv = xp.where(group, cap, mu_rep + xp.zeros_like(cap))
        a = lam / xp.maximum(mu_srv, 1e-300)
        b = xp.ones_like(a)
        if xp is np:
            j_hi = int(min(max(float(np.max(k_srv, initial=1.0)), 1.0),
                           STATIONARY_K_CAP))
            for j in range(1, j_hi + 1):
                jf = float(j)
                b = xp.where(j <= k_srv, a * b / (jf + a * b), b)
        else:
            from jax import lax

            def body(j, bb):
                jf = j.astype(bb.dtype)
                return xp.where(jf <= k_srv, a * bb / (jf + a * bb), bb)

            b = lax.fori_loop(1, STATIONARY_K_CAP + 1, body, b)
        c = k_srv * b / xp.maximum(k_srv - a * (one - b), 1e-300)
        wait = c / xp.maximum(k_srv * mu_srv - lam, 1e-300)
        scv = one if ca2 is None and cs2 is None else 0.5 * (
            (one if ca2 is None else ca2) + (one if cs2 is None else cs2)
        )
        wait = wait * scv
        stable = (lam > 0) & (k_srv >= one) & (lam < k_srv * mu_srv * (1.0 - 1e-9))
    return xp.where(stable, wait, 0.0)


def composed_wait(q_mean, admitted_rate, dt, span, k, mu, group, alpha,
                  speed=None, ca2=None, cs2=None, xp=np):
    """The §17 measurement-surface wait: ``max(little, min(stationary,
    span))`` — one function so the numpy twin, the window measurement, and
    the fused jit tick compose the two terms in the same op order."""
    if xp is np:
        fluid = little_wait(q_mean, admitted_rate, dt)
    else:
        fluid = xp.where(
            admitted_rate > 0,
            xp.maximum(q_mean / xp.maximum(admitted_rate, 1e-300) - dt, 0.0),
            0.0,
        )
    stat = stationary_wait(
        k, admitted_rate, mu, group, alpha, speed, ca2, cs2, xp=xp
    )
    return xp.maximum(fluid, xp.minimum(stat, span))


def per_op_service_time(cap, mu, group):
    """Per-tuple service time: 1/mu per replica server, 1/(gang capacity)
    for chip-gang operators (DESIGN.md §2)."""
    with np.errstate(divide="ignore", invalid="ignore"):
        return np.where(group, np.where(cap > 0, 1.0 / cap, np.inf), 1.0 / mu)


def visit_sum_sojourn(admitted_rate, wait, svc, ext_rate):
    """Eq.-3-style visit-sum E[T]: sum_i admitted_i * (W_i + S_i) / lam0.
    NaN where no external tuples were admitted (no sojourn is defined —
    mirrors the measurer's empty-window behaviour)."""
    contrib = np.where(admitted_rate > 0, admitted_rate * (wait + svc), 0.0)
    total = contrib.sum(axis=-1)
    return np.where(ext_rate > 0, total / np.maximum(ext_rate, 1e-300), np.nan)


@dataclass(frozen=True)
class BatchArrays:
    """Packed inputs for one batch run (index order per scenario is the
    scenario's AppGraph operator order, padded to the batch-wide N_max
    with zero-traffic lanes)."""

    ext: np.ndarray  # [T, B, N] external arrival counts per step (tuples)
    routing: np.ndarray  # [B, N, N] expected multiplicities
    mu: np.ndarray  # [B, N] per-processor service-rate priors
    group: np.ndarray  # [B, N] bool: chip-gang scaling
    alpha: np.ndarray  # [B, N] group efficiency rolloff
    cap_queue: np.ndarray  # [B, N] queue bound (+inf = unbounded / block)
    dt: float  # step length (seconds)
    warmup_steps: int  # steps excluded from rate/backlog accounting
    # [B, N] bool: which lanes are real operators.  Consumer metadata for
    # slicing batch results back to per-scenario shape — the dynamics need
    # no mask (padding lanes carry zero arrivals, routing, and capacity,
    # so they stay identically zero).
    active: np.ndarray
    # [B, N] machine-class speed factors (None = homogeneous reference
    # class).  Scales service capacity; the controller applies the same
    # factors on the model side (DESIGN.md §14).
    speed: np.ndarray | None = None
    # [B, N] squared coefficients of variation of the inter-arrival and
    # service laws (DESIGN.md §17) — the Allen-Cunneen inputs to
    # :func:`stationary_wait`.  None = 1.0 everywhere (the M/M/k prior);
    # pack_scenarios fills them from each scenario's arrival/service kind.
    ca2: np.ndarray | None = None
    cs2: np.ndarray | None = None

    def __post_init__(self):
        t, b, n = self.ext.shape
        names = ["routing", "mu", "group", "alpha", "cap_queue", "active"]
        for opt in ("speed", "ca2", "cs2"):
            if getattr(self, opt) is not None:
                names.append(opt)
        for name in names:
            got = getattr(self, name).shape
            want = (b, n, n) if name == "routing" else (b, n)
            if got != want:
                raise ValueError(f"{name} must be {want}, got {got}")
        if not 0 <= self.warmup_steps <= t:
            raise ValueError(f"warmup_steps must be in [0, {t}], got {self.warmup_steps}")

    @property
    def steps(self) -> int:
        return self.ext.shape[0]

    @property
    def batch(self) -> int:
        return self.ext.shape[1]

    @property
    def n(self) -> int:
        return self.ext.shape[2]

    def pad_batch(self, b_total: int) -> "BatchArrays":
        """Append ``b_total - B`` inert batch lanes (device-mesh padding,
        DESIGN.md §16): zero arrivals/routing, unit service rate, inactive.
        Such lanes stay identically zero through the recurrence and the
        controller provably decides ``"none"`` on them, so padding never
        influences real scenarios."""
        t, b, n = self.ext.shape
        if b_total < b:
            raise ValueError(f"b_total {b_total} < batch {b}")
        if b_total == b:
            return self
        pad = b_total - b
        return BatchArrays(
            ext=np.concatenate([self.ext, np.zeros((t, pad, n))], axis=1),
            routing=np.concatenate([self.routing, np.zeros((pad, n, n))]),
            mu=np.concatenate([self.mu, np.ones((pad, n))]),
            group=np.concatenate([self.group, np.zeros((pad, n), dtype=bool)]),
            alpha=np.concatenate([self.alpha, np.zeros((pad, n))]),
            cap_queue=np.concatenate([self.cap_queue, np.full((pad, n), np.inf)]),
            dt=self.dt,
            warmup_steps=self.warmup_steps,
            active=np.concatenate([self.active, np.zeros((pad, n), dtype=bool)]),
            speed=None if self.speed is None
            else np.concatenate([self.speed, np.ones((pad, n))]),
            ca2=None if self.ca2 is None
            else np.concatenate([self.ca2, np.ones((pad, n))]),
            cs2=None if self.cs2 is None
            else np.concatenate([self.cs2, np.ones((pad, n))]),
        )


@dataclass
class BatchSimResult:
    """Post-warmup aggregates for every scenario in the batch.

    Rates are per second of post-warmup simulated time; ``sojourn`` is the
    Little's-law visit-sum estimate comparable to the DES's
    ``mean_visit_sum`` (waiting from the time-averaged backlog, service
    from the effective rate at the final allocation)."""

    offered: np.ndarray  # [B, N] tuples offered at each queue tail
    served: np.ndarray  # [B, N] tuples served
    dropped: np.ndarray  # [B, N] tuples shed
    ext_admitted: np.ndarray  # [B] external tuples admitted
    ext_offered: np.ndarray  # [B] external tuples offered
    q_final: np.ndarray  # [B, N] backlog at the horizon
    q_mean: np.ndarray  # [B, N] time-averaged backlog (post-warmup)
    max_backlog: np.ndarray  # [B, N] peak backlog (whole run)
    span: float  # post-warmup simulated seconds
    dt: float  # step length (for the discretization-bias correction)
    per_op_wait: np.ndarray = field(init=False)  # [B, N] Little's-law wait
    arrival_rate: np.ndarray = field(init=False)  # [B, N] offered tuples/s
    drop_rate: np.ndarray = field(init=False)  # [B, N] shed tuples/s

    def __post_init__(self):
        span = max(self.span, 1e-12)
        self.arrival_rate = self.offered / span
        self.drop_rate = self.dropped / span
        admitted_rate = (self.offered - self.dropped) / span
        self.per_op_wait = little_wait(self.q_mean, admitted_rate, self.dt)

    def sojourn(self, k, mu, group, alpha, speed=None, *,
                ca2=None, cs2=None) -> np.ndarray:
        """[B] visit-sum E[T] estimate at allocation ``k`` (Eq. 3 analogue):
        sum_i admitted_rate_i * (W_i + S_i) / external admitted rate, with
        S_i the per-tuple service time at the (possibly gang) allocation
        and W_i the §17 composed wait (fluid backlog term max'd with the
        Allen-Cunneen stationary term at the scenario's ``ca2``/``cs2``).
        NaN for scenarios that admitted no external tuples."""
        cap = service_capacity(k, mu, group, alpha, speed)
        svc = per_op_service_time(cap, mu if speed is None else mu * speed, group)
        span = max(self.span, 1e-12)
        admitted_rate = (self.offered - self.dropped) / span
        ext_rate = self.ext_admitted / span
        wait = composed_wait(
            self.q_mean, admitted_rate, self.dt, span, k, mu, group, alpha,
            speed, ca2, cs2,
        )
        return visit_sum_sojourn(admitted_rate, wait, svc, ext_rate)

    def saturated(
        self, k, mu, group, alpha, speed=None, *, drop_fraction: float = 0.01
    ) -> np.ndarray:
        """[B, N] bool: offered load at/above capacity, or sustained
        shedding — mirrors ``DRSScheduler.overloaded_mask``."""
        cap = service_capacity(k, mu, group, alpha, speed)
        hot = (self.arrival_rate >= cap * (1.0 - 1e-9)) | (
            self.drop_rate > drop_fraction * np.maximum(cap, 1e-300)
        )
        return hot & (self.arrival_rate > 0)  # idle/padding lanes are never hot


# --------------------------------------------------------------------------- #
# numpy float64 twin
# --------------------------------------------------------------------------- #
def _np_window(q, served_prev, ext_chunk, warm, cap_serve_dt, cap_queue, routing):
    """Advance one window in float64 numpy; returns final state + sums."""
    b, n = q.shape
    offered = np.zeros((b, n))
    served_sum = np.zeros((b, n))
    dropped = np.zeros((b, n))
    ext_adm = np.zeros(b)
    ext_off = np.zeros(b)
    q_int = np.zeros((b, n))
    q_max = np.zeros((b, n))
    for t in range(ext_chunk.shape[0]):
        ext_t = ext_chunk[t]
        served = np.minimum(q, cap_serve_dt)
        q1 = q - served
        routed = np.einsum("bi,bij->bj", served_prev, routing)
        inflow = ext_t + routed
        space = np.maximum(cap_queue - q1, 0.0)
        admitted = np.minimum(inflow, space)
        drop_t = inflow - admitted
        q = q1 + admitted
        with np.errstate(divide="ignore", invalid="ignore"):
            adm_frac = np.where(inflow > 0, admitted / np.maximum(inflow, 1e-300), 1.0)
        w = warm[t]
        offered += w * inflow
        served_sum += w * served
        dropped += w * drop_t
        ext_adm += w * (ext_t * adm_frac).sum(axis=-1)
        ext_off += w * ext_t.sum(axis=-1)
        q_int += w * q
        q_max = np.maximum(q_max, q)
        served_prev = served
    return q, served_prev, offered, served_sum, dropped, ext_adm, ext_off, q_int, q_max


# --------------------------------------------------------------------------- #
# jax path (lax.scan; per-step update through kernels/queue_step)
# --------------------------------------------------------------------------- #
_JIT_CACHE: dict = {}


def window_step_fn(*, interpret: bool = False, force_kernel: bool = False):
    """The batch simulator's window step in controller-consumable form.

    Returns ``window(q, served_prev, ext_chunk, warm, cap_serve_dt,
    cap_queue, routing)`` — a pure, traceable function advancing a whole
    control window (one lax.scan over the chunk's steps, each step's
    bounded-queue update dispatching through ``kernels/queue_step``) that
    the fused control loop (core/controller.py ``make_fused_loop``) scans
    *again* across ticks.  It carries **dual accumulators**: the ungated
    window sums (the §13 measurement surface a synthetic snapshot is made
    of) and the ``warm``-weighted sums (the whole-run post-warmup
    aggregates), so one pass serves both consumers.

    Output tuple (15): ``q, served_prev`` (state), then ungated
    ``offered, served, dropped, ext_admitted, ext_offered, q_int, q_max``
    ([B, N] / [B]), then warm-gated ``offered, served, dropped,
    ext_admitted, ext_offered, q_int``.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.queue_step import ops as qs_ops

    def window(q, served_prev, ext_chunk, warm, cap_serve_dt, cap_queue, routing):
        b, n = q.shape
        capq_flat = cap_queue.reshape(-1)
        caps_flat = cap_serve_dt.reshape(-1)

        def step(carry, xs):
            (q, served_prev, offered, served_sum, dropped, ext_adm, ext_off,
             q_int, q_max, w_off, w_srv, w_drop, w_ea, w_eo, w_qi) = carry
            ext_t, w = xs
            routed = jnp.einsum("bi,bij->bj", served_prev, routing)
            inflow = ext_t + routed
            q_next_f, served_f, drop_f = qs_ops.queue_step(
                q.reshape(-1), inflow.reshape(-1), caps_flat, capq_flat,
                interpret=interpret, force_kernel=force_kernel,
            )
            q_next = q_next_f.reshape(b, n).astype(q.dtype)
            served = served_f.reshape(b, n).astype(q.dtype)
            drop_t = drop_f.reshape(b, n).astype(q.dtype)
            admitted = inflow - drop_t
            adm_frac = jnp.where(inflow > 0, admitted / jnp.maximum(inflow, 1e-300), 1.0)
            ext_adm_t = (ext_t * adm_frac).sum(axis=-1)
            ext_off_t = ext_t.sum(axis=-1)
            carry = (
                q_next,
                served,
                offered + inflow,
                served_sum + served,
                dropped + drop_t,
                ext_adm + ext_adm_t,
                ext_off + ext_off_t,
                q_int + q_next,
                jnp.maximum(q_max, q_next),
                w_off + w * inflow,
                w_srv + w * served,
                w_drop + w * drop_t,
                w_ea + w * ext_adm_t,
                w_eo + w * ext_off_t,
                w_qi + w * q_next,
            )
            return carry, None

        zeros = jnp.zeros_like(q)
        zb = jnp.zeros(b, q.dtype)
        init = (q, served_prev, zeros, zeros, zeros, zb, zb, zeros, zeros,
                zeros, zeros, zeros, zb, zb, zeros)
        out, _ = jax.lax.scan(step, init, (ext_chunk, warm))
        return out

    return window


def _jax_window_fn(interpret: bool, force_kernel: bool):
    """BatchQueueSim's window view: the warm-weighted accumulator set of
    :func:`window_step_fn` (plus the unweighted peak backlog)."""
    dual = window_step_fn(interpret=interpret, force_kernel=force_kernel)

    def window(q, served_prev, ext_chunk, warm, cap_serve_dt, cap_queue, routing):
        (q1, sp1, _off, _srv, _drop, _ea, _eo, _qi, q_max,
         w_off, w_srv, w_drop, w_ea, w_eo, w_qi) = dual(
            q, served_prev, ext_chunk, warm, cap_serve_dt, cap_queue, routing
        )
        return (q1, sp1, w_off, w_srv, w_drop, w_ea, w_eo, w_qi, q_max)

    return window


class BatchQueueSim:
    """Stateful batch simulator: B scenarios advanced window by window.

    ``step_window(k, n_steps)`` advances every scenario under (per-
    scenario) allocation ``k`` and returns that window's aggregates — the
    measurement surface ``ScenarioRunner`` turns into synthetic
    :class:`~repro.core.measurer.MeasurementSnapshot`s.  ``run(k)`` is the
    one-shot whole-horizon convenience.
    """

    def __init__(
        self,
        arrays: BatchArrays,
        *,
        backend: str = "numpy",
        interpret: bool = False,
        force_kernel: bool = False,
    ):
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown backend {backend!r}; expected numpy|jax")
        self.arrays = arrays
        self.backend = backend
        self._t = 0  # next step index
        b, n = arrays.batch, arrays.n
        self.q = np.zeros((b, n))
        self._served_prev = np.zeros((b, n))
        # Post-warmup whole-run accumulators (run() / finalize view):
        self._offered = np.zeros((b, n))
        self._served = np.zeros((b, n))
        self._dropped = np.zeros((b, n))
        self._ext_adm = np.zeros(b)
        self._ext_off = np.zeros(b)
        self._q_int = np.zeros((b, n))
        self._q_max = np.zeros((b, n))
        if backend == "jax":
            import jax

            key = (interpret, force_kernel)
            if key not in _JIT_CACHE:  # share traces across sim instances
                _JIT_CACHE[key] = jax.jit(_jax_window_fn(interpret, force_kernel))
            self._window_jit = _JIT_CACHE[key]

    @property
    def now(self) -> float:
        return self._t * self.arrays.dt

    @property
    def step_index(self) -> int:
        """Next step to simulate (== arrays.steps once exhausted)."""
        return self._t

    def capacity(self, k) -> np.ndarray:
        a = self.arrays
        return service_capacity(k, a.mu, a.group, a.alpha, a.speed)

    # ------------------------------------------------------------------ #
    def step_window(self, k, n_steps: int | None = None) -> dict:
        """Advance ``n_steps`` (default: to the horizon) under allocation
        ``k`` ([B, N] ints).  Returns this window's aggregates (offered /
        served / dropped tuples per op, admitted external tuples, backlog
        integral) as plain numpy arrays — *without* the warmup gate, so
        the caller can measure any window; the whole-run accumulators
        apply the warmup mask themselves."""
        a = self.arrays
        if n_steps is None:
            n_steps = a.steps - self._t
        n_steps = min(n_steps, a.steps - self._t)
        if n_steps <= 0:
            raise ValueError("simulation horizon exhausted")
        t0, t1 = self._t, self._t + n_steps
        ext_chunk = a.ext[t0:t1]
        warm_run = (np.arange(t0, t1) >= a.warmup_steps).astype(np.float64)
        ones = np.ones(n_steps)
        cap_serve_dt = self.capacity(k) * a.dt
        if self.backend == "jax":
            import jax.numpy as jnp

            out = self._window_jit(
                jnp.asarray(self.q), jnp.asarray(self._served_prev),
                jnp.asarray(ext_chunk), jnp.asarray(ones),
                jnp.asarray(cap_serve_dt), jnp.asarray(a.cap_queue),
                jnp.asarray(a.routing),
            )
            (q, served_prev, offered, served_sum, dropped,
             ext_adm, ext_off, q_int, q_max) = (np.asarray(x, dtype=np.float64) for x in out)
        else:
            (q, served_prev, offered, served_sum, dropped,
             ext_adm, ext_off, q_int, q_max) = _np_window(
                self.q, self._served_prev, ext_chunk, ones,
                cap_serve_dt, a.cap_queue, a.routing,
            )
        # Whole-run accumulators are warmup-gated; a window that straddles
        # the warmup boundary is re-run on the gated mask (numpy, cheap)
        # only when the gate actually differs.
        if warm_run.all():
            self._offered += offered
            self._served += served_sum
            self._dropped += dropped
            self._ext_adm += ext_adm
            self._ext_off += ext_off
            self._q_int += q_int
        elif warm_run.any():
            (_q2, _sp2, off_w, srv_w, drop_w, ea_w, eo_w, qi_w, _qm2) = _np_window(
                self.q, self._served_prev, ext_chunk, warm_run,
                cap_serve_dt, a.cap_queue, a.routing,
            )
            self._offered += off_w
            self._served += srv_w
            self._dropped += drop_w
            self._ext_adm += ea_w
            self._ext_off += eo_w
            self._q_int += qi_w
        self._q_max = np.maximum(self._q_max, q_max)
        self.q = q
        self._served_prev = served_prev
        self._t = t1
        span = n_steps * a.dt
        return {
            "t0": t0 * a.dt,
            "t1": t1 * a.dt,
            "span": span,
            "offered": offered,
            "served": served_sum,
            "dropped": dropped,
            "ext_admitted": ext_adm,
            "ext_offered": ext_off,
            "q_mean": q_int / max(n_steps, 1),
            "q_final": q,
            "capacity": cap_serve_dt / a.dt,
        }

    def result(self) -> BatchSimResult:
        """Whole-run (post-warmup) aggregates so far."""
        a = self.arrays
        warm_steps = max(min(self._t, a.steps) - a.warmup_steps, 0)
        span = warm_steps * a.dt
        return BatchSimResult(
            offered=self._offered.copy(),
            served=self._served.copy(),
            dropped=self._dropped.copy(),
            ext_admitted=self._ext_adm.copy(),
            ext_offered=self._ext_off.copy(),
            q_final=self.q.copy(),
            q_mean=self._q_int / max(warm_steps, 1),
            max_backlog=self._q_max.copy(),
            span=span,
            dt=a.dt,
        )

    def run(self, k) -> BatchSimResult:
        """Advance to the horizon under a fixed allocation and aggregate."""
        self.step_window(k)
        return self.result()
