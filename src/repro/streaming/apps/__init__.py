"""The paper's two benchmark applications: VLD (SS V-A) and FPD (SS V-A)."""
