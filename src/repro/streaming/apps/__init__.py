"""The paper's two benchmark applications: VLD (SS V-A) and FPD (SS V-A).

Each exposes a ``build_*_graph`` constructor returning a declarative
:class:`repro.api.AppGraph` (the preferred surface) alongside the raw
``build_*_operators`` engine wiring.
"""

from .fpd import FPDConfig, build_fpd_graph, build_fpd_operators
from .vld import VLDConfig, build_vld_graph, build_vld_operators

__all__ = [
    "FPDConfig", "build_fpd_graph", "build_fpd_operators",
    "VLDConfig", "build_vld_graph", "build_vld_operators",
]
