"""Video logo detection (VLD) — the paper's first application (§V-A).

Topology (paper Fig. 4): spout -> SIFT feature extractor -> feature
matcher -> matching aggregator.

We implement a faithful, fully-JAX analogue:

* **spout**: synthetic video frames (H x W grayscale) with a known logo
  patch blended in at a random location for a controllable fraction of
  frames; frame rate follows the paper's uniform [1, 25] fps.
* **extractor**: scale-space feature extraction — Gaussian pyramid,
  difference-of-Gaussians response, local-maxima keypoints, and an
  8x8-patch descriptor per keypoint (a compact stand-in for full SIFT:
  same convolution-heavy cost profile, deterministic and testable).  The
  number of keypoints per frame varies with content, which is exactly the
  data-dependent fan-out DRS must track (paper §I).
* **matcher**: pairwise L2 distances between frame descriptors and the
  pre-generated logo descriptor library — the compute hot spot; runs on
  the MXU through the ``l2_match`` Pallas kernel (kernels/l2_match.py),
  with a jnp fallback on CPU.
* **aggregator**: per-(frame, logo) match counting + thresholding.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels.l2_match import ops as l2_ops

__all__ = ["VLDConfig", "make_frame", "extract_features", "match_features",
           "aggregate_matches", "build_vld_operators", "build_vld_graph",
           "logo_library"]


@dataclass(frozen=True)
class VLDConfig:
    height: int = 64
    width: int = 64
    patch: int = 8  # descriptor patch size
    max_keypoints: int = 32  # fixed upper bound (padded; JAX static shapes)
    n_logos: int = 16  # paper: 16 query logos
    descriptors_per_logo: int = 8
    match_threshold: float = 0.8  # L2 threshold on unit descriptors (logo
    # keypoints land ~0.5 from library entries after blend+noise+blur;
    # background minima sit ~1.07 — see tests)
    detect_threshold: int = 2  # matched features needed to declare a logo
    dog_sigma1: float = 1.0
    dog_sigma2: float = 2.0
    response_floor: float = 0.08  # only content blobs pass; noise DoG ~0.04 p90


def _gaussian_kernel(sigma: float, radius: int) -> jnp.ndarray:
    x = jnp.arange(-radius, radius + 1, dtype=jnp.float32)
    k = jnp.exp(-(x**2) / (2 * sigma**2))
    return k / k.sum()


def _blur(img: jnp.ndarray, sigma: float) -> jnp.ndarray:
    radius = int(3 * sigma + 0.5)
    k = _gaussian_kernel(sigma, radius)
    # Separable Gaussian: 1-D convolve along rows, then columns.
    out = jax.vmap(lambda row: jnp.convolve(row, k, mode="same"))(img)
    out = jax.vmap(lambda col: jnp.convolve(col, k, mode="same"))(out.T).T
    return out


@functools.partial(jax.jit, static_argnames=("cfg",))
def extract_features(frame: jnp.ndarray, cfg: VLDConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """DoG keypoints + patch descriptors.

    Returns (descriptors [max_keypoints, patch*patch], valid mask
    [max_keypoints]).  Padded to a static shape; ``valid`` marks real
    keypoints (response above floor).
    """
    g1 = _blur(frame, cfg.dog_sigma1)
    g2 = _blur(frame, cfg.dog_sigma2)
    dog = jnp.abs(g1 - g2)
    # Local maxima on a 3x3 neighbourhood (border excluded).
    pad = jnp.pad(dog, 1, constant_values=jnp.inf)
    neigh = jnp.stack(
        [
            pad[1 + dy : 1 + dy + dog.shape[0], 1 + dx : 1 + dx + dog.shape[1]]
            for dy in (-1, 0, 1)
            for dx in (-1, 0, 1)
            if not (dy == 0 and dx == 0)
        ]
    )
    is_max = (dog >= neigh.max(axis=0)) & (dog > cfg.response_floor)
    # Exclude borders where descriptor patches would clip.
    half = cfg.patch // 2
    border = jnp.zeros_like(is_max)
    border = border.at[half:-half, half:-half].set(True)
    score = jnp.where(is_max & border, dog, -jnp.inf)
    flat_idx = jnp.argsort(score.ravel())[::-1][: cfg.max_keypoints]
    ys, xs = jnp.unravel_index(flat_idx, score.shape)
    valid = score.ravel()[flat_idx] > -jnp.inf

    def patch_at(y, x):
        p = jax.lax.dynamic_slice(frame, (y - half, x - half), (cfg.patch, cfg.patch))
        v = p.ravel()
        v = v - v.mean()
        return v / (jnp.linalg.norm(v) + 1e-6)

    desc = jax.vmap(patch_at)(ys, xs)
    return desc.astype(jnp.float32), valid


def logo_library(cfg: VLDConfig, seed: int = 7) -> jnp.ndarray:
    """Pre-generated logo descriptor library [n_logos * dpl, D] (unit norm)."""
    rng = np.random.default_rng(seed)
    d = cfg.patch * cfg.patch
    lib = rng.normal(size=(cfg.n_logos * cfg.descriptors_per_logo, d)).astype(np.float32)
    lib -= lib.mean(axis=1, keepdims=True)
    lib /= np.linalg.norm(lib, axis=1, keepdims=True) + 1e-6
    return jnp.asarray(lib)


def make_frame(
    cfg: VLDConfig, rng: np.random.Generator, library: np.ndarray, with_logo: bool
) -> np.ndarray:
    """Synthetic frame; optionally blends logo descriptor patches in."""
    frame = rng.normal(scale=0.08, size=(cfg.height, cfg.width)).astype(np.float32)
    # Sprinkle generic blobs (keypoint fodder whose count varies per frame).
    n_blobs = rng.integers(2, 14)
    for _ in range(n_blobs):
        y = rng.integers(cfg.patch, cfg.height - cfg.patch)
        x = rng.integers(cfg.patch, cfg.width - cfg.patch)
        frame[y - 1 : y + 2, x - 1 : x + 2] += rng.uniform(0.5, 1.0)
    if with_logo:
        logo_id = rng.integers(cfg.n_logos)
        for j in range(cfg.descriptors_per_logo):
            d = np.asarray(library[logo_id * cfg.descriptors_per_logo + j])
            patch = d.reshape(cfg.patch, cfg.patch)
            y = rng.integers(cfg.patch, cfg.height - 2 * cfg.patch)
            x = rng.integers(cfg.patch, cfg.width - 2 * cfg.patch)
            frame[y : y + cfg.patch, x : x + cfg.patch] += patch * 2.0
            frame[y + cfg.patch // 2, x + cfg.patch // 2] += 1.0  # strong response
    return frame


@functools.partial(jax.jit, static_argnames=("threshold",))
def match_features(
    desc: jnp.ndarray, valid: jnp.ndarray, library: jnp.ndarray, threshold: float
) -> jnp.ndarray:
    """Count library descriptors within L2 `threshold` of each frame
    descriptor, per library row — the matcher bolt's inner loop.

    Returns match_counts [n_library_rows] (int32).  Dispatches to the
    FUSED l2_match kernel (distance + threshold + count accumulated in
    VMEM, the [K, L] distance matrix never hits HBM) on TPU; jnp oracle
    on CPU.
    """
    return l2_ops.match_count(desc, library, threshold, valid)


@functools.partial(jax.jit, static_argnames=("n_logos", "dpl", "detect_threshold"))
def aggregate_matches(
    match_counts: jnp.ndarray, n_logos: int, dpl: int, detect_threshold: int
) -> jnp.ndarray:
    """Fold per-descriptor matches to per-logo detections (aggregator bolt)."""
    per_logo = match_counts.reshape(n_logos, dpl).sum(axis=1)
    return per_logo >= detect_threshold


def build_vld_operators(cfg: VLDConfig, library: jnp.ndarray):
    """Operators for the StreamEngine: extract -> match -> aggregate.

    Payloads: frame (H,W) -> (desc, valid) -> match_counts -> detections.
    """
    from ..engine import Operator

    detections: list[np.ndarray] = []

    def extract_fn(frame):
        desc, valid = extract_features(jnp.asarray(frame), cfg)
        return [("match", (desc, valid))]

    def match_fn(payload):
        desc, valid = payload
        counts = match_features(desc, valid, library, cfg.match_threshold)
        return [("aggregate", counts)]

    def aggregate_fn(counts):
        det = aggregate_matches(
            counts, cfg.n_logos, cfg.descriptors_per_logo, cfg.detect_threshold
        )
        detections.append(np.asarray(det))
        return []

    ops = [
        Operator("extract", extract_fn),
        Operator("match", match_fn),
        Operator("aggregate", aggregate_fn),
    ]
    return ops, detections


def build_vld_graph(
    cfg: VLDConfig,
    library: jnp.ndarray,
    *,
    fps: float = 13.0,
    mus: tuple[float, float, float] = (2.0, 5.0, 50.0),
):
    """The VLD application as a declarative :class:`~repro.api.AppGraph`.

    The chain extract -> match -> aggregate with the frame stream entering
    at the extractor; ``mus`` are the paper-§V-B-scale service-rate priors
    (the measurer corrects them online).  Returns ``(graph, detections)``
    where ``detections`` collects the aggregator's per-frame outputs.
    """
    from ...api import AppGraph, Edge, OpDef

    ops, detections = build_vld_operators(cfg, library)
    graph = AppGraph(
        [OpDef(op.name, mu=mu, fn=op.fn) for op, mu in zip(ops, mus)],
        [Edge("extract", "match"), Edge("match", "aggregate")],
        {"extract": fps},
        arrival_kind="uniform",  # the paper's uniform [1, 25] fps
    )
    return graph, detections
