"""Frequent pattern detection (FPD) — the paper's second application (§V-A).

Topology (paper Fig. 5): two spouts (window-enter "+" and window-leave "-")
-> pattern generator -> detector (with a SELF-LOOP for cross-instance
state-change notifications) -> reporter.

Implementation: transactions are itemsets over a vocabulary of
``n_items <= 32`` items, packed into a uint32 **bitmask**.  A pattern
(itemset) P is contained in transaction T iff ``P & T == P`` — support
counting over the sliding window is a vectorised AND+compare in JAX.  A
**maximal frequent pattern** (MFP, paper's definition) is a pattern whose
occurrence count >= threshold while every superset's count < threshold.

The detector's self-loop is semantically faithful: when a pattern's MFP
state flips, a notification tuple is re-injected into the detector (the
paper uses this to propagate state changes across the detector's sharded
instances); the loop leaks — notifications do not spawn further
notifications — so the Jackson stability condition holds.
"""

from __future__ import annotations

import functools
import itertools
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FPDConfig",
    "pack_itemset",
    "candidate_patterns",
    "support_counts",
    "maximal_frequent",
    "SlidingWindowState",
    "build_fpd_operators",
    "build_fpd_graph",
]


@dataclass(frozen=True)
class FPDConfig:
    n_items: int = 16  # vocabulary (<= 32 for uint32 packing)
    max_pattern_size: int = 3  # candidate itemsets up to this many items
    window: int = 512  # sliding window size in transactions (paper: 50000)
    support_threshold: int = 32  # occurrence count for "frequent"
    items_per_txn_lo: int = 2
    items_per_txn_hi: int = 6


def pack_itemset(items: list[int] | tuple[int, ...]) -> int:
    mask = 0
    for it in items:
        mask |= 1 << it
    return mask


@functools.lru_cache(maxsize=8)
def _all_patterns(n_items: int, max_size: int) -> np.ndarray:
    """All candidate patterns (bitmasks) of size 1..max_size, sorted."""
    pats = []
    for size in range(1, max_size + 1):
        for combo in itertools.combinations(range(n_items), size):
            pats.append(pack_itemset(combo))
    return np.asarray(sorted(pats), dtype=np.uint32)


def candidate_patterns(transaction_mask: int, cfg: FPDConfig) -> np.ndarray:
    """Patterns generated from one transaction: all sub-itemsets up to
    max_pattern_size (the paper's pattern-generator bolt; 'exponential
    number of possible non-empty combinations')."""
    items = [i for i in range(cfg.n_items) if transaction_mask >> i & 1]
    pats = []
    for size in range(1, min(cfg.max_pattern_size, len(items)) + 1):
        for combo in itertools.combinations(items, size):
            pats.append(pack_itemset(combo))
    return np.asarray(pats, dtype=np.uint32)


@jax.jit
def support_counts(patterns: jnp.ndarray, window_masks: jnp.ndarray) -> jnp.ndarray:
    """Occurrence count of each pattern in the window.

    patterns: uint32 [P]; window_masks: uint32 [W] -> int32 [P].
    P is contained in T iff P & T == P.
    """
    contained = (window_masks[None, :] & patterns[:, None]) == patterns[:, None]
    return contained.sum(axis=1).astype(jnp.int32)


@jax.jit
def _superset_matrix(patterns: jnp.ndarray) -> jnp.ndarray:
    """is_superset[i, j] = True iff pattern j is a strict superset of i."""
    sub = (patterns[None, :] & patterns[:, None]) == patterns[:, None]
    return sub & (patterns[None, :] != patterns[:, None])


@jax.jit
def maximal_frequent(
    patterns: jnp.ndarray, counts: jnp.ndarray, threshold: jnp.ndarray
) -> jnp.ndarray:
    """MFP mask: frequent and no frequent strict superset (paper's (a)+(b))."""
    frequent = counts >= threshold
    sup = _superset_matrix(patterns)
    has_freq_superset = (sup & frequent[None, :]).any(axis=1)
    return frequent & ~has_freq_superset


@dataclass
class SlidingWindowState:
    """Detector state: window contents + per-pattern counts + MFP flags."""

    cfg: FPDConfig
    patterns: np.ndarray = field(default=None)
    window: deque = field(default_factory=deque)
    counts: np.ndarray = field(default=None)
    mfp: np.ndarray = field(default=None)

    def __post_init__(self):
        if self.patterns is None:
            self.patterns = _all_patterns(self.cfg.n_items, self.cfg.max_pattern_size)
        if self.counts is None:
            self.counts = np.zeros(len(self.patterns), dtype=np.int64)
        if self.mfp is None:
            self.mfp = np.zeros(len(self.patterns), dtype=bool)

    def _delta(self, mask: int, sign: int) -> None:
        contained = (self.patterns & np.uint32(mask)) == self.patterns
        self.counts[contained] += sign

    def apply(self, mask: int, entering: bool) -> list[int]:
        """Apply a +/- event; returns indices of patterns whose MFP state
        changed (these become self-loop notifications)."""
        if entering:
            self.window.append(mask)
            self._delta(mask, +1)
            evicted = None
            if len(self.window) > self.cfg.window:
                evicted = self.window.popleft()
                self._delta(evicted, -1)
        else:
            if self.window:
                try:
                    self.window.remove(mask)
                    self._delta(mask, -1)
                except ValueError:
                    pass
        new_mfp = np.asarray(
            maximal_frequent(
                jnp.asarray(self.patterns),
                jnp.asarray(self.counts.astype(np.int32)),
                jnp.int32(self.cfg.support_threshold),
            )
        )
        changed = np.nonzero(new_mfp != self.mfp)[0]
        self.mfp = new_mfp
        return changed.tolist()

    def current_mfps(self) -> np.ndarray:
        return self.patterns[self.mfp]


def random_transaction(cfg: FPDConfig, rng: np.random.Generator) -> int:
    """Skewed item popularity (Zipf-ish) so real frequent patterns emerge."""
    n = rng.integers(cfg.items_per_txn_lo, cfg.items_per_txn_hi + 1)
    probs = 1.0 / np.arange(1, cfg.n_items + 1)
    probs /= probs.sum()
    items = rng.choice(cfg.n_items, size=min(n, cfg.n_items), replace=False, p=probs)
    return pack_itemset(items.tolist())


def build_fpd_operators(cfg: FPDConfig):
    """Operators for the StreamEngine: generate -> detect (self-loop) -> report.

    Payloads: (mask, entering) -> ("pattern-event", ...) -> notifications.
    """
    from ..engine import Operator

    state = SlidingWindowState(cfg)
    reports: list[tuple[int, bool]] = []
    state_lock = __import__("threading").Lock()

    def generate_fn(payload):
        mask, entering = payload
        # The generator bolt expands candidates (cost ~ 2^|txn|); the
        # expansion result is folded into the event for the detector.
        cands = candidate_patterns(mask, cfg)
        return [("detect", (mask, entering, cands))]

    def detect_fn(payload):
        if payload[0] == "notify":
            # Self-loop notification: cross-instance state sync. Leaks (no
            # further emissions) — Jackson stability.
            return []
        mask, entering, _cands = payload
        with state_lock:
            changed = state.apply(mask, entering)
        out = [("report", (int(i), bool(state.mfp[i]))) for i in changed]
        out += [("detect", ("notify", int(i))) for i in changed]
        return out

    def report_fn(payload):
        reports.append(payload)
        return []

    ops = [
        Operator("generate", generate_fn),
        Operator("detect", detect_fn),
        Operator("report", report_fn),
    ]
    return ops, state, reports


def build_fpd_graph(
    cfg: FPDConfig,
    *,
    rate: float = 16.0,
    loop_p: float = 0.3,
    mus: tuple[float, float, float] = (4.0, 3.0, 12.0),
):
    """The FPD application as a declarative :class:`~repro.api.AppGraph`.

    generate -> detect -> report with the detector's leaking SELF-LOOP
    declared as a typed edge (``detect -> detect`` at expected multiplicity
    ``loop_p`` — the mean rate of MFP state-change notifications per
    event).  The loop leaks (``loop_p < 1``), so the graph's construction-
    time stability check passes; a non-leaking declaration would raise.
    Returns ``(graph, state, reports)``.
    """
    from ...api import AppGraph, Edge, OpDef

    ops, state, reports = build_fpd_operators(cfg)
    graph = AppGraph(
        [OpDef(op.name, mu=mu, fn=op.fn) for op, mu in zip(ops, mus)],
        [
            Edge("generate", "detect"),
            Edge("detect", "detect", multiplicity=loop_p),
            Edge("detect", "report", multiplicity=1.0 - loop_p),
        ],
        {"generate": rate},
    )
    return graph, state, reports
