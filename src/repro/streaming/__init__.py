"""Streaming substrate: DES model-validation simulator + live JAX engine.

Declare topologies with :mod:`repro.api` (``AppGraph.bind("engine")`` /
``bind("des")``) rather than wiring these primitives by hand — the classes
here stay importable as the backend layer.
"""

from .batchsim import BatchArrays, BatchQueueSim, BatchSimResult
from .des import (
    ArrivalProcess,
    NetworkSimulator,
    ServiceProcess,
    SimConfig,
    SimResult,
    simulate_allocation,
)
from .engine import Operator, StreamEngine, StreamTuple
from .overload import OVERLOAD_POLICIES, OverloadPolicy
from .scenarios import (
    ArrivalTrace,
    Scenario,
    fpd_scenario,
    pack_scenarios,
    random_appgraph,
    scenario_matrix,
    vld_scenario,
)

__all__ = [
    "ArrivalProcess",
    "NetworkSimulator",
    "ServiceProcess",
    "SimConfig",
    "SimResult",
    "simulate_allocation",
    "Operator",
    "StreamEngine",
    "StreamTuple",
    "OverloadPolicy",
    "OVERLOAD_POLICIES",
    "ArrivalTrace",
    "Scenario",
    "BatchArrays",
    "BatchQueueSim",
    "BatchSimResult",
    "pack_scenarios",
    "random_appgraph",
    "scenario_matrix",
    "vld_scenario",
    "fpd_scenario",
]
