"""Streaming substrate: DES model-validation simulator + live JAX engine.

Declare topologies with :mod:`repro.api` (``AppGraph.bind("engine")`` /
``bind("des")``) rather than wiring these primitives by hand — the classes
here stay importable as the backend layer.
"""

from .des import (
    ArrivalProcess,
    NetworkSimulator,
    ServiceProcess,
    SimConfig,
    SimResult,
    simulate_allocation,
)
from .engine import Operator, StreamEngine, StreamTuple
from .overload import OVERLOAD_POLICIES, OverloadPolicy

__all__ = [
    "ArrivalProcess",
    "NetworkSimulator",
    "ServiceProcess",
    "SimConfig",
    "SimResult",
    "simulate_allocation",
    "Operator",
    "StreamEngine",
    "StreamTuple",
    "OverloadPolicy",
    "OVERLOAD_POLICIES",
]
