"""Streaming substrate: DES model-validation simulator + live JAX engine."""

from .des import (
    ArrivalProcess,
    NetworkSimulator,
    ServiceProcess,
    SimConfig,
    SimResult,
    simulate_allocation,
)
from .engine import Operator, StreamEngine, StreamTuple

__all__ = [
    "ArrivalProcess",
    "NetworkSimulator",
    "ServiceProcess",
    "SimConfig",
    "SimResult",
    "simulate_allocation",
    "Operator",
    "StreamEngine",
    "StreamTuple",
]
