"""Overload policies for bounded operator queues (DESIGN.md §11).

The paper's premise is surviving unpredictable rate fluctuation (§I,
Fig. 9/10), which means the runtime must have *defined* behaviour when the
offered load exceeds capacity.  Both backends (the live ``StreamEngine``
and the DES ``NetworkSimulator``) bound their per-operator queues and
apply one of three policies when a queue is full:

``block``
    The producer waits for space — backpressure propagates upstream all
    the way to :meth:`~repro.streaming.engine.StreamEngine.inject`
    (lossless; latency is pushed into the source).  In the DES this is
    modelled by holding arrivals in a per-operator pending line that is
    admitted FIFO as queue slots free up.  On cyclic graphs at capacity,
    blocking can livelock the live engine's workers; prefer a shed policy
    for topologies with self-loops.
``shed-newest``
    The arriving tuple is dropped (tail drop).  Cheapest, favours tuples
    already in flight.
``shed-oldest``
    The oldest queued tuple is evicted to admit the new one (head drop —
    fresher data wins, the usual choice for real-time analytics).

Every shed tuple is recorded against the operator that shed it (visible to
the model via :meth:`~repro.core.measurer.InstanceProbe.on_dropped` and
per-op drop counters in ``SimResult``), and poisons its root: an external
tuple whose processing tree lost any member counts as *shed*, not
*completed*, so measured sojourn stays unbiased.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["OverloadPolicy", "OVERLOAD_POLICIES"]

OVERLOAD_POLICIES = ("block", "shed-newest", "shed-oldest")


@dataclass(frozen=True)
class OverloadPolicy:
    """What to do when a bounded operator queue is full.

    ``kind`` is one of :data:`OVERLOAD_POLICIES`.  ``block_poll`` is the
    live engine's wait granularity while blocked (it also bounds how long
    a worker can stall past an engine stop request).
    """

    kind: str = "block"
    block_poll: float = 0.05

    def __post_init__(self) -> None:
        if self.kind not in OVERLOAD_POLICIES:
            raise ValueError(
                f"unknown overload policy {self.kind!r}; "
                f"expected one of {OVERLOAD_POLICIES}"
            )

    @classmethod
    def coerce(cls, value: "OverloadPolicy | str") -> "OverloadPolicy":
        """Accept either a policy object or its kind string."""
        if isinstance(value, OverloadPolicy):
            return value
        return cls(kind=value)

    @property
    def blocks(self) -> bool:
        return self.kind == "block"

    @property
    def sheds(self) -> bool:
        return self.kind in ("shed-newest", "shed-oldest")
