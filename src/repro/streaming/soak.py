"""Day-scale checkpointed soak harness (DESIGN.md §17).

The fused control plane advertises two durability properties that short
CI scenarios never stress together:

* the ``lax.scan`` carry (:class:`~repro.core.controller.ControllerState`)
  is **resumable** — a checkpoint -> restore -> resume sequence through
  :class:`~repro.checkpoint.store.CheckpointStore` must be bit-identical
  to the straight-through run, and
* the loop survives a **day** of composite load (diurnal baseline, flash
  crowds, an MMPP bursty stretch) without the measurement or decide
  surfaces drifting.

This module builds that day as ONE deterministic ``kind="replay"``
:class:`ArrivalTrace` stitched from the trace zoo, wires it through the
same :class:`~repro.api.session.ScenarioRunner` packing the CI matrix
uses, and drives :func:`~repro.core.controller.make_fused_loop` either
straight through (:func:`run_straight`) or in checkpoint_every-tick
chunks with a simulated crash + restore between every chunk
(:func:`run_checkpointed`).  ``tests/test_soak.py`` asserts the two are
bit-identical — decisions, allocations, and the full trajectory — for
reactive and proactive loops, unsharded and mesh-sharded.

Nothing here samples fresh randomness at run time: the trace, the
pre-sampled arrival counts, and the controller are all pinned to the
:class:`SoakConfig` seed, which is what makes "bit-identical" a
meaningful assertion rather than a tolerance.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import numpy as np

__all__ = [
    "DIAGNOSTIC_KEYS",
    "SoakConfig",
    "SoakReport",
    "assert_bit_identical",
    "build_scenario",
    "composite_day_samples",
    "run_checkpointed",
    "run_straight",
    "soak_report",
]

DAY = 86400.0

#: stitched-output keys stacked per control window (concatenated across
#: resume chunks) vs accumulated in the carry (last chunk == whole run).
PER_TICK_KEYS = ("codes", "k", "sojourn", "et_cur", "et_target", "applied")
SUMMED_KEYS = ("miss", "warm_windows")
AGGREGATE_KEYS = (
    "k_final", "q_final", "offered", "served", "dropped",
    "ext_admitted", "ext_offered", "q_int", "q_max",
)
#: diagnostics that are NOT part of the bit-identity contract.  The §18
#: compaction trigger mask (``repriced``) depends on where the decide
#: cache went cold — the cache lives outside the checkpointed carry (so
#: checkpoints stay layout-independent), which means every resume chunk
#: starts cold and reprices densely on its first tick.  Decisions are
#: unchanged (a cold reprice of a quiet lane reproduces the cached row
#: bit for bit); only this diagnostic reveals the chunk boundaries.
DIAGNOSTIC_KEYS = ("repriced",)


@dataclass(frozen=True)
class SoakConfig:
    """One pinned soak run.  ``day`` must divide by ``tick_interval`` and
    ``tick_interval`` by ``dt`` (the ScenarioRunner fused-path gate)."""

    day: float = DAY
    dt: float = 0.5
    tick_interval: float = 120.0
    base_rate: float = 8.0
    seed: int = 42
    # Static budget (the fused loop has no negotiator hooks, so k_total
    # can't elastically scale): pinned TIGHT — the mean needs ~11 of the
    # 14, the flash/MMPP peaks need more than 14 — so the day actually
    # exercises placement rebalances, §11 overload reallocations,
    # deadline misses, and bounded-queue shedding instead of idling at an
    # overprovisioned fixed point.
    k_max: int = 14
    queue_capacity: int = 150
    checkpoint_every: int = 96  # control windows between crash+restore cycles
    name: str = "soak-day"

    @classmethod
    def smoke(cls) -> "SoakConfig":
        """Tier-1 cap: two "hours" with the same composite shape (the
        diurnal period scales with ``day``, so every segment still
        appears), crash+restore every 16 windows."""
        return cls(day=7200.0, checkpoint_every=16, name="soak-smoke")

    @property
    def n_ticks(self) -> int:
        return int(round(self.day / self.tick_interval))


def composite_day_samples(cfg: SoakConfig, sample_dt: float = 1.0) -> np.ndarray:
    """The day's rate schedule on a ``sample_dt`` grid: a diurnal
    baseline (4 cycles across ``day``) + two flash-crowd boosts + an MMPP
    bursty stretch over the middle fifth — all from the ArrivalTrace zoo,
    so each segment's shape is the one the matrix scenarios already
    exercise individually."""
    from .scenarios import ArrivalTrace

    base, day = cfg.base_rate, cfg.day
    grid = np.arange(0.0, day, sample_dt)
    diurnal = ArrivalTrace(
        kind="diurnal", rate=base, amplitude=0.4 * base, period=day / 4.0
    ).rates(grid)
    flash = np.zeros_like(grid)
    for t_on, t_off in ((0.30 * day, 0.35 * day), (0.70 * day, 0.72 * day)):
        flash += ArrivalTrace(
            kind="flash", rate=0.0, peak=0.8 * base, t_on=t_on, t_off=t_off
        ).rates(grid)
    mmpp = ArrivalTrace(
        kind="mmpp", rate=0.0, peak=0.5 * base,
        switch01=40.0 / day, switch10=80.0 / day,
    ).rates(grid, seed=cfg.seed)
    burst_window = (grid >= 0.45 * day) & (grid < 0.65 * day)
    return np.maximum(diurnal + flash + np.where(burst_window, mmpp, 0.0), 0.0)


def build_scenario(cfg: SoakConfig):
    """The soak pipeline: ingest -> parse (with a reprocessing self-loop)
    fanning out to a chip-gang operator and a sink — every operator class
    the batch simulator models (§2 gang collapse included) under the
    composite replay trace.  ``t_max`` is pinned at 1.5x the best
    mean-rate sojourn reachable within the budget (the scenario_matrix
    convention), so the deadline-miss trajectory is meaningful."""
    from ..api import AppGraph, Edge, OpDef
    from ..core.allocator import InsufficientResourcesError, allocate
    from ..core.jackson import UnstableTopologyError
    from .scenarios import ArrivalTrace, Scenario

    graph = AppGraph(
        [
            OpDef("ingest", mu=4.0),
            OpDef("parse", mu=6.0),
            OpDef("gang", mu=3.0, scaling="group", group_alpha=0.05),
            OpDef("sink", mu=20.0),
        ],
        [
            Edge("ingest", "parse"),
            Edge("parse", "parse", multiplicity=0.2),
            Edge("parse", "gang", multiplicity=0.4),
            Edge("parse", "sink", multiplicity=0.4),
            Edge("gang", "sink"),
        ],
        {"ingest": cfg.base_rate},
    )
    trace = ArrivalTrace(
        kind="replay", samples=tuple(composite_day_samples(cfg)), sample_dt=1.0
    )
    s = Scenario(
        name=cfg.name, graph=graph, traces={"ingest": trace},
        seed=cfg.seed, horizon=cfg.day, warmup=cfg.tick_interval,
        dt=cfg.dt, k_max=cfg.k_max, queue_capacity=cfg.queue_capacity,
    )
    try:
        t_max = 1.5 * allocate(s.mean_topology(), k_max=cfg.k_max).expected_sojourn
    except (InsufficientResourcesError, UnstableTopologyError):
        t_max = None
    return replace(s, t_max=t_max)


def _runner_and_loop(
    cfg: SoakConfig, *, proactive: bool = False, mesh=None, compact=None
):
    import repro.core.controller as ctl
    from ..api.session import ScenarioRunner

    s = build_scenario(cfg)
    r = ScenarioRunner(
        [s], tick_interval=cfg.tick_interval, backend="jax",
        proactive=proactive or None, mesh=mesh, compact=compact,
    )
    loop, n_ticks = ctl.make_fused_loop(
        r.arrays, r.static, r._params(),
        steps_per_tick=r._steps_per_tick, warmup_seconds=s.warmup,
        proactive=r.proactive_cfg, mesh=mesh, compact=compact,
    )
    return r, loop, n_ticks


def _np_out(out: dict) -> dict:
    return {k: np.asarray(v) for k, v in out.items()}


def run_straight(
    cfg: SoakConfig, *, proactive: bool = False, mesh=None, compact=None
) -> dict:
    """The reference: the whole day in one ``loop(k0)`` call."""
    r, loop, _ = _runner_and_loop(cfg, proactive=proactive, mesh=mesh,
                                  compact=compact)
    return _np_out(loop(r.k))


def run_checkpointed(
    cfg: SoakConfig, directory, *, proactive: bool = False, mesh=None,
    compact=None,
) -> dict:
    """The soak: every ``checkpoint_every`` windows, ``save_async`` the
    carry, throw the runner/loop/compiled executables away (the simulated
    crash), restore from disk into a freshly built loop, and continue.

    Returns the stitched whole-run output dict — per-tick stacks
    concatenated across chunks, chunk-local counters summed, carry
    aggregates from the final chunk — plus ``n_restores``.
    """
    import repro.core.controller as ctl
    from ..checkpoint.store import CheckpointStore

    store = CheckpointStore(directory)
    r, loop, n_ticks = _runner_and_loop(cfg, proactive=proactive, mesh=mesh,
                                        compact=compact)
    state = loop.init(r.k)
    chunks: list[dict] = []
    restores = 0
    while int(state.tick) < n_ticks:
        ticks = min(cfg.checkpoint_every, n_ticks - int(state.tick))
        state, out = loop.run(state, ticks)
        chunks.append(_np_out(out))
        done = int(state.tick)
        if done >= n_ticks:
            store.save(done, state)  # final sync save: nothing left to overlap
            break
        store.save_async(done, state)
        store.wait()
        # Crash: rebuild everything from scratch, restore from disk into
        # a tick-0 template (shapes/dtypes only — the restore overwrites
        # every leaf, including the tick counter).
        del r, loop, state
        r, loop, _ = _runner_and_loop(cfg, proactive=proactive, mesh=mesh,
                                      compact=compact)
        restored, _extra = store.restore(loop.init(r.k), step=done)
        state = ctl.ControllerState(*restored)
        restores += 1

    out = {}
    for key in PER_TICK_KEYS + (("mpc_used", "confident") if proactive else ()):
        out[key] = np.concatenate([c[key] for c in chunks], axis=0)
    for key in SUMMED_KEYS:
        out[key] = np.sum([c[key] for c in chunks], axis=0)
    for key in AGGREGATE_KEYS:
        out[key] = chunks[-1][key]
    out["n_restores"] = restores
    return out


def assert_bit_identical(ref: dict, got: dict) -> None:
    """Every shared output surface equal bit for bit (exact integer and
    float equality — no tolerances).  :data:`DIAGNOSTIC_KEYS` are skipped:
    they describe *how* the run computed (e.g. which lanes the §18
    compaction actually repriced), not *what* it decided."""
    for key in sorted((set(ref) & set(got)) - set(DIAGNOSTIC_KEYS)):
        np.testing.assert_array_equal(
            np.asarray(got[key]), np.asarray(ref[key]), err_msg=key
        )


@dataclass
class SoakReport:
    """Operator-facing trajectories over the day (one scenario, B=1)."""

    t: np.ndarray  # [ticks] window end times
    k_total: np.ndarray  # [ticks] provisioned processors (the cost curve)
    sojourn: np.ndarray  # [ticks] measured mean sojourn
    miss: np.ndarray  # [ticks] bool: warm window over T_max
    deadline_miss_rate: float
    drop_rate: float
    mean_cost: float  # mean provisioned processors over warm windows
    n_restores: int = 0
    extra: dict = field(default_factory=dict)

    def summary(self) -> dict[str, Any]:
        return {
            "ticks": int(self.t.size),
            "deadline_miss_rate": float(self.deadline_miss_rate),
            "drop_rate": float(self.drop_rate),
            "mean_cost": float(self.mean_cost),
            "peak_cost": float(self.k_total.max(initial=0)),
            "n_restores": int(self.n_restores),
        }


def soak_report(cfg: SoakConfig, out: dict) -> SoakReport:
    s = build_scenario(cfg)
    n_ticks = out["codes"].shape[0]
    t = (np.arange(n_ticks) + 1) * cfg.tick_interval
    warm = (np.arange(n_ticks) * cfg.tick_interval) >= s.warmup
    sojourn = np.asarray(out["sojourn"])[:, 0]
    t_max = np.inf if s.t_max is None else s.t_max
    with np.errstate(invalid="ignore"):
        miss = (sojourn > t_max) & warm
    k_total = np.asarray(out["k"])[:, 0, : s.graph.n].sum(axis=-1)
    offered = float(np.asarray(out["offered"])[0].sum())
    dropped = float(np.asarray(out["dropped"])[0].sum())
    return SoakReport(
        t=t, k_total=k_total, sojourn=sojourn, miss=miss,
        deadline_miss_rate=float(miss.sum() / max(warm.sum(), 1)),
        drop_rate=dropped / max(offered, 1e-300),
        mean_cost=float(k_total[warm].mean()) if warm.any() else float("nan"),
        n_restores=int(out.get("n_restores", 0)),
    )
