"""Quickstart: the DRS performance model + optimal allocator in 60 lines.

Reproduces the paper's core loop on the VLD-like topology from §V:
model the operators as an M/M/k Jackson network, ask Program (4) where
processors should go, ask Program (6) how many are needed for a latency
SLO, and check both against a discrete-event simulation.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Topology, assign_processors, min_processors
from repro.streaming.des import simulate_allocation

# --- the application: spout -> extract -> match -> aggregate ----------- #
# 13 frames/sec arrive; one processor extracts 2 frames/sec, matches 5
# feature-sets/sec, aggregates 50 match-sets/sec (paper §V-B scale).
top = Topology.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)

print("traffic (lambda_i):", top.arrival_rates)
print("minimum feasible allocation:", top.min_feasible_allocation())

# --- Program (4): best placement of 22 executors ----------------------- #
best = assign_processors(top, k_max=22)
print(f"\nProgram (4) @ K=22  ->  k = {best.k.tolist()}  "
      f"E[T] = {best.expected_sojourn:.3f}s")

# compare against the neighbouring configurations from the paper's Fig. 6
for cand in ([8, 12, 2], [12, 8, 2], [7, 13, 2], best.k.tolist()):
    model_t = top.expected_sojourn(cand)
    sim = simulate_allocation(top, cand, seed=1, horizon=400.0, warmup=40.0)
    star = " <- DRS" if cand == best.k.tolist() else ""
    print(f"  {cand}: model {model_t:.3f}s | simulated {sim.mean_sojourn:.3f}s{star}")

# --- Program (6): how many executors for a 1.2s SLO? ------------------- #
need = min_processors(top, t_max=1.2)
print(f"\nProgram (6) @ T_max=1.2s  ->  {need.total} processors, "
      f"k = {need.k.tolist()}, model E[T] = {need.expected_sojourn:.3f}s")

sim = simulate_allocation(top, need.k, seed=2, horizon=400.0, warmup=40.0)
print(f"simulated E[T] under that allocation: {sim.mean_sojourn:.3f}s "
      f"(SLO {'met' if sim.mean_sojourn <= 1.2 else 'MISSED'})")
