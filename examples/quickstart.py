"""Quickstart: declare the app graph once, model + simulate through it.

Reproduces the paper's core loop on the VLD-like topology from §V: declare
the operators as an AppGraph (repro.api), ask Program (4) where processors
should go, ask Program (6) how many are needed for a latency SLO, and
check both against a discrete-event simulation — all through the SAME
graph declaration.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import AppGraph

# --- the application: spout -> extract -> match -> aggregate ----------- #
# 13 frames/sec arrive; one processor extracts 2 frames/sec, matches 5
# feature-sets/sec, aggregates 50 match-sets/sec (paper §V-B scale).
graph = AppGraph.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)
top = graph.topology()

print("traffic (lambda_i):", top.arrival_rates)
print("minimum feasible allocation:", top.min_feasible_allocation())

# --- Program (4): best placement of 22 executors ----------------------- #
session = graph.bind("des", horizon=400.0, warmup=40.0)
best = session.plan(k_max=22)
print(f"\nProgram (4) @ K=22  ->  k = {best.k.tolist()}  "
      f"E[T] = {best.expected_sojourn:.3f}s")

# compare against the neighbouring configurations from the paper's Fig. 6
for cand in ([8, 12, 2], [12, 8, 2], [7, 13, 2], best.k.tolist()):
    model_t = top.expected_sojourn(cand)
    sim = session.simulate(cand, seed=1)
    star = " <- DRS" if cand == best.k.tolist() else ""
    print(f"  {cand}: model {model_t:.3f}s | simulated {sim.mean_sojourn:.3f}s{star}")

# --- Program (6): how many executors for a 1.2s SLO? ------------------- #
need = session.plan(t_max=1.2)
print(f"\nProgram (6) @ T_max=1.2s  ->  {need.total} processors, "
      f"k = {need.k.tolist()}, model E[T] = {need.expected_sojourn:.3f}s")

sim = session.simulate(need.k, seed=2)
print(f"simulated E[T] under that allocation: {sim.mean_sojourn:.3f}s "
      f"(SLO {'met' if sim.mean_sojourn <= 1.2 else 'MISSED'})")
