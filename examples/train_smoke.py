"""End-to-end training driver: a ~small llama on CPU for a few hundred
steps, with async checkpointing, a simulated crash, and an exact resume.

This is the end-to-end fault-tolerance demo: kill the run mid-flight,
start it again, watch it resume from the checkpoint and converge to the
same trajectory (the synthetic token stream is keyed by (seed, step)).

    PYTHONPATH=src python examples/train_smoke.py
"""

import shutil
import tempfile
from pathlib import Path

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.training.loop import LoopConfig, TrainLoop
from repro.training.optimizer import AdamWConfig

ckpt_dir = Path(tempfile.mkdtemp(prefix="repro_train_"))
cfg = get_config("llama3.2-1b", "smoke")
STEPS = 300


def make_loop():
    return TrainLoop(
        cfg,
        AdamWConfig(lr=3e-3, warmup_steps=20, decay_steps=STEPS),
        LoopConfig(total_steps=STEPS, ckpt_every=50, log_every=25),
        ckpt_dir=ckpt_dir,
        data_cfg=DataConfig(vocab=cfg.vocab, batch=4, seq_len=32),
        on_metrics=lambda s, m: print(
            f"  step {s:4d}  loss {m['loss']:.4f}  ({m['step_time']*1e3:.0f} ms)"
        ),
    )


print(f"[1] training {cfg.arch} for {STEPS} steps — simulated crash at 150")
try:
    make_loop().run(crash_at=150)
except RuntimeError as e:
    print(f"    crashed as planned: {e}")

print("[2] restarting — resumes from the step-150 checkpoint")
loop = make_loop()
state = loop.run()
print(f"[3] done: final loss {loop.metrics_history[-1]['loss']:.4f} "
      f"(resumed from step {150}, finished at {int(state.step)})")
first = loop.metrics_history[0]["loss"]
last = loop.metrics_history[-1]["loss"]
print(f"    loss {first:.3f} -> {last:.3f} over the resumed segment")
shutil.rmtree(ckpt_dir, ignore_errors=True)
