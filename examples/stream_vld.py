"""The paper's VLD application end-to-end on the live JAX stream engine,
with the DRS scheduler closing the loop: measure -> model -> rebalance.

Frames flow through extract -> match -> aggregate while a deliberately
bad allocation starves the extractor; after a measurement window the
DRSScheduler recommends (and the engine applies) the optimal allocation.

    PYTHONPATH=src python examples/stream_vld.py
"""

import time

import numpy as np

from repro.core import DRSScheduler, SchedulerConfig
from repro.streaming.apps.vld import VLDConfig, build_vld_operators, logo_library, make_frame
from repro.streaming.engine import StreamEngine

cfg = VLDConfig(height=80, width=80, max_keypoints=24, n_logos=8)
lib = logo_library(cfg)
ops, detections = build_vld_operators(cfg, lib)

engine = StreamEngine(ops)
routing = np.zeros((3, 3))
routing[0][1] = 1.0
routing[1][2] = 1.0

bad = {"extract": 1, "match": 2, "aggregate": 1}
print(f"[1] starting with a deliberately bad allocation: {bad}")
engine.start(bad)

sched = DRSScheduler(
    ["extract", "match", "aggregate"],
    routing,
    np.array([bad["extract"], bad["match"], bad["aggregate"]]),
    SchedulerConfig(k_max=6, min_improvement=0.01, horizon_seconds=600.0),
    measurer=engine.measurer,
)

rng = np.random.default_rng(0)
engine.measurer.pull(time.time())
t_end = time.time() + 6.0
sent = 0
while time.time() < t_end:
    engine.inject("extract", make_frame(cfg, rng, np.asarray(lib), rng.random() < 0.4))
    sent += 1
    time.sleep(0.004)

decision = sched.tick()
print(f"[2] after {sent} frames DRS says: action={decision.action} "
      f"k_target={None if decision.k_target is None else decision.k_target.tolist()}")
if decision.action == "rebalance":
    new_alloc = dict(zip(["extract", "match", "aggregate"], decision.k_current.tolist()))
    print(f"[3] applying rebalance -> {new_alloc}")
    engine.scale_to(new_alloc)
else:
    print("[3] DRS judges the current allocation adequate (cost/benefit or "
          "<min_improvement) — also a valid outcome; no disruption incurred")

t_end = time.time() + 4.0
while time.time() < t_end:
    engine.inject("extract", make_frame(cfg, rng, np.asarray(lib), rng.random() < 0.4))
    time.sleep(0.02)

engine.drain(timeout=30.0)
engine.stop()
lat = np.array(engine.completed_sojourns)
print(f"[4] processed {len(detections)} frames; "
      f"mean sojourn {lat.mean()*1e3:.1f} ms, p95 {np.percentile(lat, 95)*1e3:.1f} ms")
print(f"    detections fired on {int(sum(d.any() for d in detections))} frames")
