"""The paper's VLD application end-to-end on the live JAX stream engine,
with the DRS scheduler closing the loop: measure -> model -> rebalance.

The application is declared ONCE as an AppGraph (repro.api); binding it to
the engine backend yields a DRSSession that owns scheduler construction,
measurer wiring, and decision application — the ~40 lines of hand-synced
name/routing/k plumbing this file used to carry are gone.

Frames flow through extract -> match -> aggregate while a deliberately
bad allocation starves the extractor; after a measurement window the
session's tick() recommends and applies the optimal allocation.

    PYTHONPATH=src python examples/stream_vld.py
"""

import time

import numpy as np

from repro.api import SchedulerConfig
from repro.streaming.apps.vld import VLDConfig, build_vld_graph, logo_library, make_frame

cfg = VLDConfig(height=80, width=80, max_keypoints=24, n_logos=8)
lib = logo_library(cfg)
graph, detections = build_vld_graph(cfg, lib)

session = graph.bind(
    "engine",
    config=SchedulerConfig(k_max=6, min_improvement=0.01, horizon_seconds=600.0),
)

bad = {"extract": 1, "match": 2, "aggregate": 1}
print(f"[1] starting with a deliberately bad allocation: {bad}")
session.start(bad)

rng = np.random.default_rng(0)
t_end = time.time() + 6.0
sent = 0
while time.time() < t_end:
    session.inject(make_frame(cfg, rng, np.asarray(lib), rng.random() < 0.4))
    sent += 1
    time.sleep(0.004)

decision = session.tick()  # pull -> model -> decide -> apply (if worthwhile)
print(f"[2] after {sent} frames DRS says: action={decision.action} "
      f"k_target={None if decision.k_target is None else decision.k_target.tolist()}")
if decision.action == "rebalance":
    print(f"[3] rebalance applied -> {session.allocation}")
elif decision.action == "overloaded":
    print(f"[3] measured rho >= 1 (starved extractor saturated): overload "
          f"scale-out applied immediately -> {session.allocation}")
else:
    print("[3] DRS judges the current allocation adequate (cost/benefit or "
          "<min_improvement) — also a valid outcome; no disruption incurred")

t_end = time.time() + 4.0
while time.time() < t_end:
    session.inject(make_frame(cfg, rng, np.asarray(lib), rng.random() < 0.4))
    time.sleep(0.02)

session.drain(timeout=30.0)
session.stop()
lat = np.array(session.completed_sojourns)
print(f"[4] processed {len(detections)} frames; "
      f"mean sojourn {lat.mean()*1e3:.1f} ms, p95 {np.percentile(lat, 95)*1e3:.1f} ms")
print(f"    detections fired on {int(sum(d.any() for d in detections))} frames")
