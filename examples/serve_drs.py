"""DRS-scheduled LLM serving: prefill/decode chip split + live rebalance.

The serving pipeline is declared once (ServingModel.graph builds an
AppGraph) in which autoregressive decoding is a typed SELF-LOOP edge
(decode -> decode with p = 1 - 1/E[tokens]); DRS's traffic equations turn
the request rate into per-stage load and Algorithm 1 splits the chip
budget.  Stage service rates come from the multi-pod dry-run's roofline
records when available.

    PYTHONPATH=src python examples/serve_drs.py
"""

from pathlib import Path

import numpy as np

from repro.serving.pipeline import ServingModel, StageRates, rates_from_dryrun
from repro.serving.router import ServingSimulation

RESULTS = Path(__file__).resolve().parents[1] / "benchmarks" / "results" / "dryrun"

try:
    rates = rates_from_dryrun("llama3.2-1b", RESULTS)
    print(f"rates from dry-run roofline: prefill {rates.prefill_per_chip:.3f} "
          f"req/s/chip | decode {rates.decode_per_chip:.1f} tok/s/chip")
except (FileNotFoundError, KeyError):
    rates = StageRates(prefill_per_chip=0.5, decode_per_chip=40.0)
    print("dry-run records not found; using illustrative rates")

model = ServingModel(rates, mean_output_tokens=48.0)
# Pick a request rate the stages can actually sustain (the baseline
# dry-run's naive-attention prefill is slow; the chunked-attention variant
# in §Perf lifts this 100x): ~40% of the saturation throughput of a
# 10-chip prefill group and the matching decode load.
cap_pre = 0.4 * rates.prefill_per_chip * 10 / (1 + model.group_alpha * 9)
cap_dec = 0.4 * rates.decode_per_chip * 10 / (1 + model.group_alpha * 9) / 48.0
lam0 = min(3.0, cap_pre, cap_dec)
print(f"request rate lam0 = {lam0:.3f} req/s")
horizon = max(1200.0, 3000.0 / lam0)
sim = ServingSimulation(model, lam0, horizon=horizon, warmup=0.0, seed=7)

# Decode visits are amplified 48x by the self-loop:
graph = model.graph(lam0)
top = graph.topology()
print("per-stage traffic:", dict(zip(graph.names, np.round(top.arrival_rates, 1))))

drs = sim.drs_allocation(k_max=20)
print("DRS split @ 20 chips:", drs)

# Start from a perturbed split (decode chips pushed to prefill where
# possible), let DRS rebalance halfway through.
k_min = top.min_feasible_allocation()
spare = max(drs["decode"] - int(k_min[2]), 0)
bad = {
    "tokenize": drs["tokenize"],
    "prefill": drs["prefill"] + spare,
    "decode": drs["decode"] - spare,
    "detokenize": drs["detokenize"],
}
mid = horizon / 2
print("starting from a perturbed split:", bad)
rep = sim.run(bad, rebalance_to=drs, rebalance_at=mid)
ts = np.array([t for t, _ in rep.sojourn_series])
sj = np.array([s for _, s in rep.sojourn_series])
before = sj[(ts > mid * 0.1) & (ts < mid)].mean()
after = sj[ts > mid * 1.15].mean()
print(f"latency before rebalance: {before:.3f}s")
print(f"latency after  rebalance: {after:.3f}s "
      f"(model predicts {model.expected_latency(lam0, drs):.3f}s)")
