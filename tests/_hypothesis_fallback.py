"""Minimal stand-in for `hypothesis` when it is not installed.

The tier-1 suite must *collect and run* everywhere, including hermetic
containers without dev dependencies (see requirements-dev.txt for the real
pin).  This shim implements just the surface our tests use —
``@given(...)`` with keyword/positional strategies, ``@settings(...)``,
and the ``st.integers / st.floats / st.sampled_from / st.booleans``
strategies — as a deterministic seeded random sweep.  No shrinking, no
database, no adaptive search: when real hypothesis is available it is
always preferred (tests import it first and fall back here).
"""

from __future__ import annotations

import functools
import inspect
import os
import random
from dataclasses import dataclass
from typing import Any, Callable, Sequence

__all__ = ["given", "settings", "strategies"]

_SEED = int(os.environ.get("FALLBACK_HYPOTHESIS_SEED", "20150361"))
_DEFAULT_EXAMPLES = 20


@dataclass(frozen=True)
class _Strategy:
    sample: Callable[[random.Random], Any]

    def example_stream(self, rng: random.Random):
        while True:
            yield self.sample(rng)


class _Strategies:
    """The `st` namespace: each call returns a sampling strategy."""

    @staticmethod
    def integers(min_value: int = -(1 << 16), max_value: int = 1 << 16) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(
        min_value: float = -1e6,
        max_value: float = 1e6,
        allow_nan: bool = False,
        allow_infinity: bool = False,
    ) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> _Strategy:
        items = list(elements)
        return _Strategy(lambda rng: rng.choice(items))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def lists(elems: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        return _Strategy(
            lambda rng: [elems.sample(rng) for _ in range(rng.randint(min_size, max_size))]
        )


strategies = _Strategies()


def settings(**kwargs):
    """Record requested settings (only max_examples matters here)."""

    def deco(fn):
        fn._fallback_settings = dict(kwargs)
        return fn

    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the test once per sampled example (deterministic seed).

    Mirrors hypothesis' decorator contract closely enough for our suite:
    positional strategies fill the test's positional parameters, keyword
    strategies its keyword parameters, and ``@settings(max_examples=N)``
    (applied before or after) bounds the sweep.
    """

    def deco(fn):
        orig_params = list(inspect.signature(fn).parameters)
        kw_names = set(kw_strategies)
        non_kw = [p for p in orig_params if p not in kw_names]
        # hypothesis fills positional strategies from the right
        pos_targets = non_kw[len(non_kw) - len(arg_strategies):] if arg_strategies else []
        fixture_params = [p for p in non_kw if p not in pos_targets]

        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kwargs):
            bound = dict(zip(fixture_params, fixture_args))
            bound.update(fixture_kwargs)
            # @settings may sit above or below @given; functools.wraps
            # copies the marker up, so the wrapper always carries it.
            n = int(
                getattr(wrapper, "_fallback_settings", {}).get(
                    "max_examples", _DEFAULT_EXAMPLES
                )
            )
            rng = random.Random(_SEED)
            for i in range(n):
                drawn = dict(zip(pos_targets, (s.sample(rng) for s in arg_strategies)))
                drawn.update({k: s.sample(rng) for k, s in kw_strategies.items()})
                try:
                    fn(**bound, **drawn)
                except Exception:
                    print(f"\n[fallback-hypothesis] failing example #{i}: {drawn}")
                    raise

        # Hide the strategy-filled params from pytest's fixture resolution:
        # only genuine fixtures remain in the visible signature.
        wrapper.__signature__ = inspect.Signature(
            [
                inspect.Parameter(p, inspect.Parameter.POSITIONAL_OR_KEYWORD)
                for p in fixture_params
            ]
        )
        wrapper.__dict__.pop("__wrapped__", None)
        # keep the settings marker reachable if @settings is applied above us
        wrapper._fallback_given = True
        return wrapper

    return deco
