"""Overload semantics: bounded queues, drop accounting, unstable snapshots.

Covers DESIGN.md §11 end to end — engine/DES drop agreement on one
AppGraph, lam0_hat unbiasedness under shedding, MMPP/burst arrivals, the
scheduler's "overloaded" path — plus regression tests pinning the
satellite fixes (probe sample phase, DES rate normalization,
min_processors feasibility recompute).
"""

import math
import time

import numpy as np
import pytest

from repro.api import AppGraph, Edge, OpDef
from repro.core import (
    DRSScheduler,
    Machine,
    Measurer,
    Negotiator,
    ResourcePool,
    SchedulerConfig,
    Topology,
    min_processors,
)
from repro.core.measurer import InstanceProbe
from repro.streaming.des import (
    ArrivalProcess,
    NetworkSimulator,
    SimConfig,
    simulate_allocation,
)
from repro.streaming.engine import Operator, StreamEngine
from repro.streaming.overload import OverloadPolicy


# --------------------------------------------------------------------- #
# OverloadPolicy surface
# --------------------------------------------------------------------- #
def test_policy_validation():
    assert OverloadPolicy.coerce("block").blocks
    assert OverloadPolicy.coerce("shed-oldest").sheds
    p = OverloadPolicy("shed-newest")
    assert OverloadPolicy.coerce(p) is p
    with pytest.raises(ValueError):
        OverloadPolicy("drop-everything")


# --------------------------------------------------------------------- #
# DES drop semantics
# --------------------------------------------------------------------- #
def overloaded_sim(policy, *, capacity=10, seed=3, horizon=200.0, warmup=20.0):
    """M/D/1 at 2x capacity: mu=10, k=1, deterministic offered 20/s."""
    top = Topology.chain([("op", 10.0)], lam0=20.0)
    return simulate_allocation(
        top, [1], seed=seed, horizon=horizon, warmup=warmup,
        arrival_kind="deterministic", service_kind="deterministic",
        queue_capacity=capacity, overload_policy=policy,
    )


@pytest.mark.parametrize("policy", ["shed-newest", "shed-oldest"])
def test_des_shed_policies_drop_excess(policy):
    res = overloaded_sim(policy)
    # Offered 20/s, capacity 10/s -> shed ~10/s post-warmup.
    assert res.per_op_drop_rate[0] == pytest.approx(10.0, rel=0.05)
    assert res.per_op_arrival_rate[0] == pytest.approx(20.0, rel=0.05)  # offered
    assert res.per_op_max_backlog[0] <= 10 + 1
    assert res.shed_roots == res.dropped  # every shed tuple is external here
    # Survivors' sojourn is bounded by the queue: cap * service + service.
    assert res.mean_sojourn <= (10 + 1) * 0.1 + 1e-6


def test_des_block_policy_is_lossless():
    res = overloaded_sim("block")
    assert res.dropped == 0 and res.shed_roots == 0
    # Backlog grows without bound (backpressure pushes latency upstream).
    assert res.per_op_max_backlog[0] > 100
    # Throughput pins at capacity.
    assert res.completed == pytest.approx(10.0 * 200.0, rel=0.1)


def test_des_unbounded_counts_no_drops():
    res = overloaded_sim("shed-newest", capacity=None, horizon=60.0)
    assert res.dropped == 0
    assert res.per_op_dropped is not None and res.per_op_dropped[0] == 0


def test_lam0_hat_unbiased_under_shedding():
    """A dropped external tuple must NOT count as an external arrival:
    lam0_hat converges to the admitted rate (~capacity), not the offered
    rate — while the queue-tail probe still reports offered load."""
    top = Topology.chain([("op", 10.0)], lam0=20.0)
    m = Measurer(["op"], smoother="ewma", smoother_kw={"alpha": 0.0})
    m.pull(0.0)
    sim = NetworkSimulator(
        top, [1],
        config=SimConfig(seed=5, horizon=300.0, warmup=0.0,
                         queue_capacity=10, overload_policy="shed-newest"),
        measurer=m,
    )
    sim.run()
    snap = m.pull(sim.now)
    assert snap.lam0_hat == pytest.approx(10.0, rel=0.1)  # admitted ~ capacity
    assert snap.lam_hat[0] == pytest.approx(20.0, rel=0.1)  # offered at tail
    assert snap.drop_hat[0] == pytest.approx(10.0, rel=0.15)  # shed rate
    # offered == admitted + shed
    assert snap.lam_hat[0] == pytest.approx(snap.lam0_hat + snap.drop_hat[0], rel=0.1)


def test_shed_roots_do_not_bias_sojourn():
    """Sojourns of partially-shed trees are excluded: with a fan-out op
    whose children are shed downstream, surviving complete sojourns must
    still match the (stable) survivors' dynamics, not include truncated
    trees that 'completed' early because half their work was dropped."""
    ops = [OpDef("gen", mu=50.0), OpDef("work", mu=10.0)]
    graph = AppGraph(ops, [Edge("gen", "work", 2.0)], {"gen": 9.0})
    res = graph.bind(
        "des", seed=7, horizon=200.0, warmup=20.0,
        queue_capacity=5, overload_policy="shed-newest",
    ).simulate([1, 1])
    # work is offered 18/s vs capacity 10/s -> heavy shedding
    assert res.per_op_drop_rate[1] > 5.0
    assert res.shed_roots > 0
    # every recorded completion is a FULL tree: completed + shed == admitted
    assert res.completed > 0


# --------------------------------------------------------------------- #
# Engine drop semantics + engine/DES agreement
# --------------------------------------------------------------------- #
def test_engine_shed_newest_counts_and_completes():
    eng = StreamEngine(
        [Operator("op", lambda x: (time.sleep(0.02), [])[1])],
        queue_capacity=3,
        overload_policy="shed-newest",
    )
    eng.start({"op": 1})
    outcomes = [eng.inject("op", i) for i in range(40)]  # burst >> queue
    admitted = [r for r in outcomes if r is not None]
    shed = outcomes.count(None)
    assert eng.drain(timeout=10.0)
    eng.stop()
    assert shed > 0 and len(admitted) + shed == 40
    assert eng.drop_counts()["op"] == shed
    assert eng.shed_roots == shed
    assert len(eng.completed_sojourns) == len(admitted)


def test_engine_block_policy_backpressures_inject():
    eng = StreamEngine(
        [Operator("op", lambda x: (time.sleep(0.02), [])[1])],
        queue_capacity=2,
        overload_policy="block",
    )
    eng.start({"op": 1})
    t0 = time.perf_counter()
    for i in range(20):
        assert eng.inject("op", i) is not None
    blocked_for = time.perf_counter() - t0
    assert eng.drain(timeout=10.0)
    eng.stop()
    # 20 tuples at ~20ms each through a 2-slot queue: inject had to wait.
    assert blocked_for > 0.2
    assert eng.drop_counts()["op"] == 0
    assert len(eng.completed_sojourns) == 20


def test_engine_inject_timeout_sheds():
    eng = StreamEngine(
        [Operator("op", lambda x: (time.sleep(0.05), [])[1])],
        queue_capacity=1,
        overload_policy="block",
    )
    eng.start({"op": 1})
    results = [eng.inject("op", i, timeout=0.01) for i in range(10)]
    assert None in results  # some injections timed out and were shed
    assert eng.drop_counts()["op"] == results.count(None)
    assert eng.drain(timeout=10.0)
    eng.stop()


def shared_overload_graph():
    def work(_x):
        time.sleep(0.02)  # mu = 50/s
        return []

    return AppGraph(
        [OpDef("work", mu=50.0, fn=work, service_kind="deterministic")],
        [],
        {"work": 100.0},  # 2x capacity at k=1
        arrival_kind="deterministic",
    )


def test_engine_and_des_drop_rates_agree():
    """Same AppGraph, same policy: live shed rate ~= simulated shed rate."""
    graph = shared_overload_graph()
    session = graph.bind("engine", queue_capacity=4, overload_policy="shed-newest")
    session.start({"work": 1})
    period = 1.0 / 100.0
    t0 = time.perf_counter()
    sent = 0
    while time.perf_counter() - t0 < 2.0:
        session.inject(sent)
        sent += 1
        target = t0 + sent * period
        if (dt := target - time.perf_counter()) > 0:
            time.sleep(dt)
    elapsed = time.perf_counter() - t0
    session.drain(timeout=10.0)
    session.stop()
    eng_rate = session.drop_counts()["work"] / elapsed

    des = graph.bind(
        "des", queue_capacity=4, overload_policy="shed-newest",
        horizon=100.0, warmup=5.0, seed=11,
    ).simulate([1])
    des_rate = float(des.per_op_drop_rate[0])
    # Offered 100/s, capacity ~50/s -> both shed ~50/s.  The live engine
    # carries scheduling jitter; 20% is a safe CI bound (the benchmark
    # reports the tight comparison).
    assert des_rate == pytest.approx(50.0, rel=0.05)
    assert eng_rate == pytest.approx(des_rate, rel=0.2)


# --------------------------------------------------------------------- #
# Arrival processes
# --------------------------------------------------------------------- #
def test_mmpp_arrival_rate_sanity():
    """Long-run MMPP rate == stationary mixture of the two state rates."""
    ap = ArrivalProcess(rate=5.0, kind="mmpp", rate2=50.0, switch01=0.2, switch10=0.8)
    rng = np.random.default_rng(0)
    n = 40_000
    total = sum(ap.sample(rng) for _ in range(n))
    expect = (0.8 * 5.0 + 0.2 * 50.0) / (0.2 + 0.8)
    assert n / total == pytest.approx(expect, rel=0.05)


def test_mmpp_is_burstier_than_poisson():
    """MMPP inter-arrivals must show higher variability (CV > 1)."""
    ap = ArrivalProcess(rate=2.0, kind="mmpp", rate2=80.0, switch01=0.05, switch10=0.5)
    rng = np.random.default_rng(1)
    xs = np.array([ap.sample(rng) for _ in range(20_000)])
    cv = xs.std() / xs.mean()
    assert cv > 1.2


def test_burst_arrival_schedule():
    """Burst kind: rate2 inside the burst window, rate outside, and the
    long-run mean is the duty-cycle mixture."""
    ap = ArrivalProcess(rate=2.0, kind="burst", rate2=40.0,
                        burst_every=10.0, burst_length=2.0)
    rng = np.random.default_rng(2)
    t, in_burst, out_burst = 0.0, 0, 0
    n = 30_000
    for _ in range(n):
        t += ap.sample(rng)
        if t % 10.0 < 2.0:
            in_burst += 1
        else:
            out_burst += 1
    mean_rate = n / t
    assert mean_rate == pytest.approx(0.2 * 40.0 + 0.8 * 2.0, rel=0.05)
    # bursts dominate the arrivals despite covering 20% of the time
    assert in_burst > 3 * out_burst


def test_arrival_change_preserves_process_parameters():
    """schedule_arrival_change must keep kind AND the mmpp/burst parameters
    (a plain (rate, kind) rebuild used to zero rate2 and the schedule,
    silently killing every burst window after the change)."""
    top = Topology.chain([("op", 1000.0)], lam0=5.0)
    sim = NetworkSimulator(
        top, [1], config=SimConfig(seed=8, horizon=1.0, warmup=0.0),
        arrivals=[ArrivalProcess(rate=2.0, kind="burst", rate2=40.0,
                                 burst_every=10.0, burst_length=2.0)],
    )
    sim.schedule_arrival_change(0.5, 0, 4.0)
    sim.run()
    ap = sim.arrivals[0]
    assert ap.rate == 4.0
    assert ap.kind == "burst"
    assert ap.rate2 == 40.0
    assert ap.burst_every == 10.0 and ap.burst_length == 2.0


def test_mmpp_and_burst_require_rate2():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="rate2"):
        ArrivalProcess(rate=5.0, kind="mmpp").sample(rng)
    with pytest.raises(ValueError, match="rate2"):
        ArrivalProcess(rate=5.0, kind="burst").sample(rng)
    # explicit 0.0 is a legal ON/OFF process
    assert ArrivalProcess(rate=0.0, kind="mmpp", rate2=8.0).sample(rng) > 0


def test_queue_capacity_zero_rejected_everywhere():
    """capacity 0 used to mean 'unbounded' in the engine (queue.Queue
    semantics) but 'always full' in the DES (IndexError under
    shed-oldest); both backends now reject it."""
    with pytest.raises(ValueError, match="queue_capacity"):
        StreamEngine([Operator("op", lambda x: [])], queue_capacity=0)
    top = Topology.chain([("op", 10.0)], lam0=5.0)
    with pytest.raises(ValueError, match="queue_capacity"):
        NetworkSimulator(top, [1], config=SimConfig(queue_capacity=0))


def test_mmpp_drives_simulator():
    """End-to-end: MMPP source through the DES, measured rate sane."""
    top = Topology.chain([("op", 100.0)], lam0=14.0)  # lam0 overridden below
    sim = NetworkSimulator(
        top, [1],
        config=SimConfig(seed=4, horizon=400.0, warmup=40.0),
        arrivals=[ArrivalProcess(rate=5.0, kind="mmpp", rate2=50.0,
                                 switch01=0.2, switch10=0.8)],
    )
    res = sim.run()
    assert res.per_op_arrival_rate[0] == pytest.approx(14.0, rel=0.1)


def test_mmpp_reachable_through_declarative_api():
    """The unified API must be able to drive the modulated arrival kinds:
    arrival_kw plumbs the ArrivalProcess parameters through bind("des")."""
    graph = AppGraph.chain([("op", 100.0)], lam0=5.0, arrival_kind="mmpp")
    res = graph.bind(
        "des", seed=9, horizon=400.0, warmup=40.0,
        arrival_kw={"rate2": 50.0, "switch01": 0.2, "switch10": 0.8},
    ).simulate([1])
    # state-0 rate comes from the graph's lam0 (5/s); long-run mixture:
    expect = (0.8 * 5.0 + 0.2 * 50.0) / 1.0
    assert res.per_op_arrival_rate[0] == pytest.approx(expect, rel=0.1)


# --------------------------------------------------------------------- #
# Scheduler: unstable snapshots
# --------------------------------------------------------------------- #
def overload_snapshot(sched, lam_offered, mus, lam0_admitted, drops, dt=60.0):
    m = sched.measurer
    probes = [m.new_probe(n) for n in m.names]
    m.pull(0.0)
    for i, p in enumerate(probes):
        p.on_enqueue(int(lam_offered[i] * dt))
        p.on_dropped(int(drops[i] * dt))
        for _ in range(60):
            for _ in range(m.n_m - 1):
                p.on_processed(0.0)
            p.on_processed(1.0 / mus[i])
    m.on_external_arrival(int(lam0_admitted * dt))
    m.on_tuple_complete(2.0, n=int(lam0_admitted * dt))
    return m.pull(dt)


def chain_routing(n):
    r = np.zeros((n, n))
    for i in range(n - 1):
        r[i][i + 1] = 1.0
    return r


def test_scheduler_emits_overloaded_and_scales_out():
    """rho >= 1 at the source: immediate negotiator scale-out, offered-load
    model (downstream throughput-capped rates ignored)."""
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    pool = ResourcePool([Machine(f"m{i}", 5) for i in range(10)])
    neg = Negotiator(pool)
    neg.ensure(10)
    cfg = SchedulerConfig(t_max=1.5, min_improvement=0.01)
    sched = DRSScheduler(names, routing, np.array([5, 4, 1]), cfg, negotiator=neg)
    # extract: capacity 5*2=10, offered 26 -> rho 2.6.  Downstream probes
    # see only extract's throughput (10/s), i.e. capped measurements.
    snap = overload_snapshot(
        sched, [26.0, 10.0, 10.0], [2.0, 5.0, 50.0],
        lam0_admitted=10.0, drops=[16.0, 0.0, 0.0],
    )
    mask = sched.overloaded_mask(snap)
    assert list(mask) == [True, False, False]
    top = sched.topology_from(snap)
    # Clamped model: offered load propagated through declared routing.
    assert top.lam0[0] == pytest.approx(26.0, rel=0.05)
    np.testing.assert_allclose(top.arrival_rates, [26.0, 26.0, 26.0], rtol=0.05)
    d = sched.decide(top, snap, 60.0)
    assert d.action == "overloaded"
    assert neg.k_max > 10  # leased immediately, no hysteresis
    assert d.k_target is not None
    assert top.expected_sojourn(d.k_target) <= cfg.t_max


def test_scheduler_overloaded_without_negotiator_is_defined():
    """No negotiator: still a defined decision (best effort at k_max or an
    explicit infeasible-overloaded verdict), never an exception."""
    names = ["a"]
    routing = np.zeros((1, 1))
    cfg = SchedulerConfig(k_max=2)
    sched = DRSScheduler(names, routing, np.array([1]), cfg)
    snap = overload_snapshot(sched, [30.0], [10.0], lam0_admitted=10.0, drops=[20.0])
    top = sched.topology_from(snap)
    d = sched.decide(top, snap, 60.0)
    assert d.action == "overloaded"
    # offered 30/s needs 4 processors at mu=10; k_max=2 -> no target
    assert d.k_target is None
    assert "infeasible" in d.reason


def test_scheduler_overloaded_on_drop_rate_alone():
    """Sustained shedding flags overload even when the smoothed arrival
    rate still sits just below capacity (EWMA lag under bursty load)."""
    names = ["a"]
    routing = np.zeros((1, 1))
    sched = DRSScheduler(names, routing, np.array([1]), SchedulerConfig(k_max=8))
    # capacity 10/s; smoothed lam 9.5/s (below), but 3/s being shed
    snap = overload_snapshot(sched, [9.5], [10.0], lam0_admitted=6.5, drops=[3.0])
    assert sched.overloaded_mask(snap).any()
    d = sched.decide(sched.topology_from(snap), snap, 60.0)
    assert d.action == "overloaded"


def test_scheduler_stable_snapshot_unaffected():
    """rho < 1 everywhere: the overload path must not trigger and the
    measured-rescale model is used (drop-in regression guard)."""
    names = ["extract", "match", "agg"]
    routing = chain_routing(3)
    cfg = SchedulerConfig(k_max=22, min_improvement=0.01)
    sched = DRSScheduler(names, routing, np.array([8, 12, 2]), cfg)
    snap = overload_snapshot(
        sched, [13.0, 13.0, 13.0], [2.0, 5.0, 50.0],
        lam0_admitted=13.0, drops=[0.0, 0.0, 0.0],
    )
    assert not sched.overloaded_mask(snap).any()
    d = sched.decide(sched.topology_from(snap), snap, 60.0)
    assert d.action == "rebalance"


def test_snapshot_drop_rates_surface():
    m = Measurer(["a", "b"], smoother="ewma", smoother_kw={"alpha": 0.0})
    pa, pb = m.new_probe("a"), m.new_probe("b")
    m.pull(0.0)
    pa.on_enqueue(100)
    pa.on_dropped(40)
    pb.on_enqueue(60)
    for p in (pa, pb):
        for _ in range(20):
            p.on_processed(0.01)
    snap = m.pull(10.0)
    assert snap.drop_hat[0] == pytest.approx(4.0)
    assert snap.drop_hat[1] == 0.0
    np.testing.assert_allclose(snap.drop_rates(), [4.0, 0.0])


# --------------------------------------------------------------------- #
# Satellite regressions
# --------------------------------------------------------------------- #
def test_probe_sampling_phase_preserved_across_batches():
    """Batched on_processed(n>1) crossing the n_m boundary must keep the
    remainder: 3 batches of 25 with n_m=10 -> exactly 7 samples (75/10),
    not 3 (one per triggering call)."""
    p = InstanceProbe(n_m=10)
    for _ in range(3):
        p.on_processed(0.02, n=25)
    _, processed, _, sampled, _ = p.drain()
    assert processed == 75
    assert sampled == 7


def test_probe_sampling_rate_exact_with_mixed_batches():
    p = InstanceProbe(n_m=5)
    total = 0
    rng = np.random.default_rng(0)
    for _ in range(200):
        n = int(rng.integers(1, 12))
        total += n
        p.on_processed(0.01, n=n)
    _, processed, _, sampled, _ = p.drain()
    assert processed == total
    assert sampled == total // 5


def test_des_arrival_rate_uses_post_warmup_span():
    """Rate doubles exactly at the warmup boundary: the reported
    per-op rate must reflect the post-warmup regime only (the old code
    blended warmup arrivals into the whole-run average)."""
    top = Topology.chain([("op", 100.0)], lam0=5.0)
    sim = NetworkSimulator(
        top, [1], config=SimConfig(seed=6, horizon=400.0, warmup=200.0)
    )
    sim.schedule_arrival_change(200.0, 0, 10.0)
    res = sim.run()
    assert res.per_op_arrival_rate[0] == pytest.approx(10.0, rel=0.08)


def test_min_processors_result_truly_feasible():
    """The accepted allocation must satisfy T_max on the exactly
    recomputed E[T], across a sweep approaching the service-time floor
    (guards the incremental-et drift accept/raise)."""
    top = Topology.chain(
        [(f"op{i}", 3.0 + 0.7 * i) for i in range(8)], lam0=2.5
    )
    floor = sum(top.arrival_rates[i] / top.lam0_total / op.mu
                for i, op in enumerate(top.operators))
    for frac in (1.01, 1.02, 1.05, 1.1, 1.5, 3.0):
        t_max = floor * frac
        res = min_processors(top, t_max)
        assert top.expected_sojourn(res.k) <= t_max  # exact, not drifted
        assert res.expected_sojourn == pytest.approx(top.expected_sojourn(res.k))


def test_engine_rescale_under_load_no_lost_roots():
    """Stress the worker-loop root lookup (now lock-protected) against
    concurrent rescale + completion: no root may be lost or double-done."""
    eng = StreamEngine(
        [Operator("a", lambda x: [("b", x)]), Operator("b", lambda x: [])],
        queue_capacity=None,
    )
    eng.start({"a": 2, "b": 2})
    n = 300
    for i in range(n):
        eng.inject("a", i)
        if i % 50 == 0:
            eng.scale_to({"a": 1 + i % 3, "b": 1 + (i // 50) % 3})
    assert eng.drain(timeout=20.0)
    eng.stop()
    assert len(eng.completed_sojourns) == n
    assert eng.shed_roots == 0


def test_session_tick_applies_overloaded_decision():
    """DRSSession must apply the 'overloaded' allocation to the backend."""

    def work(_x):
        time.sleep(0.02)
        return []

    graph = AppGraph([OpDef("work", mu=50.0, fn=work)], [], {"work": 100.0})
    pool = ResourcePool([Machine(f"m{i}", 1) for i in range(6)])
    neg = Negotiator(pool)
    neg.ensure(1)
    session = graph.bind(
        "engine", queue_capacity=4, overload_policy="shed-newest",
        config=SchedulerConfig(t_max=0.5, min_improvement=0.01),
        negotiator=neg,
    )
    session.start({"work": 1})
    t0 = time.perf_counter()
    sent = 0
    while time.perf_counter() - t0 < 1.5:
        session.inject(sent)
        sent += 1
        target = t0 + sent / 100.0
        if (dt := target - time.perf_counter()) > 0:
            time.sleep(dt)
    decision = session.tick()
    applied = session.backend.allocation()
    session.drain(timeout=10.0)
    session.stop()
    assert decision.action == "overloaded"
    assert applied["work"] > 1  # backend actually rescaled
    assert math.isfinite(decision.model_sojourn_target)
