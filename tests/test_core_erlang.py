"""Tests for the Erlang M/M/k model (paper Eq. 1-2)."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no dev deps installed — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.erlang import (
    erlang_b,
    erlang_c,
    expected_sojourn,
    expected_sojourn_factorial,
    marginal_benefit,
    min_stable_k,
    sojourn_curve,
)


def test_mm1_closed_form():
    # M/M/1: E[T] = 1 / (mu - lam)
    lam, mu = 3.0, 10.0
    assert expected_sojourn(1, lam, mu) == pytest.approx(1.0 / (mu - lam), rel=1e-12)


def test_unstable_branch_is_infinite():
    assert expected_sojourn(1, 10.0, 10.0) == math.inf  # k*mu == lam
    assert expected_sojourn(2, 30.0, 10.0) == math.inf  # k*mu < lam
    assert expected_sojourn(3, 30.0, 10.0) == math.inf  # k == lam/mu exactly
    assert math.isfinite(expected_sojourn(4, 30.0, 10.0))


def test_zero_arrivals_gives_pure_service_time():
    assert expected_sojourn(3, 0.0, 4.0) == pytest.approx(0.25)


@given(
    k=st.integers(min_value=1, max_value=60),
    lam=st.floats(min_value=0.1, max_value=50.0),
    mu=st.floats(min_value=0.1, max_value=20.0),
)
@settings(max_examples=200, deadline=None)
def test_stable_recursion_matches_paper_factorial_form(k, lam, mu):
    a, b = expected_sojourn(k, lam, mu), expected_sojourn_factorial(k, lam, mu)
    if math.isinf(a) or math.isinf(b):
        assert math.isinf(a) and math.isinf(b)
    else:
        assert a == pytest.approx(b, rel=1e-9)


def test_large_k_does_not_overflow():
    # factorial form dies around k ~ 170; stable form must not.
    t = expected_sojourn(4096, 100000.0, 30.0)
    assert math.isfinite(t)
    assert t >= 1.0 / 30.0


@given(
    lam=st.floats(min_value=0.1, max_value=100.0),
    mu=st.floats(min_value=0.1, max_value=20.0),
)
@settings(max_examples=200, deadline=None)
def test_sojourn_monotone_decreasing_and_convex_in_k(lam, mu):
    """Convexity premise of Theorem 1 (paper Ineq. 5)."""
    k0 = min_stable_k(lam, mu)
    ks = range(k0, k0 + 12)
    ts = [expected_sojourn(k, lam, mu) for k in ks]
    assert all(math.isfinite(t) for t in ts)
    # monotone decreasing
    for t1, t2 in zip(ts, ts[1:]):
        assert t2 <= t1 + 1e-12
    # convex: second differences >= 0  <=>  diminishing marginal benefit
    diffs = [t1 - t2 for t1, t2 in zip(ts, ts[1:])]
    for d1, d2 in zip(diffs, diffs[1:]):
        assert d2 <= d1 + 1e-9


@given(
    lam=st.floats(min_value=0.1, max_value=100.0),
    mu=st.floats(min_value=0.1, max_value=20.0),
)
@settings(max_examples=100, deadline=None)
def test_marginal_benefit_nonincreasing(lam, mu):
    k0 = min_stable_k(lam, mu)
    deltas = [marginal_benefit(k, lam, mu) for k in range(k0, k0 + 10)]
    for d1, d2 in zip(deltas, deltas[1:]):
        assert d2 <= d1 + 1e-9


def test_sojourn_limits_to_service_time():
    # As k -> inf, E[T] -> 1/mu (no queueing).
    assert expected_sojourn(500, 10.0, 2.0) == pytest.approx(0.5, rel=1e-9)


def test_sojourn_curve_matches_pointwise():
    lam, mu = 22.0, 3.0
    lo, hi = 1, 40
    curve = sojourn_curve(lam, mu, lo, hi)
    for idx, k in enumerate(range(lo, hi + 1)):
        expect = expected_sojourn(k, lam, mu)
        if math.isinf(expect):
            assert math.isinf(curve[idx])
        else:
            assert curve[idx] == pytest.approx(expect, rel=1e-12)


def test_min_stable_k():
    assert min_stable_k(10.0, 3.0) == 4  # ceil(3.33)
    assert min_stable_k(9.0, 3.0) == 4  # integral ratio needs the bump
    assert min_stable_k(0.0, 3.0) == 1


def test_erlang_b_c_basic():
    # Known value: B(1, a) = a / (1 + a)
    assert erlang_b(1, 0.5) == pytest.approx(0.5 / 1.5)
    # C(k, a) in [B, 1]
    for k, a in [(2, 1.0), (5, 3.0), (10, 8.0)]:
        b, c = erlang_b(k, a), erlang_c(k, a)
        assert b <= c <= 1.0
