"""Day-scale checkpointed soak harness (DESIGN.md §17).

The property under test: driving the fused control plane through a
composite day (diurnal x flash x MMPP, ``streaming/soak.py``) in
checkpoint_every-window chunks — with a simulated crash, a
:class:`CheckpointStore` restore, and freshly compiled executables
between every chunk — is **bit-identical** to the straight-through run:
decisions, allocations, measurements, and the whole-run aggregates.

Tier-1 runs the smoke-capped day (two "hours"); the full day and the
mesh-sharded legs carry ``@pytest.mark.soak`` and run in the CI
``test-soak`` lane (``-m soak``, 8 emulated devices).
"""

import jax
import numpy as np
import pytest

from repro.streaming.soak import (
    SoakConfig,
    assert_bit_identical,
    run_checkpointed,
    run_straight,
    soak_report,
)

# Cross-topology agreement mirrors tests/test_mesh_control.py: decisions
# and carry aggregates are exact between mesh and unsharded loops; the
# float measurement surfaces may differ by reduction order.
EXACT_ACROSS_TOPOLOGY = (
    "codes", "k", "applied", "miss", "warm_windows", "k_final", "q_final",
    "offered", "served", "dropped", "ext_admitted", "ext_offered",
    "q_int", "q_max",
)
CLOSE_ACROSS_TOPOLOGY = ("sojourn", "et_cur", "et_target")


def _roundtrip(cfg, tmp_path, **kw):
    ref = run_straight(cfg, **kw)
    chk = run_checkpointed(cfg, tmp_path / "ckpt", **kw)
    n_chunks = -(-cfg.n_ticks // cfg.checkpoint_every)
    assert chk["n_restores"] == n_chunks - 1
    assert_bit_identical(ref, chk)
    return ref, chk


def _flash_ticks(cfg):
    """Window indices covering the first flash crowd (0.30-0.35 day)."""
    lo = int(0.30 * cfg.day / cfg.tick_interval)
    hi = int(0.35 * cfg.day / cfg.tick_interval)
    return slice(lo, hi + 1)


def test_soak_smoke_reactive_checkpoint_roundtrip(tmp_path):
    cfg = SoakConfig.smoke()
    ref, chk = _roundtrip(cfg, tmp_path)
    rep = soak_report(cfg, chk)
    assert rep.n_restores == 3
    # The day actually stresses the plane: deadline misses inside the
    # flash crowd, bounded-queue shedding, and at least one reallocation.
    assert rep.miss[_flash_ticks(cfg)].any()
    assert 0.0 < rep.deadline_miss_rate < 0.5
    assert 0.0 <= rep.drop_rate < 0.05
    assert (np.asarray(ref["codes"])[:, 0] != 0).any()
    assert rep.k_total.max() <= cfg.k_max


def test_soak_smoke_proactive_checkpoint_roundtrip(tmp_path):
    cfg = SoakConfig.smoke()
    ref, chk = _roundtrip(cfg, tmp_path, proactive=True)
    assert int(np.asarray(ref["mpc_used"]).sum()) > 0
    rep = soak_report(cfg, chk)
    # The MPC plane moves the committed budget around (static-budget
    # reactive loops can't): the cost trajectory must not be flat.
    assert len(set(rep.k_total.tolist())) > 1


def test_soak_smoke_compact_checkpoint_roundtrip(tmp_path):
    """§18 compaction under crash + restore: the decide cache lives
    outside the checkpointed carry, so every resume chunk starts cold —
    the checkpointed compacted day must still be bit-identical to the
    straight compacted run (``repriced`` is the one surface allowed to
    differ, and ``assert_bit_identical`` excludes it), and the compacted
    day must be bit-identical to the dense day."""
    cfg = SoakConfig.smoke()
    ref, chk = _roundtrip(cfg, tmp_path, compact=True)
    assert "repriced" in ref and "repriced" not in chk
    assert np.asarray(ref["repriced"]).shape == (cfg.n_ticks, 1)
    dense = run_straight(cfg)
    assert_bit_identical(dense, ref)


def test_soak_smoke_mesh_checkpoint_roundtrip(tmp_path):
    if len(jax.devices()) < 8:
        pytest.skip("mesh soak leg needs 8 (emulated) devices")
    from repro.distributed.sharding import fleet_mesh

    cfg = SoakConfig.smoke()
    ref, chk = _roundtrip(cfg, tmp_path, mesh=fleet_mesh(8))
    # ... and the sharded day agrees with the unsharded one: decisions
    # exact, measurements to reduction-order tolerance.
    ref_unsharded = run_straight(cfg)
    for key in EXACT_ACROSS_TOPOLOGY:
        np.testing.assert_array_equal(
            np.asarray(ref[key]), np.asarray(ref_unsharded[key]), err_msg=key
        )
    for key in CLOSE_ACROSS_TOPOLOGY:
        np.testing.assert_allclose(
            np.asarray(ref[key]), np.asarray(ref_unsharded[key]),
            rtol=1e-6, err_msg=key,
        )


@pytest.mark.soak
def test_soak_full_day_reactive(tmp_path):
    cfg = SoakConfig()
    ref, chk = _roundtrip(cfg, tmp_path)
    rep = soak_report(cfg, chk)
    assert rep.n_restores == 7
    assert rep.t[-1] == pytest.approx(cfg.day)
    assert rep.miss[_flash_ticks(cfg)].any()
    assert 0.0 < rep.deadline_miss_rate < 0.5
    assert rep.drop_rate < 0.05


@pytest.mark.soak
def test_soak_full_day_proactive(tmp_path):
    cfg = SoakConfig()
    ref, chk = _roundtrip(cfg, tmp_path, proactive=True)
    assert int(np.asarray(ref["mpc_used"]).sum()) > 0
    rep = soak_report(cfg, chk)
    assert len(set(rep.k_total.tolist())) > 1


@pytest.mark.soak
def test_soak_quarter_day_mesh(tmp_path):
    """The mesh leg of the full soak at a quarter day (the smoke mesh
    test covers the same property at two hours; this one adds scale)."""
    if len(jax.devices()) < 8:
        pytest.skip("mesh soak leg needs 8 (emulated) devices")
    from repro.distributed.sharding import fleet_mesh

    cfg = SoakConfig(day=21600.0, checkpoint_every=48, name="soak-quarter")
    _roundtrip(cfg, tmp_path, mesh=fleet_mesh(8))
