"""Trigger-gated lane compaction for the sparse decide (DESIGN.md §18).

The contract under test: compaction is **output-invisible**.  The
compacted decide memoizes each lane's exact decide inputs and replays
the cached outputs while they are bitwise unchanged (and the lane is not
overloaded); because the decide is a pure function of those inputs, the
replay is provably bit-identical to repricing — so every surface except
the ``repriced`` diagnostic must match the dense run bit for bit:

* the standalone jit decide (``make_decide_jax(compact=...)``) across
  cold / quiet / partially-triggered ticks at swept trigger fractions;

Decisions and allocations (codes, k, applied, every integer aggregate)
are compared **bitwise**.  The ``et_cur``/``et_target`` diagnostics get
the same ~1-ulp rtol the mesh tests use: XLA reassociates the per-lane
``N`` reductions differently at different batch extents, and a compacted
rung IS a different batch extent — the same program property the
sharded/unsharded comparison already tolerates (tests/test_mesh_control.py).
* the whole fused loop over the 32-scenario mixed zoo (the arrival-trace
  mix is the trigger-rate sweep: Poisson-sampled lanes reprice every
  window, deterministic constant lanes go quiet);
* the float64 twin (``tick_batch`` with a :class:`TwinCompactionState`),
  reactive and proactive;
* every committed golden fixture replayed with compaction on.
"""

import json
import math
import pathlib

import numpy as np
import pytest

import repro.core.controller as ctl
from repro.api.session import ScenarioRunner
from repro.core.scheduler import SchedulerConfig
from repro.distributed.sharding import bucket_ladder
from repro.streaming.scenarios import control_trace, scenario_matrix

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"


def _scens(b, seed=11, horizon=20.0):
    return [
        s.with_(negotiated=False)
        for s in scenario_matrix(b, seed=seed, horizon=horizon, warmup=5.0, dt=0.05)
    ]


def _decide_inputs(static, seed=0, k_fill=2):
    b, n = static.batch, static.n
    rng = np.random.default_rng(seed)
    lam = np.abs(rng.normal(2.0, 0.5, (b, n)))
    mu = np.abs(rng.normal(6.0, 0.5, (b, n))) + 1.0
    drop = np.zeros((b, n))
    lam0 = np.abs(rng.normal(2.0, 0.5, b))
    k = np.where(static.active, k_fill, 0).astype(np.int64)
    return lam, mu, drop, lam0, k


def _assert_decide_match(want, got):
    """(code, k_next, et_cur, et_target, applied): decisions bitwise,
    E[T] diagnostics to the mesh tests' reduction-order rtol."""
    for i in (0, 1, 4):
        np.testing.assert_array_equal(
            np.asarray(want[i]), np.asarray(got[i]), err_msg=f"out[{i}]"
        )
    for i in (2, 3):
        np.testing.assert_allclose(
            np.asarray(want[i]), np.asarray(got[i]), rtol=1e-6,
            err_msg=f"out[{i}]",
        )


def _eq_nan(a, b):
    """Recursive equality where NaN == NaN (JSON traces carry NaN rates)."""
    if isinstance(a, float) and isinstance(b, float):
        return a == b or (math.isnan(a) and math.isnan(b))
    if isinstance(a, dict):
        return set(a) == set(b) and all(_eq_nan(a[k], b[k]) for k in a)
    if isinstance(a, (list, tuple)):
        return len(a) == len(b) and all(_eq_nan(x, y) for x, y in zip(a, b))
    return a == b


# --------------------------------------------------------------------------- #
# The static bucket ladder
# --------------------------------------------------------------------------- #
def test_bucket_ladder_shape():
    assert bucket_ladder(4096) == (256, 1024, 4096)
    assert bucket_ladder(10_000) == (625, 2500, 10_000)
    # the dense rung is always present, tiny extents collapse onto it
    assert bucket_ladder(1) == (1,)
    assert bucket_ladder(3) == (1, 3)
    assert bucket_ladder(7, fractions=(2,)) == (4, 7)
    with pytest.raises(ValueError):
        bucket_ladder(0)
    for b in (1, 5, 16, 100, 4096):
        ladder = bucket_ladder(b)
        assert ladder[-1] == b
        assert all(w1 < w2 for w1, w2 in zip(ladder, ladder[1:]))


# --------------------------------------------------------------------------- #
# Standalone compacted decide: trigger semantics + bit identity
# --------------------------------------------------------------------------- #
def test_compacted_decide_bit_identity_swept_trigger_fractions():
    import jax

    with jax.experimental.enable_x64():
        scens = _scens(32)
        r = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
        st, pr = r.static, r._params()
        lam, mu, drop, lam0, k = _decide_inputs(st)
        dense = ctl.make_decide_jax(st, pr)
        comp = ctl.make_decide_jax(st, pr, compact=True)
        cache = comp.init_cache()

        def check(lam_t):
            want = dense(lam_t, mu, drop, lam0, k)
            nonlocal cache
            got, repriced, cache = comp(lam_t, mu, drop, lam0, k, cache)
            _assert_decide_match(want, got)
            return int(np.asarray(repriced).sum())

        assert check(lam) == 32  # cold cache: every lane reprices
        assert check(lam) == 0  # unchanged inputs: every lane replays
        for frac in (0.05, 0.25, 0.5, 1.0):
            n_trig = max(int(round(frac * 32)), 1)
            lam2 = lam.copy()
            lam2[:n_trig] *= 1.0 + 0.01 * frac
            assert check(lam2) == n_trig  # exactly the changed lanes
            assert check(lam2) == 0  # ...and they memoize right back


def test_compacted_decide_k_and_custom_ladder_and_nan():
    import jax

    with jax.experimental.enable_x64():
        scens = _scens(8)
        r = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
        st, pr = r.static, r._params()
        lam, mu, drop, lam0, k = _decide_inputs(st)
        dense = ctl.make_decide_jax(st, pr)
        comp = ctl.make_decide_jax(
            st, pr, compact=ctl.CompactionConfig(b_active_cap=(2, 8))
        )
        cache = comp.init_cache()

        def step(lam_t, k_t):
            want = dense(lam_t, mu, drop, lam0, k_t)
            nonlocal cache
            got, repriced, cache = comp(lam_t, mu, drop, lam0, k_t, cache)
            _assert_decide_match(want, got)
            return int(np.asarray(repriced).sum())

        step(lam, k)
        assert step(lam, k) == 0
        # a k change triggers exactly like a rate change
        k2 = k.copy()
        k2[1, 0] += 1
        assert step(lam, k2) == 1
        # NaN rates (idle windows) memoize too: NaN == NaN in the trigger
        # compare, so a persistently-idle lane goes quiet instead of
        # repricing every tick on NaN != NaN
        lam3 = lam.copy()
        lam3[2] = np.nan
        assert step(lam3, k2) == 1
        assert step(lam3, k2) == 0


# --------------------------------------------------------------------------- #
# The fused loop over the mixed zoo (property test)
# --------------------------------------------------------------------------- #
# Bitwise-equal fused-loop surfaces vs rtol'd E[T] diagnostics (mirrors
# tests/test_mesh_control.py).  ``sojourn`` stays EXACT: it is computed
# from the (never-compacted) simulate windows, and the k feeding them is
# asserted exact.
EXACT = (
    "codes", "k", "applied", "miss", "warm_windows", "k_final", "q_final",
    "offered", "served", "dropped", "ext_admitted", "ext_offered",
    "q_int", "q_max", "sojourn",
)
CLOSE = ("et_cur", "et_target")


def _assert_loop_match(ref, got, extra_exact=()):
    for key in EXACT + tuple(extra_exact):
        np.testing.assert_array_equal(ref[key], got[key], err_msg=key)
    for key in CLOSE:
        np.testing.assert_allclose(ref[key], got[key], rtol=1e-6, err_msg=key)


def _fused_out(scens, compact, **kw):
    import jax

    with jax.experimental.enable_x64():
        r = ScenarioRunner(scens, tick_interval=5.0, backend="jax",
                           compact=compact, **kw)
        assert r.fused
        run, _ = ctl.make_fused_loop(
            r.arrays, r.static, r._params(),
            steps_per_tick=r._steps_per_tick,
            warmup_seconds=scens[0].warmup,
            proactive=r.proactive_cfg, compact=r.compact,
        )
        return {key: np.asarray(v) for key, v in run(r.k).items()}


@pytest.mark.parametrize("seed", [11, 29])
def test_fused_loop_zoo_compact_bit_identity(seed):
    """The 32-scenario mixed zoo: every decision/measurement surface of
    the compacted fused loop is bitwise equal to the dense loop.  The
    zoo's trace mix is the trigger-rate sweep — Poisson lanes retrigger
    every window, constant/deterministic lanes go quiet."""
    scens = _scens(32, seed=seed)
    ref = _fused_out(scens, None)
    got = _fused_out(scens, True)
    assert "repriced" not in ref and "repriced" in got
    _assert_loop_match(ref, got)


def test_fused_loop_zoo_compact_proactive_bit_identity():
    scens = _scens(16)
    ref = _fused_out(scens, None, proactive=True)
    got = _fused_out(scens, True, proactive=True)
    _assert_loop_match(ref, got, extra_exact=("mpc_used", "confident"))


def test_fused_loop_quiet_lanes_skip_repricing():
    """Deterministic-arrival constant-trace lanes present bitwise
    identical measurements once the transient drains — the trigger must
    stop repricing them (this is the perf claim the bench quantifies;
    Poisson lanes in the same batch keep repricing every window)."""
    from dataclasses import replace

    scens = [
        replace(s.with_(negotiated=False), arrival_kind="deterministic")
        for s in scenario_matrix(8, seed=11, horizon=40.0, warmup=5.0, dt=0.05)
        if "constant" in s.name
    ]
    assert scens, "the matrix zoo lost its constant-trace scenarios"
    ref = _fused_out(scens, None)
    got = _fused_out(scens, True)
    _assert_loop_match(ref, got)
    repriced = got["repriced"]
    assert repriced[0].all()  # cold cache prices densely
    # after the transient the constant lanes are bitwise quiet
    assert not repriced[-1].any(), repriced
    assert repriced.sum() < repriced.size


# --------------------------------------------------------------------------- #
# The float64 twin
# --------------------------------------------------------------------------- #
def test_twin_tick_batch_compact_trace_identical():
    scens = _scens(32)
    ref = control_trace(scens, tick_interval=5.0)
    got = control_trace(scens, tick_interval=5.0, compact=True)
    assert _eq_nan(ref, got)


def test_twin_tick_batch_compact_proactive_trace_identical():
    scens = _scens(8)
    ref = control_trace(scens, tick_interval=5.0, proactive=True)
    got = control_trace(scens, tick_interval=5.0, proactive=True, compact=True)
    assert _eq_nan(ref, got)


def test_twin_compaction_state_replays():
    """The twin's memo actually engages on repeated identical windows
    (same lam/mu/k -> replayed row), and a replayed row is a fresh copy —
    mutating the caller's k must not corrupt the cache."""
    scens = _scens(6)
    r = ScenarioRunner(scens, tick_interval=5.0, backend="numpy", fused=False)
    cstate = ctl.TwinCompactionState.create(len(scens), r.static.n)
    from repro.core.measurer import MeasurementBatch

    lam, mu, drop, lam0, k = _decide_inputs(r.static)
    meas = MeasurementBatch.from_rates(
        lam, mu, lam0, np.full(len(scens), 0.2), 0.0, drop_hat=drop
    )
    out1 = ctl.tick_batch(meas, k, r.static, r._params(), compact_state=cstate)
    assert not cstate.replayed.any()  # cold: every lane priced
    out2 = ctl.tick_batch(meas, k, r.static, r._params(), compact_state=cstate)
    assert cstate.replayed.all()  # identical window: every lane replayed
    for r1, r2 in zip(out1.rows, out2.rows):
        assert r1.action == r2.action
        np.testing.assert_array_equal(r1.k_next, r2.k_next)
    out2.rows[0].k_next[:] = -7  # caller mutation must not reach the cache
    out3 = ctl.tick_batch(meas, k, r.static, r._params(), compact_state=cstate)
    assert (out3.rows[0].k_next >= 0).all()


# --------------------------------------------------------------------------- #
# Goldens replay with compaction on
# --------------------------------------------------------------------------- #
def _golden_entries():
    import importlib.util

    spec = importlib.util.spec_from_file_location("golden_regen", GOLDEN / "regen.py")
    regen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(regen)
    return {name: (s, pro, tick) for name, s, pro, tick in regen.entries()}


@pytest.mark.parametrize("name", ["vld", "fpd", "vld_proactive", "vld_fused",
                                  "soak"])
def test_golden_trace_replays_with_compaction(name):
    """Compaction is output-invisible: every committed golden fixture
    replays bit-for-bit with the sparse decide ON (twin path)."""
    want = json.loads((GOLDEN / f"{name}_control_trace.json").read_text())
    scenario, proactive, _tick = _golden_entries()[name]
    got = control_trace(
        [scenario], tick_interval=want["tick_interval"], proactive=proactive,
        compact=True,
    )
    w, g = want["scenarios"][name], got["scenarios"][name]
    assert g["actions"] == w["actions"], (
        f"{name} drifted under compaction — the sparse decide changed a "
        "decision, which the §18 exactness contract forbids"
    )
    assert g["allocations"] == w["allocations"]
    assert g["trajectory"] == w["trajectory"]
    for metric in ("drop_rate", "mean_sojourn", "deadline_miss_rate"):
        assert g[metric] == pytest.approx(w[metric], rel=1e-6, abs=1e-9), metric


def test_golden_fused_replays_through_compacted_jit_loop():
    """The jit-eligible golden through the fused jax loop with compaction
    on — pins twin == dense jit == compacted jit on the golden surface."""
    want = json.loads((GOLDEN / "vld_fused_control_trace.json").read_text())
    scenario, proactive, _tick = _golden_entries()["vld_fused"]
    got = control_trace(
        [scenario], tick_interval=want["tick_interval"], proactive=proactive,
        backend="jax", compact=True,
    )
    w, g = want["scenarios"]["vld_fused"], got["scenarios"]["vld_fused"]
    assert g["actions"] == w["actions"]
    assert g["allocations"] == w["allocations"]
    for key in ("k_total", "miss", "warm"):
        assert g["trajectory"][key] == w["trajectory"][key], key


# --------------------------------------------------------------------------- #
# Satellites
# --------------------------------------------------------------------------- #
def test_stack_mixed_fused_decide_error_names_indices():
    configs = [
        SchedulerConfig(k_max=4, fused_decide=(i in (1, 3))) for i in range(5)
    ]
    with pytest.raises(ValueError) as ei:
        ctl.ControllerParams.stack(configs, [4] * 5)
    msg = str(ei.value)
    assert "[1, 3]" in msg and "[0, 2, 4]" in msg


def test_bench_provenance_fields():
    from benchmarks.run import provenance

    p = provenance()
    assert set(p) == {"git_sha", "jax_version", "backend"}
    assert len(p["git_sha"]) == 40 or p["git_sha"] == "unknown"
    assert p["jax_version"] and p["backend"]


def test_mpc_plan_compact_empty_and_subset():
    """Unit check of the eligible-lane MPC gather: no eligible lanes ->
    carry-shaped defaults without calling the planner; a subset matches
    the dense plan on exactly that subset."""
    from repro.forecast.mpc import MPCConfig, mpc_plan, mpc_plan_compact

    b, n, h = 4, 3, 3
    rng = np.random.default_rng(5)
    lam_pred = np.abs(rng.normal(3.0, 0.5, (b, h, n)))
    q0 = np.abs(rng.normal(1.0, 0.3, (b, n)))
    k_cur = np.full((b, n), 2, dtype=np.int64)
    k_max = np.full(b, 12, dtype=np.int64)
    mu = np.abs(rng.normal(6.0, 0.5, (b, n))) + 1.0
    src_mask = np.zeros((b, n), dtype=bool)
    src_mask[:, 0] = True
    kw = dict(
        mu=mu, group=np.zeros((b, n), dtype=bool), alpha=np.zeros((b, n)),
        speed=np.ones((b, n)), active=np.ones((b, n), dtype=bool),
        src_mask=src_mask, cap_queue=np.full((b, n), np.inf),
        t_max=np.full(b, 2.0), span=5.0, cfg=MPCConfig(horizon=h),
        k_hi=16, xp=np,
    )
    dense = mpc_plan(lam_pred, q0, k_cur, k_max=k_max, **kw)
    eligible = np.array([True, False, True, False])
    got = mpc_plan_compact(eligible, lam_pred, q0, k_cur, k_max=k_max, **kw)
    for di, gi in zip(dense, got):
        np.testing.assert_array_equal(
            np.asarray(di)[eligible], np.asarray(gi)[eligible]
        )
    none = mpc_plan_compact(
        np.zeros(b, dtype=bool), lam_pred, q0, k_cur, k_max=k_max, **kw
    )
    assert not np.asarray(none[1]).any()  # any_ok all False
    np.testing.assert_array_equal(none[0], k_cur.astype(np.int32))
