"""HLO cost model vs known-flop programs (incl. the scan trip-count fix)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    m, k, n = 64, 128, 32
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    txt = compile_text(lambda a, b: a @ b, a, b)
    cost = analyze_hlo(txt)
    assert cost.flops == pytest.approx(2 * m * k * n, rel=0.01)
    assert cost.dot_count == 1


def test_batched_matmul_flops():
    bsz, m, k, n = 4, 32, 64, 16
    a = jax.ShapeDtypeStruct((bsz, m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((bsz, k, n), jnp.float32)
    txt = compile_text(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b), a, b)
    cost = analyze_hlo(txt)
    assert cost.flops == pytest.approx(2 * bsz * m * k * n, rel=0.01)


def test_scan_multiplies_by_trip_count():
    """THE fix: cost_analysis counts a scanned layer once; we must count L."""
    L, d = 8, 64
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((4, d), jnp.float32)

    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    txt = compile_text(f, w, x)
    cost = analyze_hlo(txt)
    expect = L * 2 * 4 * d * d  # L matmuls
    assert cost.flops == pytest.approx(expect, rel=0.05)
    assert cost.while_count >= 1
    # the builtin cost_analysis undercounts (this is why hlo_cost exists)
    builtin = jax.jit(f).lower(w, x).compile().cost_analysis()
    if isinstance(builtin, (list, tuple)):  # jax <= 0.4.x: one dict per device
        builtin = builtin[0]
    assert builtin["flops"] < expect / 2


def test_grad_scan_counts_fwd_and_bwd():
    L, d, b = 4, 32, 2
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)

    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    txt = compile_text(jax.grad(f), w, x)
    cost = analyze_hlo(txt)
    # fwd: L*2*b*d*d ; bwd: 2 matmuls per layer (dh and dW)
    expect = 3 * L * 2 * b * d * d
    assert cost.flops == pytest.approx(expect, rel=0.25)


def test_traffic_scales_with_trip_count():
    L, d = 16, 64
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((4, d), jnp.float32)

    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        return jax.lax.scan(body, x, w)[0].sum()

    def f1(w, x):  # single layer for comparison
        return jnp.tanh(x @ w[0]).sum()

    t_l = analyze_hlo(compile_text(f, w, x))
    t_1 = analyze_hlo(compile_text(f1, w, x))
    assert t_l.traffic_bytes > 4 * t_1.traffic_bytes  # grows with L


def test_collectives_counted_with_multiplicity():
    if len(jax.devices()) < 1:
        pytest.skip("needs devices")
    mesh = jax.make_mesh((1,), ("d",))
    s = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def f(x):
        return x * 2

    txt = jax.jit(f, in_shardings=s).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)
    ).compile().as_text()
    cost = analyze_hlo(txt)  # no collectives on 1 device
    assert cost.collective_bytes == 0
