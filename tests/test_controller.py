"""Batched controller parity (ISSUE 5, DESIGN.md §14).

The contract: the batched control plane (core/controller.py) is
*bit-identical* to the scalar ``DRSScheduler`` loop it was extracted
from —

* both committed golden decision traces replay unchanged through the
  batched ``ScenarioRunner`` (B=1 ``tick_batch``);
* a shuffled B-stack of zoo scenarios (mixed widths, allocators,
  overload policies, negotiated leases) decides identically to driving
  each scenario through its own per-scenario scheduler;
* the fused jit path (simulate -> measure -> decide -> apply in one
  lax.scan program) agrees with the float64 twin under enable_x64;
* the ``gain_topr`` Pallas kernel matches its jnp oracle exactly in
  interpret mode on CPU, and both match the scalar heap greedy.
"""

import json
import pathlib
import random

import numpy as np
import pytest

try:
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover - jax is a hard dep of the repo
    jax = None

from repro.core import controller as ctl
from repro.core.allocator import InsufficientResourcesError, _heap_greedy_counts
from repro.core.jackson import UnstableTopologyError
from repro.core.measurer import MeasurementBatch, MeasurementSnapshot, stack_snapshots
from repro.core.negotiator import Machine, Negotiator, ResourcePool
from repro.core.scheduler import DRSScheduler, SchedulerConfig, SchedulerDecision
from repro.api.session import ScenarioRunner
from repro.streaming.batchsim import (
    BatchQueueSim,
    little_wait,
    per_op_service_time,
    visit_sum_sojourn,
)
from repro.streaming.scenarios import (
    fpd_scenario,
    pack_allocations,
    pack_scenarios,
    scenario_matrix,
    vld_scenario,
)

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"


# --------------------------------------------------------------------------- #
# The pre-extraction reference: one DRSScheduler object per scenario,
# ticked in a Python loop (the PR-4 ScenarioRunner structure, verbatim).
# --------------------------------------------------------------------------- #
def scalar_reference_run(scenarios, tick_interval=10.0):
    arrays = pack_scenarios(scenarios)
    sim = BatchQueueSim(arrays, backend="numpy")
    k = pack_allocations(scenarios, [s.plan_k0() for s in scenarios])
    scheds = []
    for bi, s in enumerate(scenarios):
        scaling, ga = s.graph.scaling_lists()
        negotiator = None
        if s.negotiated:
            size = max(int(s.machine_size), 1)
            pool = ResourcePool(
                [Machine(f"m{i}", size) for i in range(-(-s.k_max // size))]
            )
            negotiator = Negotiator(pool)
            negotiator.ensure(int(k[bi, : s.graph.n].sum()))
        scheds.append(DRSScheduler(
            s.graph.names,
            s.graph.routing_matrix(),
            k[bi, : s.graph.n].copy(),
            SchedulerConfig(
                k_max=None if negotiator is not None else s.k_max,
                t_max=s.t_max,
                tick_interval=tick_interval,
                allocator=s.allocator,
            ),
            negotiator=negotiator,
            scaling=scaling,
            group_alpha=ga,
            speed_factors=s.speed_vector(),
        ))
    decisions = [[] for _ in scenarios]
    steps_per_tick = max(int(round(tick_interval / arrays.dt)), 1)
    while sim.step_index < arrays.steps:
        w = sim.step_window(k, steps_per_tick)
        for bi, (s, sched) in enumerate(zip(scenarios, scheds)):
            n = s.graph.n
            span = w["span"]
            lam_hat = w["offered"][bi, :n] / span
            drop_hat = w["dropped"][bi, :n] / span
            mu = arrays.mu[bi, :n]
            mu_eff = mu if arrays.speed is None else mu * arrays.speed[bi, :n]
            admitted = np.maximum(lam_hat - drop_hat, 0.0)
            wait = little_wait(w["q_mean"][bi, :n], admitted, arrays.dt)
            svc = per_op_service_time(
                w["capacity"][bi, :n], mu_eff, arrays.group[bi, :n]
            )
            lam0 = max(w["ext_admitted"][bi] / span, 0.0)
            sojourn = float(visit_sum_sojourn(admitted, wait, svc, lam0))
            snap = MeasurementSnapshot.from_rates(
                lam_hat, mu, lam0, sojourn, sim.now, drop_hat=drop_hat
            )
            try:
                d = sched.tick_from(snap, sim.now)
            except (InsufficientResourcesError, UnstableTopologyError) as e:
                d = SchedulerDecision(
                    sim.now, "infeasible", sched.k_current.copy(), None,
                    s.k_max, float("inf"), None, snap.sojourn_hat, reason=str(e),
                )
            decisions[bi].append(d)
            if (
                d.action in ("rebalance", "scale_out", "scale_in", "overloaded")
                and d.k_target is not None
            ):
                k[bi, :n] = d.k_target
    return decisions, k


def assert_decisions_identical(batched, scalar):
    assert len(batched) == len(scalar)
    for bi, (b_decs, s_decs) in enumerate(zip(batched, scalar)):
        actions_b = [d.action for d in b_decs]
        actions_s = [d.action for d in s_decs]
        assert actions_b == actions_s, f"scenario {bi}: {actions_b} != {actions_s}"
        for ti, (db, ds) in enumerate(zip(b_decs, s_decs)):
            np.testing.assert_array_equal(
                db.k_current, ds.k_current, err_msg=f"scenario {bi} tick {ti}"
            )
            # bit-identical model values, not approx
            assert db.model_sojourn_current == ds.model_sojourn_current or (
                np.isnan(db.model_sojourn_current)
                and np.isnan(ds.model_sojourn_current)
            ), f"scenario {bi} tick {ti} E[T] drifted"


# --------------------------------------------------------------------------- #
# Golden traces through the batched path at B=1
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name,factory", [("vld", vld_scenario), ("fpd", fpd_scenario)])
def test_golden_replay_through_batched_controller(name, factory):
    """The committed fixtures (generated pre-extraction) must replay
    bit-for-bit through tick_batch at B=1."""
    want = json.loads((GOLDEN / f"{name}_control_trace.json").read_text())
    s = factory()
    runner = ScenarioRunner([s], tick_interval=want["tick_interval"], backend="numpy")
    reports = runner.run()
    got_actions = list(reports[0].actions)
    got_allocs = [dict(a) for a in reports[0].allocations]
    assert got_actions == want["scenarios"][name]["actions"]
    assert got_allocs == want["scenarios"][name]["allocations"]


@pytest.mark.parametrize("name,factory", [("vld", vld_scenario), ("fpd", fpd_scenario)])
def test_golden_scenarios_batch_vs_scalar_bit_identical(name, factory):
    """B=1 tick_batch vs a hand-rolled per-scenario DRSScheduler loop:
    identical decisions, allocations, and model values."""
    s = factory()
    runner = ScenarioRunner([s], tick_interval=10.0, backend="numpy")
    runner.run()
    scalar_decs, scalar_k = scalar_reference_run([s], tick_interval=10.0)
    assert_decisions_identical(runner.decisions, scalar_decs)
    np.testing.assert_array_equal(runner.k, scalar_k)


# --------------------------------------------------------------------------- #
# Property: a shuffled B-stack decides like B independent scalar loops
# --------------------------------------------------------------------------- #
def test_shuffled_stack_decides_identically_to_scalar_ticks():
    scens = scenario_matrix(8, seed=21, horizon=25.0, warmup=5.0, dt=0.05)
    rng = random.Random(3)
    rng.shuffle(scens)
    runner = ScenarioRunner(scens, tick_interval=5.0, backend="numpy")
    runner.run()
    scalar_decs, scalar_k = scalar_reference_run(scens, tick_interval=5.0)
    assert_decisions_identical(runner.decisions, scalar_decs)
    np.testing.assert_array_equal(runner.k, scalar_k)
    # the matrix must actually exercise the interesting axes
    all_actions = {d.action for decs in runner.decisions for d in decs}
    assert all_actions - {"none"}, "matrix produced only no-ops"


def test_mixed_width_stack_pads_safely():
    """Scenarios of different operator counts share one padded stack."""
    scens = scenario_matrix(6, seed=4, horizon=15.0, warmup=2.0, dt=0.05)
    widths = {s.graph.n for s in scens}
    assert len(widths) > 1, "zoo should produce mixed widths"
    runner = ScenarioRunner(scens, tick_interval=5.0, backend="numpy")
    reports = runner.run()
    for s, r in zip(scens, reports):
        assert set(r.k_final) == set(s.graph.names)


# --------------------------------------------------------------------------- #
# Fused jit loop vs the float64 twin
# --------------------------------------------------------------------------- #
def test_fused_loop_matches_twin_under_x64():
    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(4, seed=11, horizon=20.0, warmup=5.0, dt=0.05)
    ]
    with jax.experimental.enable_x64():
        twin = ScenarioRunner(scens, tick_interval=5.0, backend="numpy")
        r_twin = twin.run()
        fused = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
        assert fused.fused, "static-budget jax runner should take the fused path"
        r_fused = fused.run()
    for a, b in zip(r_twin, r_fused):
        assert list(a.actions) == list(b.actions), a.name
        assert a.k_final == b.k_final, a.name
        assert a.provisioned_total == b.provisioned_total


def test_fused_warm_window_rule_matches_twin():
    """Window warmness is judged in seconds (t0 >= warmup), not rounded
    steps — deadline-miss accounting must agree between backends even
    when warmup is not a multiple of dt."""
    scens = [
        s.with_(negotiated=False, warmup=5.3, dt=0.25, horizon=20.0)
        for s in scenario_matrix(3, seed=6, horizon=20.0, warmup=5.3, dt=0.25)
    ]
    with jax.experimental.enable_x64():
        twin = ScenarioRunner(scens, tick_interval=5.0, backend="numpy")
        r_twin = twin.run()
        fused = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
        assert fused.fused
        r_fused = fused.run()
    assert twin._windows_warm == fused._windows_warm
    np.testing.assert_array_equal(twin._miss, fused._miss)
    for a, b in zip(r_twin, r_fused):
        assert list(a.actions) == list(b.actions)


def test_fused_loop_float32_smoke():
    """The fused program must run (and make sane decisions) at JAX's
    default float32 precision — the TPU configuration."""
    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(3, seed=13, horizon=15.0, warmup=2.0, dt=0.05)
    ]
    runner = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    assert runner.fused
    reports = runner.run()
    for s, r in zip(scens, reports):
        assert len(r.actions) == runner.arrays.steps // runner._steps_per_tick
        assert sum(r.k_final.values()) <= s.k_max
        assert set(r.actions) <= set(ctl.ACTIONS)


def test_negotiated_scenarios_fall_back_to_twin():
    scens = scenario_matrix(3, seed=2, horizon=15.0, warmup=2.0, dt=0.05)
    scens[0] = scens[0].with_(negotiated=True)
    runner = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
    assert not runner.fused  # leases are Python: batch-boundary hooks
    reports = runner.run()
    assert len(reports) == 3


def test_forcing_fused_past_preconditions_raises():
    from repro.api.graph import GraphValidationError

    scens = scenario_matrix(2, seed=2, horizon=15.0, warmup=2.0, dt=0.05)
    scens[0] = scens[0].with_(negotiated=True)
    with pytest.raises(GraphValidationError):
        ScenarioRunner(scens, tick_interval=5.0, backend="jax", fused=True)
    with pytest.raises(GraphValidationError):
        ScenarioRunner(
            [s.with_(negotiated=False) for s in scens],
            tick_interval=5.0, backend="jax", controlled=False, fused=True,
        )


# --------------------------------------------------------------------------- #
# gain_topr: oracle vs kernel vs scalar greedy
# --------------------------------------------------------------------------- #
def _random_gain_rows(rng, b, n, j):
    cand = np.maximum(rng.normal(0.6, 1.0, (b, n, j)), 0.0)
    cand.sort(axis=-1)
    return cand[..., ::-1].copy()  # non-increasing rows (convexity)


def test_gain_topr_oracle_matches_scalar_greedy():
    from repro.kernels.gain_topr import ref

    rng = np.random.default_rng(0)
    cand = _random_gain_rows(rng, 6, 5, 16).astype(np.float64)
    budgets = np.array([0, 1, 7, 80, 13, 40], dtype=np.int32)
    take = np.asarray(ref.gain_topr(jnp.asarray(cand), jnp.asarray(budgets)))
    for bi in range(cand.shape[0]):
        want = _heap_greedy_counts(cand[bi], int(budgets[bi]))
        np.testing.assert_array_equal(take[bi], want, err_msg=f"lane {bi}")
        assert take[bi].sum() == min(int(budgets[bi]), (cand[bi] > 0).sum())


def test_gain_topr_kernel_interpret_parity():
    from repro.kernels.gain_topr import kernel, ref

    rng = np.random.default_rng(1)
    cand = _random_gain_rows(rng, 7, 6, 20).astype(np.float32)
    budgets = np.array([0, 3, 9, 200, 17, 5, 60], dtype=np.int32)
    want = np.asarray(ref.gain_topr(jnp.asarray(cand), jnp.asarray(budgets)))
    got = np.asarray(
        kernel.gain_topr_pallas(jnp.asarray(cand), jnp.asarray(budgets), interpret=True)
    )
    np.testing.assert_array_equal(got, want)


def test_gain_topr_kernel_breaks_ties_in_row_order():
    from repro.kernels.gain_topr import kernel, ref

    cand = np.zeros((1, 3, 4), np.float32)
    cand[0] = [[2, 1, 1, 0], [2, 1, 0, 0], [1, 1, 1, 0]]
    bud = np.array([5], np.int32)
    want = np.asarray(ref.gain_topr(jnp.asarray(cand), jnp.asarray(bud)))
    got = np.asarray(
        kernel.gain_topr_pallas(jnp.asarray(cand), jnp.asarray(bud), interpret=True)
    )
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(want[0], _heap_greedy_counts(cand[0].astype(np.float64), 5))


# --------------------------------------------------------------------------- #
# MeasurementBatch plumbing
# --------------------------------------------------------------------------- #
def test_stack_snapshots_roundtrip():
    s1 = MeasurementSnapshot.from_rates([1.0, 2.0], [3.0, 4.0], 1.0, 0.5, 10.0,
                                        drop_hat=[0.1, 0.0])
    s2 = MeasurementSnapshot.from_rates([5.0], [6.0], 5.0, 0.2, 10.0)
    batch = stack_snapshots([s1, s2])
    assert batch.batch == 2 and batch.n == 2
    r1 = batch.row(0, 2)
    np.testing.assert_array_equal(r1.lam_hat, s1.lam_hat)
    np.testing.assert_array_equal(r1.drop_rates(), s1.drop_rates())
    r2 = batch.row(1, 1)
    np.testing.assert_array_equal(r2.lam_hat, s2.lam_hat)
    # padding lanes are inert: finite mu, zero rates
    assert batch.mu_hat[1, 1] == 1.0 and batch.lam_hat[1, 1] == 0.0


def test_measurement_batch_complete_mask():
    batch = MeasurementBatch.from_rates(
        [[1.0, np.nan], [1.0, 2.0]], [[1.0, 1.0], [1.0, 1.0]],
        [1.0, 1.0], [0.1, 0.1], 0.0,
    )
    np.testing.assert_array_equal(batch.complete(), [False, True])
    active = np.array([[True, False], [True, True]])
    np.testing.assert_array_equal(batch.complete(active), [True, True])
