"""Training loop: loss goes down, checkpoint/restart is bit-exact-resumable,
optimizer behaves, gradient compression stays accurate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed.compress import compress, compress_with_feedback, decompress
from repro.training.loop import LoopConfig, TrainLoop
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule


@pytest.fixture(scope="module")
def smoke_cfg():
    return get_config("llama3.2-1b", "smoke")


def test_loss_decreases(tmp_path, smoke_cfg):
    loop = TrainLoop(
        smoke_cfg,
        AdamWConfig(lr=3e-3, warmup_steps=5, decay_steps=60),
        LoopConfig(total_steps=60, ckpt_every=30, log_every=1000),
        ckpt_dir=tmp_path / "ckpt",
    )
    loop.run()
    losses = [m["loss"] for m in loop.metrics_history]
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1


def test_checkpoint_restart_is_exact(tmp_path, smoke_cfg):
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, decay_steps=40)
    # Run A: 40 steps straight through.
    a = TrainLoop(smoke_cfg, opt, LoopConfig(total_steps=40, ckpt_every=10),
                  ckpt_dir=tmp_path / "a")
    state_a = a.run()
    # Run B: crash at step 20, then resume to 40 in a fresh loop object.
    b1 = TrainLoop(smoke_cfg, opt, LoopConfig(total_steps=40, ckpt_every=10),
                   ckpt_dir=tmp_path / "b")
    with pytest.raises(RuntimeError, match="simulated crash"):
        b1.run(crash_at=20)
    b2 = TrainLoop(smoke_cfg, opt, LoopConfig(total_steps=40, ckpt_every=10),
                   ckpt_dir=tmp_path / "b")
    state_b = b2.run()
    assert int(b2.store.latest_step()) == 40
    # identical final params: restart replayed the same stream from 20
    la = jax.tree.leaves(state_a.params)
    lb = jax.tree.leaves(state_b.params)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), rtol=2e-2, atol=2e-2
        )


def test_synthetic_stream_deterministic():
    cfg = DataConfig(vocab=128, batch=2, seq_len=8, seed=7)
    s1, s2 = SyntheticTokens(cfg), SyntheticTokens(cfg, start_step=0)
    a, b = next(s1), next(s2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # s1 already consumed step 0 above, so after 5 more nexts it sits at 6
    s3 = SyntheticTokens(cfg)
    s3.restore({"step": 6, "seed": 7})
    for _ in range(5):
        next(s1)
    np.testing.assert_array_equal(next(s1)["tokens"], next(s3)["tokens"])


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, decay_steps=1000)
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(jnp.int32(s), cfg)) for s in range(0, 110, 5)]
    assert lrs[0] < 0.01  # warmup start
    assert max(lrs) == pytest.approx(1.0, rel=0.05)
    assert lrs[-1] == pytest.approx(0.1, rel=0.05)  # decayed to floor


def test_grad_clip_bounds_update():
    from repro.training.optimizer import clip_by_global_norm

    tree = {"a": jnp.full((4,), 100.0), "b": jnp.full((2,), -100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    flat = jnp.concatenate([clipped["a"], clipped["b"]])
    assert float(jnp.linalg.norm(flat)) == pytest.approx(1.0, rel=1e-4)
    assert float(norm) == pytest.approx(np.sqrt(6) * 100, rel=1e-4)


# --------------------------------------------------------------------- #
# gradient compression
# --------------------------------------------------------------------- #
def test_int8_compression_snr():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
    y = decompress(compress(x))
    err = jnp.linalg.norm(x - y) / jnp.linalg.norm(x)
    assert float(err) < 0.02  # absmax int8: ~1% error on gaussian


def test_error_feedback_bounds_accumulated_error():
    """With feedback, the running sum of dequantised grads tracks the true
    sum far better than without."""
    key = jax.random.PRNGKey(1)
    true_sum = jnp.zeros((256,))
    fb_sum = jnp.zeros((256,))
    plain_sum = jnp.zeros((256,))
    ef = None
    for i in range(50):
        key, k2 = jax.random.split(key)
        g = {"g": jax.random.normal(k2, (256,)) * 0.01 + 0.003}  # small w/ bias
        true_sum = true_sum + g["g"]
        comp, ef = compress_with_feedback(g, ef)
        fb_sum = fb_sum + decompress(comp["g"])
        plain_sum = plain_sum + decompress(compress(g["g"]))
    fb_err = float(jnp.linalg.norm(fb_sum - true_sum))
    plain_err = float(jnp.linalg.norm(plain_sum - true_sum))
    assert fb_err <= plain_err * 1.05
    assert fb_err < 0.02
