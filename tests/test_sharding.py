"""Sharding rule table: divisibility guards, axis reuse, per-arch overrides."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.common import logical_to_spec


@pytest.fixture(scope="module")
def mesh():
    # single CPU device arranged as a (1,1,1) production-shaped mesh;
    # axis sizes for divisibility tests come from a fake mesh below.
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))


class FakeMesh:
    """Shape-only stand-in (mesh.shape mapping) for divisibility logic."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_safe_spec_divisible():
    m = FakeMesh(pod=2, data=16, model=16)
    spec = shd.safe_spec((256, 4096), ("batch", None), shd.TRAIN_RULES, m)
    assert spec == P(("pod", "data"), None)


def test_safe_spec_indivisible_falls_back():
    m = FakeMesh(pod=2, data=16, model=16)
    # whisper vocab 51865 is not divisible by 16 -> replicated
    spec = shd.safe_spec((51865, 1024), ("vocab", "d_model"), shd.TRAIN_RULES, m)
    assert spec[0] is None
    # command-r vocab 256000 divides -> sharded
    spec = shd.safe_spec((256000, 8192), ("vocab", "d_model"), shd.TRAIN_RULES, m)
    assert spec[0] == "model"


def test_safe_spec_partial_tuple():
    m = FakeMesh(pod=2, data=16, model=16)
    # batch 16 divides data(16) but not pod*data(32): keep only "pod" prefix
    spec = shd.safe_spec((16,), ("batch",), shd.TRAIN_RULES, m)
    # greedy prefix: pod (2) divides 16 -> then data (16): 16 % 32 != 0 -> stop
    assert spec == P("pod")


def test_safe_spec_axis_reuse_guard():
    m = FakeMesh(data=16, model=16)
    rules = {"a": "model", "b": "model"}
    spec = shd.safe_spec((32, 32), ("a", "b"), rules, m)
    assert spec == P("model", None)  # second use of "model" dropped


def test_prune_rules_drops_missing_axes():
    m = FakeMesh(data=16, model=16)  # no "pod"
    pruned = shd.prune_rules(shd.TRAIN_RULES, m)
    assert pruned["batch"] == "data"
    assert pruned["heads"] == "model"


def test_mixtral_arch_override():
    r = shd.rules_for("decode", arch="mixtral-8x22b")
    assert r["d_model"] == "data"  # FSDP weights at serve time (8 experts % 16 != 0)
    r2 = shd.rules_for("decode", arch="llama3.2-1b")
    assert r2["d_model"] is None


def test_logical_to_spec_respects_rules_context():
    from repro.models.common import axis_rules

    with axis_rules({"batch": ("pod", "data"), "heads": "model"}):
        assert logical_to_spec(("batch", "heads", None)) == P(("pod", "data"), "model", None)
    assert logical_to_spec(("batch",)) == P(None)  # no rules active


def test_cache_axes_cover_all_families():
    for fam in ("dense", "moe", "vlm", "ssm", "hybrid", "audio"):
        ax = shd.cache_axes(fam)
        assert "length" in ax
        assert all(isinstance(v, tuple) for v in ax.values())


def test_decode_rules_shard_kv_seq_on_model():
    m = FakeMesh(pod=2, data=16, model=16)
    spec = shd.safe_spec(
        (16, 128, 32768, 8, 128),
        ("layers", "batch", "kv_seq", "kv_heads", None),
        shd.rules_for("decode"),
        m,
    )
    assert spec[2] == "model"  # flash-decoding sequence sharding
    assert spec[1] == ("pod", "data")
    assert spec[3] is None  # kv_heads=8 does not divide model=16 -> dropped
