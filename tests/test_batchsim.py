"""Batch simulator: queue_step kernel vs oracle, numpy twin vs jit,
seed determinism for every process kind, and DES-vs-batchsim conformance
(ISSUE 4; DESIGN.md §13)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AppGraph, Edge, OpDef
from repro.kernels.queue_step import kernel as qk, ref as qref
from repro.streaming import (
    ArrivalProcess,
    ArrivalTrace,
    BatchQueueSim,
    Scenario,
    ServiceProcess,
    pack_scenarios,
    scenario_matrix,
)
from repro.streaming.scenarios import pack_allocations

ARRIVAL_KINDS = ("exponential", "uniform", "deterministic", "mmpp", "burst")
SERVICE_KINDS = ("exponential", "uniform", "deterministic", "lognormal")


def chain_graph(lam0=10.0):
    return AppGraph(
        [OpDef("a", mu=4.0), OpDef("b", mu=6.0), OpDef("c", mu=20.0)],
        [Edge("a", "b"), Edge("b", "c", multiplicity=0.7),
         Edge("b", "b", multiplicity=0.2)],
        {"a": lam0},
    )


K = {"a": 5, "b": 4, "c": 2}


def scenario(**kw):
    defaults = dict(
        name="t",
        graph=chain_graph(),
        traces={"a": ArrivalTrace(kind="constant", rate=10.0)},
        seed=3,
        horizon=120.0,
        warmup=10.0,
        dt=0.02,
    )
    defaults.update(kw)
    return Scenario(**defaults)


def run_batch(scens, ks, **kw):
    arrays = pack_scenarios(scens)
    sim = BatchQueueSim(arrays, **kw)
    kv = pack_allocations(scens, ks)
    res = sim.run(kv)
    return arrays, kv, res


# ------------------------------------------------------------------ #
# queue_step kernel: Pallas (interpret) vs jnp oracle
# ------------------------------------------------------------------ #
def test_queue_step_kernel_interpret_matches_ref():
    rng = np.random.default_rng(0)
    m = 37
    q = jnp.asarray(rng.uniform(0, 50, m), dtype=jnp.float32)
    inflow = jnp.asarray(rng.uniform(0, 10, m), dtype=jnp.float32)
    cap_s = jnp.asarray(rng.uniform(0, 8, m), dtype=jnp.float32)
    cap_q = jnp.asarray(
        np.where(rng.random(m) < 0.5, rng.uniform(5, 40, m), np.inf), dtype=jnp.float32
    )
    got = qk.queue_step_pallas(q, inflow, cap_s, cap_q, interpret=True)
    want = qref.queue_step(q, inflow, cap_s, cap_q)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6, atol=1e-6)


def test_queue_step_kernel_lane_padding():
    m = 300  # > 2 lane rows
    q = jnp.linspace(0.0, 30.0, m)
    inflow = jnp.full((m,), 2.0)
    got = qk.queue_step_pallas(q, inflow, jnp.full((m,), 5.0), jnp.full((m,), 10.0),
                               interpret=True)
    want = qref.queue_step(q.astype(jnp.float32), inflow, jnp.full((m,), 5.0),
                           jnp.full((m,), 10.0))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-6)


def test_queue_step_semantics():
    """Served caps at capacity; shed lanes drop the overflow; +inf lanes
    (block / unbounded) never drop."""
    q = jnp.asarray([10.0, 10.0, 10.0])
    inflow = jnp.asarray([8.0, 8.0, 8.0])
    cap_s = jnp.asarray([4.0, 4.0, 4.0])
    cap_q = jnp.asarray([8.0, jnp.inf, 100.0])
    q2, served, dropped = qref.queue_step(q, inflow, cap_s, cap_q)
    np.testing.assert_allclose(np.asarray(served), [4.0, 4.0, 4.0])
    # lane 0: q1=6, space=2 -> admit 2, drop 6; lane 1/2: admit all
    np.testing.assert_allclose(np.asarray(dropped), [6.0, 0.0, 0.0])
    np.testing.assert_allclose(np.asarray(q2), [8.0, 14.0, 14.0])


# ------------------------------------------------------------------ #
# Seed determinism — batch sim
# ------------------------------------------------------------------ #
def test_batchsim_bit_identical_across_runs():
    scens = scenario_matrix(4, seed=5, horizon=20.0, warmup=2.0)
    ks = [s.plan_k0() for s in scens]
    _, _, r1 = run_batch(scens, ks)
    _, _, r2 = run_batch(scens, ks)
    for name in ("offered", "served", "dropped", "q_final", "q_mean",
                 "max_backlog", "ext_admitted"):
        np.testing.assert_array_equal(getattr(r1, name), getattr(r2, name))


def test_batchsim_seed_changes_arrivals():
    s1, s2 = scenario(seed=1), scenario(seed=2)
    assert not np.array_equal(s1.sample_arrivals(), s2.sample_arrivals())
    np.testing.assert_array_equal(s1.sample_arrivals(), scenario(seed=1).sample_arrivals())


def test_batchsim_numpy_twin_matches_jit_x64():
    scens = scenario_matrix(5, seed=7, horizon=15.0, warmup=2.0)
    ks = [s.plan_k0() for s in scens]
    _, _, rn = run_batch(scens, ks, backend="numpy")
    with jax.experimental.enable_x64():
        _, _, rj = run_batch(scens, ks, backend="jax")
    for name in ("offered", "served", "dropped", "q_final", "q_mean", "ext_admitted"):
        np.testing.assert_allclose(
            getattr(rn, name), getattr(rj, name), rtol=1e-9, atol=1e-9
        )


def test_batchsim_jit_pallas_interpret_agrees():
    scens = scenario_matrix(3, seed=9, horizon=10.0, warmup=1.0)
    ks = [s.plan_k0() for s in scens]
    _, _, rn = run_batch(scens, ks, backend="numpy")
    with jax.experimental.enable_x64():
        _, _, rk = run_batch(scens, ks, backend="jax", force_kernel=True, interpret=True)
    # float32 kernel inside a float64 scan: loose elementwise agreement
    np.testing.assert_allclose(rk.offered, rn.offered, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(rk.dropped, rn.dropped, rtol=1e-3, atol=0.5)
    np.testing.assert_allclose(rk.q_final, rn.q_final, rtol=1e-3, atol=0.5)


# ------------------------------------------------------------------ #
# Seed determinism — event DES, every process kind
# ------------------------------------------------------------------ #
def _des_result(arrival_kind, service_kind, seed=11):
    from repro.streaming import NetworkSimulator, SimConfig

    top = chain_graph().topology()
    kw = {}
    if arrival_kind in ("mmpp", "burst"):
        kw = {"rate2": 25.0, "burst_every": 10.0, "burst_length": 2.0}
    arrivals = [
        ArrivalProcess(rate=float(top.lam0[i]), kind=arrival_kind, **kw)
        for i in range(top.n)
    ]
    services = [ServiceProcess(rate=op.mu, kind=service_kind, cv=0.8)
                for op in top.operators]
    sim = NetworkSimulator(
        top, [5, 4, 2],
        config=SimConfig(seed=seed, horizon=40.0, warmup=5.0, queue_capacity=30,
                         overload_policy="shed-oldest"),
        arrivals=arrivals, services=services,
    )
    return sim.run()


@pytest.mark.parametrize("arrival_kind", ARRIVAL_KINDS)
def test_des_seed_determinism_arrival_kinds(arrival_kind):
    a = _des_result(arrival_kind, "exponential")
    b = _des_result(arrival_kind, "exponential")
    assert a.completed == b.completed
    assert a.dropped == b.dropped
    assert a.mean_sojourn == b.mean_sojourn  # bit-identical, not approx
    np.testing.assert_array_equal(a.per_op_dropped, b.per_op_dropped)
    np.testing.assert_array_equal(a.per_op_max_backlog, b.per_op_max_backlog)
    np.testing.assert_array_equal(a.per_op_arrival_rate, b.per_op_arrival_rate)


@pytest.mark.parametrize("service_kind", SERVICE_KINDS)
def test_des_seed_determinism_service_kinds(service_kind):
    a = _des_result("exponential", service_kind)
    b = _des_result("exponential", service_kind)
    assert a.completed == b.completed and a.mean_sojourn == b.mean_sojourn
    np.testing.assert_array_equal(a.per_op_dropped, b.per_op_dropped)


def test_des_different_seeds_differ():
    a = _des_result("exponential", "exponential", seed=1)
    b = _des_result("exponential", "exponential", seed=2)
    assert a.mean_sojourn != b.mean_sojourn


# ------------------------------------------------------------------ #
# DES-vs-batchsim conformance (DESIGN.md §13 divergence bounds)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("policy", ["shed-newest", "shed-oldest", "block"])
def test_conformance_stable_sojourn_and_drops(policy):
    """Stable scenario: steady-state visit-sum sojourn within 10% and
    (near-)zero drop rates under every overload policy."""
    s = scenario(arrival_kind="exponential", service_kind="exponential",
                 overload_policy=policy, queue_capacity=40,
                 horizon=300.0, warmup=20.0)
    arrays, kv, res = run_batch([s], [K])
    des = s.simulator(K).run()
    batch_soj = float(res.sojourn(kv, arrays.mu, arrays.group, arrays.alpha,
                                 ca2=arrays.ca2, cs2=arrays.cs2)[0])
    assert batch_soj == pytest.approx(des.mean_visit_sum, rel=0.10)
    batch_drop = res.dropped[0].sum() / max(res.offered[0].sum(), 1e-9)
    des_drop = des.dropped / max(des.per_op_arrival_rate.sum() * 280.0, 1e-9)
    assert batch_drop < 0.01 and des_drop < 0.01
    # per-operator offered rates agree tightly (traffic equations in action)
    np.testing.assert_allclose(
        res.arrival_rate[0], des.per_op_arrival_rate, rtol=0.08
    )


def test_conformance_stable_deterministic_is_tight():
    s = scenario(arrival_kind="deterministic", service_kind="deterministic",
                 horizon=300.0, warmup=20.0)
    arrays, kv, res = run_batch([s], [K])
    des = s.simulator(K).run()
    batch_soj = float(res.sojourn(kv, arrays.mu, arrays.group, arrays.alpha,
                                 ca2=arrays.ca2, cs2=arrays.cs2)[0])
    assert batch_soj == pytest.approx(des.mean_visit_sum, rel=0.03)


@pytest.mark.parametrize("policy", ["shed-newest", "shed-oldest", "block"])
def test_conformance_overloaded_agrees_on_saturation(policy):
    """Overloaded scenario (2x capacity at the source): both simulators
    must flag the same saturated operators; shed policies must agree on
    the aggregate drop rate within 15%."""
    s = scenario(
        traces={"a": ArrivalTrace(kind="constant", rate=30.0)},
        overload_policy=policy, queue_capacity=20,
        seed=5, horizon=200.0, warmup=20.0,
    )
    arrays, kv, res = run_batch([s], [K])
    des = s.simulator(K).run()
    sat_batch = res.saturated(kv, arrays.mu, arrays.group, arrays.alpha)[0]
    cap = np.array([5 * 4.0, 4 * 6.0, 2 * 20.0])
    sat_des = des.per_op_arrival_rate >= cap * (1.0 - 1e-9)
    np.testing.assert_array_equal(sat_batch, sat_des)
    assert sat_batch[0], "source must saturate at 2x capacity"
    if policy == "block":
        assert res.dropped[0].sum() == 0 and des.dropped == 0
        # blocked backlog grows without shedding in both simulators
        assert res.max_backlog[0].max() > 100
        assert des.per_op_max_backlog.max() > 100
    else:
        batch_rate = res.drop_rate[0].sum()
        des_rate = des.per_op_drop_rate.sum()
        assert batch_rate == pytest.approx(des_rate, rel=0.15)


def test_conformance_group_scaling():
    """Chip-gang operators (DESIGN.md §2) get the same gang-collapse in
    both simulators: one effective server at mu * k * eff(k)."""
    graph = AppGraph(
        [OpDef("tok", mu=8.0), OpDef("gang", mu=3.0, scaling="group", group_alpha=0.05)],
        [Edge("tok", "gang")],
        {"tok": 10.0},
    )
    k = {"tok": 3, "gang": 6}
    s = Scenario(name="g", graph=graph,
                 traces={"tok": ArrivalTrace(kind="constant", rate=10.0)},
                 arrival_kind="deterministic", service_kind="deterministic",
                 seed=3, horizon=200.0, warmup=20.0, dt=0.02)
    arrays, kv, res = run_batch([s], [k])
    des = s.simulator(k).run()
    batch_soj = float(res.sojourn(kv, arrays.mu, arrays.group, arrays.alpha,
                                 ca2=arrays.ca2, cs2=arrays.cs2)[0])
    assert batch_soj == pytest.approx(des.mean_visit_sum, rel=0.05)
    # effective gang rate: 3 * 6 / (1 + 0.05 * 5) = 14.4 > 10 -> stable
    assert not res.saturated(kv, arrays.mu, arrays.group, arrays.alpha)[0].any()


# Conformance floor per trace family (ISSUE 9 / DESIGN.md §17): observed
# rel errs with 3-seed DES averaging are ~0.06/0.02/0.13/0.07 — the gates
# leave ~2x headroom while staying under the 0.2 bench assertion.
_FAMILY_TOL = {"constant": 0.12, "diurnal": 0.10, "flash": 0.20, "mmpp": 0.15}


def _family_trace(family, base=10.0, h=240.0):
    if family == "constant":
        return ArrivalTrace(kind="constant", rate=base)
    if family == "diurnal":
        return ArrivalTrace(kind="diurnal", rate=base, amplitude=0.5 * base,
                            period=0.5 * h)
    if family == "flash":
        return ArrivalTrace(kind="flash", rate=base, peak=1.6 * base,
                            t_on=0.4 * h, t_off=0.6 * h)
    return ArrivalTrace(kind="mmpp", rate=0.7 * base, peak=1.5 * base,
                        switch01=0.05, switch10=0.1)


@pytest.mark.parametrize("policy", ["block", "shed-newest", "shed-oldest"])
@pytest.mark.parametrize("family", ["constant", "diurnal", "flash", "mmpp"])
def test_conformance_policy_family_matrix(policy, family):
    """DES vs batchsim visit-sum sojourn across the (overload policy x
    trace family) cross-product.  The DES side is averaged over 3 seeds
    (single-seed flash/mmpp runs have up to ~37% CV, which would make any
    sub-0.2 gate meaningless); the trace realization itself stays pinned
    to the scenario seed on both sides."""
    h = 240.0
    s = scenario(traces={"a": _family_trace(family, h=h)},
                 overload_policy=policy, queue_capacity=60,
                 horizon=h, warmup=20.0, seed=11)
    arrays, kv, res = run_batch([s], [K])
    assert not res.saturated(kv, arrays.mu, arrays.group, arrays.alpha)[0].any()
    batch_soj = float(res.sojourn(kv, arrays.mu, arrays.group, arrays.alpha,
                                  ca2=arrays.ca2, cs2=arrays.cs2)[0])
    des = float(np.mean(
        [s.simulator(K, seed=101 + i).run().mean_visit_sum for i in range(3)]
    ))
    assert batch_soj == pytest.approx(des, rel=_FAMILY_TOL[family])
    # stable matrix: every policy admits everything, so both simulators
    # must agree that (near-)nothing is dropped regardless of policy
    assert res.dropped[0].sum() / max(res.offered[0].sum(), 1e-9) < 0.01


@pytest.mark.slow
@pytest.mark.parametrize("policy", ["shed-newest", "shed-oldest", "block"])
@pytest.mark.parametrize("arrival_kind,service_kind,tol",
                         [("deterministic", "deterministic", 0.03),
                          ("exponential", "exponential", 0.12),
                          ("uniform", "uniform", 0.12)])
def test_conformance_extended_sweep(policy, arrival_kind, service_kind, tol):
    """Long-horizon stable-scenario conformance across the (policy x
    process-kind) cross-product — the `-m slow` CI tier."""
    s = scenario(arrival_kind=arrival_kind, service_kind=service_kind,
                 overload_policy=policy, queue_capacity=60,
                 horizon=600.0, warmup=50.0, seed=17)
    arrays, kv, res = run_batch([s], [K])
    des = s.simulator(K).run()
    batch_soj = float(res.sojourn(kv, arrays.mu, arrays.group, arrays.alpha,
                                 ca2=arrays.ca2, cs2=arrays.cs2)[0])
    assert batch_soj == pytest.approx(des.mean_visit_sum, rel=tol)
    np.testing.assert_allclose(res.arrival_rate[0], des.per_op_arrival_rate, rtol=0.06)
    assert res.dropped[0].sum() / max(res.offered[0].sum(), 1e-9) < 0.01


@pytest.mark.slow
def test_controlled_matrix_32_scenarios():
    """The CI smoke matrix: 32 scenarios end-to-end through the control
    loop; every scenario must finish with a feasible, bounded outcome."""
    from repro.api import ScenarioRunner

    scens = scenario_matrix(32, seed=42, horizon=40.0, warmup=5.0)
    reports = ScenarioRunner(scens, tick_interval=5.0).run()
    assert len(reports) == 32
    for r in reports:
        assert r.provisioned_total >= 1
        assert 0.0 <= r.drop_rate <= 1.0
        assert len(r.actions) == len(r.allocations) > 0
    # the matrix must exercise the interesting action space somewhere
    all_actions = {a for r in reports for a in r.actions}
    assert {"rebalance", "none"} <= all_actions


def test_conformance_flash_crowd_direction():
    """A flash crowd sheds during the burst in both simulators, and the
    batch sim sees the same post-burst recovery (bounded final backlog)."""
    s = scenario(
        traces={"a": ArrivalTrace(kind="flash", rate=8.0, peak=40.0,
                                  t_on=40.0, t_off=60.0)},
        overload_policy="shed-oldest", queue_capacity=25,
        seed=13, horizon=120.0, warmup=10.0,
    )
    arrays, kv, res = run_batch([s], [K])
    des = s.simulator(K).run()
    assert res.dropped[0].sum() > 0 and des.dropped > 0
    assert res.q_final[0].max() < 30  # recovered after the burst
    rel = res.dropped[0].sum() / max(des.dropped, 1)
    assert 0.6 < rel < 1.6
