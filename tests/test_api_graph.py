"""Tests for the declarative AppGraph + DRSSession API (repro.api).

Covers: graph -> routing-matrix round-trips for split/join/loop shapes,
construction-time validation errors, scheduler wiring derived from the
graph, and the flagship acceptance check — ONE AppGraph binding unmodified
to both the live StreamEngine and the DES NetworkSimulator with identical
traffic equations.
"""

import time

import numpy as np
import pytest

from repro.api import (
    AppGraph,
    DESBackend,
    Edge,
    EngineBackend,
    GraphValidationError,
    OpDef,
    SchedulerConfig,
    UnstableTopologyError,
)
from repro.serving.pipeline import ServingModel, StageRates
from repro.streaming.apps.fpd import FPDConfig, build_fpd_graph
from repro.streaming.apps.vld import VLDConfig, build_vld_graph, logo_library


# --------------------------------------------------------------------- #
# Graph -> routing matrix round-trips
# --------------------------------------------------------------------- #
def test_chain_roundtrip_vld_shape():
    g = AppGraph.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)
    expect = np.zeros((3, 3))
    expect[0][1] = 1.0
    expect[1][2] = 1.0
    np.testing.assert_array_equal(g.routing_matrix(), expect)
    np.testing.assert_array_equal(g.lam0_vector(), [13.0, 0.0, 0.0])
    np.testing.assert_allclose(g.topology().arrival_rates, [13.0, 13.0, 13.0])
    assert g.names == ["extract", "match", "agg"]
    assert g.index == {"extract": 0, "match": 1, "agg": 2}


def test_split_join_roundtrip():
    # A -> (B, C) -> D (paper Fig. 2 without the loop)
    g = AppGraph(
        [OpDef(n, 10.0) for n in "ABCD"],
        [Edge("A", "B", 0.5), Edge("A", "C", 0.5), Edge("B", "D"), Edge("C", "D")],
        {"A": 8.0},
    )
    r = g.routing_matrix()
    assert r[0][1] == 0.5 and r[0][2] == 0.5 and r[1][3] == 1.0 and r[2][3] == 1.0
    np.testing.assert_allclose(g.topology().arrival_rates, [8.0, 4.0, 4.0, 8.0])


def test_leaking_self_loop_roundtrip_fpd_shape():
    g = AppGraph(
        [OpDef("gen", 10.0), OpDef("det", 12.0), OpDef("rep", 40.0)],
        [Edge("gen", "det"), Edge("det", "det", 0.35), Edge("det", "rep", 0.65)],
        {"gen": 5.0},
    )
    lam = g.topology().arrival_rates
    assert lam[1] == pytest.approx(5.0 / 0.65)  # amplification 1/(1-p)
    assert lam[2] == pytest.approx(5.0)


def test_fanout_multiplicity_above_one():
    g = AppGraph(
        [OpDef("ext", 2.0), OpDef("match", 30.0)],
        [Edge("ext", "match", 7.0)],  # 7 features per frame on average
        {"ext": 13.0},
    )
    np.testing.assert_allclose(g.topology().arrival_rates, [13.0, 91.0])


def test_k_vector_dict_roundtrip():
    g = AppGraph.chain([("a", 2.0), ("b", 5.0)], lam0=1.0)
    np.testing.assert_array_equal(g.k_vector({"b": 3, "a": 7}), [7, 3])
    assert g.k_dict([7, 3]) == {"a": 7, "b": 3}
    with pytest.raises(GraphValidationError):
        g.k_vector({"a": 7})  # missing operator
    with pytest.raises(GraphValidationError):
        g.k_vector([1, 2, 3])  # wrong shape


def test_mu_overrides_compile_into_topology():
    g = AppGraph.chain([("a", 2.0), ("b", 5.0)], lam0=1.0)
    top = g.topology(mu={"b": 9.0})
    assert top.operators[0].mu == 2.0
    assert top.operators[1].mu == 9.0
    with pytest.raises(GraphValidationError):
        g.topology(mu={"zzz": 1.0})


# --------------------------------------------------------------------- #
# Construction-time validation
# --------------------------------------------------------------------- #
def test_non_leaking_loop_raises_at_construction():
    with pytest.raises(UnstableTopologyError):
        AppGraph(
            [OpDef("a", 1.0), OpDef("b", 1.0)],
            [Edge("a", "b"), Edge("b", "a")],  # a->b->a forever
            {"a": 1.0},
        )


def test_full_strength_self_loop_raises():
    with pytest.raises(UnstableTopologyError):
        AppGraph([OpDef("d", 1.0)], [Edge("d", "d", 1.0)], {"d": 1.0})


def test_unknown_edge_endpoint_raises():
    with pytest.raises(GraphValidationError, match="unknown operator"):
        AppGraph([OpDef("a", 1.0)], [Edge("a", "ghost")], {"a": 1.0})


def test_duplicate_names_raise():
    with pytest.raises(GraphValidationError, match="duplicate"):
        AppGraph([OpDef("a", 1.0), OpDef("a", 2.0)], [], {"a": 1.0})


def test_bad_rates_raise():
    with pytest.raises(GraphValidationError):
        AppGraph([OpDef("a", 0.0)], [], {"a": 1.0})  # mu must be > 0
    with pytest.raises(GraphValidationError):
        AppGraph([OpDef("a", 1.0)], [], {"ghost": 1.0})  # unknown source
    with pytest.raises(GraphValidationError):
        AppGraph([OpDef("a", 1.0)], [Edge("a", "a", -0.5)], {"a": 1.0})
    with pytest.raises(GraphValidationError):
        AppGraph([OpDef("a", 1.0), OpDef("b", 1.0)],
                 [Edge("a", "b"), Edge("a", "b")], {"a": 1.0})  # dup edge


def test_engine_backend_requires_fns():
    g = AppGraph.chain([("a", 2.0), ("b", 5.0)], lam0=1.0)  # model-only
    with pytest.raises(GraphValidationError, match="compute fn"):
        g.bind("engine")


def test_unknown_backend_name_raises():
    g = AppGraph.chain([("a", 2.0)], lam0=1.0)
    with pytest.raises(GraphValidationError, match="unknown backend"):
        g.bind("storm")


# --------------------------------------------------------------------- #
# Session wiring
# --------------------------------------------------------------------- #
def test_session_plan_and_split():
    g = AppGraph.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)
    session = g.bind("des")
    best = session.plan(k_max=22)
    assert best.k.sum() == 22
    split = session.split(best)
    assert set(split) == {"extract", "match", "agg"}
    assert sum(split.values()) == 22


def test_session_scheduler_derived_from_graph():
    """The scheduler's names/routing/scaling all come from the graph —
    no positional hand-syncing anywhere."""
    g = AppGraph(
        [
            OpDef("host", 100.0, fn=lambda x: [("gang", x)]),
            OpDef("gang", 3.0, fn=lambda x: [], scaling="group", group_alpha=0.02),
        ],
        [Edge("host", "gang")],
        {"host": 1.0},
    )
    session = g.bind("engine", config=SchedulerConfig(k_max=4))
    session.start({"host": 1, "gang": 1})
    sched = session.scheduler
    assert sched.names == g.names
    np.testing.assert_array_equal(sched.base_routing, g.routing_matrix())
    assert sched.scaling == ["replica", "group"]
    assert sched.group_alpha == [0.0, 0.02]
    session.stop()


def test_engine_session_tick_applies_rebalance():
    """Live loop end-to-end: a starved first operator gets workers after
    tick() — decision application is the session's job, not the caller's."""
    g = AppGraph(
        [
            OpDef("slow", 50.0, fn=lambda x: [("fast", x)]),
            OpDef("fast", 5000.0, fn=lambda x: []),
        ],
        [Edge("slow", "fast")],
        {"slow": 100.0},
    )
    session = g.bind(
        "engine", config=SchedulerConfig(k_max=6, min_improvement=0.0)
    )
    session.start({"slow": 1, "fast": 1})
    t_end = time.time() + 2.0
    while time.time() < t_end:
        session.inject("tuple")
        time.sleep(0.002)
    decision = session.tick()
    assert decision.action in ("rebalance", "none")
    if decision.action == "rebalance":
        # the engine was actually rescaled to match the scheduler
        assert session.backend.engine.k() == session.allocation
    assert session.drain(timeout=10.0)
    session.stop()
    assert len(session.completed_sojourns) > 0


# --------------------------------------------------------------------- #
# The acceptance check: one graph, two backends, same traffic equations
# --------------------------------------------------------------------- #
def test_one_graph_binds_to_both_backends_identical_traffic():
    g = AppGraph(
        [
            OpDef("gen", 10.0, fn=lambda x: [("det", x)]),
            OpDef("det", 12.0, fn=lambda x: []),
            OpDef("rep", 40.0, fn=lambda x: []),
        ],
        [Edge("gen", "det"), Edge("det", "det", 0.35), Edge("det", "rep", 0.65)],
        {"gen": 5.0},
    )
    eng = g.bind("engine")
    des = g.bind("des", seed=3, horizon=400.0, warmup=40.0)
    assert isinstance(eng.backend, EngineBackend)
    assert isinstance(des.backend, DESBackend)

    # Identical model compilation from the single declaration...
    t_eng, t_des = eng.topology(), des.topology()
    np.testing.assert_array_equal(t_eng.routing, t_des.routing)
    np.testing.assert_array_equal(t_eng.lam0, t_des.lam0)
    np.testing.assert_allclose(t_eng.arrival_rates, t_des.arrival_rates)
    # ...and the engine-side scheduler sees the very same routing.
    eng.start({"gen": 1, "det": 1, "rep": 1})
    np.testing.assert_array_equal(eng.scheduler.base_routing, t_des.routing)
    eng.stop()

    # The DES realises those traffic equations empirically.
    res = des.simulate({"gen": 1, "det": 2, "rep": 1})
    np.testing.assert_allclose(
        res.per_op_arrival_rate, t_des.arrival_rates, rtol=0.1
    )


def test_vld_graph_runs_on_both_backends():
    cfg = VLDConfig(height=32, width=32, max_keypoints=16, n_logos=4)
    lib = logo_library(cfg)
    graph, detections = build_vld_graph(cfg, lib)

    # DES side: model validation without touching JAX compute.
    des = graph.bind("des", seed=1, horizon=200.0, warmup=20.0)
    res = des.simulate({"extract": 8, "match": 4, "aggregate": 1})
    np.testing.assert_allclose(
        res.per_op_arrival_rate, des.topology().arrival_rates, rtol=0.15
    )

    # Engine side: the same graph object runs frames for real.
    from repro.streaming.apps.vld import make_frame

    eng = graph.bind("engine")
    eng.start({"extract": 2, "match": 1, "aggregate": 1})
    rng = np.random.default_rng(5)
    n = 6
    for _ in range(n):
        eng.inject(make_frame(cfg, rng, np.asarray(lib), rng.random() < 0.5))
    assert eng.drain(timeout=30.0)
    eng.stop()
    assert len(detections) == n


def test_fpd_graph_self_loop_on_engine():
    cfg = FPDConfig(n_items=8, max_pattern_size=2, window=16, support_threshold=4)
    graph, state, reports = build_fpd_graph(cfg)
    assert graph.routing_matrix()[1][1] == pytest.approx(0.3)  # declared loop
    session = graph.bind("engine")
    session.start({"generate": 1, "detect": 1, "report": 1})
    from repro.streaming.apps.fpd import pack_itemset, random_transaction

    rng = np.random.default_rng(6)
    hot = pack_itemset([0, 1])
    for i in range(24):
        mask = hot if i % 2 == 0 else random_transaction(cfg, rng)
        session.inject((mask, True))
    assert session.drain(timeout=30.0)
    session.stop()
    assert len(reports) > 0
    assert hot in state.current_mfps()


def test_serving_graph_declares_decode_loop():
    model = ServingModel(
        StageRates(prefill_per_chip=0.5, decode_per_chip=40.0),
        mean_output_tokens=32.0,
        group_alpha=0.0,
        host_tokenize_rate=500.0,
    )
    g = model.graph(lam0=2.0)
    assert g.names == ["tokenize", "prefill", "decode", "detokenize"]
    r = g.routing_matrix()
    assert r[2][2] == pytest.approx(1.0 - 1.0 / 32.0)
    lam = g.topology().arrival_rates
    assert lam[2] == pytest.approx(2.0 * 32.0)  # one decode visit per token
    # group-scaled ops collapse to single effective servers in the DES
    from repro.api.session import _group_effective_services

    services, k_eff = _group_effective_services(g.topology(), g.k_vector(
        {"tokenize": 1, "prefill": 8, "decode": 10, "detokenize": 1}
    ))
    np.testing.assert_array_equal(k_eff, [1, 1, 1, 1])
    assert services[1].rate == pytest.approx(0.5 * 8)
    assert services[2].rate == pytest.approx(40.0 * 10)
