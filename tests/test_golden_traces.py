"""Golden-trace regression: the committed VLD / FPD control-loop decision
traces — and the proactive forecast/MPC trace on the flash-crowd VLD —
must replay bit-for-bit on the decision surface (ISSUE 4 + §15).

The fixtures live in ``tests/golden/*.json``; regenerate after an
*intentional* decision-path change with::

    PYTHONPATH=src python tests/golden/regen.py

and commit the diff with the change (DESIGN.md §13).  Actions and
allocations are exact; scalar metrics compare with a small tolerance so a
benign float reordering doesn't fail the suite.
"""

import importlib.util
import json
import pathlib

import pytest

from repro.streaming.scenarios import control_trace

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"

# The fixture list (scenario + proactive cfg per name) lives in regen.py
# so the drift guard and this replay can never disagree about what a
# fixture is.
_spec = importlib.util.spec_from_file_location("golden_regen", GOLDEN / "regen.py")
_regen = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_regen)

ENTRIES = {
    name: (scenario, proactive)
    for name, scenario, proactive, _tick in _regen.entries()
}


def _replay(name):
    path = GOLDEN / f"{name}_control_trace.json"
    want = json.loads(path.read_text())
    scenario, proactive = ENTRIES[name]
    got = control_trace(
        [scenario], tick_interval=want["tick_interval"], proactive=proactive
    )
    return want["scenarios"][name], got["scenarios"][name]


@pytest.mark.parametrize("name", ["vld", "fpd", "vld_proactive", "vld_fused",
                                  "soak"])
def test_golden_trace_replays(name):
    want, got = _replay(name)
    assert got["actions"] == want["actions"], (
        f"{name} control-loop action sequence drifted; if intentional, "
        "regenerate with: PYTHONPATH=src python tests/golden/regen.py"
    )
    assert got["allocations"] == want["allocations"], (
        f"{name} per-tick allocations drifted; if intentional, regenerate "
        "with: PYTHONPATH=src python tests/golden/regen.py"
    )
    assert got["provisioned_total"] == want["provisioned_total"]
    assert got["optimal_total"] == want["optimal_total"]
    assert got["trajectory"] == want["trajectory"], (
        f"{name} per-tick trajectory (k/miss/mpc_used) drifted; if "
        "intentional, regenerate the goldens"
    )
    for metric in ("drop_rate", "mean_sojourn", "deadline_miss_rate"):
        assert got[metric] == pytest.approx(want[metric], rel=1e-6, abs=1e-9), metric


@pytest.mark.parametrize("fused_decide", [True, False])
def test_golden_trace_replays_through_fused_jit_loop(fused_decide):
    """The jit-eligible golden fixture replays bit-for-bit through the
    fused jax loop — with the ``kernels/decide_fused`` knob ON (interpret
    mode) and off.  The fixture itself is twin-generated, so this pins
    twin == jit two-pass == jit fused on the decision surface."""
    path = GOLDEN / "vld_fused_control_trace.json"
    want = json.loads(path.read_text())["scenarios"]["vld_fused"]
    scenario, proactive = ENTRIES["vld_fused"]
    got = control_trace(
        [scenario], tick_interval=10.0, proactive=proactive,
        backend="jax", interpret=True, fused_decide=fused_decide,
    )["scenarios"]["vld_fused"]
    assert got["actions"] == want["actions"], (
        "fused-knob replay drifted from the committed golden decision "
        "sequence — the fused dispatch must be bit-exact on CPU"
    )
    assert got["allocations"] == want["allocations"]
    assert got["provisioned_total"] == want["provisioned_total"]
    for key in ("k_total", "miss", "warm"):
        assert got["trajectory"][key] == want["trajectory"][key], key
    for metric in ("drop_rate", "mean_sojourn", "deadline_miss_rate"):
        assert got[metric] == pytest.approx(want[metric], rel=1e-6, abs=1e-9), metric


def test_golden_traces_are_nontrivial():
    """The fixtures must actually exercise the control loop: elastic
    scale-out/in and the §11 overloaded path both appear."""
    for name in ("vld", "fpd"):
        want = json.loads((GOLDEN / f"{name}_control_trace.json").read_text())
        actions = set(want["scenarios"][name]["actions"])
        assert "overloaded" in actions, name
        assert {"scale_in", "scale_out"} & actions, name
        totals = [
            sum(a.values()) for a in want["scenarios"][name]["allocations"]
        ]
        assert len(set(totals)) > 1, f"{name} allocation never changed"


def test_golden_proactive_trace_is_nontrivial():
    """The proactive fixture must prove the forecast/MPC plane actually
    drove decisions: committed MPC plans appear alongside the per-tick
    mpc_used/confident trajectory."""
    want = json.loads((GOLDEN / "vld_proactive_control_trace.json").read_text())
    assert want["proactive"] is True
    scen = want["scenarios"]["vld_proactive"]
    assert "proactive" in scen["actions"]
    traj = scen["trajectory"]
    assert sum(traj["mpc_used"]) > 0
    assert sum(traj["confident"]) >= sum(traj["mpc_used"])
