"""Golden-trace regression: the committed VLD / FPD control-loop decision
traces must replay bit-for-bit on the decision surface (ISSUE 4).

The fixtures live in ``tests/golden/*.json``; regenerate after an
*intentional* decision-path change with::

    PYTHONPATH=src python tests/golden/regen.py

and commit the diff with the change (DESIGN.md §13).  Actions and
allocations are exact; scalar metrics compare with a small tolerance so a
benign float reordering doesn't fail the suite.
"""

import json
import pathlib

import pytest

from repro.streaming.scenarios import control_trace, fpd_scenario, vld_scenario

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"


def _replay(name, scenario):
    path = GOLDEN / f"{name}_control_trace.json"
    want = json.loads(path.read_text())
    got = control_trace([scenario], tick_interval=want["tick_interval"])
    return want["scenarios"][name], got["scenarios"][name]


@pytest.mark.parametrize(
    "name,factory", [("vld", vld_scenario), ("fpd", fpd_scenario)]
)
def test_golden_trace_replays(name, factory):
    want, got = _replay(name, factory())
    assert got["actions"] == want["actions"], (
        f"{name} control-loop action sequence drifted; if intentional, "
        "regenerate with: PYTHONPATH=src python tests/golden/regen.py"
    )
    assert got["allocations"] == want["allocations"], (
        f"{name} per-tick allocations drifted; if intentional, regenerate "
        "with: PYTHONPATH=src python tests/golden/regen.py"
    )
    assert got["provisioned_total"] == want["provisioned_total"]
    assert got["optimal_total"] == want["optimal_total"]
    for metric in ("drop_rate", "mean_sojourn", "deadline_miss_rate"):
        assert got[metric] == pytest.approx(want[metric], rel=1e-6, abs=1e-9), metric


def test_golden_traces_are_nontrivial():
    """The fixtures must actually exercise the control loop: elastic
    scale-out/in and the §11 overloaded path both appear."""
    for name, factory in (("vld", vld_scenario), ("fpd", fpd_scenario)):
        want = json.loads((GOLDEN / f"{name}_control_trace.json").read_text())
        actions = set(want["scenarios"][name]["actions"])
        assert "overloaded" in actions, name
        assert {"scale_in", "scale_out"} & actions, name
        totals = [
            sum(a.values()) for a in want["scenarios"][name]["allocations"]
        ]
        assert len(set(totals)) > 1, f"{name} allocation never changed"
