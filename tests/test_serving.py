"""Serving pipeline under DRS: Jackson self-loop model + DES validation."""

import numpy as np
import pytest

from repro.core.allocator import InsufficientResourcesError, assign_processors
from repro.serving.pipeline import ServingModel, StageRates
from repro.serving.router import ServingSimulation


@pytest.fixture
def model():
    # per-chip rates: prefill 0.5 prompts/s/chip, decode 40 tokens/s/chip
    return ServingModel(
        StageRates(prefill_per_chip=0.5, decode_per_chip=40.0),
        mean_output_tokens=32.0,
        group_alpha=0.0,
        host_tokenize_rate=500.0,
    )


def test_decode_traffic_amplified_by_output_len(model):
    top = model.topology(lam0=2.0)
    lam = top.arrival_rates
    assert lam[1] == pytest.approx(2.0)  # prefill sees raw request rate
    assert lam[2] == pytest.approx(2.0 * 32.0)  # decode: one visit per token


def test_drs_split_gives_decode_enough_chips(model):
    """At 32 tokens/request, decode needs ~lam*32/40 chips vs prefill's
    lam/0.5 — DRS must respect both stability floors."""
    sim = ServingSimulation(model, lam0=4.0)
    split = sim.drs_allocation(k_max=24)
    assert split["prefill"] >= int(np.ceil(4.0 / 0.5))  # stability
    assert split["decode"] >= int(np.ceil(4.0 * 32 / 40.0))
    assert sum(split.values()) == 24


def test_infeasible_budget_raises(model):
    top = model.topology(lam0=4.0)
    with pytest.raises(InsufficientResourcesError):
        assign_processors(top, 5)


def test_des_latency_matches_jackson_model(model):
    sim = ServingSimulation(model, lam0=3.0, horizon=2000.0, warmup=200.0, seed=3)
    split = sim.drs_allocation(k_max=20)
    rep = sim.run(split)
    assert rep.completed > 2000
    # chain + self-loop: DES complete-latency ~ model (visit sums overlap-free)
    assert rep.mean_latency == pytest.approx(rep.model_latency, rel=0.25)


def test_drs_split_beats_naive_splits(model):
    """DRS allocation vs plausible hand splits at the same budget."""
    k_max = 20
    sim = ServingSimulation(model, lam0=3.0, horizon=1500.0, warmup=150.0, seed=4)
    drs = sim.drs_allocation(k_max)
    drs_lat = sim.run(drs).mean_latency
    naive_candidates = []
    # even split / prefill-heavy / decode-heavy (keeping host fixed)
    host = {"tokenize": drs["tokenize"], "detokenize": drs["detokenize"]}
    budget = k_max - host["tokenize"] - host["detokenize"]
    top = model.topology(3.0)
    k_min = top.min_feasible_allocation()
    for frac in (0.35, 0.5, 0.65):
        pre = max(int(budget * frac), int(k_min[1]))
        dec = budget - pre
        if dec < int(k_min[2]):
            continue
        naive_candidates.append({**host, "prefill": pre, "decode": dec})
    assert naive_candidates
    for cand in naive_candidates:
        lat = sim.run(cand).mean_latency
        assert drs_lat <= lat * 1.1  # DRS within noise of every candidate...
    # ...and strictly better than the worst one
    worst = max(sim.run(c).mean_latency for c in naive_candidates)
    assert drs_lat < worst


def test_rebalance_recovers_latency(model):
    """Start with a decode-starved split; DRS rebalances mid-run."""
    sim = ServingSimulation(model, lam0=3.0, horizon=1200.0, warmup=0.0, seed=5)
    top = model.topology(3.0)
    k_min = top.min_feasible_allocation()
    bad = {"tokenize": 1, "prefill": 13, "decode": max(int(k_min[2]), 3), "detokenize": 1}
    good = sim.drs_allocation(sum(bad.values()))
    rep = sim.run(bad, rebalance_to=good, rebalance_at=600.0)
    ts = np.array([t for t, _ in rep.sojourn_series])
    sj = np.array([s for _, s in rep.sojourn_series])
    before = sj[(ts > 100) & (ts < 600)].mean()
    after = sj[ts > 700].mean()
    assert after < before


def test_group_scaling_efficiency_rolloff():
    m = ServingModel(
        StageRates(0.5, 40.0), mean_output_tokens=16.0, group_alpha=0.05
    )
    top = m.topology(2.0)
    pre = top.operators[1]
    t8 = pre.sojourn(8, 2.0)
    t16 = pre.sojourn(16, 2.0)
    assert t16 < t8  # more chips still help
    # but with diminishing returns vs linear
    lin8 = pre.mu * 8
    eff16 = pre.mu * 16 / (1 + 0.05 * 15)
    assert eff16 < 2 * lin8
