"""Forecast/MPC subsystem (DESIGN.md §15): predictor properties, the
numpy-twin vs jit agreement contract, confidence-gate semantics, and the
proactive control plane end to end (twin, fused lax.scan, live scheduler).

The agreement gate mirrors the rest of the repo's twin/jit discipline:
every predictor and the whole MPC planner are written once against an
``xp`` array namespace, so the float64 twin and the x64 jit path must
agree to <= 1e-9 on identical inputs.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api.session import ScenarioRunner
from repro.core.measurer import MeasurementSnapshot
from repro.core.scheduler import DRSScheduler, SchedulerConfig
from repro.forecast import (
    MPCConfig,
    PredictorParams,
    confidence,
    error_init,
    error_update,
    forecast_rates,
    history_init,
    history_push,
    mase,
    mpc_plan,
    smape,
)
from repro.kernels.gain_topr import ops as topr_ops
from repro.streaming.scenarios import ArrivalTrace, vld_scenario

ATOL = 1e-9


# ------------------------------------------------------------------ #
# Predictor properties
# ------------------------------------------------------------------ #
def test_ewma_flat_history_predicts_level():
    hist = np.full((2, 8, 3), 7.5)
    pred = forecast_rates(hist, 4, PredictorParams(kind="ewma", alpha=0.4))
    np.testing.assert_allclose(pred, 7.5, atol=1e-12)
    assert pred.shape == (2, 4, 3)


def test_holt_extrapolates_linear_ramp():
    t = np.arange(30.0)
    hist = (5.0 + 2.0 * t)[None, :, None]  # slope 2 per tick
    pred = forecast_rates(hist, 3, PredictorParams(kind="holt", alpha=0.5, beta=0.3))
    last = hist[0, -1, 0]
    # Holt's trend converges onto the slope of a clean ramp, so the
    # h-step forecast continues it: last + 2*(h+1).
    np.testing.assert_allclose(pred[0, :, 0], last + 2.0 * np.arange(1, 4),
                               rtol=1e-3)


def test_holt_forecasts_clamped_nonnegative():
    t = np.arange(10.0)
    hist = (20.0 - 3.0 * t)[None, :, None]  # heading below zero
    pred = forecast_rates(hist, 6, PredictorParams(kind="holt"))
    assert (pred >= 0.0).all()


def test_seasonal_replays_last_season():
    season = 4
    base = np.array([3.0, 9.0, 6.0, 12.0])
    hist = np.tile(base, 3)[None, :, None]  # 3 full seasons
    pred = forecast_rates(
        hist, 2 * season,
        PredictorParams(kind="seasonal", season=season),
    )
    np.testing.assert_allclose(pred[0, :, 0], np.tile(base, 2), atol=1e-12)


def test_predictor_params_validation():
    with pytest.raises(ValueError):
        PredictorParams(kind="nope")
    with pytest.raises(ValueError):
        PredictorParams(kind="holt", alpha=1.5)
    with pytest.raises(ValueError):
        PredictorParams(kind="seasonal", season=0)


def test_history_push_backfills_first_observation():
    hist = history_init(1, 5, 2)
    n_obs = np.zeros(1)
    y = np.array([[4.0, 6.0]])
    h1 = history_push(hist, y, n_obs)
    # First observation fills the whole window — no phantom 0 -> rate step.
    np.testing.assert_array_equal(h1, np.broadcast_to(y[:, None, :], (1, 5, 2)))
    h2 = history_push(h1, np.array([[8.0, 2.0]]), n_obs + 1.0)
    np.testing.assert_array_equal(h2[0, -1], [8.0, 2.0])
    np.testing.assert_array_equal(h2[0, :-1], h1[0, 1:])


# ------------------------------------------------------------------ #
# Online error tracking + the confidence gate
# ------------------------------------------------------------------ #
def _score_series(preds, ys):
    state = error_init(1, 1)
    for p, y in zip(preds, ys):
        state = error_update(state, np.array([[p]]), np.array([[y]]))
    return state


def test_error_tracking_perfect_predictor_opens_gate():
    ys = [10.0, 12.0, 11.0, 13.0, 12.0, 14.0]
    # prev_pred scored against y: feed y itself one tick early.
    state = error_init(1, 1)
    for i, y in enumerate(ys):
        nxt = ys[i + 1] if i + 1 < len(ys) else y
        state = error_update(state, np.array([[nxt]]), np.array([[y]]))
    assert smape(state)[0, 0] < 1e-6
    conf = confidence(state, np.ones((1, 1), bool),
                      min_scored=3, mase_gate=2.0, smape_gate=0.25)
    assert bool(conf[0])


def test_error_tracking_bad_predictor_closes_gate():
    # Predict 1.0 forever against a series living at ~20: sMAPE ~ 1.8.
    state = _score_series([1.0] * 8, [20.0, 22.0, 18.0, 21.0, 19.0, 23.0, 20.0, 22.0])
    assert smape(state)[0, 0] > 1.0
    conf = confidence(state, np.ones((1, 1), bool),
                      min_scored=3, mase_gate=2.0, smape_gate=0.25)
    assert not bool(conf[0])


def test_confidence_needs_min_scored():
    state = _score_series([5.0, 5.0], [5.0, 5.0])  # only 1 scored comparison
    conf = confidence(state, np.ones((1, 1), bool),
                      min_scored=3, mase_gate=2.0, smape_gate=0.25)
    assert not bool(conf[0])


def test_mase_compares_against_naive_forecast():
    ys = [10.0, 14.0, 10.0, 14.0, 10.0, 14.0]
    state = _score_series([12.0] * 6, ys)  # always-mean predictor
    # Naive (last value) is off by 4 every step; the mean predictor by 2.
    assert mase(state)[0, 0] == pytest.approx(0.5, rel=1e-9)


# ------------------------------------------------------------------ #
# Twin vs jit agreement (the repo's <= 1e-9 x64 contract)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("kind", ["ewma", "holt", "seasonal"])
def test_forecast_rates_twin_vs_jit(kind):
    rng = np.random.default_rng(3)
    hist = rng.uniform(1.0, 25.0, (5, 12, 4))
    pp = PredictorParams(kind=kind, alpha=0.55, beta=0.35,
                         season=6 if kind == "seasonal" else 0)
    with jax.experimental.enable_x64():
        want = forecast_rates(hist, 4, pp, xp=np)
        got = jax.jit(lambda h: forecast_rates(h, 4, pp, xp=jnp))(
            jnp.asarray(hist))
        np.testing.assert_allclose(np.asarray(got), want, atol=ATOL, rtol=0)


def test_mpc_plan_twin_vs_jit():
    rng = np.random.default_rng(17)
    b, n, hzn, k_hi = 6, 4, 3, 40
    lam_pred = rng.uniform(1.0, 18.0, (b, hzn, n))
    q0 = rng.uniform(0.0, 8.0, (b, n))
    k_cur = rng.integers(1, 7, (b, n)).astype(np.int64)
    kw = dict(
        mu=rng.uniform(2.0, 9.0, (b, n)),
        group=np.zeros((b, n)),
        alpha=np.zeros((b, n)),
        speed=np.ones((b, n)),
        active=np.ones((b, n), dtype=bool),
        src_mask=(np.arange(n)[None, :] == 0).repeat(b, axis=0),
        cap_queue=np.full((b, n), np.inf),
        t_max=np.where(np.arange(b) % 2 == 0, 3.0, np.inf),
        k_max=np.full(b, 48, dtype=np.int64),
        span=10.0, cfg=MPCConfig(horizon=hzn, window=12), k_hi=k_hi,
    )
    with jax.experimental.enable_x64():
        want = mpc_plan(lam_pred, q0, k_cur, xp=np, **kw)
        got = jax.jit(
            lambda lp, q, k: mpc_plan(lp, q, k, xp=jnp,
                                      topr=topr_ops.gain_topr, **kw)
        )(jnp.asarray(lam_pred), jnp.asarray(q0), jnp.asarray(k_cur))
    for name, a, bj in zip(("k_plan", "any_ok", "et_hold", "et_plan", "need"),
                           want, got):
        av = np.asarray(a, dtype=float)
        bv = np.asarray(bj, dtype=float)
        np.testing.assert_array_equal(np.isfinite(av), np.isfinite(bv),
                                      err_msg=name)
        fin = np.isfinite(av)
        np.testing.assert_allclose(bv[fin], av[fin], atol=ATOL, rtol=0,
                                   err_msg=name)


# ------------------------------------------------------------------ #
# Proactive control plane end to end
# ------------------------------------------------------------------ #
def _ramp_scenario(**kw):
    t5 = np.arange(0.0, 151.0, 5.0)
    ramp = np.interp(t5, [0, 50, 90, 110, 150], [8, 8, 24, 24, 10])
    defaults = dict(
        traces={"extract": ArrivalTrace(kind="replay", samples=tuple(ramp),
                                        sample_dt=5.0)},
        t_max=1.2, queue_capacity=200, machine_size=1, horizon=150.0,
    )
    defaults.update(kw)
    return vld_scenario(**defaults)


def _cfg():
    return MPCConfig(horizon=3, window=12, min_scored=2,
                     predictor=PredictorParams(kind="holt", alpha=0.6, beta=0.4))


def test_proactive_twin_emits_proactive_actions():
    runner = ScenarioRunner([_ramp_scenario()], tick_interval=10.0,
                            backend="numpy", proactive=_cfg())
    rep = runner.run()[0]
    assert "proactive" in rep.actions
    tr = rep.trajectory
    assert set(tr) >= {"t", "k_total", "miss", "warm", "mpc_used", "confident"}
    assert any(tr["mpc_used"])


def test_reactive_runner_has_no_proactive_actions_but_has_trajectory():
    rep = ScenarioRunner([_ramp_scenario()], tick_interval=10.0,
                         backend="numpy").run()[0]
    assert "proactive" not in rep.actions
    tr = rep.trajectory
    assert tr is not None and "mpc_used" not in tr
    assert len(tr["t"]) == len(tr["k_total"]) == len(tr["miss"])


def test_proactive_fused_matches_twin_under_x64():
    scens = [_ramp_scenario(negotiated=False)]
    cfg = _cfg()
    with jax.experimental.enable_x64():
        twin = ScenarioRunner(scens, tick_interval=10.0, backend="numpy",
                              proactive=cfg)
        r_twin = twin.run()[0]
        fused = ScenarioRunner(scens, tick_interval=10.0, backend="jax",
                               proactive=cfg)
        assert fused.fused, "static-budget jax runner should take the fused path"
        r_fused = fused.run()[0]
    assert list(r_twin.actions) == list(r_fused.actions)
    assert r_twin.k_final == r_fused.k_final
    assert r_twin.trajectory["k_total"] == r_fused.trajectory["k_total"]
    assert r_twin.trajectory["mpc_used"] == r_fused.trajectory["mpc_used"]


def test_mmpp_confidence_gate_falls_back_to_reactive():
    scen = vld_scenario(
        name="mmpp",
        traces={"extract": ArrivalTrace(kind="mmpp", rate=4.0, peak=28.0,
                                        switch01=0.08, switch10=0.08)},
        t_max=1.0, queue_capacity=150, machine_size=1, horizon=100.0,
    )
    rep = ScenarioRunner([scen], tick_interval=10.0, backend="numpy",
                         proactive=_cfg()).run()[0]
    assert "proactive" not in rep.actions
    assert not any(rep.trajectory["mpc_used"])


def test_scheduler_live_proactive_scales_ahead_of_ramp():
    names = ["extract", "match"]
    routing = np.array([[0.0, 1.0], [0.0, 0.0]])
    sched = DRSScheduler(
        names, routing, np.array([2, 1]),
        SchedulerConfig(k_max=32, t_max=2.0, tick_interval=10.0),
        proactive=MPCConfig(horizon=3, window=8, min_scored=2,
                            predictor=PredictorParams(kind="holt",
                                                      alpha=0.6, beta=0.4)),
    )
    mu = np.array([2.0, 5.0])
    actions = []
    for i in range(8):
        lam0 = 3.0 + 1.5 * i  # steady ramp the holt predictor locks onto
        lam = np.array([lam0, lam0])
        d = sched.tick_from(
            MeasurementSnapshot.from_rates(lam, mu, lam0, 0.6, 10.0 * i),
            10.0 * i,
        )
        actions.append(d.action)
    assert "proactive" in actions
    # The committed allocation must track the ramp upward.
    assert sched.k_current.sum() > 3


@pytest.mark.slow
@pytest.mark.parametrize("horizon", [1, 2, 4, 6])
def test_slow_mpc_horizon_sweep(horizon):
    """Longer lookahead horizons must stay stable (no worse misses than
    the reactive baseline on the forecastable ramp) and keep the twin
    deterministic across repeated runs."""
    scen = _ramp_scenario()
    cfg = MPCConfig(horizon=horizon, window=12, min_scored=2,
                    predictor=PredictorParams(kind="holt", alpha=0.6, beta=0.4))
    re = ScenarioRunner([scen], tick_interval=10.0, backend="numpy").run()[0]
    pro1 = ScenarioRunner([scen], tick_interval=10.0, backend="numpy",
                          proactive=cfg).run()[0]
    pro2 = ScenarioRunner([scen], tick_interval=10.0, backend="numpy",
                          proactive=cfg).run()[0]
    assert list(pro1.actions) == list(pro2.actions)  # deterministic
    warm = np.asarray(pro1.trajectory["warm"], dtype=bool)

    def misses(rep):
        return int((np.asarray(rep.trajectory["miss"], bool) & warm).sum())

    assert misses(pro1) <= misses(re)
