#!/usr/bin/env python
"""Regenerate (or verify) the committed golden control-loop traces
(DESIGN.md §13).

One command, from the repo root:

    PYTHONPATH=src python tests/golden/regen.py           # rewrite fixtures
    PYTHONPATH=src python tests/golden/regen.py --check   # drift guard (CI)

The default mode rewrites ``vld_control_trace.json`` and
``fpd_control_trace.json`` next to this script.  Run it after an
*intentional* change to the scheduler / batch simulator decision path,
eyeball the diff (actions and allocations are the contract), and commit
the new fixtures together with the change.

``--check`` regenerates into a temporary directory and diffs against the
committed fixtures, exiting non-zero on any difference — CI runs it so a
silent decision-logic change can't leave stale goldens behind.
``tests/test_golden_traces.py`` replays the same scenarios and diffs.
"""

from __future__ import annotations

import json
import pathlib
import sys
import tempfile

HERE = pathlib.Path(__file__).resolve().parent


def generate(out_dir: pathlib.Path) -> list[pathlib.Path]:
    from repro.streaming.scenarios import control_trace, fpd_scenario, vld_scenario

    paths = []
    for name, scenario in (("vld", vld_scenario()), ("fpd", fpd_scenario())):
        trace = control_trace([scenario], tick_interval=10.0)
        path = out_dir / f"{name}_control_trace.json"
        path.write_text(json.dumps(trace, indent=2, sort_keys=True) + "\n")
        paths.append(path)
    return paths


def main() -> int:
    check = "--check" in sys.argv[1:]
    if not check:
        for path in generate(HERE):
            ticks = len(
                next(iter(json.loads(path.read_text())["scenarios"].values()))["actions"]
            )
            print(f"wrote {path} ({ticks} ticks)")
        return 0
    drifted = []
    with tempfile.TemporaryDirectory(prefix="golden-check-") as tmp:
        for fresh in generate(pathlib.Path(tmp)):
            committed = HERE / fresh.name
            if not committed.exists():
                drifted.append(f"{committed} is missing")
            elif committed.read_text() != fresh.read_text():
                drifted.append(f"{committed} differs from a fresh regeneration")
    if drifted:
        for line in drifted:
            print(f"GOLDEN DRIFT: {line}", file=sys.stderr)
        print(
            "The committed golden traces no longer match the decision path.\n"
            "If the change is intentional, regenerate and commit them:\n"
            "    PYTHONPATH=src python tests/golden/regen.py",
            file=sys.stderr,
        )
        return 1
    print("golden traces match a fresh regeneration")
    return 0


if __name__ == "__main__":
    sys.exit(main())
