#!/usr/bin/env python
"""Regenerate (or verify) the committed golden control-loop traces
(DESIGN.md §13).

One command, from the repo root:

    PYTHONPATH=src python tests/golden/regen.py           # rewrite fixtures
    PYTHONPATH=src python tests/golden/regen.py --check   # drift guard (CI)

The default mode rewrites ``vld_control_trace.json``,
``fpd_control_trace.json``, and ``vld_proactive_control_trace.json``
(the forecast/MPC plane on the flash-crowd VLD — proving predictor +
planner replayability, DESIGN.md §15) next to this script.  Run it
after an *intentional* change to the scheduler / batch simulator /
forecast decision path, eyeball the diff (actions and allocations are
the contract), and commit the new fixtures together with the change.

``--check`` regenerates into a temporary directory and diffs against the
committed fixtures, exiting non-zero on any difference — CI runs it so a
silent decision-logic change can't leave stale goldens behind.
``tests/test_golden_traces.py`` replays the same scenarios and diffs.
"""

from __future__ import annotations

import json
import pathlib
import sys
import tempfile

HERE = pathlib.Path(__file__).resolve().parent


def entries():
    """(fixture name, scenario, proactive cfg | None, tick_interval) — the
    one list both this script and tests/test_golden_traces.py replay
    from."""
    import dataclasses

    import numpy as np

    from repro.forecast import MPCConfig, PredictorParams
    from repro.streaming.scenarios import ArrivalTrace, fpd_scenario, vld_scenario
    from repro.streaming.soak import SoakConfig, build_scenario

    mpc = MPCConfig(
        horizon=3, window=12, min_scored=2, headroom=1.1,
        scale_in_hysteresis=0.7,
        predictor=PredictorParams(kind="holt", alpha=0.6, beta=0.4),
    )
    # Flash crowd as a steep ramp (the benchmarks/bench_forecast.py flash
    # scenario): forecastable, so the MPC plane actually commits plans
    # ahead of the trigger instead of just holding.
    t5 = np.arange(0.0, 231.0, 5.0)
    ramp = np.interp(t5, [0, 80, 120, 140, 170, 230], [10, 10, 30, 30, 12, 12])
    flash_vld = vld_scenario(
        name="vld_proactive",
        traces={"extract": ArrivalTrace(kind="replay", samples=tuple(ramp),
                                        sample_dt=5.0)},
        t_max=1.0, queue_capacity=40, machine_size=1, horizon=230.0,
    )
    # The soak harness's smoke-capped composite day (DESIGN.md §17):
    # pins the twin's decision surface for the same scenario
    # tests/test_soak.py drives through the fused checkpointed loop.
    soak = dataclasses.replace(build_scenario(SoakConfig.smoke()), name="soak")
    return [
        ("vld", vld_scenario(), None, 10.0),
        ("fpd", fpd_scenario(), None, 10.0),
        ("vld_proactive", flash_vld, mpc, 10.0),
        # Static-budget VLD: jit-eligible (no negotiator), so this one
        # fixture is ALSO replayed through the fused jax loop with the
        # kernels/decide_fused knob on (tests/test_golden_traces.py) —
        # the knob-on decision surface must match this twin-generated
        # trace bit-for-bit.
        ("vld_fused", vld_scenario(name="vld_fused", negotiated=False), None, 10.0),
        ("soak", soak, None, 120.0),
    ]


def generate(out_dir: pathlib.Path) -> list[pathlib.Path]:
    from repro.streaming.scenarios import control_trace

    paths = []
    for name, scenario, proactive, tick_interval in entries():
        trace = control_trace(
            [scenario], tick_interval=tick_interval, proactive=proactive
        )
        path = out_dir / f"{name}_control_trace.json"
        path.write_text(json.dumps(trace, indent=2, sort_keys=True) + "\n")
        paths.append(path)
    return paths


def main() -> int:
    check = "--check" in sys.argv[1:]
    if not check:
        for path in generate(HERE):
            ticks = len(
                next(iter(json.loads(path.read_text())["scenarios"].values()))["actions"]
            )
            print(f"wrote {path} ({ticks} ticks)")
        return 0
    drifted = []
    with tempfile.TemporaryDirectory(prefix="golden-check-") as tmp:
        for fresh in generate(pathlib.Path(tmp)):
            committed = HERE / fresh.name
            if not committed.exists():
                drifted.append(f"{committed} is missing")
            elif committed.read_text() != fresh.read_text():
                drifted.append(f"{committed} differs from a fresh regeneration")
    if drifted:
        for line in drifted:
            print(f"GOLDEN DRIFT: {line}", file=sys.stderr)
        print(
            "The committed golden traces no longer match the decision path.\n"
            "If the change is intentional, regenerate and commit them:\n"
            "    PYTHONPATH=src python tests/golden/regen.py",
            file=sys.stderr,
        )
        return 1
    print("golden traces match a fresh regeneration")
    return 0


if __name__ == "__main__":
    sys.exit(main())
