#!/usr/bin/env python
"""Regenerate the committed golden control-loop traces (DESIGN.md §13).

One command, from the repo root:

    PYTHONPATH=src python tests/golden/regen.py

Rewrites ``vld_control_trace.json`` and ``fpd_control_trace.json`` next to
this script.  Run it after an *intentional* change to the scheduler /
batch simulator decision path, eyeball the diff (actions and allocations
are the contract), and commit the new fixtures together with the change.
``tests/test_golden_traces.py`` replays the same scenarios and diffs.
"""

from __future__ import annotations

import json
import pathlib
import sys

HERE = pathlib.Path(__file__).resolve().parent


def main() -> None:
    from repro.streaming.scenarios import control_trace, fpd_scenario, vld_scenario

    for name, scenario in (("vld", vld_scenario()), ("fpd", fpd_scenario())):
        trace = control_trace([scenario], tick_interval=10.0)
        path = HERE / f"{name}_control_trace.json"
        path.write_text(json.dumps(trace, indent=2, sort_keys=True) + "\n")
        ticks = len(trace["scenarios"][name]["actions"])
        print(f"wrote {path} ({ticks} ticks)")


if __name__ == "__main__":
    sys.exit(main())
