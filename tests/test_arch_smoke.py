"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; plus a prefill->decode consistency check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import forward, init_params, loss_fn, serve
from repro.models.common import ModelConfig


def make_batch(cfg: ModelConfig, b=2, s=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        p = 4
        batch["patch_embeds"] = jax.random.normal(ks[2], (b, p, cfg.d_model), cfg.dtype)
        batch["positions_3d"] = jnp.broadcast_to(
            jnp.arange(s + p)[None, None], (3, b, s + p)
        ).astype(jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(ks[3], (b, cfg.enc_seq, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_config(arch, "smoke")
    params, axes = init_params(cfg, jax.random.PRNGKey(0))
    # axes tree mirrors params tree
    assert set(axes.keys()) == set(params.keys())
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    logits, aux = jax.jit(lambda p, bt: forward(p, cfg, bt))(params, batch)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    total, metrics = loss_fn(params, cfg, batch)
    assert np.isfinite(float(total))
    assert float(metrics["loss"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_grads_finite(arch):
    cfg = get_config(arch, "smoke")
    params, _ = init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, 2, 16, key=1)

    def loss(p):
        return loss_fn(p, cfg, batch)[0]

    g = jax.jit(jax.grad(loss))(params)
    leaves = jax.tree.leaves(g)
    assert leaves
    for leaf in leaves:
        assert not bool(jnp.isnan(leaf.astype(jnp.float32)).any())
    # at least some gradient signal reaches the embedding
    gnorm = sum(float(jnp.abs(l.astype(jnp.float32)).sum()) for l in leaves)
    assert gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, "smoke")
    params, _ = init_params(cfg, jax.random.PRNGKey(2))
    b, s, s_max = 2, 8, 32
    batch = make_batch(cfg, b, s, key=2)
    cache = serve.init_cache(cfg, b, s_max)
    logits, cache = jax.jit(lambda p, bt, c: serve.prefill(p, cfg, bt, c))(
        params, batch, cache
    )
    assert logits.shape == (b, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, t, c: serve.decode_step(p, cfg, t, c))
    for _ in range(3):
        logits, cache = step(params, tok, cache)
        assert logits.shape == (b, cfg.vocab)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    expected = (s + 3) if cfg.family != "vlm" else (s + 4 + 3)
    assert int(cache["length"]) == expected


@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "rwkv6-1.6b", "zamba2-7b", "mixtral-8x22b"]
)
def test_decode_matches_forward_teacher_forcing(arch):
    """Prefill+decode over a sequence must reproduce forward() logits."""
    cfg = get_config(arch, "smoke")
    params, _ = init_params(cfg, jax.random.PRNGKey(3))
    b, s = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    full_logits, _ = forward(params, cfg, batch)  # [B,S,V]

    cache = serve.init_cache(cfg, b, 16)
    pre_logits, cache = serve.prefill(params, cfg, {"tokens": tokens[:, :4]}, cache)
    np.testing.assert_allclose(
        np.asarray(pre_logits, dtype=np.float32),
        np.asarray(full_logits[:, 3], dtype=np.float32),
        rtol=2e-2, atol=2e-2,
    )
    # decode tokens 4..6 one at a time, comparing to teacher-forced logits
    for t in range(4, 7):
        logits, cache = serve.decode_step(params, cfg, tokens[:, t], cache)
        np.testing.assert_allclose(
            np.asarray(logits, dtype=np.float32),
            np.asarray(full_logits[:, t], dtype=np.float32),
            rtol=5e-2, atol=5e-2,  # bf16 activations: quantum ~0.008 rel
        )


def test_params_count_sane():
    """Full-config parameter counters land in the advertised ballpark."""
    from repro.models.common import ModelConfig  # noqa

    checks = {
        "llama3.2-1b": (0.9e9, 1.8e9),
        "yi-34b": (30e9, 40e9),
        "phi3-medium-14b": (12e9, 16e9),
        "command-r-35b": (30e9, 42e9),
        "mixtral-8x22b": (120e9, 150e9),
        "kimi-k2-1t-a32b": (0.85e12, 1.25e12),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "zamba2-7b": (5e9, 9e9),
        "whisper-medium": (0.6e9, 1.0e9),  # 769M real; ours counts RoPE-dec variant
        "qwen2-vl-2b": (1.2e9, 2.4e9),
    }
    for arch, (lo, hi) in checks.items():
        n = get_config(arch, "full").params_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]B"


def test_moe_active_params_below_total():
    cfg = get_config("kimi-k2-1t-a32b", "full")
    active = cfg.active_params_count()
    total = cfg.params_count()
    assert active < total / 10  # 32B active vs 1T total
    assert 20e9 <= active <= 60e9
