"""Chunked linear-recurrence formulations vs naive per-token recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no dev deps installed — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.models.ssm import rwkv6_chunked, rwkv6_step, ssd_chunked, ssd_step


def _rwkv_naive(r, k, v, w, u, s0):
    """Reference: token-by-token recurrence via rwkv6_step."""
    b, s, h, dk = r.shape
    outs = []
    state = s0
    for t in range(s):
        o, state = rwkv6_step(r[:, t], k[:, t], v[:, t], w[:, t], u, state)
        outs.append(o)
    return jnp.stack(outs, axis=1), state


def _rand_rwkv(b, s, h, dk, dv, seed, w_lo=0.6):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (b, s, h, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, dv)) * 0.5
    w = jax.random.uniform(ks[3], (b, s, h, dk), minval=w_lo, maxval=0.999)
    u = jax.random.normal(ks[4], (h, dk)) * 0.3
    return r, k, v, w, u


@pytest.mark.parametrize("s,chunk", [(32, 32), (64, 32), (96, 16)])
def test_rwkv6_chunked_matches_recurrence(s, chunk):
    b, h, dk, dv = 2, 3, 8, 8
    r, k, v, w, u = _rand_rwkv(b, s, h, dk, dv, 0)
    s0 = jnp.zeros((b, h, dk, dv))
    out_c, st_c = rwkv6_chunked(r, k, v, w, u, chunk=chunk, initial_state=s0)
    out_n, st_n = _rwkv_naive(r, k, v, w, u, s0)
    np.testing.assert_allclose(out_c, out_n, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_c, st_n, rtol=2e-4, atol=2e-4)


def test_rwkv6_chunked_nonzero_initial_state():
    b, s, h, dk, dv = 1, 64, 2, 8, 8
    r, k, v, w, u = _rand_rwkv(b, s, h, dk, dv, 1)
    s0 = jax.random.normal(jax.random.PRNGKey(9), (b, h, dk, dv)) * 0.2
    out_c, st_c = rwkv6_chunked(r, k, v, w, u, chunk=32, initial_state=s0)
    out_n, st_n = _rwkv_naive(r, k, v, w, u, s0)
    np.testing.assert_allclose(out_c, out_n, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_c, st_n, rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 100), w_lo=st.floats(0.3, 0.9))
@settings(max_examples=10, deadline=None)
def test_rwkv6_property_sweep(seed, w_lo):
    b, s, h, dk, dv = 1, 32, 2, 4, 4
    r, k, v, w, u = _rand_rwkv(b, s, h, dk, dv, seed, w_lo=w_lo)
    s0 = jnp.zeros((b, h, dk, dv))
    out_c, _ = rwkv6_chunked(r, k, v, w, u, chunk=16, initial_state=s0)
    out_n, _ = _rwkv_naive(r, k, v, w, u, s0)
    np.testing.assert_allclose(out_c, out_n, rtol=1e-3, atol=1e-3)


def _ssd_naive(x, a, bm, cm, s0):
    b, s, h, dh = x.shape
    outs = []
    state = s0
    for t in range(s):
        y, state = ssd_step(x[:, t], a[:, t], bm[:, t], cm[:, t], state)
        outs.append(y)
    return jnp.stack(outs, axis=1), state


def _rand_ssd(b, s, h, dh, dst, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (b, s, h, dh)) * 0.5
    a = -jax.random.uniform(ks[1], (b, s, h), minval=0.01, maxval=1.0)  # log decay
    bm = jax.random.normal(ks[2], (b, s, h, dst)) * 0.5
    cm = jax.random.normal(ks[3], (b, s, h, dst)) * 0.5
    return x, a, bm, cm


@pytest.mark.parametrize("s,chunk", [(64, 64), (128, 32)])
def test_ssd_chunked_matches_recurrence(s, chunk):
    b, h, dh, dst = 2, 2, 8, 4
    x, a, bm, cm = _rand_ssd(b, s, h, dh, dst, 2)
    s0 = jnp.zeros((b, h, dst, dh))
    y_c, st_c = ssd_chunked(x, a, bm, cm, chunk=chunk, initial_state=s0)
    y_n, st_n = _ssd_naive(x, a, bm, cm, s0)
    np.testing.assert_allclose(y_c, y_n, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_c, st_n, rtol=2e-4, atol=2e-4)


def test_ssd_nonzero_initial_state():
    b, s, h, dh, dst = 1, 64, 2, 8, 4
    x, a, bm, cm = _rand_ssd(b, s, h, dh, dst, 3)
    s0 = jax.random.normal(jax.random.PRNGKey(11), (b, h, dst, dh)) * 0.3
    y_c, st_c = ssd_chunked(x, a, bm, cm, chunk=32, initial_state=s0)
    y_n, st_n = _ssd_naive(x, a, bm, cm, s0)
    np.testing.assert_allclose(y_c, y_n, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_c, st_n, rtol=2e-4, atol=2e-4)


def test_ssd_state_decay_strong_forgets():
    """Strong (but in-envelope) decay: output ~ current-token term only.

    a = -5/step with chunk 16 spans exp(80) — the edge of the documented
    f32 envelope (model layers clamp dt*A well inside it).
    """
    b, s, h, dh, dst = 1, 32, 1, 4, 4
    x, _, bm, cm = _rand_ssd(b, s, h, dh, dst, 4)
    strong = jnp.full((b, s, h), -5.0)
    y_strong, _ = ssd_chunked(x, strong, bm, cm, chunk=16)
    y_direct = jnp.einsum("bshk,bshk->bsh", cm, bm)[..., None] * x
    np.testing.assert_allclose(y_strong, y_direct, rtol=2e-2, atol=2e-2)
