"""DES simulator vs the Erlang/Jackson model — the paper's Fig. 6-8 claims."""


import numpy as np
import pytest

from repro.core import OperatorSpec, Topology, assign_processors
from repro.streaming.des import (
    ArrivalProcess,
    NetworkSimulator,
    ServiceProcess,
    SimConfig,
    simulate_allocation,
)


def test_mm1_sim_matches_theory():
    """Single M/M/1 queue: simulated sojourn ~ 1/(mu - lam)."""
    top = Topology.chain([("op", 10.0)], lam0=6.0)
    res = simulate_allocation(top, [1], seed=1, horizon=2000.0, warmup=100.0)
    assert res.completed > 5000
    assert res.mean_sojourn == pytest.approx(1.0 / (10.0 - 6.0), rel=0.08)


def test_mmk_sim_matches_erlang():
    """M/M/3: simulated sojourn ~ Erlang-C prediction."""
    from repro.core.erlang import expected_sojourn

    top = Topology.chain([("op", 4.0)], lam0=9.0)
    res = simulate_allocation(top, [3], seed=2, horizon=3000.0, warmup=100.0)
    assert res.mean_sojourn == pytest.approx(expected_sojourn(3, 9.0, 4.0), rel=0.08)


def test_chain_visit_sum_matches_eq3():
    """Paper Eq. 3 predicts the *sum of per-visit sojourns*; on a chain the
    complete sojourn equals that sum, so both must match the model."""
    top = Topology.chain([("a", 8.0), ("b", 12.0)], lam0=5.0)
    k = [2, 1]
    res = simulate_allocation(top, k, seed=3, horizon=3000.0, warmup=100.0)
    model = top.expected_sojourn(k)
    assert res.mean_visit_sum == pytest.approx(model, rel=0.08)
    assert res.mean_sojourn == pytest.approx(model, rel=0.08)


def test_loop_topology_visit_sum_matches_eq3():
    """FPD-style self-loop: arrival amplification 1/(1-p) must show up."""
    ops = [OperatorSpec("gen", 10.0), OperatorSpec("det", 12.0), OperatorSpec("rep", 40.0)]
    routing = np.zeros((3, 3))
    routing[0][1] = 1.0
    routing[1][1] = 0.35
    routing[1][2] = 0.65
    top = Topology(ops, np.array([5.0, 0, 0]), routing)
    k = [1, 2, 1]
    res = simulate_allocation(top, k, seed=4, horizon=4000.0, warmup=200.0)
    # arrival rates measured in sim match the traffic equations
    np.testing.assert_allclose(
        res.per_op_arrival_rate, top.arrival_rates, rtol=0.06
    )
    assert res.mean_visit_sum == pytest.approx(top.expected_sojourn(k), rel=0.1)


def test_split_join_makespan_below_visit_sum():
    """Parallel branches overlap: complete sojourn (makespan) <= visit sum.
    This is the pipelining effect the paper lists as a model limitation."""
    ops = [OperatorSpec(n, 20.0) for n in "ABCD"]
    routing = np.zeros((4, 4))
    routing[0][1] = 1.0  # deterministic split: A -> B AND A -> C
    routing[0][2] = 1.0
    routing[1][3] = 1.0
    routing[2][3] = 1.0
    top = Topology(ops, np.array([4.0, 0, 0, 0]), routing)
    k = [1, 1, 1, 1]
    res = simulate_allocation(top, k, seed=5, horizon=2000.0, warmup=100.0)
    assert res.mean_sojourn < res.mean_visit_sum
    # Deterministic forks make the join's arrivals *correlated* (burstier
    # than the Poisson merge Jackson assumes), so the sim runs ~10% above
    # the model — a real, documented limitation (the paper's own Fig. 7 FPD
    # deviation has the same flavour).  Tolerance reflects that.
    assert res.mean_visit_sum == pytest.approx(top.expected_sojourn(k), rel=0.2)
    assert res.mean_visit_sum >= top.expected_sojourn(k)  # bursty joins hurt


def test_model_ranks_allocations_like_sim():
    """Fig. 6-7 claim: model ordering == measured ordering across configs."""
    top = Topology.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)
    configs = [(10, 11, 1), (9, 12, 1), (11, 10, 1), (8, 12, 2), (12, 8, 2), (7, 13, 2)]
    model = [top.expected_sojourn(list(c)) for c in configs]
    sim = [
        simulate_allocation(top, list(c), seed=10 + i, horizon=600.0, warmup=60.0).mean_sojourn
        for i, c in enumerate(configs)
    ]
    # The model-recommended best config must be the simulated best.
    assert int(np.argmin(model)) == int(np.argmin(sim))
    # Rank correlation (Spearman) must be strong and positive.
    mr, sr = np.argsort(np.argsort(model)), np.argsort(np.argsort(sim))
    rho = np.corrcoef(mr, sr)[0, 1]
    assert rho > 0.7


def test_drs_allocation_beats_neighbours_in_sim():
    """The DRS-recommended allocation wins in simulation (paper Fig. 6)."""
    top = Topology.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)
    best = assign_processors(top, 22).k
    best_sim = simulate_allocation(top, best, seed=42, horizon=900.0, warmup=90.0).mean_sojourn
    for d in ([-1, +1, 0], [+1, -1, 0], [-1, 0, +1], [0, -1, +1]):
        other = best + np.array(d)
        if (other >= top.min_feasible_allocation()).all():
            other_sim = simulate_allocation(
                top, other, seed=43, horizon=900.0, warmup=90.0
            ).mean_sojourn
            assert best_sim <= other_sim * 1.05  # allow sim noise


def test_robustness_to_uniform_arrivals():
    """Paper: model stays accurate under uniform (not exponential) arrivals."""
    top = Topology.chain([("a", 6.0), ("b", 9.0)], lam0=4.0)
    k = [2, 1]
    res = simulate_allocation(
        top, k, seed=6, horizon=3000.0, warmup=100.0, arrival_kind="uniform"
    )
    model = top.expected_sojourn(k)
    # Uniform arrivals are *less* bursty -> sim <= model, within 35%.
    assert res.mean_sojourn <= model * 1.05
    assert res.mean_sojourn >= model * 0.5


def test_network_delay_causes_underestimation():
    """Fig. 8: out-of-model network cost -> measured/estimated ratio > 1,
    decreasing as compute dominates."""
    ratios = []
    for mu in (50.0, 10.0, 2.0):  # light -> heavy compute per tuple
        top = Topology.chain([("a", mu), ("b", mu), ("c", mu)], lam0=1.0)
        k = list(top.min_feasible_allocation() + 1)
        res = simulate_allocation(
            top, k, seed=7, horizon=2000.0, warmup=100.0, network_delay=0.05
        )
        ratios.append(res.mean_sojourn / top.expected_sojourn(k))
    assert ratios[0] > 1.1  # light compute: network dominates -> underestimate
    assert ratios[0] > ratios[1] > ratios[2]  # decreasing trend
    assert ratios[2] < 1.25  # compute-heavy: model accurate


def test_unstable_allocation_queues_grow():
    """k below ceil(lam/mu): sojourn grows with horizon (no steady state)."""
    top = Topology.chain([("a", 2.0)], lam0=5.0)
    short = simulate_allocation(top, [2], seed=8, horizon=100.0, warmup=10.0)
    long = simulate_allocation(top, [2], seed=8, horizon=400.0, warmup=10.0)
    assert long.mean_sojourn > short.mean_sojourn * 1.5


def test_rebalance_event_improves_sojourn():
    """Fig. 9: switch from a bad to the optimal allocation mid-run."""
    top = Topology.chain([("extract", 2.0), ("match", 5.0), ("agg", 50.0)], lam0=13.0)
    bad = np.array([8, 12, 2])
    good = assign_processors(top, 22).k
    sim = NetworkSimulator(
        top,
        bad,
        config=SimConfig(seed=9, horizon=1200.0, warmup=0.0),
        arrivals=[ArrivalProcess(13.0), ArrivalProcess(0.0), ArrivalProcess(0.0)],
        services=[ServiceProcess(op.mu) for op in top.operators],
    )
    sim.rebalance_at(600.0, good, pause=2.0)
    res = sim.run()
    ts = np.array([t for t, _ in res.sojourn_series])
    sj = np.array([s for _, s in res.sojourn_series])
    before = sj[(ts > 100) & (ts < 600)].mean()
    after = sj[ts > 700].mean()
    assert after < before
    assert after == pytest.approx(top.expected_sojourn(good), rel=0.15)


def test_straggler_mu_drop_visible_in_measurements():
    """Service-rate drop mid-run shows up in the measured sojourn."""
    top = Topology.chain([("a", 10.0)], lam0=5.0)
    sim = NetworkSimulator(
        top, [1], config=SimConfig(seed=11, horizon=800.0, warmup=0.0)
    )
    sim.schedule_rate_change(400.0, 0, 6.5)  # degraded server
    res = sim.run()
    ts = np.array([t for t, _ in res.sojourn_series])
    sj = np.array([s for _, s in res.sojourn_series])
    before = sj[(ts > 50) & (ts < 400)].mean()
    after = sj[ts > 450].mean()
    assert before == pytest.approx(1.0 / (10 - 5), rel=0.2)
    assert after == pytest.approx(1.0 / (6.5 - 5), rel=0.3)
    assert after > before * 2
