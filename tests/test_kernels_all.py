"""Pallas kernels vs jnp oracles (interpret=True), with shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no dev deps installed — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.decode_attention import kernel as dk, ref as dref
from repro.kernels.flash_attention import kernel as fk, ref as fref
from repro.kernels.rwkv6_scan import kernel as rk, ref as rref
from repro.kernels.ssd_scan import kernel as sk, ref as sref
from repro.kernels.swiglu import kernel as gk, ref as gref


def rand(shape, dtype, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("sq,skv,bq,bk", [(128, 128, 64, 64), (128, 256, 64, 128)])
def test_flash_attention_causal(dtype, tol, sq, skv, bq, bk):
    b, h, dh = 1, 2, 64
    q = rand((b, h, sq, dh), dtype, 0)
    k = rand((b, h, skv, dh), dtype, 1)
    v = rand((b, h, skv, dh), dtype, 2)
    got = fk.flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    want = fref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_flash_attention_sliding_window():
    b, h, s, dh = 1, 1, 256, 32
    q, k, v = (rand((b, h, s, dh), jnp.float32, i) for i in range(3))
    got = fk.flash_attention_pallas(q, k, v, causal=True, window=64, bq=64, bk=64, interpret=True)
    want = fref.attention(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_bidirectional():
    b, h, s, dh = 2, 1, 128, 32
    q, k, v = (rand((b, h, s, dh), jnp.float32, 10 + i) for i in range(3))
    got = fk.flash_attention_pallas(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    want = fref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("length", [1, 100, 256, 511])
def test_decode_attention_lengths(length):
    b, h, s, dh = 2, 4, 512, 32
    q = rand((b, h, dh), jnp.float32, 0)
    kc = rand((b, s, h, dh), jnp.float32, 1)
    vc = rand((b, s, h, dh), jnp.float32, 2)
    got = dk.decode_attention_pallas(q, kc, vc, jnp.int32(length), bs=128, interpret=True)
    want = dref.decode_attention(q, kc, vc, jnp.int32(length))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_decode_attention_window():
    b, h, s, dh = 1, 2, 512, 32
    q = rand((b, h, dh), jnp.float32, 3)
    kc = rand((b, s, h, dh), jnp.float32, 4)
    vc = rand((b, s, h, dh), jnp.float32, 5)
    got = dk.decode_attention_pallas(q, kc, vc, jnp.int32(400), window=64, bs=128, interpret=True)
    want = dref.decode_attention(q, kc, vc, jnp.int32(400), window=64)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_decode_attention_matches_train_attention_last_row():
    """Decode at length L must equal full attention's last row."""
    b, h, s, dh = 1, 2, 256, 32
    q_full = rand((b, h, s, dh), jnp.float32, 6)
    kc = rand((b, s, h, dh), jnp.float32, 7)
    vc = rand((b, s, h, dh), jnp.float32, 8)
    k_hf = jnp.moveaxis(kc, 2, 1)
    v_hf = jnp.moveaxis(vc, 2, 1)
    full = fref.attention(q_full, k_hf, v_hf, causal=True)
    got = dk.decode_attention_pallas(q_full[:, :, -1], kc, vc, jnp.int32(s), bs=64, interpret=True)
    np.testing.assert_allclose(got, full[:, :, -1], rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# swiglu
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 5e-2)])
def test_swiglu_fused(dtype, tol):
    t, d, f = 128, 64, 256
    x = rand((t, d), dtype, 0)
    wg, wu = rand((d, f), dtype, 1), rand((d, f), dtype, 2)
    wo = rand((f, d), dtype, 3)
    got = np.asarray(gk.swiglu_pallas(x, wg, wu, wo, bt=64, bf=64, interpret=True), np.float32)
    want = np.asarray(gref.swiglu(x, wg, wu, wo), np.float32)
    # atol scales with output magnitude: bf16 rounding noise on the f=256
    # contraction lands on outputs spanning +-1000.
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.abs(want).max())


@given(
    bt=st.sampled_from([32, 64, 128]),
    bf=st.sampled_from([64, 128, 256]),
)
@settings(max_examples=6, deadline=None)
def test_swiglu_block_invariance(bt, bf):
    t, d, f = 128, 32, 256
    x = rand((t, d), jnp.float32, 9)
    wg, wu, wo = rand((d, f), jnp.float32, 10), rand((d, f), jnp.float32, 11), rand((f, d), jnp.float32, 12)
    got = gk.swiglu_pallas(x, wg, wu, wo, bt=bt, bf=bf, interpret=True)
    want = gref.swiglu(x, wg, wu, wo)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# rwkv6 scan
# --------------------------------------------------------------------- #
def _ref_rwkv_stream(r, k, v, lw, u, s0, chunk):
    """Chain the single-chunk oracle across chunks."""
    s = r.shape[0]
    outs = []
    state = s0
    for i in range(0, s, chunk):
        o, state = rref.rwkv6_chunk(
            r[i : i + chunk], k[i : i + chunk], v[i : i + chunk], lw[i : i + chunk], u, state
        )
        outs.append(o)
    return jnp.concatenate(outs, axis=0), state


def test_rwkv6_scan_kernel_matches_oracle():
    bh, s, dk_, dv, chunk = 3, 128, 16, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = jax.random.normal(ks[0], (bh, s, dk_)) * 0.5
    k = jax.random.normal(ks[1], (bh, s, dk_)) * 0.5
    v = jax.random.normal(ks[2], (bh, s, dv)) * 0.5
    lw = -jax.random.uniform(ks[3], (bh, s, dk_), minval=0.01, maxval=1.5)
    u = jax.random.normal(ks[4], (bh, dk_)) * 0.3
    s0 = jax.random.normal(ks[5], (bh, dk_, dv)) * 0.2
    got_o, got_s = rk.rwkv6_scan_pallas(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    for i in range(bh):
        want_o, want_s = _ref_rwkv_stream(r[i], k[i], v[i], lw[i], u[i], s0[i], chunk)
        np.testing.assert_allclose(got_o[i], want_o, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(got_s[i], want_s, rtol=5e-3, atol=5e-3)


def test_rwkv6_kernel_matches_model_recurrence():
    """Kernel vs the models/ssm.py step recurrence (end-to-end truth)."""
    from repro.models.ssm import rwkv6_step

    bh, s, d = 2, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (bh, s, d)) * 0.5
    k = jax.random.normal(ks[1], (bh, s, d)) * 0.5
    v = jax.random.normal(ks[2], (bh, s, d)) * 0.5
    w = jax.random.uniform(ks[3], (bh, s, d), minval=0.5, maxval=0.99)
    # one shared bonus row: the naive loop below treats bh as batch with a
    # single head, so u must be identical across streams
    u = jnp.broadcast_to(jax.random.normal(ks[4], (1, d)) * 0.3, (bh, d))
    s0 = jnp.zeros((bh, d, d))
    got_o, got_s = rk.rwkv6_scan_pallas(r, k, v, jnp.log(w), u, s0, chunk=16, interpret=True)
    # naive recurrence, per stream (treat bh as batch with 1 head)
    state = s0[:, None]
    outs = []
    for t in range(s):
        o, state = rwkv6_step(
            r[:, t, None], k[:, t, None], v[:, t, None], w[:, t, None], u[:1], state
        )
        outs.append(o[:, 0])
    want_o = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(got_o, want_o, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(got_s, state[:, 0], rtol=5e-3, atol=5e-3)


# --------------------------------------------------------------------- #
# ssd scan
# --------------------------------------------------------------------- #
def test_ssd_scan_kernel_matches_oracle():
    bh, s, dh, dst, chunk = 2, 128, 16, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (bh, s, dh)) * 0.5
    a = -jax.random.uniform(ks[1], (bh, s), minval=0.01, maxval=1.0)
    b = jax.random.normal(ks[2], (bh, s, dst)) * 0.5
    c = jax.random.normal(ks[3], (bh, s, dst)) * 0.5
    s0 = jax.random.normal(ks[4], (bh, dst, dh)) * 0.2
    got_y, got_s = sk.ssd_scan_pallas(x, a, b, c, s0, chunk=chunk, interpret=True)
    for i in range(bh):
        state = s0[i]
        outs = []
        for j in range(0, s, chunk):
            y, state = sref.ssd_chunk(
                x[i, j : j + chunk], a[i, j : j + chunk], b[i, j : j + chunk],
                c[i, j : j + chunk], state,
            )
            outs.append(y)
        want_y = jnp.concatenate(outs, axis=0)
        np.testing.assert_allclose(got_y[i], want_y, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(got_s[i], state, rtol=5e-3, atol=5e-3)


def test_ssd_kernel_matches_model_chunked():
    """Kernel vs models/ssm.py ssd_chunked (the train-path implementation)."""
    from repro.models.ssm import ssd_chunked

    bh, s, dh, dst = 2, 64, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (bh, s, dh)) * 0.5
    a = -jax.random.uniform(ks[1], (bh, s), minval=0.01, maxval=1.0)
    b = jax.random.normal(ks[2], (bh, s, dst)) * 0.5
    c = jax.random.normal(ks[3], (bh, s, dst)) * 0.5
    s0 = jnp.zeros((bh, dst, dh))
    got_y, got_s = sk.ssd_scan_pallas(x, a, b, c, s0, chunk=16, interpret=True)
    want_y, want_s = ssd_chunked(
        x[:, :, None], a[:, :, None], b[:, :, None], c[:, :, None],
        chunk=16, initial_state=s0[:, None],
    )
    np.testing.assert_allclose(got_y, want_y[:, :, 0], rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(got_s, want_s[:, 0], rtol=5e-3, atol=5e-3)
