"""Pallas kernels vs jnp oracles (interpret=True), with shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no dev deps installed — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.decide_fused import ops as ddops, ref as ddref
from repro.kernels.decode_attention import kernel as dk, ref as dref
from repro.kernels.erlang_c import ref as eref
from repro.kernels.flash_attention import kernel as fk, ref as fref
from repro.kernels.gain_topr import kernel as tk, ref as topr_ref
from repro.kernels.rwkv6_scan import kernel as rk, ref as rref
from repro.kernels.ssd_scan import kernel as sk, ref as sref
from repro.kernels.swiglu import kernel as gk, ref as gref


def rand(shape, dtype, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("sq,skv,bq,bk", [(128, 128, 64, 64), (128, 256, 64, 128)])
def test_flash_attention_causal(dtype, tol, sq, skv, bq, bk):
    b, h, dh = 1, 2, 64
    q = rand((b, h, sq, dh), dtype, 0)
    k = rand((b, h, skv, dh), dtype, 1)
    v = rand((b, h, skv, dh), dtype, 2)
    got = fk.flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    want = fref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_flash_attention_sliding_window():
    b, h, s, dh = 1, 1, 256, 32
    q, k, v = (rand((b, h, s, dh), jnp.float32, i) for i in range(3))
    got = fk.flash_attention_pallas(q, k, v, causal=True, window=64, bq=64, bk=64, interpret=True)
    want = fref.attention(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_bidirectional():
    b, h, s, dh = 2, 1, 128, 32
    q, k, v = (rand((b, h, s, dh), jnp.float32, 10 + i) for i in range(3))
    got = fk.flash_attention_pallas(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    want = fref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("length", [1, 100, 256, 511])
def test_decode_attention_lengths(length):
    b, h, s, dh = 2, 4, 512, 32
    q = rand((b, h, dh), jnp.float32, 0)
    kc = rand((b, s, h, dh), jnp.float32, 1)
    vc = rand((b, s, h, dh), jnp.float32, 2)
    got = dk.decode_attention_pallas(q, kc, vc, jnp.int32(length), bs=128, interpret=True)
    want = dref.decode_attention(q, kc, vc, jnp.int32(length))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_decode_attention_window():
    b, h, s, dh = 1, 2, 512, 32
    q = rand((b, h, dh), jnp.float32, 3)
    kc = rand((b, s, h, dh), jnp.float32, 4)
    vc = rand((b, s, h, dh), jnp.float32, 5)
    got = dk.decode_attention_pallas(q, kc, vc, jnp.int32(400), window=64, bs=128, interpret=True)
    want = dref.decode_attention(q, kc, vc, jnp.int32(400), window=64)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_decode_attention_matches_train_attention_last_row():
    """Decode at length L must equal full attention's last row."""
    b, h, s, dh = 1, 2, 256, 32
    q_full = rand((b, h, s, dh), jnp.float32, 6)
    kc = rand((b, s, h, dh), jnp.float32, 7)
    vc = rand((b, s, h, dh), jnp.float32, 8)
    k_hf = jnp.moveaxis(kc, 2, 1)
    v_hf = jnp.moveaxis(vc, 2, 1)
    full = fref.attention(q_full, k_hf, v_hf, causal=True)
    got = dk.decode_attention_pallas(q_full[:, :, -1], kc, vc, jnp.int32(s), bs=64, interpret=True)
    np.testing.assert_allclose(got, full[:, :, -1], rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# swiglu
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 5e-2)])
def test_swiglu_fused(dtype, tol):
    t, d, f = 128, 64, 256
    x = rand((t, d), dtype, 0)
    wg, wu = rand((d, f), dtype, 1), rand((d, f), dtype, 2)
    wo = rand((f, d), dtype, 3)
    got = np.asarray(gk.swiglu_pallas(x, wg, wu, wo, bt=64, bf=64, interpret=True), np.float32)
    want = np.asarray(gref.swiglu(x, wg, wu, wo), np.float32)
    # atol scales with output magnitude: bf16 rounding noise on the f=256
    # contraction lands on outputs spanning +-1000.
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.abs(want).max())


@given(
    bt=st.sampled_from([32, 64, 128]),
    bf=st.sampled_from([64, 128, 256]),
)
@settings(max_examples=6, deadline=None)
def test_swiglu_block_invariance(bt, bf):
    t, d, f = 128, 32, 256
    x = rand((t, d), jnp.float32, 9)
    wg, wu, wo = rand((d, f), jnp.float32, 10), rand((d, f), jnp.float32, 11), rand((f, d), jnp.float32, 12)
    got = gk.swiglu_pallas(x, wg, wu, wo, bt=bt, bf=bf, interpret=True)
    want = gref.swiglu(x, wg, wu, wo)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- #
# rwkv6 scan
# --------------------------------------------------------------------- #
def _ref_rwkv_stream(r, k, v, lw, u, s0, chunk):
    """Chain the single-chunk oracle across chunks."""
    s = r.shape[0]
    outs = []
    state = s0
    for i in range(0, s, chunk):
        o, state = rref.rwkv6_chunk(
            r[i : i + chunk], k[i : i + chunk], v[i : i + chunk], lw[i : i + chunk], u, state
        )
        outs.append(o)
    return jnp.concatenate(outs, axis=0), state


def test_rwkv6_scan_kernel_matches_oracle():
    bh, s, dk_, dv, chunk = 3, 128, 16, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = jax.random.normal(ks[0], (bh, s, dk_)) * 0.5
    k = jax.random.normal(ks[1], (bh, s, dk_)) * 0.5
    v = jax.random.normal(ks[2], (bh, s, dv)) * 0.5
    lw = -jax.random.uniform(ks[3], (bh, s, dk_), minval=0.01, maxval=1.5)
    u = jax.random.normal(ks[4], (bh, dk_)) * 0.3
    s0 = jax.random.normal(ks[5], (bh, dk_, dv)) * 0.2
    got_o, got_s = rk.rwkv6_scan_pallas(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    for i in range(bh):
        want_o, want_s = _ref_rwkv_stream(r[i], k[i], v[i], lw[i], u[i], s0[i], chunk)
        np.testing.assert_allclose(got_o[i], want_o, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(got_s[i], want_s, rtol=5e-3, atol=5e-3)


def test_rwkv6_kernel_matches_model_recurrence():
    """Kernel vs the models/ssm.py step recurrence (end-to-end truth)."""
    from repro.models.ssm import rwkv6_step

    bh, s, d = 2, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (bh, s, d)) * 0.5
    k = jax.random.normal(ks[1], (bh, s, d)) * 0.5
    v = jax.random.normal(ks[2], (bh, s, d)) * 0.5
    w = jax.random.uniform(ks[3], (bh, s, d), minval=0.5, maxval=0.99)
    # one shared bonus row: the naive loop below treats bh as batch with a
    # single head, so u must be identical across streams
    u = jnp.broadcast_to(jax.random.normal(ks[4], (1, d)) * 0.3, (bh, d))
    s0 = jnp.zeros((bh, d, d))
    got_o, got_s = rk.rwkv6_scan_pallas(r, k, v, jnp.log(w), u, s0, chunk=16, interpret=True)
    # naive recurrence, per stream (treat bh as batch with 1 head)
    state = s0[:, None]
    outs = []
    for t in range(s):
        o, state = rwkv6_step(
            r[:, t, None], k[:, t, None], v[:, t, None], w[:, t, None], u[:1], state
        )
        outs.append(o[:, 0])
    want_o = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(got_o, want_o, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(got_s, state[:, 0], rtol=5e-3, atol=5e-3)


# --------------------------------------------------------------------- #
# ssd scan
# --------------------------------------------------------------------- #
def test_ssd_scan_kernel_matches_oracle():
    bh, s, dh, dst, chunk = 2, 128, 16, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (bh, s, dh)) * 0.5
    a = -jax.random.uniform(ks[1], (bh, s), minval=0.01, maxval=1.0)
    b = jax.random.normal(ks[2], (bh, s, dst)) * 0.5
    c = jax.random.normal(ks[3], (bh, s, dst)) * 0.5
    s0 = jax.random.normal(ks[4], (bh, dst, dh)) * 0.2
    got_y, got_s = sk.ssd_scan_pallas(x, a, b, c, s0, chunk=chunk, interpret=True)
    for i in range(bh):
        state = s0[i]
        outs = []
        for j in range(0, s, chunk):
            y, state = sref.ssd_chunk(
                x[i, j : j + chunk], a[i, j : j + chunk], b[i, j : j + chunk],
                c[i, j : j + chunk], state,
            )
            outs.append(y)
        want_y = jnp.concatenate(outs, axis=0)
        np.testing.assert_allclose(got_y[i], want_y, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(got_s[i], state, rtol=5e-3, atol=5e-3)


# --------------------------------------------------------------------- #
# decide_fused: one pass from offered load to the Program-4 allocation
# --------------------------------------------------------------------- #
def _zoo_decide_case(seeds, extra_budget=24):
    """Zoo-derived decide inputs: random AppGraph topologies (chains,
    splits, joins, leaking loops) stacked into one padded [B, N] batch,
    with a few stable lanes flipped to gang ("group") scaling so both
    sojourn branches appear."""
    from repro.streaming.scenarios import random_appgraph

    tops = [random_appgraph(s).topology() for s in seeds]
    b, n = len(tops), max(t.n for t in tops)
    lam = np.zeros((b, n))
    mu = np.ones((b, n))
    group = np.zeros((b, n), dtype=bool)
    alpha = np.zeros((b, n))
    active = np.zeros((b, n), dtype=bool)
    rng = np.random.default_rng(seeds[0])
    for i, top in enumerate(tops):
        lam[i, : top.n] = top.arrival_rates
        mu[i, : top.n] = [op.mu for op in top.operators]
        active[i, : top.n] = top.arrival_rates > 0
        for lane in range(top.n):
            # group scaling saturates at mu/alpha; only flip lanes with
            # plenty of headroom so every lane stays feasible
            if rng.random() < 0.3 and lam[i, lane] < 0.2 * mu[i, lane] / 0.02:
                group[i, lane] = True
                alpha[i, lane] = 0.02
    k_cur = rng.integers(0, 6, size=(b, n)).astype(np.int32)
    floor = np.where(active, np.floor(lam / mu) + 1, 0).sum(axis=1)
    k_max = (floor + extra_budget).astype(np.int32)
    return lam, mu, group, alpha, active, k_cur, k_max


def _decide(fn, case, k_hi, **kw):
    lam, mu, group, alpha, active, k_cur, k_max = case
    return fn(lam, mu, group=group, alpha=alpha, active=active,
              k_cur=k_cur, k_max=k_max, k_hi=k_hi, **kw)


@pytest.mark.parametrize("seeds,k_hi", [((0, 1, 2, 3), 64), ((4, 5), 1024)])
def test_decide_fused_oracle_matches_numpy_twin_x64(seeds, k_hi):
    """jnp oracle == float64 numpy twin bit-for-bit under x64, across the
    zoo and up to K=1024."""
    case = _zoo_decide_case(seeds)
    with jax.experimental.enable_x64():
        got = _decide(ddref.batch_decide, case, k_hi)
    want = _decide(ddref.batch_decide_np, case, k_hi)
    for name, g, w in zip(("k4", "k_start", "t_cur", "t4"), got, want):
        np.testing.assert_array_equal(np.asarray(g), w, err_msg=name)


@pytest.mark.parametrize("x64", [False, True])
def test_decide_fused_matches_two_pass_decide_bitwise(x64):
    """The dispatch contract: make_decide_jax with the fused knob on must
    reproduce the two-pass erlang_c->gain_topr decide bit-for-bit on CPU,
    in both float32 and float64."""
    import contextlib

    import repro.core.controller as ctl
    from repro.api.session import ScenarioRunner
    from repro.streaming.scenarios import scenario_matrix

    scens = [
        s.with_(negotiated=False)
        for s in scenario_matrix(4, seed=17, horizon=20.0, warmup=5.0, dt=0.05)
    ]
    with jax.experimental.enable_x64() if x64 else contextlib.nullcontext():
        r = ScenarioRunner(scens, tick_interval=5.0, backend="jax")
        b, n = len(scens), r.static.n
        rng = np.random.default_rng(5)
        lam = np.abs(rng.normal(2.0, 0.6, (b, n)))
        mu = np.abs(rng.normal(6.0, 0.5, (b, n))) + 1.0
        drop = np.zeros((b, n))
        lam0 = np.abs(rng.normal(2.0, 0.5, b))
        k = np.where(r.static.active, 2, 0).astype(np.int64)
        two = ctl.make_decide_jax(r.static, r._params(), fused=False)(
            lam, mu, drop, lam0, k
        )
        one = ctl.make_decide_jax(r.static, r._params(), fused=True)(
            lam, mu, drop, lam0, k
        )
    for name, a, f in zip(("code", "k_next", "et_cur", "et_target", "applied"),
                          two, one):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(f), err_msg=name)


@pytest.mark.parametrize("seeds,k_hi,j_cap", [
    ((6, 7, 8), 64, None),     # B=3, zoo N is no tile multiple
    ((9, 10), 200, 48),        # truncated window through the kernel too
])
def test_decide_fused_kernel_interpret_matches_oracle(seeds, k_hi, j_cap):
    """Pallas kernel (interpret) vs the float32 oracle: the integer
    decision surface is exact; T gathers compare with the kernel-tier
    tolerance (loop vs vectorized FMA contraction)."""
    case = _zoo_decide_case(seeds)
    f32 = tuple(
        np.asarray(a, dtype=np.float32) if a.dtype.kind == "f" else a for a in case
    )
    got = _decide(ddops.batch_decide, f32, k_hi, j_cap=j_cap,
                  force_kernel=True, interpret=True)
    want = _decide(ddref.batch_decide, f32, k_hi, j_cap=j_cap)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]), err_msg="k4")
    np.testing.assert_array_equal(
        np.asarray(got[1]), np.asarray(want[1]), err_msg="k_start"
    )
    for name, g, w in zip(("t_cur", "t4"), got[2:], want[2:]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-6, err_msg=name
        )


def test_decide_fused_jcap_truncation_is_exact():
    """Window truncation to j_cap >= budget is provably lossless: gains
    are non-increasing per lane, so the selected set (ties included) is
    identical to the full-window selection — bitwise, not approximately."""
    case = _zoo_decide_case((11, 12, 13))
    k_max = case[-1]
    with jax.experimental.enable_x64():
        full = _decide(ddref.batch_decide, case, 128, j_cap=None)
        capped = _decide(ddref.batch_decide, case, 128, j_cap=int(k_max.max()))
    for name, a, b in zip(("k4", "k_start", "t_cur", "t4"), full, capped):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_decide_fused_erlang_unroll_is_bitwise_safe():
    """The scan-unroll perf knob must not change a single bit: unrolling
    only restructures the loop, every lane still runs the same float ops
    in the same order."""
    a = np.abs(np.random.default_rng(3).normal(4.0, 3.0, 96))
    base = np.asarray(eref.erlang_b_table(a, k_hi=512, unroll=1))
    for u in (2, 4, 8):
        np.testing.assert_array_equal(
            np.asarray(eref.erlang_b_table(a, k_hi=512, unroll=u)), base,
            err_msg=f"unroll={u}",
        )
    case = _zoo_decide_case((14, 15))
    u1 = _decide(ddref.batch_decide, case, 64, unroll=1)
    u4 = _decide(ddref.batch_decide, case, 64, unroll=4)
    for name, x, y in zip(("k4", "k_start", "t_cur", "t4"), u1, u4):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=name)


def test_gain_topr_padded_lanes_contribute_zero():
    """The hoisted pad-shape contract: tile padding rides through as zero
    gains, so hand-padding the candidate tile changes nothing — real
    lanes take identically and every padded lane takes exactly zero."""
    rng = np.random.default_rng(7)
    b, n, j = 3, 7, 12
    cand = np.where(rng.random((b, n, j)) < 0.7, rng.gamma(2.0, 1.0, (b, n, j)), 0.0)
    budget = np.array([5, 0, 40], dtype=np.int32)
    base = np.asarray(tk.gain_topr_pallas(cand, budget, interpret=True))
    padded = np.zeros((b, n + 13, j + 5), dtype=cand.dtype)
    padded[:, :n, :j] = cand
    out = np.asarray(tk.gain_topr_pallas(padded, budget, interpret=True))
    np.testing.assert_array_equal(out[:, :n], base)
    np.testing.assert_array_equal(out[:, n:], 0)
    np.testing.assert_array_equal(base, np.asarray(topr_ref.gain_topr(cand, budget)))


# --------------------------------------------------------------------- #
# compiled-backend lane: real pallas_call on TPU, interpret elsewhere.
# Deselected by default (pytest.ini); CI's test-kernels-compiled job runs
# `-m tpu`, compiling on an accelerator and falling back to the
# force_kernel+interpret path on CPU so the lane never goes dark.
# --------------------------------------------------------------------- #
@pytest.mark.tpu
def test_decide_fused_backend_lane():
    interpret = jax.default_backend() != "tpu"
    case = _zoo_decide_case((20, 21, 22))
    f32 = tuple(
        np.asarray(a, dtype=np.float32) if a.dtype.kind == "f" else a for a in case
    )
    got = _decide(ddops.batch_decide, f32, 128, j_cap=48,
                  force_kernel=True, interpret=interpret)
    want = _decide(ddref.batch_decide, f32, 128, j_cap=48)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    for g, w in zip(got[2:], want[2:]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-3, atol=1e-5)


@pytest.mark.tpu
def test_gain_topr_backend_lane():
    interpret = jax.default_backend() != "tpu"
    rng = np.random.default_rng(8)
    cand = rng.gamma(2.0, 1.0, (4, 9, 24))
    budget = np.array([3, 12, 0, 100], dtype=np.int32)
    got = np.asarray(tk.gain_topr_pallas(cand, budget, interpret=interpret))
    want = np.asarray(topr_ref.gain_topr(cand, budget))
    np.testing.assert_array_equal(got, want)


def test_ssd_kernel_matches_model_chunked():
    """Kernel vs models/ssm.py ssd_chunked (the train-path implementation)."""
    from repro.models.ssm import ssd_chunked

    bh, s, dh, dst = 2, 64, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (bh, s, dh)) * 0.5
    a = -jax.random.uniform(ks[1], (bh, s), minval=0.01, maxval=1.0)
    b = jax.random.normal(ks[2], (bh, s, dst)) * 0.5
    c = jax.random.normal(ks[3], (bh, s, dst)) * 0.5
    s0 = jnp.zeros((bh, dst, dh))
    got_y, got_s = sk.ssd_scan_pallas(x, a, b, c, s0, chunk=16, interpret=True)
    want_y, want_s = ssd_chunked(
        x[:, :, None], a[:, :, None], b[:, :, None], c[:, :, None],
        chunk=16, initial_state=s0[:, None],
    )
    np.testing.assert_allclose(got_y, want_y[:, :, 0], rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(got_s, want_s[:, 0], rtol=5e-3, atol=5e-3)
