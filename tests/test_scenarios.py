"""Scenario-matrix subsystem: trace zoo, random-topology zoo, and the
property-based model guarantees over generated graphs (ISSUE 4)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no dev deps installed — deterministic fallback sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.allocator import (
    assign_processors,
    assign_processors_naive,
    assign_processors_table,
)
from repro.core.batched import gain_table, solve_traffic_batch
from repro.core.jackson import solve_traffic_equations
from repro.streaming.scenarios import (
    ArrivalTrace,
    Scenario,
    fpd_scenario,
    pack_scenarios,
    random_appgraph,
    scenario_matrix,
    vld_scenario,
)


# ------------------------------------------------------------------ #
# Arrival-trace zoo
# ------------------------------------------------------------------ #
def grid(horizon=60.0, dt=0.5):
    return (np.arange(int(horizon / dt)) + 0.5) * dt


@pytest.mark.parametrize(
    "trace",
    [
        ArrivalTrace(kind="constant", rate=5.0),
        ArrivalTrace(kind="diurnal", rate=10.0, amplitude=8.0, period=30.0),
        ArrivalTrace(kind="flash", rate=5.0, peak=20.0, t_on=10.0, t_off=20.0),
        ArrivalTrace(kind="mmpp", rate=4.0, peak=16.0, switch01=0.2, switch10=0.3),
        ArrivalTrace(kind="replay", samples=(1.0, 5.0, 3.0, 8.0), sample_dt=10.0),
    ],
    ids=["constant", "diurnal", "flash", "mmpp", "replay"],
)
def test_trace_rates_deterministic_and_nonnegative(trace):
    t = grid()
    r1, r2 = trace.rates(t, seed=9), trace.rates(t, seed=9)
    np.testing.assert_array_equal(r1, r2)  # bit-identical across calls
    assert (r1 >= 0).all()
    assert r1.shape == t.shape


def test_trace_flash_and_replay_values():
    t = grid(40.0, 1.0)
    flash = ArrivalTrace(kind="flash", rate=2.0, peak=9.0, t_on=10.0, t_off=20.0)
    r = flash.rates(t)
    assert r[5] == 2.0 and r[15] == 9.0 and r[25] == 2.0
    replay = ArrivalTrace(kind="replay", samples=(1.0, 7.0), sample_dt=20.0)
    rr = replay.rates(t)
    assert rr[0] == 1.0 and rr[-1] == 7.0


def test_trace_mmpp_differs_across_seeds_not_within():
    t = grid(200.0, 0.5)
    tr = ArrivalTrace(kind="mmpp", rate=2.0, peak=20.0, switch01=0.2, switch10=0.2)
    a, b = tr.rates(t, seed=1), tr.rates(t, seed=2)
    assert not np.array_equal(a, b)  # different modulating paths
    assert set(np.unique(a)) <= {2.0, 20.0}


@pytest.mark.parametrize(
    "trace",
    [
        ArrivalTrace(kind="mmpp", rate=3.0, peak=18.0, switch01=0.15, switch10=0.1),
        ArrivalTrace(kind="diurnal", rate=12.0, amplitude=9.0, period=40.0),
        ArrivalTrace(kind="flash", rate=4.0, peak=17.0, t_on=20.0, t_off=45.0),
    ],
    ids=["mmpp", "diurnal", "flash"],
)
def test_trace_mean_rate_matches_trapezoid_of_rates(trace):
    """``mean_rate`` is exactly the trapezoid integral of ``rates()`` over
    the horizon grid divided by the covered span — the forecastability
    contract the predictors (repro/forecast) train against."""
    horizon, dt, seed = 120.0, 0.5, 13
    got1 = trace.mean_rate(horizon, seed, dt=dt)
    got2 = trace.mean_rate(horizon, seed, dt=dt)
    assert got1 == got2  # deterministic given (trace, seed)
    t_grid = np.arange(0.0, horizon + dt / 2.0, dt)
    r = trace.rates(t_grid, seed)
    want = (0.5 * (r[1:] + r[:-1]) * dt).sum() / (t_grid[-1] - t_grid[0])
    assert abs(got1 - want) <= 1e-9


def test_trace_validation_errors():
    with pytest.raises(ValueError):
        ArrivalTrace(kind="nope")
    with pytest.raises(ValueError):
        ArrivalTrace(kind="flash", rate=1.0)  # no peak
    with pytest.raises(ValueError):
        ArrivalTrace(kind="replay")  # no samples


# ------------------------------------------------------------------ #
# Random-topology zoo: structural validity
# ------------------------------------------------------------------ #
@settings(max_examples=25)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_zoo_graphs_are_valid_and_stable(seed):
    g = random_appgraph(seed)
    # AppGraph construction already validates; assert the zoo's own extras.
    assert g.spectral_radius < 0.95
    assert g.source_names, "zoo graph must have an external source"
    lam = solve_traffic_equations(g.lam0_vector(), g.routing_matrix())
    assert (lam >= 0).all()
    # Sources must reach every operator indirectly or the op is idle-valid;
    # the spine guarantees reachability, so traffic is positive everywhere.
    assert (lam[[g.index[n] for n in g.source_names]] > 0).all()


def test_zoo_hits_splits_joins_and_loops():
    """Across a modest seed sweep the zoo must produce every structural
    feature the paper's model claims to cover."""
    saw_split = saw_join = saw_loop = False
    for seed in range(60):
        p = random_appgraph(seed).routing_matrix()
        saw_split |= bool(((p > 0).sum(axis=1) > 1).any())
        saw_join |= bool(((p > 0).sum(axis=0) > 1).any())
        saw_loop |= bool(np.trace(p) > 0) or bool(np.tril(p, -1).sum() > 0)
    assert saw_split and saw_join and saw_loop


# ------------------------------------------------------------------ #
# Property: traffic equations on generated graphs
# ------------------------------------------------------------------ #
@settings(max_examples=20)
@given(seed=st.integers(min_value=0, max_value=10_000),
       scale=st.floats(min_value=0.1, max_value=4.0))
def test_traffic_solutions_nonnegative_and_batch_agrees(seed, scale):
    g = random_appgraph(seed)
    lam0 = scale * g.lam0_vector()
    p = g.routing_matrix()
    lam = solve_traffic_equations(lam0, p)
    assert (lam >= 0).all()
    assert lam.sum() >= lam0.sum() - 1e-9  # routing only adds derived traffic
    batch = solve_traffic_batch(np.stack([lam0, 2.0 * lam0]), p)
    np.testing.assert_allclose(batch[0], lam, atol=1e-9, rtol=1e-12)
    np.testing.assert_allclose(batch[1], 2.0 * lam, atol=1e-9, rtol=1e-9)


# ------------------------------------------------------------------ #
# Property: gain table monotone, allocators bit-identical
# ------------------------------------------------------------------ #
@settings(max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_gain_table_rows_monotone_non_increasing(seed):
    top = random_appgraph(seed).topology()
    _, G = gain_table(top, 48)
    finite = np.isfinite(G)
    both = finite[:, :-1] & finite[:, 1:]
    assert (G[:, 1:][both] <= G[:, :-1][both] + 1e-15).all(), (
        "marginal gains must be non-increasing in k (convexity, Ineq. 5)"
    )


@settings(max_examples=12)
@given(seed=st.integers(min_value=0, max_value=10_000),
       budget=st.integers(min_value=4, max_value=40))
def test_allocators_bit_identical_on_zoo_graphs(seed, budget):
    top = random_appgraph(seed).topology()
    k_min = int(top.min_feasible_allocation().sum())
    k_max = k_min + budget
    naive = assign_processors_naive(top, k_max)
    heap = assign_processors(top, k_max)
    table = assign_processors_table(top, k_max)
    np.testing.assert_array_equal(naive.k, heap.k)
    np.testing.assert_array_equal(naive.k, table.k)
    assert naive.expected_sojourn == heap.expected_sojourn == table.expected_sojourn


# ------------------------------------------------------------------ #
# Scenario spec + matrix generator
# ------------------------------------------------------------------ #
def test_scenario_validation():
    s = vld_scenario()
    with pytest.raises(ValueError):
        s.with_(traces={"nope": ArrivalTrace()})
    with pytest.raises(ValueError):
        s.with_(dt=0.0)
    with pytest.raises(ValueError):
        s.with_(warmup=s.horizon)
    with pytest.raises(ValueError):
        s.with_(overload_policy="drop-everything")


def test_scenario_matrix_is_seed_deterministic():
    a = scenario_matrix(6, seed=3, horizon=20.0, warmup=2.0)
    b = scenario_matrix(6, seed=3, horizon=20.0, warmup=2.0)
    assert [s.name for s in a] == [s.name for s in b]
    for sa, sb in zip(a, b):
        np.testing.assert_array_equal(sa.graph.routing_matrix(), sb.graph.routing_matrix())
        np.testing.assert_array_equal(sa.sample_arrivals(), sb.sample_arrivals())
        assert sa.overload_policy == sb.overload_policy
        assert sa.allocator == sb.allocator
    c = scenario_matrix(6, seed=4, horizon=20.0, warmup=2.0)
    assert any(
        not np.array_equal(x.sample_arrivals(), y.sample_arrivals())
        for x, y in zip(a, c)
    )


def test_scenario_matrix_covers_the_axes():
    scens = scenario_matrix(12, seed=0, horizon=20.0, warmup=2.0)
    kinds = {next(iter(s.traces.values())).kind for s in scens}
    assert {"constant", "diurnal", "flash", "mmpp"} <= kinds
    assert {str(s.overload_policy) for s in scens} >= {"shed-newest", "shed-oldest", "block"}
    assert {s.allocator for s in scens} == {"table", "heap"}
    assert any(s.queue_capacity is not None for s in scens)
    assert any(s.t_max is not None for s in scens)
    assert any(s.negotiated for s in scens)
    # the axes must be decorrelated, not functions of one another: the
    # flash kind appears with a bounded queue (it can actually shed), and
    # the heap allocator appears with a t_max (Program 6 via heap runs)
    assert any(
        next(iter(s.traces.values())).kind == "flash" and s.queue_capacity is not None
        for s in scens
    )
    assert any(s.allocator == "heap" and s.t_max is not None for s in scens)


def test_pack_scenarios_pads_inactive_lanes():
    scens = [vld_scenario(horizon=20.0, warmup=2.0, dt=0.1),
             fpd_scenario(horizon=20.0, warmup=2.0, dt=0.1)]
    # different op counts would pad; here both are 3-op graphs, so grow one
    scens.append(
        Scenario(
            name="five",
            graph=random_appgraph(1, n_ops=(5, 5)),
            horizon=20.0, warmup=2.0, dt=0.1,
        )
    )
    arrays = pack_scenarios(scens)
    assert arrays.n == 5
    assert arrays.active[0].sum() == 3 and arrays.active[2].sum() == 5
    # padding lanes carry no external mass and no routing
    assert arrays.ext[:, 0, 3:].sum() == 0
    assert arrays.routing[0, 3:, :].sum() == 0 and arrays.routing[0, :, 3:].sum() == 0


def test_pack_rejects_mixed_grids():
    with pytest.raises(ValueError):
        pack_scenarios([vld_scenario(horizon=20.0, warmup=2.0, dt=0.1),
                        fpd_scenario(horizon=30.0, warmup=2.0, dt=0.1)])


def test_canonical_scenarios_shapes():
    v, f = vld_scenario(), fpd_scenario()
    assert v.graph.names == ["extract", "match", "aggregate"]
    assert f.graph.names == ["generate", "detect", "report"]
    assert f.graph.routing_matrix()[1, 1] > 0  # the detector self-loop
    # model-only: no compute fns required
    assert all(op.fn is None for op in v.graph.ops)
