"""shard_map expert-parallel MoE == GSPMD MoE (multi-device subprocess).

Device count locks at jax init, so the 8-device check runs as a
subprocess (tests/_ep_equiv_main.py); this wrapper asserts its outcome.
"""

import os
import subprocess
import sys
from pathlib import Path



def test_ep_equivalence_8dev():
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "_ep_equiv_main.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-2000:]}"
    assert "forward OK" in proc.stdout
    assert "grads OK" in proc.stdout


def test_ep_falls_back_without_mesh():
    """Single device, no mesh context: moe_layer_ep == moe_layer (fallback)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models.common import ModelConfig
    from repro.models.ffn import moe_layer, moe_layer_ep

    cfg = ModelConfig(
        arch="t", family="moe", n_layers=1, d_model=16, n_heads=2, n_kv_heads=1,
        d_ff=32, vocab=32, n_experts=4, top_k=2, capacity_factor=8.0,
        dtype=jnp.float32,
    )
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    e, d, f = 4, 16, 32
    params = {
        "router": jax.random.normal(ks[0], (d, e)) * 0.3,
        "wi_gate": jax.random.normal(ks[1], (e, d, f)) * 0.1,
        "wi_up": jax.random.normal(ks[2], (e, d, f)) * 0.1,
        "wo": jax.random.normal(ks[3], (e, f, d)) * 0.1,
    }
    x = jax.random.normal(ks[4], (2, 8, d))
    a, _ = moe_layer(params, x, cfg)
    b, _ = moe_layer_ep(params, x, cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
